//! The training-mechanism modes of §5: default federated split finding
//! vs. the mix tree mode (parties alternate whole trees) vs. the layered
//! tree mode (hosts build the top layers, the guest the rest).
//!
//!     cargo run --release --example tree_modes

use sbp::prelude::*;

fn main() -> anyhow::Result<()> {
    let spec = SyntheticSpec::higgs(0.0005); // 5,500 × 28 (13 guest / 15 host)
    let vs = spec.generate_vertical(3, 1);

    let mut base = TrainConfig::secureboost_plus();
    base.epochs = 8;
    base.key_bits = 512;

    let configs = [
        ("default", base.clone()),
        ("mix", base.clone().with_mode(ModeKind::Mix { trees_per_party: 1 })),
        (
            "layered",
            base.clone()
                .with_mode(ModeKind::Layered { guest_depth: 2, host_depth: 3 }),
        ),
    ];

    println!(
        "{:<10} {:>10} {:>10} {:>12} {:>10}",
        "mode", "avg tree", "AUC", "traffic MiB", "net sim"
    );
    for (name, cfg) in configs {
        let rep = train_federated(&vs, &cfg)?;
        println!(
            "{:<10} {:>9.3}s {:>10.4} {:>12.2} {:>9.2}s",
            name,
            rep.avg_tree_seconds,
            rep.train_metric,
            rep.comm.total_bytes() as f64 / 1048576.0,
            rep.simulated_network_seconds
        );
    }
    println!("\nExpected shape (paper Fig. 8 / Table 4): mix < layered < default in");
    println!("time and traffic, with only minor AUC loss for mix/layered.");
    Ok(())
}
