//! Quickstart: train a small SecureBoost+ model on a synthetic
//! give-credit-shaped dataset with one guest and one host.
//!
//!     cargo run --release --example quickstart

use sbp::prelude::*;

fn main() -> anyhow::Result<()> {
    // A 1%-scale copy of the paper's give-credit dataset (Table 2):
    // 1,500 instances × 10 features, 5 on the guest / 5 on the host.
    let spec = SyntheticSpec::give_credit(0.01);
    let vs = spec.generate_vertical(/*seed=*/ 42, /*n_hosts=*/ 1);
    println!(
        "dataset: {} — {} instances, {} guest + {} host features",
        vs.name,
        vs.n(),
        vs.guest.d(),
        vs.hosts[0].d()
    );

    // SecureBoost+ defaults (paper §7.1) with a shorter run and a small
    // Paillier key so the example finishes in seconds.
    let mut cfg = TrainConfig::secureboost_plus();
    cfg.epochs = 10;
    cfg.key_bits = 512;
    cfg.verbose = true;

    let report = train_federated(&vs, &cfg)?;
    println!("\n{}", report.summary());
    println!(
        "per-tree: {:?}",
        report
            .tree_seconds
            .iter()
            .map(|s| format!("{s:.2}s"))
            .collect::<Vec<_>>()
    );
    println!("train AUC = {:.4}", report.train_metric);
    println!(
        "traffic: {:.2} MiB ({} messages), ≈{:.2}s on the paper's 1 GbE link",
        report.comm.total_bytes() as f64 / (1024.0 * 1024.0),
        report.comm.msgs_to_host + report.comm.msgs_to_guest,
        report.simulated_network_seconds
    );
    Ok(())
}
