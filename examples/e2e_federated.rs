//! End-to-end driver — proves all layers compose on a real workload.
//!
//! Pipeline exercised:
//!   L1/L2  AOT JAX/Pallas artifacts (g/h, histogram, gain kernels)
//!          executed through the PJRT runtime (`XlaEngine`) — falls back
//!          to the pure-Rust engine with a warning if `make artifacts`
//!          hasn't run,
//!   L3     full federated protocol: Paillier-1024 ciphertext histograms
//!          with GH packing, histogram subtraction, cipher compressing,
//!          GOSS and sparse optimization, guest + host threads,
//!          byte-accounted transport.
//!
//! Workload: susy-shaped binary task at 0.4% scale (20,000 × 18) — the
//! largest of the paper's presets that finishes in ~a minute here.
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//!     cargo run --release --example e2e_federated

use sbp::prelude::*;
use sbp::runtime::pjrt::XlaEngine;

fn main() -> anyhow::Result<()> {
    let scale = std::env::var("E2E_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.004);
    let spec = SyntheticSpec::susy(scale);
    let vs = spec.generate_vertical(2024, 1);
    println!(
        "workload: {} — {} instances × {} features ({} guest / {} host), binary",
        vs.name,
        vs.n(),
        vs.d_total(),
        vs.guest.d(),
        vs.hosts[0].d()
    );

    let mut cfg = TrainConfig::secureboost_plus();
    cfg.epochs = std::env::var("E2E_EPOCHS").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
    cfg.key_bits = 1024; // the paper's key length
    cfg.verbose = true;

    let engine: Box<dyn ComputeEngine> = match XlaEngine::load(XlaEngine::default_dir()) {
        Ok(e) => {
            println!(
                "engine: xla-pjrt (AOT artifacts, tiles N={} F={} B={} K={})",
                e.tiles.n_tile, e.tiles.f_tile, e.tiles.bins, e.tiles.k_tile
            );
            Box::new(e)
        }
        Err(err) => {
            println!("engine: cpu fallback ({err:#}) — run `make artifacts` for the AOT path");
            Box::new(CpuEngine)
        }
    };

    let t0 = std::time::Instant::now();
    let report =
        sbp::coordinator::train_federated_with_engine(&vs, &cfg, engine.as_ref())?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n================ E2E REPORT ================");
    println!("{}", report.summary());
    println!("loss curve:");
    for (i, l) in report.loss_curve.iter().enumerate() {
        println!("  epoch {:>2}  logloss {:.5}", i + 1, l);
    }
    println!("train AUC: {:.4}", report.train_metric);
    println!("wall time: {wall:.1}s (trees: {:.1}s)", report.total_tree_seconds);
    println!(
        "HE ops: enc={} dec={} add={} smul={} neg={}",
        report.ops.encrypts,
        report.ops.decrypts,
        report.ops.adds,
        report.ops.scalar_muls,
        report.ops.negates
    );
    println!(
        "traffic: {:.2} MiB guest→host, {:.2} MiB host→guest, {} msgs, ≈{:.2}s @1GbE",
        report.comm.bytes_to_host as f64 / 1048576.0,
        report.comm.bytes_to_guest as f64 / 1048576.0,
        report.comm.msgs_to_host + report.comm.msgs_to_guest,
        report.simulated_network_seconds
    );
    println!("phase breakdown:\n{}", report.phase_report);

    // sanity gates so CI catches regressions
    assert!(report.train_metric > 0.75, "AUC regression: {}", report.train_metric);
    assert!(
        report.loss_curve.last().unwrap() < report.loss_curve.first().unwrap(),
        "loss must decrease"
    );
    println!("E2E OK");
    Ok(())
}
