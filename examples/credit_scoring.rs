//! Credit scoring — the paper's motivating cross-silo scenario: a bank
//! (guest, holds default labels + bureau features) and an e-commerce
//! platform (host, holds behavioural features) jointly train a risk
//! model without exchanging raw data.
//!
//! Compares the three trust/performance points:
//!   1. centralized XGB-style training (upper bound, no privacy),
//!   2. SecureBoost (FATE-1.5 baseline, fully encrypted, slow),
//!   3. SecureBoost+ (fully encrypted + the paper's optimizations).
//!
//!     cargo run --release --example credit_scoring

use sbp::crypto::cipher::OPS;
use sbp::prelude::*;

fn main() -> anyhow::Result<()> {
    let spec = SyntheticSpec::give_credit(0.02); // 3,000 × 10
    let vs = spec.generate_vertical(7, 1);
    let ds = vs.to_centralized();

    let mut plus = TrainConfig::secureboost_plus();
    plus.epochs = 8;
    plus.key_bits = 512;
    let mut baseline = TrainConfig::secureboost_baseline();
    baseline.epochs = 8;
    baseline.key_bits = 512;

    println!("== 1. centralized (no privacy) ==");
    let cen = train_centralized(&ds, &plus)?;
    println!("{}\n", cen.summary());

    println!("== 2. SecureBoost baseline (Paillier-512) ==");
    OPS.reset();
    let base = train_federated(&vs, &baseline)?;
    println!("{}", base.summary());
    println!(
        "   HE ops: enc={} dec={} add={}\n",
        base.ops.encrypts, base.ops.decrypts, base.ops.adds
    );

    println!("== 3. SecureBoost+ (Paillier-512) ==");
    OPS.reset();
    let plus_rep = train_federated(&vs, &plus)?;
    println!("{}", plus_rep.summary());
    println!(
        "   HE ops: enc={} dec={} add={}",
        plus_rep.ops.encrypts, plus_rep.ops.decrypts, plus_rep.ops.adds
    );

    println!("\n== summary ==");
    println!(
        "AUC: centralized {:.4} | SecureBoost {:.4} | SecureBoost+ {:.4}",
        cen.train_metric, base.train_metric, plus_rep.train_metric
    );
    let speedup = base.avg_tree_seconds / plus_rep.avg_tree_seconds;
    println!(
        "tree time: SecureBoost {:.3}s → SecureBoost+ {:.3}s ({speedup:.1}× faster, paper Fig. 7 shape)",
        base.avg_tree_seconds, plus_rep.avg_tree_seconds
    );
    println!(
        "traffic:   {:.2} MiB → {:.2} MiB",
        base.comm.total_bytes() as f64 / 1048576.0,
        plus_rep.comm.total_bytes() as f64 / 1048576.0
    );
    Ok(())
}
