//! SecureBoost-MO (§5.3): multi-output trees for multi-class tasks.
//! One MO tree per boosting round instead of one tree per class — far
//! fewer trees (and federation rounds) for the same accuracy
//! (paper Fig. 9/10, Table 5).
//!
//!     cargo run --release --example multiclass_mo

use sbp::prelude::*;

fn main() -> anyhow::Result<()> {
    let spec = SyntheticSpec::sensorless(0.01); // 585 × 48, 11 classes
    let vs = spec.generate_vertical(5, 1);
    println!(
        "dataset: {} — {} instances, {} classes",
        vs.name,
        vs.n(),
        vs.n_classes
    );

    let mut ova = TrainConfig::secureboost_plus();
    ova.epochs = 5;
    ova.key_bits = 512;
    ova.goss = None;

    let mut mo = ova.clone().with_mode(ModeKind::MultiOutput);
    mo.cipher_compression = false; // paper: compression disabled for MO

    println!("\n== one-vs-all (traditional GBDT multi-class) ==");
    let rep_ova = train_federated(&vs, &ova)?;
    println!("{}", rep_ova.summary());

    println!("\n== SecureBoost-MO ==");
    let rep_mo = train_federated(&vs, &mo)?;
    println!("{}", rep_mo.summary());

    println!("\n== comparison (paper Fig. 9/10 shape) ==");
    println!(
        "trees:      {} (OvA) vs {} (MO)  — {}× fewer",
        rep_ova.trees_built,
        rep_mo.trees_built,
        rep_ova.trees_built / rep_mo.trees_built.max(1)
    );
    println!(
        "total time: {:.2}s vs {:.2}s",
        rep_ova.total_tree_seconds, rep_mo.total_tree_seconds
    );
    println!(
        "accuracy:   {:.4} vs {:.4}",
        rep_ova.train_metric, rep_mo.train_metric
    );
    Ok(())
}
