//! Reactor-host regression tests: 256 concurrent sessions on a handful
//! of worker threads (host thread count bounded by workers + constant,
//! not by session count), a dead peer reaped by the idle timeout
//! without disturbing its neighbors, and transient accept errors
//! (fd exhaustion) survived with backoff instead of draining the
//! service.

use sbp::config::{CipherKind, TrainConfig};
use sbp::coordinator::{predict_centralized, predict_sessions_tcp, serve_predict_tcp, ServeReport};
use sbp::crypto::cipher::CipherSuite;
use sbp::data::dataset::VerticalSplit;
use sbp::data::synthetic::SyntheticSpec;
use sbp::federation::message::{ToGuest, ToHost, SERVE_PROTOCOL_VERSION};
use sbp::federation::predict::{PredictOptions, PredictSession};
use sbp::federation::serve::{
    serve_predict_loop_on, AcceptSource, HostServeState, ServeConfig, ServeLoopReport,
};
use sbp::federation::tcp::TcpGuestTransport;
use sbp::federation::transport::GuestTransport;
use sbp::tree::predict::{GuestModel, HostModel};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Duration;

type Links = Vec<Box<dyn GuestTransport>>;

fn fast_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::secureboost_plus();
    cfg.epochs = 4;
    cfg.max_depth = 3;
    cfg.cipher = CipherKind::Plain;
    cfg.goss = None;
    cfg.sparse_optimization = false;
    cfg
}

fn train(spec: SyntheticSpec, cfg: &TrainConfig) -> (VerticalSplit, GuestModel, Vec<HostModel>) {
    let vs = spec.generate_vertical(cfg.seed, cfg.n_hosts);
    let rep = sbp::coordinator::train_federated(&vs, cfg).expect("training run");
    let (guest_m, host_ms) = rep.model();
    (vs, guest_m, host_ms)
}

fn start_server(
    vs: &VerticalSplit,
    host_ms: &[HostModel],
    cfg: ServeConfig,
    max_sessions: usize,
) -> (String, std::thread::JoinHandle<ServeReport>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    let model = host_ms[0].clone();
    let slice = vs.hosts[0].clone();
    let handle = std::thread::spawn(move || {
        serve_predict_tcp(&listener, model, slice, cfg, max_sessions).expect("serve loop")
    });
    (addr, handle)
}

/// Threads in this process right now (Linux: one entry per task).
/// Returns 0 where /proc is unavailable, which turns the bounded-thread
/// assertion into a no-op rather than a false failure.
fn thread_count() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

/// The tentpole regression: 256 sessions live at once on a 4-worker
/// reactor. The old architecture pinned two OS threads per session
/// (512+); the reactor must stay at workers + constant while every
/// session still bit-matches centralized scoring.
#[test]
fn reactor_serves_256_concurrent_sessions_with_bounded_threads() {
    const SESSIONS: usize = 256;
    const WORKERS: usize = 4;
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);

    let threads_before = thread_count();
    let (addr, server) = start_server(
        &vs,
        &host_ms,
        ServeConfig { workers: WORKERS, ..ServeConfig::default() },
        SESSIONS,
    );

    // open every session before predicting on any, so all 256 are
    // resident on the host at the same time
    let mut open: Vec<(PredictSession<'_>, Links)> = Vec::with_capacity(SESSIONS);
    for s in 0..SESSIONS {
        let links: Links = vec![Box::new(
            TcpGuestTransport::connect(&addr, CipherSuite::new_plain(64)).expect("connect"),
        )];
        let mut session = PredictSession::new(&guest_m, (s + 1) as u32, PredictOptions::default());
        session.open(&links);
        open.push((session, links));
    }

    // with 256 sessions resident the host must not have grown by
    // hundreds of threads: workers + accept loop + slack for the test
    // harness's own concurrency, far under one thread per session
    let threads_during = thread_count();
    assert!(
        threads_during <= threads_before + WORKERS + 16,
        "host threads must be bounded by workers + constant: \
         {threads_before} before, {threads_during} with {SESSIONS} live sessions"
    );

    for (session, links) in &mut open {
        let preds = session.predict_batch(&vs.guest, links);
        assert_eq!(preds, oracle, "session {} must bit-match centralized", session.session_id());
    }
    for (session, links) in open {
        session.close(&links);
    }

    let report = server.join().expect("server thread");
    assert_eq!(report.n_sessions, SESSIONS);
    assert_eq!(report.workers, WORKERS);
    assert_eq!(report.worker_peak_sessions.len(), WORKERS);
    assert_eq!(
        report.worker_peak_sessions.iter().sum::<usize>(),
        SESSIONS,
        "all sessions were concurrent, so shard peaks must account for every one"
    );
    assert_eq!(report.sessions_idle_reaped, 0);
    for s in &report.sessions {
        assert!(s.outcome.clean_close, "session {} must close cleanly", s.outcome.session_id);
        assert!(!s.outcome.idle_reaped);
    }
}

/// Dead-peer bugfix: a guest that goes silent without FIN (crash, NAT
/// drop) is reaped once the idle window passes — freeing its session
/// slot — while a healthy neighbor on the same reactor is untouched.
#[test]
fn dead_peer_is_reaped_without_disturbing_neighbors() {
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);
    let (addr, server) = start_server(
        &vs,
        &host_ms,
        ServeConfig {
            workers: 2,
            session_idle_timeout: Duration::from_millis(300),
            ..ServeConfig::default()
        },
        2,
    );

    // the hung guest: handshakes, sends one (empty) batch so it counts
    // as a served session, then never speaks again — and never closes
    // its socket, which is exactly what a vanished peer looks like
    let hung = TcpGuestTransport::connect(&addr, CipherSuite::new_plain(64)).expect("connect");
    hung.send(ToHost::SessionHello { session_id: 99, protocol: SERVE_PROTOCOL_VERSION });
    assert!(matches!(hung.recv(), ToGuest::SessionAccept { .. }));
    hung.send(ToHost::PredictRoute { session: 99, chunk: 0, queries: Vec::new() });
    let _ = hung.recv(); // the empty batch's answer

    // a healthy session on the same host, concurrent with the hung one
    let healthy = predict_sessions_tcp(
        &guest_m,
        &vs.guest,
        std::slice::from_ref(&addr),
        1,
        1,
        PredictOptions::default(),
    )
    .expect("healthy session");
    assert_eq!(healthy[0].preds, oracle, "the dead peer must not disturb its neighbor");

    // the server's budget is 2 sessions: the healthy close plus the
    // reap of session 99 — if the reap never fired, this join would
    // hang on the leaked slot forever
    let report = server.join().expect("server thread");
    assert_eq!(report.n_sessions, 2);
    assert_eq!(report.sessions_idle_reaped, 1);
    let reaped = report
        .sessions
        .iter()
        .find(|s| s.outcome.session_id == 99)
        .expect("the hung session must still be reported");
    assert!(reaped.outcome.idle_reaped, "session 99 must be idle-reaped");
    assert!(!reaped.outcome.clean_close);
    assert_eq!(reaped.outcome.batches, 1);
    let neighbor = report
        .sessions
        .iter()
        .find(|s| s.outcome.session_id != 99)
        .expect("the healthy session must be reported");
    assert!(neighbor.outcome.clean_close);
    assert!(!neighbor.outcome.idle_reaped);

    // only now may the hung socket drop — a FIN earlier would have been
    // an (unclean) transport close, not an idle reap
    drop(hung);
}

/// An accept source whose first accepts fail like a process out of file
/// descriptors (`EMFILE`), then behaves.
struct FlakyListener {
    inner: TcpListener,
    failures: AtomicU32,
}

impl AcceptSource for FlakyListener {
    fn accept(&self) -> std::io::Result<(TcpStream, SocketAddr)> {
        if self.failures.load(Ordering::SeqCst) > 0 {
            self.failures.fetch_sub(1, Ordering::SeqCst);
            return Err(std::io::Error::from_raw_os_error(24)); // EMFILE
        }
        self.inner.accept()
    }
    fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.inner.local_addr()
    }
}

/// Accept-loop bugfix: transient fd exhaustion is retried with backoff,
/// so the service survives a spike instead of winding down and the
/// client that arrives afterwards is served normally.
#[test]
fn transient_accept_errors_back_off_and_retry() {
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);

    let listener = FlakyListener {
        inner: TcpListener::bind("127.0.0.1:0").expect("bind loopback"),
        failures: AtomicU32::new(3),
    };
    let addr = listener.local_addr().unwrap().to_string();
    let state = HostServeState::new(
        host_ms[0].clone(),
        vs.hosts[0].clone(),
        ServeConfig { workers: 1, ..ServeConfig::default() },
    );
    let server_state = state.clone();
    let server = std::thread::spawn(move || -> ServeLoopReport {
        serve_predict_loop_on(&listener, &server_state, 1).expect("serve loop")
    });

    let reports = predict_sessions_tcp(
        &guest_m,
        &vs.guest,
        std::slice::from_ref(&addr),
        1,
        1,
        PredictOptions::default(),
    )
    .expect("session after the fd spike");
    assert_eq!(reports[0].preds, oracle);

    let loop_report = server.join().expect("server thread");
    assert_eq!(loop_report.accept_retries, 3, "every EMFILE must be retried, not fatal");
    assert_eq!(loop_report.sessions.len(), 1);
    assert!(loop_report.sessions[0].outcome.clean_close);
}
