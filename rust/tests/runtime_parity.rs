//! Artifact round-trip: the PJRT-executed AOT artifacts (JAX/Pallas,
//! lowered to HLO text) must agree with the pure-Rust `CpuEngine` on
//! every `ComputeEngine` entry point — and a federated training run on
//! the XLA engine must match the CPU engine's model exactly.
//!
//! Requires `make artifacts`; tests are skipped (with a loud message)
//! when the artifacts are missing so `cargo test` works pre-build.

use sbp::runtime::engine::{ComputeEngine, CpuEngine};
use sbp::runtime::pjrt::XlaEngine;
use sbp::util::rng::Xoshiro256;

fn engine_or_skip() -> Option<XlaEngine> {
    match XlaEngine::load(XlaEngine::default_dir()) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP runtime_parity: {e:#} — run `make artifacts`");
            None
        }
    }
}

#[test]
fn gh_binary_parity() {
    let Some(xla) = engine_or_skip() else { return };
    let cpu = CpuEngine;
    let mut rng = Xoshiro256::seed_from_u64(1);
    // sweep sizes incl. non-multiples of the tile
    for n in [1usize, 100, 4096, 5000] {
        let y: Vec<f64> = (0..n).map(|_| f64::from(rng.next_f64() > 0.5)).collect();
        let s: Vec<f64> = (0..n).map(|_| rng.next_gaussian() * 3.0).collect();
        let (gx, hx) = xla.gh_binary(&y, &s);
        let (gc, hc) = cpu.gh_binary(&y, &s);
        assert_eq!(gx.len(), n);
        for i in 0..n {
            assert!((gx[i] - gc[i]).abs() < 1e-5, "n={n} i={i}: {} vs {}", gx[i], gc[i]);
            assert!((hx[i] - hc[i]).abs() < 1e-5);
        }
    }
}

#[test]
fn gh_softmax_parity() {
    let Some(xla) = engine_or_skip() else { return };
    let cpu = CpuEngine;
    let mut rng = Xoshiro256::seed_from_u64(2);
    for (n, k) in [(64usize, 3usize), (1000, 7), (4096, 8), (4100, 5)] {
        let y: Vec<f64> = (0..n).map(|_| rng.next_below(k) as f64).collect();
        let s: Vec<f64> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let (gx, hx) = xla.gh_softmax(&y, &s, k);
        let (gc, hc) = cpu.gh_softmax(&y, &s, k);
        for i in 0..n * k {
            assert!((gx[i] - gc[i]).abs() < 1e-5, "(n={n},k={k}) i={i}");
            assert!((hx[i] - hc[i]).abs() < 1e-5);
        }
    }
}

#[test]
fn histogram_parity() {
    let Some(xla) = engine_or_skip() else { return };
    let cpu = CpuEngine;
    let mut rng = Xoshiro256::seed_from_u64(3);
    for (n, d, n_bins) in [(500usize, 5usize, 16usize), (4096, 32, 32), (6000, 40, 32)] {
        let bins: Vec<u8> = (0..n * d).map(|_| rng.next_below(n_bins) as u8).collect();
        let g: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let h: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let (gx, hx, cx) = xla.histogram(&bins, n, d, n_bins, &g, &h);
        let (gc, hc, cc) = cpu.histogram(&bins, n, d, n_bins, &g, &h);
        assert_eq!(cx, cc, "counts must match exactly (n={n},d={d})");
        for i in 0..d * n_bins {
            // f32 accumulation over ≤6000 values: generous tolerance
            assert!((gx[i] - gc[i]).abs() < 2e-2, "g[{i}]: {} vs {}", gx[i], gc[i]);
            assert!((hx[i] - hc[i]).abs() < 2e-2);
        }
    }
}

#[test]
fn gain_scan_parity() {
    let Some(xla) = engine_or_skip() else { return };
    let cpu = CpuEngine;
    let mut rng = Xoshiro256::seed_from_u64(4);
    for (d, n_bins) in [(5usize, 16usize), (32, 32), (50, 32)] {
        // monotone cumulative stats
        let mut g_cum = vec![0.0f64; d * n_bins];
        let mut h_cum = vec![0.0f64; d * n_bins];
        let mut gt = 0.0;
        let mut ht = 0.0;
        for f in 0..d {
            let (mut ag, mut ah) = (0.0f64, 0.0f64);
            for b in 0..n_bins {
                ag += rng.next_gaussian();
                ah += rng.next_f64() + 0.05;
                g_cum[f * n_bins + b] = ag;
                h_cum[f * n_bins + b] = ah;
            }
            gt = ag;
            ht = ah;
        }
        let xs = xla.gain_scan(&g_cum, &h_cum, d, n_bins, gt, ht, 0.3);
        let cs = cpu.gain_scan(&g_cum, &h_cum, d, n_bins, gt, ht, 0.3);
        for i in 0..d * n_bins {
            assert!(
                (xs[i] - cs[i]).abs() < 1e-2 * (1.0 + cs[i].abs()),
                "gain[{i}]: {} vs {}",
                xs[i],
                cs[i]
            );
        }
    }
}

#[test]
fn federated_training_same_model_on_both_engines() {
    let Some(xla) = engine_or_skip() else { return };
    use sbp::config::{CipherKind, TrainConfig};
    use sbp::coordinator::train_federated_with_engine;
    use sbp::data::synthetic::SyntheticSpec;

    let vs = SyntheticSpec::give_credit(0.002).generate_vertical(19, 1);
    let mut cfg = TrainConfig::secureboost_plus();
    cfg.epochs = 4;
    cfg.max_depth = 3;
    cfg.cipher = CipherKind::Plain;
    cfg.goss = None;
    cfg.sparse_optimization = false;

    let rx = train_federated_with_engine(&vs, &cfg, &xla).unwrap();
    let rc = train_federated_with_engine(&vs, &cfg, &CpuEngine).unwrap();
    // f32 vs f64 g/h can flip rare tie-break splits; quality must agree
    assert!(
        (rx.train_metric - rc.train_metric).abs() < 5e-3,
        "xla {} vs cpu {}",
        rx.train_metric,
        rc.train_metric
    );
    assert!(rx.train_metric > 0.75);
}
