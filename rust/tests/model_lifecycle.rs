//! Model-lifecycle round-trip tests: save → load → predict must be
//! bit-identical to in-memory prediction for binary and multi-class
//! models under every cipher, and damaged model files must be rejected
//! with errors, never panics.

use sbp::config::json::Json;
use sbp::config::{CipherKind, ModeKind, TrainConfig};
use sbp::coordinator::{predict_centralized, train_federated};
use sbp::data::synthetic::SyntheticSpec;
use sbp::model::{
    guest_file_name, host_file_name, GuestArtifact, HostArtifact, ModelError, Objective,
    MODEL_VERSION,
};
use std::path::PathBuf;

fn fast_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::secureboost_plus();
    cfg.epochs = 3;
    cfg.max_depth = 3;
    cfg.cipher = CipherKind::Plain;
    cfg.goss = None;
    cfg.sparse_optimization = false;
    cfg
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sbp-test-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir
}

/// Train on `spec`, save per-party artifacts, reload them, and assert
/// the reloaded model predicts bit-identically to the in-memory shares.
fn roundtrip_case(spec: SyntheticSpec, cfg: &TrainConfig, tag: &str) {
    let vs = spec.generate_vertical(cfg.seed, cfg.n_hosts);
    let rep = train_federated(&vs, cfg).expect("training run");
    let (guest_m, host_ms) = rep.model();
    let in_memory = predict_centralized(&guest_m, &host_ms, &vs);

    let dir = temp_dir(tag);
    let art = GuestArtifact {
        model: guest_m,
        objective: Objective::for_classes(vs.n_classes),
        dataset: vs.name.clone(),
        n_hosts: vs.hosts.len(),
        max_bin: cfg.max_bin,
        guest_features: vs.guest.d(),
        seed: cfg.seed,
        scale: 0.002,
        feature_names: Some(vs.guest.cols.iter().map(|c| format!("f{c}")).collect()),
    };
    art.save(&dir.join(guest_file_name())).expect("save guest");
    for (p, hm) in host_ms.iter().enumerate() {
        HostArtifact {
            model: hm.clone(),
            dataset: vs.name.clone(),
            n_features: vs.hosts[p].d(),
            n_hosts: vs.hosts.len(),
            seed: cfg.seed,
            scale: 0.002,
            feature_names: Some(vs.hosts[p].cols.iter().map(|c| format!("f{c}")).collect()),
        }
        .save(&dir.join(host_file_name(p)))
        .expect("save host");
    }

    let guest2 = GuestArtifact::load(&dir.join(guest_file_name())).expect("load guest");
    assert_eq!(
        guest2.feature_names, art.feature_names,
        "{tag}: recorded feature names must round-trip"
    );
    let host2: Vec<_> = (0..vs.hosts.len())
        .map(|p| HostArtifact::load(&dir.join(host_file_name(p))).expect("load host").model)
        .collect();
    assert_eq!(guest2.objective, art.objective);
    assert_eq!(guest2.dataset, vs.name);
    assert_eq!(guest2.model.trees.len(), art.model.trees.len());

    let reloaded = predict_centralized(&guest2.model, &host2, &vs);
    assert_eq!(
        reloaded, in_memory,
        "{tag}: reloaded model must predict bit-identically to the in-memory shares"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn roundtrip_binary_plain() {
    roundtrip_case(SyntheticSpec::give_credit(0.002), &fast_cfg(), "bin-plain");
}

#[test]
fn roundtrip_binary_paillier() {
    let mut cfg = fast_cfg();
    cfg.cipher = CipherKind::Paillier;
    cfg.key_bits = 512;
    cfg.epochs = 2;
    roundtrip_case(SyntheticSpec::give_credit(0.001), &cfg, "bin-paillier");
}

#[test]
fn roundtrip_binary_affine() {
    let mut cfg = fast_cfg();
    cfg.cipher = CipherKind::IterativeAffine;
    cfg.key_bits = 1024;
    cfg.epochs = 2;
    roundtrip_case(SyntheticSpec::give_credit(0.001), &cfg, "bin-affine");
}

#[test]
fn roundtrip_multiclass_one_vs_all() {
    let mut cfg = fast_cfg();
    cfg.epochs = 2;
    roundtrip_case(SyntheticSpec::sensorless(0.003), &cfg, "mc-ova");
}

#[test]
fn roundtrip_multiclass_multi_output() {
    let mut cfg = fast_cfg();
    cfg.epochs = 2;
    cfg.mode = ModeKind::MultiOutput;
    cfg.cipher_compression = false;
    roundtrip_case(SyntheticSpec::sensorless(0.003), &cfg, "mc-mo");
}

#[test]
fn roundtrip_two_hosts() {
    let mut cfg = fast_cfg();
    cfg.n_hosts = 2;
    roundtrip_case(SyntheticSpec::higgs(0.0002), &cfg, "two-hosts");
}

/// A real saved artifact, for the damage tests below.
fn saved_guest_artifact(tag: &str) -> (PathBuf, String) {
    let vs = SyntheticSpec::give_credit(0.001).generate_vertical(3, 1);
    let cfg = fast_cfg();
    let rep = train_federated(&vs, &cfg).expect("training run");
    let (guest_m, _) = rep.model();
    let dir = temp_dir(tag);
    let art = GuestArtifact {
        model: guest_m,
        objective: Objective::BinaryLogistic,
        dataset: vs.name.clone(),
        n_hosts: 1,
        max_bin: cfg.max_bin,
        guest_features: vs.guest.d(),
        seed: cfg.seed,
        scale: 0.001,
        feature_names: Some(vs.guest.cols.iter().map(|c| format!("f{c}")).collect()),
    };
    let path = dir.join(guest_file_name());
    art.save(&path).expect("save guest");
    let text = std::fs::read_to_string(&path).expect("read back");
    (path, text)
}

#[test]
fn truncated_file_rejected() {
    let (path, text) = saved_guest_artifact("truncated");
    for frac in [0.1, 0.5, 0.9] {
        let cut = (text.len() as f64 * frac) as usize;
        std::fs::write(&path, &text[..cut]).unwrap();
        match GuestArtifact::load(&path) {
            Err(ModelError::Parse(_)) | Err(ModelError::Format(_)) => {}
            other => panic!("truncation at {frac} must be rejected, got {other:?}"),
        }
    }
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn garbage_and_missing_files_rejected() {
    let dir = temp_dir("garbage");
    let path = dir.join(guest_file_name());
    assert!(matches!(GuestArtifact::load(&path), Err(ModelError::Io(_))), "missing file");
    std::fs::write(&path, "not json at all {{{").unwrap();
    assert!(matches!(GuestArtifact::load(&path), Err(ModelError::Parse(_))));
    std::fs::write(&path, "{\"format\": \"something-else\", \"version\": 1}").unwrap();
    assert!(matches!(GuestArtifact::load(&path), Err(ModelError::Format(_))));
    std::fs::write(&path, "{}").unwrap();
    assert!(matches!(GuestArtifact::load(&path), Err(ModelError::Format(_))));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn version_mismatch_rejected_at_load() {
    let (path, text) = saved_guest_artifact("version");
    let bumped = text.replacen(
        &format!("\"version\": {MODEL_VERSION}"),
        &format!("\"version\": {}", MODEL_VERSION + 1),
        1,
    );
    assert_ne!(bumped, text, "version field must be present to rewrite");
    std::fs::write(&path, bumped).unwrap();
    match GuestArtifact::load(&path) {
        Err(ModelError::Version { found, supported }) => {
            assert_eq!(found, MODEL_VERSION + 1);
            assert_eq!(supported, MODEL_VERSION);
        }
        other => panic!("expected version error, got {other:?}"),
    }
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn role_mismatch_rejected_at_load() {
    let (path, _) = saved_guest_artifact("role");
    assert!(matches!(HostArtifact::load(&path), Err(ModelError::Format(_))));
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn corrupted_payload_rejected_not_panicking() {
    let (path, text) = saved_guest_artifact("payload");
    let v = Json::parse(&text).unwrap();
    // any textual payload edit now trips the FNV-1a envelope checksum
    // before structural validation even runs
    let corrupted = text.replacen("\"left\": 1", "\"left\": 100000", 1);
    if corrupted != text {
        std::fs::write(&path, &corrupted).unwrap();
        assert!(matches!(GuestArtifact::load(&path), Err(ModelError::Checksum { .. })));
    }
    // the same edit on a checksum-less (legacy) envelope falls through to
    // structural validation, which still rejects it
    if let Json::Obj(mut m) = v.clone() {
        m.remove("checksum");
        let legacy = Json::Obj(m).to_string_pretty();
        let legacy_corrupted = legacy.replacen("\"left\": 1", "\"left\": 100000", 1);
        if legacy_corrupted != legacy {
            std::fs::write(&path, &legacy_corrupted).unwrap();
            assert!(matches!(GuestArtifact::load(&path), Err(ModelError::Format(_))));
        }
    }
    // drop the objective entirely (checksum catches the payload edit)
    if let Json::Obj(mut m) = v {
        if let Some(Json::Obj(p)) = m.get_mut("payload") {
            p.remove("objective");
        }
        std::fs::write(&path, Json::Obj(m).to_string_pretty()).unwrap();
        assert!(matches!(GuestArtifact::load(&path), Err(ModelError::Checksum { .. })));
    }
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn checksum_roundtrip_and_corruption() {
    let (path, text) = saved_guest_artifact("checksum");
    // the saved envelope records a checksum and verifies on load
    assert!(text.contains("\"checksum\""), "save must record a checksum");
    assert!(GuestArtifact::load(&path).is_ok(), "pristine artifact verifies");
    // flip one payload character (a digit inside a weight/threshold):
    // structurally valid JSON, semantically different model → Checksum
    let v = Json::parse(&text).unwrap();
    if let Json::Obj(mut m) = v {
        if let Some(Json::Obj(p)) = m.get_mut("payload") {
            p.insert("max_bin".into(), Json::Num(12345.0));
        }
        std::fs::write(&path, Json::Obj(m).to_string_pretty()).unwrap();
    }
    match GuestArtifact::load(&path) {
        Err(ModelError::Checksum { expected, found }) => {
            assert_ne!(expected, found);
            assert_eq!(expected.len(), 16, "fnv1a64 hex is 16 chars");
        }
        other => panic!("expected checksum error, got {other:?}"),
    }
    // a forged checksum field is equally rejected
    std::fs::write(
        &path,
        text.replacen("\"checksum\": \"", "\"checksum\": \"0000", 1),
    )
    .unwrap();
    assert!(matches!(GuestArtifact::load(&path), Err(ModelError::Checksum { .. })));
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn feature_names_schema_check_matches_and_rejects() {
    use sbp::model::check_feature_names;
    let (path, _) = saved_guest_artifact("schema");
    let art = GuestArtifact::load(&path).expect("load");
    let names = art.feature_names.clone().expect("save records feature names");
    assert_eq!(names.len(), art.guest_features, "one name per guest feature");

    // the recorded schema validates against itself
    assert!(check_feature_names(art.feature_names.as_deref(), &names).is_ok());

    // a renamed column is a schema mismatch, reported as such
    let mut renamed = names.clone();
    renamed[0] = "not_a_feature".into();
    match check_feature_names(art.feature_names.as_deref(), &renamed) {
        Err(ModelError::Schema { expected, found }) => {
            assert_eq!(expected, names);
            assert_eq!(found, renamed);
        }
        other => panic!("expected schema error, got {other:?}"),
    }

    // a permutation binds features to the wrong columns — also rejected
    if names.len() >= 2 {
        let mut swapped = names.clone();
        swapped.swap(0, 1);
        assert!(matches!(
            check_feature_names(art.feature_names.as_deref(), &swapped),
            Err(ModelError::Schema { .. })
        ));
    }
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn legacy_count_only_artifact_still_loads_and_skips_the_check() {
    // simulate a pre-names artifact: strip feature_names from the
    // payload (and the checksum, which a pre-names build also computed
    // over a names-free payload — removing both is exactly what an old
    // file looks like)
    let (path, text) = saved_guest_artifact("legacy-names");
    let v = Json::parse(&text).unwrap();
    let Json::Obj(mut m) = v else { panic!("envelope is an object") };
    m.remove("checksum");
    let Some(Json::Obj(p)) = m.get_mut("payload") else { panic!("payload is an object") };
    assert!(p.remove("feature_names").is_some(), "save must record names");
    std::fs::write(&path, Json::Obj(m).to_string_pretty()).unwrap();

    let art = GuestArtifact::load(&path).expect("legacy artifact must load");
    assert_eq!(art.feature_names, None);
    // and the schema check is a no-op for it, whatever the CSV brings
    assert!(sbp::model::check_feature_names(
        art.feature_names.as_deref(),
        &["anything".to_string()]
    )
    .is_ok());
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn feature_name_width_mismatch_rejected_at_load() {
    // an artifact whose names disagree with its declared width is
    // corrupt — rewrite the payload (and recompute nothing: the
    // checksum catches it first; with the checksum stripped, the
    // structural check catches it)
    let (path, text) = saved_guest_artifact("names-width");
    let v = Json::parse(&text).unwrap();
    let Json::Obj(mut m) = v else { panic!("envelope is an object") };
    m.remove("checksum");
    let Some(Json::Obj(p)) = m.get_mut("payload") else { panic!("payload is an object") };
    p.insert(
        "feature_names".into(),
        Json::Arr(vec![Json::Str("only-one".into())]),
    );
    std::fs::write(&path, Json::Obj(m).to_string_pretty()).unwrap();
    match GuestArtifact::load(&path) {
        Err(ModelError::Format(msg)) => {
            assert!(msg.contains("feature_names"), "unexpected message: {msg}")
        }
        other => panic!("expected format error, got {other:?}"),
    }
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}
