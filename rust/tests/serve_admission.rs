//! Admission-control tests (serve protocol v5): a saturated host
//! admits, queues, and sheds hellos deterministically — shed guests get
//! a retryable `Busy` frame and complete bit-identically to centralized
//! scoring once they re-dial; shed hellos never burn the
//! `--max-sessions` budget; parked v4 sessions are never shed inside
//! the resume window; and the shed/queued counters reconcile exactly
//! with the offered load.

mod common;

use common::{gen_world, World};
use sbp::coordinator::predict_centralized;
use sbp::crypto::cipher::CipherSuite;
use sbp::federation::limit::AdmissionConfig;
use sbp::federation::message::{
    BusyReason, ToGuest, ToHost, SERVE_PROTOCOL_V4, SERVE_PROTOCOL_VERSION,
};
use sbp::federation::predict::PredictOptions;
use sbp::federation::serve::{
    serve_predict_loop_on, spawn_serve_session, HostServeState, ServeConfig, ServeLoopReport,
};
use sbp::federation::tcp::TcpGuestTransport;
use sbp::federation::transport::{link_pair_bounded, GuestTransport};
use sbp::tree::predict::HostModel;
use sbp::util::rng::Xoshiro256;
use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

/// Poll `cond` (1 ms granularity) until it holds or 10 s pass.
fn wait_until(what: &str, cond: impl Fn() -> bool) {
    for _ in 0..10_000 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("timed out waiting for {what}");
}

/// A one-split toy host for the in-memory machine-level tests: split 0
/// is `feature 0 > 0.0`, and the single row's value 1.0 routes right.
fn toy_state(admission: AdmissionConfig) -> Arc<HostServeState> {
    let model = HostModel { party: 0, splits: vec![(0, 0, 0.0)] };
    let slice = sbp::data::dataset::PartySlice { cols: vec![0], x: vec![1.0], n: 1 };
    HostServeState::new(
        model,
        slice,
        ServeConfig { cache_capacity: 0, admission, ..ServeConfig::default() },
    )
}

/// One admitted slot, one queue seat, three concurrent hellos: the
/// first admits, the second queues (and admits the moment the first
/// closes), the third sheds with scaled retry advice — and the counters
/// reconcile exactly: 3 hellos offered = 2 served + 1 shed.
#[test]
fn hellos_admit_queue_and_shed_in_order_and_reconcile_exactly() {
    let state = toy_state(AdmissionConfig {
        limit: 1,
        queue: 1,
        queue_deadline: Duration::from_secs(30),
        ..AdmissionConfig::default()
    });

    let (ga, ha) = link_pair_bounded(8, 8);
    let sess_a = spawn_serve_session(state.clone(), ha);
    ga.send(ToHost::SessionHello { session_id: 1, protocol: SERVE_PROTOCOL_VERSION });
    let ToGuest::SessionAccept { session_id: 1, max_inflight, .. } = ga.recv() else {
        panic!("first hello must be admitted")
    };
    assert!(
        max_inflight >= 1 && max_inflight <= ServeConfig::default().max_inflight,
        "the advertised window stays in [1, base] (got {max_inflight})"
    );

    // second hello: the slot is held, the queue seat is free — queued,
    // no answer yet
    let (gb, hb) = link_pair_bounded(8, 8);
    let sess_b = spawn_serve_session(state.clone(), hb);
    gb.send(ToHost::SessionHello { session_id: 2, protocol: SERVE_PROTOCOL_VERSION });
    wait_until("the second hello to queue", || state.admission_stats().sessions_queued == 1);

    // third hello: slot held, queue full — shed immediately, with the
    // retry advice scaled by the backlog (base 50 ms × (1 + 1/1))
    let (gc, hc) = link_pair_bounded(8, 8);
    let sess_c = spawn_serve_session(state.clone(), hc);
    gc.send(ToHost::SessionHello { session_id: 3, protocol: SERVE_PROTOCOL_VERSION });
    let ToGuest::Busy { retry_after_ms, reason } = gc.recv() else {
        panic!("third hello must be shed")
    };
    assert_eq!(reason, BusyReason::Shed);
    assert_eq!(retry_after_ms, 100, "retry advice scales with queue depth");
    let shed = sess_c.join().expect("shed session thread");
    assert!(shed.clean_close, "a shed is an orderly refusal, not a protocol violation");
    assert!(shed.is_control_only(), "a shed hello served nothing");

    // the first session does real work and closes: its slot frees and
    // the queued hello's deferred accept finally leaves
    ga.send(ToHost::PredictRoute { session: 1, chunk: 1, queries: vec![(0, 0)] });
    let ToGuest::RouteAnswers { n: 1, .. } = ga.recv() else { panic!("expected answer") };
    ga.send(ToHost::SessionClose { session_id: 1 });
    assert!(sess_a.join().expect("session thread").clean_close);

    let ToGuest::SessionAccept { session_id: 2, .. } = gb.recv() else {
        panic!("the queued hello must admit once the slot frees")
    };
    gb.send(ToHost::PredictRoute { session: 2, chunk: 1, queries: vec![(0, 0)] });
    let ToGuest::RouteAnswers { n: 1, .. } = gb.recv() else { panic!("expected answer") };
    gb.send(ToHost::SessionClose { session_id: 2 });
    assert!(sess_b.join().expect("session thread").clean_close);

    // exact reconciliation: offered = served + shed, nothing in flight
    let adm = state.admission_stats();
    assert_eq!(adm.sessions_shed, 1);
    assert_eq!(adm.sessions_queued, 1);
    assert!(adm.queue_wait_seconds > 0.0, "the queued hello waited a measurable time");
    assert_eq!(adm.in_flight, 0, "every admitted slot was released");
    assert_eq!(state.sessions_served(), 2, "3 hellos offered = 2 served + 1 shed");
}

/// A hello that outwaits the queue deadline is shed with
/// `QueueExpired` — counted like any other shed, its wait recorded.
#[test]
fn queued_hellos_expire_to_a_retryable_busy() {
    let state = toy_state(AdmissionConfig {
        limit: 1,
        queue: 1,
        queue_deadline: Duration::from_millis(50),
        ..AdmissionConfig::default()
    });

    let (ga, ha) = link_pair_bounded(8, 8);
    let sess_a = spawn_serve_session(state.clone(), ha);
    ga.send(ToHost::SessionHello { session_id: 1, protocol: SERVE_PROTOCOL_VERSION });
    let ToGuest::SessionAccept { .. } = ga.recv() else { panic!("expected accept") };

    // the queued hello: the slot never frees, so the deadline fires
    let (gb, hb) = link_pair_bounded(8, 8);
    let sess_b = spawn_serve_session(state.clone(), hb);
    gb.send(ToHost::SessionHello { session_id: 2, protocol: SERVE_PROTOCOL_VERSION });
    let ToGuest::Busy { retry_after_ms, reason } = gb.recv() else {
        panic!("the expired hello must be shed")
    };
    assert_eq!(reason, BusyReason::QueueExpired);
    assert_eq!(retry_after_ms, 50, "advice resets once the queue drained");
    let expired = sess_b.join().expect("expired session thread");
    assert!(expired.clean_close);
    assert!(expired.is_control_only());

    let adm = state.admission_stats();
    assert_eq!(adm.sessions_shed, 1, "an expiry is a shed");
    assert_eq!(adm.sessions_queued, 1, "…that first queued");
    assert!(adm.queue_wait_seconds >= 0.05, "the full deadline was waited out");

    ga.send(ToHost::PredictRoute { session: 1, chunk: 1, queries: vec![(0, 0)] });
    let ToGuest::RouteAnswers { .. } = ga.recv() else { panic!("expected answer") };
    ga.send(ToHost::SessionClose { session_id: 1 });
    assert!(sess_a.join().expect("session thread").clean_close);
    assert_eq!(state.sessions_served(), 1);
}

/// A raw v5 hello against a TCP reactor host; returns the transport
/// once admitted.
fn raw_hello(addr: &str, session_id: u32) -> TcpGuestTransport {
    let t = TcpGuestTransport::connect(addr, CipherSuite::new_plain(64)).expect("connect");
    t.send(ToHost::SessionHello { session_id, protocol: SERVE_PROTOCOL_VERSION });
    match t.recv() {
        ToGuest::SessionAccept { .. } => t,
        other => panic!("squatter hello rejected: {:?}", other.kind()),
    }
}

/// Bring up a reactor host with its state handle exposed, so tests can
/// watch the admission counters live.
fn start_reactor(
    world: &World,
    cfg: ServeConfig,
    max_sessions: usize,
) -> (String, Arc<HostServeState>, std::thread::JoinHandle<ServeLoopReport>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    let state = HostServeState::new(world.host_ms[0].clone(), world.vs.hosts[0].clone(), cfg);
    let loop_state = state.clone();
    let handle = std::thread::spawn(move || {
        serve_predict_loop_on(&listener, &loop_state, max_sessions).expect("serve loop")
    });
    (addr, state, handle)
}

/// Satellite regression: shed hellos must not consume the lifetime
/// `--max-sessions` budget. A budget-1 host sheds three hellos while a
/// squatter holds the only slot, then still serves the one real
/// session in full.
#[test]
fn shed_hellos_do_not_consume_the_session_budget() {
    let mut rng = Xoshiro256::seed_from_u64(0xAD317);
    let world = gen_world(&mut rng, 1);
    let oracle = predict_centralized(&world.guest_m, &world.host_ms, &world.vs);
    let (addr, state, server) = start_reactor(
        &world,
        ServeConfig {
            workers: 2,
            admission: AdmissionConfig { limit: 1, queue: 0, ..AdmissionConfig::default() },
            ..ServeConfig::default()
        },
        1, // the budget under test
    );

    let squatter = raw_hello(&addr, 9001);
    for i in 0..3u32 {
        let t = TcpGuestTransport::connect(&addr, CipherSuite::new_plain(64)).expect("connect");
        t.send(ToHost::SessionHello { session_id: 7000 + i, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::Busy { retry_after_ms, reason } = t.recv() else {
            panic!("hello {i} must be shed while the squatter holds the slot")
        };
        assert_eq!(reason, BusyReason::Shed);
        assert_eq!(retry_after_ms, 50, "no backlog (queue off): base retry advice");
    }
    // release the slot and wait for the host to process it, so the real
    // guest's first hello admits (keeping the shed count exact)
    squatter.send(ToHost::SessionClose { session_id: 9001 });
    wait_until("the squatter's slot to free", || state.admission_stats().in_flight == 0);

    let reports = sbp::coordinator::predict_sessions_tcp(
        &world.guest_m,
        &world.vs.guest,
        std::slice::from_ref(&addr),
        1,
        1,
        PredictOptions { seed: 7, ..PredictOptions::default() },
    )
    .expect("the real session");
    assert_eq!(reports[0].preds, oracle);

    // budget 1 met by the one *served* session — had any of the three
    // sheds (or the control-only squatter) burned it, the real session
    // would have been refused or the loop would have exited early
    let report = server.join().expect("server thread");
    assert_eq!(state.sessions_served(), 1);
    assert_eq!(report.sessions.len(), 1, "only the served session is reported");
    assert_eq!(report.sessions_shed, 3, "exactly the three probes");
    assert_eq!(report.sessions_queued, 0);
}

/// The tentpole overload soak: 4× the admission limit in concurrent
/// guests against one reactor host whose two slots are held by
/// squatters, so every first hello queues or sheds. Every guest must
/// complete bit-identically to centralized via Busy-retry, and the
/// counters must reconcile with the offered load.
fn overload_round(seed: u64, limit: usize, queue: usize, guests: usize) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let world = gen_world(&mut rng, 1);
    let oracle = predict_centralized(&world.guest_m, &world.host_ms, &world.vs);
    let (addr, state, server) = start_reactor(
        &world,
        ServeConfig {
            workers: 2,
            admission: AdmissionConfig { limit, queue, ..AdmissionConfig::default() },
            ..ServeConfig::default()
        },
        guests,
    );

    // squatters saturate every admitted slot before the load arrives
    let squatters: Vec<TcpGuestTransport> =
        (0..limit).map(|i| raw_hello(&addr, 9000 + i as u32)).collect();

    // the releaser: once the offered load has demonstrably overflowed
    // (≥ guests − queue − limit hellos shed), free the slots so the
    // retrying guests can drain through
    let min_shed = (guests.saturating_sub(queue + limit)).max(1) as u64;
    let releaser_state = state.clone();
    let releaser = std::thread::spawn(move || {
        wait_until("the overload to shed", || {
            releaser_state.admission_stats().sessions_shed >= min_shed
        });
        for (i, s) in squatters.iter().enumerate() {
            s.send(ToHost::SessionClose { session_id: 9000 + i as u32 });
        }
        drop(squatters);
        min_shed
    });

    let reports = sbp::coordinator::predict_sessions_tcp(
        &world.guest_m,
        &world.vs.guest,
        std::slice::from_ref(&addr),
        guests,
        guests, // all concurrent: the full 4× burst hits at once
        PredictOptions { seed, admission_retries: 200, ..PredictOptions::default() },
    )
    .expect("every overloaded guest must complete via Busy-retry");
    let min_shed = releaser.join().expect("releaser thread");
    let report = server.join().expect("server thread");

    assert_eq!(reports.len(), guests);
    for r in &reports {
        assert_eq!(
            r.preds, oracle,
            "session {} must be bit-identical to centralized despite the shed/retry path",
            r.session_id
        );
    }
    // reconciliation: every guest (and no squatter) was served exactly
    // once — offered hellos = served + queued-then-admitted + shed,
    // with nothing left in flight or in the queue
    assert_eq!(state.sessions_served(), guests as u64);
    assert_eq!(report.sessions.len(), guests, "control-only squatters are not reported");
    for s in &report.sessions {
        assert!(s.outcome.clean_close, "session {} unclean", s.outcome.session_id);
    }
    assert!(
        report.sessions_shed >= min_shed,
        "the 4× burst must shed at least {min_shed} hellos (got {})",
        report.sessions_shed
    );
    if queue > 0 {
        assert!(
            report.sessions_queued >= queue as u64,
            "the burst must fill the {queue}-seat queue (got {})",
            report.sessions_queued
        );
        assert!(report.admission_queue_wait_seconds > 0.0);
    }
    let adm = state.admission_stats();
    assert_eq!(adm.in_flight, 0, "every slot released at loop end");
    assert_eq!(adm.sessions_shed, report.sessions_shed, "loop report mirrors the controller");
}

/// The fixed-seed CI instance: 8 guests against 2 slots + 2 queue
/// seats.
#[test]
fn overload_4x_all_guests_complete_bit_identically() {
    overload_round(0x0AD_1047, 2, 2, 8);
}

/// The full overload range — slow; run explicitly with
/// `cargo test --release --test serve_admission -- --ignored`.
#[test]
#[ignore = "full overload soak; run explicitly"]
fn overload_soak_full_range() {
    for seed in [0x0AD_1047u64, 0xA11CE, 0xB00B5] {
        for &(limit, queue) in &[(1usize, 0usize), (1, 1), (2, 2), (4, 2)] {
            overload_round(seed, limit, queue, 4 * limit);
        }
    }
}

/// A parked v4 session is never shed inside the resume window: its
/// resume force-admits even when a later v5 session saturated the
/// controller — the session already paid admission at its hello.
#[test]
fn parked_v4_sessions_are_never_shed_within_the_resume_window() {
    let mut rng = Xoshiro256::seed_from_u64(0xAD317_44);
    let world = gen_world(&mut rng, 1);
    let (addr, state, server) = start_reactor(
        &world,
        ServeConfig {
            workers: 2,
            resume_window: Duration::from_secs(30),
            admission: AdmissionConfig { limit: 1, queue: 0, ..AdmissionConfig::default() },
            ..ServeConfig::default()
        },
        1,
    );

    // the v4 session does real work, then its connection dies
    let t = TcpGuestTransport::connect(&addr, CipherSuite::new_plain(64)).expect("connect");
    t.send(ToHost::SessionHello { session_id: 77, protocol: SERVE_PROTOCOL_V4 });
    let ToGuest::SessionAccept { protocol, .. } = t.recv() else { panic!("expected accept") };
    assert_eq!(protocol, SERVE_PROTOCOL_V4);
    t.send(ToHost::PredictRoute { session: 77, chunk: 1, queries: vec![(0, 0)] });
    let ToGuest::RouteAnswers { chunk: 1, .. } = t.recv() else { panic!("expected answer") };
    t.reconnect().expect("re-dial"); // kills the old connection mid-session
    wait_until("the dead session to park", || state.sessions_parked() == 1);

    // a parked session consumes no slot: a v5 squatter takes the only
    // one, and a probe confirms the controller is saturated again
    let squatter = raw_hello(&addr, 9001);
    let probe = TcpGuestTransport::connect(&addr, CipherSuite::new_plain(64)).expect("connect");
    probe.send(ToHost::SessionHello { session_id: 7001, protocol: SERVE_PROTOCOL_VERSION });
    let ToGuest::Busy { reason: BusyReason::Shed, .. } = probe.recv() else {
        panic!("the probe must be shed: the squatter holds the only slot")
    };

    // the resume must not be shed: retry the handshake until the fresh
    // connection lands on the parked state, panicking on any Busy
    let (next_chunk, _epoch) = 'resume: {
        for _ in 0..200 {
            if t.try_send(ToHost::SessionResume { session: 77, last_acked_chunk: 1 }).is_err() {
                let _ = t.reconnect();
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            match t.try_recv() {
                Ok(ToGuest::ResumeAccept { next_chunk, basis_epoch }) => {
                    break 'resume (next_chunk, basis_epoch)
                }
                Ok(other) => panic!(
                    "a valid resume inside the window must never be refused (got {:?})",
                    other.kind()
                ),
                Err(_) => {
                    let _ = t.reconnect();
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
        panic!("session 77 never resumed");
    };
    assert_eq!(next_chunk, 2, "the stream picks up exactly where it left off");

    t.send(ToHost::PredictRoute { session: 77, chunk: 2, queries: vec![(0, 0)] });
    let ToGuest::RouteAnswers { chunk: 2, .. } = t.recv() else { panic!("expected answer") };
    squatter.send(ToHost::SessionClose { session_id: 9001 });
    t.send(ToHost::SessionClose { session_id: 77 });

    let report = server.join().expect("server thread");
    assert_eq!(state.sessions_resumed(), 1);
    assert_eq!(state.sessions_served(), 1, "the resumed session counts once");
    assert_eq!(report.sessions.len(), 1);
    assert_eq!(report.sessions[0].outcome.batches, 2, "both chunks, across the outage");
    assert!(report.sessions[0].outcome.clean_close);
    assert!(report.sessions_shed >= 1, "the probe was shed");
}
