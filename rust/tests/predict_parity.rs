//! Federated-inference parity tests: the batched prediction protocol
//! must produce bit-identical outputs to colocated inference over both
//! transports, and both transports must account identical wire bytes.

use sbp::config::{CipherKind, TrainConfig};
use sbp::coordinator::{
    predict_centralized, predict_federated_in_memory, predict_federated_tcp, train_federated,
    TrainReport,
};
use sbp::data::dataset::VerticalSplit;
use sbp::data::synthetic::SyntheticSpec;
use sbp::federation::message::{ToGuestKind, ToHostKind};
use sbp::federation::predict::serve_predict_once;
use sbp::metrics::auc;
use sbp::tree::predict::HostModel;

fn fast_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::secureboost_plus();
    cfg.epochs = 4;
    cfg.max_depth = 3;
    cfg.cipher = CipherKind::Plain;
    cfg.goss = None;
    cfg.sparse_optimization = false;
    cfg
}

/// Serve every host share over loopback TCP and run a federated predict.
fn predict_over_tcp(
    rep_model: &sbp::tree::predict::GuestModel,
    host_models: &[HostModel],
    vs: &VerticalSplit,
) -> sbp::coordinator::PredictReport {
    let mut addrs = Vec::new();
    let mut servers = Vec::new();
    for (p, hm) in host_models.iter().enumerate() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().unwrap().to_string());
        let model = hm.clone();
        let slice = vs.hosts[p].clone();
        servers.push(std::thread::spawn(move || {
            serve_predict_once(&listener, model, slice).expect("serve predict");
        }));
    }
    let report =
        predict_federated_tcp(rep_model, &vs.guest, &addrs).expect("tcp federated predict");
    for s in servers {
        s.join().expect("predict server thread");
    }
    report
}

fn train(spec: SyntheticSpec, cfg: &TrainConfig) -> (VerticalSplit, TrainReport) {
    let vs = spec.generate_vertical(cfg.seed, cfg.n_hosts);
    let rep = train_federated(&vs, cfg).expect("training run");
    (vs, rep)
}

#[test]
fn federated_predict_matches_centralized_exactly() {
    let (vs, rep) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let (guest_m, host_ms) = rep.model();

    let cen = predict_centralized(&guest_m, &host_ms, &vs);
    let mem = predict_federated_in_memory(&guest_m, &host_ms, &vs).unwrap();
    let tcp = predict_over_tcp(&guest_m, &host_ms, &vs);

    assert_eq!(mem.preds, cen, "in-memory federated must equal colocated bit for bit");
    assert_eq!(tcp.preds, cen, "tcp federated must equal colocated bit for bit");
    assert_eq!(mem.n_rows, vs.n());

    // prediction quality equals training-time quality (no sampling)
    let a = auc(&vs.y, &cen);
    assert!(
        (a - rep.train_metric).abs() < 1e-9,
        "inference AUC {a} vs training metric {}",
        rep.train_metric
    );
}

#[test]
fn transports_account_identical_bytes() {
    let (vs, rep) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let (guest_m, host_ms) = rep.model();

    let mem = predict_federated_in_memory(&guest_m, &host_ms, &vs).unwrap();
    let tcp = predict_over_tcp(&guest_m, &host_ms, &vs);

    // NetCounters parity: the in-memory links charge the exact serialized
    // frame sizes the TCP transport actually sent, per kind and direction
    assert_eq!(mem.comm, tcp.comm, "per-kind wire accounting must match across transports");
    assert!(mem.comm.total_bytes() > 0, "host splits must have been consulted");
    assert_eq!(
        mem.comm.to_host_kind_bytes.iter().sum::<u64>(),
        mem.comm.bytes_to_host
    );
    assert_eq!(
        mem.comm.to_guest_kind_bytes.iter().sum::<u64>(),
        mem.comm.bytes_to_guest
    );
    // only inference-phase message kinds flow: PredictRoute + Shutdown
    // guest→host, RouteAnswers host→guest
    for k in ToHostKind::ALL {
        let msgs = mem.comm.to_host_kind_msgs[k.index()];
        match k {
            ToHostKind::PredictRoute | ToHostKind::Shutdown => {}
            _ => assert_eq!(msgs, 0, "unexpected {} traffic in inference", k.name()),
        }
    }
    for k in ToGuestKind::ALL {
        let msgs = mem.comm.to_guest_kind_msgs[k.index()];
        match k {
            ToGuestKind::RouteAnswers => {}
            _ => assert_eq!(msgs, 0, "unexpected {} traffic in inference", k.name()),
        }
    }
    // batched level-wise routing: at most one PredictRoute round trip per
    // tree depth (not per sample, not per tree)
    let route_msgs = mem.comm.to_host_kind_msgs[ToHostKind::PredictRoute.index()];
    assert!(
        route_msgs <= fast_cfg().max_depth as u64,
        "{route_msgs} routing round trips for depth {}",
        fast_cfg().max_depth
    );
}

#[test]
fn multi_host_predict_parity() {
    let mut cfg = fast_cfg();
    cfg.n_hosts = 2;
    let (vs, rep) = train(SyntheticSpec::higgs(0.0002), &cfg);
    let (guest_m, host_ms) = rep.model();
    assert_eq!(host_ms.len(), 2);

    let cen = predict_centralized(&guest_m, &host_ms, &vs);
    let mem = predict_federated_in_memory(&guest_m, &host_ms, &vs).unwrap();
    let tcp = predict_over_tcp(&guest_m, &host_ms, &vs);
    assert_eq!(mem.preds, cen);
    assert_eq!(tcp.preds, cen);
    assert_eq!(mem.comm, tcp.comm);
}

#[test]
fn multiclass_predict_parity() {
    let mut cfg = fast_cfg();
    cfg.epochs = 2;
    let (vs, rep) = train(SyntheticSpec::sensorless(0.003), &cfg);
    let (guest_m, host_ms) = rep.model();
    assert_eq!(guest_m.pred_width, vs.n_classes);

    let cen = predict_centralized(&guest_m, &host_ms, &vs);
    let mem = predict_federated_in_memory(&guest_m, &host_ms, &vs).unwrap();
    let tcp = predict_over_tcp(&guest_m, &host_ms, &vs);
    assert_eq!(mem.preds, cen);
    assert_eq!(tcp.preds, cen);
    assert_eq!(mem.comm, tcp.comm);
}
