//! Stage C compute-pool tests: sharded `route_bits` must be
//! **bit-identical** to inline compute for adversarial batch sizes
//! (shard-boundary ±1, odd tails, single queries), for cache on/off ×
//! delta on/off × 1 vs N pool workers; the reactor must re-sequence
//! asynchronously computed answers back into frame order; and the
//! two-pass cache lock must never serialize concurrent sessions behind
//! each other's walks (the contention regression the lock split fixes).

use sbp::coordinator::{predict_centralized, predict_session_tcp, serve_predict_tcp};
use sbp::data::dataset::{PartySlice, VerticalSplit};
use sbp::federation::predict::{PredictOptions, PredictSession};
use sbp::federation::serve::{spawn_serve_session, HostServeState, ServeConfig};
use sbp::federation::transport::{link_pair_bounded, GuestTransport};
use sbp::tree::node::{SplitRef, Tree};
use sbp::tree::predict::{GuestModel, HostModel};
use sbp::util::rng::Xoshiro256;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn uni(rng: &mut Xoshiro256) -> f64 {
    rng.next_f64() * 2.0 - 1.0
}

/// A deterministic one-host serving world with **exactly** `n` rows —
/// the batch sizes under test are exact, not drawn. Every row consults
/// the host (host splits at both tree roots), so a single-chunk pass
/// walks a batch of exactly `n` fresh queries per routing level.
fn world(n: usize, seed: u64) -> (VerticalSplit, GuestModel, HostModel) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let guest = PartySlice { cols: vec![0], x: (0..n).map(|_| uni(&mut rng)).collect(), n };
    let host_slice =
        PartySlice { cols: vec![1, 2], x: (0..2 * n).map(|_| uni(&mut rng)).collect(), n };
    let host_m = HostModel {
        party: 0,
        splits: (0..5).map(|_| (rng.next_below(2) as u32, 0u8, uni(&mut rng))).collect(),
    };
    // tree 0: host root, one host and one guest split below it
    let mut t0 = Tree::new(1);
    let (l, r) = t0.split_node(0, SplitRef::Host { party: 0, handle: 0 });
    let (ll, lr) = t0.split_node(l, SplitRef::Host { party: 0, handle: 1 });
    let (rl, rr) = t0.split_node(r, SplitRef::Guest { feature: 0, bin: 0, threshold: 0.0 });
    for (node, w) in [(ll, -1.5), (lr, -0.5), (rl, 0.5), (rr, 1.5)] {
        t0.nodes[node as usize].weight = vec![w];
    }
    // tree 1: a second host root so repeat passes mix known/fresh keys
    let mut t1 = Tree::new(1);
    let (l1, r1) = t1.split_node(0, SplitRef::Host { party: 0, handle: 2 });
    t1.nodes[l1 as usize].weight = vec![-0.25];
    t1.nodes[r1 as usize].weight = vec![0.75];
    let guest_m =
        GuestModel { trees: vec![(t0, 0), (t1, 0)], n_classes: 2, pred_width: 1 };
    let vs = VerticalSplit {
        guest,
        hosts: vec![host_slice],
        y: vec![0.0; n],
        n_classes: 2,
        name: "compute-pool".into(),
    };
    (vs, guest_m, host_m)
}

/// Two streamed passes of the whole world through one in-memory serving
/// session under `cfg`; returns (pass-1 preds, pass-2 preds, host shard
/// jobs). Pass 1 walks every query fresh; pass 2 re-walks through
/// whatever the cache/delta config remembers — including the empty- and
/// partial-walk-list edges of the recombination.
fn run_session(
    vs: &VerticalSplit,
    guest_m: &GuestModel,
    host_m: &HostModel,
    cfg: ServeConfig,
) -> (Vec<f64>, Vec<f64>, u64) {
    let state = HostServeState::new(host_m.clone(), vs.hosts[0].clone(), cfg);
    let (gl, hl) = link_pair_bounded(8, 8);
    let host = spawn_serve_session(state, hl);
    let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
    let mut session = PredictSession::new(
        guest_m,
        77,
        PredictOptions { batch_rows: vs.n(), seed: 5, ..PredictOptions::default() },
    );
    session.open(&links);
    let (p1, _) = session.predict_stream(&vs.guest, &links);
    let (p2, _) = session.predict_stream(&vs.guest, &links);
    session.close(&links);
    let outcome = host.join().expect("serve session thread");
    assert!(outcome.clean_close);
    (p1, p2, outcome.compute_jobs)
}

/// The recombination property: for batch sizes straddling every shard
/// boundary (±1 around multiples of 8, odd tails, single queries, and
/// sizes past several whole shards), sharded compute under 1 and 4 pool
/// workers is bit-identical to inline compute — across cache on/off ×
/// delta on/off. The size-0 walk list arises on pass 2 when cache+delta
/// remember everything; `shard_geometry` keeps it (and every batch
/// below `compute_shard_min`) inline by construction.
#[test]
fn sharded_route_bits_is_bit_identical_to_inline_for_adversarial_sizes() {
    const SIZES: &[usize] = &[1, 7, 8, 9, 15, 16, 17, 63, 64, 65, 263, 264, 265, 1024, 1037];
    for &n in SIZES {
        let (vs, guest_m, host_m) = world(n, 0xC0FFEE ^ n as u64);
        let oracle = predict_centralized(&guest_m, &[host_m.clone()], &vs);
        for (cache_capacity, delta_window) in
            [(0usize, 0usize), (1 << 12, 0), (0, 1 << 12), (1 << 12, 1 << 12)]
        {
            let tag = format!("n={n} cache={cache_capacity} delta={delta_window}");
            let base = ServeConfig {
                cache_capacity,
                delta_window,
                compute_shard_min: usize::MAX, // inline baseline
                ..ServeConfig::default()
            };
            let (i1, i2, inline_jobs) = run_session(&vs, &guest_m, &host_m, base);
            assert_eq!(i1, oracle, "{tag}: inline pass 1");
            assert_eq!(i2, oracle, "{tag}: inline pass 2");
            assert_eq!(inline_jobs, 0, "{tag}: inline must dispatch no shard jobs");
            for workers in [1usize, 4] {
                let sharded = ServeConfig {
                    compute_shard_min: 1, // every walked batch fans out
                    compute_workers: workers,
                    ..base
                };
                let (s1, s2, jobs) = run_session(&vs, &guest_m, &host_m, sharded);
                assert_eq!(s1, i1, "{tag} w={workers}: sharded pass 1 must equal inline");
                assert_eq!(s2, i2, "{tag} w={workers}: sharded pass 2 must equal inline");
                assert!(jobs > 0, "{tag} w={workers}: pass 1 walks fresh queries sharded");
            }
        }
    }
}

/// The reactor's async Stage C: a pipelined TCP session whose every
/// batch fans out to the pool (with an injected walk delay, so several
/// batches are genuinely in flight on the pool at once) must still
/// deliver answers in frame order — the guest's strict chunk sequencing
/// fails loudly otherwise — and bit-identical to the centralized
/// oracle.
#[test]
fn reactor_resequences_pooled_answers_into_frame_order() {
    let (vs, guest_m, host_m) = world(200, 0xAB5ED);
    let oracle = predict_centralized(&guest_m, &[host_m.clone()], &vs);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = ServeConfig {
        cache_capacity: 1 << 12,
        compute_workers: 2,
        compute_shard_min: 1,
        walk_delay: Some(Duration::from_millis(2)),
        ..ServeConfig::default()
    };
    let model = host_m.clone();
    let slice = vs.hosts[0].clone();
    let server = std::thread::spawn(move || {
        serve_predict_tcp(&listener, model, slice, cfg, 1).expect("serve loop")
    });
    let r = predict_session_tcp(
        &guest_m,
        &vs.guest,
        std::slice::from_ref(&addr),
        1,
        PredictOptions { batch_rows: 8, max_inflight: 8, ..PredictOptions::default() },
    )
    .expect("pipelined session");
    let report = server.join().expect("server thread");
    assert_eq!(r.preds, oracle, "pooled reactor serving must equal centralized");
    assert_eq!(report.compute_workers, 2, "the pool was built with the requested width");
    assert!(report.compute_jobs > 0, "batches must have fanned out");
    assert!(report.shards_per_batch >= 1.0);
    assert!(report.sessions[0].outcome.clean_close);
}

/// The cache-lock contention regression (independent of the pool): two
/// sessions sharing one routing cache, each with a 250 ms walk, must
/// overlap their walks — the lookup/store lock split means sessions
/// contend for microseconds of map probes, never for each other's
/// compute. The old single-pass `route_bits` held the batch guard
/// across the walk and would serialize this to ≥ 500 ms.
#[test]
fn concurrent_sessions_do_not_serialize_behind_the_cache_lock() {
    // depth-1 model: exactly one routing level, so each session's pass
    // is exactly one PredictRoute frame = one (delayed) walk
    let n = 32usize;
    let mut rng = Xoshiro256::seed_from_u64(7);
    let guest = PartySlice { cols: vec![0], x: vec![1.0; n], n };
    let host_slice = PartySlice { cols: vec![1], x: (0..n).map(|_| uni(&mut rng)).collect(), n };
    let host_m = HostModel { party: 0, splits: vec![(0, 0, 0.0)] };
    let mut t = Tree::new(1);
    let (l, r) = t.split_node(0, SplitRef::Host { party: 0, handle: 0 });
    t.nodes[l as usize].weight = vec![-1.0];
    t.nodes[r as usize].weight = vec![1.0];
    let guest_m = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };

    let state = HostServeState::new(
        host_m,
        host_slice,
        ServeConfig {
            cache_capacity: 1 << 12,
            compute_shard_min: usize::MAX, // inline: this is a lock test, not a pool test
            walk_delay: Some(Duration::from_millis(250)),
            ..ServeConfig::default()
        },
    );
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for sid in [101u32, 102] {
            let state = Arc::clone(&state);
            let guest_m = &guest_m;
            let guest = &guest;
            s.spawn(move || {
                let (gl, hl) = link_pair_bounded(8, 8);
                let host = spawn_serve_session(state, hl);
                let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
                let mut session = PredictSession::new(
                    guest_m,
                    sid,
                    PredictOptions { batch_rows: n, seed: 3, ..PredictOptions::default() },
                );
                session.open(&links);
                session.predict_batch(guest, &links);
                session.close(&links);
                let outcome = host.join().expect("serve session thread");
                assert!(outcome.clean_close);
            });
        }
    });
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_millis(450),
        "two 250 ms walks serialized behind the cache lock: {elapsed:?}"
    );
    // the split pass still accounts every query exactly once
    let cs = state.cache_stats();
    assert_eq!(cs.hits + cs.misses, state.queries_answered());
}
