//! End-to-end suite for serve protocol v6 secure sessions: the
//! `--secure off|prefer|require` policy matrix on both ends over real
//! loopback TCP, negotiate-down against legacy-protocol clients, and
//! raw-socket adversarial cases (tampered ciphertext, truncated tag)
//! against a live serving host.
//!
//! The invariants:
//!
//! - **AEAD is invisible above the transport**: a keyed session's
//!   predictions are bit-identical to the plaintext session and to the
//!   centralized oracle, and the two-sided byte accounting (kept at
//!   the *plaintext* frame size by design) stays symmetric;
//! - **policy is enforced on both ends**: a `require` host closes
//!   plaintext hellos, an `off` host closes keyed ones; a `prefer`
//!   client falls back to plaintext when its keyed hello dies, a
//!   `require` client fails loudly instead — and a refused hello never
//!   consumes the host's session budget;
//! - **the host fails closed under attack**: a frame its session keys
//!   cannot authenticate — bit-flipped ciphertext, a tag-less stub —
//!   ends the connection without an answer and without a panic, and
//!   the host keeps serving honest peers afterwards.

mod common;

use common::{gen_world, start_servers};
use sbp::coordinator::{predict_centralized, predict_session_tcp};
use sbp::crypto::cipher::CipherSuite;
use sbp::crypto::secure::{
    derive_session_keys, keypair, FrameCipher, HandleRotor, SecureMode,
};
use sbp::federation::codec::{decode_to_guest, encode_to_host};
use sbp::federation::message::{
    ToGuest, ToHost, SERVE_PROTOCOL_V2, SERVE_PROTOCOL_V3, SERVE_PROTOCOL_V4, SERVE_PROTOCOL_V5,
    SERVE_PROTOCOL_VERSION,
};
use sbp::federation::predict::PredictOptions;
use sbp::federation::serve::ServeConfig;
use sbp::federation::transport::NetSnapshot;
use sbp::util::rng::{ChaCha20Rng, Xoshiro256};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

// ---------------------------------------------------------------- frames

/// Length-prefixed frame write (the codec's `u64` LE header).
fn write_frame(stream: &mut TcpStream, payload: &[u8]) {
    stream.write_all(&(payload.len() as u64).to_le_bytes()).expect("frame header");
    stream.write_all(payload).expect("frame payload");
    stream.flush().expect("flush");
}

/// Length-prefixed frame read; `None` on a clean FIN at a boundary.
fn read_frame(stream: &mut TcpStream) -> Option<Vec<u8>> {
    let mut header = [0u8; 8];
    let mut got = 0;
    while got < header.len() {
        match stream.read(&mut header[got..]).expect("frame header read") {
            0 if got == 0 => return None,
            0 => panic!("FIN inside a frame header"),
            n => got += n,
        }
    }
    let len = u64::from_le_bytes(header) as usize;
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload).expect("frame payload read");
    Some(payload)
}

/// The next read must be a FIN — the host closed without answering.
fn assert_closed_without_answer(stream: &mut TcpStream, what: &str) {
    let mut buf = [0u8; 1];
    assert_eq!(
        stream.read(&mut buf).expect("read at close"),
        0,
        "{what}: the host must close without sending anything"
    );
}

/// Manual keyed handshake on a raw socket: send `SessionHelloSecure`,
/// read the plaintext `SessionAcceptSecure`, derive the session keys.
/// Returns the two directional ciphers and the handle rotor.
fn raw_keyed_handshake(
    stream: &mut TcpStream,
    suite: &CipherSuite,
    ct_len: usize,
    sid: u32,
    rng_seed: [u8; 32],
) -> (FrameCipher, FrameCipher, HandleRotor) {
    let mut entropy = ChaCha20Rng::from_seed(rng_seed);
    let (sk, pk) = keypair(&mut entropy);
    let hello = encode_to_host(
        suite,
        ct_len,
        &ToHost::SessionHelloSecure {
            session_id: sid,
            protocol: SERVE_PROTOCOL_VERSION,
            pubkey: pk,
        },
    );
    write_frame(stream, &hello);
    let accept = read_frame(stream).expect("the keyed accept arrives in plaintext");
    let msg = decode_to_guest(suite, ct_len, &accept).expect("accept decodes");
    let host_pk = match msg {
        ToGuest::SessionAcceptSecure { session_id, protocol, pubkey, .. } => {
            assert_eq!(session_id, sid);
            assert_eq!(protocol, SERVE_PROTOCOL_VERSION);
            pubkey
        }
        other => panic!("expected SessionAcceptSecure, got {other:?}"),
    };
    let shared =
        sbp::crypto::secure::shared_secret(&sk, &host_pk).expect("host key is not degenerate");
    let keys = derive_session_keys(&shared);
    (
        FrameCipher::new(keys.guest_to_host),
        FrameCipher::new(keys.host_to_guest),
        HandleRotor::new(keys.rotor_seed),
    )
}

// ------------------------------------------------------- policy matrix

/// Every secure mode serves bit-identically to the centralized oracle,
/// with symmetric plaintext-level byte accounting, and the host reports
/// the negotiated channel state exactly.
#[test]
fn keyed_serving_is_bit_identical_to_plaintext_and_centralized() {
    let mut rng = Xoshiro256::seed_from_u64(0x5EC0_6AEA);
    let world = gen_world(&mut rng, 2);
    let oracle = predict_centralized(&world.guest_m, &world.host_ms, &world.vs);

    for secure in [SecureMode::Off, SecureMode::Prefer, SecureMode::Require] {
        let cfg = ServeConfig { secure, ..ServeConfig::default() };
        let (addrs, servers) = start_servers(&world, cfg);
        let opts = PredictOptions {
            batch_rows: 4,
            max_inflight: 2,
            seed: 0x5EC0_0001,
            protocol: SERVE_PROTOCOL_VERSION,
            secure,
            ..PredictOptions::default()
        };
        let report = predict_session_tcp(&world.guest_m, &world.vs.guest, &addrs, 51, opts)
            .expect("keyed serving session");
        assert_eq!(
            report.preds, oracle,
            "secure={secure:?}: serving must equal centralized bit for bit"
        );
        let mut host_comm = NetSnapshot::default();
        for server in servers {
            let sr = server.join().expect("server thread");
            assert_eq!(sr.n_sessions, 1, "secure={secure:?}");
            let outcome = &sr.sessions[0].outcome;
            assert!(outcome.clean_close, "secure={secure:?}");
            assert_eq!(
                outcome.secure,
                secure != SecureMode::Off,
                "secure={secure:?}: the host must report the channel it negotiated"
            );
            host_comm = host_comm.add(&sr.comm);
        }
        assert_eq!(
            report.comm, host_comm,
            "secure={secure:?}: byte accounting stays plaintext-level symmetric under AEAD"
        );
    }
}

/// A legacy-protocol hello is always plaintext; a `prefer` host accepts
/// it byte-compatibly and a `prefer` client never even generates a key
/// for it.
#[test]
fn legacy_protocols_negotiate_down_to_plaintext_under_prefer() {
    let mut rng = Xoshiro256::seed_from_u64(0x5EC0_D0E6);
    let world = gen_world(&mut rng, 1);
    let oracle = predict_centralized(&world.guest_m, &world.host_ms, &world.vs);

    for protocol in [SERVE_PROTOCOL_V2, SERVE_PROTOCOL_V3, SERVE_PROTOCOL_V4, SERVE_PROTOCOL_V5] {
        let cfg = ServeConfig { secure: SecureMode::Prefer, ..ServeConfig::default() };
        let (addrs, servers) = start_servers(&world, cfg);
        let opts = PredictOptions {
            batch_rows: 3,
            seed: 0x5EC0_0002,
            protocol,
            secure: SecureMode::Prefer,
            ..PredictOptions::default()
        };
        let report = predict_session_tcp(&world.guest_m, &world.vs.guest, &addrs, 52, opts)
            .expect("legacy session against a prefer host");
        assert_eq!(report.preds, oracle, "v{protocol}: parity");
        for server in servers {
            let sr = server.join().expect("server thread");
            assert_eq!(sr.n_sessions, 1, "v{protocol}");
            let outcome = &sr.sessions[0].outcome;
            assert!(outcome.clean_close, "v{protocol}");
            assert_eq!(outcome.protocol, protocol, "v{protocol}: negotiated down");
            assert!(!outcome.secure, "v{protocol}: a legacy hello is always plaintext");
        }
    }
}

/// A `require` host closes plaintext hellos without burning its session
/// budget, and keeps serving compliant keyed clients afterwards; the
/// refused client fails loudly.
#[test]
fn require_host_refuses_plaintext_clients_and_stays_healthy() {
    let mut rng = Xoshiro256::seed_from_u64(0x5EC0_4E07);
    let world = gen_world(&mut rng, 1);
    let oracle = predict_centralized(&world.guest_m, &world.host_ms, &world.vs);
    let cfg = ServeConfig { secure: SecureMode::Require, ..ServeConfig::default() };
    let (addrs, servers) = start_servers(&world, cfg);

    let plain = PredictOptions {
        batch_rows: 3,
        seed: 0x5EC0_0003,
        protocol: SERVE_PROTOCOL_VERSION,
        secure: SecureMode::Off,
        admission_retries: 1, // fail fast; each retry only meets another close
        ..PredictOptions::default()
    };
    let err = catch_unwind(AssertUnwindSafe(|| {
        predict_session_tcp(&world.guest_m, &world.vs.guest, &addrs, 53, plain)
    }))
    .expect_err("a plaintext client must fail loudly against a require host");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&'static str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("giving up"), "the failure names the exhausted retries, got: {msg}");

    // the host is still healthy: a keyed client completes the budget
    let keyed = PredictOptions {
        batch_rows: 3,
        seed: 0x5EC0_0004,
        protocol: SERVE_PROTOCOL_VERSION,
        secure: SecureMode::Require,
        ..PredictOptions::default()
    };
    let report = predict_session_tcp(&world.guest_m, &world.vs.guest, &addrs, 54, keyed)
        .expect("keyed client after the refused plaintext one");
    assert_eq!(report.preds, oracle);
    for server in servers {
        let sr = server.join().expect("server thread");
        assert_eq!(
            sr.n_sessions, 1,
            "refused plaintext hellos must not count against the session budget"
        );
        assert!(sr.sessions[0].outcome.secure);
        assert!(sr.sessions[0].outcome.clean_close);
    }
}

/// An `off` host closes keyed hellos: a `prefer` client falls back to a
/// plaintext hello and serves; a `require` client refuses to downgrade
/// and fails loudly.
#[test]
fn off_host_closes_keyed_hellos_prefer_falls_back_require_refuses() {
    let mut rng = Xoshiro256::seed_from_u64(0x5EC0_0FF0);
    let world = gen_world(&mut rng, 1);
    let oracle = predict_centralized(&world.guest_m, &world.host_ms, &world.vs);
    let cfg = ServeConfig { secure: SecureMode::Off, ..ServeConfig::default() };
    let (addrs, servers) = start_servers(&world, cfg);

    let require = PredictOptions {
        batch_rows: 3,
        seed: 0x5EC0_0005,
        protocol: SERVE_PROTOCOL_VERSION,
        secure: SecureMode::Require,
        admission_retries: 1,
        ..PredictOptions::default()
    };
    let err = catch_unwind(AssertUnwindSafe(|| {
        predict_session_tcp(&world.guest_m, &world.vs.guest, &addrs, 55, require)
    }))
    .expect_err("a require client must never downgrade to plaintext");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&'static str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("giving up"), "got: {msg}");

    let prefer = PredictOptions {
        batch_rows: 3,
        seed: 0x5EC0_0006,
        protocol: SERVE_PROTOCOL_VERSION,
        secure: SecureMode::Prefer,
        ..PredictOptions::default()
    };
    let report = predict_session_tcp(&world.guest_m, &world.vs.guest, &addrs, 56, prefer)
        .expect("prefer client falls back to plaintext against an off host");
    assert_eq!(report.preds, oracle);
    for server in servers {
        let sr = server.join().expect("server thread");
        assert_eq!(sr.n_sessions, 1);
        assert!(!sr.sessions[0].outcome.secure, "the fallback session is plaintext");
        assert!(sr.sessions[0].outcome.clean_close);
    }
}

// ----------------------------------------------------- adversarial wire

/// Raw-socket attack corpus against a live `require` host: a sealed
/// frame too short to carry its tag, then — on a fresh session that
/// already served one honest sealed batch — a bit-flipped ciphertext.
/// Both must end the connection without an answer and without a panic;
/// the honest part of the second session is still reported.
#[test]
fn tampered_ciphertext_and_truncated_tag_close_without_answers() {
    let mut rng = Xoshiro256::seed_from_u64(0x5EC0_BADC);
    let world = gen_world(&mut rng, 1);
    let suite = CipherSuite::new_plain(64);
    let ct_len = suite.ct_byte_len();
    let cfg = ServeConfig {
        secure: SecureMode::Require,
        delta_window: 0,                  // plain RouteAnswers, no delta frames
        resume_window: Duration::ZERO,    // a hostile close ends the session, no parking
        ..ServeConfig::default()
    };
    let (addrs, servers) = start_servers(&world, cfg);

    // --- truncated tag: the first sealed frame is 8 bytes, shorter
    // than the 16-byte Poly1305 tag. Handshake-only, so this
    // connection is control-only and must not consume the budget.
    {
        let mut stream = TcpStream::connect(&addrs[0]).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let _ = raw_keyed_handshake(&mut stream, &suite, ct_len, 77, [1u8; 32]);
        write_frame(&mut stream, &[0u8; 8]);
        assert_closed_without_answer(&mut stream, "truncated tag");
    }

    // --- tampered ciphertext, after one honest sealed round trip
    {
        let mut stream = TcpStream::connect(&addrs[0]).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let (mut enc, mut dec, rotor) =
            raw_keyed_handshake(&mut stream, &suite, ct_len, 78, [2u8; 32]);

        // honest sealed batch: one query for host handle 0, rotated
        // exactly as a real v6 guest would send it
        let route = encode_to_host(
            &suite,
            ct_len,
            &ToHost::PredictRoute { session: 78, chunk: 0, queries: vec![(0, rotor.rotate(0))] },
        );
        let mut sealed = Vec::new();
        enc.seal_into(&route, &mut sealed);
        write_frame(&mut stream, &sealed);
        let mut answer = read_frame(&mut stream).expect("the honest batch is answered");
        let n = dec.open_in_place(&mut answer).expect("the answer authenticates");
        match decode_to_guest(&suite, ct_len, &answer[..n]).expect("answer decodes") {
            ToGuest::RouteAnswers { session, chunk, n, .. } => {
                assert_eq!(session, 78);
                assert_eq!(chunk, 0);
                assert_eq!(n, 1);
            }
            other => panic!("expected RouteAnswers, got {other:?}"),
        }

        // now flip one ciphertext bit of an otherwise-valid frame
        let route2 = encode_to_host(
            &suite,
            ct_len,
            &ToHost::PredictRoute { session: 78, chunk: 1, queries: vec![(0, rotor.rotate(1))] },
        );
        enc.seal_into(&route2, &mut sealed);
        sealed[sealed.len() / 2] ^= 0x40;
        write_frame(&mut stream, &sealed);
        assert_closed_without_answer(&mut stream, "tampered ciphertext");
    }

    for server in servers {
        let sr = server.join().expect("the host survives both attacks without panicking");
        assert_eq!(
            sr.n_sessions, 1,
            "only the session that served an honest batch is reported \
             (the tag-less stub was handshake-only, hence control-only)"
        );
        let outcome = &sr.sessions[0].outcome;
        assert!(outcome.secure, "the reported session ran keyed");
        assert!(
            !outcome.clean_close,
            "a tampered frame is never a clean close — the host drops the peer"
        );
        assert_eq!(outcome.batches, 1, "exactly the honest batch was served");
    }
}
