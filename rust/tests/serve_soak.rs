//! Randomized parity-soak for the serving stack under every serve
//! protocol from v2 to the current version: each iteration draws a
//! random world (rows, model shape, host count) and a random
//! serving/client configuration (chunk size, in-flight window, delta
//! window, basis-evict policy, cache capacity, decoy padding, protocol
//! version, secure-channel mode, repeat passes), runs it through
//! real `serve_predict_tcp` hosts over loopback framed TCP, and asserts
//! the two hard invariants of the whole subsystem:
//!
//! 1. **bit-parity** — federated predictions equal the colocated
//!    centralized oracle exactly, whatever the pipeline/eviction/cache
//!    configuration (and whatever the negotiated protocol version);
//! 2. **byte-accounting symmetry** — the guest's wire counters equal
//!    the sum of the hosts' per-session counters, byte for byte.
//!
//! A small fixed-seed instance runs in CI; the full range is behind
//! `--ignored` (`cargo test --test serve_soak -- --ignored`).

mod common;

use common::{gen_world, start_servers};
use sbp::coordinator::{predict_centralized, predict_session_tcp, predict_stream_passes_tcp};
use sbp::crypto::secure::SecureMode;
use sbp::data::dataset::{PartySlice, VerticalSplit};
use sbp::federation::message::{
    BasisEvict, SERVE_PROTOCOL_V2, SERVE_PROTOCOL_V3, SERVE_PROTOCOL_V4, SERVE_PROTOCOL_VERSION,
};
use sbp::federation::predict::{PredictOptions, PredictSession};
use sbp::federation::serve::{spawn_serve_session, HostServeState, ServeConfig};
use sbp::federation::transport::{link_pair_bounded, GuestTransport, NetSnapshot};
use sbp::tree::node::SplitRef;
use sbp::tree::node::Tree;
use sbp::tree::predict::{GuestModel, HostModel};
use sbp::util::rng::Xoshiro256;

/// One soak iteration: draw a world and a configuration, score it
/// federated, and check parity + accounting symmetry. The discrete
/// dimensions (host count, delta on/off, cache on/off, eviction policy,
/// protocol version, lockstep vs pipelined, repeat passes) cycle with
/// the iteration index so even the small CI instance covers the whole
/// matrix; the continuous ones (rows, widths, windows, seeds) come from
/// the seeded rng.
fn run_iteration(seed: u64, it: usize) {
    let mut rng =
        Xoshiro256::seed_from_u64(seed ^ (it as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let n_hosts = 1 + it % 2;
    let world = gen_world(&mut rng, n_hosts);
    let oracle = predict_centralized(&world.guest_m, &world.host_ms, &world.vs);

    let delta_window = if it % 3 == 0 { 0 } else { [4usize, 64, 1 << 12][rng.next_below(3)] };
    let cache_capacity = if it % 2 == 0 { 0 } else { 1usize << (4 + rng.next_below(8)) };
    let basis_evict = if it % 4 < 2 { BasisEvict::Lru } else { BasisEvict::Freeze };
    let protocol = match it % 5 {
        4 => SERVE_PROTOCOL_V2,
        3 => SERVE_PROTOCOL_V3,
        2 => SERVE_PROTOCOL_V4,
        _ => SERVE_PROTOCOL_VERSION,
    };
    let max_inflight = 1 + rng.next_below(8) as u32;
    let batch_rows = [0usize, 1, 3, 7, 16][rng.next_below(5)];
    let dummy_queries = [0usize, 0, 3, 9][rng.next_below(4)];
    let passes = if batch_rows > 0 && it % 4 == 1 { 2 } else { 1 };
    // Stage C dimensions: pool width cycles 0 (auto) / 1 / 4, and the
    // shard threshold alternates between "everything fans out" and the
    // default (these worlds are small, so the default keeps compute
    // inline) — sharded and inline serving must be indistinguishable to
    // every assertion below (bit-parity, byte symmetry, frame order)
    let compute_workers = [0usize, 1, 4][it % 3];
    let compute_shard_min =
        if it % 2 == 0 { 1 } else { ServeConfig::default().compute_shard_min };
    // the v6 encrypted-channel axis: off / prefer / require cycle with
    // the iteration index. `require` only pairs with a current-protocol
    // hello (a legacy hello is always plaintext); legacy-protocol
    // iterations under `prefer` double as negotiate-down coverage —
    // AEAD-on serving must be indistinguishable from AEAD-off to every
    // assertion below (bit-parity, byte symmetry, negotiated protocol)
    let secure = match it % 3 {
        0 => SecureMode::Off,
        _ if protocol == SERVE_PROTOCOL_VERSION && it % 3 == 2 => SecureMode::Require,
        _ => SecureMode::Prefer,
    };
    let tag = format!(
        "it {it}: n={} hosts={n_hosts} batch_rows={batch_rows} inflight={max_inflight} \
         delta={delta_window} cache={cache_capacity} evict={} v{protocol} decoys={dummy_queries} \
         passes={passes} cw={compute_workers} csm={compute_shard_min} secure={secure:?}",
        world.vs.n(),
        basis_evict.name()
    );

    let cfg = ServeConfig {
        cache_capacity,
        delta_window,
        basis_evict,
        max_inflight,
        compute_workers,
        compute_shard_min,
        secure,
        ..ServeConfig::default()
    };
    let (addrs, servers) = start_servers(&world, cfg);
    let opts = PredictOptions {
        dummy_queries,
        seed: rng.next_u64(),
        batch_rows,
        max_inflight: 1 + rng.next_below(6),
        protocol,
        secure,
        ..PredictOptions::default()
    };

    let client_comm: Option<NetSnapshot> = if passes == 1 {
        let r = predict_session_tcp(&world.guest_m, &world.vs.guest, &addrs, 1, opts)
            .expect("soak session");
        assert_eq!(r.preds, oracle, "{tag}: federated must equal centralized");
        Some(r.comm)
    } else {
        let reports = predict_stream_passes_tcp(
            &world.guest_m,
            &world.vs.guest,
            &addrs,
            1,
            opts,
            passes,
        )
        .expect("soak repeat session");
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(r.preds, oracle, "{tag}: pass {i} must equal centralized");
        }
        None // per-pass diffs exclude the handshake; symmetry is
             // checked on the single-report iterations
    };

    let mut host_comm = NetSnapshot::default();
    for server in servers {
        let report = server.join().expect("server thread");
        assert_eq!(report.n_sessions, 1, "{tag}: exactly one serving session");
        host_comm = host_comm.add(&report.comm);
        let outcome = &report.sessions[0].outcome;
        assert!(outcome.clean_close, "{tag}: session must close cleanly");
        assert_eq!(outcome.protocol, protocol, "{tag}: negotiated protocol");
        // AEAD engages exactly when the client asked for it AND spoke the
        // current protocol; a legacy hello always lands in plaintext
        assert_eq!(
            outcome.secure,
            secure != SecureMode::Off && protocol == SERVE_PROTOCOL_VERSION,
            "{tag}: secure-channel negotiation outcome"
        );
        let expect_evict =
            if protocol >= SERVE_PROTOCOL_V3 { basis_evict } else { BasisEvict::Freeze };
        assert_eq!(outcome.basis_evict, expect_evict, "{tag}: negotiated policy");
        assert!(
            outcome.ring_high_water <= max_inflight.max(1) as usize,
            "{tag}: decode ring exceeded its bound ({} > {max_inflight})",
            outcome.ring_high_water
        );
        if delta_window == 0 {
            assert_eq!(outcome.answers_elided, 0, "{tag}: delta off elides nothing");
        }
    }
    if let Some(client) = client_comm {
        assert_eq!(
            client, host_comm,
            "{tag}: guest and host byte accounting must be symmetric"
        );
    }
}

/// The fixed-seed CI instance: small, deterministic, covers the whole
/// discrete matrix (1/2 hosts, delta on/off, cache on/off, lru/freeze,
/// v2/v3/v4, lockstep/pipelined, single/repeat passes).
#[test]
fn soak_fixed_seed() {
    for it in 0..10 {
        run_iteration(0x5EC0_0B57, it);
    }
}

/// The full soak range — slow; run explicitly with
/// `cargo test --release --test serve_soak -- --ignored`.
#[test]
#[ignore = "full randomized soak; run explicitly"]
fn soak_full_range() {
    for seed in [0x5EC0_0B57u64, 0xA11CE, 0xB00B5] {
        for it in 0..24 {
            run_iteration(seed, it);
        }
    }
}

/// The acceptance scenario for the negotiated LRU: a session whose
/// working set (4 keys) exceeds `delta_window` (2), then a repeat ask
/// of the *recently answered* keys. Under `lru` the repeat is fully
/// elided (the basis rotated to hold the recent keys); under `freeze`
/// it re-pays the wire in full (the basis froze on the oldest keys and
/// never admitted the recent ones). Bits are identical either way.
///
/// The scenario is built from whole batches — {0,1}, then {2,3}, then
/// {2,3} again — so the outcome does not depend on query order *within*
/// a batch (each batch's keys are uniformly fresh or uniformly known
/// under either policy).
#[test]
fn lru_elides_recent_rescoring_past_the_window_where_freeze_cannot() {
    // 4 records; the guest's feature decides which rows consult the
    // host split in a given scoring call
    let n = 4usize;
    let mut t = Tree::new(1);
    let (l, r) = t.split_node(0, SplitRef::Guest { feature: 0, bin: 0, threshold: 0.5 });
    t.nodes[l as usize].weight = vec![-1.0];
    t.split_node(r, SplitRef::Host { party: 0, handle: 0 });
    let r = r as usize;
    let rl = t.nodes[r].left as usize;
    let rr = t.nodes[r].right as usize;
    t.nodes[rl].weight = vec![1.0];
    t.nodes[rr].weight = vec![2.0];
    let guest_m = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
    let host_m = HostModel { party: 0, splits: vec![(0, 0, 0.0)] };
    let host_slice = PartySlice { cols: vec![1], x: vec![-0.5, 0.5, -0.5, 0.5], n };
    // guest slice where exactly `rows` consult the host (feature 1.0
    // routes right into the host split; 0.0 exits at the guest leaf)
    let gx = |rows: [usize; 2]| PartySlice {
        cols: vec![0],
        x: (0..n).map(|i| if rows.contains(&i) { 1.0 } else { 0.0 }).collect(),
        n,
    };
    let old_guest = gx([0, 1]);
    let new_guest = gx([2, 3]);
    let vs_for = |guest: &PartySlice| VerticalSplit {
        guest: guest.clone(),
        hosts: vec![host_slice.clone()],
        y: vec![0.0; n],
        n_classes: 2,
        name: "lru-recency".into(),
    };
    let oracle_old = predict_centralized(&guest_m, &[host_m.clone()], &vs_for(&old_guest));
    let oracle_new = predict_centralized(&guest_m, &[host_m.clone()], &vs_for(&new_guest));

    let run = |evict: BasisEvict| {
        let state = HostServeState::new(
            host_m.clone(),
            host_slice.clone(),
            ServeConfig {
                cache_capacity: 0,
                delta_window: 2, // < the session's 4-key working set
                basis_evict: evict,
                ..ServeConfig::default()
            },
        );
        let (gl, hl) = link_pair_bounded(8, 8);
        let host = spawn_serve_session(state, hl);
        let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
        let mut session = PredictSession::new(
            &guest_m,
            31,
            PredictOptions { batch_rows: n, seed: 9, ..PredictOptions::default() },
        );
        session.open(&links);
        // streamed passes synchronize the bases without touching the
        // session memo (chunk memos die with their chunks): first the
        // old keys {0,1}, then the new keys {2,3} — the lru basis ends
        // holding {2,3}, the frozen one froze on {0,1}
        let (p_old, _) = session.predict_stream(&old_guest, &links);
        let (p_new, _) = session.predict_stream(&new_guest, &links);
        // the repeat ask of the *recent* keys goes through predict_batch
        // (empty session memo ⇒ the keys actually travel — unless the
        // host elides them from its basis)
        let p_repeat = session.predict_batch(&new_guest, &links);
        let elided = session.delta_elided_answers();
        session.close(&links);
        let outcome = host.join().expect("serve session thread");
        (p_old, p_new, p_repeat, elided, outcome.answers_elided)
    };

    let (lo, ln, lr_, l_elided, l_host_elided) = run(BasisEvict::Lru);
    let (fo, fn_, fr_, f_elided, f_host_elided) = run(BasisEvict::Freeze);

    // parity first: eviction policy may never change bits
    assert_eq!(lo, oracle_old);
    assert_eq!(fo, oracle_old);
    assert_eq!(ln, oracle_new);
    assert_eq!(fn_, oracle_new);
    assert_eq!(lr_, oracle_new);
    assert_eq!(fr_, oracle_new);

    // the distinguishing observable: the LRU basis rotated to hold the
    // recent keys and elides the whole repeat; the frozen basis froze
    // on the oldest keys and elides nothing, ever
    assert_eq!(l_elided, 2, "lru: both recent keys resolved from the mirrored basis");
    assert_eq!(l_host_elided, 2);
    assert_eq!(f_elided, 0, "freeze: the recent keys never entered the frozen basis");
    assert_eq!(f_host_elided, 0);
}
