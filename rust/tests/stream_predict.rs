//! Pipelined streaming inference tests: chunked in-flight scoring must
//! be bit-identical to the lockstep single-batch path and to the
//! colocated oracle across chunk sizes (1, a remainder size, an exact
//! divisor, one covering chunk) and transports; the `max_inflight`
//! window must bound what the guest puts on the wire (blocking, not
//! queueing without bound); and repeat scoring in one session must get
//! cheaper on the wire through the delta-synchronized basis.

use sbp::config::{CipherKind, TrainConfig};
use sbp::coordinator::{
    predict_centralized, predict_session_tcp, predict_stream_passes_tcp, serve_predict_tcp,
    train_federated, ServeReport,
};
use sbp::data::dataset::{PartySlice, VerticalSplit};
use sbp::data::synthetic::SyntheticSpec;
use sbp::federation::message::ToHost;
use sbp::federation::predict::{PredictHostParty, PredictOptions, PredictSession};
use sbp::federation::serve::{spawn_serve_session, HostServeState, ServeConfig};
use sbp::federation::transport::{link_pair_bounded, GuestTransport};
use sbp::tree::node::{SplitRef, Tree};
use sbp::tree::predict::{GuestModel, HostModel};

fn fast_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::secureboost_plus();
    cfg.epochs = 4;
    cfg.max_depth = 3;
    cfg.cipher = CipherKind::Plain;
    cfg.goss = None;
    cfg.sparse_optimization = false;
    cfg
}

fn train(spec: SyntheticSpec, cfg: &TrainConfig) -> (VerticalSplit, GuestModel, Vec<HostModel>) {
    let vs = spec.generate_vertical(cfg.seed, cfg.n_hosts);
    let rep = train_federated(&vs, cfg).expect("training run");
    let (guest_m, host_ms) = rep.model();
    (vs, guest_m, host_ms)
}

fn start_server(
    vs: &VerticalSplit,
    host_ms: &[HostModel],
    cfg: ServeConfig,
    max_sessions: usize,
) -> (String, std::thread::JoinHandle<ServeReport>) {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    let model = host_ms[0].clone();
    let slice = vs.hosts[0].clone();
    let handle = std::thread::spawn(move || {
        serve_predict_tcp(&listener, model, slice, cfg, max_sessions).expect("serve loop")
    });
    (addr, handle)
}

/// The streamed pipelined path must be bit-identical to lockstep and to
/// colocated across chunk sizes: 1 (degenerate), 7 (remainder), an
/// exact divisor of n, and n itself (one covering chunk).
#[test]
fn pipelined_matches_lockstep_and_colocated_across_chunk_sizes() {
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);
    let n = vs.n();
    // largest proper divisor of n (falls back to n when prime)
    let divisor = (2..=n / 2).rev().find(|d| n % d == 0).map(|d| n / d).unwrap_or(n);
    let sizes = [1usize, 7, divisor, n];

    let (addr, server) =
        start_server(&vs, &host_ms, ServeConfig::default(), sizes.len() + 1);
    let addrs = [addr];

    // lockstep session first: the chunked sessions must match it exactly
    let lockstep = predict_session_tcp(
        &guest_m,
        &vs.guest,
        &addrs,
        99,
        PredictOptions { seed: 1, ..PredictOptions::default() },
    )
    .expect("lockstep session");
    assert_eq!(lockstep.preds, oracle, "lockstep must match colocated");
    assert_eq!(lockstep.chunks, 0, "lockstep reports no pipeline");

    for (i, &batch_rows) in sizes.iter().enumerate() {
        let r = predict_session_tcp(
            &guest_m,
            &vs.guest,
            &addrs,
            (i + 1) as u32,
            PredictOptions {
                batch_rows,
                max_inflight: 3,
                seed: 2,
                ..PredictOptions::default()
            },
        )
        .expect("pipelined session");
        assert_eq!(
            r.preds, oracle,
            "chunk size {batch_rows} must be bit-identical to colocated"
        );
        assert_eq!(r.chunks, n.div_ceil(batch_rows) as u64, "chunk count for {batch_rows}");
        assert_eq!(r.transport, "tcp-pipelined");
        assert_eq!(r.n_rows, n);
    }
    let serve_report = server.join().expect("server thread");
    assert_eq!(serve_report.n_sessions, sizes.len() + 1);
}

/// Multi-host pipelining: chunks in flight against two host processes,
/// answers rejoined per link in FIFO order, still bit-identical.
#[test]
fn two_host_pipelined_sessions_match_colocated() {
    let mut cfg = fast_cfg();
    cfg.n_hosts = 2;
    let (vs, guest_m, host_ms) = train(SyntheticSpec::higgs(0.0002), &cfg);
    assert_eq!(host_ms.len(), 2);
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);

    let mut addrs = Vec::new();
    let mut servers = Vec::new();
    for p in 0..2 {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().unwrap().to_string());
        let model = host_ms[p].clone();
        let slice = vs.hosts[p].clone();
        servers.push(std::thread::spawn(move || {
            serve_predict_tcp(&listener, model, slice, ServeConfig::default(), 1)
                .expect("serve loop")
        }));
    }
    let r = predict_session_tcp(
        &guest_m,
        &vs.guest,
        &addrs,
        5,
        PredictOptions { batch_rows: 37, max_inflight: 4, seed: 3, ..PredictOptions::default() },
    )
    .expect("pipelined 2-host session");
    assert_eq!(r.preds, oracle, "2-host pipelined must match colocated");
    assert!(r.chunks > 1);
    for server in servers {
        server.join().expect("server thread");
    }
}

/// The `max_inflight` window must bound what the guest puts on the
/// wire: with the host gated (accepting frames but not answering), a
/// streamed pass with window 2 sends exactly 2 chunk frames and then
/// *blocks* — it does not queue the remaining chunks unboundedly.
#[test]
fn max_inflight_window_blocks_instead_of_queueing() {
    // toy model whose every row consults the host once
    let mut t = Tree::new(1);
    t.split_node(0, SplitRef::Host { party: 0, handle: 0 });
    t.nodes[1].weight = vec![1.0];
    t.nodes[2].weight = vec![2.0];
    let guest_m = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
    let host_m = HostModel { party: 0, splits: vec![(0, 0, 0.0)] };
    let guest_slice = PartySlice { cols: vec![0], x: vec![9.0; 6], n: 6 };
    let host_slice = PartySlice {
        cols: vec![1],
        x: vec![-1.0, 1.0, -2.0, 3.0, 0.5, -0.5],
        n: 6,
    };
    let expected = vec![1.0, 2.0, 1.0, 2.0, 2.0, 1.0];

    let (gl, hl) = link_pair_bounded(8, 4); // roomy queue: blocking must come from the window
    let counters = hl.counters();
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    std::thread::scope(|s| {
        let host = s.spawn(move || {
            // gated: the host only starts serving once the window bound
            // has been observed from outside
            gate_rx.recv().ok();
            PredictHostParty::new(host_m, host_slice, hl).run()
        });
        let gm = &guest_m;
        let gs = &guest_slice;
        let guest = s.spawn(move || {
            let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
            // sessionless: no handshake to wait on, so the guest runs
            // ahead of the gated host immediately
            let mut session = PredictSession::sessionless_with(
                gm,
                PredictOptions {
                    batch_rows: 1, // 6 chunks, every one needing a host round
                    max_inflight: 2,
                    seed: 4,
                    ..PredictOptions::default()
                },
            );
            let out = session.predict_stream(gs, &links);
            links[0].send(ToHost::Shutdown);
            out
        });
        // the guest must send exactly window = 2 chunk frames, then block
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while counters.snapshot().msgs_to_host < 2 {
            assert!(std::time::Instant::now() < deadline, "guest never sent its window");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
        assert_eq!(
            counters.snapshot().msgs_to_host,
            2,
            "guest must block at the in-flight window, not queue all 6 chunks"
        );
        gate_tx.send(()).expect("host gate");
        let (preds, report) = guest.join().expect("guest thread");
        host.join().expect("host thread");
        assert_eq!(preds, expected, "gated pipelined run must still be correct");
        assert_eq!(report.chunks, 6);
        assert_eq!(report.window, 2);
        assert_eq!(report.max_inflight_observed, 2, "window fully used, never exceeded");
        assert!(report.stall_seconds > 0.0, "the gate must register as stall time");
    });
}

/// Backpressure regression for the host's 2-stage pipeline: a
/// deliberately slow Stage B (compute) must bound the Stage-A decode
/// ring at `max_inflight` decoded frames — Stage A then blocks instead
/// of buffering the guest's whole stream — and the run must still
/// complete without deadlocking the guest's undrained-answer budget,
/// bit-identically.
#[test]
fn slow_compute_stage_bounds_the_decode_ring_without_deadlock() {
    // toy model whose every row consults the host once per chunk
    let mut t = Tree::new(1);
    t.split_node(0, SplitRef::Host { party: 0, handle: 0 });
    t.nodes[1].weight = vec![1.0];
    t.nodes[2].weight = vec![2.0];
    let guest_m = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
    let host_m = HostModel { party: 0, splits: vec![(0, 0, 0.0)] };
    let n = 12usize;
    let host_x: Vec<f64> = (0..n).map(|i| if i % 3 == 0 { -1.0 } else { 1.0 }).collect();
    let expected: Vec<f64> =
        (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 2.0 }).collect();
    let guest_slice = PartySlice { cols: vec![0], x: vec![9.0; n], n };
    let host_slice = PartySlice { cols: vec![1], x: host_x, n };

    const RING: u32 = 3;
    let state = HostServeState::new(
        host_m,
        host_slice,
        ServeConfig {
            cache_capacity: 0,
            max_inflight: RING, // = the decode ring's depth
            stage_b_delay: Some(std::time::Duration::from_millis(25)),
            ..ServeConfig::default()
        },
    );
    // roomy link queue: the binding constraint must be the decode ring,
    // not the transport
    let (gl, hl) = link_pair_bounded(8, 64);
    let host = spawn_serve_session(state, hl);
    let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
    // sessionless: no handshake clamps the guest window, so the guest
    // runs ahead of the slow host by more than the ring holds — the
    // overflow must park in Stage A's blocked send, not in host memory
    let mut session = PredictSession::sessionless_with(
        &guest_m,
        PredictOptions {
            batch_rows: 1, // 12 chunks, one host round each
            max_inflight: 8,
            seed: 21,
            ..PredictOptions::default()
        },
    );
    let (preds, report) = session.predict_stream(&guest_slice, &links);
    links[0].send(ToHost::Shutdown);
    let outcome = host.join().expect("serve session thread");

    assert_eq!(preds, expected, "a throttled pipeline must still answer right");
    assert_eq!(report.chunks, n as u64);
    assert_eq!(report.window, 8, "the guest window exceeds the ring on purpose");
    assert_eq!(
        outcome.ring_high_water, RING as usize,
        "the decode ring must fill to exactly its bound and no further"
    );
    assert!(
        outcome.decode_stall_seconds > 0.0,
        "a slow Stage B must visibly throttle Stage A"
    );
    assert!(outcome.clean_close, "the trailing Shutdown ends the session cleanly");
    assert_eq!(outcome.batches, n as u64);
}

/// Repeat scoring in one session (the memo-heavy workload): with delta
/// suppression on, pass 2 is resolved from the synchronized basis and
/// crosses the wire not at all; with it off, pass 2 pays the full
/// per-row wire cost again. Both are bit-identical to colocated.
#[test]
fn repeat_scoring_bytes_drop_with_delta_suppression() {
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);
    let opts = PredictOptions {
        batch_rows: 64,
        max_inflight: 2,
        seed: 11,
        ..PredictOptions::default()
    };

    let run = |delta_window: usize| {
        let (addr, server) = start_server(
            &vs,
            &host_ms,
            ServeConfig { delta_window, ..ServeConfig::default() },
            1,
        );
        let reports =
            predict_stream_passes_tcp(&guest_m, &vs.guest, &[addr], 1, opts, 2)
                .expect("repeat-scoring session");
        let serve_report = server.join().expect("server thread");
        (reports, serve_report)
    };

    let (with_delta, _) = run(1 << 16);
    let (without_delta, serve_off) = run(0);
    assert_eq!(serve_off.answers_elided, 0, "delta off elides nothing");

    for reports in [&with_delta, &without_delta] {
        assert_eq!(reports.len(), 2);
        for r in reports.iter() {
            assert_eq!(r.preds, oracle, "every pass must be bit-identical to colocated");
        }
    }
    let on1 = with_delta[0].comm.total_bytes();
    let on2 = with_delta[1].comm.total_bytes();
    let off2 = without_delta[1].comm.total_bytes();
    assert!(on1 > 0, "pass 1 pays the full wire cost");
    assert_eq!(
        on2, 0,
        "pass 2 must be wire-free: every key is in the delta-synchronized basis"
    );
    assert!(
        off2 > 0,
        "without the delta basis, pass 2 re-pays the wire cost ({off2} B)"
    );
    assert!(with_delta[1].suppressed_queries > 0, "pass 2 resolves from the basis");
}

/// A streamed chunked session and the single-batch session agree with
/// in-memory serving too, including suppressed-query bookkeeping.
#[test]
fn streamed_session_against_live_server_reports_pipeline_stats() {
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);
    let (addr, server) = start_server(&vs, &host_ms, ServeConfig::default(), 1);
    let r = predict_session_tcp(
        &guest_m,
        &vs.guest,
        &[addr],
        17,
        PredictOptions { batch_rows: 50, max_inflight: 4, seed: 8, ..PredictOptions::default() },
    )
    .expect("streamed session");
    let serve_report = server.join().expect("server thread");
    assert_eq!(r.preds, oracle);
    assert_eq!(r.chunks, vs.n().div_ceil(50) as u64);
    assert!(r.mean_inflight >= 1.0, "pipeline occupancy is at least one chunk");
    assert!(r.stall_seconds >= 0.0);
    assert_eq!(serve_report.n_sessions, 1);
    assert!(serve_report.queries_answered > 0);
}
