//! Shared fixtures for the serving integration suites
//! (`tests/serve_soak.rs`, `tests/serve_fault.rs`): randomized serving
//! worlds and loopback `serve_predict_tcp` bring-up.
#![allow(dead_code)] // each test binary uses its own subset

use sbp::coordinator::{serve_predict_tcp, ServeReport};
use sbp::data::dataset::{PartySlice, VerticalSplit};
use sbp::federation::serve::ServeConfig;
use sbp::tree::node::{SplitRef, Tree};
use sbp::tree::predict::{GuestModel, HostModel};
use sbp::util::rng::Xoshiro256;

/// One randomly drawn serving world: aligned per-party feature slices
/// plus a hand-built (not trained) model whose every host party is
/// consulted by every row — a host with no traffic would be a
/// control-only session and would hang a budgeted serve loop.
pub struct World {
    pub vs: VerticalSplit,
    pub guest_m: GuestModel,
    pub host_ms: Vec<HostModel>,
}

fn uni(rng: &mut Xoshiro256) -> f64 {
    rng.next_f64() * 2.0 - 1.0
}

/// Recursively grow a random tree below `node`. `force_host` pins the
/// root to a split owned by that host party, guaranteeing the party is
/// consulted by every row of every batch.
fn grow(
    t: &mut Tree,
    node: u32,
    depth: u8,
    rng: &mut Xoshiro256,
    guest_d: usize,
    host_ms: &[HostModel],
    force_host: Option<usize>,
) {
    let split_here = force_host.is_some() || (depth < 3 && rng.next_below(10) < 7);
    if !split_here {
        t.nodes[node as usize].weight = vec![uni(rng) * 2.0];
        return;
    }
    let split = match force_host {
        Some(p) => SplitRef::Host {
            party: p as u8,
            handle: rng.next_below(host_ms[p].splits.len()) as u32,
        },
        None => {
            if rng.next_below(2) == 0 {
                SplitRef::Guest {
                    feature: rng.next_below(guest_d) as u32,
                    bin: 0,
                    threshold: uni(rng),
                }
            } else {
                let p = rng.next_below(host_ms.len());
                SplitRef::Host {
                    party: p as u8,
                    handle: rng.next_below(host_ms[p].splits.len()) as u32,
                }
            }
        }
    };
    let (l, r) = t.split_node(node, split);
    grow(t, l, depth + 1, rng, guest_d, host_ms, None);
    grow(t, r, depth + 1, rng, guest_d, host_ms, None);
}

pub fn gen_world(rng: &mut Xoshiro256, n_hosts: usize) -> World {
    let n = 1 + rng.next_below(48);
    let guest_d = 1 + rng.next_below(3);
    let host_ds: Vec<usize> = (0..n_hosts).map(|_| 1 + rng.next_below(3)).collect();

    let guest = PartySlice {
        cols: (0..guest_d).collect(),
        x: (0..n * guest_d).map(|_| uni(rng)).collect(),
        n,
    };
    let mut col0 = guest_d;
    let hosts: Vec<PartySlice> = host_ds
        .iter()
        .map(|&d| {
            let s = PartySlice {
                cols: (col0..col0 + d).collect(),
                x: (0..n * d).map(|_| uni(rng)).collect(),
                n,
            };
            col0 += d;
            s
        })
        .collect();

    let host_ms: Vec<HostModel> = (0..n_hosts)
        .map(|p| HostModel {
            party: p as u8,
            splits: (0..3 + rng.next_below(6))
                .map(|_| (rng.next_below(host_ds[p]) as u32, 0u8, uni(rng)))
                .collect(),
        })
        .collect();

    // every host party roots at least one tree, so every session
    // carries real traffic for every host
    let n_trees = n_hosts + 1 + rng.next_below(3);
    let mut trees = Vec::with_capacity(n_trees);
    for t_idx in 0..n_trees {
        let mut t = Tree::new(1);
        let force = (t_idx < n_hosts).then_some(t_idx);
        grow(&mut t, 0, 0, rng, guest_d, &host_ms, force);
        trees.push((t, 0usize));
    }
    let guest_m = GuestModel { trees, n_classes: 2, pred_width: 1 };

    let vs = VerticalSplit {
        guest,
        hosts,
        y: vec![0.0; n],
        n_classes: 2,
        name: "soak".into(),
    };
    World { vs, guest_m, host_ms }
}

/// Start one `serve_predict_tcp` loop per host party, budgeted to one
/// session each.
pub fn start_servers(
    world: &World,
    cfg: ServeConfig,
) -> (Vec<String>, Vec<std::thread::JoinHandle<ServeReport>>) {
    let mut addrs = Vec::new();
    let mut servers = Vec::new();
    for p in 0..world.host_ms.len() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().unwrap().to_string());
        let model = world.host_ms[p].clone();
        let slice = world.vs.hosts[p].clone();
        servers.push(std::thread::spawn(move || {
            serve_predict_tcp(&listener, model, slice, cfg, 1).expect("serve loop")
        }));
    }
    (addrs, servers)
}
