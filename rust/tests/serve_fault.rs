//! Seeded fault-injection soak for serve-protocol-v4 session
//! resumption: every scenario kills a live serving connection at a
//! *planned* frame boundary ([`FaultPlan`]) and asserts the resumed
//! stream is **bit-identical** to the centralized oracle, with
//! `StreamReport::reconnects` / `StreamReport::chunks_replayed` matching
//! the injected plan *exactly* — the replay count owed after a kill is
//! arithmetic, not luck: a graceful FIN delivers every fully-sent
//! request, the host answers all of them, and the guest acknowledged
//! precisely the answers it received, so
//! `chunks_replayed = routes_fully_sent − answers_received` at the kill.
//!
//! Coverage:
//!
//! - an **exhaustive frame-boundary kill sweep** of a 3-chunk stream —
//!   every interior boundary (route sends and answer receives alike,
//!   half of them with torn-write prefixes) dies once; the whole sweep
//!   runs in plaintext and again under the v6 encrypted channel;
//! - a **seeded randomized matrix** (kill point × chunk size × in-flight
//!   window × delta window × eviction policy × protocol version ×
//!   secure on/off × 1–2 hosts): current-protocol peers resume
//!   bit-identically (re-keying keyed channels), v2/v3 peers fail
//!   loudly and cleanly while the host stays healthy; the fixed-seed
//!   slice runs in CI, the full range behind `--ignored`
//!   (`cargo test --release --test serve_fault -- --ignored`);
//! - a **partial-I/O corpus** for the reactor's non-blocking
//!   [`NbConn`]: every sample frame split at every byte position
//!   reassembles identically, every torn-write prefix + FIN errors
//!   cleanly, and queued writes flush byte-identically.

mod common;

use common::{gen_world, start_servers, World};
use sbp::coordinator::predict_centralized;
use sbp::crypto::cipher::CipherSuite;
use sbp::crypto::secure::SecureMode;
use sbp::federation::codec::{encode_to_guest, encode_to_host, WireError};
use sbp::federation::fault::{FaultPlan, FaultyConn, FaultyTransport};
use sbp::federation::message::{
    BasisEvict, ToGuest, ToHost, SERVE_PROTOCOL_V2, SERVE_PROTOCOL_V3, SERVE_PROTOCOL_VERSION,
};
use sbp::federation::predict::{PredictOptions, PredictSession, StreamReport};
use sbp::federation::serve::ServeConfig;
use sbp::federation::tcp::{NbConn, RecvPoll, TcpGuestTransport};
use sbp::federation::transport::{GuestTransport, NetSnapshot};
use sbp::util::rng::Xoshiro256;
use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Shared handle over a [`FaultyTransport`]: the session engine drives
/// it as a boxed [`GuestTransport`] while the test keeps the same
/// wrapper for post-run kill-log assertions.
struct SharedFault(Arc<FaultyTransport>);

impl GuestTransport for SharedFault {
    fn send(&self, msg: ToHost) {
        self.0.send(msg)
    }
    fn recv(&self) -> ToGuest {
        self.0.recv()
    }
    fn snapshot(&self) -> NetSnapshot {
        self.0.snapshot()
    }
    fn try_send(&self, msg: ToHost) -> std::io::Result<()> {
        self.0.try_send(msg)
    }
    fn try_recv(&self) -> std::io::Result<ToGuest> {
        self.0.try_recv()
    }
    fn reconnect(&self) -> std::io::Result<()> {
        self.0.reconnect()
    }
    fn set_secure(&self, enc_key: [u8; 32], dec_key: [u8; 32]) {
        // must delegate (the trait default is a no-op): a keyed session
        // arms AEAD on the *real* TCP link underneath the fault wrapper
        self.0.set_secure(enc_key, dec_key);
    }
}

/// Everything one faulted client run produces.
struct FaultRun {
    preds: Vec<f64>,
    stream: StreamReport,
    /// Summed guest-side wire accounting across all links.
    comm: NetSnapshot,
    /// Per-link frames fully crossed when the stream finished (before
    /// `SessionClose`) — the sizing input for frame-boundary sweeps.
    frames_at_stream_end: Vec<u64>,
    /// The fault wrappers, in link order, for kill-log assertions.
    faults: Vec<Arc<FaultyTransport>>,
}

/// One streamed serving session over fault-wrapped TCP links:
/// `plans[p]` arms host `p`'s wrapper (empty = pass-through).
fn run_client(
    world: &World,
    addrs: &[String],
    opts: PredictOptions,
    plans: Vec<Vec<FaultPlan>>,
) -> FaultRun {
    let suite = CipherSuite::new_plain(64); // inference frames carry no ciphertexts
    let mut faults = Vec::with_capacity(addrs.len());
    let mut links: Vec<Box<dyn GuestTransport>> = Vec::with_capacity(addrs.len());
    for (addr, plan) in addrs.iter().zip(plans) {
        let inner =
            TcpGuestTransport::connect(addr, suite.clone()).expect("connect to serving host");
        let fault = Arc::new(FaultyTransport::new(inner, plan));
        faults.push(fault.clone());
        links.push(Box::new(SharedFault(fault)));
    }
    let mut session = PredictSession::new(&world.guest_m, 41, opts);
    session.open(&links);
    let (preds, stream) = session.predict_stream(&world.vs.guest, &links);
    let frames_at_stream_end = faults.iter().map(|f| f.frames_total()).collect();
    session.close(&links);
    let comm = links
        .iter()
        .map(|l| l.snapshot())
        .fold(NetSnapshot::default(), |acc, s| acc.add(&s));
    FaultRun { preds, stream, comm, frames_at_stream_end, faults }
}

/// The acceptance sweep: a fixed 3-chunk stream, one run per interior
/// frame boundary — the op carrying frame `k + 1` dies (odd boundaries
/// also leak a torn prefix of the doomed frame first). Whatever the
/// boundary — any route send, any answer receive, pipelined or not —
/// the resumed stream must equal the centralized oracle bit for bit,
/// reconnect exactly once, and replay exactly the answers that were in
/// flight at the kill.
///
/// The whole sweep runs twice: once in plaintext and once with the v6
/// encrypted channel (`--secure require`). AEAD changes nothing the
/// sweep can observe — same frame count (sealing happens inside the
/// frame), same replay arithmetic (replayed answers are re-encrypted
/// with fresh nonces, not re-sent ciphertext), same plaintext-level
/// byte accounting — except `outcome.secure`.
#[test]
fn every_stream_frame_boundary_kill_resumes_bit_identically() {
    let mut rng = Xoshiro256::seed_from_u64(0x3C41_FB0B);
    let world = loop {
        let w = gen_world(&mut rng, 1);
        // any n ≥ 5 yields exactly 3 chunks under batch_rows = ⌈n/3⌉
        if w.vs.n() >= 5 {
            break w;
        }
    };
    let n = world.vs.n();
    let oracle = predict_centralized(&world.guest_m, &world.host_ms, &world.vs);

    for secure in [SecureMode::Off, SecureMode::Require] {
        let cfg = ServeConfig {
            delta_window: 64,
            basis_evict: BasisEvict::Lru,
            max_inflight: 2,
            resume_window: Duration::from_secs(30),
            secure,
            ..ServeConfig::default()
        };
        let opts = PredictOptions {
            batch_rows: (n + 2) / 3,
            max_inflight: 2,
            seed: 0xFA117,
            protocol: SERVE_PROTOCOL_VERSION,
            reconnect_retries: 5,
            secure,
            ..PredictOptions::default()
        };
        let sealed = secure != SecureMode::Off;

        // the no-fault counting run sizes the sweep and pins the baseline
        // invariants: parity, zero reconnects, symmetric byte accounting
        let (addrs, servers) = start_servers(&world, cfg);
        let base = run_client(&world, &addrs, opts, vec![Vec::new()]);
        assert_eq!(base.preds, oracle, "no-fault run must equal centralized");
        assert_eq!(base.stream.reconnects, 0);
        assert_eq!(base.stream.chunks_replayed, 0);
        let mut host_comm = NetSnapshot::default();
        for server in servers {
            let report = server.join().expect("server thread");
            assert_eq!(report.n_sessions, 1);
            assert_eq!(report.sessions[0].outcome.secure, sealed, "secure={secure:?}");
            host_comm = host_comm.add(&report.comm);
        }
        assert_eq!(base.comm, host_comm, "no-fault byte accounting must stay two-sided equal");
        let frames = base.frames_at_stream_end[0];
        assert_eq!(
            frames, 8,
            "a 3-chunk stream is 8 frames — hello, accept, 3 routes, 3 answers — \
             keyed or not (AEAD seals inside the frame, it adds none)"
        );

        // frames 1..=2 are the handshake; boundaries 2..frames put the kill
        // on every route send and every answer receive of all three chunks
        for k in 2..frames {
            let plan = FaultPlan {
                seed: k,
                kill_after_frames: k,
                partial_write_bytes: if k % 2 == 1 { 1 + (k as usize % 13) } else { 0 },
                delay: Duration::ZERO,
            };
            let (addrs, servers) = start_servers(&world, cfg);
            let run = run_client(&world, &addrs, opts, vec![vec![plan]]);
            assert_eq!(
                run.preds, oracle,
                "kill at frame boundary {k} (secure={secure:?}): \
                 the resumed stream must be bit-identical"
            );
            assert_eq!(run.faults[0].kills(), 1, "boundary {k}: the planned kill fired");
            let (routes, answers) = run.faults[0].kill_log()[0];
            assert_eq!(run.stream.reconnects, 1, "boundary {k}: exactly one reconnect");
            assert_eq!(
                run.stream.chunks_replayed,
                routes - answers,
                "boundary {k} (secure={secure:?}): replay count must equal the answers \
                 in flight at the kill ({routes} routes fully sent, {answers} answers \
                 received)"
            );
            for server in servers {
                let report = server.join().expect("server thread");
                assert_eq!(
                    report.n_sessions, 1,
                    "boundary {k}: a disconnect-and-resume session counts once"
                );
                assert_eq!(report.sessions_resumed, 1, "boundary {k}");
                assert_eq!(report.sessions_resume_expired, 0, "boundary {k}");
                assert_eq!(
                    report.sessions_idle_reaped, 0,
                    "boundary {k}: no phantom idle-reap for a parked-then-resumed session"
                );
                assert!(
                    report.sessions[0].outcome.clean_close,
                    "boundary {k}: the resumed session still ends in a clean SessionClose"
                );
                assert_eq!(
                    report.sessions[0].outcome.secure, sealed,
                    "boundary {k}: a keyed session re-keys on resume, it never drops \
                     to plaintext (and a plain one never gains a key)"
                );
            }
        }
    }
}

/// One randomized fault iteration: draw a world and a configuration,
/// prove the no-fault invariants, then re-run the identical schedule
/// with one seeded kill per link. v4 sessions must resume
/// bit-identically with exact counters; v2/v3 sessions must fail loudly
/// (naming the missing resumption capability) while the host finishes
/// its budget cleanly.
fn run_fault_iteration(seed: u64, it: usize) {
    let mut rng =
        Xoshiro256::seed_from_u64(seed ^ (it as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let protocol = match it % 5 {
        3 => SERVE_PROTOCOL_V3,
        4 => SERVE_PROTOCOL_V2,
        _ => SERVE_PROTOCOL_VERSION,
    };
    let resumable = protocol == SERVE_PROTOCOL_VERSION;
    // legacy-death iterations use one host: the guest panics mid-stream
    // and only a host whose session already did work can finish its
    // one-session budget
    let n_hosts = if resumable { 1 + it % 2 } else { 1 };
    let world = gen_world(&mut rng, n_hosts);
    let n = world.vs.n();
    let oracle = predict_centralized(&world.guest_m, &world.host_ms, &world.vs);

    let delta_window = if it % 3 == 0 { 0 } else { [4usize, 64, 1 << 12][rng.next_below(3)] };
    let basis_evict = if it % 4 < 2 { BasisEvict::Lru } else { BasisEvict::Freeze };
    let batch_rows = 1 + rng.next_below(n.min(7));
    let max_inflight = 1 + rng.next_below(4) as u32;
    let dummy_queries = [0usize, 0, 3][rng.next_below(3)];
    // the v6 secure axis: current-protocol iterations alternate
    // plaintext with `require` (the kill/resume machinery must re-key
    // transparently); legacy-protocol iterations alternate plaintext
    // with `prefer`, which a legacy hello silently resolves to
    // plaintext (`require` + legacy is rejected at session build)
    let secure = match (resumable, it % 2) {
        (_, 0) => SecureMode::Off,
        (true, _) => SecureMode::Require,
        (false, _) => SecureMode::Prefer,
    };
    let sealed = resumable && secure != SecureMode::Off;
    let tag = format!(
        "it {it} seed {seed:#x}: n={n} hosts={n_hosts} batch_rows={batch_rows} \
         inflight={max_inflight} delta={delta_window} evict={} v{protocol} \
         decoys={dummy_queries} secure={secure:?}",
        basis_evict.name()
    );

    let cfg = ServeConfig {
        delta_window,
        basis_evict,
        max_inflight,
        resume_window: Duration::from_secs(30),
        secure,
        ..ServeConfig::default()
    };
    let opts = PredictOptions {
        batch_rows,
        max_inflight: 1 + rng.next_below(4),
        dummy_queries,
        seed: rng.next_u64(),
        protocol,
        reconnect_retries: 6,
        secure,
        ..PredictOptions::default()
    };

    // ---- phase 1: the no-fault run. Parity, zero reconnects, and the
    // two-sided byte-accounting equality the wrapper must not disturb;
    // its per-link frame counts size phase 2's kill boundaries.
    let (addrs, servers) = start_servers(&world, cfg);
    let base = run_client(&world, &addrs, opts, vec![Vec::new(); n_hosts]);
    assert_eq!(base.preds, oracle, "{tag}: no-fault parity");
    assert_eq!(base.stream.reconnects, 0, "{tag}");
    assert_eq!(base.stream.chunks_replayed, 0, "{tag}");
    let mut host_comm = NetSnapshot::default();
    for server in servers {
        let report = server.join().expect("server thread");
        assert_eq!(report.n_sessions, 1, "{tag}: one serving session");
        assert_eq!(report.sessions[0].outcome.secure, sealed, "{tag}: secure negotiation");
        host_comm = host_comm.add(&report.comm);
    }
    assert_eq!(base.comm, host_comm, "{tag}: no-fault byte accounting symmetric");
    let frames = base.frames_at_stream_end.clone();

    // ---- phase 2: the same schedule with one seeded kill per link.
    // The faulted run's frame sequence is prefix-identical to phase 1's
    // (same seeds, deterministic engine), so a boundary below the
    // stream's frame count is guaranteed to land inside the stream.
    if resumable {
        let (addrs, servers) = start_servers(&world, cfg);
        let plans: Vec<Vec<FaultPlan>> = (0..n_hosts)
            .map(|p| {
                let mut plan = FaultPlan::from_seed(rng.next_u64(), frames[p] - 1);
                plan.kill_after_frames = plan.kill_after_frames.clamp(2, frames[p] - 1);
                vec![plan]
            })
            .collect();
        let run = run_client(&world, &addrs, opts, plans);
        assert_eq!(run.preds, oracle, "{tag}: resumed run must equal centralized");
        let mut kills = 0u64;
        let mut expected_replay = 0u64;
        for fault in &run.faults {
            kills += fault.kills();
            for (routes, answers) in fault.kill_log() {
                expected_replay += routes - answers;
            }
        }
        assert_eq!(kills, n_hosts as u64, "{tag}: every planned kill fired");
        assert_eq!(run.stream.reconnects, kills, "{tag}: one reconnect per kill");
        assert_eq!(run.stream.chunks_replayed, expected_replay, "{tag}: exact replay count");
        for (p, server) in servers.into_iter().enumerate() {
            let report = server.join().expect("server thread");
            assert_eq!(
                report.n_sessions, 1,
                "{tag}: host {p}: a disconnect-and-resume session counts once"
            );
            assert_eq!(report.sessions_resumed, 1, "{tag}: host {p}");
            assert_eq!(report.sessions_resume_expired, 0, "{tag}: host {p}");
            assert_eq!(report.sessions_idle_reaped, 0, "{tag}: host {p}");
            assert!(
                report.sessions[0].outcome.clean_close,
                "{tag}: host {p}: resumed session still closes cleanly"
            );
            assert_eq!(
                report.sessions[0].outcome.secure, sealed,
                "{tag}: host {p}: the channel keeps its secure mode across resume"
            );
        }
    } else {
        let (addrs, servers) = start_servers(&world, cfg);
        // ≥ 3 full frames (hello, accept, first route) before the kill:
        // the host must have answered at least one batch so its
        // one-session budget completes after the peer dies
        let mut plan = FaultPlan::from_seed(rng.next_u64(), frames[0] - 1);
        plan.kill_after_frames = plan.kill_after_frames.clamp(3, frames[0] - 1);
        let world_ref = &world;
        let addrs_ref = &addrs;
        let result = catch_unwind(AssertUnwindSafe(|| {
            run_client(world_ref, addrs_ref, opts, vec![vec![plan]])
        }));
        let payload = result.err().unwrap_or_else(|| {
            panic!("{tag}: a v{protocol} peer must fail loudly when its connection dies")
        });
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&'static str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(
            msg.contains("cannot resume"),
            "{tag}: the failure must name the missing resumption capability, got: {msg}"
        );
        for server in servers {
            let report = server.join().expect("server survives a dying legacy peer");
            assert_eq!(report.n_sessions, 1, "{tag}: the dead session completed the budget");
            assert_eq!(report.sessions_resumed, 0, "{tag}: nothing resumed");
            assert!(
                !report.sessions[0].outcome.clean_close,
                "{tag}: a legacy peer's death is not a clean close"
            );
        }
    }
}

/// The fixed-seed CI slice: deterministic, covers the discrete matrix
/// (1/2 hosts, delta on/off, lru/freeze, v2/v3/v4, kill point per
/// seeded plan).
#[test]
fn fault_matrix_fixed_seed() {
    for it in 0..10 {
        run_fault_iteration(0xFA_017_5EED, it);
    }
}

/// The full fault-soak range — slow; run explicitly with
/// `cargo test --release --test serve_fault -- --ignored`.
#[test]
#[ignore = "full randomized fault soak; run explicitly"]
fn fault_matrix_full_range() {
    for seed in [0xFA_017_5EEDu64, 0xBAD_F00D, 0xD15_C0] {
        for it in 0..24 {
            run_fault_iteration(seed, it);
        }
    }
}

// ---------------------------------------------------------------------
// Partial-I/O corpus for the reactor's non-blocking connection
// ---------------------------------------------------------------------

/// Encoded payloads of the serving-path frames (mirroring the
/// `tests/wire_codec.rs` sample corpus, minus ciphertext-bearing
/// training frames — the reactor serves inference only).
fn sample_payloads() -> Vec<Vec<u8>> {
    let suite = CipherSuite::new_plain(64);
    let ct_len = suite.ct_byte_len();
    let to_host: Vec<ToHost> = vec![
        ToHost::SessionHello { session_id: 1, protocol: SERVE_PROTOCOL_VERSION },
        ToHost::SessionHello { session_id: 77, protocol: SERVE_PROTOCOL_V2 },
        ToHost::SessionResume { session: 7, last_acked_chunk: 3 },
        ToHost::PredictRoute { session: 1, chunk: 0, queries: vec![(0, 1), (5, 2), (9, 0)] },
        ToHost::PredictRoute { session: 1, chunk: 7, queries: Vec::new() },
        ToHost::SessionClose { session_id: 1 },
        ToHost::KeepAlive,
    ];
    let to_guest: Vec<ToGuest> = vec![
        ToGuest::SessionAccept {
            session_id: 1,
            max_inflight: 8,
            delta_window: 64,
            protocol: SERVE_PROTOCOL_VERSION,
            basis_evict: BasisEvict::Lru,
        },
        ToGuest::ResumeAccept { next_chunk: 4, basis_epoch: 9 },
        ToGuest::RouteAnswers { session: 1, chunk: 0, n: 11, bits: vec![0b1010_1010, 0b101] },
        ToGuest::RouteAnswersDelta { session: 1, chunk: 2, n: 11, n_known: 3, bits: vec![0b0101_0101] },
        ToGuest::Ack,
    ];
    let mut payloads: Vec<Vec<u8>> =
        to_host.iter().map(|m| encode_to_host(&suite, ct_len, m)).collect();
    payloads.extend(to_guest.iter().map(|m| encode_to_guest(&suite, ct_len, m)));
    payloads
}

/// Poll `conn` until it reports something other than `Pending`
/// (loopback delivery of just-written bytes is asynchronous).
fn poll_settled(conn: &mut NbConn) -> Result<RecvPoll, WireError> {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match conn.poll_frame() {
            Ok(RecvPoll::Pending) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(1));
            }
            other => return other,
        }
    }
}

/// Short-read corpus: every sample frame, delivered split at every byte
/// position, must reassemble into exactly the original payload — one
/// frame, no residue, no error — however the kernel slices the reads.
#[test]
fn nbconn_reassembles_every_split_point_of_every_sample_frame() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let client = TcpStream::connect(addr).expect("connect");
    let (server, _) = listener.accept().expect("accept");
    let mut feeder = FaultyConn::new(client, FaultPlan::benign());
    let mut conn = NbConn::new(server).expect("nonblocking conn");

    for payload in sample_payloads() {
        let mut frame = (payload.len() as u64).to_le_bytes().to_vec();
        frame.extend_from_slice(&payload);
        for cut in 0..=frame.len() {
            feeder.dribble(&frame[..cut]).expect("dribble prefix");
            feeder.dribble(&frame[cut..]).expect("dribble remainder");
            match poll_settled(&mut conn).expect("split delivery must never corrupt a frame") {
                RecvPoll::Frame => {}
                other => panic!("split at {cut}: expected a frame, got {other:?}"),
            }
            assert_eq!(conn.frame_payload(), &payload[..], "split at {cut}");
            conn.consume_frame();
            assert_eq!(
                conn.poll_frame().expect("empty wire"),
                RecvPoll::Pending,
                "split at {cut} left residue behind"
            );
        }
    }
}

/// Torn-write corpus: a complete frame, then every possible torn prefix
/// of a second frame followed by a FIN. The receiver must surface the
/// whole first frame, then classify the tail exactly: empty prefix →
/// clean close; mid-frame prefix → `Truncated`; full frame → frame,
/// then clean close. Never a panic, never a phantom frame.
#[test]
fn nbconn_rejects_every_torn_write_prefix_cleanly() {
    for payload in sample_payloads() {
        let frame_len = 8 + payload.len();
        for cut in 0..=frame_len {
            let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
            let addr = listener.local_addr().unwrap();
            let client = TcpStream::connect(addr).expect("connect");
            let (server, _) = listener.accept().expect("accept");
            // frame 1 crosses whole; frame 2 is torn at `cut` and FIN'd
            let plan = FaultPlan {
                seed: cut as u64,
                kill_after_frames: 1,
                partial_write_bytes: cut,
                delay: Duration::ZERO,
            };
            let mut feeder = FaultyConn::new(client, plan);
            assert!(feeder.feed(&payload).expect("first frame crosses"));
            assert!(!feeder.feed(&payload).expect("second frame dies"), "cut {cut}");

            let mut conn = NbConn::new(server).expect("nonblocking conn");
            match poll_settled(&mut conn).expect("first frame assembles") {
                RecvPoll::Frame => {}
                other => panic!("cut {cut}: expected the whole first frame, got {other:?}"),
            }
            assert_eq!(conn.frame_payload(), &payload[..], "cut {cut}");
            conn.consume_frame();

            let tail = poll_settled(&mut conn);
            if cut == 0 {
                assert!(
                    matches!(tail, Ok(RecvPoll::Closed)),
                    "cut 0 is a FIN at the boundary — a clean close, got {tail:?}"
                );
            } else if cut < frame_len {
                assert!(
                    matches!(tail, Err(WireError::Truncated)),
                    "cut {cut}: a torn frame + FIN must report truncation, got {tail:?}"
                );
            } else {
                match tail.expect("whole second frame crossed before the FIN") {
                    RecvPoll::Frame => {}
                    other => panic!("cut {cut}: expected the second frame, got {other:?}"),
                }
                assert_eq!(conn.frame_payload(), &payload[..], "cut {cut}");
                conn.consume_frame();
                let end = poll_settled(&mut conn);
                assert!(
                    matches!(end, Ok(RecvPoll::Closed)),
                    "cut {cut}: after both frames the FIN is a clean close, got {end:?}"
                );
            }
        }
    }
}

/// Write-side corpus: every sample frame queued through the reactor's
/// write path drains byte-identically to the blocking framing —
/// header + payload, in order, nothing duplicated by the partial-flush
/// compaction.
#[test]
fn nbconn_flushes_queued_sample_frames_byte_identically() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let client = TcpStream::connect(addr).expect("connect");
    let (server, _) = listener.accept().expect("accept");
    let mut conn = NbConn::new(server).expect("nonblocking conn");

    let payloads = sample_payloads();
    let mut want = Vec::new();
    // interleave queueing and flushing so the wpos-compaction path runs
    for payload in &payloads {
        conn.queue_frame(payload);
        want.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        want.extend_from_slice(payload);
        let _ = conn.flush_pending().expect("flush");
    }
    let reader = std::thread::spawn(move || {
        let mut got = Vec::new();
        let mut client = client;
        client.read_to_end(&mut got).expect("read to FIN");
        got
    });
    while !conn.write_idle() {
        if conn.flush_pending().expect("flush") == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    conn.shutdown();
    let got = reader.join().expect("reader thread");
    assert_eq!(got, want, "queued frames must drain byte-identically");
}
