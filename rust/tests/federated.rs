//! End-to-end integration tests over the federated protocol: guest+host
//! threads, real ciphertext histograms, split finding, and the equivalence
//! properties the paper claims (lossless vs. centralized; optimization
//! toggles change cost, not models).

use sbp::config::{CipherKind, GossConfig, ModeKind, TrainConfig};
use sbp::coordinator::{train_centralized, train_federated};
use sbp::data::synthetic::SyntheticSpec;

fn fast_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::secureboost_plus();
    cfg.epochs = 6;
    cfg.max_depth = 3;
    cfg.cipher = CipherKind::Plain;
    cfg.key_bits = 1024;
    cfg.goss = None;
    cfg.sparse_optimization = false;
    cfg
}

#[test]
fn federated_matches_centralized_plain() {
    // With the mock cipher and no sampling, federated split finding sees
    // exactly the same statistics as centralized training → same quality.
    let spec = SyntheticSpec::give_credit(0.004);
    let vs = spec.generate_vertical(11, 1);
    let ds = vs.to_centralized();
    let cfg = fast_cfg();
    let fed = train_federated(&vs, &cfg).unwrap();
    let cen = train_centralized(&ds, &cfg).unwrap();
    assert!(
        (fed.train_metric - cen.train_metric).abs() < 0.02,
        "federated {} vs centralized {}",
        fed.train_metric,
        cen.train_metric
    );
    assert!(fed.train_metric > 0.75, "AUC {}", fed.train_metric);
    assert_eq!(fed.trees_built, cfg.epochs);
}

#[test]
fn federated_paillier_binary_learns() {
    let spec = SyntheticSpec::give_credit(0.0015);
    let vs = spec.generate_vertical(3, 1);
    let mut cfg = fast_cfg();
    cfg.cipher = CipherKind::Paillier;
    cfg.key_bits = 512; // small key keeps CI fast; algebra identical
    cfg.epochs = 4;
    let rep = train_federated(&vs, &cfg).unwrap();
    assert!(rep.train_metric > 0.7, "AUC {}", rep.train_metric);
    // encrypted traffic actually flowed
    assert!(rep.comm.total_bytes() > 10_000);
    assert!(rep.ops.encrypts > 0 && rep.ops.decrypts > 0 && rep.ops.adds > 0);
}

#[test]
fn paillier_matches_plain_cipher_model() {
    // HE must be *lossless*: same splits, same AUC as the mock cipher.
    let spec = SyntheticSpec::give_credit(0.001);
    let vs = spec.generate_vertical(5, 1);
    let mut plain = fast_cfg();
    plain.epochs = 3;
    let mut paillier = plain.clone();
    paillier.cipher = CipherKind::Paillier;
    paillier.key_bits = 512;
    let rp = train_federated(&vs, &plain).unwrap();
    let re = train_federated(&vs, &paillier).unwrap();
    assert!(
        (rp.train_metric - re.train_metric).abs() < 1e-6,
        "plain {} vs paillier {}",
        rp.train_metric,
        re.train_metric
    );
}

#[test]
fn affine_matches_plain_cipher_model() {
    let spec = SyntheticSpec::give_credit(0.001);
    let vs = spec.generate_vertical(7, 1);
    let mut plain = fast_cfg();
    plain.epochs = 3;
    let mut affine = plain.clone();
    affine.cipher = CipherKind::IterativeAffine;
    affine.key_bits = 1024;
    let rp = train_federated(&vs, &plain).unwrap();
    let ra = train_federated(&vs, &affine).unwrap();
    assert!(
        (rp.train_metric - ra.train_metric).abs() < 1e-6,
        "plain {} vs affine {}",
        rp.train_metric,
        ra.train_metric
    );
}

#[test]
fn baseline_and_optimized_same_model_different_cost() {
    // The cipher-optimization framework is *lossless*: SecureBoost and
    // SecureBoost+ (no GOSS) build the same model; the + variant uses
    // fewer HE ops and less traffic (paper §4.6).
    let spec = SyntheticSpec::give_credit(0.002);
    let vs = spec.generate_vertical(13, 1);

    let mut base = TrainConfig::secureboost_baseline();
    base.epochs = 3;
    base.max_depth = 3;
    base.cipher = CipherKind::Plain;
    let mut plus = base.clone();
    plus.gh_packing = true;
    plus.hist_subtraction = true;
    plus.cipher_compression = true;

    let rb = train_federated(&vs, &base).unwrap();
    let rp = train_federated(&vs, &plus).unwrap();
    assert!(
        (rb.train_metric - rp.train_metric).abs() < 1e-9,
        "baseline {} vs plus {}",
        rb.train_metric,
        rp.train_metric
    );
    assert!(
        rp.ops.adds < rb.ops.adds,
        "packing+subtraction must reduce HE additions: {} vs {}",
        rp.ops.adds,
        rb.ops.adds
    );
    assert!(
        rp.ops.decrypts < rb.ops.decrypts,
        "compression must reduce decryptions: {} vs {}",
        rp.ops.decrypts,
        rb.ops.decrypts
    );
    assert!(
        rp.comm.bytes_to_guest < rb.comm.bytes_to_guest,
        "compression must reduce host→guest traffic: {} vs {}",
        rp.comm.bytes_to_guest,
        rb.comm.bytes_to_guest
    );
}

#[test]
fn mix_and_layered_modes_run_and_learn() {
    let spec = SyntheticSpec::give_credit(0.002);
    let vs = spec.generate_vertical(17, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 6;
    cfg.max_depth = 5;

    let default = train_federated(&vs, &cfg).unwrap();

    let mut mix = cfg.clone();
    mix.mode = ModeKind::Mix { trees_per_party: 1 };
    let rmix = train_federated(&vs, &mix).unwrap();

    let mut layered = cfg.clone();
    layered.mode = ModeKind::Layered { guest_depth: 2, host_depth: 3 };
    let rlay = train_federated(&vs, &layered).unwrap();

    for (name, r) in [("mix", &rmix), ("layered", &rlay)] {
        assert!(r.train_metric > 0.70, "{name} AUC {}", r.train_metric);
        assert!(
            r.train_metric > default.train_metric - 0.08,
            "{name} {} vs default {}",
            r.train_metric,
            default.train_metric
        );
    }
    // both modes skip federation work → less traffic than default
    assert!(rmix.comm.total_bytes() < default.comm.total_bytes());
    assert!(rlay.comm.total_bytes() < default.comm.total_bytes());
}

#[test]
fn multiclass_ova_and_mo() {
    let spec = SyntheticSpec::sensorless(0.004);
    let vs = spec.generate_vertical(23, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 3;

    let ova = train_federated(&vs, &cfg).unwrap();
    assert_eq!(ova.trees_built, 3 * 11, "one tree per class per epoch");

    let mut mo = cfg.clone();
    mo.mode = ModeKind::MultiOutput;
    mo.cipher_compression = false; // MO disables compression (paper §7.3.2)
    let rmo = train_federated(&vs, &mo).unwrap();
    assert_eq!(rmo.trees_built, 3, "one MO tree per epoch");
    assert!(rmo.train_metric > 1.2 / 11.0, "accuracy {}", rmo.train_metric);
}

#[test]
fn mo_paillier_small() {
    let spec = SyntheticSpec::sensorless(0.0015);
    let vs = spec.generate_vertical(29, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 2;
    cfg.max_depth = 2;
    cfg.mode = ModeKind::MultiOutput;
    cfg.cipher = CipherKind::Paillier;
    cfg.key_bits = 512;
    cfg.cipher_compression = false;
    let rep = train_federated(&vs, &cfg).unwrap();
    assert_eq!(rep.trees_built, 2);
    assert!(rep.train_metric > 1.0 / 11.0);
}

#[test]
fn goss_federated() {
    let spec = SyntheticSpec::give_credit(0.002);
    let vs = spec.generate_vertical(31, 1);
    let mut cfg = fast_cfg();
    cfg.goss = Some(GossConfig::default());
    let rep = train_federated(&vs, &cfg).unwrap();
    assert!(rep.train_metric > 0.72, "AUC {}", rep.train_metric);
}

#[test]
fn two_hosts() {
    let spec = SyntheticSpec::higgs(0.0002);
    let vs = spec.generate_vertical(37, 2);
    assert_eq!(vs.hosts.len(), 2);
    let mut cfg = fast_cfg();
    cfg.n_hosts = 2;
    cfg.epochs = 3;
    let rep = train_federated(&vs, &cfg).unwrap();
    assert!(rep.train_metric > 0.6, "AUC {}", rep.train_metric);
}

#[test]
fn sparse_optimization_federated() {
    let spec = SyntheticSpec::covtype(0.0005);
    let vs = spec.generate_vertical(41, 1);
    let mut dense = fast_cfg();
    dense.epochs = 2;
    let mut sparse = dense.clone();
    sparse.sparse_optimization = true;
    let rd = train_federated(&vs, &dense).unwrap();
    let rs = train_federated(&vs, &sparse).unwrap();
    // models must match in quality; sparse path does fewer HE adds
    assert!(
        (rd.train_metric - rs.train_metric).abs() < 0.05,
        "dense {} vs sparse {}",
        rd.train_metric,
        rs.train_metric
    );
    assert!(rs.ops.adds < rd.ops.adds, "sparse {} vs dense {}", rs.ops.adds, rd.ops.adds);
}

#[test]
fn invalid_config_rejected() {
    let spec = SyntheticSpec::give_credit(0.001);
    let vs = spec.generate_vertical(1, 1);
    let mut cfg = fast_cfg();
    cfg.cipher_compression = true;
    cfg.gh_packing = false;
    assert!(train_federated(&vs, &cfg).is_err());
}

#[test]
fn tiny_extremes() {
    // degenerate sizes: few rows, depth deeper than data supports, 4 bins
    let mut spec = SyntheticSpec::give_credit(0.0005); // ~75 rows
    spec.d = 4;
    spec.guest_d = 2;
    let vs = spec.generate_vertical(3, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 2;
    cfg.max_depth = 6;
    cfg.max_bin = 4;
    let rep = train_federated(&vs, &cfg).unwrap();
    assert_eq!(rep.trees_built, 2);
    // trees cannot be deeper than the data allows, and must not panic
    for t in &rep.trees {
        assert!(t.max_depth() <= 6);
    }
}

#[test]
fn deterministic_given_seed() {
    let vs = SyntheticSpec::give_credit(0.001).generate_vertical(5, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 3;
    cfg.seed = 77;
    let a = train_federated(&vs, &cfg).unwrap();
    let b = train_federated(&vs, &cfg).unwrap();
    assert_eq!(a.train_metric, b.train_metric);
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.trees_built, b.trees_built);
}

#[test]
fn host_split_refs_are_opaque() {
    // The guest's tree must never contain host feature indices — only
    // (party, handle) pairs (paper: split-info shuffling).
    use sbp::tree::node::SplitRef;
    let vs = SyntheticSpec::susy(0.0001).generate_vertical(9, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 3;
    let rep = train_federated(&vs, &cfg).unwrap();
    let mut host_splits = 0;
    for t in &rep.trees {
        for n in &t.nodes {
            match &n.split {
                Some(SplitRef::Host { party, .. }) => {
                    assert_eq!(*party, 0);
                    host_splits += 1;
                }
                Some(SplitRef::Guest { feature, .. }) => {
                    assert!((*feature as usize) < vs.guest.d());
                }
                None => {}
            }
        }
    }
    // susy gives the host 14 of 18 features — hosts must win splits
    assert!(host_splits > 0, "host features must participate");
}

#[test]
fn depth_one_stumps() {
    let vs = SyntheticSpec::give_credit(0.001).generate_vertical(13, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 5;
    cfg.max_depth = 1;
    let rep = train_federated(&vs, &cfg).unwrap();
    for t in &rep.trees {
        assert!(t.max_depth() <= 1);
        assert!(t.n_leaves() <= 2);
    }
    assert!(rep.train_metric > 0.6);
}

#[test]
fn unbalanced_guest_host_feature_split() {
    // guest holds a single feature; host holds the rest
    let mut spec = SyntheticSpec::higgs(0.0001);
    spec.guest_d = 1;
    let vs = spec.generate_vertical(21, 1);
    assert_eq!(vs.guest.d(), 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 3;
    let rep = train_federated(&vs, &cfg).unwrap();
    assert!(rep.train_metric > 0.55, "AUC {}", rep.train_metric);
}

#[test]
fn layered_tree_structure_respected() {
    use sbp::tree::node::SplitRef;
    let vs = SyntheticSpec::higgs(0.0002).generate_vertical(23, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 2;
    cfg.max_depth = 5;
    cfg.mode = ModeKind::Layered { guest_depth: 2, host_depth: 3 };
    let rep = train_federated(&vs, &cfg).unwrap();
    for t in &rep.trees {
        for n in &t.nodes {
            match &n.split {
                Some(SplitRef::Host { .. }) => {
                    assert!(n.depth < 3, "host split at depth {} ≥ host_depth", n.depth)
                }
                Some(SplitRef::Guest { .. }) => {
                    assert!(n.depth >= 3, "guest split at depth {} < host_depth", n.depth)
                }
                None => {}
            }
        }
    }
}

#[test]
fn mix_tree_ownership_alternates() {
    use sbp::tree::node::SplitRef;
    let vs = SyntheticSpec::give_credit(0.002).generate_vertical(25, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 4; // trees: guest, host0, guest, host0
    cfg.mode = ModeKind::Mix { trees_per_party: 1 };
    let rep = train_federated(&vs, &cfg).unwrap();
    for (i, t) in rep.trees.iter().enumerate() {
        let expect_guest = i % 2 == 0;
        for n in &t.nodes {
            match &n.split {
                Some(SplitRef::Guest { .. }) => {
                    assert!(expect_guest, "tree {i} should be host-owned")
                }
                Some(SplitRef::Host { .. }) => {
                    assert!(!expect_guest, "tree {i} should be guest-owned")
                }
                None => {}
            }
        }
    }
}

/// Run one in-process host over loopback TCP and train against it.
fn train_over_tcp(vs: &sbp::data::dataset::VerticalSplit, cfg: &TrainConfig) -> sbp::coordinator::TrainReport {
    use sbp::config::TransportKind;
    use sbp::data::binning::bin_party;
    use sbp::federation::tcp::serve_host_once;
    use sbp::util::timer::PhaseTimer;
    use std::net::TcpListener;
    use std::sync::{Arc, Mutex};

    assert_eq!(vs.hosts.len(), 1, "helper serves a single host");
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let bm = bin_party(&vs.hosts[0], cfg.max_bin);
    let sb = sbp::data::sparse::maybe_sparse(&vs.hosts[0], &bm, cfg.sparse_optimization);
    let timer = Arc::new(Mutex::new(PhaseTimer::new()));
    let server = std::thread::spawn(move || {
        serve_host_once(&listener, 0, bm, sb, timer).expect("host serve failed");
    });

    let mut tcp_cfg = cfg.clone();
    tcp_cfg.transport = TransportKind::Tcp { hosts: vec![addr.to_string()] };
    let report = train_federated(vs, &tcp_cfg).expect("tcp training failed");
    server.join().expect("host thread panicked");
    report
}

#[test]
fn tcp_transport_parity_with_in_memory() {
    // The tentpole guarantee: a real byte-serialized socket transport
    // trains the *identical* model to the in-memory channel transport —
    // same trees bit for bit, same metric, same loss curve — and the two
    // transports account the same serialized wire bytes.
    let spec = SyntheticSpec::give_credit(0.002);
    let vs = spec.generate_vertical(19, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 3;
    cfg.seed = 1234;

    let mem = train_federated(&vs, &cfg).unwrap();
    let tcp = train_over_tcp(&vs, &cfg);

    assert_eq!(mem.trees, tcp.trees, "trees must be bit-identical across transports");
    assert_eq!(mem.tree_classes, tcp.tree_classes);
    assert_eq!(mem.train_metric, tcp.train_metric, "train metric must match exactly");
    assert_eq!(mem.loss_curve, tcp.loss_curve);
    assert_eq!(mem.host_tables, tcp.host_tables);
    // byte accounting is transport-independent: the in-memory links charge
    // the exact serialized frame sizes the TCP transport actually sent
    assert_eq!(mem.comm, tcp.comm, "per-kind wire-byte accounting must match");
    assert!(tcp.comm.total_bytes() > 10_000);
    assert!(tcp.comm.to_host_kind_bytes.iter().sum::<u64>() == tcp.comm.bytes_to_host);
    assert!(tcp.comm.to_guest_kind_bytes.iter().sum::<u64>() == tcp.comm.bytes_to_guest);
}

#[test]
fn tcp_transport_parity_paillier_ciphertexts() {
    // Same guarantee with real Paillier ciphertexts crossing the socket
    // (wire form is standard-residue, host rebuilds the Montgomery ctx).
    let spec = SyntheticSpec::give_credit(0.001);
    let vs = spec.generate_vertical(23, 1);
    let mut cfg = fast_cfg();
    cfg.cipher = CipherKind::Paillier;
    cfg.key_bits = 512;
    cfg.epochs = 2;

    let mem = train_federated(&vs, &cfg).unwrap();
    let tcp = train_over_tcp(&vs, &cfg);
    assert_eq!(mem.trees, tcp.trees);
    assert_eq!(mem.train_metric, tcp.train_metric);
    assert_eq!(mem.comm, tcp.comm);
    assert!(tcp.ops.encrypts > 0 && tcp.ops.decrypts > 0);
}

#[test]
fn tcp_transport_parity_compressed_and_affine() {
    // Compressed split statistics (CtPackage frames) under the iterative
    // affine cipher must also cross the wire losslessly.
    let spec = SyntheticSpec::give_credit(0.001);
    let vs = spec.generate_vertical(29, 1);
    let mut cfg = fast_cfg();
    cfg.cipher = CipherKind::IterativeAffine;
    cfg.key_bits = 1024;
    cfg.epochs = 2;

    let mem = train_federated(&vs, &cfg).unwrap();
    let tcp = train_over_tcp(&vs, &cfg);
    assert_eq!(mem.trees, tcp.trees);
    assert_eq!(mem.train_metric, tcp.train_metric);
    assert_eq!(mem.comm, tcp.comm);
}

#[test]
fn exported_model_reproduces_training_predictions() {
    // Train, export the per-party model shares, JSON round-trip them, and
    // verify raw-value inference reproduces the training-time quality
    // (binned routing `bin ≤ b` ⟺ raw routing `x ≤ edges[b]`).
    use sbp::config::json::Json;
    use sbp::metrics::auc;
    use sbp::tree::predict::{GuestModel, HostModel};

    let vs = SyntheticSpec::give_credit(0.002).generate_vertical(55, 1);
    let mut cfg = fast_cfg();
    cfg.epochs = 4;
    let rep = train_federated(&vs, &cfg).unwrap();
    let (guest_m, host_ms) = rep.model();

    // JSON round-trip each share
    let guest_m =
        GuestModel::from_json(&Json::parse(&guest_m.to_json().to_string_pretty()).unwrap())
            .unwrap();
    let host_ms: Vec<HostModel> = host_ms
        .iter()
        .map(|h| {
            HostModel::from_json(&Json::parse(&h.to_json().to_string_pretty()).unwrap()).unwrap()
        })
        .collect();

    let n = vs.n();
    let mut scores = Vec::with_capacity(n);
    for i in 0..n {
        let guest_row: Vec<f64> =
            (0..vs.guest.d()).map(|c| vs.guest.value(i, c)).collect();
        let host_row: Vec<f64> =
            (0..vs.hosts[0].d()).map(|c| vs.hosts[0].value(i, c)).collect();
        let p = guest_m.predict_row(&guest_row, &host_ms, &[&host_row]);
        scores.push(p[0]);
    }
    let inferred_auc = auc(&vs.y, &scores);
    assert!(
        (inferred_auc - rep.train_metric).abs() < 1e-9,
        "inference AUC {} vs training {}",
        inferred_auc,
        rep.train_metric
    );
}
