//! Wire-codec property tests: every `ToHost`/`ToGuest` variant must
//! encode→decode round-trip byte-identically — including ciphertext
//! payloads at Paillier and iterative-affine key sizes — the exact-length
//! functions must agree with the encoder, and truncated/garbage frames
//! must fail with errors, never panics or runaway allocations.

use sbp::crypto::bigint::BigUint;
use sbp::crypto::cipher::{CipherSuite, Ct};
use sbp::crypto::compress::{CompressPlan, CtPackage};
use sbp::crypto::packing::{GhPacker, MoPacker};
use sbp::federation::codec::{
    self, decode_to_guest, decode_to_host, encode_to_guest, encode_to_host, StatCodec,
    WireError, FRAME_HEADER_LEN,
};
use sbp::federation::message::{BusyReason, HistTask, NodeStats, ToGuest, ToHost};
use sbp::util::rng::ChaCha20Rng;
use std::sync::Arc;

/// The cipher suites a run can negotiate, at the key sizes the paper
/// benchmarks (scaled down for CI: 512-bit Paillier, 1024-bit affine).
fn suites() -> Vec<CipherSuite> {
    let mut rng = ChaCha20Rng::from_u64(0xC0DEC);
    vec![
        CipherSuite::new_paillier(512, &mut rng),
        CipherSuite::new_affine(1024, &mut rng),
        CipherSuite::new_plain(1023),
    ]
}

fn cts(suite: &CipherSuite, n: usize, rng: &mut ChaCha20Rng) -> Vec<Ct> {
    (0..n)
        .map(|i| suite.encrypt(&BigUint::from_u64(1000 + i as u64), rng))
        .collect()
}

fn sample_to_host_messages(suite: &CipherSuite, rng: &mut ChaCha20Rng) -> Vec<ToHost> {
    let packer = GhPacker::plan_logistic(10_000, 53);
    let g: Vec<f64> = vec![0.5, -0.25, 0.1, 0.9, -0.9, 0.0];
    let h: Vec<f64> = vec![0.25; 6];
    let mo = MoPacker::plan(&g, &h, 3, 100, 53, suite.plaintext_bits());
    vec![
        ToHost::Setup {
            suite_public: suite.public_side(),
            codec: StatCodec::Packed(packer.clone()),
            compress: Some(CompressPlan::derive(suite.plaintext_bits(), packer.b_gh)),
            n_bins: 32,
            hist_subtraction: true,
            sparse_optimization: false,
            seed: 0xDEADBEEF,
        },
        ToHost::Setup {
            suite_public: suite.public_side(),
            codec: StatCodec::Separate(packer.clone()),
            compress: None,
            n_bins: 8,
            hist_subtraction: false,
            sparse_optimization: true,
            seed: 1,
        },
        ToHost::Setup {
            suite_public: suite.public_side(),
            codec: StatCodec::Multi(mo),
            compress: None,
            n_bins: 64,
            hist_subtraction: true,
            sparse_optimization: true,
            seed: u64::MAX,
        },
        ToHost::StartTree {
            tree_id: 3,
            instances: Arc::new(vec![5, 9, 2, 77]),
            packed: Arc::new(cts(suite, 4, rng)),
            node_total: cts(suite, 1, rng),
        },
        ToHost::StartTree {
            tree_id: 4,
            instances: Arc::new(Vec::new()),
            packed: Arc::new(Vec::new()),
            node_total: Vec::new(),
        },
        ToHost::BuildLayer {
            tree_id: 5,
            tasks: vec![
                HistTask::Direct { node: 0 },
                HistTask::Subtract { node: 2, parent: 0, sibling: 1 },
            ],
        },
        ToHost::ApplySplit {
            tree_id: 6,
            node: 4,
            handle: 99,
            instances: Arc::new(vec![1, 2, 3]),
        },
        ToHost::SyncAssign {
            tree_id: 7,
            node: 1,
            left_child: 3,
            right_child: 4,
            left: Arc::new(vec![10, 20]),
        },
        ToHost::FinishTree { tree_id: 8 },
        ToHost::DumpSplitTable,
        ToHost::Shutdown,
        ToHost::PredictRoute { session: 0, chunk: 0, queries: vec![(0, 1), (5, 2), (9, 0)] },
        ToHost::PredictRoute { session: 3, chunk: 42, queries: vec![(1, 1)] },
        // a zero-row chunk tail is a valid frame, not a malformed one
        ToHost::PredictRoute { session: 0xDEAD, chunk: 7, queries: Vec::new() },
        ToHost::SessionHello {
            session_id: 1,
            protocol: sbp::federation::message::SERVE_PROTOCOL_VERSION,
        },
        ToHost::SessionHello {
            session_id: u32::MAX,
            protocol: sbp::federation::message::SERVE_PROTOCOL_VERSION,
        },
        // a legacy v2 hello is still a valid frame (negotiated down)
        ToHost::SessionHello {
            session_id: 77,
            protocol: sbp::federation::message::SERVE_PROTOCOL_V2,
        },
        // ... as is a v3 hello (negotiated down from v4)
        ToHost::SessionHello {
            session_id: 78,
            protocol: sbp::federation::message::SERVE_PROTOCOL_V3,
        },
        ToHost::SessionClose { session_id: 1 },
        ToHost::KeepAlive,
        // v4 resume handshake: a fresh stream (nothing acked yet) and a
        // deep-in-stream cursor
        ToHost::SessionResume { session: 7, last_acked_chunk: 0 },
        ToHost::SessionResume { session: u32::MAX, last_acked_chunk: u32::MAX },
        // v6 keyed handshakes: hello and resume carrying an X25519
        // public key (the codec passes any 32 bytes — degenerate keys
        // are the DH layer's problem, so the all-zero edge round-trips)
        ToHost::SessionHelloSecure {
            session_id: 6,
            protocol: sbp::federation::message::SERVE_PROTOCOL_VERSION,
            pubkey: [0x42; 32],
        },
        ToHost::SessionHelloSecure {
            session_id: u32::MAX,
            protocol: sbp::federation::message::SERVE_PROTOCOL_VERSION,
            pubkey: [0; 32],
        },
        ToHost::SessionResumeSecure { session: 7, last_acked_chunk: 0, pubkey: [1; 32] },
        ToHost::SessionResumeSecure {
            session: u32::MAX,
            last_acked_chunk: u32::MAX,
            pubkey: [0xFF; 32],
        },
    ]
}

fn sample_to_guest_messages(suite: &CipherSuite, rng: &mut ChaCha20Rng) -> Vec<ToGuest> {
    let raw_rows: Vec<(u32, u32, Vec<Ct>)> = vec![
        (0, 3, cts(suite, 2, rng)),
        (7, 1, cts(suite, 1, rng)),
    ];
    let pkg = CtPackage {
        ct: suite.encrypt(&BigUint::from_u64(42), rng),
        ids: vec![3, 1, 4],
        counts: vec![9, 2, 6],
    };
    vec![
        ToGuest::LayerStats {
            tree_id: 1,
            nodes: vec![
                (0, NodeStats::Raw(raw_rows)),
                (1, NodeStats::Compressed(vec![pkg])),
                (2, NodeStats::Raw(Vec::new())),
            ],
        },
        ToGuest::LeftInstances { tree_id: 2, node: 5, left: vec![4, 8, 15, 16, 23, 42] },
        ToGuest::LeftInstances { tree_id: 2, node: 6, left: Vec::new() },
        ToGuest::SplitTable {
            entries: vec![(0, 7, 1.5), (1, 0, -3.25), (2, 255, f64::MAX)],
        },
        ToGuest::Ack,
        ToGuest::RouteAnswers {
            session: 0,
            chunk: 0,
            n: 11,
            bits: vec![0b1010_1010, 0b0000_0101],
        },
        // zero-row answer (empty chunk tail) round-trips
        ToGuest::RouteAnswers { session: 9, chunk: 13, n: 0, bits: Vec::new() },
        // the bare v2 accept (12 bytes on the wire, decodes as freeze)
        ToGuest::SessionAccept {
            session_id: 1,
            max_inflight: 1,
            delta_window: 0,
            protocol: sbp::federation::message::SERVE_PROTOCOL_V2,
            basis_evict: sbp::federation::message::BasisEvict::Freeze,
        },
        // v3 extended accepts: both eviction policies
        ToGuest::SessionAccept {
            session_id: u32::MAX,
            max_inflight: 64,
            delta_window: 1 << 16,
            protocol: sbp::federation::message::SERVE_PROTOCOL_VERSION,
            basis_evict: sbp::federation::message::BasisEvict::Lru,
        },
        ToGuest::SessionAccept {
            session_id: 9,
            max_inflight: 8,
            delta_window: 512,
            protocol: sbp::federation::message::SERVE_PROTOCOL_VERSION,
            basis_evict: sbp::federation::message::BasisEvict::Freeze,
        },
        // a v3-negotiated accept keeps the extended 17-byte shape
        ToGuest::SessionAccept {
            session_id: 10,
            max_inflight: 4,
            delta_window: 256,
            protocol: sbp::federation::message::SERVE_PROTOCOL_V3,
            basis_evict: sbp::federation::message::BasisEvict::Lru,
        },
        // v4 resume grant: stream start and a deep cursor with a wrapped
        // basis epoch
        ToGuest::ResumeAccept { next_chunk: 1, basis_epoch: 0 },
        ToGuest::ResumeAccept { next_chunk: u32::MAX, basis_epoch: u32::MAX },
        // v5 admission answers: every shed reason, extreme retry advice
        ToGuest::Busy { retry_after_ms: 50, reason: BusyReason::Shed },
        ToGuest::Busy { retry_after_ms: 0, reason: BusyReason::QueueExpired },
        ToGuest::Busy { retry_after_ms: u32::MAX, reason: BusyReason::Draining },
        // delta answers: partially and fully elided, and the empty batch
        ToGuest::RouteAnswersDelta {
            session: 5,
            chunk: 2,
            n: 11,
            n_known: 3,
            bits: vec![0b0101_0101],
        },
        ToGuest::RouteAnswersDelta { session: 5, chunk: 3, n: 9, n_known: 9, bits: Vec::new() },
        ToGuest::RouteAnswersDelta { session: 5, chunk: 4, n: 0, n_known: 0, bits: Vec::new() },
        // v6 keyed accepts: the host's half of the handshake, both
        // eviction policies, extreme field values
        ToGuest::SessionAcceptSecure {
            session_id: 11,
            max_inflight: 8,
            delta_window: 512,
            protocol: sbp::federation::message::SERVE_PROTOCOL_VERSION,
            basis_evict: sbp::federation::message::BasisEvict::Lru,
            pubkey: [0x7A; 32],
        },
        ToGuest::SessionAcceptSecure {
            session_id: u32::MAX,
            max_inflight: 1,
            delta_window: 0,
            protocol: sbp::federation::message::SERVE_PROTOCOL_VERSION,
            basis_evict: sbp::federation::message::BasisEvict::Freeze,
            pubkey: [0; 32],
        },
        ToGuest::ResumeAcceptSecure { next_chunk: 1, basis_epoch: 0, pubkey: [3; 32] },
        ToGuest::ResumeAcceptSecure {
            next_chunk: u32::MAX,
            basis_epoch: u32::MAX,
            pubkey: [0xFF; 32],
        },
    ]
}

/// Byte-identical double round-trip: encode → decode → encode.
#[test]
fn to_host_roundtrips_all_variants_all_suites() {
    for suite in suites() {
        let mut rng = ChaCha20Rng::from_u64(7);
        let ct_len = suite.ct_byte_len();
        let setup_state = (suite.public_side(), ct_len);
        for msg in sample_to_host_messages(&suite, &mut rng) {
            let bytes = encode_to_host(&suite, ct_len, &msg);
            assert_eq!(
                bytes.len() + FRAME_HEADER_LEN,
                codec::to_host_wire_len(&msg, ct_len),
                "wire length mismatch for {:?} under {}",
                msg.kind(),
                suite.kind_name()
            );
            let decoded = decode_to_host(Some((&setup_state.0, setup_state.1)), &bytes)
                .unwrap_or_else(|e| panic!("{} decode failed: {e}", suite.kind_name()));
            assert_eq!(decoded.kind(), msg.kind());
            let re = encode_to_host(&suite, ct_len, &decoded);
            assert_eq!(re, bytes, "re-encoding differs for {:?}", msg.kind());
        }
    }
}

#[test]
fn to_guest_roundtrips_all_variants_all_suites() {
    for suite in suites() {
        let mut rng = ChaCha20Rng::from_u64(9);
        let ct_len = suite.ct_byte_len();
        for msg in sample_to_guest_messages(&suite, &mut rng) {
            let bytes = encode_to_guest(&suite, ct_len, &msg);
            assert_eq!(
                bytes.len() + FRAME_HEADER_LEN,
                codec::to_guest_wire_len(&msg, ct_len),
                "wire length mismatch for {:?}",
                msg.kind()
            );
            let decoded = decode_to_guest(&suite, ct_len, &bytes).expect("decode");
            assert_eq!(decoded, msg, "decoded message differs for {:?}", msg.kind());
            let re = encode_to_guest(&suite, ct_len, &decoded);
            assert_eq!(re, bytes);
        }
    }
}

/// Decoded Setup must preserve everything a host needs: cipher identity,
/// plaintext capacity, ciphertext width, codec layout, compression plan —
/// and ciphertexts encrypted by the guest must decrypt identically after
/// crossing the wire through the *reconstructed* suite.
#[test]
fn setup_reconstructs_operational_suite() {
    for suite in suites() {
        let mut rng = ChaCha20Rng::from_u64(11);
        let ct_len = suite.ct_byte_len();
        let packer = GhPacker::plan_logistic(1_000_000, 53);
        let msg = ToHost::Setup {
            suite_public: suite.public_side(),
            codec: StatCodec::Packed(packer.clone()),
            compress: Some(CompressPlan::derive(suite.plaintext_bits(), packer.b_gh)),
            n_bins: 32,
            hist_subtraction: true,
            sparse_optimization: true,
            seed: 99,
        };
        let bytes = encode_to_host(&suite, ct_len, &msg);
        let ToHost::Setup { suite_public: host_suite, codec, compress, n_bins, seed, .. } =
            decode_to_host(None, &bytes).expect("setup decode")
        else {
            panic!("expected Setup");
        };
        assert_eq!(host_suite.kind_name(), suite.kind_name());
        assert_eq!(host_suite.plaintext_bits(), suite.plaintext_bits());
        assert_eq!(host_suite.ct_byte_len(), ct_len);
        assert!(!host_suite.has_secret() || matches!(host_suite, CipherSuite::Plain { .. }));
        let StatCodec::Packed(p) = codec else { panic!("expected packed codec") };
        assert_eq!((p.b_g, p.b_h, p.b_gh), (packer.b_g, packer.b_h, packer.b_gh));
        assert_eq!(p.g_off, packer.g_off);
        assert_eq!(compress, Some(CompressPlan::derive(suite.plaintext_bits(), packer.b_gh)));
        assert_eq!(n_bins, 32);
        assert_eq!(seed, 99);

        // guest-encrypted ciphertexts survive: encode with the guest suite,
        // homomorphically add through the host's reconstructed suite,
        // decrypt with the guest's secret key
        let a = suite.encrypt(&BigUint::from_u64(30), &mut rng);
        let b = suite.encrypt(&BigUint::from_u64(12), &mut rng);
        let start = ToHost::StartTree {
            tree_id: 0,
            instances: Arc::new(vec![0, 1]),
            packed: Arc::new(vec![a, b]),
            node_total: vec![],
        };
        let wire = encode_to_host(&suite, ct_len, &start);
        let ToHost::StartTree { packed, .. } =
            decode_to_host(Some((&host_suite, ct_len)), &wire).expect("start decode")
        else {
            panic!("expected StartTree");
        };
        let sum = host_suite.add(&packed[0], &packed[1]);
        assert_eq!(suite.decrypt(&sum), BigUint::from_u64(42), "{}", suite.kind_name());
    }
}

/// Every strict prefix of a valid payload must decode to an error —
/// never a panic, never a bogus success.
#[test]
fn truncated_payloads_error_cleanly() {
    let suite = CipherSuite::new_plain(256);
    let ct_len = suite.ct_byte_len();
    let mut rng = ChaCha20Rng::from_u64(13);
    let setup_state = (suite.public_side(), ct_len);
    for msg in sample_to_host_messages(&suite, &mut rng) {
        let bytes = encode_to_host(&suite, ct_len, &msg);
        for cut in 0..bytes.len() {
            assert!(
                decode_to_host(Some((&setup_state.0, setup_state.1)), &bytes[..cut]).is_err(),
                "prefix of len {cut}/{} decoded successfully for {:?}",
                bytes.len(),
                msg.kind()
            );
        }
    }
    for msg in sample_to_guest_messages(&suite, &mut rng) {
        let bytes = encode_to_guest(&suite, ct_len, &msg);
        for cut in 0..bytes.len() {
            let decoded = decode_to_guest(&suite, ct_len, &bytes[..cut]);
            // one deliberate exception: a v3 extended SessionAccept cut
            // back to its first 13 bytes IS the valid v2 accept — the
            // dual-shape encoding that keeps legacy peers decoding.
            // Every other prefix must error.
            if let (ToGuest::SessionAccept { .. }, Ok(ToGuest::SessionAccept { protocol, .. })) =
                (&msg, &decoded)
            {
                assert_eq!(
                    *protocol,
                    sbp::federation::message::SERVE_PROTOCOL_V2,
                    "a truncated accept may only decode as the v2 form"
                );
                continue;
            }
            assert!(
                decoded.is_err(),
                "prefix of len {cut} decoded for {:?}",
                msg.kind()
            );
        }
    }
}

/// Garbage payloads (random bytes) must error out, and length fields that
/// point past the frame must be rejected before allocation.
#[test]
fn garbage_payloads_error_cleanly() {
    let suite = CipherSuite::new_plain(256);
    let ct_len = suite.ct_byte_len();
    let mut rng = ChaCha20Rng::from_u64(17);
    for len in [0usize, 1, 7, 64, 1000] {
        for _ in 0..50 {
            let buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            // either a clean error or a successful decode of a small message;
            // both are fine — what is not fine is a panic or huge allocation
            let _ = decode_to_host(Some((&suite, ct_len)), &buf);
            let _ = decode_to_guest(&suite, ct_len, &buf);
        }
    }
    // unknown tags
    assert!(matches!(
        decode_to_host(Some((&suite, ct_len)), &[200]),
        Err(WireError::BadTag { .. })
    ));
    assert!(matches!(
        decode_to_guest(&suite, ct_len, &[99]),
        Err(WireError::BadTag { .. })
    ));
    // an ApplySplit claiming 2^32-1 instances in a 20-byte frame
    let mut evil = vec![3u8];
    evil.extend_from_slice(&1u32.to_le_bytes());
    evil.extend_from_slice(&2u32.to_le_bytes());
    evil.extend_from_slice(&3u32.to_le_bytes());
    evil.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(
        decode_to_host(Some((&suite, ct_len)), &evil),
        Err(WireError::Malformed(_))
    ));
    // ciphertext-bearing message before Setup
    let start = ToHost::StartTree {
        tree_id: 0,
        instances: Arc::new(vec![1]),
        packed: Arc::new(vec![suite.encrypt(&BigUint::from_u64(1), &mut rng)]),
        node_total: vec![],
    };
    let bytes = encode_to_host(&suite, ct_len, &start);
    assert!(matches!(decode_to_host(None, &bytes), Err(WireError::Malformed(_))));
}

/// A malformed `SessionHello` — reserved session id 0, an unknown
/// protocol version, or a truncated handshake frame — must be rejected
/// by the codec with an error, never accepted or panicked: a serving
/// host that half-understands a handshake would answer a session it
/// cannot attribute.
#[test]
fn malformed_session_hello_rejected() {
    use sbp::federation::message::SERVE_PROTOCOL_VERSION;
    let suite = CipherSuite::new_plain(256);
    let ct_len = suite.ct_byte_len();

    // hand-build hello payloads: tag 9, session id, protocol (u32 LE each)
    let hello = |session_id: u32, protocol: u32| {
        let mut p = vec![9u8];
        p.extend_from_slice(&session_id.to_le_bytes());
        p.extend_from_slice(&protocol.to_le_bytes());
        p
    };
    // the valid shapes decode: current and the negotiable legacy v2
    let ok = decode_to_host(None, &hello(7, SERVE_PROTOCOL_VERSION)).expect("valid hello");
    assert!(matches!(ok, ToHost::SessionHello { session_id: 7, .. }));
    let ok = decode_to_host(None, &hello(8, sbp::federation::message::SERVE_PROTOCOL_V2))
        .expect("v2 hello still decodes (negotiated down)");
    assert!(matches!(
        ok,
        ToHost::SessionHello { session_id: 8, protocol: sbp::federation::message::SERVE_PROTOCOL_V2 }
    ));
    let ok = decode_to_host(None, &hello(9, sbp::federation::message::SERVE_PROTOCOL_V3))
        .expect("v3 hello still decodes (negotiated down)");
    assert!(matches!(
        ok,
        ToHost::SessionHello { session_id: 9, protocol: sbp::federation::message::SERVE_PROTOCOL_V3 }
    ));
    let ok = decode_to_host(None, &hello(10, sbp::federation::message::SERVE_PROTOCOL_V4))
        .expect("v4 hello still decodes (negotiated down)");
    assert!(matches!(
        ok,
        ToHost::SessionHello { session_id: 10, protocol: sbp::federation::message::SERVE_PROTOCOL_V4 }
    ));
    // reserved session id 0
    assert!(matches!(
        decode_to_host(None, &hello(0, SERVE_PROTOCOL_VERSION)),
        Err(WireError::Malformed(_))
    ));
    // protocol versions this build does not speak
    for bad in [0u32, 1, SERVE_PROTOCOL_VERSION + 1, u32::MAX] {
        assert!(
            matches!(decode_to_host(None, &hello(5, bad)), Err(WireError::Malformed(_))),
            "protocol {bad} must be rejected"
        );
    }
    // truncated handshake frames
    let full = encode_to_host(
        &suite,
        ct_len,
        &ToHost::SessionHello { session_id: 3, protocol: SERVE_PROTOCOL_VERSION },
    );
    for cut in 0..full.len() {
        assert!(decode_to_host(None, &full[..cut]).is_err(), "prefix {cut} accepted");
    }
    // trailing garbage after a complete hello
    let mut long = full.clone();
    long.push(0);
    assert!(matches!(decode_to_host(None, &long), Err(WireError::Malformed(_))));
}

/// A malformed `SessionResume` — reserved session id 0, a truncated
/// cursor, or trailing bytes — must be rejected by the codec: a host
/// that grants a resume it cannot attribute would replay another
/// session's answers.
#[test]
fn malformed_session_resume_rejected() {
    let suite = CipherSuite::new_plain(256);
    let ct_len = suite.ct_byte_len();

    // hand-build resume payloads: tag 12, session, last_acked_chunk
    let resume = |session: u32, last_acked: u32| {
        let mut p = vec![12u8];
        p.extend_from_slice(&session.to_le_bytes());
        p.extend_from_slice(&last_acked.to_le_bytes());
        p
    };
    // the valid shape decodes, including a zero cursor (nothing acked yet)
    let ok = decode_to_host(None, &resume(7, 0)).expect("valid resume");
    assert!(matches!(ok, ToHost::SessionResume { session: 7, last_acked_chunk: 0 }));
    let ok = decode_to_host(None, &resume(u32::MAX, 41)).expect("valid resume");
    assert!(matches!(
        ok,
        ToHost::SessionResume { session: u32::MAX, last_acked_chunk: 41 }
    ));
    // reserved session id 0: the sessionless id has no parked state to find
    assert!(matches!(
        decode_to_host(None, &resume(0, 3)),
        Err(WireError::Malformed(_))
    ));
    // truncated resume frames
    let full = encode_to_host(
        &suite,
        ct_len,
        &ToHost::SessionResume { session: 3, last_acked_chunk: 9 },
    );
    for cut in 0..full.len() {
        assert!(decode_to_host(None, &full[..cut]).is_err(), "prefix {cut} accepted");
    }
    // trailing garbage after a complete resume
    let mut long = full.clone();
    long.push(0);
    assert!(matches!(decode_to_host(None, &long), Err(WireError::Malformed(_))));
}

/// A malformed v5 `Busy` frame — an unknown shed-reason tag, a truncated
/// retry hint, or trailing bytes — must be rejected: a guest that acted
/// on a mis-framed Busy could spin on garbage retry advice or misreport
/// why it was shed.
#[test]
fn malformed_busy_rejected() {
    let suite = CipherSuite::new_plain(256);
    let ct_len = suite.ct_byte_len();

    // hand-build busy payloads: tag 8, retry_after_ms (u32 LE), reason tag
    let busy = |retry_after_ms: u32, reason: u8| {
        let mut p = vec![8u8];
        p.extend_from_slice(&retry_after_ms.to_le_bytes());
        p.push(reason);
        p
    };
    // every defined reason decodes
    for (tag, reason) in
        [(0u8, BusyReason::Shed), (1, BusyReason::QueueExpired), (2, BusyReason::Draining)]
    {
        let got = decode_to_guest(&suite, ct_len, &busy(75, tag)).expect("valid busy");
        assert_eq!(got, ToGuest::Busy { retry_after_ms: 75, reason });
    }
    // reason tags this build does not define
    for bad in [3u8, 7, 255] {
        assert!(
            matches!(
                decode_to_guest(&suite, ct_len, &busy(75, bad)),
                Err(WireError::BadTag { tag, .. }) if tag == bad
            ),
            "busy reason {bad} must be rejected"
        );
    }
    // truncated busy frames
    let full = encode_to_guest(
        &suite,
        ct_len,
        &ToGuest::Busy { retry_after_ms: 50, reason: BusyReason::Shed },
    );
    for cut in 0..full.len() {
        assert!(decode_to_guest(&suite, ct_len, &full[..cut]).is_err(), "prefix {cut} accepted");
    }
    // trailing garbage after a complete busy
    let mut long = full.clone();
    long.push(0);
    assert!(matches!(decode_to_guest(&suite, ct_len, &long), Err(WireError::Malformed(_))));
}

/// Malformed v6 keyed-handshake frames — a secure hello or resume with
/// the reserved session id 0, a keyed hello claiming a pre-v6 protocol
/// (a peer that could not speak the sealed framing the accept would
/// switch on), a keyed accept claiming a pre-v6 protocol, a truncated
/// public key, or trailing bytes — must be rejected by the codec with
/// an error, never accepted or panicked.
#[test]
fn malformed_secure_handshake_rejected() {
    use sbp::federation::message::{SERVE_PROTOCOL_V2, SERVE_PROTOCOL_V5, SERVE_PROTOCOL_VERSION};
    let suite = CipherSuite::new_plain(256);
    let ct_len = suite.ct_byte_len();

    // hand-build keyed hellos: tag 13, session id, protocol, 32B key
    let hello = |session_id: u32, protocol: u32| {
        let mut p = vec![13u8];
        p.extend_from_slice(&session_id.to_le_bytes());
        p.extend_from_slice(&protocol.to_le_bytes());
        p.extend_from_slice(&[0x5Au8; 32]);
        p
    };
    let ok = decode_to_host(None, &hello(7, SERVE_PROTOCOL_VERSION)).expect("valid keyed hello");
    assert!(matches!(ok, ToHost::SessionHelloSecure { session_id: 7, .. }));
    // reserved session id 0
    assert!(matches!(
        decode_to_host(None, &hello(0, SERVE_PROTOCOL_VERSION)),
        Err(WireError::Malformed(_))
    ));
    // a keyed hello never negotiates down: pre-v6 versions are
    // malformed, not legacy (unlike the plaintext hello's v2..v5)
    for bad in [0u32, 1, SERVE_PROTOCOL_V2, SERVE_PROTOCOL_V5, SERVE_PROTOCOL_VERSION + 1] {
        assert!(
            matches!(decode_to_host(None, &hello(5, bad)), Err(WireError::Malformed(_))),
            "keyed hello protocol {bad} must be rejected"
        );
    }
    // truncated key material and trailing garbage
    let full = hello(3, SERVE_PROTOCOL_VERSION);
    for cut in 0..full.len() {
        assert!(decode_to_host(None, &full[..cut]).is_err(), "hello prefix {cut} accepted");
    }
    let mut long = full.clone();
    long.push(0);
    assert!(matches!(decode_to_host(None, &long), Err(WireError::Malformed(_))));

    // keyed resume: tag 14, session, cursor, 32B key
    let resume = |session: u32| {
        let mut p = vec![14u8];
        p.extend_from_slice(&session.to_le_bytes());
        p.extend_from_slice(&9u32.to_le_bytes());
        p.extend_from_slice(&[0x5Au8; 32]);
        p
    };
    let ok = decode_to_host(None, &resume(7)).expect("valid keyed resume");
    assert!(matches!(ok, ToHost::SessionResumeSecure { session: 7, last_acked_chunk: 9, .. }));
    assert!(matches!(decode_to_host(None, &resume(0)), Err(WireError::Malformed(_))));
    let full = resume(3);
    for cut in 0..full.len() {
        assert!(decode_to_host(None, &full[..cut]).is_err(), "resume prefix {cut} accepted");
    }

    // keyed accept: tag 9, session, window, delta, protocol, evict, key
    let accept = |protocol: u32, evict: u8| {
        let mut p = vec![9u8];
        p.extend_from_slice(&3u32.to_le_bytes());
        p.extend_from_slice(&8u32.to_le_bytes());
        p.extend_from_slice(&64u32.to_le_bytes());
        p.extend_from_slice(&protocol.to_le_bytes());
        p.push(evict);
        p.extend_from_slice(&[0x5Au8; 32]);
        p
    };
    let ok = decode_to_guest(&suite, ct_len, &accept(SERVE_PROTOCOL_VERSION, 1))
        .expect("valid keyed accept");
    assert!(matches!(ok, ToGuest::SessionAcceptSecure { session_id: 3, .. }));
    // a keyed accept claiming a pre-v6 protocol is a liar
    for bad in [0u32, SERVE_PROTOCOL_V2, SERVE_PROTOCOL_V5, SERVE_PROTOCOL_VERSION + 1] {
        assert!(
            matches!(
                decode_to_guest(&suite, ct_len, &accept(bad, 1)),
                Err(WireError::Malformed(_))
            ),
            "keyed accept protocol {bad} must be rejected"
        );
    }
    // unknown eviction tag
    assert!(matches!(
        decode_to_guest(&suite, ct_len, &accept(SERVE_PROTOCOL_VERSION, 2)),
        Err(WireError::BadTag { .. })
    ));
    // truncations: unlike the dual-shape plaintext accept, every strict
    // prefix of a keyed accept is an error — there is no 13-byte legacy
    // form hiding inside it
    let full = accept(SERVE_PROTOCOL_VERSION, 0);
    for cut in 0..full.len() {
        assert!(
            decode_to_guest(&suite, ct_len, &full[..cut]).is_err(),
            "keyed accept prefix {cut} accepted"
        );
    }

    // keyed resume grant: tag 10, next_chunk, basis_epoch, key
    let grant = {
        let mut p = vec![10u8];
        p.extend_from_slice(&5u32.to_le_bytes());
        p.extend_from_slice(&2u32.to_le_bytes());
        p.extend_from_slice(&[0x5Au8; 32]);
        p
    };
    let ok = decode_to_guest(&suite, ct_len, &grant).expect("valid keyed grant");
    assert!(matches!(ok, ToGuest::ResumeAcceptSecure { next_chunk: 5, basis_epoch: 2, .. }));
    for cut in 0..grant.len() {
        assert!(
            decode_to_guest(&suite, ct_len, &grant[..cut]).is_err(),
            "keyed grant prefix {cut} accepted"
        );
    }
    let mut long = grant.clone();
    long.push(0);
    assert!(matches!(decode_to_guest(&suite, ct_len, &long), Err(WireError::Malformed(_))));
}

/// Trailing bytes after a complete message are a framing error.
#[test]
fn trailing_bytes_rejected() {
    let suite = CipherSuite::new_plain(256);
    let ct_len = suite.ct_byte_len();
    let mut bytes = encode_to_host(&suite, ct_len, &ToHost::FinishTree { tree_id: 1 });
    bytes.push(0);
    assert!(matches!(
        decode_to_host(Some((&suite, ct_len)), &bytes),
        Err(WireError::Malformed(_))
    ));
}

/// Frame reader: truncated header, truncated body, oversize declaration.
#[test]
fn frame_reader_error_cases() {
    use std::io::Cursor;
    // truncated header
    let mut cur = Cursor::new(vec![1u8, 2, 3]);
    assert!(matches!(codec::read_frame(&mut cur), Err(WireError::Truncated)));
    // header promises more body than exists
    let mut buf = 100u64.to_le_bytes().to_vec();
    buf.extend_from_slice(&[7; 10]);
    let mut cur = Cursor::new(buf);
    assert!(matches!(codec::read_frame(&mut cur), Err(WireError::Truncated)));
    // oversize length field fails fast
    let mut cur = Cursor::new((codec::MAX_FRAME_LEN + 1).to_le_bytes().to_vec());
    assert!(matches!(codec::read_frame(&mut cur), Err(WireError::FrameTooLarge(_))));
    // clean EOF at a frame boundary is not an error
    let mut cur = Cursor::new(Vec::<u8>::new());
    assert!(codec::read_frame(&mut cur).unwrap().is_none());
}

/// The v3 `SessionAccept` extension: both wire shapes round-trip, a
/// truncated extension and a bad eviction tag error cleanly, and an
/// extension claiming a non-v3 protocol is malformed (the bare 12-byte
/// form IS the v2 encoding — an extended frame saying "v2" is a liar).
#[test]
fn session_accept_v3_extension_validates() {
    use sbp::federation::message::{BasisEvict, SERVE_PROTOCOL_V2, SERVE_PROTOCOL_VERSION};
    let suite = CipherSuite::new_plain(256);
    let ct_len = suite.ct_byte_len();

    let accept = |ext: Option<(u32, u8)>| {
        let mut p = vec![5u8];
        p.extend_from_slice(&3u32.to_le_bytes()); // session id
        p.extend_from_slice(&8u32.to_le_bytes()); // max_inflight
        p.extend_from_slice(&64u32.to_le_bytes()); // delta_window
        if let Some((proto, tag)) = ext {
            p.extend_from_slice(&proto.to_le_bytes());
            p.push(tag);
        }
        p
    };

    // bare 12-byte form → v2 freeze
    let ToGuest::SessionAccept { protocol, basis_evict, .. } =
        decode_to_guest(&suite, ct_len, &accept(None)).expect("v2 accept decodes")
    else {
        panic!("wrong kind")
    };
    assert_eq!(protocol, SERVE_PROTOCOL_V2);
    assert_eq!(basis_evict, BasisEvict::Freeze);

    // extended form → announced policy, for both protocols that carry it
    for proto in [SERVE_PROTOCOL_VERSION, sbp::federation::message::SERVE_PROTOCOL_V3] {
        for (tag, want) in [(0u8, BasisEvict::Freeze), (1, BasisEvict::Lru)] {
            let ToGuest::SessionAccept { protocol, basis_evict, .. } =
                decode_to_guest(&suite, ct_len, &accept(Some((proto, tag))))
                    .expect("extended accept decodes")
            else {
                panic!("wrong kind")
            };
            assert_eq!(protocol, proto);
            assert_eq!(basis_evict, want);
        }
    }

    // unknown eviction tag
    assert!(matches!(
        decode_to_guest(&suite, ct_len, &accept(Some((SERVE_PROTOCOL_VERSION, 2)))),
        Err(WireError::BadTag { .. })
    ));
    // an extension claiming v2 (or garbage) is malformed
    for proto in [0u32, 1, SERVE_PROTOCOL_V2, SERVE_PROTOCOL_VERSION + 1] {
        assert!(
            matches!(
                decode_to_guest(&suite, ct_len, &accept(Some((proto, 1)))),
                Err(WireError::Malformed(_))
            ),
            "extension protocol {proto} must be rejected"
        );
    }
    // truncating the extension mid-way errors, never panics. (Cutting
    // it off *entirely* — the 13-byte prefix — is the valid v2 accept
    // by design, so the error range starts one past it.)
    let full = accept(Some((SERVE_PROTOCOL_VERSION, 1)));
    assert!(
        matches!(
            decode_to_guest(&suite, ct_len, &full[..13]),
            Ok(ToGuest::SessionAccept { protocol: SERVE_PROTOCOL_V2, .. })
        ),
        "the extension-free prefix is the v2 accept"
    );
    for cut in 14..full.len() {
        assert!(decode_to_guest(&suite, ct_len, &full[..cut]).is_err(), "prefix {cut}");
    }
}

/// Decode never panics: replay every sample frame's encoding under
/// seeded single-byte mutations (every position, a seeded replacement
/// value) and under systematic truncations. A mutation may decode to a
/// *different valid message* (flipping a session-id byte is harmless) —
/// what must never happen is a panic or a runaway allocation; a
/// truncation must always be a clean `WireError`.
#[test]
fn mutated_frames_never_panic() {
    let suite = CipherSuite::new_plain(256);
    let ct_len = suite.ct_byte_len();
    let mut rng = ChaCha20Rng::from_u64(0xB17F11);
    let setup_state = (suite.public_side(), ct_len);
    let mut decode_errors = 0u64;
    let mut total = 0u64;

    for msg in sample_to_host_messages(&suite, &mut rng) {
        let bytes = encode_to_host(&suite, ct_len, &msg);
        for pos in 0..bytes.len() {
            let mut m = bytes.clone();
            // a seeded, guaranteed-different replacement byte
            m[pos] ^= (rng.next_u64() as u8) | 1;
            total += 1;
            if decode_to_host(Some((&setup_state.0, setup_state.1)), &m).is_err() {
                decode_errors += 1;
            }
        }
        for cut in 0..bytes.len() {
            assert!(
                decode_to_host(Some((&setup_state.0, setup_state.1)), &bytes[..cut]).is_err(),
                "truncation must error for {:?}",
                msg.kind()
            );
        }
    }
    for msg in sample_to_guest_messages(&suite, &mut rng) {
        let bytes = encode_to_guest(&suite, ct_len, &msg);
        for pos in 0..bytes.len() {
            let mut m = bytes.clone();
            m[pos] ^= (rng.next_u64() as u8) | 1;
            total += 1;
            if decode_to_guest(&suite, ct_len, &m).is_err() {
                decode_errors += 1;
            }
        }
        for cut in 0..bytes.len() {
            let decoded = decode_to_guest(&suite, ct_len, &bytes[..cut]);
            // the one legal truncation: a v3 accept's 13-byte prefix is
            // the valid v2 accept (dual-shape encoding); see
            // truncated_payloads_error_cleanly
            if matches!(
                (&msg, &decoded),
                (ToGuest::SessionAccept { .. }, Ok(ToGuest::SessionAccept { .. }))
            ) {
                continue;
            }
            assert!(
                decoded.is_err(),
                "truncation must error for {:?}",
                msg.kind()
            );
        }
    }
    // sanity: the corpus actually exercised the error paths. Many
    // mutations land in value bytes (ids, ciphertext residues, seeds)
    // and legitimately decode to a different valid message; but every
    // tag byte and length field must reject, so a healthy corpus
    // produces a solid floor of errors.
    assert!(total > 1000, "mutation corpus too small ({total})");
    assert!(
        decode_errors * 10 > total,
        "suspiciously few decode errors ({decode_errors}/{total}) — are the \
         defensive checks still armed?"
    );
}
