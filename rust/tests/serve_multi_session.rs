//! Long-lived inference service tests: **one** host process multiplexing
//! many guest sessions (sequential and concurrent) over framed TCP, each
//! session bit-identical to the colocated oracle; the shared routing
//! cache invisible on the wire but hot across sessions; decoy padding
//! changing bytes, never predictions; graceful shutdown draining an
//! unbounded server.

use sbp::config::{CipherKind, TrainConfig};
use sbp::coordinator::{
    predict_centralized, predict_federated_tcp, predict_sessions_tcp, serve_predict_tcp,
    shutdown_predict_hosts, train_federated, PredictReport, ServeReport,
};
use sbp::data::dataset::VerticalSplit;
use sbp::data::synthetic::SyntheticSpec;
use sbp::federation::predict::PredictOptions;
use sbp::federation::serve::ServeConfig;
use sbp::tree::predict::{GuestModel, HostModel};

fn fast_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::secureboost_plus();
    cfg.epochs = 4;
    cfg.max_depth = 3;
    cfg.cipher = CipherKind::Plain;
    cfg.goss = None;
    cfg.sparse_optimization = false;
    cfg
}

fn train(spec: SyntheticSpec, cfg: &TrainConfig) -> (VerticalSplit, GuestModel, Vec<HostModel>) {
    let vs = spec.generate_vertical(cfg.seed, cfg.n_hosts);
    let rep = train_federated(&vs, cfg).expect("training run");
    let (guest_m, host_ms) = rep.model();
    (vs, guest_m, host_ms)
}

/// Start one serving host process (thread) for host party 0 and return
/// (address, join handle).
fn start_server(
    vs: &VerticalSplit,
    host_ms: &[HostModel],
    cache_capacity: usize,
    max_sessions: usize,
) -> (String, std::thread::JoinHandle<ServeReport>) {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    let model = host_ms[0].clone();
    let slice = vs.hosts[0].clone();
    let handle = std::thread::spawn(move || {
        serve_predict_tcp(
            &listener,
            model,
            slice,
            ServeConfig { cache_capacity, ..ServeConfig::default() },
            max_sessions,
        )
        .expect("serve loop")
    });
    (addr, handle)
}

#[test]
fn one_host_process_serves_sequential_and_concurrent_sessions() {
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);
    let (addr, server) = start_server(&vs, &host_ms, 1 << 16, 5);

    // 3 strictly sequential sessions, then 2 concurrent ones, all against
    // the same host process and the same warm cache
    let seq = predict_sessions_tcp(
        &guest_m,
        &vs.guest,
        std::slice::from_ref(&addr),
        3,
        1,
        PredictOptions::default(),
    )
    .expect("sequential sessions");
    let conc = predict_sessions_tcp(
        &guest_m,
        &vs.guest,
        std::slice::from_ref(&addr),
        2,
        2,
        PredictOptions::default(),
    )
    .expect("concurrent sessions");
    let serve_report = server.join().expect("server thread");

    assert_eq!(seq.len(), 3);
    assert_eq!(conc.len(), 2);
    for r in seq.iter().chain(conc.iter()) {
        assert_eq!(
            r.preds, oracle,
            "session {} must be bit-identical to colocated",
            r.session_id
        );
        assert_eq!(r.n_rows, vs.n());
    }
    assert_eq!(serve_report.n_sessions, 5, "one host process served every session");

    // repeat traffic: sessions 2..5 re-ask the routing decisions session 1
    // populated, so the shared cache must report a real hit rate
    assert!(serve_report.cache.hits > 0, "repeat sessions must hit the cache");
    assert!(serve_report.cache.hit_rate() > 0.5, "4 of 5 sessions are repeats");
    assert_eq!(serve_report.queries_answered, serve_report.cache.hits + serve_report.cache.misses);

    // per-session wire accounting is exactly reproducible: every session
    // does identical work with a fresh memo, and the cache never
    // suppresses an on-the-wire message
    let host_side = &serve_report.sessions[0].comm;
    for s in &serve_report.sessions {
        assert_eq!(
            s.comm, *host_side,
            "session {} accounted different wire bytes",
            s.outcome.session_id
        );
        assert!(s.outcome.clean_close, "sessions end with SessionClose");
    }
    let client_side = &seq[0].comm;
    for r in seq.iter().chain(conc.iter()) {
        assert_eq!(r.comm, *client_side, "client-side accounting must be reproducible");
    }
    // both ends of the wire agree byte-for-byte
    assert_eq!(*client_side, *host_side);
}

#[test]
fn cached_and_uncached_serving_are_bit_identical() {
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);

    let run = |cache_capacity: usize| -> (Vec<PredictReport>, ServeReport) {
        let (addr, server) = start_server(&vs, &host_ms, cache_capacity, 4);
        let reports = predict_sessions_tcp(
            &guest_m,
            &vs.guest,
            std::slice::from_ref(&addr),
            4,
            1,
            PredictOptions::default(),
        )
        .expect("sessions");
        (reports, server.join().expect("server thread"))
    };
    let (miss_path, uncached) = run(0);
    let (hit_path, cached) = run(1 << 16);

    assert_eq!(uncached.cache.hits, 0, "capacity 0 disables the cache");
    assert_eq!(uncached.cache.misses, 0);
    assert!(cached.cache.hits > 0, "repeat sessions must hit");
    for (m, h) in miss_path.iter().zip(&hit_path) {
        assert_eq!(m.preds, oracle);
        assert_eq!(h.preds, m.preds, "hit path must equal miss path bit for bit");
        assert_eq!(h.comm, m.comm, "the cache must be invisible on the wire");
    }
    assert_eq!(cached.queries_answered, uncached.queries_answered);
}

#[test]
fn decoy_padding_changes_bytes_not_predictions() {
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);

    let run = |dummy_queries: usize| -> Vec<PredictReport> {
        let (addr, server) = start_server(&vs, &host_ms, 1 << 12, 2);
        let reports = predict_sessions_tcp(
            &guest_m,
            &vs.guest,
            std::slice::from_ref(&addr),
            2,
            1,
            PredictOptions { dummy_queries, seed: 1234, ..PredictOptions::default() },
        )
        .expect("sessions");
        server.join().expect("server thread");
        reports
    };
    let plain = run(0);
    let padded = run(16);
    for (p, q) in plain.iter().zip(&padded) {
        assert_eq!(p.preds, oracle);
        assert_eq!(q.preds, p.preds, "decoys must not change predictions");
        assert_eq!(p.decoy_queries, 0);
        assert!(q.decoy_queries >= 16, "every sent batch is padded");
        assert!(
            q.comm.bytes_to_host > p.comm.bytes_to_host,
            "padding must cost wire bytes"
        );
    }
}

#[test]
fn unbounded_server_drains_on_graceful_shutdown() {
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);
    let (addr, server) = start_server(&vs, &host_ms, 1 << 12, 0); // no session limit

    let reports = predict_sessions_tcp(
        &guest_m,
        &vs.guest,
        std::slice::from_ref(&addr),
        1,
        1,
        PredictOptions::default(),
    )
    .expect("session");
    assert_eq!(reports[0].preds, oracle);

    // a bare control connection carrying only Shutdown asks the whole
    // service to wind down; the accept loop must observe it and return
    // instead of blocking forever — and the control connection itself
    // must not show up as a served session
    shutdown_predict_hosts(std::slice::from_ref(&addr)).expect("shutdown request");
    let serve_report = server.join().expect("server thread");
    assert_eq!(serve_report.n_sessions, 1, "control connections are not sessions");
    assert!(serve_report.queries_answered > 0);
}

#[test]
fn legacy_single_shot_client_does_not_kill_the_server() {
    let (vs, guest_m, host_ms) = train(SyntheticSpec::give_credit(0.002), &fast_cfg());
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);
    let (addr, server) = start_server(&vs, &host_ms, 1 << 12, 2);

    // the legacy sessionless flow ends with a Shutdown frame after its
    // queries — that must end only *its* session, not the whole service
    let legacy = predict_federated_tcp(&guest_m, &vs.guest, std::slice::from_ref(&addr))
        .expect("legacy single-shot predict");
    assert_eq!(legacy.preds, oracle);

    // worst case: a hello-less connection that sends *only* Shutdown
    // (e.g. a legacy client whose link carried zero queries) — still
    // must not stop the server, and must not consume session budget
    {
        use sbp::federation::transport::GuestTransport;
        let t = sbp::federation::tcp::TcpGuestTransport::connect(
            &addr,
            sbp::crypto::cipher::CipherSuite::new_plain(64),
        )
        .expect("bare connection");
        t.send(sbp::federation::message::ToHost::Shutdown);
    }

    // the server must still be accepting: a second, session-ful client
    let after = predict_sessions_tcp(
        &guest_m,
        &vs.guest,
        std::slice::from_ref(&addr),
        1,
        1,
        PredictOptions::default(),
    )
    .expect("server must still accept after a legacy client");
    assert_eq!(after[0].preds, oracle);
    let serve_report = server.join().expect("server thread");
    assert_eq!(serve_report.n_sessions, 2);
}

#[test]
fn two_host_processes_serve_multi_party_sessions() {
    let mut cfg = fast_cfg();
    cfg.n_hosts = 2;
    let (vs, guest_m, host_ms) = train(SyntheticSpec::higgs(0.0002), &cfg);
    assert_eq!(host_ms.len(), 2);
    let oracle = predict_centralized(&guest_m, &host_ms, &vs);

    let mut addrs = Vec::new();
    let mut servers = Vec::new();
    for p in 0..2 {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().unwrap().to_string());
        let model = host_ms[p].clone();
        let slice = vs.hosts[p].clone();
        servers.push(std::thread::spawn(move || {
            serve_predict_tcp(
                &listener,
                model,
                slice,
                ServeConfig { cache_capacity: 1 << 12, ..ServeConfig::default() },
                2,
            )
            .expect("serve loop")
        }));
    }
    let reports =
        predict_sessions_tcp(&guest_m, &vs.guest, &addrs, 2, 1, PredictOptions::default())
            .expect("sessions");
    for server in servers {
        let rep = server.join().expect("server thread");
        assert_eq!(rep.n_sessions, 2);
    }
    for r in &reports {
        assert_eq!(r.preds, oracle, "multi-host session must match colocated");
    }
}
