//! Property-based tests over the crypto substrate's invariants —
//! randomized inputs with deterministic seeds (a lightweight
//! proptest-style harness; shrinkage isn't needed at these sizes).
//!
//! Invariants covered:
//! - bignum ring laws (distributivity, div/mod reconstruction)
//! - Paillier homomorphism over random op sequences
//! - GH packing: Σ pack(gᵢ,hᵢ) unpacks to (Σg, Σh) for any subset
//! - compression: decompress ∘ compress = id for any stat count
//! - histogram algebra: parent = left + right in ciphertext
//! - fixed-point precision bounds

use sbp::crypto::bigint::BigUint;
use sbp::crypto::cipher::{CipherSuite, Ct};
use sbp::crypto::compress::{compress, decompress, CompressPlan, SplitStatCt};
use sbp::crypto::packing::GhPacker;
use sbp::util::rng::{ChaCha20Rng, Xoshiro256};

const CASES: usize = 40;

fn rand_big(r: &mut Xoshiro256, max_limbs: usize) -> BigUint {
    let n = r.next_below(max_limbs) + 1;
    BigUint::from_limbs((0..n).map(|_| r.next_u64()).collect())
}

#[test]
fn prop_bignum_ring_laws() {
    let mut r = Xoshiro256::seed_from_u64(0xB16);
    for _ in 0..CASES {
        let a = rand_big(&mut r, 12);
        let b = rand_big(&mut r, 12);
        let c = rand_big(&mut r, 12);
        // (a + b)·c = a·c + b·c
        assert_eq!(a.add(&b).mul(&c), a.mul(&c).add(&b.mul(&c)));
        // a = (a / b)·b + a % b
        if !b.is_zero() {
            let (q, rem) = a.div_rem(&b);
            assert_eq!(q.mul(&b).add(&rem), a);
        }
        // shift laws: (a << k) >> k = a
        let k = r.next_below(120);
        assert_eq!(a.shl(k).shr(k), a);
    }
}

#[test]
fn prop_modular_identities() {
    let mut r = Xoshiro256::seed_from_u64(0x40D);
    let mut crng = ChaCha20Rng::from_u64(5);
    for _ in 0..20 {
        let mut m = BigUint::random_exact_bits(&mut crng, 256);
        if m.is_even() {
            m = m.add_u64(1);
        }
        let a = BigUint::random_below(&mut crng, &m);
        let b = BigUint::random_below(&mut crng, &m);
        let e1 = BigUint::from_u64(r.next_u64() % 1000);
        let e2 = BigUint::from_u64(r.next_u64() % 1000);
        // a^(e1+e2) = a^e1 · a^e2 (mod m)
        assert_eq!(
            a.mod_pow(&e1.add(&e2), &m),
            a.mod_pow(&e1, &m).mul_mod(&a.mod_pow(&e2, &m), &m)
        );
        // (a·b)^e = a^e · b^e (mod m)
        assert_eq!(
            a.mul_mod(&b, &m).mod_pow(&e1, &m),
            a.mod_pow(&e1, &m).mul_mod(&b.mod_pow(&e1, &m), &m)
        );
    }
}

/// Random homomorphic op sequences must track a plaintext shadow.
#[test]
fn prop_paillier_homomorphism_sequences() {
    let mut crng = ChaCha20Rng::from_u64(11);
    let suite = CipherSuite::new_paillier(512, &mut crng);
    let mut r = Xoshiro256::seed_from_u64(12);
    let modulus_bits = suite.plaintext_bits();
    for _ in 0..10 {
        // shadow value tracked in plain arithmetic (bounded well below ι)
        let mut shadow = BigUint::from_u64(r.next_u64() >> 8);
        let mut ct = suite.encrypt(&shadow, &mut crng);
        for _ in 0..8 {
            match r.next_below(3) {
                0 => {
                    let v = BigUint::from_u64(r.next_u64() >> 8);
                    let c2 = suite.encrypt(&v, &mut crng);
                    ct = suite.add(&ct, &c2);
                    shadow = shadow.add(&v);
                }
                1 => {
                    let k = BigUint::from_u64((r.next_u64() % 1000).max(1));
                    ct = suite.scalar_mul(&ct, &k);
                    shadow = shadow.mul(&k);
                }
                _ => {
                    // subtract something smaller than the shadow
                    let v = BigUint::from_u64(r.next_u64() % 1000);
                    if shadow.cmp_big(&v) == std::cmp::Ordering::Greater {
                        let c2 = suite.encrypt(&v, &mut crng);
                        ct = suite.sub(&ct, &c2);
                        shadow = shadow.sub(&v);
                    }
                }
            }
            if shadow.bit_length() > modulus_bits - 16 {
                break; // stay far from wraparound
            }
        }
        assert_eq!(suite.decrypt(&ct), shadow);
    }
}

#[test]
fn prop_packed_subset_sums() {
    let mut r = Xoshiro256::seed_from_u64(21);
    for case in 0..CASES {
        let n = r.next_below(300) + 2;
        let g: Vec<f64> = (0..n).map(|_| r.next_f64() * 2.0 - 1.0).collect();
        let h: Vec<f64> = (0..n).map(|_| r.next_f64()).collect();
        let p = GhPacker::plan(&g, &h, n as u64, 53);
        let packed = p.pack_all(&g, &h);
        // random subset
        let subset: Vec<usize> = (0..n).filter(|_| r.next_f64() < 0.4).collect();
        if subset.is_empty() {
            continue;
        }
        let mut acc = BigUint::zero();
        let (mut gs, mut hs) = (0.0f64, 0.0f64);
        for &i in &subset {
            acc = acc.add(&packed[i]);
            gs += g[i];
            hs += h[i];
        }
        let (gu, hu) = p.unpack_sum(&acc, subset.len() as u64);
        assert!((gu - gs).abs() < 1e-6, "case {case}: g {gu} vs {gs}");
        assert!((hu - hs).abs() < 1e-6, "case {case}: h {hu} vs {hs}");
    }
}

#[test]
fn prop_compress_roundtrip_any_count() {
    let mut crng = ChaCha20Rng::from_u64(31);
    let suite = CipherSuite::new_paillier(512, &mut crng);
    let mut r = Xoshiro256::seed_from_u64(32);
    let packer = GhPacker::plan_logistic(500, 40);
    let plan = CompressPlan::derive(suite.plaintext_bits(), packer.b_gh);
    assert!(plan.capacity >= 2, "test needs real compression");
    for count in [1usize, 2, plan.capacity - 1, plan.capacity, plan.capacity + 1, 23] {
        let stats: Vec<SplitStatCt> = (0..count)
            .map(|i| {
                let g = r.next_f64() * 2.0 - 1.0;
                let h = r.next_f64();
                let plain = packer.pack(g, h);
                SplitStatCt {
                    ct: suite.encrypt(&plain, &mut crng),
                    id: i as u32,
                    sample_count: 1,
                }
            })
            .collect();
        let pkgs = compress(&suite, &plan, &stats);
        assert_eq!(pkgs.len(), count.div_ceil(plan.capacity));
        let rec = decompress(&suite, &plan, &packer, &pkgs);
        assert_eq!(rec.len(), count);
        for (i, row) in rec.iter().enumerate() {
            assert_eq!(row.id, i as u32);
        }
    }
}

/// parent histogram == left + right, in ciphertext, for random splits.
#[test]
fn prop_cipher_histogram_additivity() {
    use sbp::data::binning::bin_party;
    use sbp::data::dataset::PartySlice;
    use sbp::tree::histogram::CipherHistogram;

    let mut crng = ChaCha20Rng::from_u64(41);
    let suite = CipherSuite::new_paillier(512, &mut crng);
    let mut r = Xoshiro256::seed_from_u64(42);
    let n = 80;
    let d = 3;
    let x: Vec<f64> = (0..n * d).map(|_| r.next_gaussian()).collect();
    let bm = bin_party(&PartySlice { cols: (0..d).collect(), x, n }, 8);
    let g: Vec<f64> = (0..n).map(|_| r.next_f64() * 2.0 - 1.0).collect();
    let h: Vec<f64> = (0..n).map(|_| r.next_f64()).collect();
    let packer = GhPacker::plan(&g, &h, n as u64, 40);
    let plains = packer.pack_all(&g, &h);
    let cts: Vec<Ct> = suite.encrypt_batch(&plains, &mut crng);
    let pos: Vec<u32> = (0..n as u32).collect();

    for _ in 0..5 {
        // random partition
        let mut left = Vec::new();
        let mut right = Vec::new();
        for i in 0..n as u32 {
            if r.next_f64() < 0.5 {
                left.push(i)
            } else {
                right.push(i)
            }
        }
        if left.is_empty() || right.is_empty() {
            continue;
        }
        let all: Vec<u32> = (0..n as u32).collect();
        let hp = CipherHistogram::build(&suite, &bm, 8, &all, &cts, &pos, 1);
        let hl = CipherHistogram::build(&suite, &bm, 8, &left, &cts, &pos, 1);
        let hr = CipherHistogram::build(&suite, &bm, 8, &right, &cts, &pos, 1);
        for f in 0..d {
            for b in 0..8 {
                let cell = hp.cell(f, b);
                let sum = suite.add(&hl.cells[cell], &hr.cells[cell]);
                assert_eq!(
                    suite.decrypt(&sum),
                    suite.decrypt(&hp.cells[cell]),
                    "f{f} b{b}"
                );
            }
        }
    }
}

#[test]
fn prop_fixed_point_precision_bound() {
    use sbp::crypto::encoding::FixedPointEncoder;
    let mut r = Xoshiro256::seed_from_u64(51);
    for prec in [20u32, 40, 53] {
        let enc = FixedPointEncoder::new(prec);
        let ulp = 2f64.powi(-(prec as i32));
        for _ in 0..CASES {
            let x = r.next_f64() * 100.0;
            let err = (enc.decode(&enc.encode(x)) - x).abs();
            // decode goes through f64, so allow an extra float ulp at 53
            assert!(err <= ulp + x.abs() * f64::EPSILON, "prec {prec}: err {err}");
        }
    }
}

/// Negation edges: Dec(−0) = 0; Dec(a − a) = 0 under every schema.
#[test]
fn prop_negation_edges() {
    let mut crng = ChaCha20Rng::from_u64(61);
    for suite in [
        CipherSuite::new_paillier(512, &mut crng),
        CipherSuite::new_affine(512, &mut crng),
        CipherSuite::new_plain(511),
    ] {
        let zero = suite.encrypt(&BigUint::zero(), &mut crng);
        assert_eq!(
            suite.decrypt(&suite.negate(&zero)),
            BigUint::zero(),
            "{}",
            suite.kind_name()
        );
        let a = suite.encrypt(&BigUint::from_u64(777), &mut crng);
        assert_eq!(suite.decrypt(&suite.sub(&a, &a)), BigUint::zero());
    }
}

/// GH packing at the capacity boundary: every instance at the maximum
/// planned magnitude, aggregated over exactly `n_bound` samples, must
/// stay inside the planned bit budget and unpack to the plaintext sums.
#[test]
fn packing_capacity_boundary_max_magnitude() {
    for n_bound in [1u64, 2, 100, 4096] {
        let p = GhPacker::plan_logistic(n_bound, 53);
        // logistic worst case: g = +1.0 (raw 2.0 after offset), h = 1.0
        let one = p.pack(1.0, 1.0);
        let mut acc = BigUint::zero();
        for _ in 0..n_bound {
            acc = acc.add(&one);
        }
        assert!(
            acc.bit_length() <= p.b_gh,
            "n={n_bound}: aggregate spills the budget ({} > {})",
            acc.bit_length(),
            p.b_gh
        );
        // the h field must not have leaked into the g field
        let (gs, hs) = p.unpack_sum(&acc, n_bound);
        assert!((gs - n_bound as f64).abs() < 1e-6, "g {gs} vs {n_bound}");
        assert!((hs - n_bound as f64).abs() < 1e-6, "h {hs} vs {n_bound}");

        // the negative extreme likewise: g = −1.0 encodes to raw 0
        let neg = p.pack(-1.0, 0.0);
        let (gn, hn) = p.unpack_sum(&neg, 1);
        assert!((gn + 1.0).abs() < 1e-9 && hn == 0.0);
    }
}

/// Data-derived plans hit the same boundary exactly: the plan is built
/// from the actual vectors, then every instance is packed and aggregated.
#[test]
fn packing_capacity_boundary_data_derived() {
    let mut r = Xoshiro256::seed_from_u64(81);
    let n = 1000usize;
    let mut g: Vec<f64> = (0..n).map(|_| r.next_f64() * 2.0 - 1.0).collect();
    let mut h: Vec<f64> = (0..n).map(|_| r.next_f64()).collect();
    // force the extremes to be present so max-magnitude packing happens
    g[0] = -1.0;
    g[1] = 1.0;
    h[0] = 1.0;
    let p = GhPacker::plan(&g, &h, n as u64, 53);
    let mut acc = BigUint::zero();
    for (gi, hi) in g.iter().zip(&h) {
        acc = acc.add(&p.pack(*gi, *hi));
    }
    assert!(acc.bit_length() <= p.b_gh);
    let (gs, hs) = p.unpack_sum(&acc, n as u64);
    assert!((gs - g.iter().sum::<f64>()).abs() < 1e-6);
    assert!((hs - h.iter().sum::<f64>()).abs() < 1e-6);
}

/// A gradient outside the planned range must be rejected, not silently
/// corrupt neighbouring bit fields.
#[test]
#[should_panic(expected = "packing budget")]
fn packing_overflow_gradient_rejected() {
    let g = [0.05, -0.1, 0.02];
    let h = [0.01, 0.02, 0.03];
    let p = GhPacker::plan(&g, &h, 3, 53);
    let _ = p.pack(5.0, 0.01); // ~50× the planned gradient range
}

/// A hessian outside the planned range must be rejected too.
#[test]
#[should_panic(expected = "packing budget")]
fn packing_overflow_hessian_rejected() {
    let g = [0.05, -0.1, 0.02];
    let h = [0.01, 0.02, 0.03];
    let p = GhPacker::plan(&g, &h, 3, 53);
    let _ = p.pack(0.0, 7.0);
}

/// Multi-class planning must refuse a plaintext space too small for even
/// one class (paper eq. 21 requires η_c ≥ 1).
#[test]
#[should_panic(expected = "does not fit")]
fn mo_packing_rejects_tiny_plaintext_space() {
    use sbp::crypto::packing::MoPacker;
    let k = 4;
    let g = vec![0.5; k];
    let h = vec![0.5; k];
    // b_gh for n=1M at r=53 is 147 bits; 100 bits cannot hold one class
    let _ = MoPacker::plan(&g, &h, k, 1_000_000, 53, 100);
}

/// Cipher compression at η_s capacity with every slot at the maximum
/// aggregated magnitude: the top slot sits flush against the plaintext
/// capacity, and every slot must still unpack to its plaintext sums.
#[test]
fn compression_full_capacity_max_magnitude() {
    let mut crng = ChaCha20Rng::from_u64(91);
    for suite in [
        CipherSuite::new_paillier(512, &mut crng),
        CipherSuite::new_affine(1024, &mut crng),
    ] {
        let n_bound = 1000u64;
        let packer = GhPacker::plan_logistic(n_bound, 53);
        let plan = CompressPlan::derive(suite.plaintext_bits(), packer.b_gh);
        assert!(plan.capacity >= 2);
        // each stat: the max-magnitude aggregate over n_bound instances
        let max_pack = packer.pack(1.0, 1.0);
        let mut aggregate = BigUint::zero();
        for _ in 0..n_bound {
            aggregate = aggregate.add(&max_pack);
        }
        let stats: Vec<SplitStatCt> = (0..plan.capacity)
            .map(|i| SplitStatCt {
                ct: suite.encrypt(&aggregate, &mut crng),
                id: i as u32,
                sample_count: n_bound as u32,
            })
            .collect();
        let pkgs = compress(&suite, &plan, &stats);
        assert_eq!(pkgs.len(), 1, "exactly one full package");
        let rec = decompress(&suite, &plan, &packer, &pkgs);
        assert_eq!(rec.len(), plan.capacity);
        for row in rec {
            assert_eq!(row.sample_count, n_bound as u32);
            assert!(
                (row.g_sum - n_bound as f64).abs() < 1e-6,
                "{}: g {} vs {n_bound}",
                suite.kind_name(),
                row.g_sum
            );
            assert!((row.h_sum - n_bound as f64).abs() < 1e-6);
        }
    }
}

/// `scalar_pow2` must equal `scalar_mul` by 2^k (the compression shift).
#[test]
fn prop_scalar_pow2_matches_scalar_mul() {
    let mut crng = ChaCha20Rng::from_u64(71);
    for suite in [
        CipherSuite::new_paillier(512, &mut crng),
        CipherSuite::new_affine(512, &mut crng),
        CipherSuite::new_plain(400),
    ] {
        let m = BigUint::from_u64(12345);
        let c = suite.encrypt(&m, &mut crng);
        for k in [1usize, 7, 64, 147] {
            let a = suite.scalar_pow2(&c, k);
            let b = suite.scalar_mul(&c, &BigUint::one().shl(k));
            assert_eq!(
                suite.decrypt(&a),
                suite.decrypt(&b),
                "{} k={k}",
                suite.kind_name()
            );
        }
    }
}
