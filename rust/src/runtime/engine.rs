//! `ComputeEngine`: the guest's plaintext numeric kernel interface.
//!
//! Two implementations:
//! - [`CpuEngine`] — pure Rust; the correctness oracle and fallback.
//! - [`crate::runtime::pjrt::XlaEngine`] — executes the AOT artifacts.
//!
//! Both are interchangeable; integration tests assert they agree to
//! float tolerance on every entry point.

use crate::boosting::loss;

/// Plaintext numeric kernels used by the guest on the training path.
///
/// Not `Send`/`Sync`: the guest drives training from a single thread, and
/// the PJRT client wrapper is single-threaded by construction.
pub trait ComputeEngine {
    /// Engine name for logs and reports.
    fn name(&self) -> &'static str;

    /// Binary logistic g/h from labels and logits.
    fn gh_binary(&self, y: &[f64], logits: &[f64]) -> (Vec<f64>, Vec<f64>);

    /// Softmax CE g/h (row-major n×k).
    fn gh_softmax(&self, y: &[f64], logits: &[f64], k: usize) -> (Vec<f64>, Vec<f64>);

    /// Histogram of (g, h) over `bin_idx` (row-major n×d, values < n_bins):
    /// returns (g_hist, h_hist, count), each feature-major `d × n_bins`.
    fn histogram(
        &self,
        bin_idx: &[u8],
        n: usize,
        d: usize,
        n_bins: usize,
        g: &[f64],
        h: &[f64],
    ) -> (Vec<f64>, Vec<f64>, Vec<u32>);

    /// Split gains for every (feature, bin) from a *cumulative* histogram
    /// plus node totals (paper eq. 6). Returns d×n_bins gains (last bin
    /// meaningless; emitted as 0).
    fn gain_scan(
        &self,
        g_cum: &[f64],
        h_cum: &[f64],
        d: usize,
        n_bins: usize,
        g_total: f64,
        h_total: f64,
        lambda: f64,
    ) -> Vec<f64>;
}

/// Pure-Rust reference engine.
#[derive(Default, Clone, Copy, Debug)]
pub struct CpuEngine;

impl ComputeEngine for CpuEngine {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn gh_binary(&self, y: &[f64], logits: &[f64]) -> (Vec<f64>, Vec<f64>) {
        loss::compute_gh(loss::Objective::BinaryLogistic, y, logits)
    }

    fn gh_softmax(&self, y: &[f64], logits: &[f64], k: usize) -> (Vec<f64>, Vec<f64>) {
        loss::compute_gh(loss::Objective::SoftmaxCE { k }, y, logits)
    }

    fn histogram(
        &self,
        bin_idx: &[u8],
        n: usize,
        d: usize,
        n_bins: usize,
        g: &[f64],
        h: &[f64],
    ) -> (Vec<f64>, Vec<f64>, Vec<u32>) {
        let mut gh = vec![0.0f64; d * n_bins];
        let mut hh = vec![0.0f64; d * n_bins];
        let mut ch = vec![0u32; d * n_bins];
        for i in 0..n {
            let row = &bin_idx[i * d..(i + 1) * d];
            for (f, &b) in row.iter().enumerate() {
                let cell = f * n_bins + b as usize;
                gh[cell] += g[i];
                hh[cell] += h[i];
                ch[cell] += 1;
            }
        }
        (gh, hh, ch)
    }

    fn gain_scan(
        &self,
        g_cum: &[f64],
        h_cum: &[f64],
        d: usize,
        n_bins: usize,
        g_total: f64,
        h_total: f64,
        lambda: f64,
    ) -> Vec<f64> {
        let mut out = vec![0.0f64; d * n_bins];
        let parent = g_total * g_total / (h_total + lambda);
        for f in 0..d {
            for b in 0..n_bins - 1 {
                let cell = f * n_bins + b;
                let gl = g_cum[cell];
                let hl = h_cum[cell];
                let gr = g_total - gl;
                let hr = h_total - hl;
                out[cell] =
                    0.5 * (gl * gl / (hl + lambda) + gr * gr / (hr + lambda) - parent);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_totals() {
        let e = CpuEngine;
        let n = 10;
        let d = 2;
        let bins: Vec<u8> = (0..n * d).map(|i| (i % 4) as u8).collect();
        let g: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let h = vec![1.0; n];
        let (gh, hh, ch) = e.histogram(&bins, n, d, 4, &g, &h);
        for f in 0..d {
            let gs: f64 = (0..4).map(|b| gh[f * 4 + b]).sum();
            let hs: f64 = (0..4).map(|b| hh[f * 4 + b]).sum();
            let cs: u32 = (0..4).map(|b| ch[f * 4 + b]).sum();
            assert!((gs - 45.0).abs() < 1e-12);
            assert!((hs - 10.0).abs() < 1e-12);
            assert_eq!(cs, 10);
        }
    }

    #[test]
    fn gain_scan_matches_split_module() {
        let e = CpuEngine;
        // single feature, 4 bins, simple cumulative stats
        let g_cum = [1.0, 3.0, 2.5, 4.0];
        let h_cum = [2.0, 4.0, 6.0, 8.0];
        let gains = e.gain_scan(&g_cum, &h_cum, 1, 4, 4.0, 8.0, 0.5);
        for b in 0..3 {
            let expect = crate::tree::split::gain_scalar(
                g_cum[b],
                h_cum[b],
                4.0 - g_cum[b],
                8.0 - h_cum[b],
                4.0,
                8.0,
                0.5,
            );
            assert!((gains[b] - expect).abs() < 1e-12);
        }
        assert_eq!(gains[3], 0.0);
    }
}
