//! `XlaEngine`: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text**, see /opt/xla-example/README.md for why not serialized
//! protos), compiles them once on the PJRT CPU client, and executes them
//! from the training hot path.
//!
//! Artifacts are compiled for fixed tile shapes (DESIGN.md §6):
//! `N_TILE` instances, `F_TILE` features, `B` bins, `K` classes. The
//! engine pads/tiles arbitrary problem sizes onto those shapes; padding
//! rows carry zero g/h so they never perturb statistics.
//!
//! The PJRT client comes from the external `xla` crate, which is not part
//! of the offline crate universe. The real engine is therefore gated
//! behind the `sbp_xla_pjrt` cfg flag (vendor the `xla` crate, declare
//! the dependency, build with `RUSTFLAGS="--cfg sbp_xla_pjrt"`); without
//! it this module compiles a stub whose [`XlaEngine::load`] always fails,
//! so every caller takes its existing CpuEngine fallback path.

use std::path::PathBuf;

/// Tile geometry, read from `artifacts/manifest.json`.
#[derive(Clone, Copy, Debug)]
pub struct Tiles {
    /// Instances per tile.
    pub n_tile: usize,
    /// Features per tile.
    pub f_tile: usize,
    /// Histogram bins per feature.
    pub bins: usize,
    /// Classes per tile (multi-class kernels).
    pub k_tile: usize,
}

/// Default artifact directory (`$SBP_ARTIFACTS` or `artifacts/`).
fn artifact_dir() -> PathBuf {
    std::env::var("SBP_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    })
}

#[cfg(not(sbp_xla_pjrt))]
pub use stub::XlaEngine;
#[cfg(sbp_xla_pjrt)]
pub use xla_impl::XlaEngine;

/// Stub engine compiled when the `sbp_xla_pjrt` cfg (and with it the
/// external `xla` crate) is unavailable. `load` always fails; the
/// `ComputeEngine` impl delegates to the pure-Rust oracle so the type
/// remains usable in generic positions.
#[cfg(not(sbp_xla_pjrt))]
mod stub {
    use super::Tiles;
    use crate::runtime::engine::{ComputeEngine, CpuEngine};
    use anyhow::{anyhow, Result};
    use std::path::{Path, PathBuf};

    /// Stub engine: construction always fails, compute delegates to
    /// [`CpuEngine`].
    pub struct XlaEngine {
        /// Tile geometry (defaults; no manifest was loaded).
        pub tiles: Tiles,
    }

    impl XlaEngine {
        /// Always fails in the stub build (see module docs).
        pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
            Err(anyhow!(
                "XlaEngine unavailable: built without `--cfg sbp_xla_pjrt` \
                 (the external `xla` crate is not vendored in this workspace)"
            ))
        }

        /// Default artifact directory (`$SBP_ARTIFACTS` or `artifacts/`).
        pub fn default_dir() -> PathBuf {
            super::artifact_dir()
        }
    }

    impl ComputeEngine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla-pjrt(stub)"
        }

        fn gh_binary(&self, y: &[f64], logits: &[f64]) -> (Vec<f64>, Vec<f64>) {
            CpuEngine.gh_binary(y, logits)
        }

        fn gh_softmax(&self, y: &[f64], logits: &[f64], k: usize) -> (Vec<f64>, Vec<f64>) {
            CpuEngine.gh_softmax(y, logits, k)
        }

        fn histogram(
            &self,
            bin_idx: &[u8],
            n: usize,
            d: usize,
            n_bins: usize,
            g: &[f64],
            h: &[f64],
        ) -> (Vec<f64>, Vec<f64>, Vec<u32>) {
            CpuEngine.histogram(bin_idx, n, d, n_bins, g, h)
        }

        fn gain_scan(
            &self,
            g_cum: &[f64],
            h_cum: &[f64],
            d: usize,
            n_bins: usize,
            g_total: f64,
            h_total: f64,
            lambda: f64,
        ) -> Vec<f64> {
            CpuEngine.gain_scan(g_cum, h_cum, d, n_bins, g_total, h_total, lambda)
        }
    }
}

#[cfg(sbp_xla_pjrt)]
mod xla_impl {
    use super::Tiles;
    use crate::config::json::Json;
    use crate::runtime::engine::ComputeEngine;
    use anyhow::{anyhow, Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    /// One compiled artifact.
    struct Artifact {
        exe: xla::PjRtLoadedExecutable,
    }

    /// PJRT-backed engine. Thread-safe: executions are serialized on a mutex
    /// (the PJRT CPU client parallelizes internally; the guest calls these
    /// once per epoch / per large node, so contention is nil).
    pub struct XlaEngine {
        _client: xla::PjRtClient,
        arts: Mutex<HashMap<String, Artifact>>,
        /// Tile geometry from the artifact manifest.
        pub tiles: Tiles,
    }

    impl XlaEngine {
        /// Load every artifact listed in `<dir>/manifest.json`.
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref();
            let manifest_path = dir.join("manifest.json");
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
            let manifest =
                Json::parse(&text).map_err(|e| anyhow!("manifest.json parse error: {e}"))?;
            let tiles = Tiles {
                n_tile: manifest.get("n_tile").and_then(Json::as_usize).unwrap_or(4096),
                f_tile: manifest.get("f_tile").and_then(Json::as_usize).unwrap_or(32),
                bins: manifest.get("bins").and_then(Json::as_usize).unwrap_or(32),
                k_tile: manifest.get("k_tile").and_then(Json::as_usize).unwrap_or(8),
            };
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            let mut arts = HashMap::new();
            let listed = manifest
                .get("artifacts")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("manifest missing 'artifacts' array"))?;
            for name in listed {
                let name = name.as_str().ok_or_else(|| anyhow!("artifact name not a string"))?;
                let path: PathBuf = dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
                arts.insert(name.to_string(), Artifact { exe });
            }
            Ok(XlaEngine { _client: client, arts: Mutex::new(arts), tiles })
        }

        /// Default artifact directory (`$SBP_ARTIFACTS` or `artifacts/`).
        pub fn default_dir() -> PathBuf {
            super::artifact_dir()
        }

        fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let arts = self.arts.lock().expect("engine poisoned");
            let art = arts
                .get(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
            let result = art
                .exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
            result.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
        }

        /// Execute a two-in/two-out elementwise-tiled artifact over `n` items.
        fn run_gh_tiled(&self, name: &str, a: &[f32], b: &[f32], n: usize) -> Result<(Vec<f64>, Vec<f64>)> {
            let nt = self.tiles.n_tile;
            let mut g = Vec::with_capacity(n);
            let mut h = Vec::with_capacity(n);
            let mut start = 0usize;
            while start < n {
                let end = (start + nt).min(n);
                let mut ta = a[start..end].to_vec();
                let mut tb = b[start..end].to_vec();
                ta.resize(nt, 0.0);
                tb.resize(nt, 0.0);
                let la = xla::Literal::vec1(&ta);
                let lb = xla::Literal::vec1(&tb);
                let out = self.run(name, &[la, lb])?;
                let gt = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
                let ht = out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
                g.extend(gt[..end - start].iter().map(|&v| v as f64));
                h.extend(ht[..end - start].iter().map(|&v| v as f64));
                start = end;
            }
            Ok((g, h))
        }
    }

    impl ComputeEngine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla-pjrt"
        }

        fn gh_binary(&self, y: &[f64], logits: &[f64]) -> (Vec<f64>, Vec<f64>) {
            let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
            let sf: Vec<f32> = logits.iter().map(|&v| v as f32).collect();
            self.run_gh_tiled("gh_binary", &yf, &sf, y.len())
                .expect("gh_binary artifact execution failed")
        }

        fn gh_softmax(&self, y: &[f64], logits: &[f64], k: usize) -> (Vec<f64>, Vec<f64>) {
            let kt = self.tiles.k_tile;
            assert!(k <= kt, "k={k} exceeds compiled K_TILE={kt}");
            let n = y.len();
            let nt = self.tiles.n_tile;
            let mut g = vec![0.0f64; n * k];
            let mut h = vec![0.0f64; n * k];
            let mut start = 0usize;
            while start < n {
                let end = (start + nt).min(n);
                let rows = end - start;
                // one-hot labels padded to K_TILE; padding classes get logits
                // of −inf surrogate (−1e9) so softmax mass on them is ~0.
                let mut yoh = vec![0.0f32; nt * kt];
                let mut lg = vec![-1e9f32; nt * kt];
                for i in 0..rows {
                    let cls = y[start + i] as usize;
                    yoh[i * kt + cls] = 1.0;
                    for j in 0..k {
                        lg[i * kt + j] = logits[(start + i) * k + j] as f32;
                    }
                }
                // padding rows: class 0 one-hot, logit 0 on class 0 (harmless)
                for i in rows..nt {
                    yoh[i * kt] = 1.0;
                    lg[i * kt] = 0.0;
                }
                let ly = xla::Literal::vec1(&yoh).reshape(&[nt as i64, kt as i64]).unwrap();
                let ll = xla::Literal::vec1(&lg).reshape(&[nt as i64, kt as i64]).unwrap();
                let out = self.run("gh_softmax", &[ly, ll]).expect("gh_softmax failed");
                let gt = out[0].to_vec::<f32>().unwrap();
                let ht = out[1].to_vec::<f32>().unwrap();
                for i in 0..rows {
                    for j in 0..k {
                        g[(start + i) * k + j] = gt[i * kt + j] as f64;
                        h[(start + i) * k + j] = ht[i * kt + j] as f64;
                    }
                }
                start = end;
            }
            (g, h)
        }

        fn histogram(
            &self,
            bin_idx: &[u8],
            n: usize,
            d: usize,
            n_bins: usize,
            g: &[f64],
            h: &[f64],
        ) -> (Vec<f64>, Vec<f64>, Vec<u32>) {
            let bt = self.tiles.bins;
            assert!(n_bins <= bt, "n_bins={n_bins} exceeds compiled B={bt}");
            let nt = self.tiles.n_tile;
            let ft = self.tiles.f_tile;
            let mut gh_out = vec![0.0f64; d * n_bins];
            let mut hh_out = vec![0.0f64; d * n_bins];
            let mut ch_out = vec![0u32; d * n_bins];

            let mut row_start = 0usize;
            while row_start < n {
                let row_end = (row_start + nt).min(n);
                let rows = row_end - row_start;
                // ghc tile: (N_TILE, 3) = g, h, count-indicator
                let mut ghc = vec![0.0f32; nt * 3];
                for i in 0..rows {
                    ghc[i * 3] = g[row_start + i] as f32;
                    ghc[i * 3 + 1] = h[row_start + i] as f32;
                    ghc[i * 3 + 2] = 1.0;
                }
                let lgh = xla::Literal::vec1(&ghc).reshape(&[nt as i64, 3]).unwrap();

                let mut f_start = 0usize;
                while f_start < d {
                    let f_end = (f_start + ft).min(d);
                    let fcols = f_end - f_start;
                    let mut bins = vec![0i32; nt * ft];
                    for i in 0..rows {
                        for f in 0..fcols {
                            bins[i * ft + f] = bin_idx[(row_start + i) * d + f_start + f] as i32;
                        }
                    }
                    let lb = xla::Literal::vec1(&bins).reshape(&[nt as i64, ft as i64]).unwrap();
                    let out = self.run("hist", &[lb, lgh.clone()]).expect("hist artifact failed");
                    let tile = out[0].to_vec::<f32>().unwrap(); // (F_TILE, B, 3)
                    for f in 0..fcols {
                        for b in 0..n_bins {
                            let src = (f * bt + b) * 3;
                            let dst = (f_start + f) * n_bins + b;
                            gh_out[dst] += tile[src] as f64;
                            hh_out[dst] += tile[src + 1] as f64;
                            ch_out[dst] += tile[src + 2].round() as u32;
                        }
                    }
                    f_start = f_end;
                }
                row_start = row_end;
            }
            (gh_out, hh_out, ch_out)
        }

        fn gain_scan(
            &self,
            g_cum: &[f64],
            h_cum: &[f64],
            d: usize,
            n_bins: usize,
            g_total: f64,
            h_total: f64,
            lambda: f64,
        ) -> Vec<f64> {
            let bt = self.tiles.bins;
            let ft = self.tiles.f_tile;
            assert!(n_bins <= bt);
            let mut out = vec![0.0f64; d * n_bins];
            let params = xla::Literal::vec1(&[g_total as f32, h_total as f32, lambda as f32]);
            let mut f_start = 0usize;
            while f_start < d {
                let f_end = (f_start + ft).min(d);
                let fcols = f_end - f_start;
                let mut gt = vec![0.0f32; ft * bt];
                // padding features: cum stats equal to totals → gain 0? They
                // compute to parent-vs-parent ≈ 0; sliced off anyway.
                let mut ht = vec![0.0f32; ft * bt];
                for f in 0..fcols {
                    for b in 0..n_bins {
                        gt[f * bt + b] = g_cum[(f_start + f) * n_bins + b] as f32;
                        ht[f * bt + b] = h_cum[(f_start + f) * n_bins + b] as f32;
                    }
                }
                let lg = xla::Literal::vec1(&gt).reshape(&[ft as i64, bt as i64]).unwrap();
                let lh = xla::Literal::vec1(&ht).reshape(&[ft as i64, bt as i64]).unwrap();
                let res = self.run("gain", &[lg, lh, params.clone()]).expect("gain artifact failed");
                let tile = res[0].to_vec::<f32>().unwrap();
                for f in 0..fcols {
                    // the last *logical* bin is never a valid split; leave it 0
                    // (the kernel masks only the last tile bin)
                    for b in 0..n_bins - 1 {
                        out[(f_start + f) * n_bins + b] = tile[f * bt + b] as f64;
                    }
                }
                f_start = f_end;
            }
            out
        }
    }
}
