//! The compute-engine abstraction and the PJRT runtime that executes the
//! AOT-compiled JAX/Pallas artifacts from `artifacts/*.hlo.txt`.
//!
//! The guest's plaintext numeric work (g/h from predictions, histogram
//! aggregation, gain scans) is expressed once in JAX (L2) on top of Pallas
//! kernels (L1), lowered at build time, and executed here through the
//! `xla` crate's PJRT CPU client — Python never runs at training time.

pub mod engine;
pub mod pjrt;
