//! Split gain (paper eq. 6; multi-output eq. 19–20), leaf weights
//! (eq. 7 / 18), and best-split scans over cumulative histograms.

use super::histogram::PlainHistogram;

/// Regularization and structural constraints on splits.
#[derive(Clone, Copy, Debug)]
pub struct GainParams {
    /// L2 leaf regularization λ.
    pub lambda: f64,
    /// Minimum Σh on each side of a split (XGBoost's min_child_weight).
    pub min_child_weight: f64,
    /// Minimum sample count on each side.
    pub min_leaf_samples: u32,
    /// Minimum gain to accept a split.
    pub min_gain: f64,
}

impl Default for GainParams {
    fn default() -> Self {
        GainParams { lambda: 0.1, min_child_weight: 0.0, min_leaf_samples: 2, min_gain: 1e-6 }
    }
}

/// Width-w split gain: ½ Σⱼ [gl²/(hl+λ) + gr²/(hr+λ) − g²/(h+λ)].
/// For w = 1 this is exactly eq. 6; for w = k it equals eq. 19–20 (the
/// parent/child score decomposition).
#[inline]
pub fn gain(
    gl: &[f64],
    hl: &[f64],
    gr: &[f64],
    hr: &[f64],
    gp: &[f64],
    hp: &[f64],
    lambda: f64,
) -> f64 {
    let mut acc = 0.0;
    for j in 0..gl.len() {
        acc += gl[j] * gl[j] / (hl[j] + lambda) + gr[j] * gr[j] / (hr[j] + lambda)
            - gp[j] * gp[j] / (hp[j] + lambda);
    }
    0.5 * acc
}

/// Scalar fast path for binary trees.
#[inline]
pub fn gain_scalar(gl: f64, hl: f64, gr: f64, hr: f64, gp: f64, hp: f64, lambda: f64) -> f64 {
    0.5 * (gl * gl / (hl + lambda) + gr * gr / (hr + lambda) - gp * gp / (hp + lambda))
}

/// Leaf weight(s): `w_j = −Σg / (Σh + λ)` per output (eq. 7 / 18).
pub fn leaf_weight(sum_g: &[f64], sum_h: &[f64], lambda: f64, learning_rate: f64) -> Vec<f64> {
    sum_g
        .iter()
        .zip(sum_h)
        .map(|(&g, &h)| -g / (h + lambda) * learning_rate)
        .collect()
}

/// A candidate split found locally (feature indices are party-local).
#[derive(Clone, Debug, PartialEq)]
pub struct LocalSplit {
    /// Party-local feature index.
    pub feature: u32,
    /// Split bin (`≤ bin` routes left).
    pub bin: u8,
    /// Split gain (eq. 6 / 19).
    pub gain: f64,
    /// Left-side aggregated statistics (the guest needs them to seed the
    /// children's node totals without another pass).
    pub left_g: Vec<f64>,
    /// Left-side Σh per output.
    pub left_h: Vec<f64>,
    /// Left-side sample count.
    pub left_count: u32,
}

/// Scan a *cumulative* histogram for the best split of a node with totals
/// `(gp, hp, count)`. Returns `None` when no split satisfies constraints.
pub fn best_local_split(
    hist: &PlainHistogram,
    gp: &[f64],
    hp: &[f64],
    count: u32,
    params: &GainParams,
) -> Option<LocalSplit> {
    let w = hist.w;
    debug_assert_eq!(gp.len(), w);
    let mut best: Option<LocalSplit> = None;
    let mut gr = vec![0.0; w];
    let mut hr = vec![0.0; w];
    for f in 0..hist.n_features {
        // last bin excluded: splitting there sends everything left
        for b in 0..hist.n_bins.saturating_sub(1) {
            let cell = hist.cell(f, b);
            let lc = hist.count[cell];
            let rc = count - lc;
            if lc < params.min_leaf_samples || rc < params.min_leaf_samples {
                continue;
            }
            let gl = &hist.g[cell * w..(cell + 1) * w];
            let hl = &hist.h[cell * w..(cell + 1) * w];
            let (mut hlt, mut hrt) = (0.0, 0.0);
            for j in 0..w {
                gr[j] = gp[j] - gl[j];
                hr[j] = hp[j] - hl[j];
                hlt += hl[j];
                hrt += hr[j];
            }
            if hlt < params.min_child_weight || hrt < params.min_child_weight {
                continue;
            }
            let g = gain(gl, hl, &gr, &hr, gp, hp, params.lambda);
            if g > params.min_gain && best.as_ref().map(|s| g > s.gain).unwrap_or(true) {
                best = Some(LocalSplit {
                    feature: f as u32,
                    bin: b as u8,
                    gain: g,
                    left_g: gl.to_vec(),
                    left_h: hl.to_vec(),
                    left_count: lc,
                });
            }
        }
    }
    best
}

/// Evaluate one candidate (gl, hl, lc) against node totals — the guest
/// uses this on decrypted host split statistics (Alg. 2 inner loop).
pub fn candidate_gain(
    gl: &[f64],
    hl: &[f64],
    lc: u32,
    gp: &[f64],
    hp: &[f64],
    count: u32,
    params: &GainParams,
) -> Option<f64> {
    let rc = count.checked_sub(lc)?;
    if lc < params.min_leaf_samples || rc < params.min_leaf_samples {
        return None;
    }
    let w = gl.len();
    let mut gr = vec![0.0; w];
    let mut hr = vec![0.0; w];
    let (mut hlt, mut hrt) = (0.0, 0.0);
    for j in 0..w {
        gr[j] = gp[j] - gl[j];
        hr[j] = hp[j] - hl[j];
        hlt += hl[j];
        hrt += hr[j];
    }
    if hlt < params.min_child_weight || hrt < params.min_child_weight {
        return None;
    }
    let g = gain(gl, hl, &gr, &hr, gp, hp, params.lambda);
    (g > params.min_gain).then_some(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::binning::bin_party;
    use crate::data::dataset::PartySlice;

    #[test]
    fn gain_scalar_matches_vector() {
        let g = gain(&[1.5], &[2.0], &[-0.5], &[1.0], &[1.0], &[3.0], 0.5);
        let s = gain_scalar(1.5, 2.0, -0.5, 1.0, 1.0, 3.0, 0.5);
        assert!((g - s).abs() < 1e-12);
    }

    #[test]
    fn perfect_split_has_high_gain() {
        // g = +1 on left half, −1 on right half → splitting at the middle
        // separates them; gain formula must prefer that split.
        // feature 0: value = index (separates), feature 1: constant noise
        let n = 100;
        let x: Vec<f64> = (0..n).flat_map(|i| [i as f64, (i % 7) as f64]).collect();
        let slice = PartySlice { cols: vec![0, 1], x, n };
        let bm = bin_party(&slice, 8);
        let g: Vec<f64> = (0..n).map(|i| if i < n / 2 { 1.0 } else { -1.0 }).collect();
        let h = vec![1.0; n];
        let all: Vec<u32> = (0..n as u32).collect();
        let mut hist = crate::tree::histogram::PlainHistogram::build(&bm, 8, &all, &g, &h, 1);
        hist.cumsum();
        let params = GainParams::default();
        let split = best_local_split(&hist, &[0.0], &[n as f64], n as u32, &params).unwrap();
        assert_eq!(split.feature, 0, "must pick the separating feature");
        assert!(split.gain > 30.0, "gain {}", split.gain);
        // left side is (near-)pure +1: quantile edges need not land exactly
        // on the class boundary, so require ≥90% purity rather than equality
        let purity = split.left_g.iter().sum::<f64>() / split.left_count as f64;
        assert!(purity > 0.9, "left purity {purity}");
    }

    #[test]
    fn constraints_reject() {
        let params = GainParams { min_leaf_samples: 10, ..Default::default() };
        // 5 on the left — rejected
        assert!(candidate_gain(&[1.0], &[1.0], 5, &[0.0], &[2.0], 100, &params).is_none());
        // hessian constraint
        let params2 = GainParams { min_child_weight: 5.0, ..Default::default() };
        assert!(candidate_gain(&[1.0], &[1.0], 50, &[0.0], &[2.0], 100, &params2).is_none());
        // left count exceeding total is invalid
        assert!(candidate_gain(&[1.0], &[1.0], 101, &[0.0], &[2.0], 100, &params).is_none());
    }

    #[test]
    fn leaf_weight_direction() {
        let w = leaf_weight(&[2.0], &[3.0], 1.0, 0.3);
        assert!((w[0] + 0.15).abs() < 1e-12); // −2/4·0.3
        let wm = leaf_weight(&[1.0, -1.0], &[1.0, 1.0], 1.0, 1.0);
        assert_eq!(wm.len(), 2);
        assert!(wm[0] < 0.0 && wm[1] > 0.0);
    }

    #[test]
    fn candidate_gain_matches_scan() {
        // The federated scan (candidate_gain over decrypted stats) must
        // agree with the local scan on identical statistics.
        let n = 64;
        let x: Vec<f64> = (0..n).map(|i| (i * 37 % 64) as f64).collect();
        let slice = PartySlice { cols: vec![0], x, n };
        let bm = bin_party(&slice, 8);
        let g: Vec<f64> = (0..n).map(|i| ((i * 13 % 7) as f64) - 3.0).collect();
        let h = vec![0.5; n];
        let all: Vec<u32> = (0..n as u32).collect();
        let mut hist = crate::tree::histogram::PlainHistogram::build(&bm, 8, &all, &g, &h, 1);
        hist.cumsum();
        let gp: f64 = g.iter().sum();
        let hp: f64 = h.iter().sum();
        let params = GainParams::default();
        let best = best_local_split(&hist, &[gp], &[hp], n as u32, &params).unwrap();
        let cell = hist.cell(best.feature as usize, best.bin as usize);
        let via_candidate = candidate_gain(
            &hist.g[cell..cell + 1],
            &hist.h[cell..cell + 1],
            hist.count[cell],
            &[gp],
            &[hp],
            n as u32,
            &params,
        )
        .unwrap();
        assert!((via_candidate - best.gain).abs() < 1e-12);
    }
}
