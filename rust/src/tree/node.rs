//! Federated decision-tree structure.
//!
//! A node's split either belongs to the guest (feature + bin are known to
//! the guest) or to a host, in which case the guest's copy of the tree
//! stores only an opaque `(party, handle)` — the host privately resolves
//! the handle to its local (feature, bin) pair. This mirrors the paper's
//! split-info shuffling: the guest never learns host feature semantics.

/// Who owns a split and what the owner needs to apply it.
#[derive(Clone, Debug, PartialEq)]
pub enum SplitRef {
    /// Guest-owned: local feature index, bin threshold ("≤ bin → left"),
    /// and the raw-value threshold for unbinned inference.
    Guest { feature: u32, bin: u8, threshold: f64 },
    /// Host-owned: opaque handle into the host's private split table.
    Host { party: u8, handle: u32 },
}

/// One node of a (possibly multi-output) decision tree.
#[derive(Clone, Debug, PartialEq)]
pub struct TreeNode {
    /// Node id (index into the tree's node vector).
    pub id: u32,
    /// Parent id (−1 for the root).
    pub parent: i32,
    /// Left child id (−1 while a leaf).
    pub left: i32,
    /// Right child id (−1 while a leaf).
    pub right: i32,
    /// Depth from the root (root = 0).
    pub depth: u8,
    /// The split applied at this node (`None` = leaf).
    pub split: Option<SplitRef>,
    /// Leaf output(s): 1 value for binary, k for multi-output trees.
    pub weight: Vec<f64>,
    /// Training instances routed through this node.
    pub n_samples: u32,
    /// Σg over member instances (training-time only).
    pub sum_g: Vec<f64>,
    /// Σh over member instances (training-time only).
    pub sum_h: Vec<f64>,
    /// Gain of the applied split (0 for leaves).
    pub gain: f64,
}

impl TreeNode {
    /// A fresh root node with width-`width` statistics.
    pub fn new_root(width: usize) -> Self {
        TreeNode {
            id: 0,
            parent: -1,
            left: -1,
            right: -1,
            depth: 0,
            split: None,
            weight: vec![0.0; width],
            n_samples: 0,
            sum_g: vec![0.0; width],
            sum_h: vec![0.0; width],
            gain: 0.0,
        }
    }

    /// Is this node currently a leaf?
    pub fn is_leaf(&self) -> bool {
        self.split.is_none()
    }
}

/// A grown tree. `width` is the leaf-output dimension (1 or #classes).
#[derive(Clone, Debug, PartialEq)]
pub struct Tree {
    /// Nodes indexed by id (children have larger ids).
    pub nodes: Vec<TreeNode>,
    /// Leaf-output dimension (1 or #classes).
    pub width: usize,
}

impl Tree {
    /// A single-root tree of the given output width.
    pub fn new(width: usize) -> Self {
        Tree { nodes: vec![TreeNode::new_root(width)], width }
    }

    /// Attach two children to `node_id`; returns (left_id, right_id).
    pub fn split_node(&mut self, node_id: u32, split: SplitRef) -> (u32, u32) {
        let depth = self.nodes[node_id as usize].depth;
        let left_id = self.nodes.len() as u32;
        let right_id = left_id + 1;
        let mk = |id: u32| TreeNode {
            id,
            parent: node_id as i32,
            depth: depth + 1,
            ..TreeNode::new_root(self.width)
        };
        self.nodes.push(mk(left_id));
        self.nodes.push(mk(right_id));
        let node = &mut self.nodes[node_id as usize];
        node.split = Some(split);
        node.left = left_id as i32;
        node.right = right_id as i32;
        (left_id, right_id)
    }

    /// Current leaf count.
    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    /// Depth of the deepest node.
    pub fn max_depth(&self) -> u8 {
        self.nodes.iter().map(|n| n.depth).max().unwrap_or(0)
    }

    /// Ids of the current leaves (used by layer-wise growth).
    pub fn leaf_ids(&self) -> Vec<u32> {
        self.nodes.iter().filter(|n| n.is_leaf()).map(|n| n.id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_structure() {
        let mut t = Tree::new(1);
        assert_eq!(t.n_leaves(), 1);
        let (l, r) = t.split_node(
            0,
            SplitRef::Guest { feature: 3, bin: 7, threshold: 1.5 },
        );
        assert_eq!((l, r), (1, 2));
        assert_eq!(t.n_leaves(), 2);
        assert_eq!(t.nodes[0].left, 1);
        assert_eq!(t.nodes[1].parent, 0);
        assert_eq!(t.nodes[1].depth, 1);
        let (l2, _r2) = t.split_node(l, SplitRef::Host { party: 0, handle: 9 });
        assert_eq!(t.nodes[l2 as usize].depth, 2);
        assert_eq!(t.max_depth(), 2);
        assert_eq!(t.leaf_ids(), vec![2, 3, 4]);
    }

    #[test]
    fn multi_output_width() {
        let t = Tree::new(5);
        assert_eq!(t.nodes[0].weight.len(), 5);
        assert_eq!(t.nodes[0].sum_g.len(), 5);
    }
}
