//! Federated inference and model (de)serialization.
//!
//! A trained model is split across parties exactly like the training
//! data: the guest holds tree structures, leaf weights and its own split
//! thresholds; each host holds a private table mapping its split handles
//! to (feature, threshold). Inference routes an instance level by level,
//! asking the owning party for each decision — here the parties are
//! colocated structs, in deployment they are FATE-style services.
//!
//! Serialization is per-party JSON (a host's table never leaves it).

use super::node::{SplitRef, Tree, TreeNode};
use crate::config::json::Json;

/// A host's private share of a model: handle → (local feature, threshold).
#[derive(Clone, Debug, PartialEq)]
pub struct HostModel {
    /// This host's party index.
    pub party: u8,
    /// Indexed by handle: (local feature index, bin, raw-value threshold).
    pub splits: Vec<(u32, u8, f64)>,
}

impl HostModel {
    /// Route one instance: does it go left under `handle`?
    pub fn goes_left(&self, handle: u32, row: &[f64]) -> bool {
        let (feature, _bin, threshold) = self.splits[handle as usize];
        row[feature as usize] <= threshold
    }

    /// Serialize the table (see [`crate::model`] for the envelope).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("party", Json::Num(self.party as f64)),
            (
                "splits",
                Json::Arr(
                    self.splits
                        .iter()
                        .map(|(f, b, t)| {
                            Json::Arr(vec![
                                Json::Num(*f as f64),
                                Json::Num(*b as f64),
                                Json::Num(*t),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Decode a table; structural errors are returned, not panicked.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let party = v.get("party").and_then(Json::as_f64).ok_or("missing party")? as u8;
        let splits = v
            .get("splits")
            .and_then(Json::as_arr)
            .ok_or("missing splits")?
            .iter()
            .map(|row| {
                let a = row.as_arr().ok_or("bad split row")?;
                if a.len() != 3 {
                    return Err("split row must have 3 entries".to_string());
                }
                Ok((
                    a[0].as_f64().ok_or("bad feature")? as u32,
                    a[1].as_f64().ok_or("bad bin")? as u8,
                    a[2].as_f64().ok_or("bad threshold")?,
                ))
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(HostModel { party, splits })
    }
}

/// The guest's share: the boosted trees (host splits are opaque handles).
#[derive(Clone, Debug)]
pub struct GuestModel {
    /// (tree, class): class 0 for binary / multi-output trees.
    pub trees: Vec<(Tree, usize)>,
    /// Number of classes (2 = binary).
    pub n_classes: usize,
    /// Width of a prediction row (1 binary, k multi-class).
    pub pred_width: usize,
}

impl GuestModel {
    /// Predict one instance from raw (unbinned) per-party feature rows.
    /// `guest_row` is the guest's features; `hosts[p]`/`host_rows[p]` the
    /// p-th host's model share and features.
    pub fn predict_row(
        &self,
        guest_row: &[f64],
        hosts: &[HostModel],
        host_rows: &[&[f64]],
    ) -> Vec<f64> {
        let mut out = vec![0.0; self.pred_width];
        for (tree, class) in &self.trees {
            let mut cur = 0usize;
            loop {
                let node: &TreeNode = &tree.nodes[cur];
                match &node.split {
                    None => {
                        if tree.width == 1 {
                            out[*class] += node.weight[0];
                        } else {
                            for (j, &w) in node.weight.iter().enumerate() {
                                out[j] += w;
                            }
                        }
                        break;
                    }
                    Some(SplitRef::Guest { feature, threshold, .. }) => {
                        let left = guest_row[*feature as usize] <= *threshold;
                        cur = if left { node.left as usize } else { node.right as usize };
                    }
                    Some(SplitRef::Host { party, handle }) => {
                        let p = *party as usize;
                        let left = hosts[p].goes_left(*handle, host_rows[p]);
                        cur = if left { node.left as usize } else { node.right as usize };
                    }
                }
            }
        }
        out
    }

    /// Serialize the trees (see [`crate::model`] for the envelope).
    pub fn to_json(&self) -> Json {
        let trees = self
            .trees
            .iter()
            .map(|(t, class)| {
                Json::obj(vec![
                    ("class", Json::Num(*class as f64)),
                    ("width", Json::Num(t.width as f64)),
                    (
                        "nodes",
                        Json::Arr(t.nodes.iter().map(node_to_json).collect()),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("n_classes", Json::Num(self.n_classes as f64)),
            ("pred_width", Json::Num(self.pred_width as f64)),
            ("trees", Json::Arr(trees)),
        ])
    }

    /// Decode trees; structural errors are returned, not panicked.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let n_classes =
            v.get("n_classes").and_then(Json::as_usize).ok_or("missing n_classes")?;
        let pred_width =
            v.get("pred_width").and_then(Json::as_usize).ok_or("missing pred_width")?;
        let trees = v
            .get("trees")
            .and_then(Json::as_arr)
            .ok_or("missing trees")?
            .iter()
            .map(|tv| {
                let class = tv.get("class").and_then(Json::as_usize).ok_or("class")?;
                let width = tv.get("width").and_then(Json::as_usize).ok_or("width")?;
                let nodes = tv
                    .get("nodes")
                    .and_then(Json::as_arr)
                    .ok_or("nodes")?
                    .iter()
                    .map(node_from_json)
                    .collect::<Result<Vec<_>, String>>()?;
                Ok((Tree { nodes, width }, class))
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(GuestModel { trees, n_classes, pred_width })
    }
}

fn node_to_json(n: &TreeNode) -> Json {
    let split = match &n.split {
        None => Json::Null,
        Some(SplitRef::Guest { feature, bin, threshold }) => Json::obj(vec![
            ("kind", Json::Str("guest".into())),
            ("feature", Json::Num(*feature as f64)),
            ("bin", Json::Num(*bin as f64)),
            ("threshold", Json::Num(*threshold)),
        ]),
        Some(SplitRef::Host { party, handle }) => Json::obj(vec![
            ("kind", Json::Str("host".into())),
            ("party", Json::Num(*party as f64)),
            ("handle", Json::Num(*handle as f64)),
        ]),
    };
    Json::obj(vec![
        ("id", Json::Num(n.id as f64)),
        ("parent", Json::Num(n.parent as f64)),
        ("left", Json::Num(n.left as f64)),
        ("right", Json::Num(n.right as f64)),
        ("depth", Json::Num(n.depth as f64)),
        ("split", split),
        ("weight", Json::Arr(n.weight.iter().map(|&w| Json::Num(w)).collect())),
        ("n_samples", Json::Num(n.n_samples as f64)),
        ("gain", Json::Num(n.gain)),
    ])
}

fn node_from_json(v: &Json) -> Result<TreeNode, String> {
    let num = |k: &str| v.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing {k}"));
    let split = match v.get("split") {
        None | Some(Json::Null) => None,
        Some(sv) => match sv.get("kind").and_then(Json::as_str) {
            Some("guest") => Some(SplitRef::Guest {
                feature: sv.get("feature").and_then(Json::as_f64).ok_or("feature")? as u32,
                bin: sv.get("bin").and_then(Json::as_f64).ok_or("bin")? as u8,
                threshold: sv.get("threshold").and_then(Json::as_f64).ok_or("threshold")?,
            }),
            Some("host") => Some(SplitRef::Host {
                party: sv.get("party").and_then(Json::as_f64).ok_or("party")? as u8,
                handle: sv.get("handle").and_then(Json::as_f64).ok_or("handle")? as u32,
            }),
            _ => return Err("bad split kind".into()),
        },
    };
    let weight = v
        .get("weight")
        .and_then(Json::as_arr)
        .ok_or("weight")?
        .iter()
        .map(|w| w.as_f64().ok_or_else(|| "bad weight".to_string()))
        .collect::<Result<Vec<_>, String>>()?;
    Ok(TreeNode {
        id: num("id")? as u32,
        parent: num("parent")? as i32,
        left: num("left")? as i32,
        right: num("right")? as i32,
        depth: num("depth")? as u8,
        split,
        weight,
        n_samples: num("n_samples")? as u32,
        sum_g: Vec::new(), // training-time statistics are not serialized
        sum_h: Vec::new(),
        gain: num("gain")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model() -> (GuestModel, Vec<HostModel>) {
        let mut t = Tree::new(1);
        let (l, _r) = t.split_node(
            0,
            SplitRef::Guest { feature: 0, bin: 3, threshold: 0.5 },
        );
        let (_ll, _lr) = t.split_node(l, SplitRef::Host { party: 0, handle: 1 });
        // leaves: ids 3,4 (under l) and 2 (right of root)
        t.nodes[2].weight = vec![1.0];
        t.nodes[3].weight = vec![2.0];
        t.nodes[4].weight = vec![3.0];
        let guest = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
        let host = HostModel { party: 0, splits: vec![(9, 0, 0.0), (1, 2, -1.0)] };
        (guest, vec![host])
    }

    #[test]
    fn routing_guest_and_host_splits() {
        let (guest, hosts) = toy_model();
        // guest_row[0] > 0.5 → right leaf (weight 1)
        let p = guest.predict_row(&[0.9], &hosts, &[&[0.0, 0.0]]);
        assert_eq!(p, vec![1.0]);
        // guest left, host feature 1 ≤ −1 → left leaf (weight 2)
        let p = guest.predict_row(&[0.1], &hosts, &[&[0.0, -2.0]]);
        assert_eq!(p, vec![2.0]);
        // guest left, host right → weight 3
        let p = guest.predict_row(&[0.1], &hosts, &[&[0.0, 5.0]]);
        assert_eq!(p, vec![3.0]);
    }

    #[test]
    fn json_roundtrip() {
        let (guest, hosts) = toy_model();
        let gj = guest.to_json().to_string_pretty();
        let hj = hosts[0].to_json().to_string_pretty();
        let guest2 = GuestModel::from_json(&Json::parse(&gj).unwrap()).unwrap();
        let host2 = HostModel::from_json(&Json::parse(&hj).unwrap()).unwrap();
        assert_eq!(host2, hosts[0]);
        assert_eq!(guest2.trees.len(), 1);
        // predictions identical after round-trip
        for row in [[0.9f64], [0.1]] {
            for hrow in [[0.0f64, -2.0], [0.0, 5.0]] {
                assert_eq!(
                    guest.predict_row(&row, &hosts, &[&hrow]),
                    guest2.predict_row(&row, &[host2.clone()], &[&hrow]),
                );
            }
        }
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(GuestModel::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(HostModel::from_json(&Json::parse("{\"party\": 0}").unwrap()).is_err());
    }
}
