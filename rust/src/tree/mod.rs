//! Decision-tree machinery: tree structure, plaintext + ciphertext
//! histograms (with subtraction), split gain and split finding.

pub mod histogram;
pub mod node;
pub mod predict;
pub mod split;
