//! Histograms — the computational heart of histogram-based GBDT.
//!
//! Two families:
//! - [`PlainHistogram`] — f64 statistics, used by the guest for its own
//!   features and by the centralized baseline. Generalizes to width-`w`
//!   statistic vectors for multi-output trees.
//! - [`CipherHistogram`] — homomorphic-ciphertext statistics built by
//!   hosts over the guest's encrypted packed gh (paper Alg. 1/5). Cells
//!   hold `n_k` ciphertexts per bin (1 for packed binary, 2 for the
//!   unpacked SecureBoost baseline, ⌈k/η_c⌉ for SecureBoost-MO).
//!
//! Both support the sibling trick (paper §4.3): `sibling = parent − child`
//! per cell, which for ciphertexts replaces `n_sibling` homomorphic adds
//! per feature with `n_bins` subtractions.

use crate::crypto::cipher::{CipherSuite, Ct};
use crate::data::binning::BinnedMatrix;
use crate::data::sparse::SparseBinned;
use crate::util::pool::parallel_for_dynamic;

/// Plaintext histogram: per (feature, bin), Σg / Σh (width-w) and counts.
#[derive(Clone, Debug)]
pub struct PlainHistogram {
    /// Number of features.
    pub n_features: usize,
    /// Bins per feature.
    pub n_bins: usize,
    /// Statistic width (1 = scalar g/h, k = multi-output).
    pub w: usize,
    /// `g[(f*n_bins + b)*w + j]`
    pub g: Vec<f64>,
    /// `h[(f*n_bins + b)*w + j]`
    pub h: Vec<f64>,
    /// Sample count per (feature, bin).
    pub count: Vec<u32>,
}

impl PlainHistogram {
    /// All-zero histogram of the given shape.
    pub fn zeros(n_features: usize, n_bins: usize, w: usize) -> Self {
        PlainHistogram {
            n_features,
            n_bins,
            w,
            g: vec![0.0; n_features * n_bins * w],
            h: vec![0.0; n_features * n_bins * w],
            count: vec![0u32; n_features * n_bins],
        }
    }

    /// Flat (feature, bin) cell index.
    #[inline]
    pub fn cell(&self, f: usize, b: usize) -> usize {
        f * self.n_bins + b
    }

    /// Dense build over the instances of one node.
    pub fn build(
        bm: &BinnedMatrix,
        n_bins: usize,
        instances: &[u32],
        g: &[f64],
        h: &[f64],
        w: usize,
    ) -> Self {
        let mut hist = Self::zeros(bm.d, n_bins, w);
        for &i in instances {
            let i = i as usize;
            let row = bm.row(i);
            for (f, &b) in row.iter().enumerate() {
                let cell = hist.cell(f, b as usize);
                hist.count[cell] += 1;
                let base = cell * w;
                for j in 0..w {
                    hist.g[base + j] += g[i * w + j];
                    hist.h[base + j] += h[i * w + j];
                }
            }
        }
        hist
    }

    /// Sparse-aware build (paper §6.2): only stored entries are visited;
    /// each feature's zero-bin statistics are recovered from the node
    /// totals by subtraction.
    pub fn build_sparse(
        sb: &SparseBinned,
        n_bins: usize,
        instances: &[u32],
        g: &[f64],
        h: &[f64],
        w: usize,
        node_g: &[f64],
        node_h: &[f64],
        node_count: u32,
    ) -> Self {
        let mut hist = Self::zeros(sb.d, n_bins, w);
        for &i in instances {
            let i = i as usize;
            for (f, b) in sb.row(i) {
                let cell = hist.cell(f as usize, b as usize);
                hist.count[cell] += 1;
                let base = cell * w;
                for j in 0..w {
                    hist.g[base + j] += g[i * w + j];
                    hist.h[base + j] += h[i * w + j];
                }
            }
        }
        // zero-bin recovery: whole-node totals minus what this feature saw
        for f in 0..sb.d {
            let (mut fg, mut fh) = (vec![0.0; w], vec![0.0; w]);
            let mut fc = 0u32;
            for b in 0..n_bins {
                let cell = hist.cell(f, b);
                fc += hist.count[cell];
                for j in 0..w {
                    fg[j] += hist.g[cell * w + j];
                    fh[j] += hist.h[cell * w + j];
                }
            }
            let zb = sb.zero_bins[f] as usize;
            let cell = hist.cell(f, zb);
            hist.count[cell] += node_count - fc;
            for j in 0..w {
                hist.g[cell * w + j] += node_g[j] - fg[j];
                hist.h[cell * w + j] += node_h[j] - fh[j];
            }
        }
        hist
    }

    /// `self − other`, elementwise (parent − child = sibling).
    pub fn subtract(&self, child: &PlainHistogram) -> PlainHistogram {
        assert_eq!(self.g.len(), child.g.len());
        let mut out = self.clone();
        for (o, c) in out.g.iter_mut().zip(&child.g) {
            *o -= c;
        }
        for (o, c) in out.h.iter_mut().zip(&child.h) {
            *o -= c;
        }
        for (o, c) in out.count.iter_mut().zip(&child.count) {
            *o -= c;
        }
        out
    }

    /// In-place per-feature prefix sum over bins (paper Alg. 1 cumsum).
    pub fn cumsum(&mut self) {
        for f in 0..self.n_features {
            for b in 1..self.n_bins {
                let prev = self.cell(f, b - 1);
                let cur = self.cell(f, b);
                self.count[cur] += self.count[prev];
                for j in 0..self.w {
                    self.g[cur * self.w + j] = self.g[cur * self.w + j] + self.g[prev * self.w + j];
                    self.h[cur * self.w + j] = self.h[cur * self.w + j] + self.h[prev * self.w + j];
                }
            }
        }
    }
}

/// Ciphertext histogram: per (feature, bin), `n_k` ciphertext slots of
/// aggregated packed gh, plus plaintext sample counts (counts are public
/// in the protocol — the paper shares them via split-info sample_count).
pub struct CipherHistogram {
    /// Number of features.
    pub n_features: usize,
    /// Bins per feature.
    pub n_bins: usize,
    /// Ciphertexts per cell.
    pub n_k: usize,
    /// `cells[(f*n_bins + b)*n_k + j]` — aggregated ciphertexts.
    pub cells: Vec<Ct>,
    /// Sample count per (feature, bin) — plaintext, protocol-public.
    pub count: Vec<u32>,
}

impl CipherHistogram {
    /// All-`Enc(0)` histogram of the given shape.
    pub fn zeros(suite: &CipherSuite, n_features: usize, n_bins: usize, n_k: usize) -> Self {
        CipherHistogram {
            n_features,
            n_bins,
            n_k,
            cells: vec![suite.zero_ct(); n_features * n_bins * n_k],
            count: vec![0u32; n_features * n_bins],
        }
    }

    /// Flat (feature, bin) cell index.
    #[inline]
    pub fn cell(&self, f: usize, b: usize) -> usize {
        f * self.n_bins + b
    }

    /// Dense ciphertext build (paper Alg. 1 / 5). `pos[id]` maps an
    /// instance id to its row in `packed` (the guest ships ciphertexts in
    /// sample order so unsampled instances are never encrypted). Parallel
    /// across features — each feature column accumulates into disjoint
    /// cells.
    pub fn build(
        suite: &CipherSuite,
        bm: &BinnedMatrix,
        n_bins: usize,
        instances: &[u32],
        packed: &[Ct],
        pos: &[u32],
        n_k: usize,
    ) -> Self {
        let mut hist = Self::zeros(suite, bm.d, n_bins, n_k);
        let cells_ptr = SendPtr(hist.cells.as_mut_ptr());
        let count_ptr = SendPtr(hist.count.as_mut_ptr());
        parallel_for_dynamic(bm.d, 1, move |f| {
            let cells_ptr = cells_ptr;
            let count_ptr = count_ptr;
            for &i in instances {
                let i = i as usize;
                let row = pos[i] as usize;
                let b = bm.bin(i, f) as usize;
                let cell = f * n_bins + b;
                // SAFETY: each worker owns feature f's cells exclusively.
                unsafe {
                    *count_ptr.0.add(cell) += 1;
                    for j in 0..n_k {
                        let slot = &mut *cells_ptr.0.add(cell * n_k + j);
                        suite.add_assign(slot, &packed[row * n_k + j]);
                    }
                }
            }
        });
        hist
    }

    /// Sparse-aware ciphertext build: visits only stored entries, then
    /// recovers each feature's zero bin as `node_total − Σ stored bins`
    /// (two homomorphic ops per feature instead of per-instance adds).
    pub fn build_sparse(
        suite: &CipherSuite,
        sb: &SparseBinned,
        n_bins: usize,
        instances: &[u32],
        packed: &[Ct],
        pos: &[u32],
        n_k: usize,
        node_total: &[Ct],
        node_count: u32,
    ) -> Self {
        assert_eq!(node_total.len(), n_k);
        let mut hist = Self::zeros(suite, sb.d, n_bins, n_k);
        // Sparse layout is row-major, so single-threaded accumulation per
        // feature is racy; accumulate per-row instead, locking nothing by
        // chunking rows per worker into thread-local histograms would cost
        // memory (f*b ciphertexts per worker). Entry counts are already
        // ~density × n × d, so we walk rows serially but parallelize the
        // expensive zero-bin recovery + later cumsum instead.
        for &i in instances {
            let i = i as usize;
            let row = pos[i] as usize;
            for (f, b) in sb.row(i) {
                let cell = hist.cell(f as usize, b as usize);
                hist.count[cell] += 1;
                for j in 0..n_k {
                    let idx = cell * n_k + j;
                    // split_at_mut dance not needed: cells[idx] and packed
                    // never alias
                    let slot = &mut hist.cells[idx];
                    suite.add_assign(slot, &packed[row * n_k + j]);
                }
            }
        }
        let zero_bins = &sb.zero_bins;
        let cells_ptr = SendPtr(hist.cells.as_mut_ptr());
        let count_ptr = SendPtr(hist.count.as_mut_ptr());
        let countsnap: Vec<u32> = hist.count.clone();
        parallel_for_dynamic(sb.d, 1, move |f| {
            let cells_ptr = cells_ptr;
            let count_ptr = count_ptr;
            let mut fc = 0u32;
            // Σ over this feature's stored bins (cheap adds), then ONE
            // negation per feature: fsum = total − Σ stored. Negation is
            // the expensive op (~a modular inverse), so it must not run
            // per bin — this is exactly the paper's "two homomorphic
            // additions" claim for sparse recovery (§6.2).
            let mut acc: Vec<Ct> = vec![suite.zero_ct(); n_k];
            for b in 0..n_bins {
                let cell = f * n_bins + b;
                fc += countsnap[cell];
                if countsnap[cell] == 0 {
                    continue;
                }
                unsafe {
                    for (j, a) in acc.iter_mut().enumerate() {
                        let stored = &*cells_ptr.0.add(cell * n_k + j);
                        suite.add_assign(a, stored);
                    }
                }
            }
            let zb = zero_bins[f] as usize;
            let cell = f * n_bins + zb;
            unsafe {
                *count_ptr.0.add(cell) += node_count - fc;
                for (j, a) in acc.into_iter().enumerate() {
                    let fs = suite.sub(&node_total[j], &a);
                    let slot = &mut *cells_ptr.0.add(cell * n_k + j);
                    suite.add_assign(slot, &fs);
                }
            }
        });
        hist
    }

    /// Sibling via homomorphic subtraction (paper §4.3, Figure 2).
    pub fn subtract(&self, suite: &CipherSuite, child: &CipherHistogram) -> CipherHistogram {
        assert_eq!(self.cells.len(), child.cells.len());
        let n_cells = self.cells.len();
        let mut out = CipherHistogram {
            n_features: self.n_features,
            n_bins: self.n_bins,
            n_k: self.n_k,
            cells: vec![suite.zero_ct(); n_cells],
            count: self
                .count
                .iter()
                .zip(&child.count)
                .map(|(p, c)| p - c)
                .collect(),
        };
        let out_ptr = SendPtr(out.cells.as_mut_ptr());
        parallel_for_dynamic(n_cells, 8, move |i| {
            let out_ptr = out_ptr;
            unsafe {
                *out_ptr.0.add(i) = suite.sub(&self.cells[i], &child.cells[i]);
            }
        });
        out
    }

    /// Per-feature ciphertext prefix sums over bins (Alg. 1 cumsum),
    /// parallel across features.
    pub fn cumsum(&mut self, suite: &CipherSuite) {
        let n_bins = self.n_bins;
        let n_k = self.n_k;
        let cells_ptr = SendPtr(self.cells.as_mut_ptr());
        let count_ptr = SendPtr(self.count.as_mut_ptr());
        parallel_for_dynamic(self.n_features, 1, move |f| {
            let cells_ptr = cells_ptr;
            let count_ptr = count_ptr;
            for b in 1..n_bins {
                let prev = f * n_bins + b - 1;
                let cur = f * n_bins + b;
                unsafe {
                    *count_ptr.0.add(cur) += *count_ptr.0.add(prev);
                    for j in 0..n_k {
                        let prev_ct: &Ct = &*cells_ptr.0.add(prev * n_k + j);
                        let slot = &mut *cells_ptr.0.add(cur * n_k + j);
                        suite.add_assign(slot, prev_ct);
                    }
                }
            }
        });
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::packing::GhPacker;
    use crate::data::binning::bin_party;
    use crate::data::dataset::PartySlice;
    use crate::util::rng::{ChaCha20Rng, Xoshiro256};

    fn toy_binned(n: usize, d: usize, seed: u64) -> BinnedMatrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let x: Vec<f64> = (0..n * d).map(|_| rng.next_gaussian()).collect();
        let slice = PartySlice { cols: (0..d).collect(), x, n };
        bin_party(&slice, 8)
    }

    #[test]
    fn plain_build_totals() {
        let bm = toy_binned(200, 4, 1);
        let g: Vec<f64> = (0..200).map(|i| i as f64 * 0.01 - 1.0).collect();
        let h: Vec<f64> = (0..200).map(|i| i as f64 * 0.001).collect();
        let instances: Vec<u32> = (0..200).collect();
        let hist = PlainHistogram::build(&bm, 8, &instances, &g, &h, 1);
        // every feature's bins must sum to the node totals
        let gt: f64 = g.iter().sum();
        let ht: f64 = h.iter().sum();
        for f in 0..4 {
            let fg: f64 = (0..8).map(|b| hist.g[hist.cell(f, b)]).sum();
            let fh: f64 = (0..8).map(|b| hist.h[hist.cell(f, b)]).sum();
            let fc: u32 = (0..8).map(|b| hist.count[hist.cell(f, b)]).sum();
            assert!((fg - gt).abs() < 1e-9);
            assert!((fh - ht).abs() < 1e-9);
            assert_eq!(fc, 200);
        }
    }

    #[test]
    fn plain_subtract_equals_direct() {
        let bm = toy_binned(300, 3, 2);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let g: Vec<f64> = (0..300).map(|_| rng.next_gaussian()).collect();
        let h: Vec<f64> = (0..300).map(|_| rng.next_f64()).collect();
        let all: Vec<u32> = (0..300).collect();
        let left: Vec<u32> = (0..120).collect();
        let right: Vec<u32> = (120..300).collect();
        let hp = PlainHistogram::build(&bm, 8, &all, &g, &h, 1);
        let hl = PlainHistogram::build(&bm, 8, &left, &g, &h, 1);
        let hr_direct = PlainHistogram::build(&bm, 8, &right, &g, &h, 1);
        let hr_sub = hp.subtract(&hl);
        for i in 0..hr_direct.g.len() {
            assert!((hr_sub.g[i] - hr_direct.g[i]).abs() < 1e-9);
            assert!((hr_sub.h[i] - hr_direct.h[i]).abs() < 1e-9);
        }
        assert_eq!(hr_sub.count, hr_direct.count);
    }

    #[test]
    fn plain_cumsum_monotone_counts() {
        let bm = toy_binned(100, 2, 4);
        let g = vec![0.5; 100];
        let h = vec![0.25; 100];
        let all: Vec<u32> = (0..100).collect();
        let mut hist = PlainHistogram::build(&bm, 8, &all, &g, &h, 1);
        hist.cumsum();
        for f in 0..2 {
            assert_eq!(hist.count[hist.cell(f, 7)], 100);
            assert!((hist.g[hist.cell(f, 7)] - 50.0).abs() < 1e-9);
            for b in 1..8 {
                assert!(hist.count[hist.cell(f, b)] >= hist.count[hist.cell(f, b - 1)]);
            }
        }
    }

    #[test]
    fn plain_multi_width() {
        let bm = toy_binned(50, 2, 5);
        let w = 3;
        let mut rng = Xoshiro256::seed_from_u64(6);
        let g: Vec<f64> = (0..50 * w).map(|_| rng.next_gaussian()).collect();
        let h: Vec<f64> = (0..50 * w).map(|_| rng.next_f64()).collect();
        let all: Vec<u32> = (0..50).collect();
        let hist = PlainHistogram::build(&bm, 8, &all, &g, &h, w);
        for j in 0..w {
            let gt: f64 = (0..50).map(|i| g[i * w + j]).sum();
            let fg: f64 = (0..8).map(|b| hist.g[hist.cell(0, b) * w + j]).sum();
            assert!((fg - gt).abs() < 1e-9, "class {j}");
        }
    }

    fn cipher_fixture() -> (CipherSuite, GhPacker, Vec<Ct>, Vec<f64>, Vec<f64>, BinnedMatrix) {
        let mut crng = ChaCha20Rng::from_u64(42);
        let suite = CipherSuite::new_paillier(512, &mut crng);
        let n = 60;
        let mut rng = Xoshiro256::seed_from_u64(7);
        let g: Vec<f64> = (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
        let h: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let packer = GhPacker::plan(&g, &h, n as u64, 40);
        let plains = packer.pack_all(&g, &h);
        let cts = suite.encrypt_batch(&plains, &mut crng);
        let bm = toy_binned(n, 2, 8);
        (suite, packer, cts, g, h, bm)
    }

    fn decrypt_cell(
        suite: &CipherSuite,
        packer: &GhPacker,
        hist: &CipherHistogram,
        f: usize,
        b: usize,
    ) -> (f64, f64) {
        let cell = hist.cell(f, b);
        let d = suite.decrypt(&hist.cells[cell]);
        packer.unpack_sum(&d, hist.count[cell] as u64)
    }

    #[test]
    fn cipher_build_matches_plain() {
        let (suite, packer, cts, g, h, bm) = cipher_fixture();
        let all: Vec<u32> = (0..60).collect();
        let pos: Vec<u32> = (0..60).collect();
        let chist = CipherHistogram::build(&suite, &bm, 8, &all, &cts, &pos, 1);
        let phist = PlainHistogram::build(&bm, 8, &all, &g, &h, 1);
        for f in 0..2 {
            for b in 0..8 {
                let (cg, ch) = decrypt_cell(&suite, &packer, &chist, f, b);
                let cell = phist.cell(f, b);
                assert!((cg - phist.g[cell]).abs() < 1e-6, "f{f} b{b}");
                assert!((ch - phist.h[cell]).abs() < 1e-6);
                assert_eq!(chist.count[cell], phist.count[cell]);
            }
        }
    }

    #[test]
    fn cipher_subtract_matches_direct() {
        let (suite, packer, cts, _g, _h, bm) = cipher_fixture();
        let all: Vec<u32> = (0..60).collect();
        let left: Vec<u32> = (0..25).collect();
        let right: Vec<u32> = (25..60).collect();
        let pos: Vec<u32> = (0..60).collect();
        let hp = CipherHistogram::build(&suite, &bm, 8, &all, &cts, &pos, 1);
        let hl = CipherHistogram::build(&suite, &bm, 8, &left, &cts, &pos, 1);
        let hr_direct = CipherHistogram::build(&suite, &bm, 8, &right, &cts, &pos, 1);
        let hr = hp.subtract(&suite, &hl);
        for f in 0..2 {
            for b in 0..8 {
                let (sg, sh) = decrypt_cell(&suite, &packer, &hr, f, b);
                let (dg, dh) = decrypt_cell(&suite, &packer, &hr_direct, f, b);
                assert!((sg - dg).abs() < 1e-6, "f{f} b{b}: {sg} vs {dg}");
                assert!((sh - dh).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn cipher_cumsum_last_bin_is_total() {
        let (suite, packer, cts, g, h, bm) = cipher_fixture();
        let all: Vec<u32> = (0..60).collect();
        let pos: Vec<u32> = (0..60).collect();
        let mut hist = CipherHistogram::build(&suite, &bm, 8, &all, &cts, &pos, 1);
        hist.cumsum(&suite);
        let gt: f64 = g.iter().sum();
        let ht: f64 = h.iter().sum();
        for f in 0..2 {
            let (cg, ch) = decrypt_cell(&suite, &packer, &hist, f, 7);
            assert!((cg - gt).abs() < 1e-6);
            assert!((ch - ht).abs() < 1e-6);
        }
    }

    #[test]
    fn cipher_sparse_build_matches_dense() {
        use crate::data::sparse::SparseBinned;
        let (suite, packer, cts, _g, _h, bm) = cipher_fixture();
        // mark ~40% of entries "zero" (elide them); zero_bins must absorb
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mask: Vec<bool> = (0..60 * 2).map(|_| rng.next_f64() < 0.4).collect();
        // dense reference: entries counted into their own bins, BUT the
        // sparse path puts elided entries into the feature's zero_bin; to
        // compare we build the dense equivalent with masked bins rewritten.
        let mut bm2 = bm.clone();
        for r in 0..60 {
            for c in 0..2 {
                if mask[r * 2 + c] {
                    bm2.bins[r * 2 + c] = bm.specs[c].zero_bin;
                }
            }
        }
        let all: Vec<u32> = (0..60).collect();
        let pos: Vec<u32> = (0..60).collect();
        let dense_ref = CipherHistogram::build(&suite, &bm2, 8, &all, &cts, &pos, 1);

        let sb = SparseBinned::from_dense(&bm, |r, c| mask[r * 2 + c]);
        // node totals: Σ packed over node instances
        let mut total = suite.zero_ct();
        for i in 0..60 {
            suite.add_assign(&mut total, &cts[i]);
        }
        let sparse =
            CipherHistogram::build_sparse(&suite, &sb, 8, &all, &cts, &pos, 1, &[total], 60);
        for f in 0..2 {
            for b in 0..8 {
                let (sg, sh) = decrypt_cell(&suite, &packer, &sparse, f, b);
                let (dg, dh) = decrypt_cell(&suite, &packer, &dense_ref, f, b);
                assert!((sg - dg).abs() < 1e-6, "f{f} b{b}");
                assert!((sh - dh).abs() < 1e-6);
                assert_eq!(
                    sparse.count[sparse.cell(f, b)],
                    dense_ref.count[dense_ref.cell(f, b)]
                );
            }
        }
    }
}
