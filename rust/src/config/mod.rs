//! Training configuration: hyper-parameters, cipher selection, the
//! paper's optimization toggles (packing / subtraction / compression /
//! GOSS / sparse), and training-mechanism modes (§5).

pub mod json;

use crate::tree::split::GainParams;

/// Which HE schema to use (paper §7.1 benchmarks both).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CipherKind {
    /// Paillier (the paper's default).
    Paillier,
    /// FATE-style iterative affine cipher.
    IterativeAffine,
    /// No encryption — tests & ablation lower bound only.
    Plain,
}

impl CipherKind {
    /// Parse a cipher name from the CLI.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "paillier" => Some(CipherKind::Paillier),
            "iterativeaffine" | "iterative-affine" | "affine" => Some(CipherKind::IterativeAffine),
            "plain" | "none" => Some(CipherKind::Plain),
            _ => None,
        }
    }

    /// Cipher name for logs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            CipherKind::Paillier => "paillier",
            CipherKind::IterativeAffine => "iterative-affine",
            CipherKind::Plain => "plain",
        }
    }
}

/// Training-mechanism mode (paper §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModeKind {
    /// Full federated split finding on every node (SecureBoost+ default).
    Default,
    /// Mix mode: parties take turns building whole trees locally (§5.1).
    Mix { trees_per_party: usize },
    /// Layered mode: hosts build the top `host_depth` layers, the guest
    /// the remaining `guest_depth` (§5.2).
    Layered { guest_depth: u8, host_depth: u8 },
    /// SecureBoost-MO: one multi-output tree per boosting round (§5.3).
    MultiOutput,
}

/// How the guest reaches the host parties.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Hosts run as threads in this process, joined by in-memory channels
    /// (the historical default; byte accounting still uses exact
    /// serialized wire sizes).
    #[default]
    InMemory,
    /// Hosts run as separate processes (`sbp serve-host`); one framed TCP
    /// connection per host, in the order of the host feature slices.
    Tcp {
        /// One `host:port` address per host party.
        hosts: Vec<String>,
    },
}

impl TransportKind {
    /// Transport name for logs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InMemory => "in-memory",
            TransportKind::Tcp { .. } => "tcp",
        }
    }
}

/// GOSS configuration (§6.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GossConfig {
    /// Fraction of instances with the largest |g| always kept.
    pub top_rate: f64,
    /// Uniform sample fraction of the remainder.
    pub other_rate: f64,
}

impl Default for GossConfig {
    fn default() -> Self {
        GossConfig { top_rate: 0.2, other_rate: 0.1 }
    }
}

/// Full training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Boosting rounds (per class for one-vs-all multi-class).
    pub epochs: usize,
    /// Maximum tree depth.
    pub max_depth: u8,
    /// Quantile bins per feature.
    pub max_bin: usize,
    /// Shrinkage applied to leaf weights.
    pub learning_rate: f64,
    /// Split gain constraints and regularization.
    pub gain: GainParams,

    /// Which HE schema encrypts the statistics.
    pub cipher: CipherKind,
    /// HE key length in bits.
    pub key_bits: usize,
    /// Fixed-point precision r (paper eq. 11; default 53).
    pub precision: u32,

    // ---- the paper's cipher-optimization toggles (§4) ----
    /// GH packing (Alg. 3). Off = SecureBoost baseline behaviour
    /// (g and h encrypted separately).
    pub gh_packing: bool,
    /// Ciphertext histogram subtraction (§4.3).
    pub hist_subtraction: bool,
    /// Cipher compressing (Alg. 4/6).
    pub cipher_compression: bool,

    // ---- engineering optimizations (§6) ----
    /// GOSS sampling (§6.1); `None` disables it.
    pub goss: Option<GossConfig>,
    /// Sparse-aware histogram building (§6.2).
    pub sparse_optimization: bool,

    /// Training-mechanism mode (§5).
    pub mode: ModeKind,
    /// Number of host parties.
    pub n_hosts: usize,
    /// How to reach the host parties (in-memory threads or framed TCP).
    pub transport: TransportKind,
    /// Master seed: data generation, GOSS, shuffling, keygen.
    pub seed: u64,
    /// Print per-tree progress.
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self::secureboost_plus()
    }
}

impl TrainConfig {
    /// SecureBoost+ defaults (paper §7.1: depth 5, 32 bins, lr 0.3,
    /// 25 trees, GOSS(0.2, 0.1), all cipher optimizations on).
    pub fn secureboost_plus() -> Self {
        TrainConfig {
            epochs: 25,
            max_depth: 5,
            max_bin: 32,
            learning_rate: 0.3,
            gain: GainParams::default(),
            cipher: CipherKind::Paillier,
            key_bits: 1024,
            precision: 53,
            gh_packing: true,
            hist_subtraction: true,
            cipher_compression: true,
            goss: Some(GossConfig::default()),
            sparse_optimization: true,
            mode: ModeKind::Default,
            n_hosts: 1,
            transport: TransportKind::InMemory,
            seed: 42,
            verbose: false,
        }
    }

    /// The SecureBoost (FATE-1.5) baseline: none of the paper's
    /// optimizations.
    pub fn secureboost_baseline() -> Self {
        TrainConfig {
            gh_packing: false,
            hist_subtraction: false,
            cipher_compression: false,
            goss: None,
            sparse_optimization: false,
            ..Self::secureboost_plus()
        }
    }

    /// Builder-style cipher override.
    pub fn with_cipher(mut self, cipher: CipherKind, key_bits: usize) -> Self {
        self.cipher = cipher;
        self.key_bits = key_bits;
        self
    }

    /// Builder-style mode override.
    pub fn with_mode(mut self, mode: ModeKind) -> Self {
        self.mode = mode;
        self
    }

    /// Builder-style epoch override.
    pub fn with_epochs(mut self, epochs: usize) -> Self {
        self.epochs = epochs;
        self
    }

    /// Validate invariants; returns a description of the first violation.
    pub fn validate(&self) -> Result<(), String> {
        if self.epochs == 0 {
            return Err("epochs must be ≥ 1".into());
        }
        if self.max_depth == 0 || self.max_depth > 16 {
            return Err("max_depth must be in 1..=16".into());
        }
        if !(2..=256).contains(&self.max_bin) {
            return Err("max_bin must be in 2..=256".into());
        }
        if self.cipher_compression && !self.gh_packing {
            return Err("cipher_compression requires gh_packing".into());
        }
        if let Some(g) = &self.goss {
            if g.top_rate <= 0.0 || g.top_rate + g.other_rate > 1.0 {
                return Err("invalid GOSS rates".into());
            }
        }
        if let ModeKind::Layered { guest_depth, host_depth } = self.mode {
            if guest_depth + host_depth != self.max_depth {
                return Err(format!(
                    "layered mode: guest_depth + host_depth ({}) must equal max_depth ({})",
                    guest_depth + host_depth,
                    self.max_depth
                ));
            }
        }
        if self.key_bits < 128 {
            return Err("key_bits too small".into());
        }
        if let TransportKind::Tcp { hosts } = &self.transport {
            if hosts.is_empty() {
                return Err("tcp transport needs at least one host address".into());
            }
            if hosts.len() != self.n_hosts {
                return Err(format!(
                    "tcp transport: {} host addresses but n_hosts = {}",
                    hosts.len(),
                    self.n_hosts
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TrainConfig::secureboost_plus();
        assert_eq!(c.epochs, 25);
        assert_eq!(c.max_depth, 5);
        assert_eq!(c.max_bin, 32);
        assert!((c.learning_rate - 0.3).abs() < 1e-12);
        assert_eq!(c.key_bits, 1024);
        assert_eq!(c.precision, 53);
        assert!(c.gh_packing && c.hist_subtraction && c.cipher_compression);
        let g = c.goss.unwrap();
        assert!((g.top_rate - 0.2).abs() < 1e-12 && (g.other_rate - 0.1).abs() < 1e-12);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn baseline_disables_everything() {
        let c = TrainConfig::secureboost_baseline();
        assert!(!c.gh_packing && !c.hist_subtraction && !c.cipher_compression);
        assert!(c.goss.is_none() && !c.sparse_optimization);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = TrainConfig::secureboost_plus();
        c.cipher_compression = true;
        c.gh_packing = false;
        assert!(c.validate().is_err());

        let mut c = TrainConfig::secureboost_plus();
        c.mode = ModeKind::Layered { guest_depth: 2, host_depth: 2 };
        assert!(c.validate().is_err());
        c.mode = ModeKind::Layered { guest_depth: 2, host_depth: 3 };
        assert!(c.validate().is_ok());

        let mut c = TrainConfig::secureboost_plus();
        c.goss = Some(GossConfig { top_rate: 0.8, other_rate: 0.5 });
        assert!(c.validate().is_err());
    }

    #[test]
    fn tcp_transport_validation() {
        let mut c = TrainConfig::secureboost_plus();
        assert_eq!(c.transport, TransportKind::InMemory);
        c.transport = TransportKind::Tcp { hosts: vec![] };
        assert!(c.validate().is_err());
        c.transport = TransportKind::Tcp {
            hosts: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
        };
        assert!(c.validate().is_err(), "address count must match n_hosts");
        c.n_hosts = 2;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn cipher_parse() {
        assert_eq!(CipherKind::parse("paillier"), Some(CipherKind::Paillier));
        assert_eq!(CipherKind::parse("Iterative-Affine"), Some(CipherKind::IterativeAffine));
        assert_eq!(CipherKind::parse("bogus"), None);
    }
}
