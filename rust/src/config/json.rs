//! A minimal JSON value type with parser and serializer (no `serde` in the
//! offline crate universe). Used for the AOT artifact manifest, config
//! files, and bench result dumps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always carried as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at {}", p.pos));
        }
        Ok(v)
    }

    /// Number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Number value truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.write(out, indent + 1);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    let _ = write!(out, "{pad}\"{k}\": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected character at {}", self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| "invalid utf-8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let arr = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[2].as_str(), Some("x\ny"));
        // serialize → parse → equal
        let again = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(again, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        let v = Json::obj(vec![("n", Json::Num(32.0))]);
        assert!(v.to_string_pretty().contains("\"n\": 32"));
    }
}
