//! A small criterion-style bench harness (criterion is unavailable in the
//! offline crate universe). Provides warmup + repeated measurement with
//! median/mean/σ reporting, and tabular output helpers shared by the
//! `rust/benches/*` targets.

use std::time::Instant;

/// Measurement statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    /// Middle sample.
    pub median: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub stddev: f64,
    /// Fastest sample.
    pub min: f64,
    /// Slowest sample.
    pub max: f64,
    /// Number of measured runs.
    pub runs: usize,
}

impl Stats {
    /// Summarize raw samples (seconds).
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let median = if n % 2 == 1 {
            samples[n / 2]
        } else {
            (samples[n / 2 - 1] + samples[n / 2]) / 2.0
        };
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        Stats { median, mean, stddev: var.sqrt(), min: samples[0], max: samples[n - 1], runs: n }
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `runs` measured ones.
pub fn bench<T>(warmup: usize, runs: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

/// Time a single run (for expensive end-to-end benches where the paper's
/// own protocol is one training run per configuration).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// Format seconds compactly.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Simple fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Print the table with auto-sized columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        let even = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(even.median, 2.5);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(2, 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 7);
        assert_eq!(s.runs, 5);
        assert!(s.min >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
        assert!(fmt_secs(2.5e-5).ends_with("µs"));
        assert!(fmt_secs(2.5e-2).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }
}
