//! Versioned on-disk model artifacts and the model lifecycle.
//!
//! A trained model is *split across parties* exactly like the training
//! data, mirroring the paper's privacy model (SecureBoost §"Federated
//! Inference"; SecureBoost+ inherits the same semi-honest setting):
//!
//! - the **guest artifact** holds everything needed to drive inference —
//!   tree topology, leaf weights, the guest's own split thresholds, the
//!   objective, and binning metadata — but host splits appear only as
//!   opaque `(party, handle)` pairs;
//! - each **host artifact** holds only that host's private lookup table
//!   mapping split handles to its local `(feature, bin, threshold)`
//!   triples. A host artifact reveals nothing about tree structure, leaf
//!   values, labels, or any other party's features.
//!
//! Artifacts are JSON (via [`crate::config::json`]; the offline crate
//! universe has no serde) wrapped in a *versioned envelope*:
//!
//! ```json
//! { "format": "sbp-model", "version": 1, "role": "guest", "payload": { … } }
//! ```
//!
//! ## Version policy
//!
//! [`MODEL_VERSION`] bumps whenever the payload schema changes
//! incompatibly — a field is removed or re-interpreted, the tree-node
//! encoding changes, or split routing semantics change ("≤ threshold goes
//! left"). Adding a new *optional* field does not bump the version.
//! Loaders reject any version other than the one they were built with
//! ([`ModelError::Version`]) instead of guessing: a model file is a
//! contract between the party that saved it and every party that serves
//! it, and silent reinterpretation of split thresholds would corrupt
//! predictions rather than fail loudly.
//!
//! All load paths return [`ModelError`] — corrupted, truncated, or
//! role-mismatched files are errors, never panics (asserted by
//! `tests/model_lifecycle.rs`).

use crate::config::json::Json;
use crate::tree::node::SplitRef;
use crate::tree::predict::{GuestModel, HostModel};
use std::path::Path;

/// Magic string identifying an sbp model file.
pub const MODEL_FORMAT: &str = "sbp-model";

/// Current (and only supported) model format version. See the module
/// docs for what constitutes a version bump.
pub const MODEL_VERSION: u64 = 1;

/// Errors surfaced by model save/load. Structural problems are
/// distinguished from I/O so callers can tell "bad file" from "no file".
#[derive(Debug)]
pub enum ModelError {
    /// Reading or writing the file failed.
    Io(std::io::Error),
    /// The file is not valid JSON (truncated, corrupted, not JSON).
    Parse(String),
    /// The JSON is well-formed but not a valid artifact of the expected
    /// role/schema.
    Format(String),
    /// The envelope declares a version this build does not understand.
    Version {
        /// Version found in the file.
        found: u64,
        /// Version this build supports.
        supported: u64,
    },
    /// The payload does not hash to the envelope's FNV-1a checksum —
    /// the artifact was corrupted or tampered with after it was saved.
    Checksum {
        /// Checksum recorded in the envelope (hex).
        expected: String,
        /// Checksum recomputed from the payload (hex).
        found: String,
    },
    /// The columns a `--data` CSV provides do not match the feature
    /// names this artifact records — scoring would silently bind model
    /// features to the wrong columns, so it is refused up front.
    Schema {
        /// Feature names the artifact records, in model-feature order.
        expected: Vec<String>,
        /// Column names the CSV selection actually provides.
        found: Vec<String>,
    },
}

impl std::fmt::Display for ModelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelError::Io(e) => write!(f, "model i/o: {e}"),
            ModelError::Parse(m) => write!(f, "model file is not valid JSON: {m}"),
            ModelError::Format(m) => write!(f, "malformed model file: {m}"),
            ModelError::Version { found, supported } => write!(
                f,
                "unsupported model format version {found} (this build supports {supported})"
            ),
            ModelError::Checksum { expected, found } => write!(
                f,
                "model payload checksum mismatch: envelope records {expected}, \
                 payload hashes to {found} — the artifact is corrupted"
            ),
            ModelError::Schema { expected, found } => write!(
                f,
                "CSV feature columns do not match the artifact: the model was trained \
                 on features [{}] but the data provides [{}] (fix --features or the \
                 CSV header)",
                expected.join(", "),
                found.join(", ")
            ),
        }
    }
}

impl std::error::Error for ModelError {}

impl From<std::io::Error> for ModelError {
    fn from(e: std::io::Error) -> Self {
        ModelError::Io(e)
    }
}

/// The training objective recorded in the guest artifact, so inference
/// can map raw margins to the right score/probability semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Binary classification with logistic loss; margins are logits.
    BinaryLogistic,
    /// `k`-class classification with softmax cross-entropy; margins are
    /// per-class logits.
    SoftmaxCE {
        /// Number of classes.
        k: usize,
    },
}

impl Objective {
    /// Objective for a dataset with `n_classes` classes.
    pub fn for_classes(n_classes: usize) -> Objective {
        if n_classes == 2 {
            Objective::BinaryLogistic
        } else {
            Objective::SoftmaxCE { k: n_classes }
        }
    }

    fn to_json(self) -> Json {
        match self {
            Objective::BinaryLogistic => Json::obj(vec![(
                "kind",
                Json::Str("binary-logistic".into()),
            )]),
            Objective::SoftmaxCE { k } => Json::obj(vec![
                ("kind", Json::Str("softmax-ce".into())),
                ("k", Json::Num(k as f64)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Objective, ModelError> {
        match v.get("kind").and_then(Json::as_str) {
            Some("binary-logistic") => Ok(Objective::BinaryLogistic),
            Some("softmax-ce") => {
                let k = v
                    .get("k")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| ModelError::Format("softmax objective missing k".into()))?;
                if k < 2 {
                    return Err(ModelError::Format("softmax objective needs k ≥ 2".into()));
                }
                Ok(Objective::SoftmaxCE { k })
            }
            _ => Err(ModelError::Format("unknown or missing objective kind".into())),
        }
    }
}

/// The guest's deployable model share plus the training metadata needed
/// to serve it (see the module docs for the privacy split).
#[derive(Clone, Debug)]
pub struct GuestArtifact {
    /// Trees, leaf weights, and the guest's own split thresholds.
    pub model: GuestModel,
    /// Loss the margins were trained against.
    pub objective: Objective,
    /// Dataset preset the model was trained on (presets are regenerated
    /// deterministically at serve time).
    pub dataset: String,
    /// Number of host parties whose artifacts complement this one.
    pub n_hosts: usize,
    /// Binning metadata: quantile-bin budget used at training time.
    pub max_bin: usize,
    /// Binning metadata: width of the guest's feature slice.
    pub guest_features: usize,
    /// Seed the training preset was generated with — serving regenerates
    /// the same rows from it.
    pub seed: u64,
    /// Instance-count scale the preset was generated at.
    pub scale: f64,
    /// Column names of the guest's features, in model-feature order —
    /// what `sbp predict --data` validates a CSV header against (and
    /// selects by, when `--features` is omitted). **Optional**: legacy
    /// count-only artifacts record `None` and skip the check, so no
    /// version bump.
    pub feature_names: Option<Vec<String>>,
}

/// One host's deployable model share: its private split lookup table
/// (handles → local feature/bin/threshold) plus the preset parameters
/// needed to regenerate its feature slice at serve time — and nothing
/// about trees, leaves, labels, or other parties.
#[derive(Clone, Debug, PartialEq)]
pub struct HostArtifact {
    /// The host's split table keyed by opaque handles.
    pub model: HostModel,
    /// Dataset preset (must match the guest artifact at serve time).
    pub dataset: String,
    /// Width of this host's feature slice (routing sanity check).
    pub n_features: usize,
    /// Number of host parties the training split was generated with.
    pub n_hosts: usize,
    /// Seed the training preset was generated with.
    pub seed: u64,
    /// Instance-count scale the preset was generated at.
    pub scale: f64,
    /// Column names of this host's features, in model-feature order —
    /// what `sbp serve-predict --data` validates a CSV header against.
    /// **Optional** like the guest's (legacy artifacts: `None`).
    pub feature_names: Option<Vec<String>>,
}

/// Seeds are full-range u64; JSON numbers are f64 and would silently
/// round seeds above 2^53, regenerating *different* rows at serve time —
/// so seeds travel as decimal strings.
fn seed_to_json(seed: u64) -> Json {
    Json::Str(seed.to_string())
}

fn get_seed(p: &Json) -> Result<u64, ModelError> {
    p.get("seed")
        .and_then(Json::as_str)
        .and_then(|s| s.parse::<u64>().ok())
        .ok_or_else(|| ModelError::Format("missing or non-integer seed".into()))
}

fn feature_names_json(names: &[String]) -> Json {
    Json::Arr(names.iter().map(|n| Json::Str(n.clone())).collect())
}

/// Decode the optional `feature_names` payload field: absent on legacy
/// count-only artifacts (`Ok(None)`), a list of strings otherwise.
fn get_feature_names(p: &Json) -> Result<Option<Vec<String>>, ModelError> {
    let Some(v) = p.get("feature_names") else {
        return Ok(None);
    };
    let Json::Arr(items) = v else {
        return Err(ModelError::Format("feature_names must be an array".into()));
    };
    let mut names = Vec::with_capacity(items.len());
    for item in items {
        let Some(s) = item.as_str() else {
            return Err(ModelError::Format("feature_names entries must be strings".into()));
        };
        names.push(s.to_string());
    }
    Ok(Some(names))
}

/// Validate a `--data` CSV's selected column names against the feature
/// names an artifact records: model feature `i` must read the column
/// named `recorded[i]`, so the two sequences must match element for
/// element (a permutation would silently bind features to the wrong
/// columns). Legacy count-only artifacts (`recorded = None`) skip the
/// check — the width checks elsewhere still apply.
pub fn check_feature_names(
    recorded: Option<&[String]>,
    selected: &[String],
) -> Result<(), ModelError> {
    let Some(expected) = recorded else {
        return Ok(());
    };
    if expected != selected {
        return Err(ModelError::Schema {
            expected: expected.to_vec(),
            found: selected.to_vec(),
        });
    }
    Ok(())
}

/// FNV-1a 64-bit hash — the artifact integrity checksum. Not a
/// cryptographic MAC: it catches corruption (truncation, bit rot, a
/// hand-edited threshold), not a deliberate adversary, who could simply
/// recompute it. Deterministic across platforms.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Checksum of a payload's canonical serialization. The canonical form
/// is `Json::to_string_pretty` of the payload value: object keys are
/// BTreeMap-sorted and number formatting is the single in-tree
/// serializer, so save-time and load-time serializations agree
/// byte-for-byte.
fn payload_checksum(payload: &Json) -> String {
    format!("{:016x}", fnv1a64(payload.to_string_pretty().as_bytes()))
}

fn envelope(role: &str, payload: Json) -> Json {
    let checksum = payload_checksum(&payload);
    Json::obj(vec![
        ("format", Json::Str(MODEL_FORMAT.into())),
        ("version", Json::Num(MODEL_VERSION as f64)),
        ("role", Json::Str(role.into())),
        ("checksum", Json::Str(checksum)),
        ("payload", payload),
    ])
}

/// Validate the envelope and return the payload.
fn open_envelope<'a>(v: &'a Json, want_role: &str) -> Result<&'a Json, ModelError> {
    let format = v
        .get("format")
        .and_then(Json::as_str)
        .ok_or_else(|| ModelError::Format("missing format field".into()))?;
    if format != MODEL_FORMAT {
        return Err(ModelError::Format(format!("not an sbp model file (format '{format}')")));
    }
    let version = v
        .get("version")
        .and_then(Json::as_f64)
        .ok_or_else(|| ModelError::Format("missing version field".into()))? as u64;
    if version != MODEL_VERSION {
        return Err(ModelError::Version { found: version, supported: MODEL_VERSION });
    }
    let role = v
        .get("role")
        .and_then(Json::as_str)
        .ok_or_else(|| ModelError::Format("missing role field".into()))?;
    if role != want_role {
        return Err(ModelError::Format(format!(
            "artifact role is '{role}', expected '{want_role}'"
        )));
    }
    let payload =
        v.get("payload").ok_or_else(|| ModelError::Format("missing payload".into()))?;
    // checksum is an *optional* envelope field (adding it did not bump
    // the version — pre-checksum artifacts still load), but when present
    // it must match the payload's canonical serialization
    if let Some(expected) = v.get("checksum").and_then(Json::as_str) {
        let found = payload_checksum(payload);
        if expected != found {
            return Err(ModelError::Checksum {
                expected: expected.to_string(),
                found,
            });
        }
    }
    Ok(payload)
}

/// Structural validation of a decoded guest model: every child index in
/// range, every guest feature index within the guest's slice width,
/// every host reference within the declared party count, leaf widths
/// consistent — so a corrupted file fails at load time instead of
/// panicking mid-inference.
fn validate_guest_model(
    m: &GuestModel,
    n_hosts: usize,
    guest_features: usize,
) -> Result<(), ModelError> {
    if m.pred_width == 0 {
        return Err(ModelError::Format("pred_width must be ≥ 1".into()));
    }
    for (ti, (tree, class)) in m.trees.iter().enumerate() {
        if tree.nodes.is_empty() {
            return Err(ModelError::Format(format!("tree {ti} has no nodes")));
        }
        if tree.width == 0 {
            return Err(ModelError::Format(format!("tree {ti} has width 0")));
        }
        if tree.width == 1 && *class >= m.pred_width {
            return Err(ModelError::Format(format!(
                "tree {ti} class {class} out of range for pred_width {}",
                m.pred_width
            )));
        }
        for node in &tree.nodes {
            match &node.split {
                None => {
                    if node.weight.len() != tree.width {
                        return Err(ModelError::Format(format!(
                            "tree {ti} node {} leaf width {} ≠ tree width {}",
                            node.id,
                            node.weight.len(),
                            tree.width
                        )));
                    }
                }
                Some(split) => {
                    let n = tree.nodes.len() as i32;
                    if node.left < 0 || node.left >= n || node.right < 0 || node.right >= n {
                        return Err(ModelError::Format(format!(
                            "tree {ti} node {} has child index out of range",
                            node.id
                        )));
                    }
                    match split {
                        SplitRef::Host { party, .. } => {
                            if (*party as usize) >= n_hosts {
                                return Err(ModelError::Format(format!(
                                    "tree {ti} node {} references host party {party} \
                                     but the artifact declares {n_hosts} host(s)",
                                    node.id
                                )));
                            }
                        }
                        SplitRef::Guest { feature, threshold, .. } => {
                            if (*feature as usize) >= guest_features {
                                return Err(ModelError::Format(format!(
                                    "tree {ti} node {} references guest feature {feature} \
                                     but the guest has {guest_features}",
                                    node.id
                                )));
                            }
                            if threshold.is_nan() {
                                return Err(ModelError::Format(format!(
                                    "tree {ti} node {} has NaN threshold",
                                    node.id
                                )));
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

impl GuestArtifact {
    /// Serialize into the versioned envelope.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", self.model.to_json()),
            ("objective", self.objective.to_json()),
            ("dataset", Json::Str(self.dataset.clone())),
            ("n_hosts", Json::Num(self.n_hosts as f64)),
            ("max_bin", Json::Num(self.max_bin as f64)),
            ("guest_features", Json::Num(self.guest_features as f64)),
            ("seed", seed_to_json(self.seed)),
            ("scale", Json::Num(self.scale)),
        ];
        // optional field: omitted entirely when unknown, so pre-names
        // builds produce byte-identical envelopes (no version bump)
        if let Some(names) = &self.feature_names {
            fields.push(("feature_names", feature_names_json(names)));
        }
        let payload = Json::obj(fields);
        envelope("guest", payload)
    }

    /// Decode and structurally validate a guest artifact.
    pub fn from_json(v: &Json) -> Result<Self, ModelError> {
        let p = open_envelope(v, "guest")?;
        let n_hosts = p
            .get("n_hosts")
            .and_then(Json::as_usize)
            .ok_or_else(|| ModelError::Format("missing n_hosts".into()))?;
        let guest_features = p
            .get("guest_features")
            .and_then(Json::as_usize)
            .ok_or_else(|| ModelError::Format("missing guest_features".into()))?;
        let model_v = p.get("model").ok_or_else(|| ModelError::Format("missing model".into()))?;
        let model = GuestModel::from_json(model_v).map_err(ModelError::Format)?;
        validate_guest_model(&model, n_hosts, guest_features)?;
        let objective = Objective::from_json(
            p.get("objective").ok_or_else(|| ModelError::Format("missing objective".into()))?,
        )?;
        let dataset = p
            .get("dataset")
            .and_then(Json::as_str)
            .ok_or_else(|| ModelError::Format("missing dataset".into()))?
            .to_string();
        let max_bin = p
            .get("max_bin")
            .and_then(Json::as_usize)
            .ok_or_else(|| ModelError::Format("missing max_bin".into()))?;
        let seed = get_seed(p)?;
        let scale = p
            .get("scale")
            .and_then(Json::as_f64)
            .ok_or_else(|| ModelError::Format("missing scale".into()))?;
        if !scale.is_finite() || scale <= 0.0 {
            return Err(ModelError::Format("scale must be finite and positive".into()));
        }
        let feature_names = get_feature_names(p)?;
        if let Some(names) = &feature_names {
            if names.len() != guest_features {
                return Err(ModelError::Format(format!(
                    "feature_names lists {} column(s) but guest_features is {guest_features}",
                    names.len()
                )));
            }
        }
        Ok(GuestArtifact {
            model,
            objective,
            dataset,
            n_hosts,
            max_bin,
            guest_features,
            seed,
            scale,
            feature_names,
        })
    }

    /// Cross-share validation for colocated serving: every `(party,
    /// handle)` the trees reference must exist in the loaded host tables.
    pub fn validate_against_hosts(&self, hosts: &[HostModel]) -> Result<(), ModelError> {
        for (ti, (tree, _)) in self.model.trees.iter().enumerate() {
            for node in &tree.nodes {
                if let Some(SplitRef::Host { party, handle }) = &node.split {
                    let table = hosts.get(*party as usize).ok_or_else(|| {
                        ModelError::Format(format!(
                            "tree {ti} references host party {party} but only {} \
                             host share(s) are loaded",
                            hosts.len()
                        ))
                    })?;
                    if (*handle as usize) >= table.splits.len() {
                        return Err(ModelError::Format(format!(
                            "tree {ti} references handle {handle} of host {party}, \
                             whose table has {} entries",
                            table.splits.len()
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Write the artifact to `path` (pretty-printed JSON).
    pub fn save(&self, path: &Path) -> Result<(), ModelError> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Read and validate an artifact from `path`.
    pub fn load(path: &Path) -> Result<Self, ModelError> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text).map_err(ModelError::Parse)?;
        Self::from_json(&v)
    }

    /// Highest host party index referenced by any tree, plus one
    /// (0 when every split is guest-owned).
    pub fn referenced_hosts(&self) -> usize {
        let mut max: Option<u8> = None;
        for (tree, _) in &self.model.trees {
            for node in &tree.nodes {
                if let Some(SplitRef::Host { party, .. }) = &node.split {
                    max = Some(max.map_or(*party, |m: u8| m.max(*party)));
                }
            }
        }
        max.map(|m| m as usize + 1).unwrap_or(0)
    }
}

impl HostArtifact {
    /// Serialize into the versioned envelope.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", self.model.to_json()),
            ("dataset", Json::Str(self.dataset.clone())),
            ("n_features", Json::Num(self.n_features as f64)),
            ("n_hosts", Json::Num(self.n_hosts as f64)),
            ("seed", seed_to_json(self.seed)),
            ("scale", Json::Num(self.scale)),
        ];
        if let Some(names) = &self.feature_names {
            fields.push(("feature_names", feature_names_json(names)));
        }
        let payload = Json::obj(fields);
        envelope("host", payload)
    }

    /// Decode and structurally validate a host artifact.
    pub fn from_json(v: &Json) -> Result<Self, ModelError> {
        let p = open_envelope(v, "host")?;
        let model_v = p.get("model").ok_or_else(|| ModelError::Format("missing model".into()))?;
        let model = HostModel::from_json(model_v).map_err(ModelError::Format)?;
        let dataset = p
            .get("dataset")
            .and_then(Json::as_str)
            .ok_or_else(|| ModelError::Format("missing dataset".into()))?
            .to_string();
        let n_features = p
            .get("n_features")
            .and_then(Json::as_usize)
            .ok_or_else(|| ModelError::Format("missing n_features".into()))?;
        let n_hosts = p
            .get("n_hosts")
            .and_then(Json::as_usize)
            .ok_or_else(|| ModelError::Format("missing n_hosts".into()))?;
        let seed = get_seed(p)?;
        let scale = p
            .get("scale")
            .and_then(Json::as_f64)
            .ok_or_else(|| ModelError::Format("missing scale".into()))?;
        if !scale.is_finite() || scale <= 0.0 {
            return Err(ModelError::Format("scale must be finite and positive".into()));
        }
        for (i, (f, _b, t)) in model.splits.iter().enumerate() {
            if (*f as usize) >= n_features {
                return Err(ModelError::Format(format!(
                    "split {i} references feature {f} but the host has {n_features}"
                )));
            }
            if t.is_nan() {
                return Err(ModelError::Format(format!("split {i} has NaN threshold")));
            }
        }
        let feature_names = get_feature_names(p)?;
        if let Some(names) = &feature_names {
            if names.len() != n_features {
                return Err(ModelError::Format(format!(
                    "feature_names lists {} column(s) but n_features is {n_features}",
                    names.len()
                )));
            }
        }
        Ok(HostArtifact { model, dataset, n_features, n_hosts, seed, scale, feature_names })
    }

    /// Write the artifact to `path` (pretty-printed JSON).
    pub fn save(&self, path: &Path) -> Result<(), ModelError> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Read and validate an artifact from `path`.
    pub fn load(path: &Path) -> Result<Self, ModelError> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text).map_err(ModelError::Parse)?;
        Self::from_json(&v)
    }
}

/// Canonical artifact file name for the guest share.
pub fn guest_file_name() -> String {
    "guest.model.json".to_string()
}

/// Canonical artifact file name for host party `p`.
pub fn host_file_name(p: usize) -> String {
    format!("host-{p}.model.json")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::node::Tree;

    fn toy_guest() -> GuestArtifact {
        let mut t = Tree::new(1);
        let (l, _r) = t.split_node(0, SplitRef::Guest { feature: 0, bin: 3, threshold: 0.5 });
        t.split_node(l, SplitRef::Host { party: 0, handle: 1 });
        t.nodes[2].weight = vec![1.0];
        t.nodes[3].weight = vec![2.0];
        t.nodes[4].weight = vec![3.0];
        GuestArtifact {
            model: GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 },
            objective: Objective::BinaryLogistic,
            dataset: "toy".into(),
            n_hosts: 1,
            max_bin: 32,
            guest_features: 1,
            seed: 42,
            scale: 0.01,
            feature_names: Some(vec!["f0".into()]),
        }
    }

    #[test]
    fn envelope_roundtrip_guest() {
        let a = toy_guest();
        let text = a.to_json().to_string_pretty();
        let back = GuestArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.dataset, "toy");
        assert_eq!(back.objective, Objective::BinaryLogistic);
        assert_eq!(back.model.trees.len(), 1);
        assert_eq!(back.referenced_hosts(), 1);
    }

    #[test]
    fn envelope_roundtrip_host() {
        let a = HostArtifact {
            model: HostModel { party: 0, splits: vec![(0, 1, 0.25), (1, 2, -3.0)] },
            dataset: "toy".into(),
            n_features: 2,
            n_hosts: 1,
            seed: 42,
            scale: 0.01,
            feature_names: Some(vec!["f3".into(), "f4".into()]),
        };
        let text = a.to_json().to_string_pretty();
        let back = HostArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, a);
        // a legacy count-only artifact (no names) round-trips too
        let legacy = HostArtifact { feature_names: None, ..a };
        let text = legacy.to_json().to_string_pretty();
        assert!(!text.contains("feature_names"), "None must omit the field");
        let back = HostArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, legacy);
    }

    #[test]
    fn feature_name_count_must_match_width() {
        let mut a = toy_guest();
        a.feature_names = Some(vec!["a".into(), "b".into()]); // guest_features = 1
        assert!(matches!(GuestArtifact::from_json(&a.to_json()), Err(ModelError::Format(_))));
    }

    #[test]
    fn check_feature_names_contract() {
        let recorded = vec!["age".to_string(), "income".to_string()];
        assert!(check_feature_names(Some(&recorded), &recorded).is_ok());
        // legacy artifacts skip the check entirely
        assert!(check_feature_names(None, &["whatever".to_string()]).is_ok());
        // a permutation would bind features to the wrong columns
        let swapped = vec!["income".to_string(), "age".to_string()];
        match check_feature_names(Some(&recorded), &swapped) {
            Err(ModelError::Schema { expected, found }) => {
                assert_eq!(expected, recorded);
                assert_eq!(found, swapped);
            }
            other => panic!("expected schema error, got {other:?}"),
        }
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut v = toy_guest().to_json();
        if let Json::Obj(m) = &mut v {
            m.insert("version".into(), Json::Num(99.0));
        }
        match GuestArtifact::from_json(&v) {
            Err(ModelError::Version { found: 99, supported: MODEL_VERSION }) => {}
            other => panic!("expected version error, got {other:?}"),
        }
    }

    #[test]
    fn fnv1a64_known_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn corrupted_payload_fails_checksum() {
        let mut v = toy_guest().to_json();
        if let Json::Obj(m) = &mut v {
            let Some(Json::Obj(p)) = m.get_mut("payload") else {
                panic!("payload must be an object")
            };
            p.insert("max_bin".into(), Json::Num(999.0));
        }
        match GuestArtifact::from_json(&v) {
            Err(ModelError::Checksum { expected, found }) => assert_ne!(expected, found),
            other => panic!("expected checksum error, got {other:?}"),
        }
    }

    #[test]
    fn missing_checksum_still_loads() {
        // pre-checksum artifacts (the field is optional — no version bump)
        let mut v = toy_guest().to_json();
        if let Json::Obj(m) = &mut v {
            assert!(m.remove("checksum").is_some(), "save must record a checksum");
        }
        assert!(GuestArtifact::from_json(&v).is_ok());
    }

    #[test]
    fn role_mismatch_rejected() {
        let v = toy_guest().to_json();
        assert!(matches!(HostArtifact::from_json(&v), Err(ModelError::Format(_))));
    }

    #[test]
    fn out_of_range_children_rejected() {
        let mut a = toy_guest();
        a.model.trees[0].0.nodes[0].left = 40;
        let v = a.to_json();
        assert!(matches!(GuestArtifact::from_json(&v), Err(ModelError::Format(_))));
    }

    #[test]
    fn guest_feature_out_of_range_rejected() {
        let mut a = toy_guest();
        a.guest_features = 0; // trees reference guest feature 0 → reject
        let v = a.to_json();
        assert!(matches!(GuestArtifact::from_json(&v), Err(ModelError::Format(_))));
    }

    #[test]
    fn large_seed_roundtrips_exactly() {
        let mut a = toy_guest();
        a.seed = (1u64 << 53) + 1; // not representable as f64
        let text = a.to_json().to_string_pretty();
        let back = GuestArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.seed, (1u64 << 53) + 1);
    }

    #[test]
    fn host_party_out_of_range_rejected() {
        let mut a = toy_guest();
        a.n_hosts = 0; // trees reference party 0 → must fail to load
        let v = a.to_json();
        assert!(matches!(GuestArtifact::from_json(&v), Err(ModelError::Format(_))));
    }

    #[test]
    fn handle_out_of_range_caught_by_cross_validation() {
        let a = toy_guest(); // references host 0, handle 1
        let short = HostModel { party: 0, splits: vec![(0, 0, 1.0)] };
        assert!(matches!(
            a.validate_against_hosts(std::slice::from_ref(&short)),
            Err(ModelError::Format(_))
        ));
        let ok = HostModel { party: 0, splits: vec![(0, 0, 1.0), (1, 0, 2.0)] };
        assert!(a.validate_against_hosts(std::slice::from_ref(&ok)).is_ok());
    }

    #[test]
    fn host_feature_out_of_range_rejected() {
        let a = HostArtifact {
            model: HostModel { party: 0, splits: vec![(7, 0, 0.0)] },
            dataset: "toy".into(),
            n_features: 2,
            n_hosts: 1,
            seed: 42,
            scale: 0.01,
            feature_names: None,
        };
        let v = a.to_json();
        assert!(matches!(HostArtifact::from_json(&v), Err(ModelError::Format(_))));
    }
}
