//! # SecureBoost+ — vertical federated gradient boosting
//!
//! A from-scratch reproduction of *SecureBoost+: A High Performance Gradient
//! Boosting Tree Framework for Large Scale Vertical Federated Learning*
//! (Chen et al., 2021) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the federated coordinator: guest/host
//!   parties, homomorphic-ciphertext histograms, GH packing, cipher
//!   compressing, split finding, mix/layered/multi-output tree modes,
//!   GOSS, and the boosting driver.
//! - **Layer 2/1 (python/compile)** — the guest's plaintext compute graph
//!   (g/h, histograms, gain scans) authored in JAX + Pallas, AOT-lowered to
//!   HLO text and executed from Rust via PJRT (see [`runtime`]).
//!
//! Python never runs on the training path; `make artifacts` is the only
//! python invocation.
//!
//! ## Transports
//!
//! The federation layer is transport-pluggable
//! ([`federation::transport::GuestTransport`] /
//! [`federation::transport::HostTransport`], selected by
//! [`config::TransportKind`]):
//!
//! - **in-memory** — host parties run as threads joined by mpsc channels
//!   (default; tests and benches);
//! - **framed TCP** — host parties run as separate processes
//!   (`sbp serve-host` ↔ `sbp train-guest`); every message is serialized
//!   through the wire codec in [`federation::codec`].
//!
//! Both charge identical *exact serialized* byte counts per message kind
//! to [`federation::transport::NetCounters`], and both train bit-identical
//! models at the same seed (`tests/federated.rs` parity tests).
//!
//! ## Model lifecycle
//!
//! Trained models outlive the training process: [`model`] defines a
//! versioned per-party artifact format (the guest keeps topology, leaf
//! weights, objective, and binning metadata; each host keeps only its
//! private split table), and [`federation::predict`] serves *federated
//! inference* over the same pluggable transports with batched routing
//! queries. The CLI wires the whole cycle together:
//! `sbp save` → `sbp serve-predict` / `sbp predict`. See
//! `docs/ARCHITECTURE.md` for the message flows.
//!
//! ## Quickstart
//!
//! ```no_run
//! use sbp::prelude::*;
//!
//! let vs = SyntheticSpec::give_credit(0.02).generate_vertical(7, /*hosts=*/ 1);
//! let cfg = TrainConfig::default();
//! let report = train_federated(&vs, &cfg).unwrap();
//! println!("AUC = {:.4}", report.train_metric);
//!
//! // deployable per-party model shares + colocated inference
//! let (guest_model, host_models) = report.model();
//! let preds = predict_centralized(&guest_model, &host_models, &vs);
//! assert_eq!(preds.len(), vs.n() * guest_model.pred_width);
//! ```

#![warn(missing_docs)]

pub mod bench_harness;
pub mod boosting;
pub mod config;
pub mod coordinator;
pub mod crypto;
pub mod data;
pub mod federation;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod tree;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::config::{CipherKind, GossConfig, ModeKind, TrainConfig, TransportKind};
    pub use crate::coordinator::{
        predict_centralized, predict_federated_in_memory, predict_federated_tcp,
        predict_sessions_tcp, serve_predict_tcp, train_centralized, train_federated,
        PredictReport, ServeReport, TrainReport,
    };
    pub use crate::federation::predict::{PredictOptions, PredictSession};
    pub use crate::federation::serve::{CacheStats, ServeConfig};
    pub use crate::crypto::cipher::CipherSuite;
    pub use crate::data::dataset::{Dataset, VerticalSplit};
    pub use crate::data::synthetic::SyntheticSpec;
    pub use crate::metrics::{accuracy_multiclass, auc};
    pub use crate::model::{GuestArtifact, HostArtifact, ModelError, Objective};
    pub use crate::runtime::engine::{ComputeEngine, CpuEngine};
    pub use crate::tree::predict::{GuestModel, HostModel};
}
