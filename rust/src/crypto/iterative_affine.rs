//! The IterativeAffine cipher — the second HE schema shipped by
//! SecureBoost / FATE-1.5 and benchmarked throughout the paper.
//!
//! FATE's scheme applies `r` affine rounds `x ↦ aᵢ·x mod nᵢ`. For the
//! additive homomorphism *and* ciphertext subtraction (needed by the
//! paper's ciphertext histogram subtraction, §4.3) to hold simultaneously,
//! all rounds must share one modulus — with distinct moduli, a negative
//! intermediate difference wraps at the outer modulus and corrupts inner
//! rounds. We therefore compose the rounds over a single odd modulus `n`:
//! the effective key is `a = Π aᵢ mod n` (kept as separate rounds for
//! fidelity to FATE's key format). Like FATE's original, this is a
//! *symmetric, linear* scheme: dramatically faster than Paillier and with
//! correspondingly weaker security guarantees — the paper uses it as the
//! "cheap cipher" point of comparison and so do we.
//!
//! Homomorphic ops: `E(x)+E(y) = E(x+y) mod n`, `k·E(x) = E(k·x) mod n`,
//! `E(x)−E(y) = E(x−y)` when `x ≥ y` (histogram subtraction case).

use super::bigint::BigUint;
use super::prime::gen_prime;
use crate::util::rng::ChaCha20Rng;

/// Number of affine rounds (FATE default).
const DEFAULT_ROUNDS: usize = 3;

/// IterativeAffine key. Symmetric: the guest generates and keeps it; hosts
/// only ever see ciphertexts and the public modulus.
#[derive(Clone, Debug)]
pub struct AffineKey {
    /// Round multipliers a₁..a_r (each coprime with n).
    pub rounds: Vec<BigUint>,
    /// Composite forward multiplier `a = Π aᵢ mod n`.
    a: BigUint,
    /// Composite inverse `a⁻¹ mod n`.
    a_inv: BigUint,
    /// The shared odd modulus.
    pub n: BigUint,
}

/// Public parameters a host needs to operate on ciphertexts.
#[derive(Clone, Debug)]
pub struct AffinePub {
    /// The shared modulus.
    pub n: BigUint,
    /// Modulus bit length.
    pub key_bits: usize,
}

/// IterativeAffine ciphertext: a residue mod n.
pub type AffineCt = BigUint;

impl AffineKey {
    /// Generate a key with a `key_bits`-bit prime modulus.
    pub fn generate(key_bits: usize, rng: &mut ChaCha20Rng) -> Self {
        // A prime modulus guarantees every non-zero aᵢ is invertible.
        let n = gen_prime(key_bits, rng);
        let mut rounds = Vec::with_capacity(DEFAULT_ROUNDS);
        let mut a = BigUint::one();
        for _ in 0..DEFAULT_ROUNDS {
            let ai = loop {
                let c = BigUint::random_below(rng, &n);
                if !c.is_zero() && !c.is_one() {
                    break c;
                }
            };
            a = a.mul_mod(&ai, &n);
            rounds.push(ai);
        }
        let a_inv = a.mod_inverse(&n).expect("a invertible (prime modulus)");
        Self { rounds, a, a_inv, n }
    }

    /// The public parameters a host receives.
    pub fn public(&self) -> AffinePub {
        AffinePub { n: self.n.clone(), key_bits: self.n.bit_length() }
    }

    /// Encrypt: apply every round (equivalent to one multiply by the
    /// composite key; kept explicit for parity with FATE's construction).
    pub fn encrypt(&self, m: &BigUint) -> AffineCt {
        debug_assert!(
            m.bit_length() < self.n.bit_length(),
            "plaintext overflow for affine cipher"
        );
        m.mul_mod(&self.a, &self.n)
    }

    /// Decrypt: multiply by the composite inverse.
    pub fn decrypt(&self, c: &AffineCt) -> BigUint {
        c.mul_mod(&self.a_inv, &self.n)
    }
}

impl AffinePub {
    /// Plaintext capacity ι in bits.
    pub fn plaintext_bits(&self) -> usize {
        self.n.bit_length() - 1
    }

    /// Serialized ciphertext width in bytes.
    pub fn ct_byte_len(&self) -> usize {
        self.n.byte_len()
    }

    /// Homomorphic addition (residue addition mod n).
    #[inline]
    pub fn add(&self, a: &AffineCt, b: &AffineCt) -> AffineCt {
        a.add_mod(b, &self.n)
    }

    /// In-place homomorphic addition.
    #[inline]
    pub fn add_assign(&self, a: &mut AffineCt, b: &AffineCt) {
        *a = a.add_mod(b, &self.n);
    }

    /// Homomorphic scalar multiplication.
    pub fn scalar_mul(&self, c: &AffineCt, k: &BigUint) -> AffineCt {
        c.mul_mod(k, &self.n)
    }

    /// Homomorphic negation (`n − c`).
    pub fn negate(&self, c: &AffineCt) -> AffineCt {
        if c.is_zero() {
            BigUint::zero()
        } else {
            self.n.sub(c)
        }
    }

    /// `a − b` on plaintexts (true difference must be ≥ 0).
    pub fn sub(&self, a: &AffineCt, b: &AffineCt) -> AffineCt {
        a.sub_mod(b, &self.n)
    }

    /// The additive identity.
    pub fn zero_ct(&self) -> AffineCt {
        BigUint::zero()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(seed: u64) -> (AffineKey, AffinePub) {
        let mut rng = ChaCha20Rng::from_u64(seed);
        let key = AffineKey::generate(512, &mut rng);
        let p = key.public();
        (key, p)
    }

    #[test]
    fn roundtrip() {
        let (key, _) = setup(1);
        for v in [0u64, 1, 53, u64::MAX] {
            let m = BigUint::from_u64(v);
            assert_eq!(key.decrypt(&key.encrypt(&m)), m);
        }
    }

    #[test]
    fn composite_equals_rounds() {
        // Applying the rounds one by one must equal the composite multiply.
        let (key, _) = setup(2);
        let m = BigUint::from_u64(123456);
        let mut x = m.clone();
        for a in &key.rounds {
            x = x.mul_mod(a, &key.n);
        }
        assert_eq!(x, key.encrypt(&m));
    }

    #[test]
    fn additive_homomorphism() {
        let (key, p) = setup(3);
        let (a, b) = (BigUint::from_u64(1000), BigUint::from_u64(2345));
        let sum = p.add(&key.encrypt(&a), &key.encrypt(&b));
        assert_eq!(key.decrypt(&sum), BigUint::from_u64(3345));
    }

    #[test]
    fn scalar_and_negate() {
        let (key, p) = setup(4);
        let m = BigUint::from_u64(77);
        let c = key.encrypt(&m);
        assert_eq!(key.decrypt(&p.scalar_mul(&c, &BigUint::from_u64(9))), BigUint::from_u64(693));
        // subtraction with a ≥ b
        let big = key.encrypt(&BigUint::from_u64(100));
        let small = key.encrypt(&BigUint::from_u64(40));
        assert_eq!(key.decrypt(&p.sub(&big, &small)), BigUint::from_u64(60));
        // negate(0) stays 0
        assert_eq!(p.negate(&p.zero_ct()), BigUint::zero());
    }

    #[test]
    fn zero_identity() {
        let (key, p) = setup(5);
        let m = BigUint::from_u64(5);
        let c = key.encrypt(&m);
        assert_eq!(key.decrypt(&p.add(&c, &p.zero_ct())), m);
    }

    #[test]
    fn large_plaintext_near_capacity() {
        let (key, p) = setup(6);
        let mut rng = ChaCha20Rng::from_u64(60);
        let m = BigUint::random_bits(&mut rng, p.plaintext_bits() - 1);
        assert_eq!(key.decrypt(&key.encrypt(&m)), m);
    }
}
