//! GH packing (paper §4.2, Algorithm 3) and multi-class GH packing for
//! SecureBoost-MO (paper §5.3, Algorithms 7–8).
//!
//! Gradients are offset to be non-negative, fixed-point encoded, and the
//! (g, h) pair is bundled into one plaintext integer `gh = (g << b_h) + h`
//! whose bit budget `b_gh = b_g + b_h` is sized so that a histogram-bin
//! *sum over all n instances* cannot overflow (eq. 12–13). One ciphertext
//! then carries both statistics — halving every downstream HE cost.

use super::bigint::BigUint;
use super::encoding::FixedPointEncoder;

/// Plan for packing scalar (binary-task) g/h pairs.
#[derive(Clone, Debug)]
pub struct GhPacker {
    /// Fixed-point encoding of the raw statistics.
    pub enc: FixedPointEncoder,
    /// Offset added to every gradient so it is non-negative.
    pub g_off: f64,
    /// Bits reserved for the aggregated gradient (eq. 13).
    pub b_g: usize,
    /// Bits reserved for the aggregated hessian.
    pub b_h: usize,
    /// Total bits per packed pair.
    pub b_gh: usize,
}

impl GhPacker {
    /// Build a plan from the actual g/h vectors (Algorithm 3 preamble):
    /// `n_bound` is the instance count used for the overflow bound.
    pub fn plan(g: &[f64], h: &[f64], n_bound: u64, precision: u32) -> Self {
        assert!(!g.is_empty() && g.len() == h.len());
        let enc = FixedPointEncoder::new(precision);
        let g_min = g.iter().copied().fold(f64::INFINITY, f64::min);
        let g_off = (-g_min).max(0.0);
        let g_max = g.iter().copied().fold(f64::NEG_INFINITY, f64::max) + g_off;
        let h_max = h.iter().copied().fold(0.0f64, f64::max);
        let b_g = enc.sum_bits(g_max, n_bound);
        let b_h = enc.sum_bits(h_max, n_bound);
        Self { enc, g_off, b_g, b_h, b_gh: b_g + b_h }
    }

    /// Plan with a known loss range (binary logistic: g∈[-1,1], h∈[0,1]) —
    /// lets hosts reproduce the layout without seeing any statistics.
    pub fn plan_logistic(n_bound: u64, precision: u32) -> Self {
        let enc = FixedPointEncoder::new(precision);
        let b_g = enc.sum_bits(2.0, n_bound);
        let b_h = enc.sum_bits(1.0, n_bound);
        Self { enc, g_off: 1.0, b_g, b_h, b_gh: b_g + b_h }
    }

    /// Pack one (g, h) pair (Algorithm 3 body). Rejects values outside
    /// the planned bit budget: a silently overflowing pack would corrupt
    /// every histogram sum it enters, so this is a hard check (two
    /// `bit_length` reads — negligible next to the shift/add).
    pub fn pack(&self, g: f64, h: f64) -> BigUint {
        let ge = self.enc.encode(g + self.g_off);
        let he = self.enc.encode(h.max(0.0));
        assert!(
            ge.bit_length() <= self.b_g && he.bit_length() <= self.b_h,
            "g/h magnitude exceeds the planned packing budget (b_g={}, b_h={})",
            self.b_g,
            self.b_h
        );
        ge.shl(self.b_h).add(&he)
    }

    /// Pack every (g, h) pair of a vector.
    pub fn pack_all(&self, g: &[f64], h: &[f64]) -> Vec<BigUint> {
        g.iter().zip(h).map(|(&gi, &hi)| self.pack(gi, hi)).collect()
    }

    /// Recover the aggregated (Σg, Σh) from a *sum* of `count` packed
    /// values (paper Algorithm 6 inner loop): mask off the hessian bits,
    /// shift for the gradient, then remove the accumulated offset.
    pub fn unpack_sum(&self, v: &BigUint, count: u64) -> (f64, f64) {
        let h = self.enc.decode(&v.low_bits(self.b_h));
        let g_raw = self.enc.decode(&v.shr(self.b_h));
        (g_raw - self.g_off * count as f64, h)
    }
}

/// Multi-class packing plan (SecureBoost-MO, Algorithm 7).
///
/// The per-class (g, h) pairs of one instance are packed `η_c = ⌊ι / b_gh⌋`
/// classes per ciphertext, needing `n_k = ⌈k / η_c⌉` ciphertexts per
/// instance. Cipher compressing is disabled in MO mode (the plaintext
/// space is already full), exactly as in the paper.
#[derive(Clone, Debug)]
pub struct MoPacker {
    /// Per-class scalar packing layout.
    pub base: GhPacker,
    /// Number of classes.
    pub k: usize,
    /// Classes per ciphertext (η_c, eq. 21).
    pub eta_c: usize,
    /// Ciphertexts per instance (n_k, eq. 22).
    pub n_k: usize,
}

impl MoPacker {
    /// `g` and `h` are row-major n×k matrices.
    pub fn plan(
        g: &[f64],
        h: &[f64],
        k: usize,
        n_bound: u64,
        precision: u32,
        plaintext_bits: usize,
    ) -> Self {
        let base = GhPacker::plan(g, h, n_bound, precision);
        let eta_c = (plaintext_bits / base.b_gh).max(1);
        assert!(
            base.b_gh <= plaintext_bits,
            "one class does not fit the plaintext space: b_gh={} > ι={}",
            base.b_gh,
            plaintext_bits
        );
        let eta_c = eta_c.min(k.max(1));
        let n_k = k.div_ceil(eta_c);
        Self { base, k, eta_c, n_k }
    }

    /// Number of classes stored in the `idx`-th ciphertext of an instance.
    pub fn classes_in_ct(&self, idx: usize) -> usize {
        debug_assert!(idx < self.n_k);
        (self.k - idx * self.eta_c).min(self.eta_c)
    }

    /// Pack one instance's g/h vectors (each of length k) into `n_k`
    /// plaintext integers (Algorithm 7 inner loop). The first class of a
    /// chunk lands in the top bits.
    pub fn pack_instance(&self, g_row: &[f64], h_row: &[f64]) -> Vec<BigUint> {
        assert_eq!(g_row.len(), self.k);
        assert_eq!(h_row.len(), self.k);
        let mut out = Vec::with_capacity(self.n_k);
        for chunk in 0..self.n_k {
            let classes = self.classes_in_ct(chunk);
            let mut e = BigUint::zero();
            for s in 0..classes {
                let j = chunk * self.eta_c + s;
                let gh = self.base.pack(g_row[j], h_row[j]);
                e = e.shl(self.base.b_gh).add(&gh);
            }
            out.push(e);
        }
        out
    }

    /// Recover aggregated per-class (Σg, Σh) vectors from decrypted sums
    /// (Algorithm 8). `sums` has length `n_k`; `count` is the number of
    /// instances aggregated into them.
    pub fn unpack_sums(&self, sums: &[BigUint], count: u64) -> (Vec<f64>, Vec<f64>) {
        assert_eq!(sums.len(), self.n_k);
        let mut g = Vec::with_capacity(self.k);
        let mut h = Vec::with_capacity(self.k);
        for (chunk, v) in sums.iter().enumerate() {
            let classes = self.classes_in_ct(chunk);
            for s in 0..classes {
                let shift = self.base.b_gh * (classes - 1 - s);
                let gh = v.shr(shift).low_bits(self.base.b_gh);
                let (gi, hi) = self.base.unpack_sum(&gh, count);
                g.push(gi);
                h.push(hi);
            }
        }
        (g, h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn pack_unpack_single() {
        let p = GhPacker::plan_logistic(1000, 53);
        for (g, h) in [(-1.0, 0.0), (1.0, 1.0), (0.0, 0.25), (-0.37, 0.91)] {
            let v = p.pack(g, h);
            let (gu, hu) = p.unpack_sum(&v, 1);
            assert!((gu - g).abs() < 1e-9, "g {g} -> {gu}");
            assert!((hu - h).abs() < 1e-9, "h {h} -> {hu}");
        }
    }

    #[test]
    fn packed_sums_recover_plain_sums() {
        // The whole point of packing: Σ pack(gᵢ,hᵢ) unpacks to (Σg, Σh).
        let mut rng = Xoshiro256::seed_from_u64(1);
        let n = 5000usize;
        let g: Vec<f64> = (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
        let h: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let p = GhPacker::plan(&g, &h, n as u64, 53);
        let mut acc = BigUint::zero();
        for v in p.pack_all(&g, &h) {
            acc = acc.add(&v);
        }
        let (gs, hs) = p.unpack_sum(&acc, n as u64);
        let (gt, ht) = (g.iter().sum::<f64>(), h.iter().sum::<f64>());
        assert!((gs - gt).abs() < 1e-6, "{gs} vs {gt}");
        assert!((hs - ht).abs() < 1e-6, "{hs} vs {ht}");
        // the aggregate must fit the planned bit budget
        assert!(acc.bit_length() <= p.b_gh);
    }

    #[test]
    fn partial_sums_with_offset_correction() {
        let g = [-0.9, -0.5, 0.3];
        let h = [0.1, 0.2, 0.3];
        let p = GhPacker::plan(&g, &h, 3, 53);
        let packed = p.pack_all(&g, &h);
        let two = packed[0].add(&packed[1]);
        let (gs, hs) = p.unpack_sum(&two, 2);
        assert!((gs - (-1.4)).abs() < 1e-9);
        assert!((hs - 0.3).abs() < 1e-9);
    }

    #[test]
    fn paper_bit_assignment_example() {
        // §4.4: 1M instances, r=53 → b_g=74, b_h=73, b_gh=147; with a
        // 1023-bit plaintext space, η_s = ⌊1023/147⌋ = 6.
        let p = GhPacker::plan_logistic(1_000_000, 53);
        assert_eq!(p.b_g, 74);
        assert_eq!(p.b_h, 73);
        assert_eq!(p.b_gh, 147);
        assert_eq!(1023 / p.b_gh, 6);
    }

    #[test]
    fn mo_pack_roundtrip() {
        let k = 7;
        let n = 100usize;
        let mut rng = Xoshiro256::seed_from_u64(2);
        let g: Vec<f64> = (0..n * k).map(|_| rng.next_f64() - 0.5).collect();
        let h: Vec<f64> = (0..n * k).map(|_| rng.next_f64() * 0.25).collect();
        let p = MoPacker::plan(&g, &h, k, n as u64, 53, 1023);
        assert_eq!(p.eta_c.min(k) * p.n_k >= k, true);

        // aggregate all instances homomorphically in plaintext space
        let mut sums = vec![BigUint::zero(); p.n_k];
        for i in 0..n {
            let row = p.pack_instance(&g[i * k..(i + 1) * k], &h[i * k..(i + 1) * k]);
            for (s, v) in sums.iter_mut().zip(row) {
                *s = s.add(&v);
            }
        }
        let (gs, hs) = p.unpack_sums(&sums, n as u64);
        assert_eq!(gs.len(), k);
        for j in 0..k {
            let gt: f64 = (0..n).map(|i| g[i * k + j]).sum();
            let ht: f64 = (0..n).map(|i| h[i * k + j]).sum();
            assert!((gs[j] - gt).abs() < 1e-6, "class {j}: {} vs {gt}", gs[j]);
            assert!((hs[j] - ht).abs() < 1e-6, "class {j}: {} vs {ht}", hs[j]);
        }
    }

    #[test]
    fn mo_last_chunk_partial() {
        // k not divisible by eta_c → last ciphertext holds fewer classes.
        let k = 11;
        let g: Vec<f64> = vec![0.1; k];
        let h: Vec<f64> = vec![0.2; k];
        // force small plaintext space so eta_c is small
        let p = MoPacker::plan(&g, &h, k, 10, 20, 150);
        assert!(p.n_k > 1);
        let row = p.pack_instance(&g, &h);
        let (gs, hs) = p.unpack_sums(&row, 1);
        for j in 0..k {
            assert!((gs[j] - 0.1).abs() < 1e-4);
            assert!((hs[j] - 0.2).abs() < 1e-4);
        }
    }

    #[test]
    fn all_negative_gradients() {
        let g = [-0.5, -0.9, -0.1];
        let h = [0.5, 0.5, 0.5];
        let p = GhPacker::plan(&g, &h, 3, 53);
        assert!((p.g_off - 0.9).abs() < 1e-12);
        let v = p.pack(g[1], h[1]);
        let (gu, _) = p.unpack_sum(&v, 1);
        assert!((gu - g[1]).abs() < 1e-9);
    }
}
