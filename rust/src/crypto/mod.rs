//! Cryptographic substrate built from scratch (the offline crate universe
//! has no bignum / HE crates).
//!
//! - [`bigint`] — arbitrary-precision unsigned integers (u64 limbs).
//! - [`mont`] — Montgomery modular arithmetic (REDC, windowed modexp).
//! - [`prime`] — Miller–Rabin and random prime generation.
//! - [`paillier`] — the Paillier additively homomorphic cryptosystem.
//! - [`iterative_affine`] — FATE-style iterative affine cipher.
//! - [`cipher`] — the `CipherSuite` abstraction the trainer talks to.
//! - [`encoding`] — fixed-point encoding of gradients/hessians (paper eq. 11).
//! - [`packing`] — GH packing (Alg. 3) and multi-class packing (Alg. 7–8).
//! - [`compress`] — cipher compressing of split statistics (Alg. 4/6).
//! - [`secure`] — serve-protocol v6 session channel: X25519 handshake,
//!   ChaCha20-Poly1305 per-frame AEAD, per-session handle rotation.

pub mod bigint;
pub mod cipher;
pub mod compress;
pub mod encoding;
pub mod iterative_affine;
pub mod mont;
pub mod packing;
pub mod paillier;
pub mod prime;
pub mod secure;
