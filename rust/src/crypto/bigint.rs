//! Arbitrary-precision unsigned integers on u64 limbs (little-endian).
//!
//! Implements exactly what the HE layer needs: add/sub/mul/div-rem, bit
//! shifts and masks, modular arithmetic (incl. inverse and gcd), random
//! sampling, and (de)serialization. Multiplication is schoolbook with a
//! Karatsuba split above [`KARATSUBA_THRESHOLD`] limbs; division is Knuth's
//! Algorithm D. Hot modular exponentiation lives in [`super::mont`].

use crate::util::rng::ChaCha20Rng;
use std::cmp::Ordering;

/// Limb count above which multiplication switches to Karatsuba.
const KARATSUBA_THRESHOLD: usize = 24;

/// Unsigned big integer; `limbs` is little-endian and normalized
/// (no trailing zero limbs; `0` is the empty vector).
#[derive(Clone, PartialEq, Eq, Hash, Default)]
pub struct BigUint {
    pub(crate) limbs: Vec<u64>,
}

impl std::fmt::Debug for BigUint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BigUint(0x{})", self.to_hex())
    }
}

impl BigUint {
    /// The value 0.
    pub fn zero() -> Self {
        Self { limbs: Vec::new() }
    }

    /// The value 1.
    pub fn one() -> Self {
        Self { limbs: vec![1] }
    }

    /// From a u64.
    pub fn from_u64(v: u64) -> Self {
        if v == 0 {
            Self::zero()
        } else {
            Self { limbs: vec![v] }
        }
    }

    /// From a u128.
    pub fn from_u128(v: u128) -> Self {
        let lo = v as u64;
        let hi = (v >> 64) as u64;
        let mut out = Self { limbs: vec![lo, hi] };
        out.normalize();
        out
    }

    /// From little-endian 64-bit limbs (normalized).
    pub fn from_limbs(limbs: Vec<u64>) -> Self {
        let mut out = Self { limbs };
        out.normalize();
        out
    }

    #[inline]
    fn normalize(&mut self) {
        while let Some(&0) = self.limbs.last() {
            self.limbs.pop();
        }
    }

    /// Is this 0?
    #[inline]
    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    /// Is this 1?
    #[inline]
    pub fn is_one(&self) -> bool {
        self.limbs.len() == 1 && self.limbs[0] == 1
    }

    /// Is this even?
    #[inline]
    pub fn is_even(&self) -> bool {
        self.limbs.first().map(|l| l & 1 == 0).unwrap_or(true)
    }

    /// Number of significant bits (0 for zero).
    pub fn bit_length(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&top) => (self.limbs.len() - 1) * 64 + (64 - top.leading_zeros() as usize),
        }
    }

    /// Value of bit `i` (LSB = bit 0).
    pub fn bit(&self, i: usize) -> bool {
        let (limb, off) = (i / 64, i % 64);
        self.limbs.get(limb).map(|l| (l >> off) & 1 == 1).unwrap_or(false)
    }

    /// Lowest 64 bits.
    pub fn low_u64(&self) -> u64 {
        self.limbs.first().copied().unwrap_or(0)
    }

    /// Lowest 128 bits.
    pub fn low_u128(&self) -> u128 {
        let lo = self.low_u64() as u128;
        let hi = self.limbs.get(1).copied().unwrap_or(0) as u128;
        (hi << 64) | lo
    }

    // ---------------------------------------------------------------- cmp

    /// Magnitude comparison.
    pub fn cmp_big(&self, other: &Self) -> Ordering {
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => continue,
                ord => return ord,
            }
        }
        Ordering::Equal
    }

    // ------------------------------------------------------------ add/sub

    /// `self + other`.
    pub fn add(&self, other: &Self) -> Self {
        let (a, b) = if self.limbs.len() >= other.limbs.len() {
            (&self.limbs, &other.limbs)
        } else {
            (&other.limbs, &self.limbs)
        };
        let mut out = Vec::with_capacity(a.len() + 1);
        let mut carry = 0u64;
        for i in 0..a.len() {
            let bi = b.get(i).copied().unwrap_or(0);
            let (s1, c1) = a[i].overflowing_add(bi);
            let (s2, c2) = s1.overflowing_add(carry);
            out.push(s2);
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry > 0 {
            out.push(carry);
        }
        Self::from_limbs(out)
    }

    /// `self + v`.
    pub fn add_u64(&self, v: u64) -> Self {
        self.add(&Self::from_u64(v))
    }

    /// `self - other`; panics if `other > self`.
    pub fn sub(&self, other: &Self) -> Self {
        debug_assert!(self.cmp_big(other) != Ordering::Less, "BigUint::sub underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let bi = other.limbs.get(i).copied().unwrap_or(0);
            let (d1, b1) = self.limbs[i].overflowing_sub(bi);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out.push(d2);
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        Self::from_limbs(out)
    }

    // ---------------------------------------------------------------- mul

    /// `self · other` (schoolbook).
    pub fn mul(&self, other: &Self) -> Self {
        if self.is_zero() || other.is_zero() {
            return Self::zero();
        }
        let n = self.limbs.len().min(other.limbs.len());
        if n >= KARATSUBA_THRESHOLD {
            return self.mul_karatsuba(other);
        }
        self.mul_schoolbook(other)
    }

    fn mul_schoolbook(&self, other: &Self) -> Self {
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            if a == 0 {
                continue;
            }
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let cur = out[i + j] as u128 + a as u128 * b as u128 + carry;
                out[i + j] = cur as u64;
                carry = cur >> 64;
            }
            let mut k = i + other.limbs.len();
            while carry > 0 {
                let cur = out[k] as u128 + carry;
                out[k] = cur as u64;
                carry = cur >> 64;
                k += 1;
            }
        }
        Self::from_limbs(out)
    }

    fn mul_karatsuba(&self, other: &Self) -> Self {
        let half = self.limbs.len().min(other.limbs.len()) / 2;
        let (a0, a1) = self.split_at(half);
        let (b0, b1) = other.split_at(half);
        let z0 = a0.mul(&b0);
        let z2 = a1.mul(&b1);
        let z1 = a0.add(&a1).mul(&b0.add(&b1)).sub(&z0).sub(&z2);
        // result = z2 << (2*half*64) + z1 << (half*64) + z0
        z2.shl_limbs(2 * half).add(&z1.shl_limbs(half)).add(&z0)
    }

    fn split_at(&self, k: usize) -> (Self, Self) {
        if self.limbs.len() <= k {
            (self.clone(), Self::zero())
        } else {
            (
                Self::from_limbs(self.limbs[..k].to_vec()),
                Self::from_limbs(self.limbs[k..].to_vec()),
            )
        }
    }

    fn shl_limbs(&self, k: usize) -> Self {
        if self.is_zero() {
            return Self::zero();
        }
        let mut limbs = vec![0u64; k];
        limbs.extend_from_slice(&self.limbs);
        Self { limbs }
    }

    /// `self · v`.
    pub fn mul_u64(&self, v: u64) -> Self {
        if v == 0 || self.is_zero() {
            return Self::zero();
        }
        let mut out = Vec::with_capacity(self.limbs.len() + 1);
        let mut carry = 0u128;
        for &a in &self.limbs {
            let cur = a as u128 * v as u128 + carry;
            out.push(cur as u64);
            carry = cur >> 64;
        }
        if carry > 0 {
            out.push(carry as u64);
        }
        Self::from_limbs(out)
    }

    /// `self * self` (dedicated squaring is ~2x schoolbook; good enough to
    /// share the mul path — modexp hot loops use Montgomery instead).
    pub fn square(&self) -> Self {
        self.mul(self)
    }

    // --------------------------------------------------------------- shifts

    /// `self << bits`.
    pub fn shl(&self, bits: usize) -> Self {
        if self.is_zero() {
            return Self::zero();
        }
        let limb_shift = bits / 64;
        let bit_shift = bits % 64;
        let mut out = vec![0u64; limb_shift];
        if bit_shift == 0 {
            out.extend_from_slice(&self.limbs);
        } else {
            let mut carry = 0u64;
            for &l in &self.limbs {
                out.push((l << bit_shift) | carry);
                carry = l >> (64 - bit_shift);
            }
            if carry > 0 {
                out.push(carry);
            }
        }
        Self::from_limbs(out)
    }

    /// `self >> bits`.
    pub fn shr(&self, bits: usize) -> Self {
        let limb_shift = bits / 64;
        if limb_shift >= self.limbs.len() {
            return Self::zero();
        }
        let bit_shift = bits % 64;
        let src = &self.limbs[limb_shift..];
        let mut out = Vec::with_capacity(src.len());
        if bit_shift == 0 {
            out.extend_from_slice(src);
        } else {
            for i in 0..src.len() {
                let lo = src[i] >> bit_shift;
                let hi = if i + 1 < src.len() {
                    src[i + 1] << (64 - bit_shift)
                } else {
                    0
                };
                out.push(lo | hi);
            }
        }
        Self::from_limbs(out)
    }

    /// The low `bits` bits of `self` (mask).
    pub fn low_bits(&self, bits: usize) -> Self {
        let full = bits / 64;
        let rem = bits % 64;
        if full >= self.limbs.len() {
            return self.clone();
        }
        let mut limbs = self.limbs[..full].to_vec();
        if rem > 0 {
            limbs.push(self.limbs[full] & ((1u64 << rem) - 1));
        }
        Self::from_limbs(limbs)
    }

    // ------------------------------------------------------------- div/rem

    /// Quotient and remainder. Panics on division by zero.
    pub fn div_rem(&self, divisor: &Self) -> (Self, Self) {
        assert!(!divisor.is_zero(), "division by zero");
        match self.cmp_big(divisor) {
            Ordering::Less => return (Self::zero(), self.clone()),
            Ordering::Equal => return (Self::one(), Self::zero()),
            Ordering::Greater => {}
        }
        if divisor.limbs.len() == 1 {
            let (q, r) = self.div_rem_u64(divisor.limbs[0]);
            return (q, Self::from_u64(r));
        }
        self.div_rem_knuth(divisor)
    }

    /// Quotient and remainder by a u64 divisor.
    pub fn div_rem_u64(&self, d: u64) -> (Self, u64) {
        assert!(d != 0);
        let mut out = vec![0u64; self.limbs.len()];
        let mut rem = 0u128;
        for i in (0..self.limbs.len()).rev() {
            let cur = (rem << 64) | self.limbs[i] as u128;
            out[i] = (cur / d as u128) as u64;
            rem = cur % d as u128;
        }
        (Self::from_limbs(out), rem as u64)
    }

    /// Knuth TAOCP vol.2 Algorithm 4.3.1-D.
    fn div_rem_knuth(&self, divisor: &Self) -> (Self, Self) {
        let n = divisor.limbs.len();
        let shift = divisor.limbs[n - 1].leading_zeros() as usize;
        let v = divisor.shl(shift);
        let mut u = self.shl(shift).limbs;
        u.push(0); // u has m+n+1 limbs
        let m = u.len() - 1 - n;
        let vn1 = v.limbs[n - 1];
        let vn2 = v.limbs[n - 2];
        let mut q = vec![0u64; m + 1];

        for j in (0..=m).rev() {
            let numer = ((u[j + n] as u128) << 64) | u[j + n - 1] as u128;
            let mut qhat = numer / vn1 as u128;
            let mut rhat = numer % vn1 as u128;
            loop {
                if qhat >> 64 != 0
                    || qhat * vn2 as u128 > ((rhat << 64) | u[j + n - 2] as u128)
                {
                    qhat -= 1;
                    rhat += vn1 as u128;
                    if rhat >> 64 == 0 {
                        continue;
                    }
                }
                break;
            }
            // multiply-subtract qhat * v from u[j .. j+n+1]
            let mut borrow = 0i128;
            let mut carry = 0u128;
            for i in 0..n {
                let p = qhat * v.limbs[i] as u128 + carry;
                carry = p >> 64;
                let sub = u[j + i] as i128 - (p as u64) as i128 + borrow;
                u[j + i] = sub as u64;
                borrow = sub >> 64; // arithmetic shift: 0 or -1
            }
            let sub = u[j + n] as i128 - carry as i128 + borrow;
            u[j + n] = sub as u64;
            borrow = sub >> 64;

            if borrow < 0 {
                // qhat was one too large: add v back.
                qhat -= 1;
                let mut carry = 0u128;
                for i in 0..n {
                    let s = u[j + i] as u128 + v.limbs[i] as u128 + carry;
                    u[j + i] = s as u64;
                    carry = s >> 64;
                }
                u[j + n] = u[j + n].wrapping_add(carry as u64);
            }
            q[j] = qhat as u64;
        }

        let rem = Self::from_limbs(u[..n].to_vec()).shr(shift);
        (Self::from_limbs(q), rem)
    }

    /// `self mod m`.
    pub fn rem(&self, m: &Self) -> Self {
        self.div_rem(m).1
    }

    // --------------------------------------------------------- modular ops

    /// `(self + other) mod m` (inputs already reduced).
    pub fn add_mod(&self, other: &Self, m: &Self) -> Self {
        let s = self.add(other);
        if s.cmp_big(m) == Ordering::Less {
            s
        } else {
            s.rem(m)
        }
    }

    /// `(self - other) mod m`, both operands already reduced mod m.
    pub fn sub_mod(&self, other: &Self, m: &Self) -> Self {
        if self.cmp_big(other) != Ordering::Less {
            self.sub(other)
        } else {
            m.add(self).sub(other)
        }
    }

    /// `(self · other) mod m`.
    pub fn mul_mod(&self, other: &Self, m: &Self) -> Self {
        self.mul(other).rem(m)
    }

    /// Modular exponentiation. For odd moduli this delegates to Montgomery;
    /// the general path is square-and-multiply with division-based reduction.
    pub fn mod_pow(&self, exp: &Self, m: &Self) -> Self {
        if m.is_one() {
            return Self::zero();
        }
        if !m.is_even() {
            let ctx = super::mont::MontCtx::new(m.clone());
            return ctx.mod_pow(self, exp);
        }
        let mut base = self.rem(m);
        let mut result = Self::one();
        for i in 0..exp.bit_length() {
            if exp.bit(i) {
                result = result.mul_mod(&base, m);
            }
            if i + 1 < exp.bit_length() {
                base = base.mul_mod(&base, m);
            }
        }
        result
    }

    /// Greatest common divisor (binary GCD).
    pub fn gcd(&self, other: &Self) -> Self {
        let (mut a, mut b) = (self.clone(), other.clone());
        while !b.is_zero() {
            let r = a.rem(&b);
            a = b;
            b = r;
        }
        a
    }

    /// Modular inverse via extended Euclid; `None` if gcd(self, m) != 1.
    pub fn mod_inverse(&self, m: &Self) -> Option<Self> {
        if m.is_zero() || m.is_one() {
            return None;
        }
        // Track Bezout coefficient for `self` with explicit sign.
        let (mut old_r, mut r) = (self.rem(m), m.clone());
        let (mut old_s, mut s) = ((Self::one(), false), (Self::zero(), false));
        if old_r.is_zero() {
            return None;
        }
        while !r.is_zero() {
            let (q, rem) = old_r.div_rem(&r);
            old_r = std::mem::replace(&mut r, rem);
            // new_s = old_s - q * s  (signed arithmetic)
            let qs = q.mul(&s.0);
            let new_s = signed_sub(&old_s, &(qs, s.1));
            old_s = std::mem::replace(&mut s, new_s);
        }
        if !old_r.is_one() {
            return None;
        }
        let (mag, neg) = old_s;
        let red = mag.rem(m);
        Some(if neg && !red.is_zero() { m.sub(&red) } else { red })
    }

    // --------------------------------------------------------------- random

    /// Uniform sample in `[0, bound)`.
    pub fn random_below(rng: &mut ChaCha20Rng, bound: &Self) -> Self {
        assert!(!bound.is_zero());
        let bits = bound.bit_length();
        loop {
            let c = Self::random_bits(rng, bits);
            if c.cmp_big(bound) == Ordering::Less {
                return c;
            }
        }
    }

    /// Uniform sample with at most `bits` bits.
    pub fn random_bits(rng: &mut ChaCha20Rng, bits: usize) -> Self {
        let limbs_n = bits.div_ceil(64);
        let mut limbs = Vec::with_capacity(limbs_n);
        for _ in 0..limbs_n {
            limbs.push(rng.next_u64());
        }
        let extra = limbs_n * 64 - bits;
        if extra > 0 {
            let last = limbs.last_mut().unwrap();
            *last &= u64::MAX >> extra;
        }
        Self::from_limbs(limbs)
    }

    /// Uniform sample with *exactly* `bits` bits (top bit forced to 1).
    pub fn random_exact_bits(rng: &mut ChaCha20Rng, bits: usize) -> Self {
        assert!(bits > 0);
        let mut v = Self::random_bits(rng, bits);
        let top = bits - 1;
        let (limb, off) = (top / 64, top % 64);
        while v.limbs.len() <= limb {
            v.limbs.push(0);
        }
        v.limbs[limb] |= 1u64 << off;
        v.normalize();
        v
    }

    // ----------------------------------------------------------- serialization

    /// Lowercase hex, no leading zeros.
    pub fn to_hex(&self) -> String {
        if self.is_zero() {
            return "0".to_string();
        }
        let mut s = format!("{:x}", self.limbs.last().unwrap());
        for l in self.limbs.iter().rev().skip(1) {
            s.push_str(&format!("{l:016x}"));
        }
        s
    }

    /// Parse lowercase/uppercase hex.
    pub fn from_hex(s: &str) -> Option<Self> {
        let s = s.trim_start_matches("0x");
        if s.is_empty() || !s.chars().all(|c| c.is_ascii_hexdigit()) {
            return None;
        }
        let mut limbs = Vec::with_capacity(s.len().div_ceil(16));
        let bytes = s.as_bytes();
        let mut end = bytes.len();
        while end > 0 {
            let start = end.saturating_sub(16);
            let chunk = std::str::from_utf8(&bytes[start..end]).ok()?;
            limbs.push(u64::from_str_radix(chunk, 16).ok()?);
            end = start;
        }
        Some(Self::from_limbs(limbs))
    }

    /// Big-endian bytes, no leading zeros (empty for 0).
    pub fn to_bytes_be(&self) -> Vec<u8> {
        if self.is_zero() {
            return vec![0];
        }
        let mut out: Vec<u8> = Vec::with_capacity(self.limbs.len() * 8);
        for l in self.limbs.iter().rev() {
            out.extend_from_slice(&l.to_be_bytes());
        }
        let skip = out.iter().take_while(|&&b| b == 0).count();
        out.drain(..skip.min(out.len() - 1));
        out
    }

    /// From big-endian bytes.
    pub fn from_bytes_be(bytes: &[u8]) -> Self {
        let mut limbs = Vec::with_capacity(bytes.len().div_ceil(8));
        let mut chunk_end = bytes.len();
        while chunk_end > 0 {
            let start = chunk_end.saturating_sub(8);
            let mut v = 0u64;
            for &b in &bytes[start..chunk_end] {
                v = (v << 8) | b as u64;
            }
            limbs.push(v);
            chunk_end = start;
        }
        Self::from_limbs(limbs)
    }

    /// Number of bytes this value occupies on the wire (for the transport's
    /// byte accounting).
    pub fn byte_len(&self) -> usize {
        self.bit_length().div_ceil(8).max(1)
    }

    /// Lossy conversion to f64 (used when decoding fixed-point statistics).
    pub fn to_f64(&self) -> f64 {
        match self.limbs.len() {
            0 => 0.0,
            1 => self.limbs[0] as f64,
            2 => self.low_u128() as f64,
            k => {
                // top 128 bits + exponent
                let hi = ((self.limbs[k - 1] as u128) << 64) | self.limbs[k - 2] as u128;
                hi as f64 * 2f64.powi(64 * (k as i32 - 2))
            }
        }
    }
}

/// `a - b` with sign tracking, where operands are `(magnitude, is_negative)`.
fn signed_sub(a: &(BigUint, bool), b: &(BigUint, bool)) -> (BigUint, bool) {
    match (a.1, b.1) {
        // a - b with both positive
        (false, false) => {
            if a.0.cmp_big(&b.0) != Ordering::Less {
                (a.0.sub(&b.0), false)
            } else {
                (b.0.sub(&a.0), true)
            }
        }
        // a - (-b) = a + b
        (false, true) => (a.0.add(&b.0), false),
        // -a - b = -(a + b)
        (true, false) => (a.0.add(&b.0), true),
        // -a - (-b) = b - a
        (true, true) => {
            if b.0.cmp_big(&a.0) != Ordering::Less {
                (b.0.sub(&a.0), false)
            } else {
                (a.0.sub(&b.0), true)
            }
        }
    }
}

impl std::fmt::Display for BigUint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // decimal via repeated division by 10^19
        if self.is_zero() {
            return write!(f, "0");
        }
        let mut parts = Vec::new();
        let mut cur = self.clone();
        while !cur.is_zero() {
            let (q, r) = cur.div_rem_u64(10_000_000_000_000_000_000);
            parts.push(r);
            cur = q;
        }
        write!(f, "{}", parts.pop().unwrap())?;
        for p in parts.iter().rev() {
            write!(f, "{p:019}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn big(v: u128) -> BigUint {
        BigUint::from_u128(v)
    }

    /// Random BigUint with up to `limbs` limbs from a deterministic PRNG.
    fn rand_big(r: &mut Xoshiro256, limbs: usize) -> BigUint {
        let n = r.next_below(limbs) + 1;
        BigUint::from_limbs((0..n).map(|_| r.next_u64()).collect())
    }

    #[test]
    fn add_sub_roundtrip_small() {
        for (a, b) in [(0u128, 0u128), (1, 2), (u64::MAX as u128, 1), (1 << 100, 1 << 90)] {
            let s = big(a).add(&big(b));
            assert_eq!(s, big(a + b));
            assert_eq!(s.sub(&big(b)), big(a));
        }
    }

    #[test]
    fn mul_matches_u128() {
        let mut r = Xoshiro256::seed_from_u64(11);
        for _ in 0..500 {
            let a = r.next_u64() as u128;
            let b = r.next_u64() as u128;
            assert_eq!(big(a).mul(&big(b)), big(a * b));
        }
    }

    #[test]
    fn karatsuba_matches_schoolbook() {
        let mut r = Xoshiro256::seed_from_u64(13);
        for _ in 0..20 {
            let a = rand_big(&mut r, 80);
            let b = rand_big(&mut r, 80);
            assert_eq!(a.mul_schoolbook(&b), a.mul(&b));
        }
    }

    #[test]
    fn div_rem_reconstructs() {
        let mut r = Xoshiro256::seed_from_u64(17);
        for _ in 0..300 {
            let a = rand_big(&mut r, 40);
            let b = rand_big(&mut r, 20);
            if b.is_zero() {
                continue;
            }
            let (q, rem) = a.div_rem(&b);
            assert!(rem.cmp_big(&b) == Ordering::Less);
            assert_eq!(q.mul(&b).add(&rem), a);
        }
    }

    #[test]
    fn div_rem_edge_cases() {
        let a = big(100);
        assert_eq!(a.div_rem(&big(100)), (BigUint::one(), BigUint::zero()));
        assert_eq!(a.div_rem(&big(101)), (BigUint::zero(), a.clone()));
        assert_eq!(a.div_rem(&BigUint::one()), (a.clone(), BigUint::zero()));
        // Knuth-D add-back path is rare; exercise dense bit patterns.
        let x = BigUint::from_limbs(vec![0, 0, 1, u64::MAX, u64::MAX]);
        let y = BigUint::from_limbs(vec![u64::MAX, u64::MAX, 1]);
        let (q, rem) = x.div_rem(&y);
        assert_eq!(q.mul(&y).add(&rem), x);
    }

    #[test]
    fn shifts_roundtrip() {
        let mut r = Xoshiro256::seed_from_u64(19);
        for _ in 0..200 {
            let a = rand_big(&mut r, 10);
            let k = r.next_below(200);
            assert_eq!(a.shl(k).shr(k), a);
        }
        assert_eq!(big(0b1011).shr(2), big(0b10));
    }

    #[test]
    fn low_bits_mask() {
        let v = big(0xDEAD_BEEF_CAFE_BABE_1234_5678u128);
        assert_eq!(v.low_bits(16), big(0x5678));
        assert_eq!(v.low_bits(64), big(0xCAFE_BABE_1234_5678u128));
        assert_eq!(v.low_bits(200), v);
    }

    #[test]
    fn hex_roundtrip() {
        let mut r = Xoshiro256::seed_from_u64(23);
        for _ in 0..100 {
            let a = rand_big(&mut r, 8);
            assert_eq!(BigUint::from_hex(&a.to_hex()).unwrap(), a);
        }
        assert_eq!(BigUint::from_hex("0").unwrap(), BigUint::zero());
        assert!(BigUint::from_hex("xyz").is_none());
    }

    #[test]
    fn bytes_roundtrip() {
        let mut r = Xoshiro256::seed_from_u64(29);
        for _ in 0..100 {
            let a = rand_big(&mut r, 8);
            assert_eq!(BigUint::from_bytes_be(&a.to_bytes_be()), a);
        }
    }

    #[test]
    fn display_decimal() {
        assert_eq!(big(0).to_string(), "0");
        assert_eq!(big(1234567890123456789012345678u128).to_string(), "1234567890123456789012345678");
    }

    #[test]
    fn mod_pow_small_vectors() {
        // 4^13 mod 497 = 445
        assert_eq!(big(4).mod_pow(&big(13), &big(497)), big(445));
        // even modulus path
        assert_eq!(big(3).mod_pow(&big(7), &big(100)), big(87));
        // exponent zero
        assert_eq!(big(7).mod_pow(&BigUint::zero(), &big(13)), BigUint::one());
    }

    #[test]
    fn mod_pow_matches_naive_random() {
        let mut r = Xoshiro256::seed_from_u64(31);
        for _ in 0..50 {
            let base = (r.next_u64() % 1000) as u128;
            let exp = (r.next_u64() % 50) as u32;
            let m = (r.next_u64() % 999 + 2) as u128;
            let naive = {
                let mut acc: u128 = 1;
                for _ in 0..exp {
                    acc = acc * base % m;
                }
                acc
            };
            assert_eq!(
                big(base).mod_pow(&big(exp as u128), &big(m)),
                big(naive),
                "base={base} exp={exp} m={m}"
            );
        }
    }

    #[test]
    fn gcd_and_inverse() {
        assert_eq!(big(12).gcd(&big(18)), big(6));
        assert_eq!(big(17).mod_inverse(&big(3120)).unwrap(), big(2753));
        assert!(big(6).mod_inverse(&big(9)).is_none());
        let mut r = Xoshiro256::seed_from_u64(37);
        for _ in 0..100 {
            let m = rand_big(&mut r, 6);
            if m.cmp_big(&big(2)) == Ordering::Less {
                continue;
            }
            let a = rand_big(&mut r, 6).rem(&m);
            if let Some(inv) = a.mod_inverse(&m) {
                assert_eq!(a.mul_mod(&inv, &m), BigUint::one());
            }
        }
    }

    #[test]
    fn random_below_in_range() {
        let mut rng = ChaCha20Rng::from_u64(7);
        let bound = BigUint::from_hex("ffffffffffffffffffffffff").unwrap();
        for _ in 0..100 {
            let v = BigUint::random_below(&mut rng, &bound);
            assert!(v.cmp_big(&bound) == Ordering::Less);
        }
    }

    #[test]
    fn random_exact_bits_has_top_bit() {
        let mut rng = ChaCha20Rng::from_u64(8);
        for bits in [1usize, 5, 64, 65, 512] {
            let v = BigUint::random_exact_bits(&mut rng, bits);
            assert_eq!(v.bit_length(), bits);
        }
    }

    #[test]
    fn sub_mod_wraps() {
        let m = big(97);
        assert_eq!(big(5).sub_mod(&big(10), &m), big(92));
        assert_eq!(big(10).sub_mod(&big(5), &m), big(5));
    }
}
