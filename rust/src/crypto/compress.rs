//! Cipher compressing (paper §4.4, Algorithms 4 and 6).
//!
//! GH packing leaves most of the plaintext space unused (b_gh ≈ 147 bits
//! inside a 1023-bit Paillier plaintext). Hosts therefore shift-and-add
//! `η_s = ⌊ι / b_gh⌋` split-statistics into a single ciphertext before
//! returning them: one decryption then recovers up to η_s split-infos,
//! cutting both decryption count and transfer volume by η_s×.

use super::cipher::{CipherSuite, Ct};
use super::packing::GhPacker;

/// Compression parameters the guest derives and broadcasts (§4.5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompressPlan {
    /// Split-stats per ciphertext (η_s); 1 disables compression.
    pub capacity: usize,
    /// Bits per packed statistic (b_gh).
    pub b_gh: usize,
}

impl CompressPlan {
    /// Fill the plaintext space: `η_s = ⌊ι / b_gh⌋` (eq. 14).
    pub fn derive(plaintext_bits: usize, b_gh: usize) -> Self {
        Self { capacity: (plaintext_bits / b_gh).max(1), b_gh }
    }

    /// A disabled plan (used by the SecureBoost baseline and MO mode).
    pub fn disabled(b_gh: usize) -> Self {
        Self { capacity: 1, b_gh }
    }
}

/// One host-side split statistic prior to compression: the ciphertext of
/// the left-side packed Σgh, the (shuffled) split id, and the left-side
/// sample count the guest needs for the offset correction.
#[derive(Clone, Debug)]
pub struct SplitStatCt {
    /// Ciphertext of the left-side packed Σgh.
    pub ct: Ct,
    /// Shuffled split-info id (the host's split handle).
    pub id: u32,
    /// Left-side sample count (public in the protocol).
    pub sample_count: u32,
}

/// A compressed package: one ciphertext carrying ≤ η_s statistics
/// (most-significant = first pushed), plus their ids and counts.
#[derive(Clone, Debug, PartialEq)]
pub struct CtPackage {
    /// One ciphertext carrying ≤ η_s shifted statistics.
    pub ct: Ct,
    /// Split ids, most-significant slot first.
    pub ids: Vec<u32>,
    /// Left-side sample counts, aligned with `ids`.
    pub counts: Vec<u32>,
}

/// Host side (Algorithm 4): fold split statistics into packages.
pub fn compress(suite: &CipherSuite, plan: &CompressPlan, stats: &[SplitStatCt]) -> Vec<CtPackage> {
    let mut out = Vec::with_capacity(stats.len().div_ceil(plan.capacity));
    let mut iter = stats.iter().peekable();
    while iter.peek().is_some() {
        let mut ids = Vec::with_capacity(plan.capacity);
        let mut counts = Vec::with_capacity(plan.capacity);
        let mut acc: Option<Ct> = None;
        for _ in 0..plan.capacity {
            let Some(s) = iter.next() else { break };
            acc = Some(match acc {
                None => s.ct.clone(),
                Some(e) => {
                    // e <<= b_gh ; e += ct  (pure-squaring shift)
                    let shifted = suite.scalar_pow2(&e, plan.b_gh);
                    suite.add(&shifted, &s.ct)
                }
            });
            ids.push(s.id);
            counts.push(s.sample_count);
        }
        out.push(CtPackage { ct: acc.expect("non-empty package"), ids, counts });
    }
    out
}

/// One recovered split statistic on the guest.
#[derive(Clone, Debug, PartialEq)]
pub struct SplitStatPlain {
    /// Split id (host split handle).
    pub id: u32,
    /// Left-side sample count.
    pub sample_count: u32,
    /// Recovered left-side Σg (offset removed).
    pub g_sum: f64,
    /// Recovered left-side Σh.
    pub h_sum: f64,
}

/// Guest side (Algorithm 6): decrypt each package and peel off the packed
/// statistics, correcting each gradient sum by `g_off · sample_count`.
pub fn decompress(
    suite: &CipherSuite,
    plan: &CompressPlan,
    packer: &GhPacker,
    packages: &[CtPackage],
) -> Vec<SplitStatPlain> {
    let cts: Vec<Ct> = packages.iter().map(|p| p.ct.clone()).collect();
    let plains = suite.decrypt_batch(&cts);
    let mut out = Vec::new();
    for (pkg, d) in packages.iter().zip(plains) {
        let eta = pkg.ids.len();
        debug_assert!(eta <= plan.capacity);
        for (s, (&id, &count)) in pkg.ids.iter().zip(&pkg.counts).enumerate() {
            // first-pushed statistic sits in the top bits
            let shift = plan.b_gh * (eta - 1 - s);
            let gh = d.shr(shift).low_bits(plan.b_gh);
            let (g_sum, h_sum) = packer.unpack_sum(&gh, count as u64);
            out.push(SplitStatPlain { id, sample_count: count, g_sum, h_sum });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{ChaCha20Rng, Xoshiro256};

    fn make_stats(
        suite: &CipherSuite,
        packer: &GhPacker,
        pairs: &[(f64, f64, u32)],
        rng: &mut ChaCha20Rng,
    ) -> Vec<SplitStatCt> {
        pairs
            .iter()
            .enumerate()
            .map(|(i, &(g, h, count))| {
                // a "sum" over `count` instances: pack already-summed values,
                // offset appears `count` times as it would from real addition
                let encoded = packer
                    .enc
                    .encode(g + packer.g_off * count as f64)
                    .shl(packer.b_h)
                    .add(&packer.enc.encode(h));
                SplitStatCt { ct: suite.encrypt(&encoded, rng), id: i as u32, sample_count: count }
            })
            .collect()
    }

    fn roundtrip_for(suite: CipherSuite) {
        let mut rng = ChaCha20Rng::from_u64(7);
        let mut xr = Xoshiro256::seed_from_u64(3);
        let n_bound = 1000u64;
        let g: Vec<f64> = (0..16).map(|_| xr.next_f64() * 2.0 - 1.0).collect();
        let h: Vec<f64> = (0..16).map(|_| xr.next_f64()).collect();
        let packer = GhPacker::plan_logistic(n_bound, 53);
        let plan = CompressPlan::derive(suite.plaintext_bits(), packer.b_gh);
        assert!(plan.capacity >= 1);

        let pairs: Vec<(f64, f64, u32)> = g
            .iter()
            .zip(&h)
            .enumerate()
            .map(|(i, (&gi, &hi))| (gi * (i + 1) as f64, hi * (i + 1) as f64, (i + 1) as u32))
            .collect();
        let stats = make_stats(&suite, &packer, &pairs, &mut rng);
        let packages = compress(&suite, &plan, &stats);
        let expected_pkgs = stats.len().div_ceil(plan.capacity);
        assert_eq!(packages.len(), expected_pkgs);

        let recovered = decompress(&suite, &plan, &packer, &packages);
        assert_eq!(recovered.len(), stats.len());
        for (r, (gt, ht, ct)) in recovered.iter().zip(&pairs) {
            assert_eq!(r.sample_count, *ct);
            assert!((r.g_sum - gt).abs() < 1e-6, "g {} vs {gt}", r.g_sum);
            assert!((r.h_sum - ht).abs() < 1e-6, "h {} vs {ht}", r.h_sum);
        }
    }

    #[test]
    fn roundtrip_paillier() {
        let mut rng = ChaCha20Rng::from_u64(1);
        roundtrip_for(CipherSuite::new_paillier(512, &mut rng));
    }

    #[test]
    fn roundtrip_affine() {
        let mut rng = ChaCha20Rng::from_u64(2);
        roundtrip_for(CipherSuite::new_affine(512, &mut rng));
    }

    #[test]
    fn roundtrip_plain() {
        roundtrip_for(CipherSuite::new_plain(1023));
    }

    #[test]
    fn capacity_matches_paper() {
        // 1023-bit plaintext, b_gh=147 → η_s = 6 (paper §4.4).
        let plan = CompressPlan::derive(1023, 147);
        assert_eq!(plan.capacity, 6);
    }

    #[test]
    fn disabled_plan_packs_one_each() {
        let suite = CipherSuite::new_plain(512);
        let packer = GhPacker::plan_logistic(100, 30);
        let plan = CompressPlan::disabled(packer.b_gh);
        let mut rng = ChaCha20Rng::from_u64(3);
        let stats = make_stats(&suite, &packer, &[(0.5, 0.5, 1), (-0.25, 0.1, 1)], &mut rng);
        let pkgs = compress(&suite, &plan, &stats);
        assert_eq!(pkgs.len(), 2);
        let rec = decompress(&suite, &plan, &packer, &pkgs);
        assert!((rec[0].g_sum - 0.5).abs() < 1e-6);
        assert!((rec[1].g_sum + 0.25).abs() < 1e-6);
    }

    #[test]
    fn empty_stats() {
        let suite = CipherSuite::new_plain(512);
        let plan = CompressPlan::derive(512, 100);
        let pkgs = compress(&suite, &plan, &[]);
        assert!(pkgs.is_empty());
    }
}
