//! Secure serving sessions (serve protocol v6): the std-only primitives
//! behind the encrypted session channel — X25519 key agreement
//! (RFC 7748), the ChaCha20 stream cipher and Poly1305 one-time
//! authenticator composed into the RFC 8439 AEAD, the per-connection
//! [`FrameCipher`] that seals every serving frame with a per-direction
//! nonce counter, and the per-session [`HandleRotor`] (a keyed Feistel
//! permutation of host handle ids) that closes the cross-session
//! correlation channel stable handle ids left open.
//!
//! The offline build rules out dependency crates, so everything here is
//! built on `std` plus the in-repo [`super::bigint`] (X25519 field
//! arithmetic) and pinned against the RFC 7748 / RFC 8439 published
//! test vectors in this module's tests. The implementation favors
//! clarity over side-channel hardening: the big-integer ladder is *not*
//! constant-time, which is acceptable for the semi-honest model this
//! reproduction targets (both parties follow the protocol; the
//! adversary is a passive network observer).
//!
//! Key schedule (one handshake per TCP connection):
//!
//! ```text
//! guest                                   host
//!   ephemeral (sk_g, pk_g)                  ephemeral (sk_h, pk_h)
//!   SessionHelloSecure { pk_g }  ───────▶
//!                              ◀───────    SessionAcceptSecure { pk_h }
//!   shared = X25519(sk_g, pk_h)    ==      shared = X25519(sk_h, pk_g)
//!   okm    = ChaCha20(shared, nonce = "sbp6-kdf-001")[0..72]
//!   okm[ 0..32] → guest→host AEAD key
//!   okm[32..64] → host→guest AEAD key
//!   okm[64..72] → handle-rotor seed (u64 LE; first handshake of the
//!                 session only — resumes derive fresh AEAD keys but
//!                 keep the session's original rotor)
//! ```
//!
//! Frame nonces are never transmitted: each direction counts frames
//! from zero (`nonce = 4 zero bytes ‖ u64 LE counter`), so nonce reuse
//! is impossible within a connection and replayed v4 answer frames are
//! re-sealed with fresh nonces on the new connection by construction
//! (the host retains plaintext frames, never ciphertext).

use super::bigint::BigUint;
use crate::util::rng::{splitmix64, ChaCha20Rng};

/// AEAD key length (ChaCha20-Poly1305).
pub const KEY_LEN: usize = 32;
/// Poly1305 authentication tag length appended to every sealed frame.
pub const TAG_LEN: usize = 16;
/// X25519 public-key length carried in the secure hello/accept frames.
pub const PUBKEY_LEN: usize = 32;

/// `--secure` policy: whether a serving endpoint offers, requires, or
/// refuses the v6 encrypted channel.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SecureMode {
    /// Never offer or accept encryption: speak plaintext v5 semantics
    /// even to v6-capable peers.
    Off,
    /// Offer encryption and use it when the peer is v6-capable, fall
    /// back to plaintext for older peers (the default).
    #[default]
    Prefer,
    /// Demand encryption: a host closes plaintext hellos, a guest
    /// treats a plaintext accept as a handshake failure.
    Require,
}

impl SecureMode {
    /// Parse the `--secure` CLI token.
    pub fn parse(s: &str) -> Option<SecureMode> {
        match s {
            "off" => Some(SecureMode::Off),
            "prefer" => Some(SecureMode::Prefer),
            "require" => Some(SecureMode::Require),
            _ => None,
        }
    }

    /// Human-readable mode name (also the CLI token).
    pub fn name(self) -> &'static str {
        match self {
            SecureMode::Off => "off",
            SecureMode::Prefer => "prefer",
            SecureMode::Require => "require",
        }
    }
}

// ------------------------------------------------------------ ChaCha20

#[inline]
fn quarter_round(st: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    st[a] = st[a].wrapping_add(st[b]);
    st[d] = (st[d] ^ st[a]).rotate_left(16);
    st[c] = st[c].wrapping_add(st[d]);
    st[b] = (st[b] ^ st[c]).rotate_left(12);
    st[a] = st[a].wrapping_add(st[b]);
    st[d] = (st[d] ^ st[a]).rotate_left(8);
    st[c] = st[c].wrapping_add(st[d]);
    st[b] = (st[b] ^ st[c]).rotate_left(7);
}

/// One ChaCha20 keystream block (RFC 8439 §2.3): 32-byte key, 32-bit
/// block counter, 96-bit nonce.
fn chacha20_block(key: &[u8; 32], counter: u32, nonce: &[u8; 12]) -> [u8; 64] {
    let mut st = [0u32; 16];
    st[0] = 0x6170_7865;
    st[1] = 0x3320_646e;
    st[2] = 0x7962_2d32;
    st[3] = 0x6b20_6574;
    for i in 0..8 {
        st[4 + i] = u32::from_le_bytes(key[i * 4..i * 4 + 4].try_into().unwrap());
    }
    st[12] = counter;
    for i in 0..3 {
        st[13 + i] = u32::from_le_bytes(nonce[i * 4..i * 4 + 4].try_into().unwrap());
    }
    let initial = st;
    for _ in 0..10 {
        quarter_round(&mut st, 0, 4, 8, 12);
        quarter_round(&mut st, 1, 5, 9, 13);
        quarter_round(&mut st, 2, 6, 10, 14);
        quarter_round(&mut st, 3, 7, 11, 15);
        quarter_round(&mut st, 0, 5, 10, 15);
        quarter_round(&mut st, 1, 6, 11, 12);
        quarter_round(&mut st, 2, 7, 8, 13);
        quarter_round(&mut st, 3, 4, 9, 14);
    }
    let mut out = [0u8; 64];
    for i in 0..16 {
        let w = st[i].wrapping_add(initial[i]);
        out[i * 4..i * 4 + 4].copy_from_slice(&w.to_le_bytes());
    }
    out
}

/// XOR `data` with the ChaCha20 keystream starting at `counter`
/// (encrypt and decrypt are the same operation).
fn chacha20_xor(key: &[u8; 32], counter: u32, nonce: &[u8; 12], data: &mut [u8]) {
    for (i, chunk) in data.chunks_mut(64).enumerate() {
        let ks = chacha20_block(key, counter.wrapping_add(i as u32), nonce);
        for (b, k) in chunk.iter_mut().zip(ks.iter()) {
            *b ^= k;
        }
    }
}

// ------------------------------------------------------------ Poly1305
//
// Field arithmetic mod 2^130 − 5 on three 64-bit limbs. Operand bounds:
// the accumulator stays < 2^131 (fully reduced < p after every multiply,
// then one block value < 2^129 is added) and the clamped `r` is < 2^124,
// so every 6-limb product is < 2^255 and one fold brings it under 2^131.

type Fe = [u64; 3];

const P1305: Fe = [0xFFFF_FFFF_FFFF_FFFB, 0xFFFF_FFFF_FFFF_FFFF, 0x3];

#[inline]
fn fe_from_le(bytes: &[u8]) -> Fe {
    debug_assert!(bytes.len() <= 17);
    let mut buf = [0u8; 24];
    buf[..bytes.len()].copy_from_slice(bytes);
    [
        u64::from_le_bytes(buf[0..8].try_into().unwrap()),
        u64::from_le_bytes(buf[8..16].try_into().unwrap()),
        u64::from_le_bytes(buf[16..24].try_into().unwrap()),
    ]
}

#[inline]
fn fe_add(a: &Fe, b: &Fe) -> Fe {
    let mut out = [0u64; 3];
    let mut carry = 0u128;
    for i in 0..3 {
        let s = a[i] as u128 + b[i] as u128 + carry;
        out[i] = s as u64;
        carry = s >> 64;
    }
    debug_assert_eq!(carry, 0);
    out
}

#[inline]
fn fe_ge(a: &Fe, b: &Fe) -> bool {
    for i in (0..3).rev() {
        if a[i] != b[i] {
            return a[i] > b[i];
        }
    }
    true
}

/// `(a · b) mod (2^130 − 5)`, fully reduced. Requires `a < 2^131` and
/// `b < 2^124` (the clamped Poly1305 `r`).
fn fe_mulmod(a: &Fe, b: &Fe) -> Fe {
    let mut prod = [0u64; 6];
    for i in 0..3 {
        let mut carry = 0u128;
        for j in 0..3 {
            let cur = prod[i + j] as u128 + a[i] as u128 * b[j] as u128 + carry;
            prod[i + j] = cur as u64;
            carry = cur >> 64;
        }
        prod[i + 3] = (prod[i + 3] as u128 + carry) as u64;
    }
    // fold once: x = (x mod 2^130) + 5·(x >> 130); the bound above makes
    // x >> 130 fit in two limbs
    let lo = [prod[0], prod[1], prod[2] & 0x3];
    let mut hi = [0u64; 4];
    for i in 0..4 {
        let lo_part = prod[i + 2] >> 2;
        let hi_part = if i + 3 < 6 { prod[i + 3] << 62 } else { 0 };
        hi[i] = lo_part | hi_part;
    }
    debug_assert!(hi[2] == 0 && hi[3] == 0);
    let mut t = [0u64; 3];
    let mut carry = 0u128;
    for i in 0..3 {
        let s = lo[i] as u128 + 5 * hi[i] as u128 + carry;
        t[i] = s as u64;
        carry = s >> 64;
    }
    debug_assert_eq!(carry, 0);
    // fold the at-most-one remaining high bit, then subtract p if needed
    let hi2 = t[2] >> 2;
    let mut r = [t[0], t[1], t[2] & 0x3];
    let mut carry = 5 * hi2 as u128;
    for limb in r.iter_mut() {
        let s = *limb as u128 + carry;
        *limb = s as u64;
        carry = s >> 64;
    }
    while fe_ge(&r, &P1305) {
        let mut borrow = 0i128;
        for i in 0..3 {
            let d = r[i] as i128 - P1305[i] as i128 - borrow;
            borrow = i128::from(d < 0);
            r[i] = d as u64;
        }
        debug_assert_eq!(borrow, 0);
    }
    r
}

/// Poly1305 one-time authenticator (RFC 8439 §2.5) over `msg`.
fn poly1305_tag(key: &[u8; 32], msg: &[u8]) -> [u8; 16] {
    let mut rb = [0u8; 16];
    rb.copy_from_slice(&key[..16]);
    rb[3] &= 15;
    rb[7] &= 15;
    rb[11] &= 15;
    rb[15] &= 15;
    rb[4] &= 252;
    rb[8] &= 252;
    rb[12] &= 252;
    let r = fe_from_le(&rb);
    let mut acc: Fe = [0, 0, 0];
    for block in msg.chunks(16) {
        let mut n = fe_from_le(block);
        let bit = 8 * block.len();
        n[bit / 64] |= 1u64 << (bit % 64);
        acc = fe_mulmod(&fe_add(&acc, &n), &r);
    }
    // tag = (acc + s) mod 2^128
    let s_lo = u64::from_le_bytes(key[16..24].try_into().unwrap());
    let s_hi = u64::from_le_bytes(key[24..32].try_into().unwrap());
    let (t0, c0) = acc[0].overflowing_add(s_lo);
    let t1 = acc[1].wrapping_add(s_hi).wrapping_add(u64::from(c0));
    let mut out = [0u8; 16];
    out[..8].copy_from_slice(&t0.to_le_bytes());
    out[8..].copy_from_slice(&t1.to_le_bytes());
    out
}

// ------------------------------------------------- ChaCha20-Poly1305 AEAD

/// RFC 8439 §2.8 tag over the ciphertext with empty AAD: the one-time
/// key is the first 32 bytes of keystream block 0, the MAC input is
/// `ct ‖ pad16(ct) ‖ le64(0) ‖ le64(len(ct))`.
fn aead_tag(key: &[u8; 32], nonce: &[u8; 12], ct: &[u8]) -> [u8; 16] {
    let block0 = chacha20_block(key, 0, nonce);
    let mut otk = [0u8; 32];
    otk.copy_from_slice(&block0[..32]);
    let mut mac = Vec::with_capacity(ct.len() + 32);
    mac.extend_from_slice(ct);
    while mac.len() % 16 != 0 {
        mac.push(0);
    }
    mac.extend_from_slice(&0u64.to_le_bytes());
    mac.extend_from_slice(&(ct.len() as u64).to_le_bytes());
    poly1305_tag(&otk, &mac)
}

#[inline]
fn ct_eq16(a: &[u8; 16], b: &[u8]) -> bool {
    debug_assert_eq!(b.len(), 16);
    let mut diff = 0u8;
    for i in 0..16 {
        diff |= a[i] ^ b[i];
    }
    diff == 0
}

/// One direction of an established secure channel: an AEAD key plus the
/// implicit frame counter that forms each nonce. The counter is never
/// transmitted — both ends count frames from zero, so a lost, reordered
/// or replayed frame fails authentication instead of decrypting.
#[derive(Clone)]
pub struct FrameCipher {
    key: [u8; 32],
    counter: u64,
}

impl FrameCipher {
    /// Channel keyed for one direction, counting frames from zero.
    pub fn new(key: [u8; 32]) -> Self {
        FrameCipher { key, counter: 0 }
    }

    /// Frames sealed or opened so far (the next frame's nonce counter).
    pub fn frames(&self) -> u64 {
        self.counter
    }

    #[inline]
    fn next_nonce(&mut self) -> [u8; 12] {
        let mut nonce = [0u8; 12];
        nonce[4..].copy_from_slice(&self.counter.to_le_bytes());
        self.counter += 1;
        nonce
    }

    /// Seal `payload` into `out` (cleared first): ciphertext followed by
    /// the 16-byte Poly1305 tag.
    pub fn seal_into(&mut self, payload: &[u8], out: &mut Vec<u8>) {
        let nonce = self.next_nonce();
        out.clear();
        out.extend_from_slice(payload);
        chacha20_xor(&self.key, 1, &nonce, out);
        let tag = aead_tag(&self.key, &nonce, out);
        out.extend_from_slice(&tag);
    }

    /// Open a sealed frame in place: verify the trailing tag over the
    /// ciphertext *before* decrypting, then return the plaintext length
    /// (`buf.len() − 16`; the plaintext occupies `buf[..len]`). `Err`
    /// means the frame was tampered with or truncated — the caller must
    /// treat the connection as hostile and close it without answering.
    pub fn open_in_place(&mut self, buf: &mut [u8]) -> Result<usize, ()> {
        if buf.len() < TAG_LEN {
            return Err(());
        }
        let nonce = self.next_nonce();
        let split = buf.len() - TAG_LEN;
        let want = aead_tag(&self.key, &nonce, &buf[..split]);
        if !ct_eq16(&want, &buf[split..]) {
            return Err(());
        }
        chacha20_xor(&self.key, 1, &nonce, &mut buf[..split]);
        Ok(split)
    }
}

impl std::fmt::Debug for FrameCipher {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // never print key material
        write!(f, "FrameCipher {{ counter: {} }}", self.counter)
    }
}

// ------------------------------------------------------------- X25519

/// The X25519 base point (u = 9).
pub const BASEPOINT: [u8; 32] = [
    9, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
    0,
];

fn big_from_le(bytes: &[u8; 32]) -> BigUint {
    let mut be = *bytes;
    be.reverse();
    BigUint::from_bytes_be(&be)
}

fn big_to_le32(v: &BigUint) -> [u8; 32] {
    let be = v.to_bytes_be();
    debug_assert!(be.len() <= 32);
    let mut out = [0u8; 32];
    for (i, byte) in be.iter().rev().enumerate() {
        out[i] = *byte;
    }
    out
}

/// X25519 scalar multiplication (RFC 7748 §5): the Montgomery ladder
/// over GF(2^255 − 19), with the standard scalar clamping and input
/// top-bit masking. Built on [`BigUint`], so *not* constant-time — fine
/// for the semi-honest model, unacceptable against a local-timing
/// adversary (documented trade-off of the offline build).
pub fn x25519(scalar: &[u8; 32], point: &[u8; 32]) -> [u8; 32] {
    let p = BigUint::from_hex("7fffffffffffffffffffffffffffffffffffffffffffffffffffffffffffffed")
        .expect("curve prime literal");
    let a24 = BigUint::from_u64(121_665);
    let mut k = *scalar;
    k[0] &= 248;
    k[31] &= 127;
    k[31] |= 64;
    let mut u = *point;
    u[31] &= 127;
    let x1 = big_from_le(&u).rem(&p);
    let mut x2 = BigUint::one();
    let mut z2 = BigUint::zero();
    let mut x3 = x1.clone();
    let mut z3 = BigUint::one();
    let mut swap = 0u8;
    for t in (0..=254u32).rev() {
        let kt = (k[(t / 8) as usize] >> (t % 8)) & 1;
        swap ^= kt;
        if swap == 1 {
            std::mem::swap(&mut x2, &mut x3);
            std::mem::swap(&mut z2, &mut z3);
        }
        swap = kt;
        let a = x2.add_mod(&z2, &p);
        let aa = a.mul_mod(&a, &p);
        let b = x2.sub_mod(&z2, &p);
        let bb = b.mul_mod(&b, &p);
        let e = aa.sub_mod(&bb, &p);
        let c = x3.add_mod(&z3, &p);
        let d = x3.sub_mod(&z3, &p);
        let da = d.mul_mod(&a, &p);
        let cb = c.mul_mod(&b, &p);
        let sum = da.add_mod(&cb, &p);
        x3 = sum.mul_mod(&sum, &p);
        let diff = da.sub_mod(&cb, &p);
        z3 = x1.mul_mod(&diff.mul_mod(&diff, &p), &p);
        x2 = aa.mul_mod(&bb, &p);
        z2 = e.mul_mod(&aa.add_mod(&a24.mul_mod(&e, &p), &p), &p);
    }
    if swap == 1 {
        std::mem::swap(&mut x2, &mut x3);
        std::mem::swap(&mut z2, &mut z3);
    }
    let exp = p.sub(&BigUint::from_u64(2));
    let out = x2.mul_mod(&z2.mod_pow(&exp, &p), &p);
    big_to_le32(&out)
}

/// Generate an ephemeral X25519 keypair `(secret, public)` from the
/// given CSPRNG.
pub fn keypair(rng: &mut ChaCha20Rng) -> ([u8; 32], [u8; 32]) {
    let mut sk = [0u8; 32];
    rng.fill_bytes(&mut sk);
    let pk = x25519(&sk, &BASEPOINT);
    (sk, pk)
}

/// Diffie–Hellman: our secret × peer public. `None` when the shared
/// point is all zero (the peer sent a small-order point — RFC 7748 §6.1
/// requires aborting the handshake).
pub fn shared_secret(secret: &[u8; 32], peer_public: &[u8; 32]) -> Option<[u8; 32]> {
    let shared = x25519(secret, peer_public);
    if shared.iter().all(|&b| b == 0) {
        None
    } else {
        Some(shared)
    }
}

// ------------------------------------------------------- key derivation

/// Everything one handshake derives from the X25519 shared secret.
pub struct SessionKeys {
    /// AEAD key sealing guest→host frames.
    pub guest_to_host: [u8; 32],
    /// AEAD key sealing host→guest frames.
    pub host_to_guest: [u8; 32],
    /// Seed of the session's [`HandleRotor`]. Only the session's *first*
    /// handshake establishes the rotor; a resume handshake derives fresh
    /// AEAD keys but keeps rotating handles with the original rotor (the
    /// guest's memo keys survive the reconnect).
    pub rotor_seed: u64,
}

/// Domain-separation label of the v6 key-derivation keystream.
const KDF_LABEL: &[u8; 12] = b"sbp6-kdf-001";

/// Expand an X25519 shared secret into the session key material: 72
/// bytes of ChaCha20 keystream keyed by the shared secret under the
/// fixed [`KDF_LABEL`] nonce.
pub fn derive_session_keys(shared: &[u8; 32]) -> SessionKeys {
    let mut okm = [0u8; 72];
    chacha20_xor(shared, 0, KDF_LABEL, &mut okm);
    let mut guest_to_host = [0u8; 32];
    let mut host_to_guest = [0u8; 32];
    guest_to_host.copy_from_slice(&okm[..32]);
    host_to_guest.copy_from_slice(&okm[32..64]);
    let rotor_seed = u64::from_le_bytes(okm[64..72].try_into().unwrap());
    SessionKeys { guest_to_host, host_to_guest, rotor_seed }
}

// ------------------------------------------------------- handle rotor

/// Per-session keyed permutation of `u32` host handle ids: a 4-round
/// balanced Feistel network on 16-bit halves, keyed from the
/// handshake's rotor seed. A network observer comparing two sessions of
/// the same guest sees unrelated handle ids for the same underlying
/// split, closing the cross-session correlation channel; being a
/// bijection, the host inverts it exactly ([`HandleRotor::unrotate`])
/// and serves from its true split table.
///
/// The rotation crosses the wire *inside* the AEAD: it defends against
/// a different observer than the encryption (a log-scraping adversary at
/// either endpoint, or future plaintext-metadata paths), and it keeps
/// `PredictRoute` wire length unchanged, so byte accounting is identical
/// with and without it.
#[derive(Clone, Copy)]
pub struct HandleRotor {
    keys: [u32; 4],
}

impl HandleRotor {
    /// Expand the handshake's rotor seed into the four round keys.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let keys = std::array::from_fn(|_| splitmix64(&mut s) as u32);
        HandleRotor { keys }
    }

    #[inline]
    fn round(x: u32, k: u32) -> u32 {
        (x.wrapping_add(k).wrapping_mul(0x9E37_79B9) >> 16) & 0xFFFF
    }

    /// Map a true handle id to its on-the-wire rotated form.
    #[inline]
    pub fn rotate(&self, handle: u32) -> u32 {
        let mut l = handle >> 16;
        let mut r = handle & 0xFFFF;
        for &k in &self.keys {
            let next = l ^ Self::round(r, k);
            l = r;
            r = next;
        }
        (l << 16) | r
    }

    /// Invert [`HandleRotor::rotate`].
    #[inline]
    pub fn unrotate(&self, wire: u32) -> u32 {
        let mut l = wire >> 16;
        let mut r = wire & 0xFFFF;
        for &k in self.keys.iter().rev() {
            let prev = r ^ Self::round(l, k);
            r = l;
            l = prev;
        }
        (l << 16) | r
    }
}

impl std::fmt::Debug for HandleRotor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // round keys are session-secret material
        write!(f, "HandleRotor {{ .. }}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hx(s: &str) -> Vec<u8> {
        let clean: String = s.chars().filter(|c| c.is_ascii_hexdigit()).collect();
        clean
            .as_bytes()
            .chunks(2)
            .map(|p| u8::from_str_radix(std::str::from_utf8(p).unwrap(), 16).unwrap())
            .collect()
    }

    fn arr32(v: &[u8]) -> [u8; 32] {
        v.try_into().unwrap()
    }

    const SUNSCREEN: &[u8] = b"Ladies and Gentlemen of the class of '99: \
If I could offer you only one tip for the future, sunscreen would be it.";

    #[test]
    fn chacha20_rfc8439_encryption_vector() {
        // RFC 8439 §2.4.2: key 00..1f, counter 1, nonce 00..00 4a 00..
        let key: [u8; 32] = std::array::from_fn(|i| i as u8);
        let nonce = arr_nonce("000000000000004a00000000");
        let mut data = SUNSCREEN.to_vec();
        chacha20_xor(&key, 1, &nonce, &mut data);
        assert_eq!(
            data,
            hx("6e2e359a2568f98041ba0728dd0d6981e97e7aec1d4360c20a27afccfd9fae0b\
                f91b65c5524733ab8f593dabcd62b3571639d624e65152ab8f530c359f0861d8\
                07ca0dbf500d6a6156a38e088a22b65e52bc514d16ccf806818ce91ab7793736\
                5af90bbf74a35be6b40b8eedf2785e42874d")
        );
        // xor-ing again round-trips
        chacha20_xor(&key, 1, &nonce, &mut data);
        assert_eq!(data, SUNSCREEN);
    }

    fn arr_nonce(s: &str) -> [u8; 12] {
        hx(s).as_slice().try_into().unwrap()
    }

    #[test]
    fn poly1305_rfc8439_tag_vector() {
        // RFC 8439 §2.5.2
        let key = arr32(&hx(
            "85d6be7857556d337f4452fe42d506a80103808afb0db2fd4abff6af4149f51b",
        ));
        let tag = poly1305_tag(&key, b"Cryptographic Forum Research Group");
        assert_eq!(tag.to_vec(), hx("a8061dc1305136c6c22b8baf0c0127a9"));
        // empty message: tag = s
        let tag0 = poly1305_tag(&key, b"");
        assert_eq!(tag0.to_vec(), key[16..].to_vec());
    }

    #[test]
    fn aead_tag_matches_rfc8439_construction() {
        // RFC 8439 §2.8.2 uses a 12-byte AAD; our frame channel always
        // seals with empty AAD, so pin the §2.8.2 key/nonce/plaintext
        // with aad = "" against the verified reference implementation.
        let key: [u8; 32] = std::array::from_fn(|i| 0x80 + i as u8);
        let nonce = arr_nonce("070000004041424344454647");
        let mut ct = SUNSCREEN.to_vec();
        chacha20_xor(&key, 1, &nonce, &mut ct);
        // ciphertext body is the RFC's (AAD does not affect it)
        assert_eq!(
            ct,
            hx("d31a8d34648e60db7b86afbc53ef7ec2a4aded51296e08fea9e2b5a736ee62d6\
                3dbea45e8ca9671282fafb69da92728b1a71de0a9e060b2905d6a5b67ecd3b36\
                92ddbd7f2d778b8c9803aee328091b58fab324e4fad675945585808b4831d7bc\
                3ff4def08e4b7a9de576d26586cec64b6116")
        );
        let tag = aead_tag(&key, &nonce, &ct);
        assert_eq!(tag.len(), TAG_LEN);
    }

    #[test]
    fn frame_cipher_round_trip_and_counter_discipline() {
        let key = [7u8; 32];
        let mut tx = FrameCipher::new(key);
        let mut rx = FrameCipher::new(key);
        let mut wire = Vec::new();
        for i in 0..10u32 {
            let payload = vec![i as u8; 3 + i as usize * 17];
            tx.seal_into(&payload, &mut wire);
            assert_eq!(wire.len(), payload.len() + TAG_LEN);
            let n = rx.open_in_place(&mut wire).expect("honest frame opens");
            assert_eq!(&wire[..n], payload.as_slice());
        }
        assert_eq!(tx.frames(), 10);
        assert_eq!(rx.frames(), 10);
    }

    #[test]
    fn frame_cipher_pinned_vectors() {
        // generated by the verified Python reference (RFC-self-checked):
        // key = KDF(guest→host) of the RFC 7748 §6.1 DH shared secret
        let keys = derive_session_keys(&arr32(&hx(
            "4a5d9d5ba4ce2de1728e3bf480350f25e07e21c947d19e3376f09b3c1e161742",
        )));
        assert_eq!(
            keys.guest_to_host.to_vec(),
            hx("49325f578b733c17a7e84bc01f5c2e5c2744cc20a311c29931cd6344f8feff15")
        );
        assert_eq!(
            keys.host_to_guest.to_vec(),
            hx("e330599b4728c43503da263833e697651e4dedce3b6673fa3ad01df953f8893f")
        );
        assert_eq!(keys.rotor_seed, 0xf2d8_2e38_4dd9_0e7c);
        let mut tx = FrameCipher::new(keys.guest_to_host);
        let mut wire = Vec::new();
        tx.seal_into(b"serve-frame-0", &mut wire);
        assert_eq!(wire, hx("f0bf6e91493fcc7c2163ce1dce9cc7dcfc5d89e377388106fdf8f96b76"));
        tx.seal_into(b"serve-frame-1", &mut wire);
        assert_eq!(wire, hx("21fbee955385506d1aaacca4a8fa86dbd59c5781a80ee6728fd59fd1f9"));
        let mut tx2 = FrameCipher::new(keys.host_to_guest);
        tx2.seal_into(b"", &mut wire);
        assert_eq!(wire, hx("d1ca8d46d8cb9c781e1e8c40b99c1bd4"));
    }

    #[test]
    fn tampered_and_truncated_frames_fail_closed() {
        let key = [42u8; 32];
        let mut tx = FrameCipher::new(key);
        let mut wire = Vec::new();
        tx.seal_into(b"the plaintext never leaks", &mut wire);
        // flip one ciphertext bit
        let mut tampered = wire.clone();
        tampered[2] ^= 1;
        assert!(FrameCipher::new(key).open_in_place(&mut tampered).is_err());
        // flip one tag bit
        let mut bad_tag = wire.clone();
        let last = bad_tag.len() - 1;
        bad_tag[last] ^= 0x80;
        assert!(FrameCipher::new(key).open_in_place(&mut bad_tag).is_err());
        // truncate into (and past) the tag
        for cut in [1usize, TAG_LEN, wire.len() - 1] {
            let mut short = wire[..wire.len() - cut].to_vec();
            assert!(FrameCipher::new(key).open_in_place(&mut short).is_err());
        }
        // wrong direction counter (replay of frame 0 as frame 1) fails
        let mut rx = FrameCipher::new(key);
        let mut first = wire.clone();
        rx.open_in_place(&mut first).unwrap();
        let mut replayed = wire.clone();
        assert!(rx.open_in_place(&mut replayed).is_err());
        // the honest frame still opens with a fresh counter
        let mut ok = wire.clone();
        assert_eq!(
            FrameCipher::new(key).open_in_place(&mut ok),
            Ok(wire.len() - TAG_LEN)
        );
    }

    #[test]
    fn x25519_rfc7748_vectors() {
        // §5.2 vector 1
        let out = x25519(
            &arr32(&hx("a546e36bf0527c9d3b16154b82465edd62144c0ac1fc5a18506a2244ba449ac4")),
            &arr32(&hx("e6db6867583030db3594c1a424b15f7c726624ec26b3353b10a903a6d0ab1c4c")),
        );
        assert_eq!(
            out.to_vec(),
            hx("c3da55379de9c6908e94ea4df28d084f32eccf03491c71f754b4075577a28552")
        );
        // §5.2 iteration test, 1 iteration: k = u = basepoint
        let it = x25519(&BASEPOINT, &BASEPOINT);
        assert_eq!(
            it.to_vec(),
            hx("422c8e7a6227d7bca1350b3e2bb7279f7897b87bb6854b783c60e80311ae3079")
        );
    }

    #[test]
    fn x25519_rfc7748_diffie_hellman() {
        // §6.1: both parties derive the same shared secret
        let a_sk = arr32(&hx("77076d0a7318a57d3c16c17251b26645df4c2f87ebc0992ab177fba51db92c2a"));
        let b_sk = arr32(&hx("5dab087e624a8a4b79e17f8b83800ee66f3bb1292618b6fd1c2f8b27ff88e0eb"));
        let a_pk = x25519(&a_sk, &BASEPOINT);
        let b_pk = x25519(&b_sk, &BASEPOINT);
        assert_eq!(
            a_pk.to_vec(),
            hx("8520f0098930a754748b7ddcb43ef75a0dbf3a0d26381af4eba4a98eaa9b4e6a")
        );
        assert_eq!(
            b_pk.to_vec(),
            hx("de9edb7d7b7dc1b4d35b61c2ece435373f8343c85b78674dadfc7e146f882b4f")
        );
        let ab = shared_secret(&a_sk, &b_pk).unwrap();
        let ba = shared_secret(&b_sk, &a_pk).unwrap();
        assert_eq!(ab, ba);
        assert_eq!(
            ab.to_vec(),
            hx("4a5d9d5ba4ce2de1728e3bf480350f25e07e21c947d19e3376f09b3c1e161742")
        );
        // all-zero shared secret (small-order peer point) is rejected
        assert!(shared_secret(&a_sk, &[0u8; 32]).is_none());
    }

    #[test]
    fn keypair_agreement_from_seeded_rng() {
        let mut rng = ChaCha20Rng::from_u64(0xDEAD_BEEF);
        let (g_sk, g_pk) = keypair(&mut rng);
        let (h_sk, h_pk) = keypair(&mut rng);
        assert_ne!(g_pk, h_pk);
        let a = shared_secret(&g_sk, &h_pk).unwrap();
        let b = shared_secret(&h_sk, &g_pk).unwrap();
        assert_eq!(a, b);
        // two ends of the derived channel interoperate
        let keys = derive_session_keys(&a);
        let mut tx = FrameCipher::new(keys.guest_to_host);
        let mut rx = FrameCipher::new(keys.guest_to_host);
        let mut wire = Vec::new();
        tx.seal_into(b"handshake smoke", &mut wire);
        let n = rx.open_in_place(&mut wire).unwrap();
        assert_eq!(&wire[..n], b"handshake smoke");
    }

    #[test]
    fn handle_rotor_pinned_and_invertible() {
        // pinned against the Python reference for the KDF-derived seed
        let rotor = HandleRotor::new(0xf2d8_2e38_4dd9_0e7c);
        for (handle, wire) in [
            (0u32, 0x0546_f02e_u32),
            (1, 0x2fe8_4b6c),
            (2, 0x01b8_9408),
            (42, 0xd90b_db98),
            (1000, 0x5bc1_677b),
            (0xDEAD_BEEF, 0x5cca_17d4),
            (0xFFFF_FFFF, 0xa2df_ad70),
        ] {
            assert_eq!(rotor.rotate(handle), wire, "rotate({handle})");
            assert_eq!(rotor.unrotate(wire), handle, "unrotate({wire:#x})");
        }
        // different seed, different permutation
        let other = HandleRotor::new(0x1234_5678_9ABC_DEF0);
        assert_eq!(other.rotate(42), 0x620d_383f);
        // bijective over a dense range
        let mut seen = std::collections::HashSet::new();
        for h in 0..5000u32 {
            let w = rotor.rotate(h);
            assert_eq!(rotor.unrotate(w), h);
            assert!(seen.insert(w), "collision at {h}");
        }
    }

    #[test]
    fn secure_mode_parse_and_names() {
        assert_eq!(SecureMode::parse("off"), Some(SecureMode::Off));
        assert_eq!(SecureMode::parse("prefer"), Some(SecureMode::Prefer));
        assert_eq!(SecureMode::parse("require"), Some(SecureMode::Require));
        assert_eq!(SecureMode::parse("tls"), None);
        assert_eq!(SecureMode::default(), SecureMode::Prefer);
        for m in [SecureMode::Off, SecureMode::Prefer, SecureMode::Require] {
            assert_eq!(SecureMode::parse(m.name()), Some(m));
        }
    }
}
