//! Montgomery modular arithmetic for odd moduli.
//!
//! This is the hot core of the whole system: every homomorphic "addition"
//! of packed gradient/hessian ciphertexts is one Montgomery multiplication
//! mod n² (2048-bit for a 1024-bit Paillier key), and every encryption /
//! decryption / scalar-multiplication is a windowed Montgomery
//! exponentiation. The CIOS (coarsely integrated operand scanning) inner
//! loop below is what `cargo bench --bench micro_cipher` measures.
//!
//! Ciphertexts that live inside histograms are kept in the Montgomery
//! domain for their whole lifetime (the domain is closed under
//! `mont_mul`), so the per-histogram-add cost is exactly one `mont_mul` —
//! see [`crate::tree::histogram`].

use super::bigint::BigUint;
use std::cmp::Ordering;

/// Precomputed context for arithmetic mod an odd modulus `m`.
#[derive(Clone, Debug)]
pub struct MontCtx {
    /// The modulus (odd).
    pub m: BigUint,
    /// Limb count of `m`; all Montgomery residues are padded to this width.
    n: usize,
    /// `-m⁻¹ mod 2⁶⁴`.
    minv: u64,
    /// `R² mod m` where `R = 2^(64·n)`; used by [`Self::to_mont`].
    r2: Vec<u64>,
    /// `1` in Montgomery form (`R mod m`).
    one: Vec<u64>,
}

/// A value in the Montgomery domain, padded to the modulus width.
/// Only meaningful together with the `MontCtx` that produced it.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct MontInt(pub(crate) Vec<u64>);

impl MontCtx {
    /// Build a context; `m` must be odd and ≥ 3.
    pub fn new(m: BigUint) -> Self {
        assert!(!m.is_even() && !m.is_one() && !m.is_zero(), "modulus must be odd ≥ 3");
        let n = m.limbs.len();
        // Newton–Hensel: invert m mod 2^64, then negate.
        let m0 = m.limbs[0];
        let mut inv = m0; // correct to 3 bits
        for _ in 0..5 {
            inv = inv.wrapping_mul(2u64.wrapping_sub(m0.wrapping_mul(inv)));
        }
        let minv = inv.wrapping_neg();
        let r2_big = BigUint::one().shl(128 * n).rem(&m);
        let one_big = BigUint::one().shl(64 * n).rem(&m);
        let pad = |b: &BigUint| {
            let mut v = b.limbs.clone();
            v.resize(n, 0);
            v
        };
        Self { n, minv, r2: pad(&r2_big), one: pad(&one_big), m }
    }

    /// Limb count of the modulus.
    #[inline]
    pub fn limbs(&self) -> usize {
        self.n
    }

    /// Montgomery multiplication (CIOS): returns `a·b·R⁻¹ mod m`.
    /// `a`, `b` must be padded to `n` limbs.
    fn mul_raw(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let mut t = vec![0u64; self.n + 2];
        self.mul_raw_into(a, b, &mut t);
        t.truncate(self.n);
        t
    }

    /// Allocation-free CIOS into caller scratch (`t.len() == n + 2` after
    /// the call; the result occupies `t[..n]`). This is the §Perf hot
    /// path: `mont_mul_assign` and `mont_pow` reuse one scratch buffer so
    /// the histogram add loop does zero heap traffic.
    fn mul_raw_into(&self, a: &[u64], b: &[u64], t: &mut Vec<u64>) {
        let n = self.n;
        let m = &self.m.limbs;
        t.clear();
        t.resize(n + 2, 0);
        for &ai in a.iter().take(n) {
            // t += ai * b
            let mut carry: u128 = 0;
            for j in 0..n {
                let cur = t[j] as u128 + ai as u128 * b[j] as u128 + carry;
                t[j] = cur as u64;
                carry = cur >> 64;
            }
            let cur = t[n] as u128 + carry;
            t[n] = cur as u64;
            t[n + 1] = (cur >> 64) as u64;

            // reduce one limb: t = (t + ((t[0]·m') mod 2⁶⁴)·m) / 2⁶⁴
            let mval = t[0].wrapping_mul(self.minv);
            let cur = t[0] as u128 + mval as u128 * m[0] as u128;
            let mut carry = cur >> 64;
            for j in 1..n {
                let cur = t[j] as u128 + mval as u128 * m[j] as u128 + carry;
                t[j - 1] = cur as u64;
                carry = cur >> 64;
            }
            let cur = t[n] as u128 + carry;
            t[n - 1] = cur as u64;
            t[n] = t[n + 1].wrapping_add((cur >> 64) as u64);
            t[n + 1] = 0;
        }
        // conditional subtract
        if t[n] != 0 || ge_slices(&t[..n], m) {
            sub_in_place(&mut t[..n + 1], m);
        }
    }

    /// Convert into the Montgomery domain.
    pub fn to_mont(&self, a: &BigUint) -> MontInt {
        let a = if a.cmp_big(&self.m) == Ordering::Less {
            a.clone()
        } else {
            a.rem(&self.m)
        };
        let mut pad = a.limbs;
        pad.resize(self.n, 0);
        MontInt(self.mul_raw(&pad, &self.r2))
    }

    /// Convert out of the Montgomery domain.
    pub fn from_mont(&self, a: &MontInt) -> BigUint {
        let one = {
            let mut v = vec![0u64; self.n];
            v[0] = 1;
            v
        };
        BigUint::from_limbs(self.mul_raw(&a.0, &one))
    }

    /// `a·b` in the Montgomery domain.
    #[inline]
    pub fn mont_mul(&self, a: &MontInt, b: &MontInt) -> MontInt {
        MontInt(self.mul_raw(&a.0, &b.0))
    }

    /// In-place variant used in the histogram accumulation loop: zero
    /// heap allocation (thread-local scratch + buffer reuse).
    #[inline]
    pub fn mont_mul_assign(&self, acc: &mut MontInt, b: &MontInt) {
        SCRATCH.with(|s| {
            let mut t = s.borrow_mut();
            self.mul_raw_into(&acc.0, &b.0, &mut t);
            acc.0.clear();
            acc.0.extend_from_slice(&t[..self.n]);
        });
    }

    /// `c^(2^k)` — k in-place squarings. The cipher-compression "shift"
    /// (×2^b_gh) is a power-of-two exponent, so the generic windowed
    /// `mont_pow` table is wasted on it; this saves ~10% per shift and
    /// allocates nothing.
    pub fn mont_pow2k(&self, c: &MontInt, k: usize) -> MontInt {
        let mut acc = c.clone();
        SCRATCH.with(|s| {
            let mut t = s.borrow_mut();
            for _ in 0..k {
                let (a, b) = (&acc.0, &acc.0);
                self.mul_raw_into(a, b, &mut t);
                acc.0.clear();
                acc.0.extend_from_slice(&t[..self.n]);
            }
        });
        acc
    }

    /// `1` in the Montgomery domain (the identity for `mont_mul`).
    pub fn mont_one(&self) -> MontInt {
        MontInt(self.one.clone())
    }

    /// `base^exp mod m` with a fixed 4-bit window; `base` in standard form.
    pub fn mod_pow(&self, base: &BigUint, exp: &BigUint) -> BigUint {
        let b = self.to_mont(base);
        let r = self.mont_pow(&b, exp);
        self.from_mont(&r)
    }

    /// Exponentiation entirely inside the Montgomery domain.
    pub fn mont_pow(&self, base: &MontInt, exp: &BigUint) -> MontInt {
        let bits = exp.bit_length();
        if bits == 0 {
            return self.mont_one();
        }
        // Precompute base^0..base^15.
        let mut table = Vec::with_capacity(16);
        table.push(self.mont_one());
        for i in 1..16 {
            table.push(self.mont_mul(&table[i - 1], base));
        }
        let nibbles = bits.div_ceil(4);
        let mut acc = self.mont_one();
        let mut started = false;
        SCRATCH.with(|s| {
            let mut t = s.borrow_mut();
            for w in (0..nibbles).rev() {
                if started {
                    for _ in 0..4 {
                        self.mul_raw_into(&acc.0, &acc.0, &mut t);
                        acc.0.clear();
                        acc.0.extend_from_slice(&t[..self.n]);
                    }
                }
                let mut nib = 0usize;
                for b in 0..4 {
                    let bit_idx = w * 4 + (3 - b);
                    nib = (nib << 1) | (bit_idx < bits && exp.bit(bit_idx)) as usize;
                }
                if nib != 0 {
                    self.mul_raw_into(&acc.0, &table[nib].0, &mut t);
                    acc.0.clear();
                    acc.0.extend_from_slice(&t[..self.n]);
                    started = true;
                }
            }
        });
        if !started {
            return self.mont_one();
        }
        acc
    }

    /// Modular inverse of a Montgomery-domain value, staying in the domain.
    /// Used for ciphertext negation (histogram subtraction).
    ///
    /// The raw limbs of a Montgomery residue equal `a·R mod m`, so a binary
    /// inverse gives `a⁻¹·R⁻¹`; two REDC-multiplications by `R²` append an
    /// `R` each: `a⁻¹·R⁻¹ → a⁻¹ → a⁻¹·R`.
    pub fn mont_inverse(&self, a: &MontInt) -> Option<MontInt> {
        let raw = BigUint::from_limbs(a.0.clone()); // = a·R mod m
        let inv = inv_mod_odd(&raw, &self.m)?; // = a⁻¹·R⁻¹ mod m
        let mut pad = inv.limbs;
        pad.resize(self.n, 0);
        let step = self.mul_raw(&pad, &self.r2); // = a⁻¹
        Some(MontInt(self.mul_raw(&step, &self.r2))) // = a⁻¹·R
    }
}

/// Binary extended GCD inverse for odd modulus (HAC 14.61 specialization).
/// Returns `a⁻¹ mod m` or `None` if `gcd(a, m) ≠ 1`.
pub fn inv_mod_odd(a: &BigUint, m: &BigUint) -> Option<BigUint> {
    debug_assert!(!m.is_even());
    let mut u = a.rem(m);
    if u.is_zero() {
        return None;
    }
    let mut v = m.clone();
    let mut x1 = BigUint::one();
    let mut x2 = BigUint::zero();
    while !u.is_one() && !v.is_one() {
        while u.is_even() {
            u = u.shr(1);
            x1 = if x1.is_even() { x1.shr(1) } else { x1.add(m).shr(1) };
        }
        while v.is_even() {
            v = v.shr(1);
            x2 = if x2.is_even() { x2.shr(1) } else { x2.add(m).shr(1) };
        }
        if u.cmp_big(&v) != Ordering::Less {
            u = u.sub(&v);
            x1 = x1.sub_mod(&x2, m);
        } else {
            v = v.sub(&u);
            x2 = x2.sub_mod(&x1, m);
        }
        if u.is_zero() || v.is_zero() {
            return None;
        }
    }
    Some(if u.is_one() { x1.rem(m) } else { x2.rem(m) })
}

thread_local! {
    /// Shared CIOS scratch for the allocation-free paths.
    static SCRATCH: std::cell::RefCell<Vec<u64>> = const { std::cell::RefCell::new(Vec::new()) };
}

#[inline]
fn ge_slices(a: &[u64], b: &[u64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    for i in (0..a.len()).rev() {
        match a[i].cmp(&b[i]) {
            Ordering::Greater => return true,
            Ordering::Less => return false,
            Ordering::Equal => {}
        }
    }
    true
}

#[inline]
fn sub_in_place(a: &mut [u64], b: &[u64]) {
    let mut borrow = 0u64;
    for i in 0..b.len() {
        let (d1, b1) = a[i].overflowing_sub(b[i]);
        let (d2, b2) = d1.overflowing_sub(borrow);
        a[i] = d2;
        borrow = (b1 as u64) + (b2 as u64);
    }
    if a.len() > b.len() {
        a[b.len()] = a[b.len()].wrapping_sub(borrow);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{ChaCha20Rng, Xoshiro256};

    fn big(v: u128) -> BigUint {
        BigUint::from_u128(v)
    }

    fn random_odd(rng: &mut ChaCha20Rng, bits: usize) -> BigUint {
        let mut m = BigUint::random_exact_bits(rng, bits);
        if m.is_even() {
            m = m.add_u64(1);
        }
        m
    }

    #[test]
    fn roundtrip_to_from_mont() {
        let mut rng = ChaCha20Rng::from_u64(1);
        for bits in [64usize, 128, 512, 2048] {
            let m = random_odd(&mut rng, bits);
            let ctx = MontCtx::new(m.clone());
            for _ in 0..20 {
                let a = BigUint::random_below(&mut rng, &m);
                assert_eq!(ctx.from_mont(&ctx.to_mont(&a)), a);
            }
        }
    }

    #[test]
    fn mont_mul_matches_mul_mod() {
        let mut rng = ChaCha20Rng::from_u64(2);
        for bits in [64usize, 192, 1024] {
            let m = random_odd(&mut rng, bits);
            let ctx = MontCtx::new(m.clone());
            for _ in 0..20 {
                let a = BigUint::random_below(&mut rng, &m);
                let b = BigUint::random_below(&mut rng, &m);
                let got = ctx.from_mont(&ctx.mont_mul(&ctx.to_mont(&a), &ctx.to_mont(&b)));
                assert_eq!(got, a.mul_mod(&b, &m));
            }
        }
    }

    #[test]
    fn mod_pow_matches_small() {
        let ctx = MontCtx::new(big(497));
        assert_eq!(ctx.mod_pow(&big(4), &big(13)), big(445));
        assert_eq!(ctx.mod_pow(&big(4), &BigUint::zero()), BigUint::one());
        assert_eq!(ctx.mod_pow(&big(0), &big(5)), BigUint::zero());
    }

    #[test]
    fn mod_pow_matches_naive_random() {
        let mut r = Xoshiro256::seed_from_u64(3);
        for _ in 0..100 {
            let m = (r.next_u64() % 100_000) | 1;
            if m < 3 {
                continue;
            }
            let base = r.next_u64() % m;
            let exp = r.next_u64() % 64;
            let naive = {
                let mut acc: u128 = 1;
                for _ in 0..exp {
                    acc = acc * base as u128 % m as u128;
                }
                acc as u64
            };
            let ctx = MontCtx::new(big(m as u128));
            assert_eq!(
                ctx.mod_pow(&big(base as u128), &big(exp as u128)),
                big(naive as u128),
                "base={base} exp={exp} m={m}"
            );
        }
    }

    #[test]
    fn mod_pow_large_exponent_consistency() {
        // a^(e1+e2) == a^e1 · a^e2 mod m — catches windowing bugs at width
        // boundaries without needing an external oracle.
        let mut rng = ChaCha20Rng::from_u64(4);
        let m = random_odd(&mut rng, 768);
        let ctx = MontCtx::new(m.clone());
        for _ in 0..10 {
            let a = BigUint::random_below(&mut rng, &m);
            let e1 = BigUint::random_bits(&mut rng, 300);
            let e2 = BigUint::random_bits(&mut rng, 300);
            let lhs = ctx.mod_pow(&a, &e1.add(&e2));
            let rhs = ctx.mod_pow(&a, &e1).mul_mod(&ctx.mod_pow(&a, &e2), &m);
            assert_eq!(lhs, rhs);
        }
    }

    #[test]
    fn inverse_binary_matches_euclid() {
        let mut rng = ChaCha20Rng::from_u64(5);
        for bits in [64usize, 256, 1024] {
            let m = random_odd(&mut rng, bits);
            for _ in 0..20 {
                let a = BigUint::random_below(&mut rng, &m);
                let bin = inv_mod_odd(&a, &m);
                let euc = a.mod_inverse(&m);
                assert_eq!(bin, euc);
                if let Some(inv) = bin {
                    assert_eq!(a.mul_mod(&inv, &m), BigUint::one());
                }
            }
        }
    }

    #[test]
    fn mont_inverse_stays_in_domain() {
        let mut rng = ChaCha20Rng::from_u64(6);
        let m = random_odd(&mut rng, 512);
        let ctx = MontCtx::new(m.clone());
        for _ in 0..20 {
            let a = BigUint::random_below(&mut rng, &m);
            if a.gcd(&m).is_one() {
                let am = ctx.to_mont(&a);
                let inv = ctx.mont_inverse(&am).unwrap();
                let prod = ctx.from_mont(&ctx.mont_mul(&am, &inv));
                assert!(prod.is_one(), "a·a⁻¹ ≠ 1");
            }
        }
    }

    #[test]
    fn mont_pow_in_domain_matches() {
        let mut rng = ChaCha20Rng::from_u64(7);
        let m = random_odd(&mut rng, 256);
        let ctx = MontCtx::new(m.clone());
        let a = BigUint::random_below(&mut rng, &m);
        let e = BigUint::random_bits(&mut rng, 100);
        let via_domain = ctx.from_mont(&ctx.mont_pow(&ctx.to_mont(&a), &e));
        assert_eq!(via_domain, ctx.mod_pow(&a, &e));
    }
}
