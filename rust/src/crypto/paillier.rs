//! The Paillier additively homomorphic cryptosystem (Paillier, 1999) —
//! the default HE schema of SecureBoost / SecureBoost+.
//!
//! - Encryption uses the standard `g = n + 1` optimization:
//!   `Enc(m) = (1 + m·n) · rⁿ mod n²` — one big multiplication plus the
//!   obfuscation exponentiation.
//! - Decryption uses CRT over p² and q² (≈ 4× faster than the direct
//!   `c^λ mod n²` form).
//! - Ciphertexts are kept **in the Montgomery domain of n²** for their
//!   whole life: homomorphic addition is then exactly one Montgomery
//!   multiplication (the hot op of ciphertext histogram building), and
//!   scalar multiplication / negation are windowed Montgomery
//!   exponentiation / binary inversion.
//! - *Fast obfuscation* (DJN-style, on by default for training; exact
//!   `rⁿ` available via [`PaillierPub::obfuscator_full`]): a public
//!   `h = r₀ⁿ mod n²` is published and encryption draws `h^ρ` with a short
//!   (256-bit) exponent ρ. This is the same short-exponent optimization
//!   production FL stacks use to make million-row encryption tractable.

use super::bigint::BigUint;
use super::mont::{MontCtx, MontInt};
use super::prime::gen_prime;
use crate::util::rng::ChaCha20Rng;
use std::sync::Arc;

/// Bits of the short obfuscation exponent (fast mode).
const FAST_OBF_BITS: usize = 256;

/// Size of the precomputed obfuscator pool (perf mode, see below).
const OBF_POOL: usize = 64;
/// Pool elements multiplied per encryption.
const OBF_DRAW: usize = 3;

/// Public key + shared Montgomery context for n².
#[derive(Clone, Debug)]
pub struct PaillierPub {
    /// Modulus `n = p·q`.
    pub n: BigUint,
    /// `n²`, the ciphertext modulus.
    pub n_squared: BigUint,
    /// Montgomery context modulo n² — shared by every ciphertext op.
    pub ctx: Arc<MontCtx>,
    /// Montgomery context modulo n (used by decrypt CRT recombination).
    pub key_bits: usize,
    /// `h = r₀ⁿ mod n²` in Montgomery form; base for fast obfuscation.
    h_mont: MontInt,
    /// Precomputed obfuscator pool: `h^ρᵢ` for random 256-bit ρᵢ. An
    /// encryption draws the product of [`OBF_DRAW`] random pool entries —
    /// ~3 Montgomery multiplications instead of a ~330-multiplication
    /// windowed exponentiation (§Perf iteration 2; the classic
    /// precomputed-randomizer trade-off — weaker randomizer entropy than
    /// a fresh exponent, documented in DESIGN.md §Perf; use
    /// [`Self::encrypt_exact`] when full-strength obfuscation is needed).
    obf_pool: Vec<MontInt>,
}

/// Secret key (CRT form).
#[derive(Clone, Debug)]
pub struct PaillierSk {
    /// First prime factor.
    pub p: BigUint,
    /// Second prime factor.
    pub q: BigUint,
    p_squared: BigUint,
    q_squared: BigUint,
    ctx_p2: Arc<MontCtx>,
    ctx_q2: Arc<MontCtx>,
    /// `hp = L_p(g^(p-1) mod p²)⁻¹ mod p`
    hp: BigUint,
    hq: BigUint,
    /// `q⁻¹ mod p` for CRT recombination.
    q_inv_p: BigUint,
}

/// A Paillier ciphertext: a Montgomery-domain residue mod n².
pub type PaillierCt = MontInt;

/// Generate a key pair; `key_bits` is the bit length of `n` (1024/2048).
pub fn keygen(key_bits: usize, rng: &mut ChaCha20Rng) -> (PaillierPub, PaillierSk) {
    assert!(key_bits >= 128, "key too small");
    let half = key_bits / 2;
    let (p, q, n) = loop {
        let p = gen_prime(half, rng);
        let q = gen_prime(key_bits - half, rng);
        if p == q {
            continue;
        }
        let n = p.mul(&q);
        if n.bit_length() == key_bits {
            break (p, q, n);
        }
    };
    let n_squared = n.square();
    let ctx = Arc::new(MontCtx::new(n_squared.clone()));

    // Fast-obfuscation base: h = r0^n mod n², r0 random.
    let r0 = BigUint::random_below(rng, &n);
    let h = ctx.mod_pow(&r0, &n);
    let h_mont = ctx.to_mont(&h);
    let obf_pool: Vec<MontInt> = (0..OBF_POOL)
        .map(|_| {
            let rho = BigUint::random_bits(rng, FAST_OBF_BITS);
            ctx.mont_pow(&h_mont, &rho)
        })
        .collect();

    let p_squared = p.square();
    let q_squared = q.square();
    let ctx_p2 = Arc::new(MontCtx::new(p_squared.clone()));
    let ctx_q2 = Arc::new(MontCtx::new(q_squared.clone()));

    // hp = L_p(g^(p-1) mod p²)⁻¹ mod p with g = n+1, L_p(x) = (x-1)/p.
    let g = n.add_u64(1);
    let p_minus_1 = p.sub(&BigUint::one());
    let q_minus_1 = q.sub(&BigUint::one());
    let l_p = |x: &BigUint| x.sub(&BigUint::one()).div_rem(&p).0;
    let l_q = |x: &BigUint| x.sub(&BigUint::one()).div_rem(&q).0;
    let hp = l_p(&ctx_p2.mod_pow(&g, &p_minus_1))
        .mod_inverse(&p)
        .expect("hp invertible");
    let hq = l_q(&ctx_q2.mod_pow(&g, &q_minus_1))
        .mod_inverse(&q)
        .expect("hq invertible");
    let q_inv_p = q.mod_inverse(&p).expect("q invertible mod p");

    let pk = PaillierPub { n, n_squared, ctx, key_bits, h_mont, obf_pool };
    let sk = PaillierSk { p, q, p_squared, q_squared, ctx_p2, ctx_q2, hp, hq, q_inv_p };
    (pk, sk)
}

impl PaillierPub {
    /// Rebuild a *host-side* public key from its wire form: the modulus
    /// `n` plus the declared key length. Reconstructs the n² Montgomery
    /// context so all homomorphic ops work; the obfuscation material
    /// (`h`, pool) is **not** transferred — hosts only ever add/scale
    /// ciphertexts, never encrypt, so the pool stays empty and the
    /// pooled/fast encryption paths panic on such a key (`encrypt_exact`
    /// would still obfuscate correctly via a full-size `rⁿ`).
    pub fn public_from_parts(n: BigUint, key_bits: usize) -> Self {
        assert!(!n.is_even() && !n.is_zero(), "paillier modulus must be odd");
        let n_squared = n.square();
        let ctx = Arc::new(MontCtx::new(n_squared.clone()));
        let h_mont = ctx.mont_one();
        PaillierPub { n, n_squared, ctx, key_bits, h_mont, obf_pool: Vec::new() }
    }

    /// Plaintext bit capacity ι (values up to n−1; we use bit_length(n)−1
    /// to be safe against wraparound).
    pub fn plaintext_bits(&self) -> usize {
        self.n.bit_length() - 1
    }

    /// Serialized ciphertext size in bytes (a residue mod n²).
    pub fn ct_byte_len(&self) -> usize {
        self.n_squared.byte_len()
    }

    /// `(1 + m·n) mod n²` in Montgomery form — the unobfuscated payload.
    fn payload(&self, m: &BigUint) -> MontInt {
        debug_assert!(
            m.bit_length() <= self.plaintext_bits(),
            "plaintext overflow: {} > {} bits",
            m.bit_length(),
            self.plaintext_bits()
        );
        let body = BigUint::one().add(&m.mul(&self.n)).rem(&self.n_squared);
        self.ctx.to_mont(&body)
    }

    /// Fast obfuscator: `h^ρ mod n²`, ρ short random exponent.
    pub fn obfuscator_fast(&self, rng: &mut ChaCha20Rng) -> MontInt {
        assert!(
            !self.obf_pool.is_empty(),
            "wire-reconstructed public key has no obfuscation base (hosts never encrypt)"
        );
        let rho = BigUint::random_bits(rng, FAST_OBF_BITS);
        self.ctx.mont_pow(&self.h_mont, &rho)
    }

    /// Pooled obfuscator: product of [`OBF_DRAW`] random pool entries —
    /// ~3 mont_muls (§Perf). Default for bulk training encryption.
    pub fn obfuscator_pooled(&self, rng: &mut ChaCha20Rng) -> MontInt {
        assert!(
            !self.obf_pool.is_empty(),
            "wire-reconstructed public key has no obfuscator pool (hosts never encrypt)"
        );
        let mut acc = self.obf_pool[(rng.next_u64() % OBF_POOL as u64) as usize].clone();
        for _ in 1..OBF_DRAW {
            let idx = (rng.next_u64() % OBF_POOL as u64) as usize;
            self.ctx.mont_mul_assign(&mut acc, &self.obf_pool[idx]);
        }
        acc
    }

    /// Exact obfuscator `rⁿ mod n²` with full-size random `r` (slow path).
    pub fn obfuscator_full(&self, rng: &mut ChaCha20Rng) -> MontInt {
        let r = BigUint::random_below(rng, &self.n);
        self.ctx.to_mont(&self.ctx.mod_pow(&r, &self.n))
    }

    /// Encrypt with a caller-provided obfuscator (lets the encryption loop
    /// draw obfuscators from a precomputed pool).
    pub fn encrypt_with(&self, m: &BigUint, obf: &MontInt) -> PaillierCt {
        self.ctx.mont_mul(&self.payload(m), obf)
    }

    /// Encrypt with a pooled obfuscator (bulk/training default).
    pub fn encrypt(&self, m: &BigUint, rng: &mut ChaCha20Rng) -> PaillierCt {
        let obf = self.obfuscator_pooled(rng);
        self.encrypt_with(m, &obf)
    }

    /// Encrypt with a fresh short-exponent obfuscator (`h^ρ`, ρ 256-bit).
    pub fn encrypt_fresh(&self, m: &BigUint, rng: &mut ChaCha20Rng) -> PaillierCt {
        let obf = self.obfuscator_fast(rng);
        self.encrypt_with(m, &obf)
    }

    /// Encrypt with an exact full-size `rⁿ` obfuscator (slow path).
    pub fn encrypt_exact(&self, m: &BigUint, rng: &mut ChaCha20Rng) -> PaillierCt {
        let obf = self.obfuscator_full(rng);
        self.encrypt_with(m, &obf)
    }

    /// Homomorphic addition of plaintexts = multiplication of ciphertexts.
    #[inline]
    pub fn add(&self, a: &PaillierCt, b: &PaillierCt) -> PaillierCt {
        self.ctx.mont_mul(a, b)
    }

    /// In-place homomorphic addition.
    #[inline]
    pub fn add_assign(&self, a: &mut PaillierCt, b: &PaillierCt) {
        self.ctx.mont_mul_assign(a, b);
    }

    /// Homomorphic scalar multiplication: `Enc(k·m) = Enc(m)^k`.
    pub fn scalar_mul(&self, c: &PaillierCt, k: &BigUint) -> PaillierCt {
        self.ctx.mont_pow(c, k)
    }

    /// `Enc(2^bits · m)` — the cipher-compression shift; pure squarings.
    pub fn scalar_pow2(&self, c: &PaillierCt, bits: usize) -> PaillierCt {
        self.ctx.mont_pow2k(c, bits)
    }

    /// Homomorphic negation: `Enc(-m) = Enc(m)⁻¹ mod n²`
    /// (the plaintext becomes `n − m`). Used by histogram subtraction.
    pub fn negate(&self, c: &PaillierCt) -> PaillierCt {
        self.ctx.mont_inverse(c).expect("ciphertext invertible")
    }

    /// `a − b` on plaintexts (requires the true difference to be
    /// non-negative, which histogram subtraction guarantees).
    pub fn sub(&self, a: &PaillierCt, b: &PaillierCt) -> PaillierCt {
        self.add(a, &self.negate(b))
    }

    /// Encryption of zero without obfuscation (identity element).
    pub fn zero_ct(&self) -> PaillierCt {
        self.ctx.mont_one()
    }

    /// Standard-form residue (for wire serialization).
    pub fn ct_to_bytes(&self, c: &PaillierCt) -> Vec<u8> {
        self.ctx.from_mont(c).to_bytes_be()
    }

    /// Rebuild a ciphertext from its standard-form wire bytes.
    pub fn ct_from_bytes(&self, bytes: &[u8]) -> PaillierCt {
        self.ctx.to_mont(&BigUint::from_bytes_be(bytes))
    }
}

impl PaillierSk {
    /// CRT decryption. Returns the plaintext in `[0, n)`.
    pub fn decrypt(&self, pk: &PaillierPub, c: &PaillierCt) -> BigUint {
        let c_std = pk.ctx.from_mont(c);
        let p_minus_1 = self.p.sub(&BigUint::one());
        let q_minus_1 = self.q.sub(&BigUint::one());

        // m_p = L_p(c^(p-1) mod p²)·hp mod p
        let cp = c_std.rem(&self.p_squared);
        let cq = c_std.rem(&self.q_squared);
        let xp = self.ctx_p2.mod_pow(&cp, &p_minus_1);
        let xq = self.ctx_q2.mod_pow(&cq, &q_minus_1);
        let lp = xp.sub(&BigUint::one()).div_rem(&self.p).0;
        let lq = xq.sub(&BigUint::one()).div_rem(&self.q).0;
        let mp = lp.mul_mod(&self.hp, &self.p);
        let mq = lq.mul_mod(&self.hq, &self.q);

        // CRT: m = mq + q·((mp − mq)·q⁻¹ mod p)
        let diff = mp.sub_mod(&mq.rem(&self.p), &self.p);
        let t = diff.mul_mod(&self.q_inv_p, &self.p);
        mq.add(&self.q.mul(&t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(bits: usize, seed: u64) -> (PaillierPub, PaillierSk, ChaCha20Rng) {
        let mut rng = ChaCha20Rng::from_u64(seed);
        let (pk, sk) = keygen(bits, &mut rng);
        (pk, sk, rng)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (pk, sk, mut rng) = setup(512, 1);
        for v in [0u64, 1, 2, 53, u32::MAX as u64, u64::MAX] {
            let m = BigUint::from_u64(v);
            let c = pk.encrypt(&m, &mut rng);
            assert_eq!(sk.decrypt(&pk, &c), m, "v={v}");
        }
    }

    #[test]
    fn full_obfuscation_roundtrip() {
        let (pk, sk, mut rng) = setup(512, 2);
        let m = BigUint::from_u64(123456789);
        let obf = pk.obfuscator_full(&mut rng);
        let c = pk.encrypt_with(&m, &obf);
        assert_eq!(sk.decrypt(&pk, &c), m);
    }

    #[test]
    fn homomorphic_addition() {
        let (pk, sk, mut rng) = setup(512, 3);
        let a = BigUint::from_u64(11111);
        let b = BigUint::from_u64(22222);
        let ca = pk.encrypt(&a, &mut rng);
        let cb = pk.encrypt(&b, &mut rng);
        let sum = pk.add(&ca, &cb);
        assert_eq!(sk.decrypt(&pk, &sum), BigUint::from_u64(33333));
    }

    #[test]
    fn homomorphic_scalar_mul() {
        let (pk, sk, mut rng) = setup(512, 4);
        let m = BigUint::from_u64(777);
        let c = pk.encrypt(&m, &mut rng);
        let c3 = pk.scalar_mul(&c, &BigUint::from_u64(1000));
        assert_eq!(sk.decrypt(&pk, &c3), BigUint::from_u64(777_000));
    }

    #[test]
    fn negation_and_subtraction() {
        let (pk, sk, mut rng) = setup(512, 5);
        let a = BigUint::from_u64(5000);
        let b = BigUint::from_u64(1234);
        let ca = pk.encrypt(&a, &mut rng);
        let cb = pk.encrypt(&b, &mut rng);
        let diff = pk.sub(&ca, &cb);
        assert_eq!(sk.decrypt(&pk, &diff), BigUint::from_u64(3766));
        // negate alone: Dec(-b) = n − b
        let neg = pk.negate(&cb);
        assert_eq!(sk.decrypt(&pk, &neg), pk.n.sub(&b));
    }

    #[test]
    fn zero_ct_is_identity() {
        let (pk, sk, mut rng) = setup(512, 6);
        let m = BigUint::from_u64(42);
        let c = pk.encrypt(&m, &mut rng);
        let s = pk.add(&c, &pk.zero_ct());
        assert_eq!(sk.decrypt(&pk, &s), m);
        assert_eq!(sk.decrypt(&pk, &pk.zero_ct()), BigUint::zero());
    }

    #[test]
    fn large_plaintexts_near_capacity() {
        let (pk, sk, mut rng) = setup(512, 7);
        let bits = pk.plaintext_bits();
        let m = BigUint::random_bits(&mut rng, bits - 1);
        let c = pk.encrypt(&m, &mut rng);
        assert_eq!(sk.decrypt(&pk, &c), m);
    }

    #[test]
    fn add_assign_matches_add() {
        let (pk, sk, mut rng) = setup(512, 8);
        let a = BigUint::from_u64(10);
        let b = BigUint::from_u64(20);
        let ca = pk.encrypt(&a, &mut rng);
        let cb = pk.encrypt(&b, &mut rng);
        let mut acc = ca.clone();
        pk.add_assign(&mut acc, &cb);
        assert_eq!(sk.decrypt(&pk, &acc), sk.decrypt(&pk, &pk.add(&ca, &cb)));
    }

    #[test]
    fn wire_roundtrip() {
        let (pk, sk, mut rng) = setup(512, 9);
        let m = BigUint::from_u64(987654321);
        let c = pk.encrypt(&m, &mut rng);
        let bytes = pk.ct_to_bytes(&c);
        assert!(bytes.len() <= pk.ct_byte_len());
        let c2 = pk.ct_from_bytes(&bytes);
        assert_eq!(sk.decrypt(&pk, &c2), m);
    }

    #[test]
    fn public_from_parts_operates_on_ciphertexts() {
        // the host's wire-reconstructed key must interoperate with
        // ciphertexts produced (and later decrypted) by the full key
        let (pk, sk, mut rng) = setup(512, 11);
        let host_pk = PaillierPub::public_from_parts(pk.n.clone(), pk.key_bits);
        assert_eq!(host_pk.ct_byte_len(), pk.ct_byte_len());
        assert_eq!(host_pk.plaintext_bits(), pk.plaintext_bits());
        let a = pk.encrypt(&BigUint::from_u64(70), &mut rng);
        let b = pk.encrypt(&BigUint::from_u64(5), &mut rng);
        let sum = host_pk.add(&a, &b);
        assert_eq!(sk.decrypt(&pk, &sum), BigUint::from_u64(75));
        let diff = host_pk.sub(&a, &b);
        assert_eq!(sk.decrypt(&pk, &diff), BigUint::from_u64(65));
        let bytes = host_pk.ct_to_bytes(&sum);
        assert_eq!(
            sk.decrypt(&pk, &host_pk.ct_from_bytes(&bytes)),
            BigUint::from_u64(75)
        );
    }

    #[test]
    fn ciphertexts_are_randomized() {
        let (pk, _sk, mut rng) = setup(512, 10);
        let m = BigUint::from_u64(5);
        let c1 = pk.encrypt(&m, &mut rng);
        let c2 = pk.encrypt(&m, &mut rng);
        assert_ne!(pk.ct_to_bytes(&c1), pk.ct_to_bytes(&c2));
    }
}
