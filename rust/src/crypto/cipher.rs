//! `CipherSuite` — the single abstraction the trainer talks to for all
//! homomorphic operations, dispatching to Paillier, IterativeAffine, or a
//! `Plain` mock (tests/ablation only).
//!
//! Every operation is counted in the global [`OpCounters`] so the cost
//! model of the paper (§4.1/§4.6: homomorphic-op, enc/dec and
//! communication counts) can be *measured* rather than estimated — the
//! `ablations` bench compares these counters against the paper's formulas.

use super::bigint::BigUint;
use super::iterative_affine::{AffineCt, AffineKey, AffinePub};
use super::paillier::{keygen as paillier_keygen, PaillierCt, PaillierPub, PaillierSk};
use crate::util::pool::parallel_for_chunks;
use crate::util::rng::ChaCha20Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A ciphertext under whichever schema the suite was built with.
#[derive(Clone, Debug, PartialEq)]
pub enum Ct {
    /// Paillier ciphertext (Montgomery form mod n²).
    Paillier(PaillierCt),
    /// Iterative-affine ciphertext (residue mod n).
    Affine(AffineCt),
    /// Plaintext passthrough (mock cipher for tests and the "no crypto
    /// overhead" ablation lower bound). Value stored mod 2^bits.
    Plain(BigUint),
}

/// Global homomorphic-operation counters (process-wide, reset per bench).
#[derive(Debug, Default)]
pub struct OpCounters {
    /// Encryptions performed.
    pub encrypts: AtomicU64,
    /// Decryptions performed.
    pub decrypts: AtomicU64,
    /// Homomorphic additions.
    pub adds: AtomicU64,
    /// Homomorphic scalar multiplications (incl. pow-2 shifts).
    pub scalar_muls: AtomicU64,
    /// Homomorphic negations.
    pub negates: AtomicU64,
}

/// Snapshot of [`OpCounters`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct OpSnapshot {
    /// Encryptions performed.
    pub encrypts: u64,
    /// Decryptions performed.
    pub decrypts: u64,
    /// Homomorphic additions.
    pub adds: u64,
    /// Homomorphic scalar multiplications (incl. pow-2 shifts).
    pub scalar_muls: u64,
    /// Homomorphic negations.
    pub negates: u64,
}

/// The process-wide homomorphic-operation counters.
pub static OPS: OpCounters = OpCounters {
    encrypts: AtomicU64::new(0),
    decrypts: AtomicU64::new(0),
    adds: AtomicU64::new(0),
    scalar_muls: AtomicU64::new(0),
    negates: AtomicU64::new(0),
};

impl OpCounters {
    /// Point-in-time copy of the counters.
    pub fn snapshot(&self) -> OpSnapshot {
        OpSnapshot {
            encrypts: self.encrypts.load(Ordering::Relaxed),
            decrypts: self.decrypts.load(Ordering::Relaxed),
            adds: self.adds.load(Ordering::Relaxed),
            scalar_muls: self.scalar_muls.load(Ordering::Relaxed),
            negates: self.negates.load(Ordering::Relaxed),
        }
    }

    /// Zero all counters.
    pub fn reset(&self) {
        self.encrypts.store(0, Ordering::Relaxed);
        self.decrypts.store(0, Ordering::Relaxed);
        self.adds.store(0, Ordering::Relaxed);
        self.scalar_muls.store(0, Ordering::Relaxed);
        self.negates.store(0, Ordering::Relaxed);
    }
}

impl OpSnapshot {
    /// Counter deltas since `earlier`.
    pub fn diff(&self, earlier: &OpSnapshot) -> OpSnapshot {
        OpSnapshot {
            encrypts: self.encrypts - earlier.encrypts,
            decrypts: self.decrypts - earlier.decrypts,
            adds: self.adds - earlier.adds,
            scalar_muls: self.scalar_muls - earlier.scalar_muls,
            negates: self.negates - earlier.negates,
        }
    }
}

/// The cipher abstraction. The guest holds the secret material; the
/// "public side" clone handed to hosts can perform only homomorphic ops.
#[derive(Clone, Debug)]
pub enum CipherSuite {
    /// Paillier (the paper's default schema).
    Paillier {
        pk: Arc<PaillierPub>,
        sk: Option<Arc<PaillierSk>>,
    },
    /// FATE-style iterative affine cipher.
    Affine {
        pubp: AffinePub,
        key: Option<Arc<AffineKey>>,
    },
    /// No encryption — tests and ablation lower bound only.
    Plain {
        bits: usize,
        modulus: BigUint,
    },
}

impl CipherSuite {
    /// Generate a fresh Paillier suite (guest side, with secret key).
    pub fn new_paillier(key_bits: usize, rng: &mut ChaCha20Rng) -> Self {
        let (pk, sk) = paillier_keygen(key_bits, rng);
        CipherSuite::Paillier { pk: Arc::new(pk), sk: Some(Arc::new(sk)) }
    }

    /// Generate a fresh iterative-affine suite (guest side).
    pub fn new_affine(key_bits: usize, rng: &mut ChaCha20Rng) -> Self {
        let key = AffineKey::generate(key_bits, rng);
        CipherSuite::Affine { pubp: key.public(), key: Some(Arc::new(key)) }
    }

    /// Mock cipher: no crypto, plaintext space of `bits` bits. Tests only.
    pub fn new_plain(bits: usize) -> Self {
        CipherSuite::Plain { bits, modulus: BigUint::one().shl(bits) }
    }

    /// The view a host party receives: no secret key material.
    pub fn public_side(&self) -> Self {
        match self {
            CipherSuite::Paillier { pk, .. } => {
                CipherSuite::Paillier { pk: pk.clone(), sk: None }
            }
            CipherSuite::Affine { pubp, .. } => {
                CipherSuite::Affine { pubp: pubp.clone(), key: None }
            }
            CipherSuite::Plain { bits, modulus } => {
                CipherSuite::Plain { bits: *bits, modulus: modulus.clone() }
            }
        }
    }

    /// Schema name for logs and reports.
    pub fn kind_name(&self) -> &'static str {
        match self {
            CipherSuite::Paillier { .. } => "paillier",
            CipherSuite::Affine { .. } => "iterative-affine",
            CipherSuite::Plain { .. } => "plain",
        }
    }

    /// Plaintext capacity ι in bits (paper notation).
    pub fn plaintext_bits(&self) -> usize {
        match self {
            CipherSuite::Paillier { pk, .. } => pk.plaintext_bits(),
            CipherSuite::Affine { pubp, .. } => pubp.plaintext_bits(),
            CipherSuite::Plain { bits, .. } => *bits,
        }
    }

    /// Serialized ciphertext size (for the transport's byte accounting).
    pub fn ct_byte_len(&self) -> usize {
        match self {
            CipherSuite::Paillier { pk, .. } => pk.ct_byte_len(),
            CipherSuite::Affine { pubp, .. } => pubp.ct_byte_len(),
            CipherSuite::Plain { bits, .. } => bits.div_ceil(8),
        }
    }

    /// Encrypt one plaintext (guest side).
    pub fn encrypt(&self, m: &BigUint, rng: &mut ChaCha20Rng) -> Ct {
        OPS.encrypts.fetch_add(1, Ordering::Relaxed);
        match self {
            CipherSuite::Paillier { pk, .. } => Ct::Paillier(pk.encrypt(m, rng)),
            CipherSuite::Affine { key, .. } => {
                Ct::Affine(key.as_ref().expect("guest-side suite required").encrypt(m))
            }
            CipherSuite::Plain { bits, .. } => Ct::Plain(m.low_bits(*bits)),
        }
    }

    /// Parallel batch encryption (the paper's g/h synchronization step).
    pub fn encrypt_batch(&self, ms: &[BigUint], rng: &mut ChaCha20Rng) -> Vec<Ct> {
        // Derive independent per-chunk streams from the master rng so the
        // batch is deterministic given the caller's rng state.
        let master = rng.next_u64();
        let n = ms.len();
        let mut out: Vec<Ct> = Vec::with_capacity(n);
        let slots_ptr = SendSlice(out.spare_capacity_mut().as_mut_ptr());
        parallel_for_chunks(n, move |start, end| {
            let slots_ptr = slots_ptr; // capture the Send/Sync wrapper whole
            let mut local = ChaCha20Rng::from_u64(master ^ (start as u64).wrapping_mul(0x9E3779B97F4A7C15));
            for i in start..end {
                let c = self.encrypt(&ms[i], &mut local);
                // SAFETY: disjoint indices; each written exactly once.
                unsafe {
                    (*slots_ptr.0.add(i)).write(c);
                }
            }
        });
        // SAFETY: all n slots initialized above.
        unsafe { out.set_len(n) };
        out
    }

    /// Decrypt one ciphertext (requires the secret material).
    pub fn decrypt(&self, c: &Ct) -> BigUint {
        OPS.decrypts.fetch_add(1, Ordering::Relaxed);
        match (self, c) {
            (CipherSuite::Paillier { pk, sk }, Ct::Paillier(ct)) => {
                sk.as_ref().expect("secret key required").decrypt(pk, ct)
            }
            (CipherSuite::Affine { key, .. }, Ct::Affine(ct)) => {
                key.as_ref().expect("secret key required").decrypt(ct)
            }
            (CipherSuite::Plain { .. }, Ct::Plain(v)) => v.clone(),
            _ => panic!("ciphertext kind does not match cipher suite"),
        }
    }

    /// Parallel batch decryption (split-info recovery step).
    pub fn decrypt_batch(&self, cs: &[Ct]) -> Vec<BigUint> {
        let n = cs.len();
        let mut out: Vec<BigUint> = Vec::with_capacity(n);
        let slots_ptr = SendSlice(out.spare_capacity_mut().as_mut_ptr());
        parallel_for_chunks(n, move |start, end| {
            let slots_ptr = slots_ptr; // capture the Send/Sync wrapper whole
            for i in start..end {
                let v = self.decrypt(&cs[i]);
                unsafe {
                    (*slots_ptr.0.add(i)).write(v);
                }
            }
        });
        unsafe { out.set_len(n) };
        out
    }

    /// Homomorphic addition of plaintexts.
    #[inline]
    pub fn add(&self, a: &Ct, b: &Ct) -> Ct {
        OPS.adds.fetch_add(1, Ordering::Relaxed);
        match (self, a, b) {
            (CipherSuite::Paillier { pk, .. }, Ct::Paillier(x), Ct::Paillier(y)) => {
                Ct::Paillier(pk.add(x, y))
            }
            (CipherSuite::Affine { pubp, .. }, Ct::Affine(x), Ct::Affine(y)) => {
                Ct::Affine(pubp.add(x, y))
            }
            (CipherSuite::Plain { modulus, .. }, Ct::Plain(x), Ct::Plain(y)) => {
                Ct::Plain(x.add_mod(y, modulus))
            }
            _ => panic!("ciphertext kind mismatch"),
        }
    }

    /// In-place homomorphic addition.
    #[inline]
    pub fn add_assign(&self, a: &mut Ct, b: &Ct) {
        OPS.adds.fetch_add(1, Ordering::Relaxed);
        match (self, a, b) {
            (CipherSuite::Paillier { pk, .. }, Ct::Paillier(x), Ct::Paillier(y)) => {
                pk.add_assign(x, y)
            }
            (CipherSuite::Affine { pubp, .. }, Ct::Affine(x), Ct::Affine(y)) => {
                pubp.add_assign(x, y)
            }
            (CipherSuite::Plain { modulus, .. }, Ct::Plain(x), Ct::Plain(y)) => {
                *x = x.add_mod(y, modulus)
            }
            _ => panic!("ciphertext kind mismatch"),
        }
    }

    /// Homomorphic scalar multiplication `Enc(k·m)`.
    pub fn scalar_mul(&self, c: &Ct, k: &BigUint) -> Ct {
        OPS.scalar_muls.fetch_add(1, Ordering::Relaxed);
        match (self, c) {
            (CipherSuite::Paillier { pk, .. }, Ct::Paillier(x)) => {
                Ct::Paillier(pk.scalar_mul(x, k))
            }
            (CipherSuite::Affine { pubp, .. }, Ct::Affine(x)) => {
                Ct::Affine(pubp.scalar_mul(x, k))
            }
            (CipherSuite::Plain { modulus, .. }, Ct::Plain(x)) => {
                Ct::Plain(x.mul(k).rem(modulus))
            }
            _ => panic!("ciphertext kind mismatch"),
        }
    }

    /// `2^bits · m` — the compression shift (paper Alg. 4's `e × 2^b_gh`).
    pub fn scalar_pow2(&self, c: &Ct, bits: usize) -> Ct {
        OPS.scalar_muls.fetch_add(1, Ordering::Relaxed);
        match (self, c) {
            (CipherSuite::Paillier { pk, .. }, Ct::Paillier(x)) => {
                Ct::Paillier(pk.scalar_pow2(x, bits))
            }
            (CipherSuite::Affine { pubp, .. }, Ct::Affine(x)) => {
                Ct::Affine(pubp.scalar_mul(x, &BigUint::one().shl(bits)))
            }
            (CipherSuite::Plain { modulus, .. }, Ct::Plain(x)) => {
                Ct::Plain(x.shl(bits).rem(modulus))
            }
            _ => panic!("ciphertext kind mismatch"),
        }
    }

    /// Estimated cost ratio negate/add — used by the cost-aware histogram
    /// subtraction planner (DESIGN.md §Perf iteration 1): ciphertext
    /// subtraction beats a direct rebuild only when the sibling has more
    /// than `n_bins × ratio` instances.
    pub fn negate_cost_ratio(&self) -> usize {
        match self {
            // measured: mont_inverse ≈ 474µs vs add ≈ 2.2µs at 1024-bit
            CipherSuite::Paillier { .. } => 220,
            CipherSuite::Affine { .. } => 2,
            CipherSuite::Plain { .. } => 2,
        }
    }

    /// Plaintext negation (mod the plaintext space). Histogram subtraction.
    pub fn negate(&self, c: &Ct) -> Ct {
        OPS.negates.fetch_add(1, Ordering::Relaxed);
        match (self, c) {
            (CipherSuite::Paillier { pk, .. }, Ct::Paillier(x)) => Ct::Paillier(pk.negate(x)),
            (CipherSuite::Affine { pubp, .. }, Ct::Affine(x)) => Ct::Affine(pubp.negate(x)),
            (CipherSuite::Plain { modulus, .. }, Ct::Plain(x)) => Ct::Plain(if x.is_zero() {
                BigUint::zero()
            } else {
                modulus.sub(x)
            }),
            _ => panic!("ciphertext kind mismatch"),
        }
    }

    /// `a − b` on plaintexts; correct when the true difference is ≥ 0.
    pub fn sub(&self, a: &Ct, b: &Ct) -> Ct {
        let nb = self.negate(b);
        self.add(a, &nb)
    }

    /// The additive identity (`Enc(0)` without obfuscation).
    pub fn zero_ct(&self) -> Ct {
        match self {
            CipherSuite::Paillier { pk, .. } => Ct::Paillier(pk.zero_ct()),
            CipherSuite::Affine { pubp, .. } => Ct::Affine(pubp.zero_ct()),
            CipherSuite::Plain { .. } => Ct::Plain(BigUint::zero()),
        }
    }

    /// Does this suite hold secret key material (guest side)?
    pub fn has_secret(&self) -> bool {
        match self {
            CipherSuite::Paillier { sk, .. } => sk.is_some(),
            CipherSuite::Affine { key, .. } => key.is_some(),
            CipherSuite::Plain { .. } => true,
        }
    }
}

struct SendSlice<T>(*mut T);
unsafe impl<T> Send for SendSlice<T> {}
unsafe impl<T> Sync for SendSlice<T> {}
impl<T> Clone for SendSlice<T> {
    fn clone(&self) -> Self {
        SendSlice(self.0)
    }
}
impl<T> Copy for SendSlice<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    fn suites() -> Vec<CipherSuite> {
        let mut rng = ChaCha20Rng::from_u64(99);
        vec![
            CipherSuite::new_paillier(512, &mut rng),
            CipherSuite::new_affine(512, &mut rng),
            CipherSuite::new_plain(511),
        ]
    }

    #[test]
    fn roundtrip_all_suites() {
        let mut rng = ChaCha20Rng::from_u64(1);
        for s in suites() {
            for v in [0u64, 1, 12345, u64::MAX] {
                let m = BigUint::from_u64(v);
                let c = s.encrypt(&m, &mut rng);
                assert_eq!(s.decrypt(&c), m, "suite={}", s.kind_name());
            }
        }
    }

    #[test]
    fn homomorphic_ops_all_suites() {
        let mut rng = ChaCha20Rng::from_u64(2);
        for s in suites() {
            let a = BigUint::from_u64(500);
            let b = BigUint::from_u64(300);
            let (ca, cb) = (s.encrypt(&a, &mut rng), s.encrypt(&b, &mut rng));
            assert_eq!(s.decrypt(&s.add(&ca, &cb)), BigUint::from_u64(800));
            assert_eq!(s.decrypt(&s.sub(&ca, &cb)), BigUint::from_u64(200));
            assert_eq!(
                s.decrypt(&s.scalar_mul(&ca, &BigUint::from_u64(4))),
                BigUint::from_u64(2000)
            );
            let mut acc = s.zero_ct();
            s.add_assign(&mut acc, &ca);
            s.add_assign(&mut acc, &cb);
            assert_eq!(s.decrypt(&acc), BigUint::from_u64(800));
        }
    }

    #[test]
    fn public_side_cannot_decrypt_paillier() {
        let mut rng = ChaCha20Rng::from_u64(3);
        let s = CipherSuite::new_paillier(512, &mut rng);
        let host = s.public_side();
        assert!(!host.has_secret());
        // host can still add
        let c = s.encrypt(&BigUint::from_u64(7), &mut rng);
        let sum = host.add(&c, &c);
        assert_eq!(s.decrypt(&sum), BigUint::from_u64(14));
    }

    #[test]
    fn batch_encrypt_decrypt() {
        let mut rng = ChaCha20Rng::from_u64(4);
        for s in suites() {
            let ms: Vec<BigUint> = (0..100u64).map(BigUint::from_u64).collect();
            let cts = s.encrypt_batch(&ms, &mut rng);
            let back = s.decrypt_batch(&cts);
            assert_eq!(back, ms, "suite={}", s.kind_name());
        }
    }

    #[test]
    fn op_counters_advance() {
        let mut rng = ChaCha20Rng::from_u64(5);
        let s = CipherSuite::new_plain(64);
        let before = OPS.snapshot();
        let c = s.encrypt(&BigUint::from_u64(1), &mut rng);
        let _ = s.add(&c, &c);
        let _ = s.decrypt(&c);
        let after = OPS.snapshot().diff(&before);
        assert!(after.encrypts >= 1 && after.adds >= 1 && after.decrypts >= 1);
    }
}
