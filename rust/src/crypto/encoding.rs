//! Fixed-point encoding of gradients/hessians (paper eq. 11):
//! `n_int = ⌊n_float · 2^r⌋` with precision `r` (default 53).
//!
//! Values must be non-negative at encoding time — the packer applies the
//! gradient offset `g_off` first (paper §4.2).

use super::bigint::BigUint;

/// Default fixed-point precision (the paper's `r = 53`).
pub const DEFAULT_PRECISION: u32 = 53;

/// Fixed-point encoder with precision `r` bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FixedPointEncoder {
    /// Fractional bits `r` (eq. 11).
    pub precision: u32,
}

impl Default for FixedPointEncoder {
    fn default() -> Self {
        Self { precision: DEFAULT_PRECISION }
    }
}

impl FixedPointEncoder {
    /// Encoder with the given precision (≤ 63).
    pub fn new(precision: u32) -> Self {
        assert!(precision <= 63, "precision too large");
        Self { precision }
    }

    /// Encode a non-negative float. Panics on negatives (offset first).
    pub fn encode(&self, x: f64) -> BigUint {
        assert!(x >= 0.0 && x.is_finite(), "encode requires finite x ≥ 0, got {x}");
        let scaled = x * 2f64.powi(self.precision as i32);
        // Values this system encodes are ≤ ~2·2^53 < 2^63; keep u128 headroom.
        BigUint::from_u128(scaled.round() as u128)
    }

    /// Decode an (aggregated) fixed-point integer back to f64.
    pub fn decode(&self, v: &BigUint) -> f64 {
        v.to_f64() / 2f64.powi(self.precision as i32)
    }

    /// Bit length needed for a sum of `n` encoded values each ≤ `max_val`
    /// (paper eq. 12–13).
    pub fn sum_bits(&self, max_val: f64, n: u64) -> usize {
        let imax = self.encode(max_val.max(0.0)).mul_u64(n.max(1));
        imax.bit_length().max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_precision() {
        let e = FixedPointEncoder::default();
        for x in [0.0, 1.0, 0.5, 0.123456789, 1.999999, 123.456] {
            let v = e.encode(x);
            assert!((e.decode(&v) - x).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn lower_precision_coarser() {
        let e = FixedPointEncoder::new(10);
        let v = e.encode(0.123456789);
        assert!((e.decode(&v) - 0.123456789).abs() < 1e-3);
    }

    #[test]
    fn sum_bits_matches_paper_example() {
        // Paper §4.4: n=1,000,000, r=53, g∈[-1,1] offset to [0,2] → b_g=74,
        // h∈[0,1] → b_h=73.
        let e = FixedPointEncoder::new(53);
        assert_eq!(e.sum_bits(2.0, 1_000_000), 74);
        assert_eq!(e.sum_bits(1.0, 1_000_000), 73);
    }

    #[test]
    #[should_panic]
    fn negative_rejected() {
        FixedPointEncoder::default().encode(-0.1);
    }

    #[test]
    fn zero_and_tiny() {
        let e = FixedPointEncoder::default();
        assert_eq!(e.decode(&e.encode(0.0)), 0.0);
        // below one ulp of the fixed-point grid decodes to 0
        let v = e.encode(1e-20);
        assert_eq!(v, BigUint::zero());
    }
}
