//! Probabilistic primality testing and random prime generation
//! (for Paillier / IterativeAffine key generation).

use super::bigint::BigUint;
use super::mont::MontCtx;
use crate::util::rng::ChaCha20Rng;

/// Small primes for trial division before Miller–Rabin.
const SMALL_PRIMES: [u64; 60] = [
    3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67, 71, 73, 79, 83, 89, 97,
    101, 103, 107, 109, 113, 127, 131, 137, 139, 149, 151, 157, 163, 167, 173, 179, 181, 191, 193,
    197, 199, 211, 223, 227, 229, 233, 239, 241, 251, 257, 263, 269, 271, 277, 281, 283,
];

/// Miller–Rabin with `rounds` random bases. For the key sizes used here
/// (≥ 256-bit primes) 20 rounds gives error < 2⁻⁴⁰ per the standard bound.
pub fn is_probable_prime(n: &BigUint, rounds: usize, rng: &mut ChaCha20Rng) -> bool {
    if n.is_zero() || n.is_one() {
        return false;
    }
    if n.is_even() {
        return *n == BigUint::from_u64(2);
    }
    for &p in &SMALL_PRIMES {
        let pb = BigUint::from_u64(p);
        if *n == pb {
            return true;
        }
        if n.rem(&pb).is_zero() {
            return false;
        }
    }
    // write n-1 = d * 2^s
    let n_minus_1 = n.sub(&BigUint::one());
    let s = {
        let mut s = 0usize;
        let mut d = n_minus_1.clone();
        while d.is_even() {
            d = d.shr(1);
            s += 1;
        }
        s
    };
    let d = n_minus_1.shr(s);
    let ctx = MontCtx::new(n.clone());
    let two = BigUint::from_u64(2);
    let upper = n.sub(&two); // bases in [2, n-2]
    'witness: for _ in 0..rounds {
        let a = BigUint::random_below(rng, &upper).add(&two);
        let mut x = ctx.mod_pow(&a, &d);
        if x.is_one() || x == n_minus_1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = x.mul_mod(&x, n);
            if x == n_minus_1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// Generate a random prime with exactly `bits` bits.
pub fn gen_prime(bits: usize, rng: &mut ChaCha20Rng) -> BigUint {
    assert!(bits >= 16, "prime size too small: {bits}");
    loop {
        let mut cand = BigUint::random_exact_bits(rng, bits);
        if cand.is_even() {
            cand = cand.add_u64(1);
        }
        // March forward over odd numbers from the random start; re-randomize
        // after a while to avoid biasing toward prime gaps.
        for _ in 0..200 {
            if is_probable_prime(&cand, 20, rng) {
                return cand;
            }
            cand = cand.add_u64(2);
            if cand.bit_length() != bits {
                break;
            }
        }
    }
}

/// Generate a prime `p` with `gcd(p-1, e) == 1` — not needed by Paillier
/// (which needs gcd(pq, (p-1)(q-1)) = 1, ensured by equal-size primes), but
/// used by tests to cross-check generator behaviour.
pub fn gen_prime_coprime(bits: usize, e: &BigUint, rng: &mut ChaCha20Rng) -> BigUint {
    loop {
        let p = gen_prime(bits, rng);
        if p.sub(&BigUint::one()).gcd(e).is_one() {
            return p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_primes_and_composites() {
        let mut rng = ChaCha20Rng::from_u64(1);
        for p in [2u64, 3, 5, 7, 97, 257, 65537, 2_147_483_647] {
            assert!(
                is_probable_prime(&BigUint::from_u64(p), 20, &mut rng),
                "{p} should be prime"
            );
        }
        for c in [1u64, 4, 9, 15, 341, 561, 645, 1105, 65535, 4_294_967_295] {
            assert!(
                !is_probable_prime(&BigUint::from_u64(c), 20, &mut rng),
                "{c} should be composite"
            );
        }
    }

    #[test]
    fn strong_pseudoprimes_rejected() {
        // Carmichael numbers & base-2 strong pseudoprimes.
        let mut rng = ChaCha20Rng::from_u64(2);
        for c in [2047u64, 3277, 4033, 8321, 15841, 29341, 252601, 3215031751] {
            assert!(
                !is_probable_prime(&BigUint::from_u64(c), 20, &mut rng),
                "{c} should be composite"
            );
        }
    }

    #[test]
    fn generated_primes_have_exact_bits() {
        let mut rng = ChaCha20Rng::from_u64(3);
        for bits in [64usize, 128, 256] {
            let p = gen_prime(bits, &mut rng);
            assert_eq!(p.bit_length(), bits);
            assert!(is_probable_prime(&p, 30, &mut rng));
        }
    }

    #[test]
    fn coprime_variant() {
        let mut rng = ChaCha20Rng::from_u64(4);
        let e = BigUint::from_u64(65537);
        let p = gen_prime_coprime(96, &e, &mut rng);
        assert!(p.sub(&BigUint::one()).gcd(&e).is_one());
    }
}
