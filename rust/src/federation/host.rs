//! The host party: features but no labels. Receives encrypted packed gh,
//! builds ciphertext histograms (direct or by subtraction), constructs
//! shuffled split-infos, compresses them, and applies winning splits —
//! paper Algorithms 1 and 5.
//!
//! Talks to the guest through any [`HostTransport`]: the in-process
//! [`HostLink`] (host runs as a thread, see [`spawn_host`]) or the framed
//! TCP transport (host runs as its own process, see
//! [`crate::federation::tcp::serve_host_once`] and the `sbp serve-host`
//! subcommand). The host never sees a plaintext statistic or the guest's
//! labels; the guest never learns which (feature, bin) a split handle
//! denotes.

use crate::crypto::cipher::{CipherSuite, Ct};
use crate::crypto::compress::{compress, CompressPlan, SplitStatCt};
use crate::data::binning::BinnedMatrix;
use crate::data::sparse::SparseBinned;
use crate::federation::codec::StatCodec;
use crate::federation::message::{HistTask, NodeStats, ToGuest, ToHost};
use crate::federation::transport::{HostLink, HostTransport};
use crate::tree::histogram::CipherHistogram;
use crate::util::rng::Xoshiro256;
use crate::util::timer::PhaseTimer;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Host-side per-run state, generic over the transport carrying the
/// guest's protocol messages.
pub struct HostParty<T: HostTransport> {
    /// This host's party index.
    pub id: u8,
    bm: BinnedMatrix,
    sb: Option<SparseBinned>,
    link: T,
    timer: Arc<Mutex<PhaseTimer>>,

    // protocol parameters (Setup)
    suite: Option<CipherSuite>,
    codec: Option<StatCodec>,
    compress_plan: Option<CompressPlan>,
    n_bins: usize,
    hist_subtraction: bool,
    sparse_optimization: bool,
    rng: Xoshiro256,

    // per-tree state
    members: HashMap<u32, Vec<u32>>,
    packed: Option<Arc<Vec<Ct>>>,
    /// instance id → row in `packed` (ciphertexts arrive in sample order).
    pos: Vec<u32>,
    node_total: Vec<Ct>,
    hist_cache: HashMap<u32, CipherHistogram>,

    /// handle → (feature, bin, threshold); persists across trees so
    /// handles stay valid for inference.
    split_table: Vec<(u32, u8, f64)>,
}

impl<T: HostTransport> HostParty<T> {
    /// Build a host party over its binned feature slice and transport.
    pub fn new(
        id: u8,
        bm: BinnedMatrix,
        sb: Option<SparseBinned>,
        link: T,
        timer: Arc<Mutex<PhaseTimer>>,
    ) -> Self {
        HostParty {
            id,
            bm,
            sb,
            link,
            timer,
            suite: None,
            codec: None,
            compress_plan: None,
            n_bins: 32,
            hist_subtraction: true,
            sparse_optimization: false,
            rng: Xoshiro256::seed_from_u64(0),
            members: HashMap::new(),
            packed: None,
            pos: Vec::new(),
            node_total: Vec::new(),
            hist_cache: HashMap::new(),
            split_table: Vec::new(),
        }
    }

    /// Main loop; returns on `Shutdown` or channel close.
    pub fn run(mut self) {
        while let Some(msg) = self.link.recv() {
            match msg {
                ToHost::Setup {
                    suite_public,
                    codec,
                    compress,
                    n_bins,
                    hist_subtraction,
                    sparse_optimization,
                    seed,
                } => {
                    assert!(!suite_public.has_secret() || matches!(suite_public, CipherSuite::Plain { .. }),
                        "host must not receive secret key material");
                    self.suite = Some(suite_public);
                    self.codec = Some(codec);
                    self.compress_plan = compress;
                    self.n_bins = n_bins;
                    self.hist_subtraction = hist_subtraction;
                    self.sparse_optimization = sparse_optimization;
                    self.rng = Xoshiro256::seed_from_u64(seed ^ (self.id as u64 + 1) * 0x9E37);
                    self.link.send(ToGuest::Ack);
                }
                ToHost::StartTree { tree_id: _, instances, packed, node_total } => {
                    self.members.clear();
                    self.hist_cache.clear();
                    // id → sample-row map for histogram indexing
                    let max_id = instances.iter().copied().max().unwrap_or(0) as usize;
                    self.pos = vec![u32::MAX; max_id + 1];
                    for (row, &id) in instances.iter().enumerate() {
                        self.pos[id as usize] = row as u32;
                    }
                    self.members.insert(0, instances.as_ref().clone());
                    self.packed = Some(packed);
                    self.node_total = node_total;
                    self.link.send(ToGuest::Ack);
                }
                ToHost::BuildLayer { tree_id, tasks } => {
                    let reply = self.build_layer(tree_id, &tasks);
                    self.link.send(reply);
                }
                ToHost::ApplySplit { tree_id, node, handle, instances } => {
                    let (f, b, _thr) = self.split_table[handle as usize];
                    let left: Vec<u32> = instances
                        .iter()
                        .copied()
                        .filter(|&i| self.bm.bin(i as usize, f as usize) <= b)
                        .collect();
                    self.link.send(ToGuest::LeftInstances { tree_id, node, left });
                }
                ToHost::SyncAssign { tree_id: _, node, left_child, right_child, left } => {
                    if let Some(mine) = self.members.remove(&node) {
                        let leftset: std::collections::HashSet<u32> =
                            left.iter().copied().collect();
                        let (li, ri): (Vec<u32>, Vec<u32>) =
                            mine.into_iter().partition(|i| leftset.contains(i));
                        self.members.insert(left_child, li);
                        self.members.insert(right_child, ri);
                    }
                    self.link.send(ToGuest::Ack);
                }
                ToHost::FinishTree { .. } => {
                    self.members.clear();
                    self.hist_cache.clear();
                    self.packed = None;
                    self.link.send(ToGuest::Ack);
                }
                ToHost::DumpSplitTable => {
                    self.link.send(ToGuest::SplitTable { entries: self.split_table.clone() });
                }
                ToHost::PredictRoute { session, chunk, queries } => {
                    // in-session inference against the just-trained split
                    // table: binned routing `bin ≤ b` is exactly the raw
                    // rule `x ≤ threshold` the exported model applies
                    let n = queries.len();
                    let mut bits = vec![0u8; n.div_ceil(8)];
                    for (i, (row, handle)) in queries.iter().enumerate() {
                        let left = (*row as usize) < self.bm.n
                            && (*handle as usize) < self.split_table.len()
                            && {
                                let (f, b, _thr) = self.split_table[*handle as usize];
                                self.bm.bin(*row as usize, f as usize) <= b
                            };
                        if left {
                            bits[i / 8] |= 1 << (i % 8);
                        }
                    }
                    self.link.send(ToGuest::RouteAnswers { session, chunk, n: n as u32, bits });
                }
                // serving-session control frames are not part of the
                // training protocol; a training host acknowledges probes
                // and ignores stray session bookkeeping rather than
                // aborting a run over them (delta_window 0: a training
                // host keeps no per-session basis, so every answer
                // travels in full)
                ToHost::SessionHello { session_id, protocol } => {
                    self.link.send(ToGuest::SessionAccept {
                        session_id,
                        max_inflight: 1,
                        delta_window: 0,
                        // negotiate like a serving host would (v2 peers
                        // get the bare accept); with delta_window 0 the
                        // eviction policy is moot, so announce freeze
                        protocol: protocol
                            .min(crate::federation::message::SERVE_PROTOCOL_VERSION),
                        basis_evict: crate::federation::message::BasisEvict::Freeze,
                    });
                }
                ToHost::SessionClose { .. } => {}
                ToHost::KeepAlive => self.link.send(ToGuest::Ack),
                ToHost::Shutdown => break,
            }
        }
    }

    /// Alg. 5: build histograms for a layer's nodes (direct builds first,
    /// then subtraction-derived siblings), cumsum, split-info, compress.
    fn build_layer(&mut self, tree_id: u32, tasks: &[HistTask]) -> ToGuest {
        let suite = self.suite.clone().expect("Setup first");
        let codec = self.codec.clone().expect("Setup first");
        let packed = self.packed.clone().expect("StartTree first");
        let n_k = codec.n_k();
        let mut new_cache: HashMap<u32, CipherHistogram> = HashMap::new();
        let mut t_hist = std::time::Duration::ZERO;
        let mut t_info = std::time::Duration::ZERO;

        for task in tasks {
            let start = std::time::Instant::now();
            let hist = match task {
                HistTask::Direct { node } => {
                    let insts = self.members.get(node).cloned().unwrap_or_default();
                    // node-level gate: sparse recovery costs ~1 negation per
                    // feature; it pays only when the elided work exceeds it
                    let sparse_worth = self
                        .sb
                        .as_ref()
                        .map(|sb| {
                            let zero_frac = 1.0 - sb.density();
                            insts.len() as f64 * zero_frac
                                > suite.negate_cost_ratio() as f64
                        })
                        .unwrap_or(false);
                    match (self.sparse_optimization && sparse_worth, &self.sb) {
                        (true, Some(sb)) => {
                            // node totals for zero-bin recovery: Σ over the
                            // node's members (root uses the tree totals)
                            let node_total = if *node == 0 {
                                self.node_total.clone()
                            } else {
                                let mut tot = vec![suite.zero_ct(); n_k];
                                for &i in &insts {
                                    let row = self.pos[i as usize] as usize;
                                    for j in 0..n_k {
                                        suite.add_assign(
                                            &mut tot[j],
                                            &packed[row * n_k + j],
                                        );
                                    }
                                }
                                tot
                            };
                            CipherHistogram::build_sparse(
                                &suite,
                                sb,
                                self.n_bins,
                                &insts,
                                &packed,
                                &self.pos,
                                n_k,
                                &node_total,
                                insts.len() as u32,
                            )
                        }
                        _ => CipherHistogram::build(
                            &suite,
                            &self.bm,
                            self.n_bins,
                            &insts,
                            &packed,
                            &self.pos,
                            n_k,
                        ),
                    }
                }
                HistTask::Subtract { node: _, parent, sibling } => {
                    let parent_h =
                        self.hist_cache.get(parent).expect("parent histogram cached");
                    let sib_h = new_cache.get(sibling).expect("sibling built first");
                    parent_h.subtract(&suite, sib_h)
                }
            };
            t_hist += start.elapsed();
            new_cache.insert(task.node(), hist);
        }

        // cumsum + split-info construction + shuffle + compress per node
        let mut nodes_out = Vec::with_capacity(tasks.len());
        for task in tasks {
            let node = task.node();
            let start = std::time::Instant::now();
            let mut hist = clone_hist(&suite, &new_cache[&node]);
            hist.cumsum(&suite);
            let node_count: u32 = self.members.get(&node).map(|m| m.len() as u32).unwrap_or(
                // subtraction nodes: count = parent − sibling tracked in hist
                hist.count[hist.cell(0, self.n_bins - 1)],
            );
            let mut stats: Vec<(u32, u32, Vec<Ct>)> = Vec::new();
            for f in 0..hist.n_features {
                let mut prev_cnt = u32::MAX;
                for b in 0..self.n_bins.saturating_sub(1) {
                    let cell = hist.cell(f, b);
                    let cnt = hist.count[cell];
                    if cnt == 0 || cnt == node_count {
                        continue; // no-op split, never a candidate
                    }
                    if cnt == prev_cnt {
                        // empty bin: cumulative stats identical to the
                        // previous candidate → same split, skip (§Perf:
                        // saves a compression shift + 1/η_s decryption)
                        continue;
                    }
                    prev_cnt = cnt;
                    let handle = self.split_table.len() as u32;
                    self.split_table.push((
                        f as u32,
                        b as u8,
                        self.bm.specs[f].threshold(b as u8),
                    ));
                    let cts: Vec<Ct> =
                        hist.cells[cell * n_k..(cell + 1) * n_k].to_vec();
                    stats.push((handle, cnt, cts));
                }
            }
            // ShuffleAndSendToGuest (Alg. 1): hide feature/bin ordering
            self.rng.shuffle(&mut stats);

            let payload = match (&self.compress_plan, codec.compressible_b_gh()) {
                (Some(plan), Some(_)) => {
                    let flat: Vec<SplitStatCt> = stats
                        .into_iter()
                        .map(|(id, count, mut cts)| SplitStatCt {
                            ct: cts.pop().expect("n_k = 1 for compressible codec"),
                            id,
                            sample_count: count,
                        })
                        .collect();
                    NodeStats::Compressed(compress(&suite, plan, &flat))
                }
                _ => NodeStats::Raw(stats),
            };
            t_info += start.elapsed();
            nodes_out.push((node, payload));
        }

        self.hist_cache = new_cache;
        if let Ok(mut t) = self.timer.lock() {
            t.add("host.histogram", t_hist);
            t.add("host.splitinfo+compress", t_info);
        }
        ToGuest::LayerStats { tree_id, nodes: nodes_out }
    }
}

/// Clone a ciphertext histogram (cumsum is destructive; the cache keeps
/// the raw version for next layer's subtraction).
fn clone_hist(suite: &CipherSuite, h: &CipherHistogram) -> CipherHistogram {
    let _ = suite;
    CipherHistogram {
        n_features: h.n_features,
        n_bins: h.n_bins,
        n_k: h.n_k,
        cells: h.cells.clone(),
        count: h.count.clone(),
    }
}

/// Spawn an in-process host thread over an mpsc [`HostLink`]. Returns its
/// join handle. (Networked hosts run through
/// [`crate::federation::tcp::serve_host_once`] instead.)
pub fn spawn_host(
    id: u8,
    bm: BinnedMatrix,
    sb: Option<SparseBinned>,
    link: HostLink,
    timer: Arc<Mutex<PhaseTimer>>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("sbp-host-{id}"))
        .spawn(move || HostParty::new(id, bm, sb, link, timer).run())
        .expect("spawn host thread")
}
