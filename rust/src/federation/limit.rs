//! Host-level **admission control**: a deterministic AIMD concurrency
//! limiter that decides, per [`ToHost::SessionHello`], whether to admit
//! the session now, park the hello in a bounded FIFO queue with a
//! deadline, or shed it with a [`ToGuest::Busy`] frame the guest
//! retries against — plus the **self-tuning pipeline window**: the
//! `max_inflight` a [`ToGuest::SessionAccept`] announces is no longer
//! the static config knob but a live value the limiter shrinks under
//! observed congestion and grows back when the host is healthy.
//!
//! [`ToHost::SessionHello`]: super::message::ToHost::SessionHello
//! [`ToGuest::Busy`]: super::message::ToGuest::Busy
//! [`ToGuest::SessionAccept`]: super::message::ToGuest::SessionAccept
//!
//! ## Signals
//!
//! The limiter consumes only signals the serving engines already
//! measure, fed as *cumulative* totals in a [`LoadSample`] and diffed
//! internally per retune interval:
//!
//! - `decode_stall_seconds` — threaded-engine Stage A blocked on a full
//!   ring: compute is behind socket I/O (**congestion**);
//! - `compute_queue_stall_seconds` — Stage C shard jobs sitting queued
//!   before a pool worker picks them up (**congestion**);
//! - per-batch **service latency** (`service_seconds / batches`) —
//!   compared against the best latency the host has ever sustained;
//!   inflation past [`LATENCY_TOLERANCE`]× means queueing somewhere the
//!   stall counters cannot see (**congestion**);
//! - `poll_stall_seconds` — reactor workers parked with nothing
//!   readable. This one is **idleness**, not congestion: a mostly
//!   parked host is safely below its knee, so the limiter uses it to
//!   grow the window back *faster* after an overload has passed.
//!
//! ## The AIMD retune rule
//!
//! Once per [`AdmissionConfig::retune_interval`]:
//!
//! - **congested** (stall fraction over [`STALL_TOLERANCE`], or mean
//!   batch latency over [`LATENCY_TOLERANCE`]× the best observed):
//!   multiplicative decrease — the concurrency limit is scaled by
//!   [`MD_FACTOR`] and the advertised window is halved (floors: 1);
//! - otherwise: additive increase — limit `+1` session, window `+1`
//!   batch (`+2` when the idle fraction shows the host mostly parked),
//!   capped at the configured ceiling.
//!
//! ## Determinism
//!
//! Every decision is a pure function of the call sequence and the
//! injected [`Clock`] — the controller never reads wall time, never
//! randomizes, and owns no threads. Replaying the same sequence of
//! `try_admit`/`poll_ticket`/`release`/`retune` calls against a
//! [`ManualClock`] reproduces every admit/queue/shed verdict and every
//! retuned window bit-for-bit, which is what makes the admission tests
//! assertable down to exact counter values. (Jitter belongs to the
//! *guest's* retry schedule, where it breaks re-dial lockstep — never
//! to the host's decisions.)

use super::message::BusyReason;
use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Stall fraction of a retune interval (decode + compute-queue stalls)
/// past which the interval counts as congested.
pub const STALL_TOLERANCE: f64 = 0.05;

/// Mean per-batch service latency past this multiple of the best
/// sustained latency counts as congested (queueing the stall counters
/// cannot see).
pub const LATENCY_TOLERANCE: f64 = 2.0;

/// Multiplicative-decrease factor applied to the concurrency limit on a
/// congested interval.
pub const MD_FACTOR: f64 = 0.7;

/// Per-retune decay of the best-latency baseline (so a permanently
/// slower workload — bigger batches, colder cache — re-anchors instead
/// of reading as congestion forever).
const BASELINE_DECAY: f64 = 1.02;

/// Idle fraction (reactor poll stall / interval) past which additive
/// increase takes the bigger step: the host is mostly parked, so the
/// window can recover quickly after an overload has passed.
const IDLE_FAST_RECOVERY: f64 = 0.25;

/// Tunables of the admission controller. Embedded in
/// `ServeConfig::admission`; `limit == 0` disables admission entirely —
/// every hello is admitted with the static window, no counters move,
/// and serving behaves exactly as it did before protocol v5.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Concurrent admitted sessions the host accepts before queueing or
    /// shedding (the AIMD *ceiling*; the live limit retunes between 1
    /// and this). 0 = admission control off.
    pub limit: usize,
    /// Capacity of the bounded FIFO hello queue (0 = no queue: a hello
    /// past the limit is shed immediately).
    pub queue: usize,
    /// How long a queued hello may wait for a slot before it is shed
    /// with [`BusyReason::QueueExpired`].
    pub queue_deadline: Duration,
    /// Base retry advice carried in [`super::message::ToGuest::Busy`]
    /// (`retry_after_ms`); the guest treats it as a floor and adds its
    /// own seeded jitter.
    pub retry_after: Duration,
    /// Minimum spacing between AIMD retunes; calls inside the interval
    /// are no-ops, so engines may call [`AdmissionController::retune`]
    /// opportunistically from any loop.
    pub retune_interval: Duration,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            limit: 0,
            queue: 0,
            queue_deadline: Duration::from_secs(2),
            retry_after: Duration::from_millis(50),
            retune_interval: Duration::from_millis(250),
        }
    }
}

/// The limiter's clock: monotonic time since an arbitrary epoch.
/// Injected so every limiter decision is a replayable function of the
/// call sequence — production uses [`RealClock`], tests drive a
/// [`ManualClock`] by hand.
pub trait Clock: Send + Sync {
    /// Monotonic now.
    fn now(&self) -> Duration;
}

/// Wall-clock [`Clock`] for production: elapsed time since the
/// controller was built.
pub struct RealClock(Instant);

impl Default for RealClock {
    fn default() -> Self {
        RealClock(Instant::now())
    }
}

impl Clock for RealClock {
    fn now(&self) -> Duration {
        self.0.elapsed()
    }
}

/// Hand-cranked [`Clock`] for deterministic tests.
#[derive(Default)]
pub struct ManualClock(Mutex<Duration>);

impl ManualClock {
    /// Advance the clock by `d`.
    pub fn advance(&self, d: Duration) {
        let mut t = self.0.lock().unwrap_or_else(|p| p.into_inner());
        *t += d;
    }
}

impl Clock for ManualClock {
    fn now(&self) -> Duration {
        *self.0.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Cumulative host load signals, as the serving engines measure them.
/// The controller diffs consecutive samples internally, so callers just
/// snapshot their running totals.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadSample {
    /// Reactor workers parked with nothing readable (idleness).
    pub poll_stall_seconds: f64,
    /// Threaded-engine decode stages blocked on a full ring.
    pub decode_stall_seconds: f64,
    /// Stage C shard jobs queued before a pool worker picked them up.
    pub compute_queue_stall_seconds: f64,
    /// `PredictRoute` batches answered.
    pub batches: u64,
    /// Total service time of those batches (decode-to-emit).
    pub service_seconds: f64,
}

/// The controller's verdict on one arriving hello.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Admit now; announce `window` as the session's `max_inflight`.
    Admit {
        /// The retuned pipeline window to advertise.
        window: u32,
    },
    /// Park the hello in the FIFO queue; poll the ticket until it
    /// admits or expires.
    Queued {
        /// Handle for [`AdmissionController::poll_ticket`] /
        /// [`AdmissionController::cancel_ticket`].
        ticket: u64,
    },
    /// Shed: answer [`super::message::ToGuest::Busy`] (v5 peers) or
    /// close (older peers).
    Busy {
        /// Retry advice for the `Busy` frame.
        retry_after_ms: u32,
        /// Why the hello was refused.
        reason: BusyReason,
    },
}

/// One poll of a queued hello's ticket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TicketPoll {
    /// Still queued; poll again.
    Pending,
    /// A slot freed and this ticket is at the front: admitted.
    Admit {
        /// The retuned pipeline window to advertise.
        window: u32,
    },
    /// The queue deadline ran out: shed with
    /// [`BusyReason::QueueExpired`].
    Expired {
        /// Retry advice for the `Busy` frame.
        retry_after_ms: u32,
    },
}

/// Point-in-time admission counters, in the style of
/// [`super::serve::CacheStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AdmissionStats {
    /// Hellos refused with `Busy` (immediate sheds + queue expiries).
    pub sessions_shed: u64,
    /// Hellos that entered the admission queue (whether they later
    /// admitted or expired).
    pub sessions_queued: u64,
    /// Total seconds hellos spent in the admission queue (admitted and
    /// expired alike).
    pub queue_wait_seconds: f64,
    /// Retunes that *changed* the advertised window.
    pub window_retunes: u64,
    /// Sessions currently admitted (in flight).
    pub in_flight: usize,
    /// The current advertised `max_inflight` window.
    pub window: u32,
    /// The current live concurrency limit (≤ the configured ceiling).
    pub limit: usize,
}

struct Inner {
    /// Sessions currently admitted.
    in_flight: usize,
    /// Live AIMD concurrency limit, in `[1, cfg.limit]`. Kept as f64 so
    /// multiplicative decrease accumulates below the integer floor
    /// function (`limit()` truncates).
    limit: f64,
    /// Advertised pipeline window, in `[1, base_window]`.
    window: u32,
    /// Queued hellos: (ticket, enqueued-at), FIFO.
    queue: VecDeque<(u64, Duration)>,
    next_ticket: u64,
    last_retune: Duration,
    last_sample: LoadSample,
    /// Best sustained mean batch latency (0 = none observed yet).
    best_latency: f64,
    shed: u64,
    queued: u64,
    queue_wait: Duration,
    window_retunes: u64,
}

/// The host's admission controller. One per serving process, shared by
/// both engines; all state behind one mutex (admission runs once per
/// *session*, not per frame — never on the hot path).
pub struct AdmissionController {
    cfg: AdmissionConfig,
    /// Ceiling of the advertised window (the static `max_inflight`).
    base_window: u32,
    clock: Box<dyn Clock>,
    inner: Mutex<Inner>,
}

impl AdmissionController {
    /// Build a controller advertising at most `base_window` as
    /// `max_inflight`, on the real clock.
    pub fn new(cfg: AdmissionConfig, base_window: u32) -> Self {
        Self::with_clock(cfg, base_window, Box::new(RealClock::default()))
    }

    /// Build on an injected clock (deterministic tests).
    pub fn with_clock(cfg: AdmissionConfig, base_window: u32, clock: Box<dyn Clock>) -> Self {
        let base_window = base_window.max(1);
        AdmissionController {
            cfg,
            base_window,
            clock,
            inner: Mutex::new(Inner {
                in_flight: 0,
                limit: cfg.limit.max(1) as f64,
                window: base_window,
                queue: VecDeque::new(),
                next_ticket: 1,
                last_retune: Duration::ZERO,
                last_sample: LoadSample::default(),
                best_latency: 0.0,
                shed: 0,
                queued: 0,
                queue_wait: Duration::ZERO,
                window_retunes: 0,
            }),
        }
    }

    /// Is admission control on at all? Off (`limit == 0`) means every
    /// hello admits with the static window and nothing is counted —
    /// byte-for-byte the pre-v5 behavior.
    pub fn enabled(&self) -> bool {
        self.cfg.limit > 0
    }

    /// Recover the state lock from poison like the routing cache does —
    /// one panicking session must not take admission down with it (the
    /// counters it guards are monotone and updated whole).
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn retry_advice(&self, inner: &Inner) -> u32 {
        // deterministic advice that grows with queue depth, so the
        // retry horizon stretches as the backlog does
        let base = self.cfg.retry_after.as_millis() as u64;
        let scale = 1 + inner.queue.len() as u64 / self.cfg.queue.max(1) as u64;
        (base * scale).min(u32::MAX as u64) as u32
    }

    /// Decide one arriving hello: admit, queue, or shed.
    pub fn try_admit(&self) -> Admission {
        if !self.enabled() {
            return Admission::Admit { window: self.base_window };
        }
        let mut inner = self.lock();
        // admitted sessions may exceed floor(limit) transiently after a
        // multiplicative decrease (and resumes force-admit past it);
        // new hellos simply wait for the drain
        if inner.in_flight < inner.limit as usize && inner.queue.is_empty() {
            inner.in_flight += 1;
            return Admission::Admit { window: inner.window };
        }
        if inner.queue.len() < self.cfg.queue {
            let ticket = inner.next_ticket;
            inner.next_ticket += 1;
            let now = self.clock.now();
            inner.queue.push_back((ticket, now));
            inner.queued += 1;
            return Admission::Queued { ticket };
        }
        inner.shed += 1;
        Admission::Busy {
            retry_after_ms: self.retry_advice(&inner),
            reason: BusyReason::Shed,
        }
    }

    /// Shed a hello because the host is winding down (stop requested):
    /// counted like any other shed, reason [`BusyReason::Draining`].
    pub fn shed_draining(&self) -> Admission {
        let mut inner = self.lock();
        inner.shed += 1;
        Admission::Busy {
            retry_after_ms: self.retry_advice(&inner),
            reason: BusyReason::Draining,
        }
    }

    /// Poll a queued hello's ticket. Only the ticket's owner calls this
    /// (and stops at the first non-`Pending` verdict).
    pub fn poll_ticket(&self, ticket: u64) -> TicketPoll {
        let mut inner = self.lock();
        let now = self.clock.now();
        let Some(pos) = inner.queue.iter().position(|&(t, _)| t == ticket) else {
            // unreachable for a well-behaved owner; defined anyway so a
            // driver bug degrades to one shed session, not a panic
            return TicketPoll::Expired { retry_after_ms: self.retry_advice(&inner) };
        };
        let waited = now.saturating_sub(inner.queue[pos].1);
        if waited > self.cfg.queue_deadline {
            inner.queue.remove(pos);
            inner.queue_wait += waited;
            inner.shed += 1;
            return TicketPoll::Expired { retry_after_ms: self.retry_advice(&inner) };
        }
        if pos == 0 && inner.in_flight < inner.limit as usize {
            inner.queue.pop_front();
            inner.queue_wait += waited;
            inner.in_flight += 1;
            return TicketPoll::Admit { window: inner.window };
        }
        TicketPoll::Pending
    }

    /// How long `ticket`'s owner may sleep before its next
    /// [`Self::poll_ticket`] without sleeping through a verdict: the
    /// earlier of the ticket's queue-deadline expiry and the next AIMD
    /// retune boundary (retunes grow the limit, which is what admits a
    /// queued hello on a quiet host), floored at 1 ms. Replaces the
    /// fixed 1 ms spin the threaded engine ran — a queued hello now
    /// wakes a handful of times across the whole deadline instead of a
    /// thousand times a second — while poll order stays deterministic:
    /// the queue is FIFO inside the controller, so *when* owners poll
    /// cannot reorder who admits first.
    pub fn poll_wait_hint(&self, ticket: u64) -> Duration {
        let floor = Duration::from_millis(1);
        if !self.enabled() {
            return floor;
        }
        let inner = self.lock();
        let now = self.clock.now();
        let deadline_left = inner
            .queue
            .iter()
            .find(|&&(t, _)| t == ticket)
            .map(|&(_, enqueued)| (enqueued + self.cfg.queue_deadline).saturating_sub(now))
            // unknown ticket: the next poll resolves it as Expired —
            // don't sleep on it
            .unwrap_or(Duration::ZERO);
        let retune_left = (inner.last_retune + self.cfg.retune_interval).saturating_sub(now);
        deadline_left.min(retune_left).max(floor)
    }

    /// Abandon a queued hello whose connection died before resolving.
    pub fn cancel_ticket(&self, ticket: u64) {
        let mut inner = self.lock();
        if let Some(pos) = inner.queue.iter().position(|&(t, _)| t == ticket) {
            inner.queue.remove(pos);
        }
    }

    /// An admitted session ended (or parked): its slot frees.
    pub fn release(&self) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.lock();
        inner.in_flight = inner.in_flight.saturating_sub(1);
    }

    /// Re-admit a resuming parked session **unconditionally**: a valid
    /// resume inside the window is never shed (the session already paid
    /// admission at its hello), even if that transiently overshoots the
    /// live limit — new hellos queue behind the overshoot instead.
    pub fn force_admit(&self) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.lock();
        inner.in_flight += 1;
    }

    /// The window a `SessionAccept` should advertise right now.
    pub fn window(&self) -> u32 {
        if !self.enabled() {
            return self.base_window;
        }
        self.lock().window
    }

    /// One AIMD retune pass over a fresh cumulative [`LoadSample`].
    /// Rate-limited internally to [`AdmissionConfig::retune_interval`];
    /// cheap no-op inside the interval, so engines call it from any
    /// convenient loop.
    pub fn retune(&self, sample: LoadSample) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.lock();
        let now = self.clock.now();
        let dt = now.saturating_sub(inner.last_retune);
        if dt < self.cfg.retune_interval {
            return;
        }
        let prev = inner.last_sample;
        inner.last_retune = now;
        inner.last_sample = sample;
        let dt = dt.as_secs_f64();
        let pressure = (sample.decode_stall_seconds - prev.decode_stall_seconds)
            + (sample.compute_queue_stall_seconds - prev.compute_queue_stall_seconds);
        let idle = sample.poll_stall_seconds - prev.poll_stall_seconds;
        let d_batches = sample.batches.saturating_sub(prev.batches);
        let d_service = sample.service_seconds - prev.service_seconds;
        let mean_latency = if d_batches > 0 { d_service / d_batches as f64 } else { 0.0 };
        if mean_latency > 0.0 {
            inner.best_latency = if inner.best_latency == 0.0 {
                mean_latency
            } else {
                // slow upward decay keeps the baseline honest when the
                // workload itself gets permanently slower
                (inner.best_latency * BASELINE_DECAY).min(mean_latency.max(inner.best_latency))
            };
            if mean_latency < inner.best_latency {
                inner.best_latency = mean_latency;
            }
        }
        let congested = pressure / dt > STALL_TOLERANCE
            || (inner.best_latency > 0.0
                && mean_latency > LATENCY_TOLERANCE * inner.best_latency);
        let old_window = inner.window;
        if congested {
            inner.limit = (inner.limit * MD_FACTOR).max(1.0);
            inner.window = (inner.window / 2).max(1);
        } else {
            inner.limit = (inner.limit + 1.0).min(self.cfg.limit as f64);
            // a mostly parked reactor is far below the knee: recover
            // the window at double speed
            let step = if idle / dt > IDLE_FAST_RECOVERY { 2 } else { 1 };
            inner.window = (inner.window + step).min(self.base_window);
        }
        if inner.window != old_window {
            inner.window_retunes += 1;
        }
    }

    /// Current counters, for `ServeReport`.
    pub fn stats(&self) -> AdmissionStats {
        let inner = self.lock();
        AdmissionStats {
            sessions_shed: inner.shed,
            sessions_queued: inner.queued,
            queue_wait_seconds: inner.queue_wait.as_secs_f64(),
            window_retunes: inner.window_retunes,
            in_flight: inner.in_flight,
            window: inner.window,
            limit: (inner.limit as usize).min(self.cfg.limit),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    struct SharedClock(Arc<ManualClock>);
    impl Clock for SharedClock {
        fn now(&self) -> Duration {
            self.0.now()
        }
    }

    fn controller(cfg: AdmissionConfig, window: u32) -> (AdmissionController, Arc<ManualClock>) {
        let clock = Arc::new(ManualClock::default());
        let c =
            AdmissionController::with_clock(cfg, window, Box::new(SharedClock(Arc::clone(&clock))));
        (c, clock)
    }

    #[test]
    fn disabled_controller_admits_everything_with_the_static_window() {
        let (c, _) = controller(AdmissionConfig::default(), 8);
        assert!(!c.enabled());
        for _ in 0..1000 {
            assert_eq!(c.try_admit(), Admission::Admit { window: 8 });
        }
        let s = c.stats();
        assert_eq!((s.sessions_shed, s.sessions_queued), (0, 0));
    }

    #[test]
    fn admit_queue_shed_in_that_order() {
        let cfg = AdmissionConfig { limit: 2, queue: 1, ..AdmissionConfig::default() };
        let (c, _) = controller(cfg, 8);
        assert_eq!(c.try_admit(), Admission::Admit { window: 8 });
        assert_eq!(c.try_admit(), Admission::Admit { window: 8 });
        let Admission::Queued { ticket } = c.try_admit() else { panic!("third hello queues") };
        let Admission::Busy { reason, .. } = c.try_admit() else { panic!("fourth hello sheds") };
        assert_eq!(reason, BusyReason::Shed);
        // queue is FIFO ahead of fresh slots: a released slot goes to
        // the ticket, not to a newcomer
        c.release();
        assert!(matches!(c.try_admit(), Admission::Queued { .. }), "queue precedes fresh admits");
        assert_eq!(c.poll_ticket(ticket), TicketPoll::Admit { window: 8 });
        let s = c.stats();
        assert_eq!(s.sessions_shed, 1);
        assert_eq!(s.sessions_queued, 2);
        assert_eq!(s.in_flight, 3);
    }

    #[test]
    fn queued_ticket_expires_by_deadline_and_counts_as_shed() {
        let cfg = AdmissionConfig {
            limit: 1,
            queue: 4,
            queue_deadline: Duration::from_millis(100),
            ..AdmissionConfig::default()
        };
        let (c, clock) = controller(cfg, 8);
        assert!(matches!(c.try_admit(), Admission::Admit { .. }));
        let Admission::Queued { ticket } = c.try_admit() else { panic!("expected queue") };
        assert_eq!(c.poll_ticket(ticket), TicketPoll::Pending);
        clock.advance(Duration::from_millis(99));
        assert_eq!(c.poll_ticket(ticket), TicketPoll::Pending, "inside the deadline");
        clock.advance(Duration::from_millis(2));
        assert!(matches!(c.poll_ticket(ticket), TicketPoll::Expired { .. }));
        let s = c.stats();
        assert_eq!(s.sessions_shed, 1);
        assert_eq!(s.sessions_queued, 1);
        assert!(s.queue_wait_seconds > 0.1 && s.queue_wait_seconds < 0.2);
        // the expired ticket left the queue: a freed slot admits fresh
        c.release();
        assert!(matches!(c.try_admit(), Admission::Admit { .. }));
    }

    #[test]
    fn aimd_decreases_under_stall_pressure_and_recovers_additively() {
        let cfg = AdmissionConfig {
            limit: 16,
            queue: 0,
            retune_interval: Duration::from_millis(100),
            ..AdmissionConfig::default()
        };
        let (c, clock) = controller(cfg, 8);
        // congested interval: 50% of the time stalled on decode
        clock.advance(Duration::from_millis(150));
        c.retune(LoadSample { decode_stall_seconds: 0.075, ..LoadSample::default() });
        let s = c.stats();
        assert_eq!(s.window, 4, "congestion halves the advertised window");
        assert_eq!(s.limit, 11, "16 × 0.7 truncates to 11");
        assert_eq!(s.window_retunes, 1);
        // second congested interval, cumulative sample keeps growing
        clock.advance(Duration::from_millis(150));
        c.retune(LoadSample { decode_stall_seconds: 0.15, ..LoadSample::default() });
        assert_eq!(c.stats().window, 2);
        // healthy idle intervals recover the window at double speed
        for i in 1..=3u32 {
            clock.advance(Duration::from_millis(150));
            c.retune(LoadSample {
                decode_stall_seconds: 0.15,
                poll_stall_seconds: 0.14 * i as f64,
                ..LoadSample::default()
            });
        }
        assert_eq!(c.stats().window, 8, "2 → 4 → 6 → 8, capped at the base window");
        // determinism: replaying the identical sequence gives the
        // identical trajectory
        let (c2, clock2) = controller(cfg, 8);
        clock2.advance(Duration::from_millis(150));
        c2.retune(LoadSample { decode_stall_seconds: 0.075, ..LoadSample::default() });
        clock2.advance(Duration::from_millis(150));
        c2.retune(LoadSample { decode_stall_seconds: 0.15, ..LoadSample::default() });
        for i in 1..=3u32 {
            clock2.advance(Duration::from_millis(150));
            c2.retune(LoadSample {
                decode_stall_seconds: 0.15,
                poll_stall_seconds: 0.14 * i as f64,
                ..LoadSample::default()
            });
        }
        assert_eq!(c.stats(), c2.stats(), "identical call sequence, identical state");
    }

    #[test]
    fn latency_inflation_alone_triggers_decrease() {
        let cfg = AdmissionConfig {
            limit: 8,
            queue: 0,
            retune_interval: Duration::from_millis(100),
            ..AdmissionConfig::default()
        };
        let (c, clock) = controller(cfg, 8);
        // healthy interval establishes the baseline: 1ms per batch
        clock.advance(Duration::from_millis(150));
        c.retune(LoadSample { batches: 100, service_seconds: 0.1, ..LoadSample::default() });
        assert_eq!(c.stats().window, 8, "healthy interval cannot shrink the window");
        // same stall counters, but batches now take 5ms: congestion the
        // stall clocks cannot see
        clock.advance(Duration::from_millis(150));
        c.retune(LoadSample { batches: 200, service_seconds: 0.6, ..LoadSample::default() });
        assert_eq!(c.stats().window, 4, "latency inflation halves the window");
    }

    #[test]
    fn poll_wait_hint_sleeps_to_the_nearer_of_deadline_and_retune() {
        let cfg = AdmissionConfig {
            limit: 1,
            queue: 2,
            queue_deadline: Duration::from_millis(400),
            retune_interval: Duration::from_millis(250),
            ..AdmissionConfig::default()
        };
        let (c, clock) = controller(cfg, 8);
        assert!(matches!(c.try_admit(), Admission::Admit { .. }));
        let Admission::Queued { ticket } = c.try_admit() else { panic!("expected queue") };
        // fresh ticket at t=0: the first retune boundary (250 ms) is
        // nearer than the queue deadline (400 ms)
        assert_eq!(c.poll_wait_hint(ticket), Duration::from_millis(250));
        // t=300, a retune just ran: the next boundary is t=550, but the
        // queue deadline at t=400 is nearer now
        clock.advance(Duration::from_millis(300));
        c.retune(LoadSample::default());
        assert_eq!(c.poll_wait_hint(ticket), Duration::from_millis(100));
        // past the deadline the hint floors at 1 ms — the very next
        // poll resolves the ticket as expired, no sleep lost
        clock.advance(Duration::from_millis(150));
        assert_eq!(c.poll_wait_hint(ticket), Duration::from_millis(1));
        assert!(matches!(c.poll_ticket(ticket), TicketPoll::Expired { .. }));
        // a resolved (unknown) ticket never sleeps its caller either
        assert_eq!(c.poll_wait_hint(ticket), Duration::from_millis(1));
    }

    #[test]
    fn retune_is_rate_limited_and_resumes_force_past_the_limit() {
        let cfg = AdmissionConfig {
            limit: 1,
            queue: 0,
            retune_interval: Duration::from_millis(100),
            ..AdmissionConfig::default()
        };
        let (c, clock) = controller(cfg, 4);
        // two calls inside one interval: the second is a no-op
        clock.advance(Duration::from_millis(150));
        c.retune(LoadSample { decode_stall_seconds: 0.1, ..LoadSample::default() });
        let w = c.stats().window;
        c.retune(LoadSample { decode_stall_seconds: 10.0, ..LoadSample::default() });
        assert_eq!(c.stats().window, w, "second retune inside the interval is a no-op");
        // a resume is never refused, even past the limit
        assert!(matches!(c.try_admit(), Admission::Admit { .. }));
        c.force_admit();
        assert_eq!(c.stats().in_flight, 2, "resume overshoots the limit by force");
        assert!(matches!(c.try_admit(), Admission::Busy { .. }), "fresh hellos shed meanwhile");
    }
}
