//! Federated batch inference over the pluggable transport layer.
//!
//! Reproduces the paper's *federated inference* phase (SecureBoost
//! §"Federated Inference"): the guest walks each sample down its trees;
//! guest-owned splits are resolved locally, and host-owned splits are
//! resolved by asking the owning host to apply its private
//! `(feature, threshold)` rule. The protocol is **batched level-wise**:
//! every sample × tree pair advances through all of its consecutive
//! guest-owned splits for free, then all pending host queries across the
//! whole batch and *all trees* are shipped in a single
//! [`ToHost::PredictRoute`] message per host, answered by one bit-packed
//! [`ToGuest::RouteAnswers`]. A batch therefore costs at most
//! `max_depth` round trips per host, independent of batch size and tree
//! count.
//!
//! Privacy directions:
//!
//! - the **guest** learns one routing bit per consulted host split —
//!   exactly what it must learn to reach a leaf, and the same bit
//!   training's `ApplySplit`/`LeftInstances` exchange already revealed;
//! - a **host** learns which of its split handles are consulted for
//!   which record ids, but never the tree position of a split, the
//!   routing decisions of other parties, leaf values, or predictions.
//!
//! Both the in-memory ([`spawn_predict_host`]) and framed-TCP
//! ([`serve_predict_once`]) deployments run this exact message flow, and
//! both charge identical serialized byte counts to
//! [`super::transport::NetCounters`] — asserted by
//! `tests/predict_parity.rs`.

use super::message::{ToGuest, ToHost};
use super::transport::{GuestTransport, HostLink, HostTransport};
use crate::data::dataset::PartySlice;
use crate::tree::node::SplitRef;
use crate::tree::predict::{GuestModel, HostModel};

/// Host-side inference service: the host's private model share plus its
/// raw feature rows keyed by record id. Answers [`ToHost::PredictRoute`]
/// batches until `Shutdown`/close.
pub struct PredictHostParty<T: HostTransport> {
    model: HostModel,
    slice: PartySlice,
    link: T,
}

impl<T: HostTransport> PredictHostParty<T> {
    /// Build a serving party from a loaded host model share and the
    /// host's feature slice (record id = row index).
    pub fn new(model: HostModel, slice: PartySlice, link: T) -> Self {
        PredictHostParty { model, slice, link }
    }

    /// Serve routing queries until `Shutdown` or transport close.
    pub fn run(self) {
        let d = self.slice.d();
        while let Some(msg) = self.link.recv() {
            match msg {
                ToHost::PredictRoute { queries } => {
                    let n = queries.len();
                    let mut bits = vec![0u8; n.div_ceil(8)];
                    for (i, (row, handle)) in queries.iter().enumerate() {
                        let left = self.goes_left(*row as usize, *handle as usize, d);
                        if left {
                            bits[i / 8] |= 1 << (i % 8);
                        }
                    }
                    self.link.send(ToGuest::RouteAnswers { n: n as u32, bits });
                }
                ToHost::Shutdown => break,
                other => {
                    // inference sessions speak only PredictRoute/Shutdown;
                    // anything else is a protocol error — close rather
                    // than answer wrong
                    eprintln!(
                        "[sbp-predict-host] unexpected {:?} message in inference session, closing",
                        other.kind()
                    );
                    break;
                }
            }
        }
    }

    /// Bounds-checked routing: malformed queries (unknown record or
    /// handle) route right and are reported, rather than panicking the
    /// serving party.
    fn goes_left(&self, row: usize, handle: usize, d: usize) -> bool {
        if row >= self.slice.n || handle >= self.model.splits.len() {
            eprintln!(
                "[sbp-predict-host] query out of range (row {row}, handle {handle}); \
                 answering right"
            );
            return false;
        }
        self.model.goes_left(handle as u32, &self.slice.x[row * d..(row + 1) * d])
    }
}

/// Spawn an in-process inference host thread over an mpsc [`HostLink`]
/// (the in-memory analogue of [`serve_predict_once`]).
pub fn spawn_predict_host(
    model: HostModel,
    slice: PartySlice,
    link: HostLink,
) -> std::thread::JoinHandle<()> {
    let party = model.party;
    std::thread::Builder::new()
        .name(format!("sbp-predict-host-{party}"))
        .spawn(move || PredictHostParty::new(model, slice, link).run())
        .expect("spawn predict host thread")
}

/// Accept one guest connection on `listener` and serve inference routing
/// queries over it until `Shutdown`/close. Returns the peer address.
/// This is the body of the `sbp serve-predict` subcommand.
pub fn serve_predict_once(
    listener: &std::net::TcpListener,
    model: HostModel,
    slice: PartySlice,
) -> std::io::Result<std::net::SocketAddr> {
    let (stream, peer) = listener.accept()?;
    let transport = super::tcp::TcpHostTransport::new(stream);
    PredictHostParty::new(model, slice, transport).run();
    Ok(peer)
}

/// One in-flight (tree, sample) walk.
struct Cursor {
    tree: u32,
    row: u32,
    node: u32,
}

/// Drive batched federated inference for every row of `guest` (record
/// id = row index on every party) and return the raw margin matrix,
/// row-major `n × pred_width` — bit-identical to colocated
/// [`GuestModel::predict_row`] on the same shares.
///
/// `links` must hold one [`GuestTransport`] per host party referenced by
/// the model, in party order, each connected to a serving
/// [`PredictHostParty`].
pub fn federated_predict(
    model: &GuestModel,
    guest: &PartySlice,
    links: &[Box<dyn GuestTransport>],
) -> Vec<f64> {
    let n = guest.n;
    let d = guest.d();
    let n_trees = model.trees.len();
    // every referenced host party must have a connected link
    for (tree, _) in &model.trees {
        for node in &tree.nodes {
            if let Some(SplitRef::Host { party, .. }) = &node.split {
                assert!(
                    (*party as usize) < links.len(),
                    "model references host party {party} but only {} link(s) are connected",
                    links.len()
                );
            }
        }
    }
    // final leaf per (tree, sample); filled as cursors finish
    let mut final_node: Vec<u32> = vec![0; n_trees * n];
    let mut active: Vec<Cursor> = Vec::with_capacity(n_trees * n);
    for t in 0..n_trees {
        for i in 0..n {
            active.push(Cursor { tree: t as u32, row: i as u32, node: 0 });
        }
    }

    while !active.is_empty() {
        // ---- phase A: advance through guest-owned splits / settle leaves
        let mut i = 0;
        while i < active.len() {
            let c = &mut active[i];
            let (tree, _class) = &model.trees[c.tree as usize];
            let guest_row = &guest.x[c.row as usize * d..(c.row as usize + 1) * d];
            let mut finished = false;
            loop {
                let node = &tree.nodes[c.node as usize];
                match &node.split {
                    None => {
                        final_node[c.tree as usize * n + c.row as usize] = c.node;
                        finished = true;
                        break;
                    }
                    Some(SplitRef::Guest { feature, threshold, .. }) => {
                        let left = guest_row[*feature as usize] <= *threshold;
                        c.node = if left { node.left as u32 } else { node.right as u32 };
                    }
                    Some(SplitRef::Host { .. }) => break, // needs a host answer
                }
            }
            if finished {
                active.swap_remove(i); // swapped-in cursor re-processed at i
            } else {
                i += 1;
            }
        }
        if active.is_empty() {
            break;
        }

        // ---- phase B: one PredictRoute per host for every pending walk
        let mut pending: Vec<Vec<usize>> = vec![Vec::new(); links.len()];
        for (idx, c) in active.iter().enumerate() {
            let (tree, _) = &model.trees[c.tree as usize];
            let Some(SplitRef::Host { party, .. }) = &tree.nodes[c.node as usize].split else {
                unreachable!("phase A leaves cursors at host splits only")
            };
            pending[*party as usize].push(idx);
        }
        for (p, idxs) in pending.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let queries: Vec<(u32, u32)> = idxs
                .iter()
                .map(|&idx| {
                    let c = &active[idx];
                    let (tree, _) = &model.trees[c.tree as usize];
                    let Some(SplitRef::Host { handle, .. }) =
                        &tree.nodes[c.node as usize].split
                    else {
                        unreachable!()
                    };
                    (c.row, *handle)
                })
                .collect();
            links[p].send(ToHost::PredictRoute { queries });
        }
        for (p, idxs) in pending.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let msg = links[p].recv();
            let ToGuest::RouteAnswers { n: n_ans, bits } = msg else {
                panic!("expected RouteAnswers from host {p}")
            };
            assert_eq!(n_ans as usize, idxs.len(), "host {p} answered a different batch size");
            for (q, &idx) in idxs.iter().enumerate() {
                let left = bits[q / 8] & (1 << (q % 8)) != 0;
                let c = &mut active[idx];
                let (tree, _) = &model.trees[c.tree as usize];
                let node = &tree.nodes[c.node as usize];
                c.node = if left { node.left as u32 } else { node.right as u32 };
            }
        }
    }

    // ---- accumulate leaf weights in tree order (matches predict_row's
    // per-row summation order exactly, so results are bit-identical)
    let k = model.pred_width;
    let mut preds = vec![0.0f64; n * k];
    for i in 0..n {
        for (t, (tree, class)) in model.trees.iter().enumerate() {
            let leaf = &tree.nodes[final_node[t * n + i] as usize];
            if tree.width == 1 {
                preds[i * k + *class] += leaf.weight[0];
            } else {
                for (j, &w) in leaf.weight.iter().enumerate() {
                    preds[i * k + j] += w;
                }
            }
        }
    }
    preds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::federation::transport::link_pair;
    use crate::tree::node::Tree;

    /// Guest tree: root guest split, left child host split — exercising
    /// both local advancement and a host round trip.
    fn toy_shares() -> (GuestModel, HostModel) {
        let mut t = Tree::new(1);
        let (l, _r) = t.split_node(0, SplitRef::Guest { feature: 0, bin: 3, threshold: 0.5 });
        t.split_node(l, SplitRef::Host { party: 0, handle: 1 });
        t.nodes[2].weight = vec![1.0];
        t.nodes[3].weight = vec![2.0];
        t.nodes[4].weight = vec![3.0];
        let guest = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
        let host = HostModel { party: 0, splits: vec![(0, 0, 9.0), (1, 2, -1.0)] };
        (guest, host)
    }

    #[test]
    fn batched_protocol_matches_colocated_predict() {
        let (guest_m, host_m) = toy_shares();
        // 4 rows: guest feature picks the branch, host feature 1 vs −1
        let guest_slice = PartySlice {
            cols: vec![0],
            x: vec![0.9, 0.1, 0.1, 0.4],
            n: 4,
        };
        let host_slice = PartySlice {
            cols: vec![1, 2],
            x: vec![0.0, 0.0, 0.0, -2.0, 0.0, 5.0, 0.0, -1.5],
            n: 4,
        };

        let (gl, hl) = link_pair(8);
        let handle = spawn_predict_host(host_m.clone(), host_slice.clone(), hl);
        let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
        let preds = federated_predict(&guest_m, &guest_slice, &links);
        links[0].send(ToHost::Shutdown);
        handle.join().unwrap();

        assert_eq!(preds.len(), 4);
        for i in 0..4 {
            let grow = &guest_slice.x[i..=i];
            let hrow = &host_slice.x[i * 2..(i + 1) * 2];
            let expect = guest_m.predict_row(grow, std::slice::from_ref(&host_m), &[hrow]);
            assert_eq!(preds[i], expect[0], "row {i}");
        }
        // expected leaves: row0 → right (1.0); row1 → host left (2.0);
        // row2 → host right (3.0); row3 → host left (2.0)
        assert_eq!(preds, vec![1.0, 2.0, 3.0, 2.0]);
        // exactly one PredictRoute round trip for the whole batch
        let snap = links[0].snapshot();
        assert_eq!(snap.msgs_to_host, 2, "one PredictRoute + one Shutdown");
        assert_eq!(snap.msgs_to_guest, 1, "one RouteAnswers");
    }

    #[test]
    fn guest_only_model_needs_no_links() {
        let mut t = Tree::new(1);
        t.split_node(0, SplitRef::Guest { feature: 0, bin: 0, threshold: 0.0 });
        t.nodes[1].weight = vec![-1.0];
        t.nodes[2].weight = vec![1.0];
        let m = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
        let slice = PartySlice { cols: vec![0], x: vec![-0.5, 0.5], n: 2 };
        let preds = federated_predict(&m, &slice, &[]);
        assert_eq!(preds, vec![-1.0, 1.0]);
    }
}
