//! Federated batch inference over the pluggable transport layer — the
//! **guest side** of the serving stack ([`super::serve`] is the host
//! side).
//!
//! Reproduces the paper's *federated inference* phase (SecureBoost
//! §"Federated Inference"): the guest walks each sample down its trees;
//! guest-owned splits are resolved locally, and host-owned splits are
//! resolved by asking the owning host to apply its private
//! `(feature, threshold)` rule. The protocol is **batched level-wise**:
//! every sample × tree pair advances through all of its consecutive
//! guest-owned splits for free, then all pending host queries across the
//! whole batch and *all trees* are shipped in a single
//! [`ToHost::PredictRoute`] message per host, answered by one bit-packed
//! [`ToGuest::RouteAnswers`]. A batch therefore costs at most
//! `max_depth` round trips per host, independent of batch size and tree
//! count.
//!
//! [`PredictSession`] is the reusable per-session state machine behind
//! the long-lived service: it opens with a `SessionHello` handshake,
//! scores any number of batches over one shared immutable model, keeps a
//! per-session **routing memo** so a `(host, record, handle)` decision
//! learned once is never re-queried (those are the protocol's
//! *cache-suppressed* queries, counted per session), optionally pads
//! every outgoing batch with decoy queries to blunt the host's view of
//! the access pattern, and closes with `SessionClose`. The legacy
//! single-shot [`federated_predict`] is a thin hello-less wrapper over
//! one sessionless batch.
//!
//! Privacy directions:
//!
//! - the **guest** learns one routing bit per consulted host split —
//!   exactly what it must learn to reach a leaf, and the same bit
//!   training's `ApplySplit`/`LeftInstances` exchange already revealed;
//! - a **host** learns which of its split handles are consulted for
//!   which record ids, but never the tree position of a split, the
//!   routing decisions of other parties, leaf values, or predictions.
//!   Decoy padding ([`PredictOptions::dummy_queries`]) dilutes that
//!   access pattern: decoys are drawn from the same record and handle
//!   population as real queries **and shuffled into the batch** (a
//!   fixed-position tail would be trivially separable), so the host
//!   cannot tell them apart, and their (correct) answers are simply
//!   discarded by the guest.
//!
//! Both the in-memory ([`spawn_predict_host`]) and framed-TCP
//! ([`serve_predict_once`]) deployments run this exact message flow, and
//! both charge identical serialized byte counts to
//! [`super::transport::NetCounters`] — asserted by
//! `tests/predict_parity.rs`.

use super::message::{ToGuest, ToHost, SERVE_PROTOCOL_VERSION, SESSIONLESS_ID};
use super::serve::{serve_session, HostServeState, ServeConfig, SessionOutcome};
use super::transport::{GuestTransport, HostTransport};
use crate::data::dataset::PartySlice;
use crate::tree::node::SplitRef;
use crate::tree::predict::{GuestModel, HostModel};
use crate::util::rng::Xoshiro256;
use std::collections::hash_map::Entry;
use std::collections::HashMap;

/// Host-side inference service for **one** session: the host's model
/// share plus its raw feature rows keyed by record id. Answers
/// [`ToHost::PredictRoute`] batches until the session ends. Kept as the
/// single-session veneer over [`super::serve::HostServeState`] — the
/// looping, cache-enabled, multi-session service lives in
/// [`super::serve`].
pub struct PredictHostParty<T: HostTransport> {
    state: std::sync::Arc<HostServeState>,
    link: T,
}

impl<T: HostTransport> PredictHostParty<T> {
    /// Build a serving party from a loaded host model share and the
    /// host's feature slice (record id = row index). Caching is off —
    /// single-session servers see no repeat traffic worth memoizing.
    pub fn new(model: HostModel, slice: PartySlice, link: T) -> Self {
        let state = HostServeState::new(
            model,
            slice,
            ServeConfig { cache_capacity: 0, ..ServeConfig::default() },
        );
        PredictHostParty { state, link }
    }

    /// Serve routing queries until the session closes (by
    /// `SessionClose`, `Shutdown`, or transport close).
    pub fn run(self) -> SessionOutcome {
        serve_session(&self.state, self.link)
    }
}

/// Spawn an in-process inference host thread over any owned host
/// transport (the in-memory analogue of [`serve_predict_once`]).
pub fn spawn_predict_host<T: HostTransport + Send + 'static>(
    model: HostModel,
    slice: PartySlice,
    link: T,
) -> std::thread::JoinHandle<()> {
    let party = model.party;
    std::thread::Builder::new()
        .name(format!("sbp-predict-host-{party}"))
        .spawn(move || {
            PredictHostParty::new(model, slice, link).run();
        })
        .expect("spawn predict host thread")
}

/// Accept one guest connection on `listener` and serve inference routing
/// queries over it until the session ends. Returns the peer address.
/// Single-session body of `sbp serve-predict --max-sessions 1`; the
/// looping multi-session variant is
/// [`super::serve::serve_predict_loop`].
pub fn serve_predict_once(
    listener: &std::net::TcpListener,
    model: HostModel,
    slice: PartySlice,
) -> std::io::Result<std::net::SocketAddr> {
    let (stream, peer) = listener.accept()?;
    let transport = super::tcp::TcpHostTransport::new(stream);
    PredictHostParty::new(model, slice, transport).run();
    Ok(peer)
}

/// Per-session client knobs.
#[derive(Clone, Copy, Debug)]
pub struct PredictOptions {
    /// Decoy queries shuffled into every outgoing `PredictRoute` batch
    /// (per host). 0 disables padding.
    pub dummy_queries: usize,
    /// Seed of the per-session decoy stream (mixed with the session id,
    /// so concurrent sessions draw different decoys). **Defaults to OS
    /// entropy**: decoys only obfuscate if the host cannot predict them,
    /// and any value derivable from artifact metadata (like the training
    /// seed, which host artifacts also record) would let the host replay
    /// the decoy stream and strip the padding. Fix it explicitly only
    /// for reproducible tests and benches.
    pub seed: u64,
}

impl Default for PredictOptions {
    fn default() -> Self {
        let mut entropy = crate::util::rng::ChaCha20Rng::from_os_entropy();
        PredictOptions { dummy_queries: 0, seed: entropy.next_u64() }
    }
}

/// One in-flight (tree, sample) walk.
struct Cursor {
    tree: u32,
    row: u32,
    node: u32,
}

/// A reusable guest-side prediction session over a shared, load-once
/// model: handshake, any number of scored batches, close. See the module
/// docs for the memo ("cache-suppressed" queries) and decoy semantics.
pub struct PredictSession<'a> {
    model: &'a GuestModel,
    session_id: u32,
    opts: PredictOptions,
    /// `(host party, record id, handle) → routing bit`, filled from every
    /// `RouteAnswers` frame of this session (decoys included — their
    /// answers are correct too).
    memo: HashMap<(u8, u32, u32), bool>,
    /// Per-party pool of host handles the model references (decoy pool:
    /// decoys are indistinguishable from real consultations).
    host_handles: Vec<Vec<u32>>,
    rng: Xoshiro256,
    suppressed: u64,
    decoys: u64,
}

impl<'a> PredictSession<'a> {
    /// Create a session with a client-chosen nonzero id.
    pub fn new(model: &'a GuestModel, session_id: u32, opts: PredictOptions) -> Self {
        assert_ne!(session_id, SESSIONLESS_ID, "session id 0 is reserved for the legacy flow");
        Self::build(model, session_id, opts)
    }

    /// The legacy hello-less session ([`SESSIONLESS_ID`]): what
    /// [`federated_predict`] runs under.
    pub fn sessionless(model: &'a GuestModel) -> Self {
        Self::build(model, SESSIONLESS_ID, PredictOptions::default())
    }

    fn build(model: &'a GuestModel, session_id: u32, opts: PredictOptions) -> Self {
        let mut host_handles: Vec<Vec<u32>> = Vec::new();
        for (tree, _) in &model.trees {
            for node in &tree.nodes {
                if let Some(SplitRef::Host { party, handle }) = &node.split {
                    let p = *party as usize;
                    if host_handles.len() <= p {
                        host_handles.resize_with(p + 1, Vec::new);
                    }
                    host_handles[p].push(*handle);
                }
            }
        }
        for pool in &mut host_handles {
            pool.sort_unstable();
            pool.dedup();
        }
        PredictSession {
            model,
            session_id,
            opts,
            memo: HashMap::new(),
            host_handles,
            rng: Xoshiro256::seed_from_u64(opts.seed ^ (session_id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            suppressed: 0,
            decoys: 0,
        }
    }

    /// This session's id.
    pub fn session_id(&self) -> u32 {
        self.session_id
    }

    /// Queries resolved from the session memo instead of the wire
    /// (including within-batch duplicates collapsed before sending).
    pub fn suppressed_queries(&self) -> u64 {
        self.suppressed
    }

    /// Decoy queries sent so far.
    pub fn decoy_queries(&self) -> u64 {
        self.decoys
    }

    /// Open the session: one `SessionHello` per host, each answered by a
    /// `SessionAccept` echoing the id. Panics on a rejected handshake —
    /// the guest cannot proceed against a host that refused it.
    pub fn open(&self, links: &[Box<dyn GuestTransport>]) {
        for link in links {
            link.send(ToHost::SessionHello {
                session_id: self.session_id,
                protocol: SERVE_PROTOCOL_VERSION,
            });
        }
        for (p, link) in links.iter().enumerate() {
            let msg = link.recv();
            let ToGuest::SessionAccept { session_id, .. } = msg else {
                panic!("host {p} rejected the session handshake")
            };
            assert_eq!(
                session_id, self.session_id,
                "host {p} accepted a different session id"
            );
        }
    }

    /// Probe every host of an idle session (`KeepAlive` → `Ack`).
    pub fn keep_alive(&self, links: &[Box<dyn GuestTransport>]) {
        for link in links {
            link.send(ToHost::KeepAlive);
        }
        for (p, link) in links.iter().enumerate() {
            let ToGuest::Ack = link.recv() else {
                panic!("host {p} answered a keep-alive with a non-Ack")
            };
        }
    }

    /// Close the session on every host. The servers keep running and
    /// keep accepting new sessions.
    pub fn close(self, links: &[Box<dyn GuestTransport>]) {
        for link in links {
            link.send(ToHost::SessionClose { session_id: self.session_id });
        }
    }

    /// Drive batched federated inference for every row of `guest`
    /// (record id = row index on every party) and return the raw margin
    /// matrix, row-major `n × pred_width` — bit-identical to colocated
    /// [`GuestModel::predict_row`] on the same shares, with or without
    /// memo suppression and decoy padding.
    ///
    /// `links` must hold one [`GuestTransport`] per host party referenced
    /// by the model, in party order, each connected to a serving host.
    pub fn predict_batch(
        &mut self,
        guest: &PartySlice,
        links: &[Box<dyn GuestTransport>],
    ) -> Vec<f64> {
        let model = self.model;
        let n = guest.n;
        let d = guest.d();
        let n_trees = model.trees.len();
        // every referenced host party must have a connected link;
        // `host_handles` (built once per session) already records the
        // highest referenced party, so this is O(1) per batch
        assert!(
            self.host_handles.len() <= links.len(),
            "model references host parties up to {} but only {} link(s) are connected",
            self.host_handles.len().saturating_sub(1),
            links.len()
        );
        // final leaf per (tree, sample); filled as cursors finish
        let mut final_node: Vec<u32> = vec![0; n_trees * n];
        let mut active: Vec<Cursor> = Vec::with_capacity(n_trees * n);
        for t in 0..n_trees {
            for i in 0..n {
                active.push(Cursor { tree: t as u32, row: i as u32, node: 0 });
            }
        }

        while !active.is_empty() {
            // ---- phase A: advance through guest-owned splits and
            // memo-answered host splits / settle leaves
            let mut i = 0;
            while i < active.len() {
                let c = &mut active[i];
                let (tree, _class) = &model.trees[c.tree as usize];
                let guest_row = &guest.x[c.row as usize * d..(c.row as usize + 1) * d];
                let mut finished = false;
                loop {
                    let node = &tree.nodes[c.node as usize];
                    match &node.split {
                        None => {
                            final_node[c.tree as usize * n + c.row as usize] = c.node;
                            finished = true;
                            break;
                        }
                        Some(SplitRef::Guest { feature, threshold, .. }) => {
                            let left = guest_row[*feature as usize] <= *threshold;
                            c.node = if left { node.left as u32 } else { node.right as u32 };
                        }
                        Some(SplitRef::Host { party, handle }) => {
                            // a decision this session already learned
                            // never crosses the wire again
                            match self.memo.get(&(*party, c.row, *handle)) {
                                Some(&left) => {
                                    self.suppressed += 1;
                                    c.node =
                                        if left { node.left as u32 } else { node.right as u32 };
                                }
                                None => break, // needs a host answer
                            }
                        }
                    }
                }
                if finished {
                    active.swap_remove(i); // swapped-in cursor re-processed at i
                } else {
                    i += 1;
                }
            }
            if active.is_empty() {
                break;
            }

            // ---- phase B: one PredictRoute per host for every pending
            // walk, duplicates collapsed, decoys appended
            let mut pending: Vec<Vec<usize>> = vec![Vec::new(); links.len()];
            for (idx, c) in active.iter().enumerate() {
                let (tree, _) = &model.trees[c.tree as usize];
                let Some(SplitRef::Host { party, .. }) = &tree.nodes[c.node as usize].split
                else {
                    unreachable!("phase A leaves cursors at host splits only")
                };
                pending[*party as usize].push(idx);
            }
            // (host, cursor idxs, queries sent, answer slot per cursor)
            let mut rounds: Vec<(usize, Vec<usize>, Vec<(u32, u32)>, Vec<usize>)> = Vec::new();
            for (p, idxs) in pending.into_iter().enumerate() {
                if idxs.is_empty() {
                    continue;
                }
                let mut queries: Vec<(u32, u32)> = Vec::new();
                let mut qpos: HashMap<(u32, u32), usize> = HashMap::new();
                let mut slots: Vec<usize> = Vec::with_capacity(idxs.len());
                for &idx in &idxs {
                    let c = &active[idx];
                    let (tree, _) = &model.trees[c.tree as usize];
                    let Some(SplitRef::Host { handle, .. }) =
                        &tree.nodes[c.node as usize].split
                    else {
                        unreachable!()
                    };
                    let key = (c.row, *handle);
                    let slot = match qpos.entry(key) {
                        Entry::Occupied(e) => {
                            // same (record, handle) pending for several
                            // trees: ask once, fan the answer out
                            self.suppressed += 1;
                            *e.get()
                        }
                        Entry::Vacant(v) => {
                            queries.push(key);
                            *v.insert(queries.len() - 1)
                        }
                    };
                    slots.push(slot);
                }
                if self.opts.dummy_queries > 0 && n > 0 {
                    let pool = self.host_handles.get(p).filter(|h| !h.is_empty());
                    if let Some(pool) = pool {
                        for _ in 0..self.opts.dummy_queries {
                            let row = self.rng.next_below(n) as u32;
                            let handle = pool[self.rng.next_below(pool.len())];
                            queries.push((row, handle));
                            self.decoys += 1;
                        }
                        // decoys must be indistinguishable by *position*
                        // too — a fixed-size tail would be trivially
                        // separable — so shuffle the whole batch and
                        // remap the cursors' answer slots accordingly
                        let mut order: Vec<usize> = (0..queries.len()).collect();
                        self.rng.shuffle(&mut order);
                        let mut new_pos = vec![0usize; queries.len()];
                        for (np, &op) in order.iter().enumerate() {
                            new_pos[op] = np;
                        }
                        queries = order.iter().map(|&op| queries[op]).collect();
                        for slot in &mut slots {
                            *slot = new_pos[*slot];
                        }
                    }
                }
                links[p].send(ToHost::PredictRoute {
                    session: self.session_id,
                    queries: queries.clone(),
                });
                rounds.push((p, idxs, queries, slots));
            }
            for (p, idxs, queries, slots) in rounds {
                let msg = links[p].recv();
                let ToGuest::RouteAnswers { session, n: n_ans, bits } = msg else {
                    panic!("expected RouteAnswers from host {p}")
                };
                assert_eq!(
                    session, self.session_id,
                    "host {p} answered for a different session"
                );
                assert_eq!(
                    n_ans as usize,
                    queries.len(),
                    "host {p} answered a different batch size"
                );
                // memoize every answered (record, handle) — decoys too
                for (q, &(row, handle)) in queries.iter().enumerate() {
                    let left = bits[q / 8] & (1 << (q % 8)) != 0;
                    self.memo.insert((p as u8, row, handle), left);
                }
                for (k, &idx) in idxs.iter().enumerate() {
                    let slot = slots[k];
                    let left = bits[slot / 8] & (1 << (slot % 8)) != 0;
                    let c = &mut active[idx];
                    let (tree, _) = &model.trees[c.tree as usize];
                    let node = &tree.nodes[c.node as usize];
                    c.node = if left { node.left as u32 } else { node.right as u32 };
                }
            }
        }

        // ---- accumulate leaf weights in tree order (matches
        // predict_row's per-row summation order exactly, so results are
        // bit-identical)
        let k = model.pred_width;
        let mut preds = vec![0.0f64; n * k];
        for i in 0..n {
            for (t, (tree, class)) in model.trees.iter().enumerate() {
                let leaf = &tree.nodes[final_node[t * n + i] as usize];
                if tree.width == 1 {
                    preds[i * k + *class] += leaf.weight[0];
                } else {
                    for (j, &w) in leaf.weight.iter().enumerate() {
                        preds[i * k + j] += w;
                    }
                }
            }
        }
        preds
    }
}

/// Drive one sessionless batched federated prediction (the legacy
/// single-shot flow): equivalent to a [`PredictSession`] without the
/// hello/close handshake, under [`SESSIONLESS_ID`]. See
/// [`PredictSession::predict_batch`] for the contract.
pub fn federated_predict(
    model: &GuestModel,
    guest: &PartySlice,
    links: &[Box<dyn GuestTransport>],
) -> Vec<f64> {
    PredictSession::sessionless(model).predict_batch(guest, links)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::federation::transport::link_pair;
    use crate::tree::node::Tree;

    /// Guest tree: root guest split, left child host split — exercising
    /// both local advancement and a host round trip.
    fn toy_shares() -> (GuestModel, HostModel) {
        let mut t = Tree::new(1);
        let (l, _r) = t.split_node(0, SplitRef::Guest { feature: 0, bin: 3, threshold: 0.5 });
        t.split_node(l, SplitRef::Host { party: 0, handle: 1 });
        t.nodes[2].weight = vec![1.0];
        t.nodes[3].weight = vec![2.0];
        t.nodes[4].weight = vec![3.0];
        let guest = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
        let host = HostModel { party: 0, splits: vec![(0, 0, 9.0), (1, 2, -1.0)] };
        (guest, host)
    }

    #[test]
    fn batched_protocol_matches_colocated_predict() {
        let (guest_m, host_m) = toy_shares();
        // 4 rows: guest feature picks the branch, host feature 1 vs −1
        let guest_slice = PartySlice {
            cols: vec![0],
            x: vec![0.9, 0.1, 0.1, 0.4],
            n: 4,
        };
        let host_slice = PartySlice {
            cols: vec![1, 2],
            x: vec![0.0, 0.0, 0.0, -2.0, 0.0, 5.0, 0.0, -1.5],
            n: 4,
        };

        let (gl, hl) = link_pair(8);
        let handle = spawn_predict_host(host_m.clone(), host_slice.clone(), hl);
        let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
        let preds = federated_predict(&guest_m, &guest_slice, &links);
        links[0].send(ToHost::Shutdown);
        handle.join().unwrap();

        assert_eq!(preds.len(), 4);
        for i in 0..4 {
            let grow = &guest_slice.x[i..=i];
            let hrow = &host_slice.x[i * 2..(i + 1) * 2];
            let expect = guest_m.predict_row(grow, std::slice::from_ref(&host_m), &[hrow]);
            assert_eq!(preds[i], expect[0], "row {i}");
        }
        // expected leaves: row0 → right (1.0); row1 → host left (2.0);
        // row2 → host right (3.0); row3 → host left (2.0)
        assert_eq!(preds, vec![1.0, 2.0, 3.0, 2.0]);
        // exactly one PredictRoute round trip for the whole batch
        let snap = links[0].snapshot();
        assert_eq!(snap.msgs_to_host, 2, "one PredictRoute + one Shutdown");
        assert_eq!(snap.msgs_to_guest, 1, "one RouteAnswers");
    }

    #[test]
    fn guest_only_model_needs_no_links() {
        let mut t = Tree::new(1);
        t.split_node(0, SplitRef::Guest { feature: 0, bin: 0, threshold: 0.0 });
        t.nodes[1].weight = vec![-1.0];
        t.nodes[2].weight = vec![1.0];
        let m = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
        let slice = PartySlice { cols: vec![0], x: vec![-0.5, 0.5], n: 2 };
        let preds = federated_predict(&m, &slice, &[]);
        assert_eq!(preds, vec![-1.0, 1.0]);
    }

    #[test]
    fn session_memo_suppresses_repeat_queries_bit_identically() {
        let (guest_m, host_m) = toy_shares();
        let guest_slice = PartySlice { cols: vec![0], x: vec![0.1, 0.1], n: 2 };
        let host_slice =
            PartySlice { cols: vec![1, 2], x: vec![0.0, -2.0, 0.0, 5.0], n: 2 };

        let (gl, hl) = link_pair(8);
        let handle = spawn_predict_host(host_m, host_slice, hl);
        let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
        let mut session = PredictSession::new(&guest_m, 42, PredictOptions::default());
        let first = session.predict_batch(&guest_slice, &links);
        let snap1 = links[0].snapshot();
        // second pass over the same rows: every host decision comes from
        // the memo — no further PredictRoute traffic at all
        let second = session.predict_batch(&guest_slice, &links);
        let snap2 = links[0].snapshot();
        assert_eq!(first, second, "memo-resolved pass must be bit-identical");
        assert_eq!(snap1, snap2, "no wire traffic for a fully memoized batch");
        assert!(session.suppressed_queries() >= 2);
        links[0].send(ToHost::Shutdown);
        handle.join().unwrap();
    }

    #[test]
    fn decoy_padding_leaves_predictions_unchanged() {
        let (guest_m, host_m) = toy_shares();
        let guest_slice = PartySlice { cols: vec![0], x: vec![0.1, 0.1, 0.4], n: 3 };
        let host_slice = PartySlice {
            cols: vec![1, 2],
            x: vec![0.0, -2.0, 0.0, 5.0, 0.0, -1.5],
            n: 3,
        };

        let run = |dummy_queries: usize| {
            let (gl, hl) = link_pair(8);
            let handle = spawn_predict_host(host_m.clone(), host_slice.clone(), hl);
            let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
            let mut session = PredictSession::new(
                &guest_m,
                7,
                PredictOptions { dummy_queries, seed: 99 },
            );
            let preds = session.predict_batch(&guest_slice, &links);
            let decoys = session.decoy_queries();
            let bytes = links[0].snapshot().bytes_to_host;
            links[0].send(ToHost::Shutdown);
            handle.join().unwrap();
            (preds, decoys, bytes)
        };
        let (plain, d0, b0) = run(0);
        let (padded, d8, b8) = run(8);
        assert_eq!(plain, padded, "decoys must not change predictions");
        assert_eq!(d0, 0);
        assert_eq!(d8, 8, "one padded PredictRoute batch in this walk");
        assert!(b8 > b0, "padding must cost wire bytes");
    }
}
