//! Federated batch inference over the pluggable transport layer — the
//! **guest side** of the serving stack ([`super::serve`] is the host
//! side).
//!
//! Reproduces the paper's *federated inference* phase (SecureBoost
//! §"Federated Inference"): the guest walks each sample down its trees;
//! guest-owned splits are resolved locally, and host-owned splits are
//! resolved by asking the owning host to apply its private
//! `(feature, threshold)` rule. The protocol is **batched level-wise**:
//! every sample × tree pair advances through all of its consecutive
//! guest-owned splits for free, then all pending host queries across the
//! whole batch and *all trees* are shipped in a single
//! [`ToHost::PredictRoute`] message per host, answered by one bit-packed
//! [`ToGuest::RouteAnswers`]. A batch therefore costs at most
//! `max_depth` round trips per host, independent of batch size and tree
//! count.
//!
//! [`PredictSession`] is the reusable per-session state machine behind
//! the long-lived service: it opens with a `SessionHello` handshake,
//! scores any number of batches over one shared immutable model, keeps a
//! per-session **routing memo** so a `(host, record, handle)` decision
//! learned once is never re-queried (those are the protocol's
//! *cache-suppressed* queries, counted per session), optionally pads
//! every outgoing batch with decoy queries to blunt the host's view of
//! the access pattern, and closes with `SessionClose`. The legacy
//! single-shot [`federated_predict`] is a thin hello-less wrapper over
//! one sessionless batch.
//!
//! Two scoring engines share that session state:
//!
//! - [`PredictSession::predict_batch`] — the lockstep single-batch walk
//!   (send every level's queries, wait, repeat);
//! - [`PredictSession::predict_stream`] — the **pipelined streaming**
//!   engine: rows are split into [`PredictOptions::batch_rows`]-sized
//!   chunks and up to [`PredictOptions::max_inflight`] chunks ride the
//!   wire concurrently (chunk ids on the session frames rejoin answers
//!   to walks), overlapping host round-trip latency with guest
//!   encode/decode work at `O(batch_rows × max_inflight)` guest memory.
//!
//! Handshaked sessions additionally run the **delta protocol** (cache-
//! aware wire suppression): the session mirrors each host's bounded
//! "already answered" set — the *delta basis* — so hosts elide repeat
//! answers via `RouteAnswersDelta` frames and the guest reconstructs
//! them locally, bit-identically (see [`super::serve`]).
//!
//! Sessions that negotiated **serve protocol v4** can additionally
//! *resume* a stream across a dropped connection: with
//! [`PredictOptions::reconnect_retries`] set, a transport error in the
//! streaming engine re-dials the host with capped exponential backoff,
//! presents `SessionResume(session, last_acked_chunk)`, and — after
//! the host's `ResumeAccept` is cross-checked against the session's
//! own answer and basis-insert cursors — re-sends the requests the
//! host never received while the host replays, verbatim, the answers
//! the guest never received. The stream continues bit-identically;
//! [`StreamReport::reconnects`] / [`StreamReport::chunks_replayed`]
//! account for what the recovery cost.
//!
//! Against a **v5** host running admission control, a `SessionHello`
//! may be answered by `ToGuest::Busy {retry_after_ms, reason}` instead
//! of an accept: the host is past its concurrency limit and shed the
//! hello rather than degrade every admitted session. The guest then
//! backs off — the host's `retry_after_ms` as the floor, capped
//! exponential growth, **seeded jitter** so a fleet of guests does not
//! re-dial in lockstep — re-dials, and presents the identical hello
//! again, up to [`PredictOptions::admission_retries`] times before
//! failing loudly. The same jittered schedule paces the v4 reconnect
//! path above (one backoff helper serves both).
//!
//! Privacy directions:
//!
//! - the **guest** learns one routing bit per consulted host split —
//!   exactly what it must learn to reach a leaf, and the same bit
//!   training's `ApplySplit`/`LeftInstances` exchange already revealed;
//! - a **host** learns which of its split handles are consulted for
//!   which record ids, but never the tree position of a split, the
//!   routing decisions of other parties, leaf values, or predictions.
//!   Decoy padding ([`PredictOptions::dummy_queries`]) dilutes that
//!   access pattern: decoys are drawn from the same record and handle
//!   population as real queries **and shuffled into the batch** (a
//!   fixed-position tail would be trivially separable), so the host
//!   cannot tell them apart, and their (correct) answers are simply
//!   discarded by the guest.
//!
//! Both the in-memory ([`spawn_predict_host`]) and framed-TCP
//! ([`serve_predict_once`]) deployments run this exact message flow, and
//! both charge identical serialized byte counts to
//! [`super::transport::NetCounters`] — asserted by
//! `tests/predict_parity.rs`.

use super::delta::DeltaBasis;
use super::message::{
    BasisEvict, ToGuest, ToHost, SERVE_PROTOCOL_V2, SERVE_PROTOCOL_V3, SERVE_PROTOCOL_V4,
    SERVE_PROTOCOL_V5, SERVE_PROTOCOL_VERSION, SESSIONLESS_ID,
};
use super::serve::{serve_session, HostServeState, ServeConfig, SessionOutcome};
use super::transport::{GuestTransport, HostTransport};
use crate::crypto::secure::{
    derive_session_keys, keypair, shared_secret, HandleRotor, SecureMode, PUBKEY_LEN,
};
use crate::data::dataset::PartySlice;
use crate::tree::node::SplitRef;
use crate::tree::predict::{GuestModel, HostModel};
use crate::util::rng::{ChaCha20Rng, Xoshiro256};
use std::collections::hash_map::Entry;
use std::collections::HashMap;

/// Host-side inference service for **one** session: the host's model
/// share plus its raw feature rows keyed by record id. Answers
/// [`ToHost::PredictRoute`] batches until the session ends. Kept as the
/// single-session veneer over [`super::serve::HostServeState`] — the
/// looping, cache-enabled, multi-session service lives in
/// [`super::serve`].
pub struct PredictHostParty<T: HostTransport> {
    state: std::sync::Arc<HostServeState>,
    link: T,
}

impl<T: HostTransport + Send + Sync + 'static> PredictHostParty<T> {
    /// Build a serving party from a loaded host model share and the
    /// host's feature slice (record id = row index). Caching is off —
    /// single-session servers see no repeat traffic worth memoizing.
    pub fn new(model: HostModel, slice: PartySlice, link: T) -> Self {
        let state = HostServeState::new(
            model,
            slice,
            ServeConfig { cache_capacity: 0, ..ServeConfig::default() },
        );
        PredictHostParty { state, link }
    }

    /// Serve routing queries until the session closes (by
    /// `SessionClose`, `Shutdown`, or transport close).
    pub fn run(self) -> SessionOutcome {
        serve_session(&self.state, self.link)
    }
}

/// Spawn an in-process inference host thread over any owned host
/// transport (the in-memory analogue of [`serve_predict_once`]).
pub fn spawn_predict_host<T: HostTransport + Send + Sync + 'static>(
    model: HostModel,
    slice: PartySlice,
    link: T,
) -> std::thread::JoinHandle<()> {
    let party = model.party;
    std::thread::Builder::new()
        .name(format!("sbp-predict-host-{party}"))
        .spawn(move || {
            PredictHostParty::new(model, slice, link).run();
        })
        .expect("spawn predict host thread")
}

/// Accept one guest connection on `listener` and serve inference routing
/// queries over it until the session ends. Returns the peer address.
/// Single-session body of `sbp serve-predict --max-sessions 1`; the
/// looping multi-session variant is
/// [`super::serve::serve_predict_loop`].
pub fn serve_predict_once(
    listener: &std::net::TcpListener,
    model: HostModel,
    slice: PartySlice,
) -> std::io::Result<std::net::SocketAddr> {
    let (stream, peer) = listener.accept()?;
    let transport = super::tcp::TcpHostTransport::new(stream);
    PredictHostParty::new(model, slice, transport).run();
    Ok(peer)
}

/// Per-session client knobs.
#[derive(Clone, Copy, Debug)]
pub struct PredictOptions {
    /// Decoy queries shuffled into every outgoing `PredictRoute` batch
    /// (per host). 0 disables padding.
    pub dummy_queries: usize,
    /// Seed of the per-session decoy stream (mixed with the session id,
    /// so concurrent sessions draw different decoys). **Defaults to OS
    /// entropy**: decoys only obfuscate if the host cannot predict them,
    /// and any value derivable from artifact metadata (like the training
    /// seed, which host artifacts also record) would let the host replay
    /// the decoy stream and strip the padding. Fix it explicitly only
    /// for reproducible tests and benches.
    pub seed: u64,
    /// Rows per streamed chunk for [`PredictSession::predict_stream`];
    /// 0 = the single-batch lockstep flow (`predict_batch`). Guest
    /// working memory is `O(batch_rows × max_inflight)` instead of
    /// `O(total rows)`.
    pub batch_rows: usize,
    /// Chunks kept in flight per host while streaming (≥ 1). Clamped to
    /// the `max_inflight` each host announces in its `SessionAccept` —
    /// the serving host's per-session queue bound.
    pub max_inflight: usize,
    /// Serve-protocol version the session's `SessionHello` announces.
    /// Defaults to [`SERVE_PROTOCOL_VERSION`]; set
    /// [`SERVE_PROTOCOL_V3`] or [`SERVE_PROTOCOL_V2`] to speak as a
    /// legacy client (the host then serves the session with that
    /// protocol's semantics — v2 means a frozen delta basis and the
    /// bare 12-byte accept; v3 adds negotiated eviction but cannot
    /// resume). Anything else is rejected at session build.
    pub protocol: u32,
    /// Reconnect attempts per broken link while streaming (capped
    /// exponential backoff between attempts). 0 disables resumption:
    /// any transport error panics, the pre-v4 behavior. Nonzero only
    /// helps on sessions that negotiated serve protocol v4 — a v2/v3
    /// host cannot park a dead session, so the guest fails loudly
    /// instead of retrying against a server that already reaped it.
    pub reconnect_retries: u32,
    /// Hello retries against a v5 host that answers
    /// [`ToGuest::Busy`] (admission shed): the guest sleeps the host's
    /// `retry_after_ms` floor plus jittered exponential backoff,
    /// re-dials, and presents the identical hello again, this many
    /// times, then fails loudly. 0 makes the first `Busy` fatal.
    pub admission_retries: u32,
    /// Emit one stderr progress line per finished chunk while streaming.
    pub progress: bool,
    /// Encrypted-channel policy for the v6 handshake. `Prefer` (the
    /// default) opens with a keyed `SessionHelloSecure` and falls back
    /// to a plaintext hello when the host closes it (a pre-v6 host, or
    /// one running `--secure off`); `Require` never falls back and
    /// fails loudly instead; `Off` always speaks plaintext. Only
    /// meaningful when `protocol` is [`SERVE_PROTOCOL_VERSION`] — a
    /// legacy-protocol hello is always plaintext, so `Require` combined
    /// with a legacy `protocol` is rejected at session build.
    pub secure: SecureMode,
}

impl Default for PredictOptions {
    fn default() -> Self {
        let mut entropy = crate::util::rng::ChaCha20Rng::from_os_entropy();
        PredictOptions {
            dummy_queries: 0,
            seed: entropy.next_u64(),
            batch_rows: 0,
            max_inflight: 4,
            protocol: SERVE_PROTOCOL_VERSION,
            reconnect_retries: 0,
            admission_retries: 8,
            progress: false,
            secure: SecureMode::default(),
        }
    }
}

/// One sleep of the guest's retry schedule, shared by the v4 reconnect
/// path, the v5 `Busy` retry path, and the coordinator's shutdown
/// drain. The host's `retry_after_ms` advice (`floor_ms`) is a hard
/// **floor**: the sleep is drawn uniformly from `(floor, floor +
/// spine]`, where the spine is the capped exponential 10ms, 20ms,
/// 40ms … 500ms by `attempt`. Strictly above the floor always — a host
/// that says "come back in 200ms" never sees the guest at 101ms — and
/// the cap bounds only the jitter, so advice above 500ms keeps its full
/// weight. Seeded jitter: deterministic per RNG seed (tests replay the
/// exact schedule) while a fleet of guests seeded differently spreads
/// out instead of re-dialing a restarted or overloaded host in
/// lockstep. (An earlier version derived the sleep from half of
/// `max(spine, floor)`, which both undercut the advertised floor by up
/// to 2× and flattened the exponential growth whenever the advice
/// exceeded the 500ms cap.)
pub(crate) fn backoff_with_jitter(
    rng: &mut Xoshiro256,
    attempt: u32,
    floor_ms: u64,
) -> std::time::Duration {
    let spine = (10u64 << attempt.min(6)).min(500).max(2);
    std::time::Duration::from_millis(floor_ms + 1 + rng.next_below(spine as usize) as u64)
}

/// One in-flight (tree, sample) walk.
struct Cursor {
    tree: u32,
    row: u32,
    node: u32,
}

/// Per-host serving limits learned from the `SessionAccept` handshake.
#[derive(Clone, Copy, Debug, Default)]
struct HostCaps {
    /// Unanswered chunks the host tolerates per session.
    max_inflight: u32,
    /// Delta-basis capacity (0 = wire suppression off for this host).
    delta_window: u32,
    /// Delta-basis eviction policy this host negotiated (always
    /// [`BasisEvict::Freeze`] when the session speaks v2).
    basis_evict: BasisEvict,
    /// Serve-protocol version this host's accept negotiated. Resumption
    /// ([`PredictOptions::reconnect_retries`]) requires ≥ 4.
    protocol: u32,
}

/// What one [`PredictSession::predict_stream`] pass did: pipeline
/// occupancy and stall accounting for the bench JSONs.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamReport {
    /// Chunks the pass was split into.
    pub chunks: u64,
    /// Rows per chunk the pass ran with.
    pub batch_rows: usize,
    /// The effective in-flight window: the requested `max_inflight`,
    /// clamped to every host's announced bound and to the answer-byte
    /// budget that keeps blocking-socket pipelining deadlock-free.
    pub window: usize,
    /// Highest number of chunks simultaneously in flight.
    pub max_inflight_observed: usize,
    /// Mean chunks in flight, sampled at every answer-frame wait.
    pub mean_inflight: f64,
    /// Wall seconds the guest spent blocked waiting for host answers
    /// with no runnable chunk — the pipeline's stall time. A full
    /// window that still stalls means the hosts are the bottleneck;
    /// zero stalls mean the guest is.
    pub stall_seconds: f64,
    /// Successful session resumptions this pass performed (one per
    /// reconnect handshake that reached `ResumeAccept` and replayed).
    pub reconnects: u64,
    /// Answer frames the hosts replayed verbatim across all
    /// resumptions of this pass — frames that were generated before a
    /// connection died but never fully received the first time.
    pub chunks_replayed: u64,
}

/// A reusable guest-side prediction session over a shared, load-once
/// model: handshake, any number of scored batches, close. See the module
/// docs for the memo ("cache-suppressed" queries) and decoy semantics.
pub struct PredictSession<'a> {
    model: &'a GuestModel,
    session_id: u32,
    opts: PredictOptions,
    /// `(host party, record id, handle) → routing bit`, filled from every
    /// `RouteAnswers` frame of this session (decoys included — their
    /// answers are correct too).
    memo: HashMap<(u8, u32, u32), bool>,
    /// Per-party pool of host handles the model references (decoy pool:
    /// decoys are indistinguishable from real consultations).
    host_handles: Vec<Vec<u32>>,
    /// Per-host mirror of the serving host's delta "seen" set:
    /// `(record id, handle) → routing bit` for every key that host has
    /// answered this session, bounded by the host-announced
    /// `delta_window` and governed by the negotiated eviction policy
    /// (frozen on v2 sessions, deterministic frame-order LRU when v3
    /// negotiated `lru`) — byte-for-byte the same touch/insert rule the
    /// host runs, so elided answers in `RouteAnswersDelta` frames
    /// resolve locally and bit-identically.
    basis: Vec<DeltaBasis>,
    /// Limits each host announced in its `SessionAccept` (empty until
    /// [`PredictSession::open`]; sessionless flows never fill it).
    host_caps: Vec<HostCaps>,
    /// Per-host count of answer frames fully received this session —
    /// the guest's side of the v4 resume cursor. A resuming
    /// `SessionResume` presents this as `last_acked_chunk`; the host
    /// replays exactly the answers beyond it.
    acked: Vec<u64>,
    /// Per-host mirror of the host's cumulative delta-basis insert
    /// count (mod 2³² on the wire), advanced from received frame
    /// fields alone: a plain `RouteAnswers` on a delta session inserts
    /// all `n` keys, a `RouteAnswersDelta` inserts the `n − n_known`
    /// fresh ones. `ResumeAccept::basis_epoch` must equal this mirror
    /// or the two bases have desynchronized.
    basis_inserts: Vec<u64>,
    /// Per-host handle rotor of a keyed (v6 encrypted) session, `None`
    /// on plaintext links. All guest-side state — memo, basis mirror,
    /// pending rounds — keys on **true** handle ids; the rotor touches
    /// only the outgoing `PredictRoute` wire copy (and the host
    /// un-rotates before its range check). A session property derived
    /// from the first handshake: resume re-keys the AEAD channel but
    /// keeps the rotor, so replayed answers still describe the same
    /// permuted id space.
    rotors: Vec<Option<HandleRotor>>,
    rng: Xoshiro256,
    suppressed: u64,
    decoys: u64,
    delta_elided: u64,
}

impl<'a> PredictSession<'a> {
    /// Create a session with a client-chosen nonzero id.
    pub fn new(model: &'a GuestModel, session_id: u32, opts: PredictOptions) -> Self {
        assert_ne!(session_id, SESSIONLESS_ID, "session id 0 is reserved for the legacy flow");
        assert!(
            opts.protocol == SERVE_PROTOCOL_VERSION
                || opts.protocol == SERVE_PROTOCOL_V5
                || opts.protocol == SERVE_PROTOCOL_V4
                || opts.protocol == SERVE_PROTOCOL_V3
                || opts.protocol == SERVE_PROTOCOL_V2,
            "this build speaks serve protocols {SERVE_PROTOCOL_V2}..{SERVE_PROTOCOL_VERSION}, not {}",
            opts.protocol
        );
        assert!(
            opts.secure != SecureMode::Require || opts.protocol == SERVE_PROTOCOL_VERSION,
            "--secure require needs a v{SERVE_PROTOCOL_VERSION} hello; a v{} hello is always plaintext",
            opts.protocol
        );
        Self::build(model, session_id, opts)
    }

    /// The legacy hello-less session ([`SESSIONLESS_ID`]): what
    /// [`federated_predict`] runs under.
    pub fn sessionless(model: &'a GuestModel) -> Self {
        Self::build(model, SESSIONLESS_ID, PredictOptions::default())
    }

    /// A hello-less session with explicit options — the streaming knobs
    /// work sessionless too (the host still echoes chunk ids); delta
    /// suppression stays off because no handshake announced a window.
    pub fn sessionless_with(model: &'a GuestModel, opts: PredictOptions) -> Self {
        Self::build(model, SESSIONLESS_ID, opts)
    }

    fn build(model: &'a GuestModel, session_id: u32, opts: PredictOptions) -> Self {
        let mut host_handles: Vec<Vec<u32>> = Vec::new();
        for (tree, _) in &model.trees {
            for node in &tree.nodes {
                if let Some(SplitRef::Host { party, handle }) = &node.split {
                    let p = *party as usize;
                    if host_handles.len() <= p {
                        host_handles.resize_with(p + 1, Vec::new);
                    }
                    host_handles[p].push(*handle);
                }
            }
        }
        for pool in &mut host_handles {
            pool.sort_unstable();
            pool.dedup();
        }
        PredictSession {
            model,
            session_id,
            opts,
            memo: HashMap::new(),
            host_handles,
            basis: Vec::new(),
            host_caps: Vec::new(),
            acked: Vec::new(),
            basis_inserts: Vec::new(),
            rotors: Vec::new(),
            rng: Xoshiro256::seed_from_u64(opts.seed ^ (session_id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            suppressed: 0,
            decoys: 0,
            delta_elided: 0,
        }
    }

    /// This session's id.
    pub fn session_id(&self) -> u32 {
        self.session_id
    }

    /// Queries resolved from the session memo instead of the wire
    /// (including within-batch duplicates collapsed before sending).
    pub fn suppressed_queries(&self) -> u64 {
        self.suppressed
    }

    /// Decoy queries sent so far.
    pub fn decoy_queries(&self) -> u64 {
        self.decoys
    }

    /// Answers the hosts elided from the wire via `RouteAnswersDelta`
    /// and this session resolved from its mirrored delta basis.
    pub fn delta_elided_answers(&self) -> u64 {
        self.delta_elided
    }

    /// Open the session: one `SessionHello` per host (announcing
    /// [`PredictOptions::protocol`]), each answered by a
    /// `SessionAccept` echoing the id and announcing the host's
    /// `max_inflight` / `delta_window` limits plus the negotiated
    /// protocol and delta-basis eviction policy (recorded for streaming
    /// and delta decoding; a bare 12-byte accept from a v2 host
    /// negotiates the session down to frozen-basis v2 semantics).
    /// Panics on a rejected handshake — the guest cannot proceed
    /// against a host that refused it. A v5 host past its admission
    /// limit answers [`ToGuest::Busy`] instead: that is not a
    /// rejection but a *retry instruction* — the guest backs off
    /// (jittered, floored at the host's `retry_after_ms`), re-dials,
    /// and presents the identical hello again, up to
    /// [`PredictOptions::admission_retries`] times before giving up
    /// loudly.
    pub fn open(&mut self, links: &[Box<dyn GuestTransport>]) {
        // hellos to every host first (the accepts pipeline), each keyed
        // with its own ephemeral X25519 secret when the session wants
        // the encrypted channel
        let mut secrets: Vec<Option<[u8; 32]>> = Vec::with_capacity(links.len());
        for link in links {
            match self.hello_keypair() {
                Some((sk, pk)) => {
                    link.send(ToHost::SessionHelloSecure {
                        session_id: self.session_id,
                        protocol: self.opts.protocol,
                        pubkey: pk,
                    });
                    secrets.push(Some(sk));
                }
                None => {
                    link.send(ToHost::SessionHello {
                        session_id: self.session_id,
                        protocol: self.opts.protocol,
                    });
                    secrets.push(None);
                }
            }
        }
        self.host_caps.clear();
        self.rotors.clear();
        for (p, link) in links.iter().enumerate() {
            let (caps, rotor) = self.open_link(p, link.as_ref(), secrets[p]);
            self.host_caps.push(caps);
            self.rotors.push(rotor);
        }
        // a (re)opened session faces hosts with *fresh* per-session seen
        // sets — the mirrored bases must restart empty too (and under
        // the freshly negotiated policy/capacity), or the first repeat
        // key after a reconnect would desync the delta protocol
        self.basis = self
            .host_caps
            .iter()
            .map(|c| DeltaBasis::new(c.delta_window as usize, c.basis_evict))
            .collect();
        // fresh host sessions also mean fresh resume cursors: the hosts
        // count answer frames and basis inserts from zero for this
        // session, and these mirrors must match frame-for-frame
        self.acked = vec![0; self.host_caps.len()];
        self.basis_inserts = vec![0; self.host_caps.len()];
    }

    /// A fresh ephemeral X25519 keypair for a keyed hello, or `None`
    /// when this session opens in plaintext (secure off, or a legacy
    /// protocol whose hello cannot carry a key).
    fn hello_keypair(&self) -> Option<([u8; 32], [u8; PUBKEY_LEN])> {
        if self.opts.secure == SecureMode::Off || self.opts.protocol != SERVE_PROTOCOL_VERSION {
            return None;
        }
        let mut entropy = ChaCha20Rng::from_os_entropy();
        Some(keypair(&mut entropy))
    }

    /// Complete one host's handshake: wait for the accept, and ride out
    /// `Busy` sheds with the jittered retry loop. A re-dial that fails,
    /// or a connection a shedding host already closed, consumes an
    /// attempt like a `Busy` does — the host may be mid-overload either
    /// way. `secret` is the ephemeral X25519 secret whose public half
    /// the already-sent hello carried (`None` for a plaintext hello);
    /// every keyed re-dial draws a **fresh** keypair. Under
    /// [`SecureMode::Prefer`], a host that closes the keyed hello
    /// (pre-v6, or serving `--secure off`) downgrades the remaining
    /// attempts to plaintext; under [`SecureMode::Require`] the guest
    /// never downgrades and fails loudly instead. Returns the announced
    /// caps plus the handle rotor of a keyed channel.
    fn open_link(
        &self,
        p: usize,
        link: &dyn GuestTransport,
        secret: Option<[u8; 32]>,
    ) -> (HostCaps, Option<HandleRotor>) {
        let mut secret = secret;
        let mut keyed = secret.is_some();
        let retries = self.opts.admission_retries;
        // deterministic per (seed, session, host): replayable in tests,
        // de-correlated across a fleet of guests sharing a wall clock
        let mut rng = Xoshiro256::seed_from_u64(
            self.opts.seed
                ^ (self.session_id as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ ((p as u64 + 1) << 48)
                ^ 0xB055_5EED,
        );
        let mut attempt = 0u32;
        let mut floor_ms = 0u64;
        loop {
            let msg = if attempt == 0 {
                // first answer on the original connection: a queued
                // hello just blocks here until the host's deferred
                // accept (or its Busy) arrives
                match link.try_recv() {
                    Ok(m) => m,
                    Err(e) => {
                        assert!(
                            retries > 0,
                            "host {p} closed the connection during the session handshake: {e} \
                             (admission retries disabled)"
                        );
                        if keyed && self.opts.secure == SecureMode::Prefer {
                            eprintln!(
                                "[sbp-predict] host {p} closed the keyed hello ({e}); \
                                 falling back to a plaintext hello"
                            );
                            keyed = false;
                            secret = None;
                        }
                        attempt += 1;
                        continue;
                    }
                }
            } else {
                assert!(
                    attempt <= retries,
                    "host {p} still busy after {retries} admission retr(y/ies) on session {} \
                     — giving up",
                    self.session_id
                );
                std::thread::sleep(backoff_with_jitter(&mut rng, attempt - 1, floor_ms));
                // a shedding host closed the connection after its Busy:
                // dial a fresh one and present the hello again (keyed
                // hellos with a fresh ephemeral keypair — the previous
                // secret died with the previous connection)
                if link.reconnect().is_err() {
                    attempt += 1;
                    continue;
                }
                let hello = if keyed {
                    let mut entropy = ChaCha20Rng::from_os_entropy();
                    let (sk, pk) = keypair(&mut entropy);
                    secret = Some(sk);
                    ToHost::SessionHelloSecure {
                        session_id: self.session_id,
                        protocol: self.opts.protocol,
                        pubkey: pk,
                    }
                } else {
                    ToHost::SessionHello {
                        session_id: self.session_id,
                        protocol: self.opts.protocol,
                    }
                };
                if link.try_send(hello).is_err() {
                    attempt += 1;
                    continue;
                }
                match link.try_recv() {
                    Ok(m) => m,
                    Err(e) => {
                        if keyed && self.opts.secure == SecureMode::Prefer {
                            eprintln!(
                                "[sbp-predict] host {p} closed the keyed hello ({e}); \
                                 falling back to a plaintext hello"
                            );
                            keyed = false;
                            secret = None;
                        }
                        attempt += 1;
                        continue;
                    }
                }
            };
            match msg {
                ToGuest::SessionAccept {
                    session_id,
                    max_inflight,
                    delta_window,
                    protocol,
                    basis_evict,
                } => {
                    assert_eq!(
                        session_id, self.session_id,
                        "host {p} accepted a different session id"
                    );
                    assert!(
                        protocol <= self.opts.protocol,
                        "host {p} answered protocol {protocol} to a v{} hello",
                        self.opts.protocol
                    );
                    // a plaintext accept to a *keyed* hello would be an
                    // in-band downgrade the host protocol never
                    // performs (a v6 host answers keyed or closes) —
                    // treat it as an attack, not a negotiation
                    assert!(
                        !keyed,
                        "host {p} answered a plaintext accept to a keyed hello — refusing the \
                         downgrade"
                    );
                    return (HostCaps { max_inflight, delta_window, basis_evict, protocol }, None);
                }
                ToGuest::SessionAcceptSecure {
                    session_id,
                    max_inflight,
                    delta_window,
                    protocol,
                    basis_evict,
                    pubkey,
                } => {
                    assert_eq!(
                        session_id, self.session_id,
                        "host {p} accepted a different session id"
                    );
                    assert_eq!(
                        protocol, SERVE_PROTOCOL_VERSION,
                        "host {p} answered a keyed accept with protocol {protocol}"
                    );
                    let sk = secret.unwrap_or_else(|| {
                        panic!("host {p} answered a keyed accept to a plaintext hello")
                    });
                    let Some(shared) = shared_secret(&sk, &pubkey) else {
                        panic!("host {p} presented a degenerate public key in its accept");
                    };
                    let keys = derive_session_keys(&shared);
                    // guest encrypts with the guest→host key, decrypts
                    // with host→guest; from here every frame both ways
                    // rides the AEAD channel
                    link.set_secure(keys.guest_to_host, keys.host_to_guest);
                    return (
                        HostCaps { max_inflight, delta_window, basis_evict, protocol },
                        Some(HandleRotor::new(keys.rotor_seed)),
                    );
                }
                ToGuest::Busy { retry_after_ms, reason } => {
                    assert!(
                        retries > 0,
                        "host {p} is busy ({}) and admission retries are disabled",
                        reason.name()
                    );
                    eprintln!(
                        "[sbp-predict] host {p} busy ({}), retry {attempt}/{retries} in \
                         ≥{retry_after_ms}ms",
                        reason.name()
                    );
                    floor_ms = retry_after_ms as u64;
                    attempt += 1;
                }
                other => panic!(
                    "host {p} rejected the session handshake (answered {:?})",
                    other.kind()
                ),
            }
        }
    }

    /// Probe every host of an idle session (`KeepAlive` → `Ack`).
    pub fn keep_alive(&self, links: &[Box<dyn GuestTransport>]) {
        for link in links {
            link.send(ToHost::KeepAlive);
        }
        for (p, link) in links.iter().enumerate() {
            let ToGuest::Ack = link.recv() else {
                panic!("host {p} answered a keep-alive with a non-Ack")
            };
        }
    }

    /// Close the session on every host. The servers keep running and
    /// keep accepting new sessions.
    pub fn close(self, links: &[Box<dyn GuestTransport>]) {
        for link in links {
            link.send(ToHost::SessionClose { session_id: self.session_id });
        }
    }

    /// Drive batched federated inference for every row of `guest`
    /// (record id = row index on every party) and return the raw margin
    /// matrix, row-major `n × pred_width` — bit-identical to colocated
    /// [`GuestModel::predict_row`] on the same shares, with or without
    /// memo suppression and decoy padding.
    ///
    /// `links` must hold one [`GuestTransport`] per host party referenced
    /// by the model, in party order, each connected to a serving host.
    pub fn predict_batch(
        &mut self,
        guest: &PartySlice,
        links: &[Box<dyn GuestTransport>],
    ) -> Vec<f64> {
        let model = self.model;
        let n = guest.n;
        let d = guest.d();
        let n_trees = model.trees.len();
        self.ensure_basis(links.len());
        // every referenced host party must have a connected link;
        // `host_handles` (built once per session) already records the
        // highest referenced party, so this is O(1) per batch
        assert!(
            self.host_handles.len() <= links.len(),
            "model references host parties up to {} but only {} link(s) are connected",
            self.host_handles.len().saturating_sub(1),
            links.len()
        );
        // final leaf per (tree, sample); filled as cursors finish
        let mut final_node: Vec<u32> = vec![0; n_trees * n];
        let mut active: Vec<Cursor> = Vec::with_capacity(n_trees * n);
        for t in 0..n_trees {
            for i in 0..n {
                active.push(Cursor { tree: t as u32, row: i as u32, node: 0 });
            }
        }

        while !active.is_empty() {
            // ---- phase A: advance through guest-owned splits and
            // memo-answered host splits / settle leaves
            let mut i = 0;
            while i < active.len() {
                let c = &mut active[i];
                let (tree, _class) = &model.trees[c.tree as usize];
                let guest_row = &guest.x[c.row as usize * d..(c.row as usize + 1) * d];
                let mut finished = false;
                loop {
                    let node = &tree.nodes[c.node as usize];
                    match &node.split {
                        None => {
                            final_node[c.tree as usize * n + c.row as usize] = c.node;
                            finished = true;
                            break;
                        }
                        Some(SplitRef::Guest { feature, threshold, .. }) => {
                            let left = guest_row[*feature as usize] <= *threshold;
                            c.node = if left { node.left as u32 } else { node.right as u32 };
                        }
                        Some(SplitRef::Host { party, handle }) => {
                            // a decision this session already learned
                            // never crosses the wire again
                            match self.memo.get(&(*party, c.row, *handle)) {
                                Some(&left) => {
                                    self.suppressed += 1;
                                    c.node =
                                        if left { node.left as u32 } else { node.right as u32 };
                                }
                                None => break, // needs a host answer
                            }
                        }
                    }
                }
                if finished {
                    active.swap_remove(i); // swapped-in cursor re-processed at i
                } else {
                    i += 1;
                }
            }
            if active.is_empty() {
                break;
            }

            // ---- phase B: one PredictRoute per host for every pending
            // walk, duplicates collapsed, decoys appended
            let mut pending: Vec<Vec<usize>> = vec![Vec::new(); links.len()];
            for (idx, c) in active.iter().enumerate() {
                let (tree, _) = &model.trees[c.tree as usize];
                let Some(SplitRef::Host { party, .. }) = &tree.nodes[c.node as usize].split
                else {
                    unreachable!("phase A leaves cursors at host splits only")
                };
                pending[*party as usize].push(idx);
            }
            // (host, cursor idxs, queries sent, answer slot per cursor)
            let mut rounds: Vec<(usize, Vec<usize>, Vec<(u32, u32)>, Vec<usize>)> = Vec::new();
            for (p, idxs) in pending.into_iter().enumerate() {
                if idxs.is_empty() {
                    continue;
                }
                let (queries, slots) = self.build_host_queries(p, &idxs, &active, n);
                links[p].send(ToHost::PredictRoute {
                    session: self.session_id,
                    chunk: 0,
                    queries: self.wire_queries(p, &queries),
                });
                rounds.push((p, idxs, queries, slots));
            }
            for (p, idxs, queries, slots) in rounds {
                let bits = self.recv_answers(p, links[p].as_ref(), 0, &queries);
                // memoize every answered (record, handle) — decoys too
                for (q, &(row, handle)) in queries.iter().enumerate() {
                    self.memo.insert((p as u8, row, handle), bits[q]);
                }
                for (k, &idx) in idxs.iter().enumerate() {
                    let left = bits[slots[k]];
                    let c = &mut active[idx];
                    let (tree, _) = &model.trees[c.tree as usize];
                    let node = &tree.nodes[c.node as usize];
                    c.node = if left { node.left as u32 } else { node.right as u32 };
                }
            }
        }

        // ---- accumulate leaf weights in tree order (matches
        // predict_row's per-row summation order exactly, so results are
        // bit-identical)
        let k = model.pred_width;
        let mut preds = vec![0.0f64; n * k];
        for i in 0..n {
            for (t, (tree, class)) in model.trees.iter().enumerate() {
                let leaf = &tree.nodes[final_node[t * n + i] as usize];
                if tree.width == 1 {
                    preds[i * k + *class] += leaf.weight[0];
                } else {
                    for (j, &w) in leaf.weight.iter().enumerate() {
                        preds[i * k + j] += w;
                    }
                }
            }
        }
        preds
    }

    /// Streamed, **pipelined** federated inference: split `guest`'s rows
    /// into [`PredictOptions::batch_rows`]-sized chunks and keep up to
    /// [`PredictOptions::max_inflight`] chunks in flight per host —
    /// while one chunk awaits its level's `RouteAnswers`, the next
    /// chunk's `PredictRoute` is already encoded and on the wire, so
    /// host round-trip latency overlaps with guest encode/decode work
    /// instead of serializing with it. Answers rejoin their chunks by
    /// the echoed chunk id. Guest working memory is bounded by the
    /// chunk window (`O(batch_rows × max_inflight)` walk state plus the
    /// bounded delta basis), not by the total row count; predictions
    /// are **bit-identical** to [`PredictSession::predict_batch`] and
    /// to colocated inference.
    ///
    /// Returns the full margin matrix plus the pass's [`StreamReport`].
    /// For true bounded-memory scoring of unbounded inputs, use
    /// [`PredictSession::predict_stream_with`] and write each chunk out
    /// as it lands.
    pub fn predict_stream(
        &mut self,
        guest: &PartySlice,
        links: &[Box<dyn GuestTransport>],
    ) -> (Vec<f64>, StreamReport) {
        let k = self.model.pred_width;
        let mut preds = vec![0.0f64; guest.n * k];
        let report = self.predict_stream_with(guest, links, |row0, chunk_preds| {
            preds[row0 * k..row0 * k + chunk_preds.len()].copy_from_slice(chunk_preds);
        });
        (preds, report)
    }

    /// [`PredictSession::predict_stream`] with a caller-supplied sink:
    /// `sink(row0, preds)` is called once per finished chunk (in
    /// completion order, which may differ from row order under
    /// pipelining) with that chunk's row-major `rows × pred_width`
    /// margins. The guest never materializes the full prediction
    /// matrix — this is the bounded-memory path for million-row runs.
    pub fn predict_stream_with(
        &mut self,
        guest: &PartySlice,
        links: &[Box<dyn GuestTransport>],
        mut sink: impl FnMut(usize, &[f64]),
    ) -> StreamReport {
        let n = guest.n;
        let n_trees = self.model.trees.len();
        self.ensure_basis(links.len());
        assert!(
            self.host_handles.len() <= links.len(),
            "model references host parties up to {} but only {} link(s) are connected",
            self.host_handles.len().saturating_sub(1),
            links.len()
        );
        let chunk_rows = if self.opts.batch_rows == 0 { n.max(1) } else { self.opts.batch_rows };
        let n_chunks = n.div_ceil(chunk_rows.max(1));
        // the in-flight window honors every host's announced bound —
        // that is the serving side's per-session queue backpressure
        let mut window = self.opts.max_inflight.max(1);
        for caps in &self.host_caps {
            window = window.min((caps.max_inflight.max(1)) as usize);
        }
        // Deadlock guard: both ends use blocking sockets with no
        // dedicated reader thread, so while this guest is writing chunk
        // frames it is NOT draining answers. A host whose pending
        // answer bytes exceed the kernel's socket buffering blocks in
        // its write, stops reading, and the guest's own in-progress
        // request write then blocks too — a permanent mutual hang.
        // Answers are tiny (1 bit/query + 21 B framing), so keeping the
        // worst-case *undrained* answer bytes per host — (window − 1)
        // chunks × one outstanding level each, ≤ batch_rows × n_trees
        // queries + decoys per level — under a conservative buffer
        // budget makes the host's answer writes always complete, which
        // keeps it reading, which keeps the guest's sends completing.
        const ANSWER_BUDGET_BYTES: usize = 48 << 10; // well under any OS default
        let per_chunk_answer_bytes =
            (chunk_rows * n_trees.max(1) + self.opts.dummy_queries).div_ceil(8) + 21;
        window = window.min(1 + ANSWER_BUDGET_BYTES / per_chunk_answer_bytes).max(1);
        let mut report = StreamReport {
            chunks: n_chunks as u64,
            batch_rows: chunk_rows,
            window,
            ..StreamReport::default()
        };
        let t0 = std::time::Instant::now();
        let mut chunks: HashMap<u32, ChunkState> = HashMap::new();
        let mut ready: std::collections::VecDeque<u32> = std::collections::VecDeque::new();
        // per-link FIFO of chunk ids with an unanswered PredictRoute:
        // a host answers its session's frames strictly in arrival
        // order, so the head of each queue names the next frame
        let mut outstanding: Vec<std::collections::VecDeque<u32>> =
            (0..links.len()).map(|_| std::collections::VecDeque::new()).collect();
        let mut next_row = 0usize;
        let mut next_id = 1u32;
        let mut done_chunks = 0u64;
        let mut inflight_sum = 0u64;
        let mut inflight_samples = 0u64;
        loop {
            // admit chunks until the window is full
            while chunks.len() < window && next_row < n {
                let rows = chunk_rows.min(n - next_row);
                let id = next_id;
                next_id = next_id.wrapping_add(1);
                let mut st = ChunkState {
                    row0: next_row,
                    rows,
                    active: Vec::with_capacity(n_trees * rows),
                    final_node: vec![0; n_trees * rows],
                    memo: HashMap::new(),
                    pending: (0..links.len()).map(|_| None).collect(),
                    awaiting: 0,
                };
                for t in 0..n_trees {
                    for i in 0..rows {
                        st.active.push(Cursor {
                            tree: t as u32,
                            row: (next_row + i) as u32,
                            node: 0,
                        });
                    }
                }
                chunks.insert(id, st);
                ready.push_back(id);
                next_row += rows;
                report.max_inflight_observed = report.max_inflight_observed.max(chunks.len());
            }
            // run every runnable chunk: local advancement, then either
            // finalize it or put its next level's queries on the wire
            if let Some(id) = ready.pop_front() {
                let mut st = chunks.remove(&id).expect("ready chunk exists");
                let mut send_failures: Vec<(usize, std::io::Error)> = Vec::new();
                if self.advance_chunk(id, &mut st, guest, links, &mut outstanding, &mut send_failures)
                {
                    let chunk_preds = self.finalize_chunk(&st);
                    sink(st.row0, &chunk_preds);
                    done_chunks += 1;
                    if self.opts.progress {
                        eprintln!(
                            "[sbp] chunk {done_chunks}/{n_chunks} done \
                             (rows {}..{}, {} in flight)",
                            st.row0,
                            st.row0 + st.rows,
                            chunks.len()
                        );
                    }
                } else {
                    chunks.insert(id, st);
                }
                // a link broke mid-send: the failed round was recorded
                // as outstanding like any other (the host never saw a
                // complete frame), so the resume handshake re-sends it
                // together with everything else the kill swallowed
                for (p, err) in send_failures {
                    self.resume_link(p, links, &chunks, &outstanding, &mut report, &err);
                }
                continue; // admit/advance before blocking on answers
            }
            if chunks.is_empty() {
                break; // everything admitted, advanced and finalized
            }
            // every in-flight chunk awaits host answers: block on the
            // oldest unanswered frame. All wall time spent here is
            // pipeline stall — there was nothing else runnable.
            let p = outstanding
                .iter()
                .position(|q| !q.is_empty())
                .expect("chunks await answers but no frame is outstanding");
            let id = *outstanding[p].front().expect("nonempty queue");
            inflight_sum += chunks.len() as u64;
            inflight_samples += 1;
            let wait0 = std::time::Instant::now();
            // receive BEFORE touching the chunk's pending round: if the
            // connection is dead, the round must stay in place so the
            // resume path can re-send it from the retained queries
            let msg = match links[p].try_recv() {
                Ok(msg) => msg,
                Err(err) => {
                    report.stall_seconds += wait0.elapsed().as_secs_f64();
                    self.resume_link(p, links, &chunks, &outstanding, &mut report, &err);
                    // replayed and re-answered frames drain through this
                    // same loop in the original outstanding order
                    continue;
                }
            };
            report.stall_seconds += wait0.elapsed().as_secs_f64();
            outstanding[p].pop_front();
            let st = chunks.get_mut(&id).expect("outstanding chunk exists");
            let round = st.pending[p].take().expect("outstanding round exists");
            let bits = self.decode_answers(p, msg, id, &round.queries);
            // memoize within the chunk (decoys included) and advance
            // the cursors that were waiting on this host
            for (q, &(row, handle)) in round.queries.iter().enumerate() {
                st.memo.insert((p as u8, row, handle), bits[q]);
            }
            for (j, &idx) in round.idxs.iter().enumerate() {
                let left = bits[round.slots[j]];
                let c = &mut st.active[idx];
                let (tree, _) = &self.model.trees[c.tree as usize];
                let node = &tree.nodes[c.node as usize];
                c.node = if left { node.left as u32 } else { node.right as u32 };
            }
            st.awaiting -= 1;
            if st.awaiting == 0 {
                ready.push_back(id);
            }
        }
        report.mean_inflight = if inflight_samples == 0 {
            0.0
        } else {
            inflight_sum as f64 / inflight_samples as f64
        };
        if self.opts.progress {
            eprintln!(
                "[sbp] streamed {n} row(s) in {n_chunks} chunk(s): \
                 window {window}, mean in-flight {:.2}, stall {:.3}s of {:.3}s",
                report.mean_inflight,
                report.stall_seconds,
                t0.elapsed().as_secs_f64(),
            );
        }
        report
    }

    /// Phase A + phase B for one streamed chunk: advance every cursor
    /// through guest splits and memo/basis-answered host splits; then
    /// either report the chunk finished (`true`) or send one
    /// `PredictRoute` per host with the chunk's pending queries and
    /// record the expectation FIFO entries. A send that hits a dead
    /// connection is still recorded as outstanding (its round is what
    /// the resume handshake will re-send) and reported through
    /// `send_failures` for the caller to recover.
    fn advance_chunk(
        &mut self,
        id: u32,
        st: &mut ChunkState,
        guest: &PartySlice,
        links: &[Box<dyn GuestTransport>],
        outstanding: &mut [std::collections::VecDeque<u32>],
        send_failures: &mut Vec<(usize, std::io::Error)>,
    ) -> bool {
        let model = self.model;
        let d = guest.d();
        let mut i = 0;
        while i < st.active.len() {
            let c = &mut st.active[i];
            let (tree, _class) = &model.trees[c.tree as usize];
            let guest_row = &guest.x[c.row as usize * d..(c.row as usize + 1) * d];
            let mut finished = false;
            loop {
                let node = &tree.nodes[c.node as usize];
                match &node.split {
                    None => {
                        let local = c.row as usize - st.row0;
                        st.final_node[c.tree as usize * st.rows + local] = c.node;
                        finished = true;
                        break;
                    }
                    Some(SplitRef::Guest { feature, threshold, .. }) => {
                        let left = guest_row[*feature as usize] <= *threshold;
                        c.node = if left { node.left as u32 } else { node.right as u32 };
                    }
                    Some(SplitRef::Host { party, handle }) => {
                        // chunk memo first, then the session's delta
                        // basis — a decision this session already holds
                        // never crosses the wire again. The basis probe
                        // must be the NON-MUTATING peek: the host never
                        // sees suppressed queries, so refreshing LRU
                        // recency here would desynchronize the mirrors.
                        let key = (*party, c.row, *handle);
                        let hit = st.memo.get(&key).copied().or_else(|| {
                            self.basis
                                .get(*party as usize)
                                .and_then(|b| b.peek(&(c.row, *handle)))
                        });
                        match hit {
                            Some(left) => {
                                self.suppressed += 1;
                                c.node =
                                    if left { node.left as u32 } else { node.right as u32 };
                            }
                            None => break, // needs a host answer
                        }
                    }
                }
            }
            if finished {
                st.active.swap_remove(i); // swapped-in cursor re-processed at i
            } else {
                i += 1;
            }
        }
        if st.active.is_empty() {
            return true;
        }
        let mut pending: Vec<Vec<usize>> = vec![Vec::new(); links.len()];
        for (idx, c) in st.active.iter().enumerate() {
            let (tree, _) = &model.trees[c.tree as usize];
            let Some(SplitRef::Host { party, .. }) = &tree.nodes[c.node as usize].split else {
                unreachable!("phase A leaves cursors at host splits only")
            };
            pending[*party as usize].push(idx);
        }
        for (p, idxs) in pending.into_iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let (queries, slots) = self.build_host_queries(p, &idxs, &st.active, guest.n);
            let sent = links[p].try_send(ToHost::PredictRoute {
                session: self.session_id,
                chunk: id,
                queries: self.wire_queries(p, &queries),
            });
            // the round stores TRUE handles (memo and answer decoding
            // key on them); only the wire copy above was rotated
            st.pending[p] = Some(PendingRound { idxs, queries, slots });
            st.awaiting += 1;
            outstanding[p].push_back(id);
            if let Err(err) = sent {
                send_failures.push((p, err));
            }
        }
        debug_assert!(st.awaiting > 0, "unfinished chunk sent no queries");
        false
    }

    /// Recover one broken streaming link through the serve-protocol-v4
    /// resume handshake: re-dial with capped exponential backoff,
    /// present `SessionResume(session, last_acked_chunk)`, verify the
    /// host's `ResumeAccept` against this session's own cursors, and
    /// re-send every outstanding request the host never received —
    /// beyond the `next_chunk − 1 − acked` answers the host replays
    /// verbatim — in the original send order. The replayed and
    /// re-answered frames then drain through the normal receive loop,
    /// so the stream continues bit-identically from where it stood.
    ///
    /// Panics loudly (the stream is unrecoverable) when resumption is
    /// disabled, the session negotiated a pre-v4 protocol, or every
    /// reconnect attempt fails.
    fn resume_link(
        &self,
        p: usize,
        links: &[Box<dyn GuestTransport>],
        chunks: &HashMap<u32, ChunkState>,
        outstanding: &[std::collections::VecDeque<u32>],
        report: &mut StreamReport,
        err: &std::io::Error,
    ) {
        let retries = self.opts.reconnect_retries;
        assert!(
            retries > 0,
            "host {p} link failed mid-stream: {err} (reconnection disabled; set \
             PredictOptions::reconnect_retries to resume v{SERVE_PROTOCOL_VERSION} sessions)"
        );
        let negotiated = self.host_caps.get(p).map_or(0, |c| c.protocol);
        assert!(
            negotiated >= SERVE_PROTOCOL_V4 && self.session_id != SESSIONLESS_ID,
            "host {p} link failed mid-stream: {err}; the session negotiated serve \
             protocol v{negotiated}, which cannot resume \
             (v{SERVE_PROTOCOL_V4} handshake required) — the stream is lost"
        );
        // deterministic per (seed, session, host), distinct from the
        // open_link stream: resuming guests fan out over the restarted
        // host instead of arriving in lockstep
        let mut backoff_rng = Xoshiro256::seed_from_u64(
            self.opts.seed
                ^ (self.session_id as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ ((p as u64 + 1) << 48)
                ^ 0x4E5C_0994,
        );
        let mut attempts_left = retries;
        'resume: loop {
            // ---- reconnect + handshake. A refused resume is a plain
            // close from the host (its reactor may not have swept the
            // dead connection into the parking lot yet), which surfaces
            // here as a receive error — back off and try again.
            let (next_chunk, basis_epoch) = loop {
                assert!(
                    attempts_left > 0,
                    "host {p}: gave up resuming session {} after {retries} reconnect \
                     attempt(s); original link error: {err}",
                    self.session_id
                );
                let attempt = retries - attempts_left;
                attempts_left -= 1;
                if attempt > 0 {
                    // 10ms, 20ms, 40ms, … capped at 500ms — jittered,
                    // so a fleet of resuming guests spreads out
                    std::thread::sleep(backoff_with_jitter(&mut backoff_rng, attempt - 1, 0));
                }
                if links[p].reconnect().is_err() {
                    continue;
                }
                // a keyed session resumes keyed: fresh ephemeral
                // keypair (the old AEAD keys died with the old
                // connection), but the handle rotor is a session
                // property and stays — replayed answers describe the
                // same permuted id space
                if self.rotors.get(p).is_some_and(|r| r.is_some()) {
                    let mut entropy = ChaCha20Rng::from_os_entropy();
                    let (sk, pk) = keypair(&mut entropy);
                    if links[p]
                        .try_send(ToHost::SessionResumeSecure {
                            session: self.session_id,
                            last_acked_chunk: self.acked[p] as u32,
                            pubkey: pk,
                        })
                        .is_err()
                    {
                        continue;
                    }
                    match links[p].try_recv() {
                        Ok(ToGuest::ResumeAcceptSecure { next_chunk, basis_epoch, pubkey }) => {
                            let Some(shared) = shared_secret(&sk, &pubkey) else {
                                panic!(
                                    "host {p} presented a degenerate public key in its resume \
                                     accept"
                                );
                            };
                            let keys = derive_session_keys(&shared);
                            // fresh AEAD keys for the new connection;
                            // the derived rotor seed is deliberately
                            // ignored — the session rotor survives
                            links[p].set_secure(keys.guest_to_host, keys.host_to_guest);
                            break (next_chunk, basis_epoch);
                        }
                        Err(_) => continue,
                        Ok(other) => {
                            panic!(
                                "host {p} answered SessionResumeSecure with {:?}",
                                other.kind()
                            )
                        }
                    }
                }
                if links[p]
                    .try_send(ToHost::SessionResume {
                        session: self.session_id,
                        last_acked_chunk: self.acked[p] as u32,
                    })
                    .is_err()
                {
                    continue;
                }
                match links[p].try_recv() {
                    Ok(ToGuest::ResumeAccept { next_chunk, basis_epoch }) => {
                        break (next_chunk, basis_epoch)
                    }
                    Err(_) => continue,
                    Ok(other) => {
                        panic!("host {p} answered SessionResume with {:?}", other.kind())
                    }
                }
            };
            // ---- verify both ends agree on where the stream stands:
            // the host's basis-insert epoch at the acked cursor must
            // equal this session's mirror, and the replay length must
            // fit what is actually outstanding
            assert_eq!(
                basis_epoch, self.basis_inserts[p] as u32,
                "host {p} resumed session {} at a different delta-basis epoch — \
                 the mirrored bases have desynchronized",
                self.session_id
            );
            let acked = self.acked[p];
            let next = next_chunk as u64;
            assert!(
                next >= acked + 1 && next - 1 - acked <= outstanding[p].len() as u64,
                "host {p} resumed with next_chunk {next_chunk} against {acked} acked \
                 answer frame(s) and {} outstanding request(s)",
                outstanding[p].len()
            );
            let replay = next - 1 - acked;
            // ---- re-send what the host never received: every
            // outstanding round beyond the replayed answers, in the
            // original send order. The host answers strictly in arrival
            // order, so replays followed by fresh answers drain the
            // outstanding FIFO exactly as the lost originals would have.
            for &chunk in outstanding[p].iter().skip(replay as usize) {
                let st = chunks.get(&chunk).expect("outstanding chunk exists");
                let round = st.pending[p].as_ref().expect("outstanding round retained");
                let resent = links[p].try_send(ToHost::PredictRoute {
                    session: self.session_id,
                    chunk,
                    queries: self.wire_queries(p, &round.queries),
                });
                if resent.is_err() {
                    continue 'resume; // this connection died too
                }
            }
            report.reconnects += 1;
            report.chunks_replayed += replay;
            return;
        }
    }

    /// Accumulate one finished chunk's leaf weights in tree order —
    /// exactly [`PredictSession::predict_batch`]'s summation order per
    /// row, so streamed results are bit-identical.
    fn finalize_chunk(&self, st: &ChunkState) -> Vec<f64> {
        let k = self.model.pred_width;
        let mut preds = vec![0.0f64; st.rows * k];
        for i in 0..st.rows {
            for (t, (tree, class)) in self.model.trees.iter().enumerate() {
                let leaf = &tree.nodes[st.final_node[t * st.rows + i] as usize];
                if tree.width == 1 {
                    preds[i * k + *class] += leaf.weight[0];
                } else {
                    for (j, &w) in leaf.weight.iter().enumerate() {
                        preds[i * k + j] += w;
                    }
                }
            }
        }
        preds
    }

    /// One host's query list for a set of pending cursors: within-batch
    /// duplicates collapsed (each extra ask counted as suppressed),
    /// decoys appended and the whole batch shuffled so position reveals
    /// nothing. Returns `(queries, answer slot per cursor)`.
    fn build_host_queries(
        &mut self,
        p: usize,
        idxs: &[usize],
        active: &[Cursor],
        n_rows: usize,
    ) -> (Vec<(u32, u32)>, Vec<usize>) {
        let model = self.model;
        let mut queries: Vec<(u32, u32)> = Vec::new();
        let mut qpos: HashMap<(u32, u32), usize> = HashMap::new();
        let mut slots: Vec<usize> = Vec::with_capacity(idxs.len());
        for &idx in idxs {
            let c = &active[idx];
            let (tree, _) = &model.trees[c.tree as usize];
            let Some(SplitRef::Host { handle, .. }) = &tree.nodes[c.node as usize].split
            else {
                unreachable!()
            };
            let key = (c.row, *handle);
            let slot = match qpos.entry(key) {
                Entry::Occupied(e) => {
                    // same (record, handle) pending for several trees:
                    // ask once, fan the answer out
                    self.suppressed += 1;
                    *e.get()
                }
                Entry::Vacant(v) => {
                    queries.push(key);
                    *v.insert(queries.len() - 1)
                }
            };
            slots.push(slot);
        }
        if self.opts.dummy_queries > 0 && n_rows > 0 {
            let pool = self.host_handles.get(p).filter(|h| !h.is_empty());
            if let Some(pool) = pool {
                for _ in 0..self.opts.dummy_queries {
                    let row = self.rng.next_below(n_rows) as u32;
                    let handle = pool[self.rng.next_below(pool.len())];
                    queries.push((row, handle));
                    self.decoys += 1;
                }
                // decoys must be indistinguishable by *position* too —
                // a fixed-size tail would be trivially separable — so
                // shuffle the whole batch and remap the cursors' answer
                // slots accordingly
                let mut order: Vec<usize> = (0..queries.len()).collect();
                self.rng.shuffle(&mut order);
                let mut new_pos = vec![0usize; queries.len()];
                for (np, &op) in order.iter().enumerate() {
                    new_pos[op] = np;
                }
                queries = order.iter().map(|&op| queries[op]).collect();
                for slot in &mut slots {
                    *slot = new_pos[*slot];
                }
            }
        }
        (queries, slots)
    }

    /// The wire form of one host's query list: handle ids passed
    /// through the session rotor when host `p` negotiated the keyed v6
    /// channel, the list verbatim otherwise. Every guest-side structure
    /// keys on true handles — rotation exists only between here and the
    /// host's `unrotate` pass, so the ids that transit (even under the
    /// AEAD layer, e.g. in logs either side keeps) never equal the
    /// model's stable split handles.
    fn wire_queries(&self, p: usize, queries: &[(u32, u32)]) -> Vec<(u32, u32)> {
        match self.rotors.get(p).and_then(|r| r.as_ref()) {
            Some(rotor) => queries.iter().map(|&(row, h)| (row, rotor.rotate(h))).collect(),
            None => queries.to_vec(),
        }
    }

    /// Receive and decode one host's answer frame for `queries` (sent
    /// as chunk `expect_chunk`). Handles both the plain `RouteAnswers`
    /// and the delta-suppressed `RouteAnswersDelta` forms, applying the
    /// mirrored delta-basis update rule in frame order — byte-for-byte
    /// the rule the host runs — so elided answers resolve locally and
    /// both ends stay key-for-key in sync.
    fn recv_answers(
        &mut self,
        p: usize,
        link: &dyn GuestTransport,
        expect_chunk: u32,
        queries: &[(u32, u32)],
    ) -> Vec<bool> {
        let msg = link.recv();
        self.decode_answers(p, msg, expect_chunk, queries)
    }

    /// Decode one already-received answer frame — the transport-free
    /// half of [`PredictSession::recv_answers`], shared with the
    /// streaming engine's fallible receive path. Besides the delta
    /// mirroring, this advances the session's v4 resume cursors: one
    /// acked answer frame, plus however many basis inserts the frame
    /// implies (`n` for a plain frame on a delta session, `n − n_known`
    /// for a delta frame) — the same arithmetic the host runs, so a
    /// `ResumeAccept` can cross-check both ends.
    fn decode_answers(
        &mut self,
        p: usize,
        msg: ToGuest,
        expect_chunk: u32,
        queries: &[(u32, u32)],
    ) -> Vec<bool> {
        let dw = self.host_caps.get(p).map_or(0, |c| c.delta_window as usize);
        match msg {
            ToGuest::RouteAnswers { session, chunk, n, bits } => {
                assert_eq!(session, self.session_id, "host {p} answered for a different session");
                assert_eq!(chunk, expect_chunk, "host {p} answered out of frame order");
                assert_eq!(
                    n as usize,
                    queries.len(),
                    "host {p} answered a different batch size"
                );
                let out: Vec<bool> =
                    (0..queries.len()).map(|q| bits[q / 8] & (1 << (q % 8)) != 0).collect();
                self.acked[p] += 1;
                if dw > 0 {
                    // a plain frame on a delta session means the host
                    // found every key fresh and inserted it — mirror
                    // the identical touch-else-insert sequence (under
                    // LRU that includes the same evictions)
                    self.basis_inserts[p] += n as u64;
                    let basis = &mut self.basis[p];
                    for (q, key) in queries.iter().enumerate() {
                        basis.observe(*key, out[q]);
                    }
                }
                out
            }
            ToGuest::RouteAnswersDelta { session, chunk, n, n_known, bits } => {
                assert!(
                    dw > 0,
                    "host {p} sent a delta answer on a session without delta suppression"
                );
                assert_eq!(session, self.session_id, "host {p} answered for a different session");
                assert_eq!(chunk, expect_chunk, "host {p} answered out of frame order");
                assert_eq!(
                    n as usize,
                    queries.len(),
                    "host {p} answered a different batch size"
                );
                let expected_fresh = (n - n_known) as usize;
                self.acked[p] += 1;
                self.basis_inserts[p] += (n - n_known) as u64;
                let mut out = Vec::with_capacity(queries.len());
                let mut fresh = 0usize;
                let mut known = 0usize;
                let basis = &mut self.basis[p];
                for key in queries {
                    // the host's scan ran touch-else-insert over these
                    // same keys in this same order; running the
                    // identical sequence here keeps the two bases
                    // key-for-key (and, under LRU, eviction-for-
                    // eviction) in sync
                    match basis.touch(key) {
                        Some(b) => {
                            known += 1;
                            out.push(b);
                        }
                        None => {
                            assert!(
                                fresh < expected_fresh,
                                "host {p} delta basis out of sync (more fresh answers \
                                 expected than sent)"
                            );
                            let b = bits[fresh / 8] & (1 << (fresh % 8)) != 0;
                            fresh += 1;
                            basis.insert(*key, b);
                            out.push(b);
                        }
                    }
                }
                assert_eq!(
                    known as u32, n_known,
                    "host {p} delta basis out of sync (elision counts differ)"
                );
                self.delta_elided += known as u64;
                out
            }
            other => panic!("expected RouteAnswers from host {p}, got {:?}", other.kind()),
        }
    }

    /// Size the per-host delta-basis table to the connected link count
    /// (sessionless links get an inert basis — no handshake announced a
    /// window, so wire suppression stays off), along with the v4 resume
    /// cursor mirrors (inert for sessionless links too).
    fn ensure_basis(&mut self, n_links: usize) {
        if self.basis.len() < n_links {
            self.basis.resize_with(n_links, DeltaBasis::off);
        }
        if self.acked.len() < n_links {
            self.acked.resize(n_links, 0);
            self.basis_inserts.resize(n_links, 0);
        }
        if self.rotors.len() < n_links {
            // sessionless links never ran a keyed handshake: no rotor
            self.rotors.resize_with(n_links, || None);
        }
    }
}

/// Per-host state of one in-flight `PredictRoute` round of a chunk.
struct PendingRound {
    /// Cursor indices (into the chunk's `active`) awaiting this host.
    idxs: Vec<usize>,
    /// The queries exactly as sent (decoys included, post-shuffle).
    queries: Vec<(u32, u32)>,
    /// Answer slot per cursor in `idxs` (index into `queries`).
    slots: Vec<usize>,
}

/// The walk state of one streamed chunk: its row range, live cursors,
/// settled leaves, chunk-local routing memo, and the per-host rounds
/// currently on the wire. Dropped whole when the chunk finishes — the
/// guest's streaming memory is `O(batch_rows × max_inflight)` of these,
/// never `O(total rows)`.
struct ChunkState {
    row0: usize,
    rows: usize,
    active: Vec<Cursor>,
    final_node: Vec<u32>,
    /// `(party, record, handle) → bit` learned by THIS chunk. Chunks
    /// partition the row space, so cross-chunk sharing would never hit
    /// within a pass; repeat passes are covered by the session-level
    /// delta basis instead.
    memo: HashMap<(u8, u32, u32), bool>,
    pending: Vec<Option<PendingRound>>,
    awaiting: usize,
}

/// Drive one sessionless batched federated prediction (the legacy
/// single-shot flow): equivalent to a [`PredictSession`] without the
/// hello/close handshake, under [`SESSIONLESS_ID`]. See
/// [`PredictSession::predict_batch`] for the contract.
pub fn federated_predict(
    model: &GuestModel,
    guest: &PartySlice,
    links: &[Box<dyn GuestTransport>],
) -> Vec<f64> {
    PredictSession::sessionless(model).predict_batch(guest, links)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::federation::transport::link_pair;
    use crate::tree::node::Tree;

    /// Guest tree: root guest split, left child host split — exercising
    /// both local advancement and a host round trip.
    fn toy_shares() -> (GuestModel, HostModel) {
        let mut t = Tree::new(1);
        let (l, _r) = t.split_node(0, SplitRef::Guest { feature: 0, bin: 3, threshold: 0.5 });
        t.split_node(l, SplitRef::Host { party: 0, handle: 1 });
        t.nodes[2].weight = vec![1.0];
        t.nodes[3].weight = vec![2.0];
        t.nodes[4].weight = vec![3.0];
        let guest = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
        let host = HostModel { party: 0, splits: vec![(0, 0, 9.0), (1, 2, -1.0)] };
        (guest, host)
    }

    #[test]
    fn batched_protocol_matches_colocated_predict() {
        let (guest_m, host_m) = toy_shares();
        // 4 rows: guest feature picks the branch, host feature 1 vs −1
        let guest_slice = PartySlice {
            cols: vec![0],
            x: vec![0.9, 0.1, 0.1, 0.4],
            n: 4,
        };
        let host_slice = PartySlice {
            cols: vec![1, 2],
            x: vec![0.0, 0.0, 0.0, -2.0, 0.0, 5.0, 0.0, -1.5],
            n: 4,
        };

        let (gl, hl) = link_pair(8);
        let handle = spawn_predict_host(host_m.clone(), host_slice.clone(), hl);
        let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
        let preds = federated_predict(&guest_m, &guest_slice, &links);
        links[0].send(ToHost::Shutdown);
        handle.join().unwrap();

        assert_eq!(preds.len(), 4);
        for i in 0..4 {
            let grow = &guest_slice.x[i..=i];
            let hrow = &host_slice.x[i * 2..(i + 1) * 2];
            let expect = guest_m.predict_row(grow, std::slice::from_ref(&host_m), &[hrow]);
            assert_eq!(preds[i], expect[0], "row {i}");
        }
        // expected leaves: row0 → right (1.0); row1 → host left (2.0);
        // row2 → host right (3.0); row3 → host left (2.0)
        assert_eq!(preds, vec![1.0, 2.0, 3.0, 2.0]);
        // exactly one PredictRoute round trip for the whole batch
        let snap = links[0].snapshot();
        assert_eq!(snap.msgs_to_host, 2, "one PredictRoute + one Shutdown");
        assert_eq!(snap.msgs_to_guest, 1, "one RouteAnswers");
    }

    #[test]
    fn guest_only_model_needs_no_links() {
        let mut t = Tree::new(1);
        t.split_node(0, SplitRef::Guest { feature: 0, bin: 0, threshold: 0.0 });
        t.nodes[1].weight = vec![-1.0];
        t.nodes[2].weight = vec![1.0];
        let m = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
        let slice = PartySlice { cols: vec![0], x: vec![-0.5, 0.5], n: 2 };
        let preds = federated_predict(&m, &slice, &[]);
        assert_eq!(preds, vec![-1.0, 1.0]);
    }

    #[test]
    fn session_memo_suppresses_repeat_queries_bit_identically() {
        let (guest_m, host_m) = toy_shares();
        let guest_slice = PartySlice { cols: vec![0], x: vec![0.1, 0.1], n: 2 };
        let host_slice =
            PartySlice { cols: vec![1, 2], x: vec![0.0, -2.0, 0.0, 5.0], n: 2 };

        let (gl, hl) = link_pair(8);
        let handle = spawn_predict_host(host_m, host_slice, hl);
        let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
        let mut session = PredictSession::new(&guest_m, 42, PredictOptions::default());
        let first = session.predict_batch(&guest_slice, &links);
        let snap1 = links[0].snapshot();
        // second pass over the same rows: every host decision comes from
        // the memo — no further PredictRoute traffic at all
        let second = session.predict_batch(&guest_slice, &links);
        let snap2 = links[0].snapshot();
        assert_eq!(first, second, "memo-resolved pass must be bit-identical");
        assert_eq!(snap1, snap2, "no wire traffic for a fully memoized batch");
        assert!(session.suppressed_queries() >= 2);
        links[0].send(ToHost::Shutdown);
        handle.join().unwrap();
    }

    #[test]
    fn decoy_padding_leaves_predictions_unchanged() {
        let (guest_m, host_m) = toy_shares();
        let guest_slice = PartySlice { cols: vec![0], x: vec![0.1, 0.1, 0.4], n: 3 };
        let host_slice = PartySlice {
            cols: vec![1, 2],
            x: vec![0.0, -2.0, 0.0, 5.0, 0.0, -1.5],
            n: 3,
        };

        let run = |dummy_queries: usize| {
            let (gl, hl) = link_pair(8);
            let handle = spawn_predict_host(host_m.clone(), host_slice.clone(), hl);
            let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
            let mut session = PredictSession::new(
                &guest_m,
                7,
                PredictOptions { dummy_queries, seed: 99, ..PredictOptions::default() },
            );
            let preds = session.predict_batch(&guest_slice, &links);
            let decoys = session.decoy_queries();
            let bytes = links[0].snapshot().bytes_to_host;
            links[0].send(ToHost::Shutdown);
            handle.join().unwrap();
            (preds, decoys, bytes)
        };
        let (plain, d0, b0) = run(0);
        let (padded, d8, b8) = run(8);
        assert_eq!(plain, padded, "decoys must not change predictions");
        assert_eq!(d0, 0);
        assert_eq!(d8, 8, "one padded PredictRoute batch in this walk");
        assert!(b8 > b0, "padding must cost wire bytes");
    }

    #[test]
    fn streamed_chunks_match_single_batch_bit_identically() {
        let (guest_m, host_m) = toy_shares();
        let guest_slice =
            PartySlice { cols: vec![0], x: vec![0.9, 0.1, 0.1, 0.4, 0.2], n: 5 };
        let host_slice = PartySlice {
            cols: vec![1, 2],
            x: vec![0.0, 0.0, 0.0, -2.0, 0.0, 5.0, 0.0, -1.5, 0.0, 1.0],
            n: 5,
        };
        // oracle: the lockstep single-batch flow
        let (gl, hl) = link_pair(8);
        let h = spawn_predict_host(host_m.clone(), host_slice.clone(), hl);
        let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
        let oracle = federated_predict(&guest_m, &guest_slice, &links);
        links[0].send(ToHost::Shutdown);
        h.join().unwrap();

        // chunk sizes: 1 (degenerate), a remainder split, an exact
        // divisor, and one covering chunk
        for batch_rows in [1usize, 2, 3, 5] {
            let (gl, hl) = link_pair(8);
            let h = spawn_predict_host(host_m.clone(), host_slice.clone(), hl);
            let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
            let mut session = PredictSession::new(
                &guest_m,
                10 + batch_rows as u32,
                PredictOptions {
                    batch_rows,
                    max_inflight: 2,
                    seed: 5,
                    ..PredictOptions::default()
                },
            );
            session.open(&links);
            let (preds, report) = session.predict_stream(&guest_slice, &links);
            assert_eq!(preds, oracle, "chunk size {batch_rows} must be bit-identical");
            assert_eq!(report.chunks, 5usize.div_ceil(batch_rows) as u64);
            assert_eq!(report.batch_rows, batch_rows);
            session.close(&links);
            h.join().unwrap();
        }
    }

    #[test]
    fn guest_only_stream_needs_no_links() {
        let mut t = Tree::new(1);
        t.split_node(0, SplitRef::Guest { feature: 0, bin: 0, threshold: 0.0 });
        t.nodes[1].weight = vec![-1.0];
        t.nodes[2].weight = vec![1.0];
        let m = GuestModel { trees: vec![(t, 0)], n_classes: 2, pred_width: 1 };
        let slice = PartySlice { cols: vec![0], x: vec![-0.5, 0.5, -0.1], n: 3 };
        let mut session = PredictSession::new(
            &m,
            4,
            PredictOptions { batch_rows: 2, ..PredictOptions::default() },
        );
        let (preds, report) = session.predict_stream(&slice, &[]);
        assert_eq!(preds, vec![-1.0, 1.0, -1.0]);
        assert_eq!(report.chunks, 2);
        assert_eq!(report.stall_seconds, 0.0, "no host, no stalls");
    }

    #[test]
    fn stream_repeat_pass_is_wire_free_via_delta_basis() {
        let (guest_m, host_m) = toy_shares();
        let guest_slice = PartySlice { cols: vec![0], x: vec![0.1, 0.1, 0.4, 0.2], n: 4 };
        let host_slice = PartySlice {
            cols: vec![1, 2],
            x: vec![0.0, -2.0, 0.0, 5.0, 0.0, -1.5, 0.0, 1.0],
            n: 4,
        };
        let (gl, hl) = link_pair(8);
        let h = spawn_predict_host(host_m, host_slice, hl);
        let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
        let mut session = PredictSession::new(
            &guest_m,
            77,
            PredictOptions { batch_rows: 3, max_inflight: 2, ..PredictOptions::default() },
        );
        session.open(&links);
        let (first, _) = session.predict_stream(&guest_slice, &links);
        let snap1 = links[0].snapshot();
        // repeat scoring in the same session: every host decision is in
        // the delta basis the first pass synchronized, so the second
        // pass crosses the wire not at all — the chunk memos are gone
        // (bounded memory) but the bounded basis still suppresses
        let (second, _) = session.predict_stream(&guest_slice, &links);
        let snap2 = links[0].snapshot();
        assert_eq!(first, second, "repeat pass must be bit-identical");
        assert_eq!(snap1, snap2, "repeat pass must be wire-free");
        assert!(session.suppressed_queries() > 0);
        session.close(&links);
        h.join().unwrap();
    }

    #[test]
    fn batch_after_stream_decodes_delta_answers() {
        // a streamed pass synchronizes the delta bases; a subsequent
        // predict_batch in the same session starts with an empty session
        // memo, so it re-asks every key — the host elides all of them
        // via RouteAnswersDelta and the guest must reconstruct the bits
        // from its mirrored basis, bit-identically
        let (guest_m, host_m) = toy_shares();
        let guest_slice = PartySlice { cols: vec![0], x: vec![0.1, 0.1, 0.4], n: 3 };
        let host_slice = PartySlice {
            cols: vec![1, 2],
            x: vec![0.0, -2.0, 0.0, 5.0, 0.0, -1.5],
            n: 3,
        };
        let (gl, hl) = link_pair(8);
        let h = spawn_predict_host(host_m, host_slice, hl);
        let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
        let mut session = PredictSession::new(
            &guest_m,
            91,
            PredictOptions { batch_rows: 2, ..PredictOptions::default() },
        );
        session.open(&links);
        let (streamed, _) = session.predict_stream(&guest_slice, &links);
        assert_eq!(session.delta_elided_answers(), 0, "first pass is all fresh");
        let batched = session.predict_batch(&guest_slice, &links);
        assert_eq!(batched, streamed, "delta-elided answers must be bit-identical");
        assert!(
            session.delta_elided_answers() > 0,
            "the repeat batch must have received elided answers"
        );
        session.close(&links);
        h.join().unwrap();
    }

    #[test]
    fn backoff_never_sleeps_below_the_advertised_floor() {
        use std::time::Duration;
        // retry_after_ms advice below, at, and above the 500ms jitter
        // cap: the sleep must stay strictly above the floor in every
        // case, and the cap must bound only the jitter — a 2000ms
        // floor still yields a >2000ms sleep (the old derivation slept
        // in (base/2, base], undercutting the advice by up to 2×)
        for &floor in &[0u64, 30, 200, 500, 2_000] {
            let mut rng = Xoshiro256::seed_from_u64(0xBAC0_0FF);
            for attempt in 0..10u32 {
                let spine = (10u64 << attempt.min(6)).min(500).max(2);
                let d = backoff_with_jitter(&mut rng, attempt, floor);
                assert!(
                    d > Duration::from_millis(floor),
                    "attempt {attempt}, floor {floor}: slept {d:?}, at or below the floor"
                );
                assert!(
                    d <= Duration::from_millis(floor + spine),
                    "attempt {attempt}, floor {floor}: slept {d:?}, beyond floor + spine"
                );
            }
        }
        // pinned seed ⇒ exact replayable schedule (what lets the soak
        // tests reason about retry timing deterministically)
        let schedule = |seed: u64| -> Vec<u128> {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            (0..8u32).map(|a| backoff_with_jitter(&mut rng, a, 700).as_millis()).collect()
        };
        assert_eq!(schedule(7), schedule(7), "same seed must replay the same schedule");
        assert_ne!(schedule(7), schedule(8), "different seeds must jitter apart");
        assert!(schedule(7).iter().all(|&ms| ms > 700 && ms <= 1200));
    }

    #[test]
    fn keyed_session_matches_plaintext_bit_identically() {
        // the full keyed v6 handshake over in-memory links: X25519 +
        // KDF run for real and the handle rotor permutes every wire
        // query (the AEAD layer is a no-op on in-memory transports —
        // byte privacy there is trivial). Predictions, suppression,
        // and message counts must equal the plaintext session's.
        let (guest_m, host_m) = toy_shares();
        let guest_slice = PartySlice { cols: vec![0], x: vec![0.9, 0.1, 0.1, 0.4], n: 4 };
        let host_slice = PartySlice {
            cols: vec![1, 2],
            x: vec![0.0, 0.0, 0.0, -2.0, 0.0, 5.0, 0.0, -1.5],
            n: 4,
        };
        let run = |secure: SecureMode| {
            let (gl, hl) = link_pair(8);
            let h = spawn_predict_host(host_m.clone(), host_slice.clone(), hl);
            let links: Vec<Box<dyn GuestTransport>> = vec![Box::new(gl)];
            let mut session = PredictSession::new(
                &guest_m,
                33,
                PredictOptions { batch_rows: 2, seed: 11, secure, ..PredictOptions::default() },
            );
            session.open(&links);
            let keyed = session.rotors.iter().filter(|r| r.is_some()).count();
            let (preds, _) = session.predict_stream(&guest_slice, &links);
            let msgs = links[0].snapshot().msgs_to_host;
            session.close(&links);
            h.join().unwrap();
            (preds, msgs, keyed)
        };
        let (plain, plain_msgs, plain_keyed) = run(SecureMode::Off);
        let (keyed, keyed_msgs, keyed_keyed) = run(SecureMode::Require);
        assert_eq!(plain_keyed, 0, "secure off must not negotiate a rotor");
        assert_eq!(keyed_keyed, 1, "secure require must negotiate the keyed channel");
        assert_eq!(plain, keyed, "keyed serving must be bit-identical to plaintext");
        assert_eq!(plain_msgs, keyed_msgs, "the keyed channel adds no extra frames");
    }

}
