//! The guest party: owns labels and the secret key, drives boosting.
//!
//! Per tree (paper §4.5 pipeline):
//! 1. compute g/h from current predictions (via the [`ComputeEngine`] —
//!    the AOT JAX/Pallas path or the pure-Rust oracle),
//! 2. GOSS-sample, pack (Alg. 3 / Alg. 7) and encrypt,
//! 3. grow layer-wise: hosts return compressed split statistics
//!    (Alg. 5), the guest decrypts (Alg. 6), evaluates gains (Alg. 2)
//!    against its own local candidates, picks global winners, applies
//!    splits and synchronizes assignments,
//! 4. after the tree completes, routes the *full* population through it
//!    to update predictions (host-owned nodes are resolved with
//!    `ApplySplit` round-trips, as in FATE's distributed inference).

use crate::config::{ModeKind, TrainConfig};
use crate::crypto::cipher::{CipherSuite, Ct};
use crate::crypto::compress::{decompress, CompressPlan};
use crate::crypto::packing::{GhPacker, MoPacker};
use crate::data::binning::{bin_party, BinnedMatrix};
use crate::data::dataset::VerticalSplit;
use crate::data::goss::goss_sample;
use crate::data::sparse::SparseBinned;
use crate::federation::codec::StatCodec;
use crate::federation::message::{CandidateMask, HistTask, NodeStats, ToGuest, ToHost};
use crate::federation::transport::GuestTransport;
use crate::metrics::{accuracy_multiclass, auc, celoss_multiclass, logloss_binary};
use crate::runtime::engine::ComputeEngine;
use crate::tree::histogram::PlainHistogram;
use crate::tree::node::{SplitRef, Tree};
use crate::tree::split::{best_local_split, candidate_gain, LocalSplit};
use crate::util::rng::{ChaCha20Rng, Xoshiro256};
use crate::util::timer::PhaseTimer;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// A candidate split for one node: either the guest's local best or a
/// decrypted host statistic.
enum Candidate {
    Guest(LocalSplit),
    Host { party: u8, handle: u32, gain: f64, left_g: Vec<f64>, left_h: Vec<f64>, left_count: u32 },
}

impl Candidate {
    fn gain(&self) -> f64 {
        match self {
            Candidate::Guest(s) => s.gain,
            Candidate::Host { gain, .. } => *gain,
        }
    }
}

/// Everything the guest accumulates during a training run.
pub struct GuestOutcome {
    /// The boosted trees, in build order.
    pub trees: Vec<Tree>,
    /// Class tag per tree (0 for binary / multi-output trees).
    pub tree_classes: Vec<usize>,
    /// Wall time per tree (tree building only).
    pub tree_seconds: Vec<f64>,
    /// Final raw margins over the training set.
    pub preds: Vec<f64>,
    /// Training loss after each epoch.
    pub loss_curve: Vec<f64>,
    /// AUC (binary) or accuracy (multi-class) on the training set.
    pub train_metric: f64,
    /// Guest-side phase timings.
    pub timer: PhaseTimer,
}

/// Guest training engine.
pub struct GuestParty<'a> {
    vs: &'a VerticalSplit,
    cfg: &'a TrainConfig,
    engine: &'a dyn ComputeEngine,
    links: &'a [Box<dyn GuestTransport>],
    bm: BinnedMatrix,
    sb: Option<SparseBinned>,
    suite: CipherSuite,
    rng: Xoshiro256,
    crng: ChaCha20Rng,
    /// Fixed statistic layout for the whole run (must match what Setup
    /// told the hosts — bit widths are part of the protocol, paper §4.5).
    codec: StatCodec,
    compress: Option<CompressPlan>,
    /// Guest-side phase timings (merged into the train report).
    pub timer: PhaseTimer,
}

impl<'a> GuestParty<'a> {
    /// Build a guest over pre-connected host links (does not talk yet;
    /// call [`Self::setup_hosts`] before [`Self::train`]).
    pub fn new(
        vs: &'a VerticalSplit,
        cfg: &'a TrainConfig,
        engine: &'a dyn ComputeEngine,
        links: &'a [Box<dyn GuestTransport>],
        suite: CipherSuite,
    ) -> Self {
        let bm = bin_party(&vs.guest, cfg.max_bin);
        // sparse view only when the data is actually sparse (density gate)
        let sb = crate::data::sparse::maybe_sparse(&vs.guest, &bm, cfg.sparse_optimization);
        let (codec, compress) = plan_codec(vs, cfg, &suite);
        GuestParty {
            vs,
            cfg,
            engine,
            links,
            bm,
            sb,
            suite,
            rng: Xoshiro256::seed_from_u64(cfg.seed),
            crng: ChaCha20Rng::from_u64(cfg.seed ^ 0xC1FE),
            codec,
            compress,
            timer: PhaseTimer::new(),
        }
    }

    /// Width of the statistic vectors (1 binary / one-vs-all, k for MO).
    fn width(&self) -> usize {
        match self.cfg.mode {
            ModeKind::MultiOutput => self.vs.n_classes,
            _ => 1,
        }
    }

    /// Run the whole boosting loop. Hosts must already be set up with
    /// [`Self::setup_hosts`].
    pub fn train(&mut self) -> GuestOutcome {
        let n = self.vs.n();
        let k = self.vs.n_classes;
        let binary = k == 2;
        let mo = matches!(self.cfg.mode, ModeKind::MultiOutput);
        let pred_width = if binary { 1 } else { k };
        let mut preds = vec![0.0f64; n * pred_width];
        let mut trees: Vec<Tree> = Vec::new();
        let mut tree_classes: Vec<usize> = Vec::new();
        let mut tree_seconds = Vec::new();
        let mut loss_curve = Vec::new();

        for epoch in 0..self.cfg.epochs {
            // -------- g/h via the compute engine (L2/L1 artifacts) -----
            let t_gh = Instant::now();
            let (g, h) = if binary {
                self.engine.gh_binary(&self.vs.y, &preds)
            } else {
                self.engine.gh_softmax(&self.vs.y, &preds, k)
            };
            self.timer.add("guest.gh_compute", t_gh.elapsed());

            if mo || binary {
                let t0 = Instant::now();
                let tree = self.build_one_tree(trees.len() as u32, &g, &h, self.width());
                tree_seconds.push(t0.elapsed().as_secs_f64());
                self.route_and_update(&tree, &mut preds, 0, pred_width);
                trees.push(tree);
                tree_classes.push(0);
            } else {
                // traditional multi-class: one tree per class per epoch
                for cls in 0..k {
                    let gc: Vec<f64> = (0..n).map(|i| g[i * k + cls]).collect();
                    let hc: Vec<f64> = (0..n).map(|i| h[i * k + cls]).collect();
                    let t0 = Instant::now();
                    let tree = self.build_one_tree(trees.len() as u32, &gc, &hc, 1);
                    tree_seconds.push(t0.elapsed().as_secs_f64());
                    self.route_and_update(&tree, &mut preds, cls, pred_width);
                    trees.push(tree);
                    tree_classes.push(cls);
                }
            }

            let loss = if binary {
                logloss_binary(&self.vs.y, &preds)
            } else {
                celoss_multiclass(&self.vs.y, &preds, k)
            };
            loss_curve.push(loss);
            if self.cfg.verbose {
                eprintln!(
                    "[sbp] epoch {epoch:>3} loss {loss:.5} trees {}",
                    trees.len()
                );
            }
        }

        let train_metric = if binary {
            auc(&self.vs.y, &preds)
        } else {
            accuracy_multiclass(&self.vs.y, &preds, k)
        };
        GuestOutcome {
            trees,
            tree_classes,
            tree_seconds,
            preds,
            loss_curve,
            train_metric,
            timer: self.timer.clone(),
        }
    }

    /// Which party builds tree `t` in mix mode (round-robin, guest first).
    fn mix_owner(&self, tree_id: u32) -> Option<u8> {
        match self.cfg.mode {
            ModeKind::Mix { trees_per_party } => {
                let parties = 1 + self.links.len();
                let slot = (tree_id as usize / trees_per_party.max(1)) % parties;
                if slot == 0 {
                    None // guest
                } else {
                    Some((slot - 1) as u8)
                }
            }
            _ => None,
        }
    }

    /// Candidate mask for a layer at `depth` under the current mode.
    fn layer_mask(&self, tree_id: u32, depth: u8) -> CandidateMask {
        match self.cfg.mode {
            ModeKind::Default | ModeKind::MultiOutput => CandidateMask::All,
            ModeKind::Mix { .. } => match self.mix_owner(tree_id) {
                None => CandidateMask::GuestOnly,
                Some(h) => CandidateMask::HostOnly(h),
            },
            ModeKind::Layered { host_depth, .. } => {
                if depth < host_depth {
                    CandidateMask::HostsOnly
                } else {
                    CandidateMask::GuestOnly
                }
            }
        }
    }

    /// Does the protocol need any host participation for this tree?
    fn tree_uses_hosts(&self, tree_id: u32) -> bool {
        !matches!(self.layer_mask(tree_id, 0), CandidateMask::GuestOnly)
            || matches!(self.cfg.mode, ModeKind::Layered { .. })
    }

    fn hosts_for(&self, mask: CandidateMask) -> Vec<usize> {
        match mask {
            CandidateMask::All | CandidateMask::HostsOnly => (0..self.links.len()).collect(),
            CandidateMask::HostOnly(h) => vec![h as usize],
            CandidateMask::GuestOnly => Vec::new(),
        }
    }

    /// Build one federated tree on (possibly width-k) statistics.
    fn build_one_tree(&mut self, tree_id: u32, g: &[f64], h: &[f64], w: usize) -> Tree {
        let n = self.vs.n();
        // -------- GOSS sampling + weight amplification ------------------
        // GOSS is skipped for multi-output trees: class-summed gradient
        // magnitudes are near-uniform in early rounds, so the sample is
        // arbitrary and the (1−a)/b amplification destabilizes the
        // vector-valued leaves (measured: sensorless diverges, loss
        // 2.3 → 65; see EXPERIMENTS.md §Fig9/10 notes).
        let goss_cfg = if w > 1 { None } else { self.cfg.goss };
        let (instances, gs, hs): (Vec<u32>, Vec<f64>, Vec<f64>) = match &goss_cfg {
            Some(gc) => {
                let mag: Vec<f64> = (0..n)
                    .map(|i| (0..w).map(|j| g[i * w + j].abs()).sum())
                    .collect();
                let s = goss_sample(&mag, gc.top_rate, gc.other_rate, &mut self.rng);
                let mut gv = g.to_vec();
                let mut hv = h.to_vec();
                for (&i, &wt) in s.indices.iter().zip(&s.weights) {
                    if wt != 1.0 {
                        for j in 0..w {
                            gv[i as usize * w + j] *= wt;
                            hv[i as usize * w + j] *= wt;
                        }
                    }
                }
                (s.indices, gv, hv)
            }
            None => ((0..n as u32).collect(), g.to_vec(), h.to_vec()),
        };

        // entirely-local guest tree (mix mode)
        if !self.tree_uses_hosts(tree_id) {
            let grow = crate::boosting::gbdt::GrowParams::from_config(self.cfg);
            let t0 = Instant::now();
            let tree = crate::boosting::gbdt::grow_tree_plain(
                &self.bm,
                self.sb.as_ref(),
                &instances,
                &gs,
                &hs,
                w,
                &grow,
            );
            self.timer.add("guest.local_tree", t0.elapsed());
            return tree;
        }

        // -------- pack + encrypt + ship to hosts ------------------------
        let codec = self.codec.clone();
        let sampled: Vec<u32> = instances.clone();
        let t_pack = Instant::now();
        let (packed_cts, node_total) = {
            // SAMPLE-ORDER packing: only the GOSS-sampled instances are
            // encoded and encrypted (row s of `packed` ↔ instances[s]);
            // hosts rebuild the id→row map from StartTree's instance list.
            let n_k = codec.n_k();
            let mut plains = Vec::with_capacity(sampled.len() * n_k);
            for &i in &sampled {
                let i = i as usize;
                plains.extend(
                    codec.encode_instance(&gs[i * w..(i + 1) * w], &hs[i * w..(i + 1) * w]),
                );
            }
            let cts = self.suite.encrypt_batch(&plains, &mut self.crng);
            // node totals over the sample (sparse zero-bin recovery)
            let mut tot = vec![self.suite.zero_ct(); n_k];
            for row in 0..sampled.len() {
                for j in 0..n_k {
                    self.suite.add_assign(&mut tot[j], &cts[row * n_k + j]);
                }
            }
            (cts, tot)
        };
        self.timer.add("guest.pack_encrypt", t_pack.elapsed());
        let packed = Arc::new(packed_cts);
        let instances_arc = Arc::new(sampled);

        let engaged = self.hosts_for(match self.layer_mask(tree_id, 0) {
            CandidateMask::GuestOnly => CandidateMask::HostsOnly, // layered: hosts engaged later
            m => m,
        });
        for &hidx in &engaged {
            self.links[hidx].send(ToHost::StartTree {
                tree_id,
                instances: instances_arc.clone(),
                packed: packed.clone(),
                node_total: node_total.clone(),
            });
        }
        for &hidx in &engaged {
            let _ = self.links[hidx].recv(); // Ack
        }

        // -------- layer-wise growth -------------------------------------
        let mut tree = Tree::new(w);
        let mut members: HashMap<u32, Vec<u32>> = HashMap::new();
        members.insert(0, instances_arc.as_ref().clone());
        let (rg, rh) = node_totals(&instances, &gs, &hs, w);
        tree.nodes[0].sum_g = rg;
        tree.nodes[0].sum_h = rh;
        tree.nodes[0].n_samples = instances.len() as u32;

        let mut layer: Vec<u32> = vec![0];
        let mut guest_hist_cache: HashMap<u32, PlainHistogram> = HashMap::new();

        for depth in 0..self.cfg.max_depth {
            if layer.is_empty() {
                break;
            }
            let mask = self.layer_mask(tree_id, depth);
            let hosts = self.hosts_for(mask);
            let guest_active = matches!(mask, CandidateMask::All | CandidateMask::GuestOnly);

            // ---- plan tasks: smaller sibling direct, larger subtracted.
            // Ciphertext subtraction costs one negation (~inverse) per
            // (feature, bin) cell, so it only beats a direct rebuild when
            // the sibling holds > n_bins × (c_neg/c_add) instances — the
            // planner is cost-aware (DESIGN.md §Perf iteration 1). At the
            // paper's million-row scale this always chooses subtraction.
            let host_threshold =
                self.cfg.max_bin * self.suite.negate_cost_ratio();
            let host_tasks = self.plan_tasks(&tree, &layer, &members, host_threshold);
            // Plaintext subtraction is virtually free — always on for the
            // guest's own f64 histograms.
            let tasks = self.plan_tasks(&tree, &layer, &members, 0);

            // ---- dispatch to hosts
            for &hidx in &hosts {
                self.links[hidx]
                    .send(ToHost::BuildLayer { tree_id, tasks: host_tasks.clone() });
            }

            // ---- guest's own histograms + local candidates (overlapped
            //      with host work in real deployments; sequential here —
            //      wall time attribution stays per-party via timers)
            let mut candidates: HashMap<u32, Candidate> = HashMap::new();
            if guest_active {
                let t_local = Instant::now();
                {
                    let mut new_cache = HashMap::new();
                    for task in &tasks {
                        let node = task.node();
                        let hist = match task {
                            HistTask::Direct { .. } => self.build_guest_hist(
                                &members[&node],
                                &gs,
                                &hs,
                                w,
                                &tree.nodes[node as usize],
                            ),
                            HistTask::Subtract { parent, sibling, .. } => {
                                // In layered mode the guest joins mid-tree:
                                // no cached parent yet → build directly.
                                match (guest_hist_cache.get(parent), new_cache.get(sibling)) {
                                    (Some(p), Some(s)) => {
                                        let s: &PlainHistogram = s;
                                        p.subtract(s)
                                    }
                                    _ => self.build_guest_hist(
                                        &members[&node],
                                        &gs,
                                        &hs,
                                        w,
                                        &tree.nodes[node as usize],
                                    ),
                                }
                            }
                        };
                        new_cache.insert(node, hist);
                    }
                    for (&node, hist) in &new_cache {
                        let nd = &tree.nodes[node as usize];
                        let mut cum = hist.clone();
                        cum.cumsum();
                        if let Some(s) = best_local_split(
                            &cum,
                            &nd.sum_g,
                            &nd.sum_h,
                            nd.n_samples,
                            &self.cfg.gain,
                        ) {
                            candidates.insert(node, Candidate::Guest(s));
                        }
                    }
                    guest_hist_cache = new_cache;
                }
                self.timer.add("guest.local_hist+split", t_local.elapsed());
            }

            // ---- receive + decrypt host statistics, keep global best
            for &hidx in &hosts {
                let msg = self.links[hidx].recv();
                let ToGuest::LayerStats { nodes, .. } = msg else {
                    panic!("expected LayerStats")
                };
                let t_dec = Instant::now();
                {
                    for (node, stats) in nodes {
                        let nd = &tree.nodes[node as usize];
                        let decoded = self.decode_stats(&codec, stats);
                        for (handle, count, gsum, hsum) in decoded {
                            if let Some(gain) = candidate_gain(
                                &gsum,
                                &hsum,
                                count,
                                &nd.sum_g,
                                &nd.sum_h,
                                nd.n_samples,
                                &self.cfg.gain,
                            ) {
                                let better = candidates
                                    .get(&node)
                                    .map(|c| gain > c.gain())
                                    .unwrap_or(true);
                                if better {
                                    candidates.insert(
                                        node,
                                        Candidate::Host {
                                            party: hidx as u8,
                                            handle,
                                            gain,
                                            left_g: gsum,
                                            left_h: hsum,
                                            left_count: count,
                                        },
                                    );
                                }
                            }
                        }
                    }
                }
                self.timer.add("guest.decrypt+gain", t_dec.elapsed());
            }

            // ---- apply winners
            let mut next_layer = Vec::new();
            for node in layer {
                let Some(cand) = candidates.remove(&node) else { continue };
                let insts = members.remove(&node).expect("members tracked");
                let (split_ref, left_ids, lg, lh, lc, gain) = match cand {
                    Candidate::Guest(s) => {
                        let thr = self.bm.specs[s.feature as usize].threshold(s.bin);
                        let left: Vec<u32> = insts
                            .iter()
                            .copied()
                            .filter(|&i| {
                                self.bm.bin(i as usize, s.feature as usize) <= s.bin
                            })
                            .collect();
                        (
                            SplitRef::Guest { feature: s.feature, bin: s.bin, threshold: thr },
                            left,
                            s.left_g,
                            s.left_h,
                            s.left_count,
                            s.gain,
                        )
                    }
                    Candidate::Host { party, handle, gain, left_g, left_h, left_count } => {
                        let link = &self.links[party as usize];
                        link.send(ToHost::ApplySplit {
                            tree_id,
                            node,
                            handle,
                            instances: Arc::new(insts.clone()),
                        });
                        let ToGuest::LeftInstances { left, .. } = link.recv() else {
                            panic!("expected LeftInstances")
                        };
                        (
                            SplitRef::Host { party, handle },
                            left,
                            left_g,
                            left_h,
                            left_count,
                            gain,
                        )
                    }
                };
                let (lid, rid) = tree.split_node(node, split_ref);
                tree.nodes[node as usize].gain = gain;
                // partition members
                let leftset: std::collections::HashSet<u32> = left_ids.iter().copied().collect();
                let (li, ri): (Vec<u32>, Vec<u32>) =
                    insts.into_iter().partition(|i| leftset.contains(i));
                debug_assert_eq!(li.len() as u32, lc);
                let pg = tree.nodes[node as usize].sum_g.clone();
                let ph = tree.nodes[node as usize].sum_h.clone();
                let rgv: Vec<f64> = pg.iter().zip(&lg).map(|(a, b)| a - b).collect();
                let rhv: Vec<f64> = ph.iter().zip(&lh).map(|(a, b)| a - b).collect();
                set_stats(&mut tree, lid, &lg, &lh, li.len() as u32);
                set_stats(&mut tree, rid, &rgv, &rhv, ri.len() as u32);

                // synchronize the assignment to all engaged hosts
                let left_arc = Arc::new(li.clone());
                for &hidx in &engaged {
                    self.links[hidx].send(ToHost::SyncAssign {
                        tree_id,
                        node,
                        left_child: lid,
                        right_child: rid,
                        left: left_arc.clone(),
                    });
                }
                for &hidx in &engaged {
                    let _ = self.links[hidx].recv(); // Ack
                }
                members.insert(lid, li);
                members.insert(rid, ri);
                next_layer.push(lid);
                next_layer.push(rid);
            }
            layer = next_layer;
        }

        crate::boosting::gbdt::finalize_leaves(
            &mut tree,
            self.cfg.gain.lambda,
            self.cfg.learning_rate,
        );
        for &hidx in &engaged {
            self.links[hidx].send(ToHost::FinishTree { tree_id });
        }
        for &hidx in &engaged {
            let _ = self.links[hidx].recv();
        }
        tree
    }

    /// Decode a host's node statistics into (handle, count, Σg, Σh) rows.
    fn decode_stats(
        &self,
        codec: &StatCodec,
        stats: NodeStats,
    ) -> Vec<(u32, u32, Vec<f64>, Vec<f64>)> {
        match stats {
            NodeStats::Compressed(packages) => {
                let StatCodec::Packed(packer) = codec else {
                    panic!("compressed stats require the packed codec")
                };
                let plan = self.compress.expect("compression plan agreed at setup");
                decompress(&self.suite, &plan, packer, &packages)
                    .into_iter()
                    .map(|s| (s.id, s.sample_count, vec![s.g_sum], vec![s.h_sum]))
                    .collect()
            }
            NodeStats::Raw(rows) => {
                // batch-decrypt all ciphertexts of this node at once
                let flat: Vec<Ct> = rows.iter().flat_map(|(_, _, cts)| cts.clone()).collect();
                let plains = self.suite.decrypt_batch(&flat);
                let n_k = codec.n_k();
                rows.iter()
                    .enumerate()
                    .map(|(idx, (handle, count, _))| {
                        let (gsum, hsum) = codec
                            .decode_sum(&plains[idx * n_k..(idx + 1) * n_k], *count as u64);
                        (*handle, *count, gsum, hsum)
                    })
                    .collect()
            }
        }
    }

    /// Guest-side plaintext histogram for a node (sparse-aware; large
    /// nodes use the compute engine's histogram kernel).
    fn build_guest_hist(
        &self,
        insts: &[u32],
        g: &[f64],
        h: &[f64],
        w: usize,
        node: &crate::tree::node::TreeNode,
    ) -> PlainHistogram {
        let n_bins = self.cfg.max_bin;
        // Engine path: scalar stats over large nodes — the AOT histogram
        // kernel works on gathered rows.
        if w == 1 && insts.len() >= 2048 && self.sb.is_none() {
            let d = self.bm.d;
            let mut gather_bins = Vec::with_capacity(insts.len() * d);
            let mut gg = Vec::with_capacity(insts.len());
            let mut hh = Vec::with_capacity(insts.len());
            for &i in insts {
                gather_bins.extend_from_slice(self.bm.row(i as usize));
                gg.push(g[i as usize]);
                hh.push(h[i as usize]);
            }
            let (gh, hh2, ch) =
                self.engine.histogram(&gather_bins, insts.len(), d, n_bins, &gg, &hh);
            return PlainHistogram { n_features: d, n_bins, w: 1, g: gh, h: hh2, count: ch };
        }
        match &self.sb {
            Some(sb) => PlainHistogram::build_sparse(
                sb,
                n_bins,
                insts,
                g,
                h,
                w,
                &node.sum_g,
                &node.sum_h,
                node.n_samples,
            ),
            None => PlainHistogram::build(&self.bm, n_bins, insts, g, h, w),
        }
    }

    /// Direct/subtract task plan for a layer (smaller sibling direct).
    /// The larger sibling is derived by subtraction only when it holds
    /// more than `threshold` instances (0 = always subtract).
    fn plan_tasks(
        &self,
        tree: &Tree,
        layer: &[u32],
        members: &HashMap<u32, Vec<u32>>,
        threshold: usize,
    ) -> Vec<HistTask> {
        if layer == [0] {
            return vec![HistTask::Direct { node: 0 }];
        }
        let mut direct = Vec::new();
        let mut subtract = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for &node in layer {
            if seen.contains(&node) {
                continue;
            }
            let parent = tree.nodes[node as usize].parent as u32;
            let pnode = &tree.nodes[parent as usize];
            let (l, r) = (pnode.left as u32, pnode.right as u32);
            seen.insert(l);
            seen.insert(r);
            let (small, big) =
                if members[&l].len() <= members[&r].len() { (l, r) } else { (r, l) };
            direct.push(HistTask::Direct { node: small });
            if self.cfg.hist_subtraction && members[&big].len() > threshold {
                subtract.push(HistTask::Subtract { node: big, parent, sibling: small });
            } else {
                direct.push(HistTask::Direct { node: big });
            }
        }
        direct.extend(subtract);
        direct
    }

    /// Route the full population through a finished tree and add leaf
    /// weights into the prediction matrix.
    fn route_and_update(&mut self, tree: &Tree, preds: &mut [f64], class: usize, k: usize) {
        let n = self.vs.n();
        let t_route = Instant::now();
        {
            let mut at_node: HashMap<u32, Vec<u32>> = HashMap::new();
            at_node.insert(0, (0..n as u32).collect());
            // BFS over nodes in id order (children have larger ids)
            for node in &tree.nodes {
                let Some(split) = &node.split else { continue };
                let Some(insts) = at_node.remove(&node.id) else { continue };
                let left: Vec<u32> = match split {
                    SplitRef::Guest { feature, bin, .. } => insts
                        .iter()
                        .copied()
                        .filter(|&i| self.bm.bin(i as usize, *feature as usize) <= *bin)
                        .collect(),
                    SplitRef::Host { party, handle } => {
                        let link = &self.links[*party as usize];
                        link.send(ToHost::ApplySplit {
                            tree_id: u32::MAX, // routing pass
                            node: node.id,
                            handle: *handle,
                            instances: Arc::new(insts.clone()),
                        });
                        let ToGuest::LeftInstances { left, .. } = link.recv() else {
                            panic!("expected LeftInstances")
                        };
                        left
                    }
                };
                let leftset: std::collections::HashSet<u32> = left.iter().copied().collect();
                let (li, ri): (Vec<u32>, Vec<u32>) =
                    insts.into_iter().partition(|i| leftset.contains(i));
                at_node.insert(node.left as u32, li);
                at_node.insert(node.right as u32, ri);
            }
            for (node_id, insts) in at_node {
                let node = &tree.nodes[node_id as usize];
                debug_assert!(node.is_leaf());
                for &i in &insts {
                    if tree.width == 1 {
                        preds[i as usize * k + class] += node.weight[0];
                    } else {
                        for (j, &v) in node.weight.iter().enumerate() {
                            preds[i as usize * k + j] += v;
                        }
                    }
                }
            }
        }
        self.timer.add("guest.route_predict", t_route.elapsed());
    }

    /// One-time host setup (cipher material, codec layout, toggles).
    pub fn setup_hosts(&mut self) {
        for link in self.links {
            link.send(ToHost::Setup {
                suite_public: self.suite.public_side(),
                codec: self.codec.clone(),
                compress: self.compress,
                n_bins: self.cfg.max_bin,
                hist_subtraction: self.cfg.hist_subtraction,
                sparse_optimization: self.cfg.sparse_optimization,
                seed: self.cfg.seed,
            });
        }
        for link in self.links {
            let _ = link.recv();
        }
    }
}

/// Plan the fixed statistic layout for a whole run. The bit widths must
/// bound the *worst case* over all trees: GOSS amplifies small-gradient
/// survivors by `(1−a)/b`, so the value range is the loss's natural range
/// scaled by that factor. Guest and hosts agree on this layout once, at
/// setup (the paper synchronizes `b_gh` and η_s the same way, §4.5).
fn plan_codec(
    vs: &VerticalSplit,
    cfg: &TrainConfig,
    suite: &CipherSuite,
) -> (StatCodec, Option<CompressPlan>) {
    // GOSS never applies to multi-output trees (see build_one_tree)
    let goss = if matches!(cfg.mode, ModeKind::MultiOutput) { None } else { cfg.goss };
    let amp = goss
        .map(|g| ((1.0 - g.top_rate) / g.other_rate.max(1e-9)).max(1.0))
        .unwrap_or(1.0);
    // overflow bound: only sampled instances ever enter a histogram sum
    let sample_frac = goss.map(|g| g.top_rate + g.other_rate).unwrap_or(1.0);
    let n_bound = ((vs.n() as f64 * sample_frac).ceil() as u64).max(1);
    let enc = crate::crypto::encoding::FixedPointEncoder::new(cfg.precision);
    // logistic/softmax ranges: g ∈ [−1, 1]·amp (offset by amp), h ∈ [0, 1]·amp
    let g_off = amp;
    let b_g = enc.sum_bits(2.0 * amp, n_bound);
    let b_h = enc.sum_bits(amp, n_bound);
    let packer = GhPacker { enc, g_off, b_g, b_h, b_gh: b_g + b_h };

    let codec = match cfg.mode {
        ModeKind::MultiOutput => {
            let eta_c = (suite.plaintext_bits() / packer.b_gh).max(1).min(vs.n_classes);
            assert!(
                packer.b_gh <= suite.plaintext_bits(),
                "one class does not fit the plaintext space"
            );
            let n_k = vs.n_classes.div_ceil(eta_c);
            StatCodec::Multi(MoPacker { base: packer, k: vs.n_classes, eta_c, n_k })
        }
        _ if cfg.gh_packing => StatCodec::Packed(packer),
        _ => StatCodec::Separate(packer),
    };
    let compress = match (cfg.cipher_compression, codec.compressible_b_gh()) {
        (true, Some(b_gh)) => Some(CompressPlan::derive(suite.plaintext_bits(), b_gh)),
        _ => None,
    };
    (codec, compress)
}

fn node_totals(instances: &[u32], g: &[f64], h: &[f64], w: usize) -> (Vec<f64>, Vec<f64>) {
    let mut sg = vec![0.0; w];
    let mut sh = vec![0.0; w];
    for &i in instances {
        for j in 0..w {
            sg[j] += g[i as usize * w + j];
            sh[j] += h[i as usize * w + j];
        }
    }
    (sg, sh)
}

fn set_stats(tree: &mut Tree, id: u32, g: &[f64], h: &[f64], n: u32) {
    let node = &mut tree.nodes[id as usize];
    node.sum_g = g.to_vec();
    node.sum_h = h.to_vec();
    node.n_samples = n;
}
