//! Deterministic fault injection for the serving transports.
//!
//! Recovery code that is only exercised by real network failures is
//! recovery code that is never exercised. This module makes failures
//! *happen on demand*: a seeded [`FaultPlan`] names the exact frame
//! boundary where a connection dies, how many bytes of the next frame
//! leak out first (torn write), and how much latency to inject — so
//! every failure scenario the resumption tests assert
//! (`tests/serve_fault.rs`) is a replayable seed, never a flaky race.
//!
//! Two composable wrappers cover both transport styles:
//!
//! - [`FaultyTransport`] wraps the guest's blocking
//!   [`TcpGuestTransport`] behind the same [`GuestTransport`] trait, so
//!   the prediction engine cannot tell it is being sabotaged. It counts
//!   every frame that fully crosses the link (both directions) and,
//!   when the armed plan's boundary is reached, kills the socket —
//!   optionally after tearing the next outbound frame — and surfaces
//!   the injected death through `try_send`/`try_recv` exactly like a
//!   real one. Kills are **graceful FINs**, not RSTs: everything fully
//!   written before the kill still reaches the host, which is what
//!   makes the replay arithmetic of a resumed session deterministic
//!   (the host answers precisely the requests that were fully sent).
//! - [`FaultyConn`] is the byte-level feeder for the host's
//!   non-blocking [`NbConn`](super::tcp::NbConn): it dribbles raw bytes
//!   at chosen split points and tears/kills frames mid-flight, driving
//!   the reactor's incremental reassembly through every short-read
//!   shape the partial-I/O corpus enumerates.
//!
//! Per-kill bookkeeping ([`FaultyTransport::kill_log`]) records how
//! many `PredictRoute` frames had fully crossed versus how many answer
//! frames had come back at the moment of each kill — the two numbers
//! whose difference is exactly the count of answer frames a resumed
//! session must see replayed, letting tests assert
//! `chunks_replayed` matches the injected plan *exactly*.

use super::message::{ToGuest, ToGuestKind, ToHost, ToHostKind};
use super::tcp::TcpGuestTransport;
use super::transport::{GuestTransport, NetSnapshot};
use crate::util::rng::Xoshiro256;
use std::collections::VecDeque;
use std::io::Write;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

/// One injected connection failure, fully determined up front.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// The seed this plan was derived from (bookkeeping only — carried
    /// so a failing test case prints the seed that reproduces it).
    pub seed: u64,
    /// Kill the connection at the frame boundary after this many
    /// frames (both directions combined) have fully crossed the
    /// wrapper since it was armed: the operation that would carry
    /// frame `kill_after_frames + 1` dies instead. `0` = never kill.
    pub kill_after_frames: u64,
    /// When the kill lands on a *send*, write this many bytes of the
    /// doomed frame first (a torn write the receiver must discard);
    /// `0` kills cleanly at the boundary. Ignored for kills landing on
    /// a receive.
    pub partial_write_bytes: usize,
    /// Latency injected immediately before the kill fires.
    pub delay: Duration,
}

impl FaultPlan {
    /// A plan that never fires (pass-through wrapper).
    pub fn benign() -> FaultPlan {
        FaultPlan { seed: 0, kill_after_frames: 0, partial_write_bytes: 0, delay: Duration::ZERO }
    }

    /// Derive a kill plan deterministically from `seed`: the boundary
    /// lands in `1..=max_frames`, roughly half the kills tear the
    /// doomed frame (1–63 leaked bytes), and a quarter inject a small
    /// (≤ 5 ms) delay first. Same seed, same plan — always.
    pub fn from_seed(seed: u64, max_frames: u64) -> FaultPlan {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xFA17_FA17_FA17_FA17);
        let kill_after_frames = 1 + rng.next_u64() % max_frames.max(1);
        let partial_write_bytes =
            if rng.next_u64() % 2 == 0 { 1 + (rng.next_u64() % 63) as usize } else { 0 };
        let delay = if rng.next_u64() % 4 == 0 {
            Duration::from_millis(1 + rng.next_u64() % 5)
        } else {
            Duration::ZERO
        };
        FaultPlan { seed, kill_after_frames, partial_write_bytes, delay }
    }
}

fn injected(what: &'static str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::ConnectionReset, format!("injected fault: {what}"))
}

struct FaultState {
    /// Remaining plans; the front one is armed. After its kill fires the
    /// wrapper stays dead until [`GuestTransport::reconnect`] pops it
    /// and arms the next (a connection's plan dies with the connection).
    plans: VecDeque<FaultPlan>,
    /// Frames fully crossed since the armed plan was armed.
    frames_since_arm: u64,
    /// Frames fully crossed over the wrapper's whole life.
    frames_total: u64,
    /// The armed plan has fired and no reconnect has happened yet.
    dead: bool,
    /// Cumulative fully-sent `PredictRoute` frames.
    routes_sent: u64,
    /// Cumulative fully-received answer frames
    /// (`RouteAnswers`/`RouteAnswersDelta`).
    answers_recv: u64,
    /// `(routes_sent, answers_recv)` at the moment of each kill.
    kill_log: Vec<(u64, u64)>,
}

impl FaultState {
    fn armed_kill(&self) -> Option<FaultPlan> {
        let plan = self.plans.front()?;
        (plan.kill_after_frames != 0 && self.frames_since_arm >= plan.kill_after_frames)
            .then_some(*plan)
    }

    fn record_kill(&mut self) {
        self.dead = true;
        self.kill_log.push((self.routes_sent, self.answers_recv));
    }
}

/// Fault-injecting [`GuestTransport`] wrapper over a
/// [`TcpGuestTransport`] (see the module docs). Traffic counters and
/// reconnection are the inner transport's — the wrapper only decides
/// *when* the connection dies.
pub struct FaultyTransport {
    inner: TcpGuestTransport,
    st: Mutex<FaultState>,
}

impl FaultyTransport {
    /// Wrap `inner` with a queue of plans: the first is armed now, each
    /// subsequent one is armed by the reconnect that recovers from its
    /// predecessor's kill. An empty queue (or [`FaultPlan::benign`]
    /// entries) makes the wrapper a pure pass-through.
    pub fn new(inner: TcpGuestTransport, plans: Vec<FaultPlan>) -> FaultyTransport {
        FaultyTransport {
            inner,
            st: Mutex::new(FaultState {
                plans: plans.into(),
                frames_since_arm: 0,
                frames_total: 0,
                dead: false,
                routes_sent: 0,
                answers_recv: 0,
                kill_log: Vec::new(),
            }),
        }
    }

    /// Kills fired so far.
    pub fn kills(&self) -> u64 {
        self.st.lock().expect("fault state poisoned").kill_log.len() as u64
    }

    /// `(fully_sent_routes, fully_received_answers)` at the moment of
    /// each kill, in kill order. For each entry the difference is the
    /// exact number of answer frames the host must replay on resume:
    /// a graceful kill delivers every fully-sent request, the host
    /// answers all of them into its replay buffer, and the guest has
    /// acknowledged precisely `answers_recv`.
    pub fn kill_log(&self) -> Vec<(u64, u64)> {
        self.st.lock().expect("fault state poisoned").kill_log.clone()
    }

    /// Frames fully crossed in both directions over the wrapper's life
    /// (sizing input for exhaustive frame-boundary sweeps).
    pub fn frames_total(&self) -> u64 {
        self.st.lock().expect("fault state poisoned").frames_total
    }
}

impl GuestTransport for FaultyTransport {
    fn send(&self, msg: ToHost) {
        self.try_send(msg).expect("injected fault on send reached a non-resuming caller");
    }

    fn recv(&self) -> ToGuest {
        self.try_recv().expect("injected fault on recv reached a non-resuming caller")
    }

    fn snapshot(&self) -> NetSnapshot {
        self.inner.snapshot()
    }

    fn try_send(&self, msg: ToHost) -> std::io::Result<()> {
        let kind = msg.kind();
        let mut st = self.st.lock().expect("fault state poisoned");
        if st.dead {
            return Err(injected("connection already killed"));
        }
        if let Some(plan) = st.armed_kill() {
            if !plan.delay.is_zero() {
                std::thread::sleep(plan.delay);
            }
            if plan.partial_write_bytes > 0 {
                // leak a deterministic prefix of the doomed frame; the
                // receiver's defensive decode discards the torn frame
                let _ = self.inner.send_torn(&msg, plan.partial_write_bytes);
            }
            self.inner.kill();
            st.record_kill();
            return Err(injected("send at planned frame boundary"));
        }
        self.inner.try_send(msg)?;
        st.frames_since_arm += 1;
        st.frames_total += 1;
        if kind == ToHostKind::PredictRoute {
            st.routes_sent += 1;
        }
        Ok(())
    }

    fn try_recv(&self) -> std::io::Result<ToGuest> {
        {
            let mut st = self.st.lock().expect("fault state poisoned");
            if st.dead {
                return Err(injected("connection already killed"));
            }
            if let Some(plan) = st.armed_kill() {
                if !plan.delay.is_zero() {
                    std::thread::sleep(plan.delay);
                }
                self.inner.kill();
                st.record_kill();
                return Err(injected("recv at planned frame boundary"));
            }
        }
        // blocking read outside the lock (nothing else races: one
        // thread drives a guest link)
        let msg = self.inner.try_recv()?;
        let mut st = self.st.lock().expect("fault state poisoned");
        st.frames_since_arm += 1;
        st.frames_total += 1;
        if matches!(msg.kind(), ToGuestKind::RouteAnswers | ToGuestKind::RouteAnswersDelta) {
            st.answers_recv += 1;
        }
        Ok(msg)
    }

    fn reconnect(&self) -> std::io::Result<()> {
        self.inner.reconnect()?;
        let mut st = self.st.lock().expect("fault state poisoned");
        if st.dead {
            st.plans.pop_front();
        }
        st.dead = false;
        st.frames_since_arm = 0;
        Ok(())
    }

    fn set_secure(&self, enc_key: [u8; 32], dec_key: [u8; 32]) {
        // pure delegation: fault plans count frames and pick kill
        // boundaries the same way whether or not the channel is sealed
        self.inner.set_secure(enc_key, dec_key);
    }
}

/// Byte-level fault-injecting feeder for a non-blocking receiver: owns
/// the *sending* end of a socket whose other end is a
/// [`NbConn`](super::tcp::NbConn) under test, and delivers frames in
/// deliberately hostile shapes — split at arbitrary byte positions
/// ([`FaultyConn::dribble`]), torn and FIN'd mid-frame
/// ([`FaultyConn::feed`] under a killing plan) — so incremental
/// reassembly is exercised at every boundary the plan names.
pub struct FaultyConn {
    stream: TcpStream,
    plan: FaultPlan,
    frames_fed: u64,
    killed: bool,
}

impl FaultyConn {
    /// Wrap the feeder end of a socket with a plan.
    pub fn new(stream: TcpStream, plan: FaultPlan) -> FaultyConn {
        stream.set_nodelay(true).ok();
        FaultyConn { stream, plan, frames_fed: 0, killed: false }
    }

    /// Feed one frame (header built here from `payload`) honoring the
    /// plan: past the planned boundary the frame is torn at
    /// `partial_write_bytes` (possibly 0) and the connection FIN'd.
    /// Returns `Ok(true)` if the frame fully crossed, `Ok(false)` if
    /// the plan killed the connection instead.
    pub fn feed(&mut self, payload: &[u8]) -> std::io::Result<bool> {
        if self.killed {
            return Ok(false);
        }
        let mut frame = (payload.len() as u64).to_le_bytes().to_vec();
        frame.extend_from_slice(payload);
        if self.plan.kill_after_frames != 0 && self.frames_fed >= self.plan.kill_after_frames {
            if !self.plan.delay.is_zero() {
                std::thread::sleep(self.plan.delay);
            }
            let cut = self.plan.partial_write_bytes.min(frame.len());
            self.stream.write_all(&frame[..cut])?;
            self.stream.flush()?;
            self.kill();
            return Ok(false);
        }
        self.stream.write_all(&frame)?;
        self.stream.flush()?;
        self.frames_fed += 1;
        Ok(true)
    }

    /// Write raw bytes as-is (no framing, no plan): the split-point
    /// primitive of the partial-I/O corpus — callers deliver a frame
    /// as `dribble(&frame[..k])` + `dribble(&frame[k..])` for every
    /// `k`, asserting the receiver reassembles it identically.
    pub fn dribble(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.stream.write_all(bytes)?;
        self.stream.flush()
    }

    /// FIN both directions now (graceful: everything already written
    /// is still delivered).
    pub fn kill(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        self.killed = true;
    }

    /// Frames fully fed so far.
    pub fn frames_fed(&self) -> u64 {
        self.frames_fed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_from_equal_seeds_are_identical() {
        for seed in [0u64, 1, 7, 0xDEAD_BEEF] {
            let a = FaultPlan::from_seed(seed, 40);
            let b = FaultPlan::from_seed(seed, 40);
            assert_eq!(a.kill_after_frames, b.kill_after_frames);
            assert_eq!(a.partial_write_bytes, b.partial_write_bytes);
            assert_eq!(a.delay, b.delay);
            assert!(a.kill_after_frames >= 1 && a.kill_after_frames <= 40);
        }
    }

    #[test]
    fn benign_plan_never_fires() {
        let p = FaultPlan::benign();
        assert_eq!(p.kill_after_frames, 0);
        let st = FaultState {
            plans: vec![p].into(),
            frames_since_arm: u64::MAX,
            frames_total: 0,
            dead: false,
            routes_sent: 0,
            answers_recv: 0,
            kill_log: Vec::new(),
        };
        assert!(st.armed_kill().is_none());
    }

    #[test]
    fn faulty_conn_tears_and_fins_at_the_planned_boundary() {
        use std::io::Read;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let plan = FaultPlan {
            seed: 0,
            kill_after_frames: 1,
            partial_write_bytes: 10,
            delay: Duration::ZERO,
        };
        let mut feeder = FaultyConn::new(client, plan);
        assert!(feeder.feed(b"whole frame").unwrap());
        assert!(!feeder.feed(b"doomed frame").unwrap(), "second frame dies");
        assert!(!feeder.feed(b"never sent").unwrap(), "dead feeders stay dead");
        assert_eq!(feeder.frames_fed(), 1);

        // receiver sees: frame 1 complete, then exactly 10 bytes of
        // frame 2, then FIN
        let mut got = Vec::new();
        let mut server = server;
        server.read_to_end(&mut got).unwrap();
        let want_frame1_len = 8 + b"whole frame".len();
        assert_eq!(got.len(), want_frame1_len + 10);
        assert_eq!(&got[..8], &(b"whole frame".len() as u64).to_le_bytes());
        assert_eq!(&got[8..want_frame1_len], b"whole frame");
    }
}
