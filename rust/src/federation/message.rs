//! Protocol messages between the guest and the hosts.
//!
//! Every message has a [`ToHostKind`]/[`ToGuestKind`] — the kind's index
//! doubles as the wire tag byte in [`super::codec`], and the transport's
//! [`super::transport::NetCounters`] accumulate traffic per kind. Sizes
//! reported by [`to_host_size`]/[`to_guest_size`] are the *exact* number
//! of serialized bytes (frame header included), not struct sizes: the
//! quantities the paper's communication cost model (eq. 10/16) counts.

use crate::crypto::cipher::Ct;
use crate::crypto::compress::CtPackage;
use std::sync::Arc;

/// Version of the *serving* session protocol spoken after a
/// [`ToHost::SessionHello`]. Bumps whenever the meaning of a serving
/// frame changes incompatibly (query encoding, answer packing, session
/// semantics). The wire codec accepts hellos for this version and for
/// [`SERVE_PROTOCOL_V3`]/[`SERVE_PROTOCOL_V2`] (the host negotiates
/// such sessions *down* to the older semantics) and rejects everything
/// else — a serving host must never half-understand a session.
///
/// v2: chunked pipelined streaming — `PredictRoute`/`RouteAnswers`
/// carry a chunk id so several batches may be in flight per session,
/// and handshaked sessions may receive [`ToGuest::RouteAnswersDelta`]
/// answers (cache-aware wire suppression) when the host's
/// [`ToGuest::SessionAccept`] announced a nonzero `delta_window`.
///
/// v3: negotiated delta-basis eviction — [`ToGuest::SessionAccept`]
/// additionally announces the negotiated protocol and the
/// [`BasisEvict`] policy both ends must apply to their mirrored delta
/// bases (`freeze` reproduces v2 bit-for-bit; `lru` keeps suppression
/// effective for working sets larger than `delta_window`). A v2 peer
/// never sees the extension: hellos carrying `protocol = 2` are
/// answered with the 12-byte v2 accept and served with frozen bases.
///
/// v4: resumable sessions — a v4 session whose connection dies without
/// a [`ToHost::SessionClose`] is *parked* by the host for a configured
/// resume window instead of being reaped; the guest re-dials and sends
/// [`ToHost::SessionResume`] naming the session and how many answer
/// frames it has received on that link, and the host replays the
/// verbatim un-acknowledged answer frames after its
/// [`ToGuest::ResumeAccept`] so the stream continues bit-identically.
/// v3/v2 hellos are negotiated down exactly as before and never see
/// the resume pair on the wire.
///
/// v5: admission control — a host past its concurrency limit may answer
/// a [`ToHost::SessionHello`] with [`ToGuest::Busy`] (load shed: "come
/// back in `retry_after_ms`") instead of accepting or silently closing,
/// and the [`ToGuest::SessionAccept`] `max_inflight` it eventually
/// sends is a *live* value retuned by the host's AIMD limiter, not the
/// static configuration knob. v4-and-older peers never see a `Busy`
/// frame — a shed pre-v5 hello is answered by a close, exactly the
/// failure those peers already handle.
///
/// v6: secure sessions — a guest may open with
/// [`ToHost::SessionHelloSecure`] (a v5 hello plus an ephemeral X25519
/// public key); the host answers [`ToGuest::SessionAcceptSecure`]
/// carrying its own public key, both ends derive per-direction
/// ChaCha20-Poly1305 keys and a handle-rotation seed
/// ([`crate::crypto::secure`]), and **every frame after the accept, in
/// both directions, is sealed** with per-direction nonce counters.
/// Resumes use [`ToHost::SessionResumeSecure`]/
/// [`ToGuest::ResumeAcceptSecure`], deriving *fresh* AEAD keys for the
/// new connection (replayed answer frames are re-sealed under fresh
/// nonces — ciphertext is never cached) while the session's original
/// handle rotor persists. The handshake frames themselves and the
/// pre-handshake control plane ([`ToGuest::Busy`], silent closes) stay
/// plaintext — keys do not exist yet. Plain v5-and-older hellos are
/// served exactly as before, so pre-v6 peers negotiate down
/// byte-compatibly.
pub const SERVE_PROTOCOL_VERSION: u32 = 6;

/// The v5 serve protocol, still accepted on the wire: a
/// [`ToHost::SessionHello`] carrying it is served with v5 semantics
/// (admission `Busy` frames, live `max_inflight`, no encryption — only
/// v6 peers send or expect the secure handshake frames).
pub const SERVE_PROTOCOL_V5: u32 = 5;

/// The v4 serve protocol, still accepted on the wire: a
/// [`ToHost::SessionHello`] carrying it is served with v4 semantics
/// (resumable sessions, no admission `Busy` frames — a shed v4 hello is
/// closed, which its reconnect machinery already rides out).
pub const SERVE_PROTOCOL_V4: u32 = 4;

/// The v3 serve protocol, still accepted on the wire: a
/// [`ToHost::SessionHello`] carrying it is served with v3 semantics
/// (negotiated basis eviction, 17-byte extended
/// [`ToGuest::SessionAccept`], no session resumption).
pub const SERVE_PROTOCOL_V3: u32 = 3;

/// The v2 serve protocol, still accepted on the wire: a
/// [`ToHost::SessionHello`] carrying it is served with v2 semantics
/// (freeze-on-full delta basis, 12-byte [`ToGuest::SessionAccept`]).
pub const SERVE_PROTOCOL_V2: u32 = 2;

/// Eviction policy of the per-session **delta basis** (the mirrored
/// "already answered" set behind [`ToGuest::RouteAnswersDelta`]),
/// negotiated in the v3 [`ToGuest::SessionAccept`]. Both ends must run
/// the same policy over the same frame-order key sequence, or their
/// bases diverge and elided answers become undecodable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BasisEvict {
    /// v2 behavior: the basis stops admitting new keys once full. Both
    /// ends stay in lockstep trivially, but suppression dies for
    /// sessions whose working set exceeds `delta_window`.
    #[default]
    Freeze = 0,
    /// Deterministic least-recently-used eviction: a full basis evicts
    /// the key whose last appearance *in per-link frame order* is
    /// oldest. Recency is defined purely by the key sequence both ends
    /// already see (queries in frame order), so no membership map ever
    /// crosses the wire and suppression keeps working for working sets
    /// larger than `delta_window`.
    Lru = 1,
}

/// Why a [`ToGuest::Busy`] frame was sent instead of a
/// [`ToGuest::SessionAccept`]/[`ToGuest::ResumeAccept`]. The
/// discriminant is the wire tag byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum BusyReason {
    /// The host is past its admission limit and its admission queue is
    /// full (or disabled): the hello was shed outright.
    Shed = 0,
    /// The hello was queued behind the admission limit but no slot
    /// freed before the queue deadline ran out.
    QueueExpired = 1,
    /// The host is winding down (stop requested or session budget met)
    /// and is not admitting new sessions.
    Draining = 2,
}

impl BusyReason {
    /// Wire tag mapping.
    pub fn from_tag(tag: u8) -> Option<BusyReason> {
        match tag {
            0 => Some(BusyReason::Shed),
            1 => Some(BusyReason::QueueExpired),
            2 => Some(BusyReason::Draining),
            _ => None,
        }
    }

    /// Human-readable reason for logs and reports.
    pub fn name(self) -> &'static str {
        match self {
            BusyReason::Shed => "shed",
            BusyReason::QueueExpired => "queue-expired",
            BusyReason::Draining => "draining",
        }
    }
}

impl BasisEvict {
    /// Wire tag / CLI token mapping.
    pub fn from_tag(tag: u8) -> Option<BasisEvict> {
        match tag {
            0 => Some(BasisEvict::Freeze),
            1 => Some(BasisEvict::Lru),
            _ => None,
        }
    }

    /// Parse the `--basis-evict` CLI token.
    pub fn parse(s: &str) -> Option<BasisEvict> {
        match s {
            "freeze" => Some(BasisEvict::Freeze),
            "lru" => Some(BasisEvict::Lru),
            _ => None,
        }
    }

    /// Human-readable policy name (also the CLI token).
    pub fn name(self) -> &'static str {
        match self {
            BasisEvict::Freeze => "freeze",
            BasisEvict::Lru => "lru",
        }
    }
}

/// Session id reserved for the legacy *sessionless* inference flow
/// (a bare `PredictRoute` without a preceding handshake). Real sessions
/// pick a nonzero id; the codec rejects a `SessionHello` claiming id 0.
pub const SESSIONLESS_ID: u32 = 0;

/// Which parties may propose splits in a layer (mechanism modes, §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CandidateMask {
    /// Everyone (SecureBoost+ default).
    All,
    /// Only the named host (mix-mode host trees; layered-mode host layers).
    HostOnly(u8),
    /// All hosts, no guest (layered host layers with multiple hosts).
    HostsOnly,
    /// Guest only — hosts skip the layer entirely.
    GuestOnly,
}

/// One histogram task for a host in a layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HistTask {
    /// Build this node's histogram directly from its member instances.
    Direct { node: u32 },
    /// Derive this node by ciphertext subtraction: `parent − sibling`
    /// (both already in the host's cache; sibling built this layer).
    Subtract { node: u32, parent: u32, sibling: u32 },
}

impl HistTask {
    /// The node this task builds.
    pub fn node(&self) -> u32 {
        match self {
            HistTask::Direct { node } => *node,
            HistTask::Subtract { node, .. } => *node,
        }
    }
}

/// Message-kind tags for guest→host traffic. The discriminant is the wire
/// tag byte and the per-kind counter index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ToHostKind {
    /// One-time cipher/codec setup.
    Setup = 0,
    /// Encrypted packed g/h for one boosting tree.
    StartTree = 1,
    /// Histogram tasks for one tree layer.
    BuildLayer = 2,
    /// Apply a winning host split to a node's members.
    ApplySplit = 3,
    /// Synchronize a node's left/right assignment.
    SyncAssign = 4,
    /// Free per-tree state.
    FinishTree = 5,
    /// Reveal the split table to the driver (evaluation only).
    DumpSplitTable = 6,
    /// End the session.
    Shutdown = 7,
    /// Batched inference routing queries (federated prediction phase).
    PredictRoute = 8,
    /// Open a serving session (long-lived inference service).
    SessionHello = 9,
    /// Close a serving session without tearing down the server.
    SessionClose = 10,
    /// Liveness probe for an idle serving session.
    KeepAlive = 11,
    /// Re-attach to a parked v4 serving session after a dropped
    /// connection.
    SessionResume = 12,
    /// Open a v6 serving session with an encrypted channel (hello plus
    /// the guest's ephemeral X25519 public key).
    SessionHelloSecure = 13,
    /// Re-attach to a parked secure session, rekeying the channel for
    /// the new connection.
    SessionResumeSecure = 14,
}

/// Number of guest→host message kinds.
pub const TO_HOST_KINDS: usize = 15;

impl ToHostKind {
    /// Every guest→host kind, in tag order.
    pub const ALL: [ToHostKind; TO_HOST_KINDS] = [
        ToHostKind::Setup,
        ToHostKind::StartTree,
        ToHostKind::BuildLayer,
        ToHostKind::ApplySplit,
        ToHostKind::SyncAssign,
        ToHostKind::FinishTree,
        ToHostKind::DumpSplitTable,
        ToHostKind::Shutdown,
        ToHostKind::PredictRoute,
        ToHostKind::SessionHello,
        ToHostKind::SessionClose,
        ToHostKind::KeepAlive,
        ToHostKind::SessionResume,
        ToHostKind::SessionHelloSecure,
        ToHostKind::SessionResumeSecure,
    ];

    /// Wire tag byte / per-kind counter index.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Human-readable name for traffic reports.
    pub fn name(self) -> &'static str {
        match self {
            ToHostKind::Setup => "Setup",
            ToHostKind::StartTree => "StartTree",
            ToHostKind::BuildLayer => "BuildLayer",
            ToHostKind::ApplySplit => "ApplySplit",
            ToHostKind::SyncAssign => "SyncAssign",
            ToHostKind::FinishTree => "FinishTree",
            ToHostKind::DumpSplitTable => "DumpSplitTable",
            ToHostKind::Shutdown => "Shutdown",
            ToHostKind::PredictRoute => "PredictRoute",
            ToHostKind::SessionHello => "SessionHello",
            ToHostKind::SessionClose => "SessionClose",
            ToHostKind::KeepAlive => "KeepAlive",
            ToHostKind::SessionResume => "SessionResume",
            ToHostKind::SessionHelloSecure => "SessionHelloSecure",
            ToHostKind::SessionResumeSecure => "SessionResumeSecure",
        }
    }
}

/// Message-kind tags for host→guest traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ToGuestKind {
    /// Split statistics for a layer's nodes.
    LayerStats = 0,
    /// Instances routed left under a host split.
    LeftInstances = 1,
    /// The host's split table (evaluation only).
    SplitTable = 2,
    /// Barrier acknowledgement.
    Ack = 3,
    /// Bit-packed answers to a `PredictRoute` batch.
    RouteAnswers = 4,
    /// Acceptance of a [`ToHostKind::SessionHello`] handshake.
    SessionAccept = 5,
    /// Delta-suppressed answers: only the bits for queries the host has
    /// *not* already answered this session.
    RouteAnswersDelta = 6,
    /// Acceptance of a [`ToHostKind::SessionResume`] re-attach.
    ResumeAccept = 7,
    /// Load shed: the host refused a [`ToHostKind::SessionHello`] /
    /// [`ToHostKind::SessionResume`] because it is past its admission
    /// limit; retry after the advertised delay (v5+).
    Busy = 8,
    /// Acceptance of a [`ToHostKind::SessionHelloSecure`] handshake
    /// (carries the host's ephemeral X25519 public key).
    SessionAcceptSecure = 9,
    /// Acceptance of a [`ToHostKind::SessionResumeSecure`] re-attach
    /// (rekeys the channel for the new connection).
    ResumeAcceptSecure = 10,
}

/// Number of host→guest message kinds.
pub const TO_GUEST_KINDS: usize = 11;

impl ToGuestKind {
    /// Every host→guest kind, in tag order.
    pub const ALL: [ToGuestKind; TO_GUEST_KINDS] = [
        ToGuestKind::LayerStats,
        ToGuestKind::LeftInstances,
        ToGuestKind::SplitTable,
        ToGuestKind::Ack,
        ToGuestKind::RouteAnswers,
        ToGuestKind::SessionAccept,
        ToGuestKind::RouteAnswersDelta,
        ToGuestKind::ResumeAccept,
        ToGuestKind::Busy,
        ToGuestKind::SessionAcceptSecure,
        ToGuestKind::ResumeAcceptSecure,
    ];

    /// Wire tag byte / per-kind counter index.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Human-readable name for traffic reports.
    pub fn name(self) -> &'static str {
        match self {
            ToGuestKind::LayerStats => "LayerStats",
            ToGuestKind::LeftInstances => "LeftInstances",
            ToGuestKind::SplitTable => "SplitTable",
            ToGuestKind::Ack => "Ack",
            ToGuestKind::RouteAnswers => "RouteAnswers",
            ToGuestKind::SessionAccept => "SessionAccept",
            ToGuestKind::RouteAnswersDelta => "RouteAnswersDelta",
            ToGuestKind::ResumeAccept => "ResumeAccept",
            ToGuestKind::Busy => "Busy",
            ToGuestKind::SessionAcceptSecure => "SessionAcceptSecure",
            ToGuestKind::ResumeAcceptSecure => "ResumeAcceptSecure",
        }
    }
}

/// Guest → host messages.
pub enum ToHost {
    /// One-time setup: cipher public material and protocol parameters.
    Setup {
        suite_public: crate::crypto::cipher::CipherSuite,
        codec: super::codec::StatCodec,
        compress: Option<crate::crypto::compress::CompressPlan>,
        n_bins: usize,
        hist_subtraction: bool,
        sparse_optimization: bool,
        seed: u64,
    },
    /// Start a boosting tree: encrypted packed gh for the (sampled)
    /// training instances, instance-major `n_k` ciphertexts each.
    StartTree {
        tree_id: u32,
        instances: Arc<Vec<u32>>,
        packed: Arc<Vec<Ct>>,
        /// Σ over all sampled instances (for sparse zero-bin recovery).
        node_total: Vec<Ct>,
    },
    /// Build histograms + split stats for the given nodes.
    BuildLayer { tree_id: u32, tasks: Vec<HistTask> },
    /// The split at `node` (host-owned, via `handle`) won: partition the
    /// given instances and reply with those going left.
    ApplySplit { tree_id: u32, node: u32, handle: u32, instances: Arc<Vec<u32>> },
    /// Assignment sync: `left` of `node`'s members go to `left_child`,
    /// the rest to `right_child` (paper: "synchronized to all parties").
    SyncAssign { tree_id: u32, node: u32, left_child: u32, right_child: u32, left: Arc<Vec<u32>> },
    /// Free per-tree state.
    FinishTree { tree_id: u32 },
    /// Evaluation-only: reveal the split table to the driver (out of
    /// protocol; used by the experiment harness for inference).
    DumpSplitTable,
    /// End the session.
    Shutdown,
    /// Federated inference: for each `(record, handle)` query, does the
    /// named record go *left* under the host-owned split `handle`? One
    /// message carries a whole batch level's queries, so a batch of
    /// samples advances one host-routing step per round trip.
    ///
    /// Privacy: the host learns which of its splits are consulted for
    /// which record ids (the same access pattern training's `ApplySplit`
    /// already reveals), but never the tree position, other parties'
    /// routing decisions, leaf values, or the final prediction.
    PredictRoute {
        /// The serving session this batch belongs to
        /// ([`SESSIONLESS_ID`] for the legacy single-shot flow).
        session: u32,
        /// Caller-chosen chunk id, echoed on the answer so a pipelined
        /// guest with several chunks in flight can rejoin answers to
        /// walks. Single-batch flows send 0; hosts only echo it.
        chunk: u32,
        /// `(record id, split handle)` per query, in query order. An
        /// empty list is a valid (if pointless) batch — a streaming tail
        /// may legitimately have nothing to ask one host.
        queries: Vec<(u32, u32)>,
    },
    /// Open a long-lived serving session: the guest announces a nonzero
    /// session id of its choosing and the serve-protocol version it
    /// speaks. The host answers [`ToGuest::SessionAccept`] (echoing the
    /// id) or closes the connection. Carries no model or feature data —
    /// a hello reveals nothing beyond "a client arrived".
    SessionHello {
        /// Client-chosen nonzero session id, echoed on every frame of
        /// the session so a multiplexing host can attribute traffic.
        session_id: u32,
        /// Must equal [`SERVE_PROTOCOL_VERSION`], [`SERVE_PROTOCOL_V4`]
        /// (served with v4 semantics: resumption but no admission
        /// `Busy` frames), [`SERVE_PROTOCOL_V3`] (no resumption) or
        /// [`SERVE_PROTOCOL_V2`] (v2 semantics); the codec rejects
        /// anything else at decode time.
        protocol: u32,
    },
    /// End one serving session cleanly. The server keeps running and
    /// keeps accepting new sessions. ([`ToHost::Shutdown`] sent *inside
    /// a handshaked session* instead asks the whole serving process to
    /// wind down; on a hello-less legacy connection `Shutdown` only
    /// ends that connection.)
    SessionClose {
        /// The session being closed (must match the hello).
        session_id: u32,
    },
    /// Keep-alive probe: an idle session proves liveness without
    /// shipping queries. Answered with [`ToGuest::Ack`].
    KeepAlive,
    /// Re-attach to a **parked** v4 serving session: after the guest
    /// notices a dead connection it re-dials the host and sends this as
    /// the *first* frame of the fresh connection (instead of a new
    /// hello). The host either answers [`ToGuest::ResumeAccept`] and
    /// replays the verbatim answer frames the guest never received, or
    /// closes the connection (unknown / expired / non-v4 session — the
    /// guest must treat a close here as unrecoverable for that
    /// session).
    SessionResume {
        /// The parked session being re-attached (must match the id the
        /// original hello announced; never [`SESSIONLESS_ID`]).
        session: u32,
        /// How many **answer frames** ([`ToGuest::RouteAnswers`] /
        /// [`ToGuest::RouteAnswersDelta`]) the guest has fully received
        /// on this link so far — the guest's acknowledgement cursor.
        /// Chunk ids repeat across tree levels (one `PredictRoute` per
        /// chunk per level), so the cursor counts frames, not chunk
        /// ids; the host replays every buffered answer frame past this
        /// count, in original send order.
        last_acked_chunk: u32,
    },
    /// Open a v6 serving session with an **encrypted channel**: exactly
    /// a [`ToHost::SessionHello`] plus the guest's ephemeral X25519
    /// public key. The host answers
    /// [`ToGuest::SessionAcceptSecure`] (still plaintext — it carries
    /// the host's public key), after which every frame of the session,
    /// in both directions, is sealed with ChaCha20-Poly1305 under
    /// handshake-derived per-direction keys. Only carried by `protocol
    /// ≥ 6` hellos; the codec rejects a keyed hello claiming an older
    /// version (those peers cannot speak the sealed framing).
    SessionHelloSecure {
        /// Client-chosen nonzero session id (as in the plain hello).
        session_id: u32,
        /// Serve-protocol version; must be ≥ 6 — only v6-capable peers
        /// send a keyed hello (the negotiated version is still
        /// `min(hello, host)`).
        protocol: u32,
        /// The guest's ephemeral X25519 public key for this connection.
        pubkey: [u8; 32],
    },
    /// Re-attach to a parked **secure** session: a
    /// [`ToHost::SessionResume`] plus a *fresh* ephemeral public key.
    /// Sent plaintext as the first frame of the new connection (the old
    /// connection's keys died with it); the host's
    /// [`ToGuest::ResumeAcceptSecure`] completes a rekey, and the
    /// replayed answer frames are re-sealed under the new keys with
    /// fresh nonces — ciphertext never outlives its connection. The
    /// session's handle rotor (established by the original hello's
    /// handshake) is retained. A secure session can only be resumed
    /// securely and vice versa; the host closes on a mismatch.
    SessionResumeSecure {
        /// The parked session being re-attached.
        session: u32,
        /// The guest's answer-frame acknowledgement cursor (same
        /// semantics as the plain [`ToHost::SessionResume`] cursor).
        last_acked_chunk: u32,
        /// The guest's fresh ephemeral X25519 public key.
        pubkey: [u8; 32],
    },
}

impl ToHost {
    /// Wire tag / counter kind of this message.
    pub fn kind(&self) -> ToHostKind {
        match self {
            ToHost::Setup { .. } => ToHostKind::Setup,
            ToHost::StartTree { .. } => ToHostKind::StartTree,
            ToHost::BuildLayer { .. } => ToHostKind::BuildLayer,
            ToHost::ApplySplit { .. } => ToHostKind::ApplySplit,
            ToHost::SyncAssign { .. } => ToHostKind::SyncAssign,
            ToHost::FinishTree { .. } => ToHostKind::FinishTree,
            ToHost::DumpSplitTable => ToHostKind::DumpSplitTable,
            ToHost::Shutdown => ToHostKind::Shutdown,
            ToHost::PredictRoute { .. } => ToHostKind::PredictRoute,
            ToHost::SessionHello { .. } => ToHostKind::SessionHello,
            ToHost::SessionClose { .. } => ToHostKind::SessionClose,
            ToHost::KeepAlive => ToHostKind::KeepAlive,
            ToHost::SessionResume { .. } => ToHostKind::SessionResume,
            ToHost::SessionHelloSecure { .. } => ToHostKind::SessionHelloSecure,
            ToHost::SessionResumeSecure { .. } => ToHostKind::SessionResumeSecure,
        }
    }
}

/// A host's split statistics for one node, possibly compressed.
#[derive(Debug, PartialEq)]
pub enum NodeStats {
    /// Cipher-compressed packages (Alg. 4), η_s stats per ciphertext.
    Compressed(Vec<CtPackage>),
    /// Uncompressed: (id, sample_count, n_k ciphertexts) per candidate.
    Raw(Vec<(u32, u32, Vec<Ct>)>),
}

/// Host → guest messages.
#[derive(Debug, PartialEq)]
pub enum ToGuest {
    /// Split statistics for the nodes of a layer, in task order.
    LayerStats { tree_id: u32, nodes: Vec<(u32, NodeStats)> },
    /// Instances going left under a host-owned split.
    LeftInstances { tree_id: u32, node: u32, left: Vec<u32> },
    /// The host's split table: handle → (feature, bin, threshold).
    SplitTable { entries: Vec<(u32, u8, f64)> },
    /// Acknowledgement for barrier-style messages.
    Ack,
    /// Answers to a `PredictRoute` batch, bit-packed in query order:
    /// bit `i` (LSB-first within each byte) set ⇔ query `i` goes left.
    /// The host reveals one routing bit per consulted split and nothing
    /// else about its feature values.
    RouteAnswers {
        /// The serving session the answered batch belongs to (echoes the
        /// query's session id; [`SESSIONLESS_ID`] for legacy flows).
        session: u32,
        /// Echo of the answered batch's chunk id (pipelined rejoin).
        chunk: u32,
        /// Number of valid answer bits (equals the query count).
        n: u32,
        /// `⌈n/8⌉` bytes of LSB-first routing bits.
        bits: Vec<u8>,
    },
    /// The host accepted a [`ToHost::SessionHello`]: the session is open
    /// and `PredictRoute` batches tagged with its id will be answered.
    SessionAccept {
        /// Echo of the hello's session id.
        session_id: u32,
        /// How many unanswered `PredictRoute` batches the session may
        /// have in flight before the host stops reading its frames —
        /// the bound of the host's per-session queue (backpressure).
        /// Compliant pipelined guests clamp their chunk window to it.
        max_inflight: u32,
        /// Capacity (entries) of the per-session delta basis this host
        /// maintains for cache-aware wire suppression, 0 = suppression
        /// off. Nonzero means the session may answer `PredictRoute`
        /// batches with [`ToGuest::RouteAnswersDelta`] frames; the guest
        /// must mirror the basis (same capacity, same negotiated
        /// insertion/eviction rule) to resolve elided answers.
        delta_window: u32,
        /// The serve-protocol version the session will actually speak:
        /// the minimum of the hello's version and this build's
        /// [`SERVE_PROTOCOL_VERSION`]. When it is ≥ 3 the accept frame
        /// carries the v3 extension (this field plus `basis_evict`) on
        /// the wire; a v2 accept is the bare 12-byte frame a legacy
        /// peer expects and decodes as `(2, Freeze)`.
        protocol: u32,
        /// The delta-basis eviction policy both ends must run
        /// ([`BasisEvict::Freeze`] whenever the negotiated protocol is
        /// v2, so legacy sessions stay bit-for-bit v2).
        basis_evict: BasisEvict,
    },
    /// Cache-aware wire suppression: answers for a `PredictRoute` batch
    /// in which every `(record, handle)` key the host has **already
    /// answered earlier in this session** is elided — "unchanged since
    /// your last ask". Routing is a pure function of the immutable model
    /// share and feature slice, so a repeated key's answer is necessarily
    /// the bit the guest already holds in its memo/basis; only the
    /// *fresh* queries' bits travel. Both sides maintain the same
    /// bounded "seen" set (the *delta basis*, capacity announced as
    /// `delta_window` in [`ToGuest::SessionAccept`], full-set behavior
    /// governed by the negotiated [`BasisEvict`] policy — frozen on v2
    /// sessions, deterministically LRU-evicted when v3 negotiated
    /// `lru`), updated in frame order, so the guest can reconstruct the
    /// full answer bitmap bit-identically without an explicit
    /// membership map on the wire.
    RouteAnswersDelta {
        /// The serving session the answered batch belongs to.
        session: u32,
        /// Echo of the answered batch's chunk id.
        chunk: u32,
        /// Total query count of the answered batch.
        n: u32,
        /// How many of the `n` queries were elided (already answered
        /// this session). Always ≥ 1 — an all-fresh batch is answered
        /// with a plain [`ToGuest::RouteAnswers`] instead.
        n_known: u32,
        /// `⌈(n − n_known)/8⌉` bytes of LSB-first routing bits for the
        /// fresh queries, in query order.
        bits: Vec<u8>,
    },
    /// The host accepted a [`ToHost::SessionResume`]: the parked
    /// session is live again on this connection, its delta basis, memo
    /// and counters intact. Immediately after this frame the host
    /// replays — byte-for-byte — every buffered answer frame the
    /// guest's acknowledgement cursor says it never received, then
    /// resumes normal service. Replay is verbatim (not recomputed)
    /// because both delta-basis mirrors already advanced when the
    /// answers were first produced; recomputing would misclassify
    /// previously-fresh keys as known and desynchronize the mirrors.
    ResumeAccept {
        /// One past the host's total answer-frame count: the 1-based
        /// sequence number of the next **fresh** answer the host will
        /// produce. Everything between the guest's acknowledgement
        /// cursor and this (`next_chunk − 1 − last_acked_chunk` frames)
        /// is replayed verbatim right after this frame; requests the
        /// guest had in flight *beyond* the replayed answers never
        /// reached the host (lost or torn with the dead connection) and
        /// must be re-sent, in their original order, to keep the two
        /// delta-basis mirrors advancing identically.
        next_chunk: u32,
        /// The host's cumulative count of keys inserted into the
        /// session's delta basis *as of the acked cursor* (i.e. before
        /// any replayed frame's insertions), mod 2³². The guest asserts
        /// it equals its own mirror's insert count — a cheap integrity
        /// check that the mirrors are still in lockstep before any
        /// replayed bits are trusted.
        basis_epoch: u32,
    },
    /// Load shed (v5+): the host is past its admission limit and will
    /// not open (or resume) this session right now. Sent *instead of*
    /// [`ToGuest::SessionAccept`]/[`ToGuest::ResumeAccept`]; the
    /// connection is closed right after it. The session was never
    /// opened — nothing was consumed from the host's session budget and
    /// no state was created — so the guest retries the identical hello
    /// after backing off, with jitter, for at most its configured
    /// retry budget. Only v5 hellos ever see this frame: a shed
    /// pre-v5 hello is answered by a plain close.
    Busy {
        /// Host's advice on how long to back off before re-dialing, in
        /// milliseconds. A retrying guest treats it as a *floor* and
        /// adds seeded jitter so a shed cohort does not re-dial in
        /// lockstep.
        retry_after_ms: u32,
        /// Why the hello was refused (shed / queue-expired / draining).
        reason: BusyReason,
    },
    /// The host accepted a [`ToHost::SessionHelloSecure`]: a
    /// [`ToGuest::SessionAccept`] plus the host's ephemeral X25519
    /// public key. This frame itself travels plaintext (it completes
    /// the key agreement); **every frame after it**, in both
    /// directions, is sealed. The negotiated `protocol` is always ≥ 6
    /// here — a host that would negotiate lower answers a keyed hello
    /// with a close (and a v6 host serving a *plain* hello answers with
    /// the plain accept, so older peers never see this frame).
    SessionAcceptSecure {
        /// Echo of the hello's session id.
        session_id: u32,
        /// Live in-flight window (see [`ToGuest::SessionAccept`]).
        max_inflight: u32,
        /// Delta-basis capacity (see [`ToGuest::SessionAccept`]).
        delta_window: u32,
        /// The serve-protocol version the session will speak (≥ 6).
        protocol: u32,
        /// The negotiated delta-basis eviction policy.
        basis_evict: BasisEvict,
        /// The host's ephemeral X25519 public key for this connection.
        pubkey: [u8; 32],
    },
    /// The host accepted a [`ToHost::SessionResumeSecure`]: a
    /// [`ToGuest::ResumeAccept`] plus the host's fresh ephemeral public
    /// key. Travels plaintext (it completes the rekey); the replayed
    /// answer frames that follow are already sealed under the *new*
    /// connection's keys — the host retains plaintext answers, never
    /// ciphertext, so replay gets fresh nonces by construction.
    ResumeAcceptSecure {
        /// Replay cursor (see [`ToGuest::ResumeAccept`]).
        next_chunk: u32,
        /// Delta-basis lockstep check (see [`ToGuest::ResumeAccept`]).
        basis_epoch: u32,
        /// The host's fresh ephemeral X25519 public key.
        pubkey: [u8; 32],
    },
}

impl ToGuest {
    /// Wire tag / counter kind of this message.
    pub fn kind(&self) -> ToGuestKind {
        match self {
            ToGuest::LayerStats { .. } => ToGuestKind::LayerStats,
            ToGuest::LeftInstances { .. } => ToGuestKind::LeftInstances,
            ToGuest::SplitTable { .. } => ToGuestKind::SplitTable,
            ToGuest::Ack => ToGuestKind::Ack,
            ToGuest::RouteAnswers { .. } => ToGuestKind::RouteAnswers,
            ToGuest::SessionAccept { .. } => ToGuestKind::SessionAccept,
            ToGuest::RouteAnswersDelta { .. } => ToGuestKind::RouteAnswersDelta,
            ToGuest::ResumeAccept { .. } => ToGuestKind::ResumeAccept,
            ToGuest::Busy { .. } => ToGuestKind::Busy,
            ToGuest::SessionAcceptSecure { .. } => ToGuestKind::SessionAcceptSecure,
            ToGuest::ResumeAcceptSecure { .. } => ToGuestKind::ResumeAcceptSecure,
        }
    }
}

/// Exact serialized size of a guest→host message (frame header included).
pub fn to_host_size(msg: &ToHost, ct_len: usize) -> usize {
    super::codec::to_host_wire_len(msg, ct_len)
}

/// Exact serialized size of a host→guest message (frame header included).
pub fn to_guest_size(msg: &ToGuest, ct_len: usize) -> usize {
    super::codec::to_guest_wire_len(msg, ct_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_scale_with_payload() {
        let small = ToHost::ApplySplit {
            tree_id: 0,
            node: 1,
            handle: 2,
            instances: Arc::new(vec![1, 2, 3]),
        };
        let big = ToHost::ApplySplit {
            tree_id: 0,
            node: 1,
            handle: 2,
            instances: Arc::new((0..1000).collect()),
        };
        assert!(to_host_size(&big, 256) > to_host_size(&small, 256) + 3900);
    }

    #[test]
    fn compressed_stats_smaller_than_raw() {
        use crate::crypto::cipher::CipherSuite;
        let suite = CipherSuite::new_plain(512);
        let ct = suite.zero_ct();
        // 6 stats compressed into one package vs 6 raw stats
        let compressed = ToGuest::LayerStats {
            tree_id: 0,
            nodes: vec![(
                0,
                NodeStats::Compressed(vec![CtPackage {
                    ct: ct.clone(),
                    ids: vec![0, 1, 2, 3, 4, 5],
                    counts: vec![1; 6],
                }]),
            )],
        };
        let raw = ToGuest::LayerStats {
            tree_id: 0,
            nodes: vec![(
                0,
                NodeStats::Raw((0..6).map(|i| (i, 1u32, vec![ct.clone()])).collect()),
            )],
        };
        let cl = 128;
        assert!(to_guest_size(&compressed, cl) < to_guest_size(&raw, cl));
    }

    #[test]
    fn kind_indices_cover_all_tags() {
        for (i, k) in ToHostKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
        }
        for (i, k) in ToGuestKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
        }
        assert_eq!(ToHost::Shutdown.kind(), ToHostKind::Shutdown);
        assert_eq!(ToGuest::Ack.kind(), ToGuestKind::Ack);
    }
}
