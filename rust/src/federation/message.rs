//! Protocol messages between the guest and the hosts, with wire-size
//! accounting for the network model.
//!
//! Sizes are computed from the logical payload (ciphertexts dominate:
//! `ct_byte_len` each; ids/counts 4 bytes; f64 8 bytes) plus a small
//! framing overhead per message — the quantities the paper's
//! communication cost model (eq. 10/16) counts.

use crate::crypto::cipher::Ct;
use crate::crypto::compress::CtPackage;
use std::sync::Arc;

/// Framing overhead charged per message.
pub const MSG_OVERHEAD: usize = 64;

/// Which parties may propose splits in a layer (mechanism modes, §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CandidateMask {
    /// Everyone (SecureBoost+ default).
    All,
    /// Only the named host (mix-mode host trees; layered-mode host layers).
    HostOnly(u8),
    /// All hosts, no guest (layered host layers with multiple hosts).
    HostsOnly,
    /// Guest only — hosts skip the layer entirely.
    GuestOnly,
}

/// One histogram task for a host in a layer.
#[derive(Clone, Debug)]
pub enum HistTask {
    /// Build this node's histogram directly from its member instances.
    Direct { node: u32 },
    /// Derive this node by ciphertext subtraction: `parent − sibling`
    /// (both already in the host's cache; sibling built this layer).
    Subtract { node: u32, parent: u32, sibling: u32 },
}

impl HistTask {
    pub fn node(&self) -> u32 {
        match self {
            HistTask::Direct { node } => *node,
            HistTask::Subtract { node, .. } => *node,
        }
    }
}

/// Guest → host messages.
pub enum ToHost {
    /// One-time setup: cipher public material and protocol parameters.
    Setup {
        suite_public: crate::crypto::cipher::CipherSuite,
        codec: super::codec::StatCodec,
        compress: Option<crate::crypto::compress::CompressPlan>,
        n_bins: usize,
        hist_subtraction: bool,
        sparse_optimization: bool,
        seed: u64,
    },
    /// Start a boosting tree: encrypted packed gh for the (sampled)
    /// training instances, instance-major `n_k` ciphertexts each.
    StartTree {
        tree_id: u32,
        instances: Arc<Vec<u32>>,
        packed: Arc<Vec<Ct>>,
        /// Σ over all sampled instances (for sparse zero-bin recovery).
        node_total: Vec<Ct>,
    },
    /// Build histograms + split stats for the given nodes.
    BuildLayer { tree_id: u32, tasks: Vec<HistTask> },
    /// The split at `node` (host-owned, via `handle`) won: partition the
    /// given instances and reply with those going left.
    ApplySplit { tree_id: u32, node: u32, handle: u32, instances: Arc<Vec<u32>> },
    /// Assignment sync: `left` of `node`'s members go to `left_child`,
    /// the rest to `right_child` (paper: "synchronized to all parties").
    SyncAssign { tree_id: u32, node: u32, left_child: u32, right_child: u32, left: Arc<Vec<u32>> },
    /// Free per-tree state.
    FinishTree { tree_id: u32 },
    /// Evaluation-only: reveal the split table to the driver (out of
    /// protocol; used by the experiment harness for inference).
    DumpSplitTable,
    Shutdown,
}

/// A host's split statistics for one node, possibly compressed.
pub enum NodeStats {
    Compressed(Vec<CtPackage>),
    /// Uncompressed: (id, sample_count, n_k ciphertexts) per candidate.
    Raw(Vec<(u32, u32, Vec<Ct>)>),
}

/// Host → guest messages.
pub enum ToGuest {
    /// Split statistics for the nodes of a layer, in task order.
    LayerStats { tree_id: u32, nodes: Vec<(u32, NodeStats)> },
    /// Instances going left under a host-owned split.
    LeftInstances { tree_id: u32, node: u32, left: Vec<u32> },
    /// The host's split table: handle → (feature, bin, threshold).
    SplitTable { entries: Vec<(u32, u8, f64)> },
    /// Acknowledgement for barrier-style messages.
    Ack,
}

/// Wire size of a guest→host message given the ciphertext byte length.
pub fn to_host_size(msg: &ToHost, ct_len: usize) -> usize {
    MSG_OVERHEAD
        + match msg {
            ToHost::Setup { .. } => 512, // key material + parameters
            ToHost::StartTree { instances, packed, node_total, .. } => {
                instances.len() * 4 + packed.len() * ct_len + node_total.len() * ct_len
            }
            ToHost::BuildLayer { tasks, .. } => tasks.len() * 12,
            ToHost::ApplySplit { instances, .. } => 12 + instances.len() * 4,
            ToHost::SyncAssign { left, .. } => 16 + left.len() * 4,
            ToHost::FinishTree { .. } | ToHost::Shutdown | ToHost::DumpSplitTable => 0,
        }
}

/// Wire size of a host→guest message.
pub fn to_guest_size(msg: &ToGuest, ct_len: usize) -> usize {
    MSG_OVERHEAD
        + match msg {
            ToGuest::LayerStats { nodes, .. } => nodes
                .iter()
                .map(|(_, s)| match s {
                    NodeStats::Compressed(pkgs) => pkgs
                        .iter()
                        .map(|p| ct_len + p.ids.len() * 8)
                        .sum::<usize>(),
                    NodeStats::Raw(stats) => stats
                        .iter()
                        .map(|(_, _, cts)| 8 + cts.len() * ct_len)
                        .sum::<usize>(),
                })
                .sum::<usize>(),
            ToGuest::LeftInstances { left, .. } => 8 + left.len() * 4,
            ToGuest::SplitTable { entries } => entries.len() * 16,
            ToGuest::Ack => 0,
        }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_scale_with_payload() {
        let small = ToHost::ApplySplit {
            tree_id: 0,
            node: 1,
            handle: 2,
            instances: Arc::new(vec![1, 2, 3]),
        };
        let big = ToHost::ApplySplit {
            tree_id: 0,
            node: 1,
            handle: 2,
            instances: Arc::new((0..1000).collect()),
        };
        assert!(to_host_size(&big, 256) > to_host_size(&small, 256) + 3900);
    }

    #[test]
    fn compressed_stats_smaller_than_raw() {
        use crate::crypto::cipher::CipherSuite;
        let suite = CipherSuite::new_plain(512);
        let ct = suite.zero_ct();
        // 6 stats compressed into one package vs 6 raw stats
        let compressed = ToGuest::LayerStats {
            tree_id: 0,
            nodes: vec![(
                0,
                NodeStats::Compressed(vec![CtPackage {
                    ct: ct.clone(),
                    ids: vec![0, 1, 2, 3, 4, 5],
                    counts: vec![1; 6],
                }]),
            )],
        };
        let raw = ToGuest::LayerStats {
            tree_id: 0,
            nodes: vec![(
                0,
                NodeStats::Raw((0..6).map(|i| (i, 1u32, vec![ct.clone()])).collect()),
            )],
        };
        let cl = 128;
        assert!(to_guest_size(&compressed, cl) < to_guest_size(&raw, cl));
    }
}
