//! Length-prefixed framed TCP transport — the networked implementation of
//! [`GuestTransport`]/[`HostTransport`].
//!
//! Every [`ToHost`]/[`ToGuest`] message is serialized through
//! [`super::codec`] into one frame (`u64 LE length` + payload). The
//! protocol is already batched level-wise — one `BuildLayer` /
//! `LayerStats` message carries all nodes of a depth — so a layer costs a
//! single frame in each direction regardless of tree width.
//!
//! Connection bring-up needs no handshake: the first frame the guest sends
//! is `Setup`, which carries the cipher suite's public material; the host
//! side decodes it and locks the suite (and with it the fixed ciphertext
//! wire width) for the rest of the session. [`NetCounters`] on both ends
//! record the actual framed byte counts, which equal the in-memory
//! transport's accounting byte-for-byte (`codec::*_wire_len` are exact).
//!
//! Concurrency: one socket per guest↔host pair. The guest endpoint is
//! driven by one thread, so a single `Mutex` over its connection state
//! suffices; the host endpoint serves the 2-stage pipelined session
//! engine — one thread reading, another writing — so its two
//! directions live behind separate locks over cloned socket handles.
//! Training is strictly request/response; the pipelined serving path
//! keeps up to `max_inflight` request frames on the wire per session
//! (the host still answers them strictly in arrival order). The
//! long-lived serving path multiplexes many *sessions* over one
//! listener — each accepted connection becomes a **non-blocking**
//! [`NbConn`] owned by one reactor worker of
//! [`crate::federation::serve::serve_predict_loop`], which reads,
//! answers, and flushes it with explicit would-block results instead
//! of parked threads; per-session backpressure is the socket buffer
//! plus the announced in-flight bound, and per-session byte accounting
//! stays a per-connection [`NetCounters`].
//!
//! Hot-path allocation: each endpoint owns per-connection read/write
//! scratch buffers; frames are encoded with
//! [`codec::encode_to_host_into`]/[`codec::encode_to_guest_into`] and
//! read with [`codec::read_frame_into`], so steady-state serving does
//! no per-frame payload allocation.

use super::codec;
use super::message::{ToGuest, ToHost};
use super::transport::{GuestTransport, HostTransport, NetCounters, NetSnapshot};
use crate::crypto::cipher::CipherSuite;
use crate::crypto::secure::FrameCipher;
use crate::data::binning::BinnedMatrix;
use crate::data::sparse::SparseBinned;
use crate::federation::host::HostParty;
use crate::util::timer::PhaseTimer;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

/// One connection's I/O state: the socket plus the per-connection
/// scratch buffers the framed hot path reuses — every frame is encoded
/// into `wbuf` and decoded out of `rbuf` in place, so a serving
/// connection performs **zero** per-frame payload allocations after its
/// buffers warm up ([`codec::encode_to_host_into`] /
/// [`codec::read_frame_into`]).
struct ConnIo {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
}

impl ConnIo {
    fn new(stream: TcpStream) -> Self {
        stream.set_nodelay(true).ok();
        ConnIo { stream, rbuf: Vec::new(), wbuf: Vec::new() }
    }
}

/// Result of one [`NbConn::poll_frame`] attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvPoll {
    /// A complete frame is buffered: read it with
    /// [`NbConn::frame_payload`], then release it with
    /// [`NbConn::consume_frame`].
    Frame,
    /// No complete frame yet and the socket has nothing more to read
    /// right now (`EWOULDBLOCK`) — try again on the next sweep.
    Pending,
    /// The peer closed the connection cleanly at a frame boundary.
    Closed,
}

/// One **non-blocking** framed connection: the readiness-driven
/// counterpart of the blocking `ConnIo`, built for the serving reactor
/// ([`crate::federation::serve::serve_predict_loop`]) where one worker
/// thread multiplexes many sockets and must never park inside a read
/// or write on any single one of them. Reads accumulate into an
/// internal buffer until one whole `u64 LE length`-prefixed frame is
/// resident ([`RecvPoll::Frame`]); writes queue into an internal
/// buffer and drain as far as the kernel allows
/// ([`NbConn::flush_pending`]) — both directions report would-block
/// explicitly instead of blocking. Frame boundaries, length limits,
/// and error classification mirror [`codec::read_frame_into`] /
/// [`codec::write_frame`] exactly, so the bytes on the wire are
/// byte-identical to the blocking transport's.
pub struct NbConn {
    stream: TcpStream,
    /// Read accumulation buffer; the first `rfill` bytes are valid.
    rbuf: Vec<u8>,
    rfill: usize,
    /// Total size (header + payload) of the frame being assembled, set
    /// once the 8-byte header is in; `None` while still reading it.
    rneed: Option<usize>,
    /// Outbound bytes queued for the kernel; the first `wpos` of them
    /// are already written.
    wbuf: Vec<u8>,
    wpos: usize,
    /// v6 session channel, armed per direction once the handshake keys
    /// are derived ([`Self::arm_secure_rx`]/[`Self::arm_secure_tx`]).
    /// `rplain` marks the resident frame as already opened: header +
    /// plaintext length, set once per frame so repeated polls never
    /// double-decrypt. `wseal` is the reused seal scratch.
    dec: Option<FrameCipher>,
    enc: Option<FrameCipher>,
    rplain: Option<usize>,
    wseal: Vec<u8>,
}

impl NbConn {
    /// Take ownership of an accepted socket, switching it to
    /// non-blocking mode (plus `TCP_NODELAY`, like the blocking path).
    pub fn new(stream: TcpStream) -> std::io::Result<Self> {
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true).ok();
        Ok(NbConn {
            stream,
            rbuf: Vec::new(),
            rfill: 0,
            rneed: None,
            wbuf: Vec::new(),
            wpos: 0,
            dec: None,
            enc: None,
            rplain: None,
            wseal: Vec::new(),
        })
    }

    /// Arm v6 AEAD on the read direction: every frame *completed* after
    /// this call is opened with `key` before being surfaced. Safe to
    /// call while the (plaintext) handshake frame is still resident —
    /// decryption happens once per frame at completion, and reads never
    /// run past the current frame's end, so no sealed byte of the next
    /// frame can have been pre-buffered.
    pub fn arm_secure_rx(&mut self, key: [u8; 32]) {
        self.dec = Some(FrameCipher::new(key));
    }

    /// Arm v6 AEAD on the write direction: every frame *queued* after
    /// this call is sealed with `key`. Called only after the plaintext
    /// accept has been queued, so the accept itself stays in the clear.
    pub fn arm_secure_tx(&mut self, key: [u8; 32]) {
        self.enc = Some(FrameCipher::new(key));
    }

    /// Whether the read direction is armed (used by the reactor to
    /// refuse a second keyed hello on an already-secure link).
    pub fn secure_rx(&self) -> bool {
        self.dec.is_some()
    }

    /// Drive the read side as far as the socket allows without
    /// blocking. Returns [`RecvPoll::Frame`] as soon as one complete
    /// frame is resident; the frame stays buffered until
    /// [`Self::consume_frame`], so callers decode it in place. Reads
    /// never run past the current frame's end, so pipelined back-to-back
    /// frames are surfaced one at a time, in order.
    pub fn poll_frame(&mut self) -> Result<RecvPoll, codec::WireError> {
        loop {
            let target = self.rneed.unwrap_or(codec::FRAME_HEADER_LEN);
            if self.rfill >= target {
                if self.rneed.is_some() {
                    if self.dec.is_some() && self.rplain.is_none() {
                        self.open_resident(target)?;
                    }
                    return Ok(RecvPoll::Frame);
                }
                // header complete: learn the frame's total size
                let hdr: [u8; codec::FRAME_HEADER_LEN] =
                    self.rbuf[..codec::FRAME_HEADER_LEN].try_into().expect("8-byte header");
                let len = u64::from_le_bytes(hdr);
                if len > codec::MAX_FRAME_LEN {
                    return Err(codec::WireError::FrameTooLarge(len));
                }
                self.rneed = Some(codec::FRAME_HEADER_LEN + len as usize);
                continue;
            }
            // grow toward the target in bounded (1 MiB) steps, like
            // read_frame_into: a garbage length field cannot drive one
            // giant up-front allocation
            let step = target.min(self.rfill + (1 << 20));
            if self.rbuf.len() < step {
                self.rbuf.resize(step, 0);
            }
            match self.stream.read(&mut self.rbuf[self.rfill..step]) {
                Ok(0) => {
                    return if self.rfill == 0 && self.rneed.is_none() {
                        Ok(RecvPoll::Closed)
                    } else {
                        Err(codec::WireError::Truncated)
                    };
                }
                Ok(n) => self.rfill += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return Ok(RecvPoll::Pending);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(codec::WireError::Io(e)),
            }
        }
    }

    /// Open the resident sealed frame in place: verify the tag, then
    /// decrypt the ciphertext prefix and remember the plaintext bound.
    /// A bad tag (tampering, truncation, or a plaintext frame from a
    /// peer that skipped the handshake) is a [`codec::WireError`] — the
    /// reactor closes the connection loudly, exactly like any other
    /// malformed frame, and never answers it.
    fn open_resident(&mut self, total: usize) -> Result<(), codec::WireError> {
        let dec = self.dec.as_mut().expect("decrypt direction armed");
        let plain = dec
            .open_in_place(&mut self.rbuf[codec::FRAME_HEADER_LEN..total])
            .map_err(|()| codec::WireError::Malformed("AEAD tag verification failed"))?;
        self.rplain = Some(codec::FRAME_HEADER_LEN + plain);
        Ok(())
    }

    /// The completed frame's payload (valid after [`RecvPoll::Frame`]);
    /// the decrypted plaintext when the read direction is armed.
    pub fn frame_payload(&self) -> &[u8] {
        let total = self.rneed.expect("no completed frame resident");
        &self.rbuf[codec::FRAME_HEADER_LEN..self.rplain.unwrap_or(total)]
    }

    /// Release the current frame so the next [`Self::poll_frame`] can
    /// assemble its successor.
    pub fn consume_frame(&mut self) {
        let total = self.rneed.take().expect("no completed frame resident");
        self.rplain = None;
        // reads are bounded by the frame end, so nothing of the next
        // frame can be in the buffer — but shift defensively anyway
        self.rbuf.copy_within(total..self.rfill, 0);
        self.rfill -= total;
    }

    /// Queue one frame (length prefix + `payload`) for transmission.
    /// Bytes sit in the write buffer until [`Self::flush_pending`]
    /// drains them; the already-flushed prefix is compacted away so a
    /// long-lived session's buffer is bounded by its unflushed backlog,
    /// not its history.
    pub fn queue_frame(&mut self, payload: &[u8]) {
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos >= (1 << 16) {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        if let Some(enc) = &mut self.enc {
            // replayed v4 answers re-enter here as plaintext, so every
            // (re)transmission is sealed under a fresh nonce — the host
            // never caches or re-sends ciphertext
            enc.seal_into(payload, &mut self.wseal);
            self.wbuf.extend_from_slice(&(self.wseal.len() as u64).to_le_bytes());
            self.wbuf.extend_from_slice(&self.wseal);
        } else {
            self.wbuf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            self.wbuf.extend_from_slice(payload);
        }
    }

    /// Write queued bytes until the kernel would block or all are gone.
    /// Returns how many bytes the kernel accepted this call.
    pub fn flush_pending(&mut self) -> std::io::Result<usize> {
        let mut written = 0usize;
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ));
                }
                Ok(n) => {
                    self.wpos += n;
                    written += n;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        }
        Ok(written)
    }

    /// Bytes queued but not yet accepted by the kernel.
    pub fn pending_write(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// True when every queued byte has reached the kernel.
    pub fn write_idle(&self) -> bool {
        self.wpos == self.wbuf.len()
    }

    /// Close both directions (best effort — the peer may be gone).
    pub fn shutdown(&self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Guest-side endpoint of one guest↔host TCP connection.
///
/// Supports **re-dialing**: [`GuestTransport::reconnect`] replaces the
/// socket while keeping the traffic counters, so a v4 serving session
/// resumed after a dropped connection keeps one cumulative accounting
/// stream. The fallible [`GuestTransport::try_send`] /
/// [`GuestTransport::try_recv`] surface connection death as errors for
/// the resumption path; the infallible `send`/`recv` keep their
/// historical panic behavior for protocol drivers that cannot recover.
pub struct TcpGuestTransport {
    io: Mutex<ConnIo>,
    addr: String,
    suite: CipherSuite,
    ct_len: usize,
    /// v6 session channel (both directions plus the seal scratch),
    /// armed by [`GuestTransport::set_secure`] once the handshake keys
    /// are derived and cleared by [`GuestTransport::reconnect`] — a
    /// re-dialed connection always re-handshakes with fresh keys, so a
    /// nonce counter burned on a dead socket is never reused. Locked
    /// strictly after `io` (the only nesting order used).
    secure: Mutex<Option<GuestSecure>>,
    counters: Arc<NetCounters>,
}

/// The guest endpoint's armed v6 channel state.
struct GuestSecure {
    /// Seals guest→host frames.
    enc: FrameCipher,
    /// Opens host→guest frames.
    dec: FrameCipher,
    /// Reused seal output buffer (keeps secure sends allocation-free).
    scratch: Vec<u8>,
}

impl TcpGuestTransport {
    /// Connect to a host party at `addr` (e.g. `"127.0.0.1:7878"`). The
    /// guest's cipher suite fixes the ciphertext wire width; hosts learn
    /// it from the `Setup` frame.
    pub fn connect(addr: &str, suite: CipherSuite) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let ct_len = suite.ct_byte_len();
        Ok(TcpGuestTransport {
            io: Mutex::new(ConnIo::new(stream)),
            addr: addr.to_string(),
            suite,
            ct_len,
            secure: Mutex::new(None),
            counters: Arc::new(NetCounters::default()),
        })
    }

    /// This endpoint's traffic counters.
    pub fn counters(&self) -> Arc<NetCounters> {
        self.counters.clone()
    }

    /// Abort the connection (FIN in both directions, queued bytes still
    /// delivered). Fault-injection support
    /// ([`crate::federation::fault`]): a graceful shutdown — not an
    /// RST — so everything fully written before the kill still reaches
    /// the host, which keeps injected-kill outcomes deterministic.
    pub fn kill(&self) {
        let io = self.io.lock().expect("tcp stream poisoned");
        let _ = io.stream.shutdown(std::net::Shutdown::Both);
    }

    /// Fault-injection support: encode `msg`'s frame but write only its
    /// first `n_bytes` bytes — a deterministic **torn write**. The torn
    /// frame is not recorded in the counters: the host's defensive
    /// decode discards an incomplete frame, so neither side counts it
    /// and the message never takes protocol effect. Callers follow up
    /// with [`Self::kill`] so the host sees the FIN.
    pub fn send_torn(&self, msg: &ToHost, n_bytes: usize) -> std::io::Result<()> {
        let mut io = self.io.lock().expect("tcp stream poisoned");
        let ConnIo { stream, wbuf, .. } = &mut *io;
        codec::encode_to_host_into(&self.suite, self.ct_len, msg, wbuf);
        // seal first when the channel is armed: the torn bytes on the
        // wire must be a prefix of what a whole send would have written
        let mut sec = self.secure.lock().expect("secure channel poisoned");
        let body: &[u8] = match sec.as_mut() {
            Some(GuestSecure { enc, scratch, .. }) => {
                enc.seal_into(wbuf, scratch);
                scratch
            }
            None => wbuf,
        };
        let mut frame = (body.len() as u64).to_le_bytes().to_vec();
        frame.extend_from_slice(body);
        let cut = n_bytes.min(frame.len());
        stream.write_all(&frame[..cut])?;
        stream.flush()
    }
}

impl GuestTransport for TcpGuestTransport {
    fn send(&self, msg: ToHost) {
        self.try_send(msg).expect("tcp send to host failed");
    }

    fn recv(&self) -> ToGuest {
        self.try_recv().expect("tcp recv from host failed")
    }

    fn snapshot(&self) -> NetSnapshot {
        self.counters.snapshot()
    }

    fn try_send(&self, msg: ToHost) -> std::io::Result<()> {
        let mut io = self.io.lock().expect("tcp stream poisoned");
        let ConnIo { stream, wbuf, .. } = &mut *io;
        codec::encode_to_host_into(&self.suite, self.ct_len, &msg, wbuf);
        let mut sec = self.secure.lock().expect("secure channel poisoned");
        match sec.as_mut() {
            Some(GuestSecure { enc, scratch, .. }) => {
                enc.seal_into(wbuf, scratch);
                codec::write_frame(stream, scratch)?;
            }
            None => codec::write_frame(stream, wbuf)?,
        }
        drop(sec);
        // recorded only after the kernel accepted the whole frame — a
        // failed send never took protocol effect and is not counted.
        // Byte accounting stays at the plaintext frame size so secure
        // and plain runs snapshot identically.
        self.counters
            .record_to_host(msg.kind(), (wbuf.len() + codec::FRAME_HEADER_LEN) as u64);
        Ok(())
    }

    fn try_recv(&self) -> std::io::Result<ToGuest> {
        let mut io = self.io.lock().expect("tcp stream poisoned");
        let ConnIo { stream, rbuf, .. } = &mut *io;
        match codec::read_frame_into(stream, rbuf) {
            Ok(true) => {}
            // connection-level failures are recoverable (the resumption
            // path re-dials); a *malformed* frame from the host is a
            // protocol bug and still panics
            Ok(false) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "host closed the connection mid-protocol",
                ));
            }
            Err(codec::WireError::Truncated) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection died mid-frame",
                ));
            }
            Err(codec::WireError::Io(e)) => return Err(e),
            Err(e) => panic!("malformed frame from host: {e}"),
        }
        let mut sec = self.secure.lock().expect("secure channel poisoned");
        if let Some(GuestSecure { dec, .. }) = sec.as_mut() {
            // the guest drives the protocol: a frame the session keys
            // cannot authenticate means the host is broken or the
            // channel is under attack, and like any other malformed
            // host frame there is no way to make progress
            let plain = dec
                .open_in_place(rbuf)
                .unwrap_or_else(|()| panic!("malformed frame from host: bad AEAD tag"));
            rbuf.truncate(plain);
        }
        drop(sec);
        let msg = codec::decode_to_guest(&self.suite, self.ct_len, rbuf)
            .expect("malformed frame from host");
        self.counters
            .record_to_guest(msg.kind(), (rbuf.len() + codec::FRAME_HEADER_LEN) as u64);
        Ok(msg)
    }

    fn reconnect(&self) -> std::io::Result<()> {
        let stream = TcpStream::connect(&self.addr)?;
        let mut io = self.io.lock().expect("tcp stream poisoned");
        let _ = io.stream.shutdown(std::net::Shutdown::Both);
        *io = ConnIo::new(stream);
        // keys die with the connection: the resume handshake on the new
        // socket derives a fresh pair before re-arming
        *self.secure.lock().expect("secure channel poisoned") = None;
        Ok(())
    }

    fn set_secure(&self, enc_key: [u8; 32], dec_key: [u8; 32]) {
        *self.secure.lock().expect("secure channel poisoned") = Some(GuestSecure {
            enc: FrameCipher::new(enc_key),
            dec: FrameCipher::new(dec_key),
            scratch: Vec::new(),
        });
    }
}

/// Host-side endpoint. The cipher suite is unknown until the guest's
/// `Setup` frame arrives; it is captured then and used for every
/// subsequent ciphertext-bearing frame in both directions.
///
/// Unlike the guest endpoint, the two directions live behind **separate
/// locks** over cloned handles of one socket: the pipelined serving
/// engine reads frames on its decode thread while the compute thread
/// writes answers, so a receive blocked waiting for the guest's next
/// frame must never hold up an outgoing answer (one shared lock here
/// would wedge a lockstep session outright).
pub struct TcpHostTransport {
    rd: Mutex<ConnIo>,
    wr: Mutex<ConnIo>,
    /// Unlocked handle for [`HostTransport::shutdown`]: aborting a read
    /// blocked inside the `rd` lock requires a path that does not take
    /// that lock.
    ctl: TcpStream,
    suite: Mutex<Option<(CipherSuite, usize)>>,
    /// v6 AEAD, split per direction like the I/O locks themselves so
    /// the decode thread opening a request never contends with the
    /// compute thread sealing an answer. Nesting order is always
    /// `rd → sec_rx` and `wr → sec_tx` — the two chains never touch,
    /// so no deadlock is possible.
    sec_rx: Mutex<Option<FrameCipher>>,
    sec_tx: Mutex<Option<FrameCipher>>,
    counters: Arc<NetCounters>,
}

impl TcpHostTransport {
    /// Wrap an accepted guest connection.
    pub fn new(stream: TcpStream) -> Self {
        let rd = stream.try_clone().expect("clone tcp stream for the read half");
        let ctl = stream.try_clone().expect("clone tcp stream for shutdown");
        TcpHostTransport {
            rd: Mutex::new(ConnIo::new(rd)),
            wr: Mutex::new(ConnIo::new(stream)),
            ctl,
            suite: Mutex::new(None),
            sec_rx: Mutex::new(None),
            sec_tx: Mutex::new(None),
            counters: Arc::new(NetCounters::default()),
        }
    }

    /// This endpoint's traffic counters.
    pub fn counters(&self) -> Arc<NetCounters> {
        self.counters.clone()
    }
}

impl HostTransport for TcpHostTransport {
    fn recv(&self) -> Option<ToHost> {
        let mut io = self.rd.lock().expect("tcp stream poisoned");
        let ConnIo { stream, rbuf, .. } = &mut *io;
        match codec::read_frame_into(stream, rbuf) {
            Ok(true) => {}
            Ok(false) => return None, // guest closed cleanly
            Err(e) => {
                eprintln!("[sbp-host] transport error, closing: {e}");
                return None;
            }
        }
        if let Some(dec) = self.sec_rx.lock().expect("secure rx poisoned").as_mut() {
            // a frame the session keys cannot authenticate ends the
            // session loudly and is never decoded, let alone answered
            match dec.open_in_place(rbuf) {
                Ok(plain) => rbuf.truncate(plain),
                Err(()) => {
                    eprintln!("[sbp-host] AEAD tag verification failed, closing");
                    return None;
                }
            }
        }
        let mut suite = self.suite.lock().expect("suite poisoned");
        let msg = match codec::decode_to_host(suite.as_ref().map(|(s, l)| (s, *l)), rbuf) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("[sbp-host] malformed frame, closing: {e}");
                return None;
            }
        };
        if let ToHost::Setup { suite_public, .. } = &msg {
            let ct_len = suite_public.ct_byte_len();
            *suite = Some((suite_public.clone(), ct_len));
        }
        self.counters
            .record_to_host(msg.kind(), (rbuf.len() + codec::FRAME_HEADER_LEN) as u64);
        Some(msg)
    }

    fn send(&self, msg: ToGuest) {
        // Training sessions lock the suite from the guest's Setup frame.
        // Inference sessions (serve_predict) carry no ciphertexts and
        // never send Setup, so ct-free messages fall back to a fixed
        // plain suite — their wire size is ct_len-independent, keeping
        // byte accounting identical across transports.
        let (suite, ct_len) = self.suite.lock().expect("suite poisoned").clone().unwrap_or_else(
            || {
                let s = CipherSuite::new_plain(64);
                let l = s.ct_byte_len();
                (s, l)
            },
        );
        let mut io = self.wr.lock().expect("tcp stream poisoned");
        let ConnIo { stream, rbuf, wbuf } = &mut *io;
        codec::encode_to_guest_into(&suite, ct_len, &msg, wbuf);
        self.counters
            .record_to_guest(msg.kind(), (wbuf.len() + codec::FRAME_HEADER_LEN) as u64);
        if let Some(enc) = self.sec_tx.lock().expect("secure tx poisoned").as_mut() {
            // the write half's read scratch is otherwise idle — reuse
            // it as the seal buffer, keeping secure sends allocation-free
            enc.seal_into(wbuf, rbuf);
            codec::write_frame(stream, rbuf).expect("tcp send to guest failed");
        } else {
            codec::write_frame(stream, wbuf).expect("tcp send to guest failed");
        }
    }

    fn shutdown(&self) {
        // flushed answers are already in the kernel buffer and precede
        // the FIN; this only aborts a decode-stage read still blocked
        // after the session ended
        let _ = self.ctl.shutdown(std::net::Shutdown::Both);
    }

    fn set_secure_rx(&self, key: [u8; 32]) {
        *self.sec_rx.lock().expect("secure rx poisoned") = Some(FrameCipher::new(key));
    }

    fn set_secure_tx(&self, key: [u8; 32]) {
        *self.sec_tx.lock().expect("secure tx poisoned") = Some(FrameCipher::new(key));
    }
}

/// Accept one guest connection on `listener` and run a host party over it
/// until `Shutdown`/close. Returns the peer address it served.
///
/// This is the body of the `sbp serve-host` subcommand and of the
/// transport-parity integration test.
pub fn serve_host_once(
    listener: &TcpListener,
    id: u8,
    bm: BinnedMatrix,
    sb: Option<SparseBinned>,
    timer: Arc<Mutex<PhaseTimer>>,
) -> std::io::Result<std::net::SocketAddr> {
    let (stream, peer) = listener.accept()?;
    let transport = TcpHostTransport::new(stream);
    HostParty::new(id, bm, sb, transport, timer).run();
    Ok(peer)
}

/// Decode errors on the guest side panic (the guest drives the protocol
/// and cannot make progress), host-side errors end the serve loop — see
/// [`TcpHostTransport::recv`]. Exposed for reuse by error-path tests.
pub use super::codec::WireError as TcpWireError;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::federation::message::{ToGuestKind, ToHostKind};
    use std::thread;

    #[test]
    fn frames_cross_a_real_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        let server = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let host = TcpHostTransport::new(stream);
            // Setup must arrive first and fix the suite
            let msg = host.recv().expect("setup frame");
            assert!(matches!(msg, ToHost::Setup { .. }));
            host.send(ToGuest::Ack);
            let msg = host.recv().expect("apply frame");
            let ToHost::ApplySplit { instances, .. } = msg else {
                panic!("expected ApplySplit")
            };
            host.send(ToGuest::LeftInstances {
                tree_id: 0,
                node: 0,
                left: instances.iter().copied().filter(|i| i % 2 == 0).collect(),
            });
            assert!(host.recv().is_none(), "guest closes after shutdown");
        });

        let suite = CipherSuite::new_plain(256);
        let guest = TcpGuestTransport::connect(&addr.to_string(), suite.clone()).unwrap();
        let packer = crate::crypto::packing::GhPacker::plan_logistic(100, 53);
        guest.send(ToHost::Setup {
            suite_public: suite.public_side(),
            codec: crate::federation::codec::StatCodec::Packed(packer),
            compress: None,
            n_bins: 32,
            hist_subtraction: true,
            sparse_optimization: false,
            seed: 7,
        });
        assert!(matches!(guest.recv(), ToGuest::Ack));
        guest.send(ToHost::ApplySplit {
            tree_id: 0,
            node: 0,
            handle: 0,
            instances: Arc::new(vec![1, 2, 3, 4]),
        });
        let ToGuest::LeftInstances { left, .. } = guest.recv() else {
            panic!("expected LeftInstances")
        };
        assert_eq!(left, vec![2, 4]);

        let snap = guest.snapshot();
        assert_eq!(snap.msgs_to_host, 2);
        assert_eq!(snap.msgs_to_guest, 2);
        assert_eq!(snap.to_host_kind_msgs[ToHostKind::Setup.index()], 1);
        assert_eq!(snap.to_guest_kind_msgs[ToGuestKind::Ack.index()], 1);
        assert!(snap.bytes_to_host > 0 && snap.bytes_to_guest > 0);

        drop(guest); // closes the socket → server recv sees clean EOF
        server.join().unwrap();
    }

    /// Poll `conn` until it reports something other than `Pending` (the
    /// loopback delivery of a just-written chunk is asynchronous).
    fn poll_settled(conn: &mut NbConn) -> Result<RecvPoll, codec::WireError> {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            match conn.poll_frame() {
                Ok(RecvPoll::Pending) if std::time::Instant::now() < deadline => {
                    thread::sleep(std::time::Duration::from_millis(1));
                }
                other => return other,
            }
        }
    }

    #[test]
    fn nonblocking_conn_assembles_split_frames_without_blocking() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = NbConn::new(server).unwrap();

        // nothing sent yet: pending, not closed, not an error — and the
        // poll returned instead of parking the thread
        assert_eq!(conn.poll_frame().unwrap(), RecvPoll::Pending);

        let payload = b"reactor frame";
        let mut frame = (payload.len() as u64).to_le_bytes().to_vec();
        frame.extend_from_slice(payload);
        // half a header is never a frame, whether or not it has landed
        client.write_all(&frame[..5]).unwrap();
        assert_eq!(conn.poll_frame().unwrap(), RecvPoll::Pending);
        client.write_all(&frame[5..]).unwrap();
        assert_eq!(poll_settled(&mut conn).unwrap(), RecvPoll::Frame);
        assert_eq!(conn.frame_payload(), payload);
        conn.consume_frame();

        // a clean FIN at the frame boundary is a close, not an error
        drop(client);
        assert_eq!(poll_settled(&mut conn).unwrap(), RecvPoll::Closed);
    }

    #[test]
    fn nonblocking_conn_reports_mid_frame_fin_as_truncated() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = NbConn::new(server).unwrap();

        // a header promising 10 bytes, then only 3, then FIN
        client.write_all(&10u64.to_le_bytes()).unwrap();
        client.write_all(b"abc").unwrap();
        drop(client);
        let err = poll_settled(&mut conn).expect_err("mid-frame FIN must error");
        assert!(
            matches!(err, codec::WireError::Truncated),
            "expected Truncated, got {err:?}"
        );
    }

    #[test]
    fn secure_channel_crosses_blocking_transports_with_plaintext_accounting() {
        use crate::federation::message::SERVE_PROTOCOL_VERSION;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let k_gh = [0x11u8; 32];
        let k_hg = [0x22u8; 32];

        let server = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let host = TcpHostTransport::new(stream);
            let msg = host.recv().expect("hello frame");
            assert!(matches!(msg, ToHost::SessionHello { session_id: 9, .. }));
            // rx armed before the (plaintext) answer goes out, tx after:
            // the guest's first sealed frame may already be in flight
            // the moment it sees our plaintext accept
            host.set_secure_rx(k_gh);
            host.send(ToGuest::Ack);
            host.set_secure_tx(k_hg);
            let msg = host.recv().expect("sealed route frame");
            let ToHost::PredictRoute { session, chunk, queries } = msg else {
                panic!("expected PredictRoute")
            };
            assert_eq!((session, chunk), (9, 1));
            assert_eq!(queries, vec![(0, 5), (1, 7)]);
            host.send(ToGuest::RouteAnswers { session: 9, chunk: 1, n: 2, bits: vec![0b10] });
            let msg = host.recv().expect("second sealed frame");
            assert!(matches!(msg, ToHost::KeepAlive), "nonce counters stay in step");
            host.send(ToGuest::Ack);
            assert!(host.recv().is_none(), "guest closes");
        });

        let suite = CipherSuite::new_plain(64);
        let ct_len = suite.ct_byte_len();
        let guest = TcpGuestTransport::connect(&addr.to_string(), suite).unwrap();
        let hello = ToHost::SessionHello { session_id: 9, protocol: SERVE_PROTOCOL_VERSION };
        let mut want_to_host = codec::to_host_wire_len(&hello, ct_len) as u64;
        guest.send(hello);
        assert!(matches!(guest.recv(), ToGuest::Ack));
        guest.set_secure(k_gh, k_hg);
        let route = ToHost::PredictRoute { session: 9, chunk: 1, queries: vec![(0, 5), (1, 7)] };
        want_to_host += codec::to_host_wire_len(&route, ct_len) as u64;
        guest.send(route);
        let ToGuest::RouteAnswers { n, bits, .. } = guest.recv() else {
            panic!("expected RouteAnswers")
        };
        assert_eq!((n, bits), (2, vec![0b10]));
        want_to_host += codec::to_host_wire_len(&ToHost::KeepAlive, ct_len) as u64;
        guest.send(ToHost::KeepAlive);
        assert!(matches!(guest.recv(), ToGuest::Ack));

        // both ends account the plaintext frame size: sealed frames add
        // 16 tag bytes on the wire, but snapshots must stay identical
        // across secure modes and transports
        let snap = guest.snapshot();
        assert_eq!(snap.bytes_to_host, want_to_host);
        drop(guest);
        server.join().unwrap();
    }

    #[test]
    fn host_transport_closes_on_tampered_ciphertext() {
        use crate::crypto::secure::FrameCipher;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let host = TcpHostTransport::new(stream);
        host.set_secure_rx([7u8; 32]);

        let mut enc = FrameCipher::new([7u8; 32]);
        let mut sealed = Vec::new();
        enc.seal_into(b"not a real frame, tag is what matters", &mut sealed);
        sealed[3] ^= 0x01; // one flipped ciphertext bit
        client.write_all(&(sealed.len() as u64).to_le_bytes()).unwrap();
        client.write_all(&sealed).unwrap();
        client.flush().unwrap();
        // loud close, no panic, no answer
        assert!(host.recv().is_none());
    }

    #[test]
    fn nonblocking_conn_opens_sealed_frames_and_rejects_tampering() {
        use crate::crypto::secure::FrameCipher;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = NbConn::new(server).unwrap();
        conn.arm_secure_rx([9u8; 32]);
        assert!(conn.secure_rx());

        let mut enc = FrameCipher::new([9u8; 32]);
        let mut sealed = Vec::new();
        enc.seal_into(b"sealed reactor frame", &mut sealed);
        let mut frame = (sealed.len() as u64).to_le_bytes().to_vec();
        frame.extend_from_slice(&sealed);
        // dribble it so decryption happens exactly once, at completion
        client.write_all(&frame[..11]).unwrap();
        assert_eq!(conn.poll_frame().unwrap(), RecvPoll::Pending);
        client.write_all(&frame[11..]).unwrap();
        assert_eq!(poll_settled(&mut conn).unwrap(), RecvPoll::Frame);
        // a second poll on the resident frame must not double-decrypt
        assert_eq!(conn.poll_frame().unwrap(), RecvPoll::Frame);
        assert_eq!(conn.frame_payload(), b"sealed reactor frame");
        conn.consume_frame();

        // tampered follow-up: tag verification fails loudly
        let mut sealed = Vec::new();
        enc.seal_into(b"tampered in flight", &mut sealed);
        let mid = sealed.len() / 2;
        sealed[mid] ^= 0x80;
        client.write_all(&(sealed.len() as u64).to_le_bytes()).unwrap();
        client.write_all(&sealed).unwrap();
        client.flush().unwrap();
        let err = poll_settled(&mut conn).expect_err("bad tag must error");
        assert!(
            matches!(err, codec::WireError::Malformed("AEAD tag verification failed")),
            "expected AEAD failure, got {err:?}"
        );
    }

    #[test]
    fn nonblocking_conn_seals_queued_frames_with_fresh_nonces() {
        use crate::crypto::secure::FrameCipher;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = NbConn::new(server).unwrap();
        conn.arm_secure_tx([3u8; 32]);

        // the same payload queued twice — a v4 replay re-sends retained
        // plaintext — must seal to different bytes (fresh nonce each)
        conn.queue_frame(b"replayed answer");
        conn.queue_frame(b"replayed answer");
        while !conn.write_idle() {
            conn.flush_pending().unwrap();
        }
        let body_len = b"replayed answer".len() + crate::crypto::secure::TAG_LEN;
        let mut buf = vec![0u8; 2 * (8 + body_len)];
        client.read_exact(&mut buf).unwrap();
        let (f1, f2) = buf.split_at(8 + body_len);
        assert_eq!(&f1[..8], &(body_len as u64).to_le_bytes());
        assert_ne!(f1[8..], f2[8..], "identical plaintext, distinct ciphertext");
        let mut dec = FrameCipher::new([3u8; 32]);
        for frame in [f1, f2] {
            let mut body = frame[8..].to_vec();
            let n = dec.open_in_place(&mut body).expect("honest sealed frame");
            assert_eq!(&body[..n], b"replayed answer");
        }
    }

    #[test]
    fn nonblocking_conn_queues_and_flushes_writes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = NbConn::new(server).unwrap();

        conn.queue_frame(b"abc");
        conn.queue_frame(b"defg");
        assert_eq!(conn.pending_write(), 8 + 3 + 8 + 4);
        assert!(!conn.write_idle());
        while !conn.write_idle() {
            conn.flush_pending().unwrap();
        }
        assert_eq!(conn.pending_write(), 0);

        let mut buf = vec![0u8; 8 + 3 + 8 + 4];
        client.read_exact(&mut buf).unwrap();
        assert_eq!(&buf[..8], &3u64.to_le_bytes());
        assert_eq!(&buf[8..11], b"abc");
        assert_eq!(&buf[11..19], &4u64.to_le_bytes());
        assert_eq!(&buf[19..], b"defg");
    }
}
