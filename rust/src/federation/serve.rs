//! The host-side **inference serving engine**: a long-lived service that
//! multiplexes many guest prediction sessions over one loaded model
//! share, with an LRU routing cache shared across sessions.
//!
//! This is the serving half of the split introduced with the
//! session-multiplexed protocol: the *guest*-side per-session walk lives
//! in [`super::predict`] ([`super::predict::PredictSession`]); this
//! module owns everything that runs on the serving host —
//!
//! - [`HostServeState`] — the shared, load-once, immutable model share
//!   and feature slice plus the [`RoutingCache`] and service counters;
//!   one instance serves every session of a server's lifetime;
//! - [`serve_session`] — the per-session engine (`SessionHello →
//!   SessionAccept`, `PredictRoute → RouteAnswers`, `KeepAlive → Ack`,
//!   `SessionClose`), transport-agnostic and run as a **2-stage
//!   pipeline**: a decode thread (Stage A) reads frame `k+1` off the
//!   transport while the compute stage (Stage B) answers frame `k`,
//!   joined by a bounded SPSC ring — host CPU overlaps socket I/O the
//!   same way the pipelined guest overlaps encode with RTT, and
//!   answers still leave in frame order;
//! - [`serve_predict_loop`] — the framed-TCP accept loop behind
//!   `sbp serve-predict`, run as a **sharded event-driven reactor**:
//!   [`ServeConfig::workers`] worker threads (default one per CPU) each
//!   own a shard of the live sessions as non-blocking state machines
//!   over [`super::tcp::NbConn`] sockets, one decode/encode scratch set
//!   per worker instead of one thread + ring per session, so ten
//!   thousand idle sessions cost ten thousand sockets — not twenty
//!   thousand parked OS threads. Frames are still answered strictly in
//!   arrival order per session (a session lives on exactly one worker
//!   and its answers queue FIFO), so serve protocol v3 is byte-identical
//!   on the wire to the threaded engine. Sessions whose peer vanishes
//!   without FIN are reaped after [`ServeConfig::session_idle_timeout`]
//!   ([`SessionOutcome::idle_reaped`]); transient accept errors (fd
//!   exhaustion, aborted handshakes) are retried with capped backoff
//!   instead of winding the service down.
//!
//! ## Cache placement and correctness
//!
//! The cache memoizes `(record id, split handle) → routing bit` **on the
//! host**, across batches *and across sessions*: repeat traffic from the
//! same record population hits the same hot splits (ROADMAP "Prediction
//! caching"), so a warm cache answers without touching the feature
//! matrix. Because host routing is a pure function of the immutable
//! model share and feature slice, a cached bit always equals the
//! recomputed bit — cached and uncached serving are **bit-identical**
//! (asserted by `tests/serve_multi_session.rs`), and the cache is
//! invisible on the wire: every query is still answered, only host CPU
//! is saved. Hit/miss counts are surfaced through [`CacheStats`] in
//! `NetCounters` style.
//!
//! ## Backpressure
//!
//! Per-session queues are bounded at three levels: the transport queue
//! ([`super::transport::link_pair_bounded`] in-process; the OS socket
//! buffer plus strict framing over TCP), the `max_inflight` bound a
//! [`ToGuest::SessionAccept`] announces (the pipelined guest clamps its
//! chunk window to it), and the [`ServeConfig::max_batch_queries`]
//! ceiling on a single `PredictRoute` batch — a session that exceeds it
//! is closed as a protocol error instead of growing the server's memory
//! without bound.
//!
//! ## Cache-aware wire suppression
//!
//! On top of the CPU-saving routing cache, handshaked sessions run the
//! **delta protocol** ([`ToGuest::RouteAnswersDelta`]): the host tracks
//! which `(record, handle)` keys it already answered this session (a
//! bounded [`super::delta::DeltaBasis`] of
//! [`ServeConfig::delta_window`] entries, full-set behavior negotiated
//! as [`ServeConfig::basis_evict`] — v2 peers always freeze,
//! v3 sessions may run the deterministic frame-order LRU) and elides
//! repeat answers from the wire; the guest mirrors the set
//! ([`super::predict::PredictSession`]'s delta basis) and reconstructs
//! the full bitmap bit-identically. Unlike the routing cache — which is
//! wire-invisible — this layer makes repeat traffic cheaper *on the
//! wire*, per session, with bounded memory at both ends.
//!
//! ## Session resumption (serve protocol v4)
//!
//! A v4 session whose transport dies *uncleanly* (FIN or error without
//! a `SessionClose`) is not reported dead on the spot: the reactor
//! **parks** its entire state — protocol machine, delta basis, traffic
//! counters, and a bounded buffer of the encoded answer frames the
//! guest has not yet acknowledged — keyed by session id, for up to
//! [`ServeConfig::resume_window`]. A reconnecting guest presents
//! [`ToHost::SessionResume`] with its acknowledgement cursor; the host
//! answers [`ToGuest::ResumeAccept`] and **replays the buffered answer
//! frames byte-for-byte**. Replaying verbatim (instead of recomputing)
//! is what keeps the two mirrored delta bases in lockstep: the basis
//! advanced when those answers were first *computed*, so recomputing
//! them against the already-advanced basis would elide keys the guest
//! never saw. Host state only ever advances on *complete* decoded
//! frames — a frame torn by the failure is discarded by the framing
//! layer, never half-applied — so the parked machine is always at a
//! frame boundary and the resumed stream is bit-identical to an
//! uninterrupted one (asserted exhaustively by `tests/serve_fault.rs`).
//! A parked session still counts **once** against `--max-sessions` and
//! appears **once** in the final report, whether it resumes, expires
//! ([`HostServeState::sessions_resume_expired`]), or is still parked at
//! loop drain; the dead-peer idle reaper never touches parked sessions
//! — their only clock is the resume window. Resumption is a
//! reactor-only feature: the threaded [`serve_session`] engine and
//! in-memory links close on `SessionResume` (their transports cannot
//! drop frames mid-stream, so there is nothing to resume).
//!
//! ## Stage C: the shared compute pool
//!
//! Both engines route big-batch compute through one shared **compute
//! worker pool** ([`ComputePool`], [`ServeConfig::compute_workers`],
//! built lazily on first use): a batch of at least
//! [`ServeConfig::compute_shard_min`] walked queries is split into
//! shards cut at multiples of **8 queries**, so every shard owns a
//! whole number of bytes of the packed answer bitmap and the per-shard
//! results concatenate byte-exactly — sharded and inline compute are
//! bit-identical at any worker count (deterministic recombination).
//! Only the *pure* walk fans out; everything frame-order-sensitive —
//! the delta-basis membership pass, cache lookup/store, answer emission
//! — stays serial per session. The threaded engine blocks its Stage B
//! on the sharded walk ([`HostServeState::route_bits`]); the reactor
//! instead dispatches fire-and-forget shard jobs and keeps polling
//! sockets, re-sequencing completed answers per session FIFO through a
//! pending queue before flush, so one hot session saturates the pool
//! without freezing the other sessions on its worker's shard.

use super::codec;
use super::delta::DeltaBasis;
use super::limit::{Admission, AdmissionConfig, AdmissionController, LoadSample, TicketPoll};
use super::message::{
    BasisEvict, BusyReason, ToGuest, ToGuestKind, ToHost, ToHostKind, SERVE_PROTOCOL_V2,
    SERVE_PROTOCOL_V3, SERVE_PROTOCOL_V4, SERVE_PROTOCOL_V5, SERVE_PROTOCOL_VERSION,
    SESSIONLESS_ID,
};
use super::tcp::{NbConn, RecvPoll};
use super::transport::{HostTransport, NetCounters, NetSnapshot};
use crate::crypto::cipher::CipherSuite;
use crate::crypto::secure::{
    derive_session_keys, keypair, shared_secret, HandleRotor, SecureMode, SessionKeys, PUBKEY_LEN,
};
use crate::data::dataset::PartySlice;
use crate::tree::predict::HostModel;
use crate::util::pool::{num_threads, ComputePool};
use crate::util::rng::ChaCha20Rng;
use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

/// Sentinel index for the intrusive LRU list.
const NIL: usize = usize::MAX;

/// Point-in-time routing-cache counters, in the style of
/// [`super::transport::NetSnapshot`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that had to touch the feature matrix.
    pub misses: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Configured capacity (0 = caching disabled).
    pub capacity: usize,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct LruNode {
    key: (u32, u32),
    bit: bool,
    prev: usize,
    next: usize,
}

struct LruInner {
    map: HashMap<(u32, u32), usize>,
    nodes: Vec<LruNode>,
    head: usize,
    tail: usize,
    free: Vec<usize>,
}

impl LruInner {
    fn detach(&mut self, i: usize) {
        let (p, n) = (self.nodes[i].prev, self.nodes[i].next);
        if p != NIL {
            self.nodes[p].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.nodes[n].prev = p;
        } else {
            self.tail = p;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.nodes[i].prev = NIL;
        self.nodes[i].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = i;
        } else {
            self.tail = i;
        }
        self.head = i;
    }
}

/// A bounded LRU memo of `(record id, split handle) → routing bit`,
/// shared by every serving session of a host process. Thread-safe;
/// `capacity = 0` disables caching entirely (every lookup misses
/// without being counted, nothing is stored) so the uncached baseline
/// stays allocation-free.
pub struct RoutingCache {
    inner: Mutex<LruInner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl RoutingCache {
    /// Create a cache holding at most `capacity` routing bits.
    pub fn new(capacity: usize) -> Self {
        RoutingCache {
            inner: Mutex::new(LruInner {
                map: HashMap::new(),
                nodes: Vec::new(),
                head: NIL,
                tail: NIL,
                free: Vec::new(),
            }),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Configured capacity (0 = caching disabled).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Acquire the LRU lock, **recovering from poison**. The cache is
    /// shared by every session of the host's lifetime, so treating a
    /// poisoned mutex as fatal would turn one panicking session into a
    /// panic cascade for every later session. Recovery is sound here
    /// because every mutation under this lock leaves the structure
    /// consistent at each step it could unwind from: `lookup` and
    /// `store` only index slots they just read out of `map` (no slot it
    /// holds can be out of bounds), `detach`/`push_front` rewrite links
    /// of already-resident nodes, and the only fallible operations in
    /// the sequence (`Vec`/`HashMap` growth) abort on allocation
    /// failure rather than unwinding. A panic can therefore only enter
    /// *between* complete map/list updates — worst case the interrupted
    /// session's final store is lost, which is just a future miss.
    fn lock_inner(&self) -> MutexGuard<'_, LruInner> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Lock once for a whole batch of lookups/stores — the serving hot
    /// path takes one mutex acquisition per `PredictRoute` batch, not
    /// per query. Caller must ensure `capacity() > 0`.
    pub fn batch(&self) -> CacheBatch<'_> {
        debug_assert!(self.capacity > 0, "batch() on a disabled cache");
        CacheBatch { cache: self, inner: self.lock_inner() }
    }

    /// Cached routing bit for `key`, refreshing its recency on a hit.
    pub fn lookup(&self, key: (u32, u32)) -> Option<bool> {
        if self.capacity == 0 {
            return None;
        }
        self.batch().lookup(key)
    }

    /// Remember a computed routing bit, evicting the least-recently-used
    /// entry when full.
    pub fn store(&self, key: (u32, u32), bit: bool) {
        if self.capacity == 0 {
            return;
        }
        self.batch().store(key, bit)
    }

    /// Current hit/miss/occupancy counters.
    pub fn stats(&self) -> CacheStats {
        let entries = self.lock_inner().map.len();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries,
            capacity: self.capacity,
        }
    }
}

/// A held lock over the cache for batched access (see
/// [`RoutingCache::batch`]).
pub struct CacheBatch<'a> {
    cache: &'a RoutingCache,
    inner: std::sync::MutexGuard<'a, LruInner>,
}

impl CacheBatch<'_> {
    /// Count a hit that was resolved *outside* the LRU map — the
    /// lookup pass of [`HostServeState::route_plan`] resolves a
    /// within-batch repeat of a not-yet-stored miss locally (the
    /// inline path would have hit the just-stored entry), so the
    /// hit/miss totals stay identical to single-pass serving.
    fn count_hit(&self) {
        self.cache.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Cached routing bit for `key`, refreshing its recency on a hit.
    pub fn lookup(&mut self, key: (u32, u32)) -> Option<bool> {
        match self.inner.map.get(&key).copied() {
            Some(i) => {
                self.inner.detach(i);
                self.inner.push_front(i);
                self.cache.hits.fetch_add(1, Ordering::Relaxed);
                Some(self.inner.nodes[i].bit)
            }
            None => {
                self.cache.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Remember a computed routing bit, evicting the least-recently-used
    /// entry when full.
    pub fn store(&mut self, key: (u32, u32), bit: bool) {
        if let Some(i) = self.inner.map.get(&key).copied() {
            // racing sessions may store the same key twice; routing is
            // deterministic so the bit is necessarily identical
            self.inner.nodes[i].bit = bit;
            self.inner.detach(i);
            self.inner.push_front(i);
            return;
        }
        if self.inner.map.len() >= self.cache.capacity {
            let victim = self.inner.tail;
            self.inner.detach(victim);
            let old_key = self.inner.nodes[victim].key;
            self.inner.map.remove(&old_key);
            self.inner.free.push(victim);
        }
        let slot = match self.inner.free.pop() {
            Some(s) => {
                self.inner.nodes[s] = LruNode { key, bit, prev: NIL, next: NIL };
                s
            }
            None => {
                self.inner.nodes.push(LruNode { key, bit, prev: NIL, next: NIL });
                self.inner.nodes.len() - 1
            }
        };
        self.inner.map.insert(key, slot);
        self.inner.push_front(slot);
    }
}

/// Tunables of a serving host process.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Routing-cache capacity in entries (0 disables caching).
    pub cache_capacity: usize,
    /// Largest `PredictRoute` batch a session may send; bigger batches
    /// are a protocol error and close the session (memory backpressure).
    pub max_batch_queries: usize,
    /// In-flight batch bound announced in `SessionAccept`: how many
    /// unanswered `PredictRoute` chunks a pipelined guest may keep on
    /// the wire per session. Compliant guests clamp their
    /// `--max-inflight` window to it; the transport (socket buffer /
    /// bounded in-memory queue) enforces the rest.
    pub max_inflight: u32,
    /// Capacity (entries) of the per-session **delta basis** for
    /// cache-aware wire suppression, 0 = off. Handshaked sessions track
    /// which `(record, handle)` keys they have already answered and
    /// elide repeat answers via [`ToGuest::RouteAnswersDelta`]; what a
    /// *full* basis does is governed by [`ServeConfig::basis_evict`].
    /// Hello-less legacy sessions never use deltas.
    pub delta_window: usize,
    /// Eviction policy of a full delta basis, announced to v3 clients
    /// in the `SessionAccept` handshake and mirrored by them. Sessions
    /// negotiated down to v2 always run [`BasisEvict::Freeze`],
    /// whatever this says — a v2 peer has no LRU to mirror.
    pub basis_evict: BasisEvict,
    /// **Test/bench knob, not a serving option:** artificial per-batch
    /// latency injected into the compute stage (Stage B) before it
    /// answers a `PredictRoute`, to make the decode stage's ring
    /// backpressure observable. `None` in any real deployment.
    pub stage_b_delay: Option<std::time::Duration>,
    /// Reactor worker threads the TCP serve loop shards sessions over
    /// (0 = one per available CPU). Each worker owns its sessions
    /// exclusively — a session's frames are decoded, answered, and
    /// flushed by exactly one thread, which is what preserves the
    /// per-link answer-order contract without any cross-worker
    /// synchronization. Ignored by the transport-agnostic
    /// [`serve_session`] engine (in-memory links keep their dedicated
    /// 2-stage pipeline).
    pub workers: usize,
    /// Reap a session that produced no frame at all — no batch, no
    /// `KeepAlive` — for this long (zero = never). This is the
    /// dead-peer bound: a guest that vanishes without FIN (crash, NAT
    /// drop, cable pull) otherwise pins its session slot forever.
    /// Reaped sessions end unclean with
    /// [`SessionOutcome::idle_reaped`] set. Guests that idle
    /// legitimately must keep-alive inside this window. Parked
    /// (disconnected v4) sessions are *not* subject to this clock —
    /// theirs is [`ServeConfig::resume_window`].
    pub session_idle_timeout: std::time::Duration,
    /// How long the reactor keeps the state of an uncleanly
    /// disconnected v4 session parked and resumable
    /// ([`ToHost::SessionResume`]) before giving the session up and
    /// reporting it. Zero (the default) disables resumption entirely:
    /// disconnects are final, exactly the pre-v4 behavior. Only the
    /// sharded TCP reactor honors this; the threaded [`serve_session`]
    /// engine never parks.
    pub resume_window: std::time::Duration,
    /// Worker threads of the shared **Stage C compute pool** (0 = one
    /// per available CPU). The pool is built lazily on the first batch
    /// big enough to shard ([`ServeConfig::compute_shard_min`]), so
    /// hosts that only ever see small batches never spawn it. Both
    /// engines use the same pool: the threaded [`serve_session`]
    /// engine's Stage B blocks on a scoped fan-out, the reactor's sweep
    /// threads enqueue detached shard jobs and keep polling sockets.
    pub compute_workers: usize,
    /// Smallest *walked* batch (queries after delta elision and cache
    /// hits) that fans out across the compute pool; anything smaller is
    /// computed inline on the calling thread, because a sub-threshold
    /// batch finishes faster than its dispatch costs. Shards are cut on
    /// 8-query boundaries so the bit-packed sub-results concatenate
    /// byte-exactly — sharded and inline compute are **bit-identical**
    /// at every worker count. Set to `usize::MAX` to force everything
    /// inline (the benchmark baseline).
    pub compute_shard_min: usize,
    /// **Test/bench knob, not a serving option:** artificial latency
    /// injected into each pure routing walk ([`HostServeState`]'s
    /// `walk_packed`), *outside every lock* — used to prove that two
    /// sessions sharing the routing cache overlap their walks instead
    /// of serializing on the cache lock. `None` in any real deployment.
    pub walk_delay: Option<std::time::Duration>,
    /// Admission control (serve protocol v5): the AIMD concurrency
    /// limiter that decides per hello whether to admit, queue, or shed
    /// with a retryable [`ToGuest::Busy`], and retunes the
    /// `max_inflight` window each [`ToGuest::SessionAccept`] advertises
    /// (never above [`ServeConfig::max_inflight`]). The default
    /// (`limit == 0`) turns admission off entirely — every hello admits
    /// with the static window, exactly the pre-v5 behavior.
    pub admission: AdmissionConfig,
    /// Encrypted-session policy (serve protocol v6, the `--secure`
    /// flag): [`SecureMode::Prefer`] (default) answers keyed hellos
    /// with a keyed accept and serves the session over per-frame
    /// ChaCha20-Poly1305 while still serving plaintext v5-and-older
    /// peers; [`SecureMode::Require`] closes any plaintext hello;
    /// [`SecureMode::Off`] closes keyed hellos (forcing v6-capable
    /// guests to fall back or leave, useful for wire-level debugging).
    /// Pre-handshake control frames — `Busy` above all — are plaintext
    /// in every mode: they exist precisely for peers that have no
    /// session keys yet.
    pub secure: SecureMode,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            cache_capacity: 1 << 16,
            max_batch_queries: 1 << 22,
            max_inflight: 8,
            delta_window: 1 << 16,
            basis_evict: BasisEvict::Lru,
            stage_b_delay: None,
            workers: 0,
            session_idle_timeout: std::time::Duration::from_secs(60),
            resume_window: std::time::Duration::ZERO,
            compute_workers: 0,
            compute_shard_min: 1 << 12,
            walk_delay: None,
            admission: AdmissionConfig::default(),
            secure: SecureMode::default(),
        }
    }
}

/// The frozen state of an uncleanly disconnected v4 session awaiting a
/// [`ToHost::SessionResume`]: everything a reconnecting guest needs the
/// host to still remember, parked for at most
/// [`ServeConfig::resume_window`].
struct ParkedSession {
    machine: SessionMachine,
    /// The session's cumulative traffic counters — they move with the
    /// session across connections, so a resumed session's report spans
    /// its whole life.
    counters: NetCounters,
    answers_sent: u64,
    basis_inserts: u64,
    replay: std::collections::VecDeque<ReplayEntry>,
    resumes: u32,
    /// Session start (first connection) — resumed wall time is
    /// cumulative.
    t0: Instant,
    /// When the session was parked; the resume-window clock.
    parked_at: Instant,
    peer: SocketAddr,
}

/// One buffered host→guest answer frame, retained until the guest
/// acknowledges it (which only ever happens via a resume handshake) or
/// the bounded buffer rolls it out.
struct ReplayEntry {
    kind: ToGuestKind,
    /// The session's cumulative basis-insert count *before* this
    /// frame's batch mutated the basis — the epoch a guest resuming
    /// with this frame as its first replay must be at.
    epoch_before: u64,
    /// The encoded frame payload, byte-for-byte as first sent.
    bytes: Vec<u8>,
}

/// Answer frames retained per v4 session for replay. The guest never
/// keeps more than `max_inflight` requests unanswered per link, so its
/// un-received answer backlog is bounded by the same number; the slack
/// covers nonconforming clients without letting them grow host memory.
fn replay_retain_cap(cfg: &ServeConfig) -> usize {
    cfg.max_inflight.max(1) as usize * 4 + 64
}

/// The shared, immutable state of a serving host process: one loaded
/// model share + feature slice serving *every* session, the routing
/// cache, and service-level counters. Cheap to clone behind an [`Arc`];
/// sessions only read the model and share the cache.
pub struct HostServeState {
    model: HostModel,
    slice: PartySlice,
    cache: RoutingCache,
    cfg: ServeConfig,
    stop: AtomicBool,
    sessions_served: AtomicU64,
    queries_answered: AtomicU64,
    answers_elided: AtomicU64,
    ring_high_water: AtomicUsize,
    decode_stall_nanos: AtomicU64,
    sessions_idle_reaped: AtomicU64,
    poll_stall_nanos: AtomicU64,
    sessions_resumed: AtomicU64,
    sessions_resume_expired: AtomicU64,
    /// Disconnected v4 sessions awaiting a resume, keyed by session id.
    /// Global (not per shard): the reconnecting guest may be dispatched
    /// to any worker.
    parked: Mutex<HashMap<u32, ParkedSession>>,
    /// The shared Stage C compute pool, built lazily on the first batch
    /// that crosses [`ServeConfig::compute_shard_min`] — a host that
    /// only sees small batches never pays the threads.
    pool: OnceLock<ComputePool>,
    /// Shard jobs dispatched to the compute pool (all sessions).
    compute_jobs: AtomicU64,
    /// Batches whose walk fanned out across the pool (vs inline).
    compute_sharded_batches: AtomicU64,
    /// The v5 admission controller: per-hello admit / queue / shed and
    /// the self-tuning `max_inflight` window. Disabled (pass-through)
    /// unless [`AdmissionConfig::limit`] is set.
    admission: AdmissionController,
    /// `PredictRoute` batches answered, for the limiter's mean service
    /// latency (with [`Self::service_nanos`]).
    service_batches: AtomicU64,
    /// Total decode-to-emit service time of those batches.
    service_nanos: AtomicU64,
}

impl HostServeState {
    /// Build the shared serving state from a loaded host model share and
    /// the host's feature rows (record id = row index).
    pub fn new(model: HostModel, slice: PartySlice, cfg: ServeConfig) -> Arc<Self> {
        Arc::new(HostServeState {
            model,
            slice,
            cache: RoutingCache::new(cfg.cache_capacity),
            cfg,
            stop: AtomicBool::new(false),
            sessions_served: AtomicU64::new(0),
            queries_answered: AtomicU64::new(0),
            answers_elided: AtomicU64::new(0),
            ring_high_water: AtomicUsize::new(0),
            decode_stall_nanos: AtomicU64::new(0),
            sessions_idle_reaped: AtomicU64::new(0),
            poll_stall_nanos: AtomicU64::new(0),
            sessions_resumed: AtomicU64::new(0),
            sessions_resume_expired: AtomicU64::new(0),
            parked: Mutex::new(HashMap::new()),
            pool: OnceLock::new(),
            compute_jobs: AtomicU64::new(0),
            compute_sharded_batches: AtomicU64::new(0),
            admission: AdmissionController::new(cfg.admission, cfg.max_inflight),
            service_batches: AtomicU64::new(0),
            service_nanos: AtomicU64::new(0),
        })
    }

    /// Routing-cache counters (shared across all sessions).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Sessions completed so far.
    pub fn sessions_served(&self) -> u64 {
        self.sessions_served.load(Ordering::Relaxed)
    }

    /// Routing queries answered so far (all sessions; delta-elided
    /// answers included — every query is answered, some for free).
    pub fn queries_answered(&self) -> u64 {
        self.queries_answered.load(Ordering::Relaxed)
    }

    /// Answers elided from the wire by delta suppression so far (all
    /// sessions): repeat `(record, handle)` asks whose bits never left
    /// the host because the guest's mirrored basis already held them.
    pub fn answers_elided(&self) -> u64 {
        self.answers_elided.load(Ordering::Relaxed)
    }

    /// Highest decode-ring occupancy any session's pipeline reached
    /// (frames decoded by Stage A but not yet answered by Stage B).
    pub fn ring_high_water(&self) -> usize {
        self.ring_high_water.load(Ordering::Relaxed)
    }

    /// Total seconds decode stages spent blocked on a full ring — the
    /// serving side's backpressure stall, the dual of the guest's
    /// `StreamReport::stall_seconds`.
    pub fn decode_stall_seconds(&self) -> f64 {
        self.decode_stall_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Sessions ended by the dead-peer idle reaper
    /// ([`ServeConfig::session_idle_timeout`]): no frame and no
    /// keep-alive inside the window, peer presumed gone.
    pub fn sessions_idle_reaped(&self) -> u64 {
        self.sessions_idle_reaped.load(Ordering::Relaxed)
    }

    /// Total seconds reactor workers spent parked with live sessions
    /// but nothing readable — the event-driven host's idle-poll dual of
    /// [`Self::decode_stall_seconds`]. High values are healthy (quiet
    /// sessions); what they buy is sleeping in one thread per worker
    /// instead of one blocked read per session.
    pub fn poll_stall_seconds(&self) -> f64 {
        self.poll_stall_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Sessions that reconnected and resumed after an unclean
    /// disconnect (successful [`ToHost::SessionResume`] handshakes; a
    /// session surviving several disconnects counts once per resume).
    pub fn sessions_resumed(&self) -> u64 {
        self.sessions_resumed.load(Ordering::Relaxed)
    }

    /// Parked sessions given up on: no resume arrived inside
    /// [`ServeConfig::resume_window`] (or the loop drained first), so
    /// the session was finally reported. Disjoint from
    /// [`Self::sessions_idle_reaped`] — parking and idle reaping are
    /// different clocks on different states.
    pub fn sessions_resume_expired(&self) -> u64 {
        self.sessions_resume_expired.load(Ordering::Relaxed)
    }

    /// Sessions currently parked awaiting a resume.
    pub fn sessions_parked(&self) -> usize {
        self.parked_lock().len()
    }

    /// Shard jobs dispatched to the Stage C compute pool so far.
    pub fn compute_jobs(&self) -> u64 {
        self.compute_jobs.load(Ordering::Relaxed)
    }

    /// Batches whose walk fanned out across the pool (vs inline).
    pub fn compute_sharded_batches(&self) -> u64 {
        self.compute_sharded_batches.load(Ordering::Relaxed)
    }

    /// Worker threads the Stage C pool is actually running — 0 until
    /// the first shardable batch builds it.
    pub fn compute_workers_running(&self) -> usize {
        self.pool.get().map(|p| p.workers()).unwrap_or(0)
    }

    /// Cumulative seconds shard jobs sat queued before a pool worker
    /// picked them up — the signal that `--compute-workers` is too low
    /// (or the pool is oversubscribed by too many hot sessions).
    pub fn compute_queue_stall_seconds(&self) -> f64 {
        self.pool.get().map(|p| p.queue_stall_seconds()).unwrap_or(0.0)
    }

    /// The admission controller's counters (all zero when admission is
    /// off): sheds, queued hellos, queue wait, window retunes, and the
    /// current advertised window.
    pub fn admission_stats(&self) -> super::limit::AdmissionStats {
        self.admission.stats()
    }

    /// Record one answered batch's decode-to-emit service time — the
    /// limiter's latency-inflation signal.
    fn note_service(&self, elapsed: Duration) {
        self.service_batches.fetch_add(1, Ordering::Relaxed);
        self.service_nanos.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Feed the limiter one cumulative load sample and let it retune
    /// (internally rate-limited, so both engines call this from their
    /// loops at whatever cadence is convenient).
    fn admission_retune(&self) {
        if !self.admission.enabled() {
            return;
        }
        self.admission.retune(LoadSample {
            poll_stall_seconds: self.poll_stall_seconds(),
            decode_stall_seconds: self.decode_stall_seconds(),
            compute_queue_stall_seconds: self.compute_queue_stall_seconds(),
            batches: self.service_batches.load(Ordering::Relaxed),
            service_seconds: self.service_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        });
    }

    /// The Stage C pool, built on first use.
    fn pool(&self) -> &ComputePool {
        self.pool.get_or_init(|| ComputePool::new(self.cfg.compute_workers))
    }

    /// Shard geometry for a walk of `n` queries: `Some((shard_len,
    /// n_shards))` when the batch is big enough to fan out
    /// ([`ServeConfig::compute_shard_min`]), `None` when it stays
    /// inline. `shard_len` is always a multiple of 8, so every shard
    /// starts on a byte boundary of the packed answer bitmap and the
    /// per-shard outputs concatenate byte-exactly — which is the entire
    /// deterministic-recombination argument: the recombined bitmap is
    /// *structurally* identical to the single-threaded packing,
    /// whatever the worker count.
    fn shard_geometry(&self, n: usize) -> Option<(usize, usize)> {
        if n == 0 || n < self.cfg.compute_shard_min {
            return None;
        }
        let workers = if self.cfg.compute_workers > 0 {
            self.cfg.compute_workers
        } else {
            num_threads()
        };
        let shard_len = n.div_ceil(workers.max(1)).div_ceil(8).max(1) * 8;
        Some((shard_len, n.div_ceil(shard_len)))
    }

    /// The parked-session map, recovering from poison like the routing
    /// cache (same argument: entries are inserted and removed whole, a
    /// panic cannot leave a half-written entry behind).
    fn parked_lock(&self) -> MutexGuard<'_, HashMap<u32, ParkedSession>> {
        self.parked.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Ask the serve loop to stop accepting new sessions.
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Has a graceful shutdown been requested?
    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Range-check a batch against this host's rows and split table,
    /// logging a violation. Shared by the plain and delta answer paths
    /// so their contracts cannot drift apart. Batches past the shard
    /// threshold fan the scan out across the Stage C pool — the check
    /// is a pure predicate over immutable state, so it parallelizes
    /// like the walk does (any shard's verdict composes by AND).
    fn queries_in_range(&self, queries: &[(u32, u32)]) -> bool {
        let out_of_range = |&(row, handle): &(u32, u32)| {
            let bad = row as usize >= self.slice.n || handle as usize >= self.model.splits.len();
            if bad {
                eprintln!(
                    "[sbp-serve] query out of range (row {row} of {}, handle {handle} of {})",
                    self.slice.n,
                    self.model.splits.len()
                );
            }
            bad
        };
        if let Some((shard_len, n_shards)) = self.shard_geometry(queries.len()) {
            if n_shards > 1 {
                let ok = AtomicBool::new(true);
                self.pool().run_chunks(n_shards, |s| {
                    if !ok.load(Ordering::Relaxed) {
                        return; // some shard already found a violation
                    }
                    let a = s * shard_len;
                    let b = (a + shard_len).min(queries.len());
                    if queries[a..b].iter().any(out_of_range) {
                        ok.store(false, Ordering::Relaxed);
                    }
                });
                return ok.load(Ordering::Relaxed);
            }
        }
        !queries.iter().any(out_of_range)
    }

    /// The pure routing walk: bit-pack goes-left answers for `keys`
    /// against the immutable model share and feature slice. No locks,
    /// no shared mutable state — this is the function Stage C fans out.
    fn walk_packed(&self, keys: &[(u32, u32)]) -> Vec<u8> {
        if let Some(delay) = self.cfg.walk_delay {
            std::thread::sleep(delay); // test/bench knob only
        }
        let d = self.slice.d();
        let mut bits = vec![0u8; keys.len().div_ceil(8)];
        for (i, &(row, handle)) in keys.iter().enumerate() {
            let r = row as usize;
            if self.model.goes_left(handle, &self.slice.x[r * d..(r + 1) * d]) {
                bits[i / 8] |= 1 << (i % 8);
            }
        }
        bits
    }

    /// The cache **lookup pass** of a batch: resolve what the routing
    /// cache already knows under one short lock, and return the keys
    /// that still need walking. The lock is released before any walking
    /// happens — concurrent sessions contend for the microseconds of
    /// map probes, never for each other's compute (the old single-pass
    /// `route_bits` held the lock across the whole walk, serializing
    /// every co-resident session behind the hottest one).
    ///
    /// Returns the plan (hit bits pre-filled, scatter positions for the
    /// missing ones) and the walk list. With the cache disabled the
    /// plan is an identity: the walk list is the batch itself and the
    /// walked bytes are the answer.
    fn route_plan(&self, fresh: Vec<(u32, u32)>) -> (RoutePlan, Vec<(u32, u32)>) {
        if self.cache.capacity() == 0 {
            let plan =
                RoutePlan { bits: Vec::new(), miss_pos: Vec::new(), dup_pos: Vec::new(), cached: false };
            return (plan, fresh);
        }
        let n = fresh.len();
        let mut bits = vec![0u8; n.div_ceil(8)];
        let mut walk: Vec<(u32, u32)> = Vec::new();
        let mut miss_pos: Vec<u32> = Vec::new();
        let mut dup_pos: Vec<(u32, u32)> = Vec::new();
        // within-batch repeats of a miss (only possible with the delta
        // basis off — the basis dedups batches before they get here)
        let mut pending: HashMap<(u32, u32), u32> = HashMap::new();
        {
            let mut cache = self.cache.batch();
            for (i, &key) in fresh.iter().enumerate() {
                if let Some(&j) = pending.get(&key) {
                    // the inline path would hit the just-stored entry
                    cache.count_hit();
                    dup_pos.push((i as u32, j));
                } else {
                    match cache.lookup(key) {
                        Some(bit) => {
                            if bit {
                                bits[i / 8] |= 1 << (i % 8);
                            }
                        }
                        None => {
                            pending.insert(key, walk.len() as u32);
                            miss_pos.push(i as u32);
                            walk.push(key);
                        }
                    }
                }
            }
        } // cache lock released here, before any walk
        (RoutePlan { bits, miss_pos, dup_pos, cached: true }, walk)
    }

    /// The cache **store pass** + recombination: remember the walked
    /// bits under a second short lock and scatter them into the
    /// pre-filled hit bitmap. `keys`/`walked` are the walk list the
    /// plan returned and its packed walk output (shard-concatenated or
    /// inline — byte-identical either way).
    fn finish_route(&self, plan: RoutePlan, keys: &[(u32, u32)], walked: Vec<u8>) -> Vec<u8> {
        if !plan.cached {
            return walked; // identity plan: the walk was the batch
        }
        let RoutePlan { mut bits, miss_pos, dup_pos, .. } = plan;
        if !keys.is_empty() {
            let mut cache = self.cache.batch();
            for (j, &key) in keys.iter().enumerate() {
                cache.store(key, walked[j / 8] & (1 << (j % 8)) != 0);
            }
        }
        for (j, &pos) in miss_pos.iter().enumerate() {
            if walked[j / 8] & (1 << (j % 8)) != 0 {
                bits[pos as usize / 8] |= 1 << (pos as usize % 8);
            }
        }
        for &(pos, j) in &dup_pos {
            if walked[j as usize / 8] & (1 << (j as usize % 8)) != 0 {
                bits[pos as usize / 8] |= 1 << (pos as usize % 8);
            }
        }
        bits
    }

    /// Walk `keys`, sharded across the Stage C pool when the batch is
    /// big enough ([`Self::shard_geometry`]), inline otherwise. Blocks
    /// until the walk is done — the synchronous compute path used by
    /// the threaded engine's Stage B and by reactor batches below the
    /// shard threshold. Returns the packed bits and the number of shard
    /// jobs dispatched (0 = inline).
    fn walk_sharded(&self, keys: &[(u32, u32)]) -> (Vec<u8>, u64) {
        let Some((shard_len, n_shards)) = self.shard_geometry(keys.len()) else {
            return (self.walk_packed(keys), 0);
        };
        self.compute_jobs.fetch_add(n_shards as u64, Ordering::Relaxed);
        self.compute_sharded_batches.fetch_add(1, Ordering::Relaxed);
        let slots: Vec<OnceLock<Vec<u8>>> = (0..n_shards).map(|_| OnceLock::new()).collect();
        self.pool().run_chunks(n_shards, |s| {
            let a = s * shard_len;
            let b = (a + shard_len).min(keys.len());
            let _ = slots[s].set(self.walk_packed(&keys[a..b]));
        });
        // every shard starts at a multiple of 8 queries, so each
        // sub-bitmap is a whole number of bytes of the global packing:
        // concatenation *is* recombination, bit-identical to inline
        let mut walked = Vec::with_capacity(keys.len().div_ceil(8));
        for slot in &slots {
            // an empty slot means the walk panicked on a pool worker —
            // impossible for an in-range batch (the walk is total);
            // failing loudly here beats answering wrong
            walked.extend_from_slice(slot.get().expect("compute shard panicked"));
        }
        (walked, n_shards as u64)
    }

    /// Compute the bit-packed goes-left answers for an in-range batch,
    /// through the routing cache when one is configured — the
    /// **single** synchronous implementation behind both the plain and
    /// delta answer paths, so cached/uncached, plain/delta, and
    /// inline/sharded serving all stay bit-identical by construction.
    /// Returns the bits and the number of Stage C shard jobs used.
    fn route_bits(&self, fresh: Vec<(u32, u32)>) -> (Vec<u8>, u64) {
        let (plan, keys) = self.route_plan(fresh);
        let (walked, jobs) = self.walk_sharded(&keys);
        (self.finish_route(plan, &keys, walked), jobs)
    }
}

/// The serial residue of a batch's cache lookup pass: hit bits already
/// filled in, and where to scatter the walked miss bits. Built and
/// consumed under two *separate* short cache locks
/// ([`HostServeState::route_plan`] / [`HostServeState::finish_route`])
/// so the lock is never held across the (possibly parallel) walk.
struct RoutePlan {
    /// The batch's packed answer bitmap with every cache hit pre-filled
    /// (empty for the cache-off identity plan).
    bits: Vec<u8>,
    /// `miss_pos[j]` = batch position of walk key `j`.
    miss_pos: Vec<u32>,
    /// `(batch position, walk index)` of within-batch repeats of a
    /// missed key — resolved from the first occurrence's walked bit.
    dup_pos: Vec<(u32, u32)>,
    /// False = cache disabled: the walk list was the whole batch and
    /// the walked bytes are the finished answer.
    cached: bool,
}

/// What one serving session did, reported when it ends.
#[derive(Clone, Debug)]
pub struct SessionOutcome {
    /// The session's id ([`SESSIONLESS_ID`] for a legacy hello-less
    /// client).
    pub session_id: u32,
    /// Routing queries answered in this session.
    pub queries: u64,
    /// `PredictRoute` batches answered.
    pub batches: u64,
    /// Answers elided from the wire by delta suppression (repeat
    /// `(record, handle)` asks resolved from the guest's mirrored
    /// basis instead of shipping bits).
    pub answers_elided: u64,
    /// Keep-alive probes answered.
    pub keep_alives: u64,
    /// Ended by `SessionClose`/`Shutdown` (vs transport close or
    /// protocol error).
    pub clean_close: bool,
    /// Ended by the dead-peer reaper: the session produced no frame —
    /// no batch, no `KeepAlive` — for a whole
    /// [`ServeConfig::session_idle_timeout`] window, so the host
    /// presumed the peer gone and reclaimed the slot. Always implies
    /// `!clean_close`.
    pub idle_reaped: bool,
    /// Wall time from first frame awaited to session end.
    pub wall_seconds: f64,
    /// Serve-protocol version the session negotiated (4; 3 or 2 for a
    /// legacy peer; 0 for a hello-less sessionless connection).
    pub protocol: u32,
    /// The session ran the v6 encrypted channel: a keyed handshake
    /// completed, every post-accept frame was sealed, and handle ids
    /// were rotated on the wire.
    pub secure: bool,
    /// Delta-basis eviction policy the session ran
    /// ([`BasisEvict::Freeze`] for v2 and hello-less sessions).
    pub basis_evict: BasisEvict,
    /// Highest occupancy the session's decode ring reached: frames
    /// Stage A had read and decoded that Stage B had not yet consumed.
    /// Bounded by [`ServeConfig::max_inflight`] — the pipeline's
    /// per-session memory is O(this) decoded frames.
    pub ring_high_water: usize,
    /// Seconds Stage A spent blocked pushing into a full ring — the
    /// host-side pipeline's backpressure stall: nonzero means decode
    /// outran compute and was throttled instead of buffering without
    /// bound.
    pub decode_stall_seconds: f64,
    /// Seconds Stage B spent waiting on an empty ring — compute idling
    /// on socket I/O. A busy pipeline should keep this near the
    /// session's natural think time between batches.
    pub compute_idle_seconds: f64,
    /// Stage C shard jobs this session's batches dispatched to the
    /// compute pool (0 = every walk stayed inline).
    pub compute_jobs: u64,
    /// Mean shard jobs per *sharded* batch — how widely this session's
    /// big batches actually fanned out (0.0 when none sharded).
    pub shards_per_batch: f64,
}

impl SessionOutcome {
    /// A connection that did no serving work — no query batches, no
    /// keep-alives. Covers both the administrative stop connection
    /// `shutdown_predict_hosts` opens and stray probes (port scanners,
    /// health checks) that connect without speaking the protocol. Such
    /// connections are excluded from session counters, reports, and the
    /// `--max-sessions` budget.
    pub fn is_control_only(&self) -> bool {
        self.batches == 0 && self.keep_alives == 0
    }
}

/// What [`SessionMachine::on_frame`] decided about the session's fate.
enum Step {
    /// Keep feeding frames.
    Continue,
    /// The session is over; `clean` distinguishes an orderly
    /// `SessionClose`/`Shutdown` from a protocol error.
    Close { clean: bool },
}

/// The per-session serving protocol as **pure plain-data state**: one
/// decoded [`ToHost`] frame in, zero or one [`ToGuest`] answers out
/// through the `send` sink, in order. Both session drivers run exactly
/// this machine — the threaded 2-stage pipeline ([`serve_session`],
/// one engine per in-memory link) and the sharded TCP reactor
/// ([`serve_predict_loop`], many machines per worker thread) — so the
/// wire protocol cannot drift between them: same frames in, same
/// frames out, same order, byte-identical.
struct SessionMachine {
    session_id: u32,
    hello_seen: bool,
    negotiated: u32,
    queries: u64,
    batches: u64,
    keep_alives: u64,
    answers_elided: u64,
    /// Per-session delta basis: (record, handle) keys already answered —
    /// only handshaked sessions use it (hello-less legacy clients cannot
    /// decode RouteAnswersDelta frames), so it starts inert and is built
    /// at the hello under the negotiated eviction policy.
    basis: DeltaBasis,
    /// [`ServeConfig::delta_window`] clamped to what the u32
    /// `SessionAccept` announcement can carry: the enforced cap and the
    /// announced cap must be the same number, or the two ends'
    /// insertion rules diverge and the delta protocol desyncs.
    cfg_delta: usize,
    /// Stage C shard jobs this session's batches dispatched.
    compute_jobs: u64,
    /// Batches of this session whose walk fanned out (vs inline).
    compute_sharded_batches: u64,
    /// This session holds an admission slot (released exactly once, at
    /// session end or park; a resume re-acquires by force).
    admitted: bool,
    /// A hello parked in the admission queue, awaiting a slot: the
    /// handshake is deferred, the driver polls
    /// [`SessionMachine::poll_admission`] until the ticket resolves.
    pending_hello: Option<PendingHello>,
    /// Handle rotation for a keyed (protocol v6) session: every inbound
    /// `PredictRoute` carries rotated host-handle ids the machine maps
    /// back before the range check and the basis pass. `Some` exactly
    /// when the session completed a keyed handshake; resumes keep the
    /// rotor (rotation is a *session* property — the guest memoized
    /// routes under it — while AEAD keys are per connection).
    rotor: Option<HandleRotor>,
    /// A keyed handshake that completed on the last fed frame, staged
    /// for the driver: the accept to emit plus the derived AEAD keys.
    /// Deferred because only the driver can order the arming — the
    /// receive direction must seal *before* the accept leaves (the
    /// guest encrypts from the moment it sees the accept) and the send
    /// direction only *after* (the accept itself is plaintext).
    handshake: Option<(ToGuest, SessionKeys)>,
}

/// The deferred half of a queued `SessionHello` (see
/// [`SessionMachine::poll_admission`]).
#[derive(Clone, Copy)]
struct PendingHello {
    sid: u32,
    protocol: u32,
    ticket: u64,
    /// The guest's ephemeral X25519 public key when the queued hello
    /// was a [`ToHost::SessionHelloSecure`]; `None` for a plain hello.
    pubkey: Option<[u8; PUBKEY_LEN]>,
}

/// The output of [`SessionMachine::route_serial`]: a `PredictRoute`
/// reduced to its pure walk. Everything whose order the protocol fixes
/// per session — id checks, the batch bound, the range check, and the
/// delta-basis membership pass (whose touch/insert order the guest
/// mirrors frame by frame) — has already run; what remains is a walk of
/// `fresh` that any thread may execute, and an answer frame
/// ([`SessionMachine::route_answer`]) whose emission order the driver
/// must preserve.
struct RouteWalk {
    session: u32,
    chunk: u32,
    /// Total queries in the frame (fresh + elided).
    n: u32,
    /// Queries elided by the delta basis (0 ⇒ plain `RouteAnswers`).
    n_known: u32,
    /// The queries that actually need walking, in frame order.
    fresh: Vec<(u32, u32)>,
}

impl SessionMachine {
    fn new(state: &HostServeState) -> Self {
        SessionMachine {
            session_id: SESSIONLESS_ID,
            hello_seen: false,
            negotiated: 0,
            queries: 0,
            batches: 0,
            keep_alives: 0,
            answers_elided: 0,
            basis: DeltaBasis::off(),
            cfg_delta: state.cfg.delta_window.min(u32::MAX as usize),
            compute_jobs: 0,
            compute_sharded_batches: 0,
            admitted: false,
            pending_hello: None,
            rotor: None,
            handshake: None,
        }
    }

    /// The serial, frame-order-sensitive half of a `PredictRoute`:
    /// session-id adoption/validation, the batch-size bound, the range
    /// check, and the delta-basis membership pass — everything that
    /// must run on the session's driving thread in frame order for the
    /// guest's mirrored basis to stay in lockstep. Counts the batch
    /// (session + service counters) and returns the pure walk that
    /// remains; `Err` means a protocol violation the session closes
    /// over.
    fn route_serial(
        &mut self,
        state: &HostServeState,
        session: u32,
        chunk: u32,
        mut q: Vec<(u32, u32)>,
    ) -> Result<RouteWalk, ()> {
        if self.pending_hello.is_some() {
            // the reactor intercepts PredictRoute before on_frame, so
            // its queued-hello guard is repeated here: nothing may
            // arrive until the deferred accept has left
            eprintln!(
                "[sbp-serve] PredictRoute while the SessionHello is queued for admission, closing"
            );
            return Err(());
        }
        if session != self.session_id {
            // a hello-less client may still tag its frames with a
            // session id of its choosing (a `PredictSession` that never
            // called `open()`): the first batch fixes the id for
            // attribution. Handshake-gated features (delta suppression,
            // shutdown authority) stay off, and mixing ids afterwards
            // still closes.
            if !self.hello_seen && self.batches == 0 {
                self.session_id = session;
            } else {
                eprintln!(
                    "[sbp-serve] PredictRoute for session {session} on session {}, closing",
                    self.session_id
                );
                return Err(());
            }
        }
        if q.len() > state.cfg.max_batch_queries {
            eprintln!(
                "[sbp-serve] batch of {} queries exceeds the per-session bound {}, closing",
                q.len(),
                state.cfg.max_batch_queries
            );
            return Err(());
        }
        if let Some(delay) = state.cfg.stage_b_delay {
            std::thread::sleep(delay); // test/bench knob only
        }
        // a keyed session's queries carry rotated handle ids on the
        // wire; the true ids come back here, before the range check and
        // the basis pass (both ends key their mirrored bases on true
        // ids — the guest memoizes and mirrors unrotated, and only its
        // outgoing frames pass through the rotor)
        if let Some(rotor) = &self.rotor {
            for key in q.iter_mut() {
                key.1 = rotor.unrotate(key.1);
            }
        }
        // the range check comes before the basis pass: a rejected batch
        // must not have advanced the mirrored basis
        if !state.queries_in_range(&q) {
            eprintln!(
                "[sbp-serve] session {} queried records/handles this \
                 host does not have (misaligned data?), closing",
                self.session_id
            );
            return Err(());
        }
        let n = q.len() as u32;
        let (n_known, fresh) = if self.basis.capacity() > 0 {
            // the membership pass applies the exact frame-order rule
            // the guest's mirrored basis runs (touch, then insert on a
            // miss, in query order — so a within-batch duplicate counts
            // its first occurrence fresh and later ones known, and
            // under LRU both ends refresh and evict the same keys at
            // the same step). The host stores placeholder bits
            // (membership and recency are all it needs — answers are
            // recomputed through the routing cache).
            let mut fresh: Vec<(u32, u32)> = Vec::with_capacity(q.len());
            let mut n_known = 0u32;
            for &key in &q {
                if self.basis.touch(&key).is_some() {
                    n_known += 1;
                } else {
                    self.basis.insert(key, false);
                    fresh.push(key);
                }
            }
            (n_known, fresh)
        } else {
            (0, q)
        };
        state.queries_answered.fetch_add(n as u64, Ordering::Relaxed);
        state.answers_elided.fetch_add(n_known as u64, Ordering::Relaxed);
        self.queries += n as u64;
        self.batches += 1;
        self.answers_elided += n_known as u64;
        Ok(RouteWalk { session, chunk, n, n_known, fresh })
    }

    /// The answer frame for a routed batch: elided queries make it a
    /// `RouteAnswersDelta`; with nothing to elide a plain `RouteAnswers`
    /// is smaller. One rule for both drivers, so the wire cannot drift.
    fn route_answer(session: u32, chunk: u32, n: u32, n_known: u32, bits: Vec<u8>) -> ToGuest {
        if n_known == 0 {
            ToGuest::RouteAnswers { session, chunk, n, bits }
        } else {
            ToGuest::RouteAnswersDelta { session, chunk, n, n_known, bits }
        }
    }

    /// Feed one decoded frame through the protocol. Answers leave
    /// through `send` before this returns, so a driver that calls
    /// `on_frame` in frame arrival order gets answer order for free.
    fn on_frame(
        &mut self,
        state: &HostServeState,
        msg: ToHost,
        send: &mut dyn FnMut(ToGuest),
    ) -> Step {
        if self.pending_hello.is_some() {
            // the hello is still queued for admission: the guest must
            // not send anything until it sees the accept, so any frame
            // here is a protocol violation
            eprintln!(
                "[sbp-serve] {:?} frame while the SessionHello is queued for admission, closing",
                msg.kind()
            );
            self.abandon_admission(state);
            return Step::Close { clean: false };
        }
        match msg {
            ToHost::SessionHello { session_id: sid, protocol } => {
                if self.hello_seen {
                    eprintln!(
                        "[sbp-serve] duplicate SessionHello in session {}, closing",
                        self.session_id
                    );
                    return Step::Close { clean: false };
                }
                // the codec already rejects other versions; keep the
                // check so in-memory links get the same contract. A v6
                // peer may still open a *plain* hello (--secure off):
                // same protocol, unsealed channel.
                if (protocol != SERVE_PROTOCOL_VERSION
                    && protocol != SERVE_PROTOCOL_V5
                    && protocol != SERVE_PROTOCOL_V4
                    && protocol != SERVE_PROTOCOL_V3
                    && protocol != SERVE_PROTOCOL_V2)
                    || sid == SESSIONLESS_ID
                {
                    eprintln!("[sbp-serve] malformed SessionHello, closing");
                    return Step::Close { clean: false };
                }
                // policy gate before admission, so a refused plaintext
                // hello never burns a slot or a queue position
                if state.cfg.secure == SecureMode::Require {
                    eprintln!(
                        "[sbp-serve] plaintext SessionHello under --secure require, closing"
                    );
                    return Step::Close { clean: false };
                }
                // admission (v5): past the concurrency limit the host
                // queues or sheds instead of degrading every admitted
                // session at once
                let verdict = if state.admission.enabled() && state.stop_requested() {
                    state.admission.shed_draining()
                } else {
                    state.admission.try_admit()
                };
                match verdict {
                    Admission::Admit { window } => {
                        self.complete_hello(state, sid, protocol, window, send);
                        Step::Continue
                    }
                    Admission::Queued { ticket } => {
                        // no reply yet: the accept (or a Busy) leaves
                        // when the ticket resolves via poll_admission
                        self.pending_hello =
                            Some(PendingHello { sid, protocol, ticket, pubkey: None });
                        Step::Continue
                    }
                    Admission::Busy { retry_after_ms, reason } => {
                        // only a v5-or-newer guest can decode a Busy
                        // frame; a shed pre-v5 hello is answered by the
                        // close alone (its existing failure path)
                        if protocol >= SERVE_PROTOCOL_V5 {
                            send(ToGuest::Busy { retry_after_ms, reason });
                        }
                        Step::Close { clean: true }
                    }
                }
            }
            ToHost::SessionHelloSecure { session_id: sid, protocol, pubkey } => {
                // the keyed hello must be the session's very first
                // meaningful frame — stricter than the plain arm, which
                // tolerates a legacy client's late hello. The reactor's
                // deferred accept relies on this: an empty pending
                // queue lets the accept emit directly, with the AEAD
                // arming ordered around it.
                if self.hello_seen || self.batches > 0 || self.keep_alives > 0 {
                    eprintln!(
                        "[sbp-serve] late or duplicate SessionHelloSecure in session {}, closing",
                        self.session_id
                    );
                    return Step::Close { clean: false };
                }
                if state.cfg.secure == SecureMode::Off {
                    eprintln!("[sbp-serve] keyed SessionHello under --secure off, closing");
                    return Step::Close { clean: false };
                }
                // the codec already pins protocol == 6 and sid != 0;
                // repeated so in-memory links get the same contract
                if protocol != SERVE_PROTOCOL_VERSION || sid == SESSIONLESS_ID {
                    eprintln!("[sbp-serve] malformed SessionHelloSecure, closing");
                    return Step::Close { clean: false };
                }
                let verdict = if state.admission.enabled() && state.stop_requested() {
                    state.admission.shed_draining()
                } else {
                    state.admission.try_admit()
                };
                match verdict {
                    Admission::Admit { window } => {
                        if self
                            .complete_hello_secure(state, sid, protocol, window, &pubkey)
                            .is_err()
                        {
                            return Step::Close { clean: false };
                        }
                        Step::Continue
                    }
                    Admission::Queued { ticket } => {
                        self.pending_hello =
                            Some(PendingHello { sid, protocol, ticket, pubkey: Some(pubkey) });
                        Step::Continue
                    }
                    Admission::Busy { retry_after_ms, reason } => {
                        // a keyed hello is v6, so the guest decodes the
                        // Busy frame — which stays plaintext, like the
                        // whole pre-handshake control plane
                        send(ToGuest::Busy { retry_after_ms, reason });
                        Step::Close { clean: true }
                    }
                }
            }
            ToHost::PredictRoute { session, chunk, queries: q } => {
                // serial half (id/bounds/range checks + basis pass),
                // then the walk — synchronously here: the threaded
                // engine's Stage B blocks on the (possibly pool-
                // sharded) walk while its Stage A keeps decoding. The
                // reactor intercepts PredictRoute before on_frame and
                // dispatches the walk asynchronously instead.
                let t0 = Instant::now();
                let Ok(walk) = self.route_serial(state, session, chunk, q) else {
                    return Step::Close { clean: false };
                };
                let RouteWalk { session, chunk, n, n_known, fresh } = walk;
                let (bits, shard_jobs) = state.route_bits(fresh);
                if shard_jobs > 0 {
                    self.compute_jobs += shard_jobs;
                    self.compute_sharded_batches += 1;
                }
                send(Self::route_answer(session, chunk, n, n_known, bits));
                state.note_service(t0.elapsed());
                Step::Continue
            }
            ToHost::KeepAlive => {
                self.keep_alives += 1;
                send(ToGuest::Ack);
                Step::Continue
            }
            ToHost::SessionClose { session_id: sid } => {
                if sid == self.session_id {
                    Step::Close { clean: true }
                } else {
                    eprintln!(
                        "[sbp-serve] SessionClose for {sid} on session {}, closing anyway",
                        self.session_id
                    );
                    Step::Close { clean: false }
                }
            }
            ToHost::Shutdown => {
                // administrative wind-down is reserved to *handshaked*
                // sessions (what coordinator::shutdown_predict_hosts
                // opens): a hello-less legacy client's trailing Shutdown
                // — including one on a link that happened to carry zero
                // queries — only ends its own connection, so a plain
                // `sbp predict` can never kill a multi-session server.
                if self.hello_seen {
                    state.request_stop();
                }
                Step::Close { clean: true }
            }
            other => {
                eprintln!(
                    "[sbp-serve] unexpected {:?} message in serving session, closing",
                    other.kind()
                );
                Step::Close { clean: false }
            }
        }
    }

    /// Finish an admitted handshake: adopt the id, negotiate the
    /// version down for legacy peers, build the delta basis, and send
    /// the accept announcing `window` — the admission controller's
    /// current (possibly retuned-down) pipeline window, not the static
    /// config knob.
    fn complete_hello(
        &mut self,
        state: &HostServeState,
        sid: u32,
        protocol: u32,
        window: u32,
        send: &mut dyn FnMut(ToGuest),
    ) {
        self.admitted = true;
        self.hello_seen = true;
        self.session_id = sid;
        // negotiate down for legacy peers: a v2 session runs a
        // frozen basis and receives the bare 12-byte accept
        // (the codec elides the v3 extension when the
        // negotiated version says so); v3 keeps the full delta
        // machinery and only lacks resumption, v4 only lacks Busy
        self.negotiated = protocol.min(SERVE_PROTOCOL_VERSION);
        let evict = if self.negotiated >= SERVE_PROTOCOL_V3 {
            state.cfg.basis_evict
        } else {
            BasisEvict::Freeze
        };
        self.basis = DeltaBasis::new(self.cfg_delta, evict);
        send(ToGuest::SessionAccept {
            session_id: sid,
            max_inflight: window,
            delta_window: self.cfg_delta as u32,
            protocol: self.negotiated,
            basis_evict: evict,
        });
    }

    /// Finish an admitted **keyed** handshake (protocol v6): generate
    /// an ephemeral X25519 keypair, derive the per-direction AEAD keys
    /// and the handle rotor from the shared secret, and *stage* the
    /// [`ToGuest::SessionAcceptSecure`] for the driver instead of
    /// sending it — only the driver can order the transport arming
    /// around the accept (receive direction sealed before it leaves,
    /// send direction after; the accept itself is plaintext). `Err`
    /// means the client's public key produced the all-zero shared
    /// secret (a small-order point an active adversary could use to
    /// force a known key): the session closes rather than run on it.
    fn complete_hello_secure(
        &mut self,
        state: &HostServeState,
        sid: u32,
        protocol: u32,
        window: u32,
        guest_pk: &[u8; PUBKEY_LEN],
    ) -> Result<(), ()> {
        let mut rng = ChaCha20Rng::from_os_entropy();
        let (sk, host_pk) = keypair(&mut rng);
        let Some(shared) = shared_secret(&sk, guest_pk) else {
            eprintln!("[sbp-serve] degenerate client public key in keyed hello, closing");
            return Err(());
        };
        let keys = derive_session_keys(&shared);
        self.admitted = true;
        self.hello_seen = true;
        self.session_id = sid;
        // a keyed hello is v6 by construction — nothing to negotiate
        // down, the full delta machinery is on
        self.negotiated = protocol.min(SERVE_PROTOCOL_VERSION);
        let evict = state.cfg.basis_evict;
        self.basis = DeltaBasis::new(self.cfg_delta, evict);
        // the rotor survives resumption (the guest's memoized routes
        // rotate under it for the whole session); only the AEAD keys
        // are per connection — a resume re-keys, the rotor stays
        if self.rotor.is_none() {
            self.rotor = Some(HandleRotor::new(keys.rotor_seed));
        }
        self.handshake = Some((
            ToGuest::SessionAcceptSecure {
                session_id: sid,
                max_inflight: window,
                delta_window: self.cfg_delta as u32,
                protocol: self.negotiated,
                basis_evict: evict,
                pubkey: host_pk,
            },
            keys,
        ));
        Ok(())
    }

    /// Take the keyed handshake staged by the last fed frame, if one
    /// completed: the driver must arm its receive direction, emit the
    /// accept (plaintext), then arm its send direction — in that order.
    fn take_handshake(&mut self) -> Option<(ToGuest, SessionKeys)> {
        self.handshake.take()
    }

    /// Is this session's hello still parked in the admission queue?
    /// While it is, the driver polls [`Self::poll_admission`] instead
    /// of letting the idle clock run against a guest that is only
    /// waiting on *us*.
    fn pending_hello_active(&self) -> bool {
        self.pending_hello.is_some()
    }

    /// Poll a queued hello's admission ticket: on a freed slot the
    /// deferred accept finally leaves, on deadline expiry the session
    /// is shed exactly as an immediate shed would have been.
    fn poll_admission(&mut self, state: &HostServeState, send: &mut dyn FnMut(ToGuest)) -> Step {
        let Some(ph) = self.pending_hello else {
            return Step::Continue;
        };
        match state.admission.poll_ticket(ph.ticket) {
            TicketPoll::Pending => Step::Continue,
            TicketPoll::Admit { window } => {
                self.pending_hello = None;
                match ph.pubkey {
                    Some(pk) => {
                        // a queued keyed hello resolves like an
                        // immediate admit: the accept is staged and the
                        // driver arms around it
                        if self
                            .complete_hello_secure(state, ph.sid, ph.protocol, window, &pk)
                            .is_err()
                        {
                            return Step::Close { clean: false };
                        }
                    }
                    None => self.complete_hello(state, ph.sid, ph.protocol, window, send),
                }
                Step::Continue
            }
            TicketPoll::Expired { retry_after_ms } => {
                self.pending_hello = None;
                if ph.protocol >= SERVE_PROTOCOL_V5 {
                    send(ToGuest::Busy { retry_after_ms, reason: BusyReason::QueueExpired });
                }
                Step::Close { clean: true }
            }
        }
    }

    /// Give back this session's admission slot (no-op unless held).
    /// Called at session end *and* at park — a parked session consumes
    /// no serving capacity, so its slot frees for new hellos during the
    /// outage; a resume re-acquires by force.
    fn admission_release(&mut self, state: &HostServeState) {
        if self.admitted {
            self.admitted = false;
            state.admission.release();
        }
    }

    /// Session is over: release the slot if admitted, cancel the queue
    /// ticket if the hello never resolved (connection died while
    /// queued).
    fn abandon_admission(&mut self, state: &HostServeState) {
        if let Some(ph) = self.pending_hello.take() {
            state.admission.cancel_ticket(ph.ticket);
        }
        self.admission_release(state);
    }

    /// Assemble the session's [`SessionOutcome`]. Pipeline metrics
    /// (ring occupancy, decode stall, compute idle) belong to the
    /// *driver*, not the protocol — the threaded engine measures its
    /// ring, the reactor has none and passes zeros.
    fn outcome(
        &self,
        clean_close: bool,
        idle_reaped: bool,
        wall_seconds: f64,
        ring_high_water: usize,
        decode_stall_seconds: f64,
        compute_idle_seconds: f64,
    ) -> SessionOutcome {
        SessionOutcome {
            session_id: self.session_id,
            queries: self.queries,
            batches: self.batches,
            keep_alives: self.keep_alives,
            answers_elided: self.answers_elided,
            clean_close,
            idle_reaped,
            wall_seconds,
            protocol: self.negotiated,
            secure: self.rotor.is_some(),
            basis_evict: self.basis.mode(),
            ring_high_water,
            decode_stall_seconds,
            compute_idle_seconds,
            compute_jobs: self.compute_jobs,
            shards_per_batch: if self.compute_sharded_batches == 0 {
                0.0
            } else {
                self.compute_jobs as f64 / self.compute_sharded_batches as f64
            },
        }
    }
}

/// Serve one guest session over `link` until it closes: the per-session
/// engine of the long-lived inference service, run as a **2-stage
/// pipeline**. Transport-agnostic — tests and in-memory sessions run it
/// over channel links; the TCP serve loop instead runs the same
/// [`SessionMachine`] inside its sharded reactor.
///
/// **Stage A** (a per-session decode thread) reads and decodes frame
/// `k+1` from the transport while **Stage B** (the calling thread — the
/// compute stage) runs `route_bits`/cache/delta for frame `k`; the two
/// are joined by a bounded SPSC ring of [`ServeConfig::max_inflight`]
/// decoded frames, so per-session memory stays O(`max_inflight`)
/// batches and the host's CPU overlaps its socket I/O exactly the way
/// the pipelined guest overlaps encode with RTT. Stage B is the
/// **only** sender and consumes the ring FIFO, so answers still leave
/// in frame order — the ordering contract every guest relies on. When
/// compute falls behind, Stage A blocks on the full ring (counted as
/// [`SessionOutcome::decode_stall_seconds`]) and stops reading the
/// transport — the same socket-level backpressure the unpipelined host
/// applied.
///
/// Protocol: an optional `SessionHello` (answered with `SessionAccept`)
/// fixes the session id and negotiates the serve-protocol version — a
/// v3 hello gets the extended accept announcing the [`BasisEvict`]
/// policy, a v2 hello is negotiated down (12-byte accept, frozen
/// basis). Every subsequent `PredictRoute` must carry that id. A
/// hello-less session is the legacy single-shot flow and runs under
/// [`SESSIONLESS_ID`]. Any protocol violation — double hello, wrong
/// session id, oversized batch, a training-phase message — closes the
/// session (never the whole server) rather than answering wrong.
pub fn serve_session<T: HostTransport + Send + Sync + 'static>(
    state: &HostServeState,
    link: T,
) -> SessionOutcome {
    let t0 = std::time::Instant::now();
    let link = Arc::new(link);
    let ring_cap = state.cfg.max_inflight.max(1) as usize;
    // the SPSC ring joining the stages. The channel holds ring_cap − 1
    // frames and Stage A holds one more in hand (a rendezvous channel
    // when ring_cap is 1), so decoded-but-unanswered frames in host
    // memory never exceed ring_cap = max_inflight. `ring_depth` counts
    // exactly those frames: incremented by Stage A *before* the send
    // (so the matching decrement can never land first and underflow),
    // decremented by Stage B after the recv.
    let (ring_tx, ring_rx) = std::sync::mpsc::sync_channel::<ToHost>(ring_cap - 1);
    let ring_depth = Arc::new(AtomicUsize::new(0));
    let ring_high = Arc::new(AtomicUsize::new(0));
    let decode_stall_nanos = Arc::new(AtomicU64::new(0));

    // ---- Stage A: the socket/decode thread. Owns the transport's
    // receive direction; detached because it may sit blocked in a
    // transport read after Stage B has already ended the session —
    // Stage B then shuts the receive direction down (TCP), or the
    // guest's link drop ends it (in-memory), and the thread exits on
    // its own.
    {
        let link = Arc::clone(&link);
        let depth = Arc::clone(&ring_depth);
        let high = Arc::clone(&ring_high);
        let stall = Arc::clone(&decode_stall_nanos);
        std::thread::Builder::new()
            .name("sbp-serve-decode".into())
            .spawn(move || {
                while let Some(msg) = link.recv() {
                    // `d` may transiently read ring_cap+1: a blocked
                    // send completes the moment Stage B pops a frame,
                    // and B's matching fetch_sub can land after A's
                    // next fetch_add. In that window the popped frame
                    // is no longer *awaiting* compute, so the true
                    // awaiting count is ≤ ring_cap — clamp what the
                    // high-water records to keep the metric honest.
                    let d = depth.fetch_add(1, Ordering::SeqCst) + 1;
                    high.fetch_max(d.min(ring_cap), Ordering::Relaxed);
                    // a send at full depth blocks until compute drains a
                    // slot; time that block — it is the pipeline's
                    // backpressure stall
                    let wait0 = (d >= ring_cap).then(std::time::Instant::now);
                    if ring_tx.send(msg).is_err() {
                        break; // Stage B ended the session
                    }
                    if let Some(w) = wait0 {
                        stall.fetch_add(w.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                }
                // dropping ring_tx is Stage B's end-of-stream signal
            })
            .expect("spawn serve decode thread");
    }

    // ---- Stage B: the compute stage — drives the shared protocol
    // machine over the ring, preserving frame order. The optional idle
    // deadline rides on `recv_timeout`: a whole window with no decoded
    // frame at all (the guest sent neither a batch nor a KeepAlive)
    // means the peer is presumed dead and the session is reaped — the
    // blocking engine's equivalent of the reactor's per-sweep check.
    let mut machine = SessionMachine::new(state);
    let mut clean_close = false;
    let mut idle_reaped = false;
    let mut compute_idle = Duration::ZERO;
    let idle_timeout = state.cfg.session_idle_timeout;
    loop {
        state.admission_retune();
        if machine.pending_hello_active() {
            // the hello is parked in the admission queue: poll the
            // ticket at queue granularity instead of blocking a whole
            // idle window — the guest is waiting on *us*, so the
            // dead-peer clock does not run (the queue deadline bounds
            // this state instead)
            let step = machine.poll_admission(state, &mut |m| link.send(m));
            if let Some((accept, keys)) = machine.take_handshake() {
                // a queued keyed hello just admitted: arm the receive
                // direction before the accept leaves (the guest seals
                // from the accept on), send the plaintext accept, then
                // arm the send direction
                link.set_secure_rx(keys.guest_to_host);
                link.send(accept);
                link.set_secure_tx(keys.host_to_guest);
            }
            if let Step::Close { clean } = step {
                clean_close = clean;
                break;
            }
            if machine.pending_hello_active() {
                // sleep only as long as the verdict can possibly take:
                // the earlier of the ticket's queue deadline and the
                // next AIMD retune boundary, instead of a fixed 1 ms
                // spin that woke a queued hello a thousand times a
                // second on an otherwise quiet host
                let tick = machine
                    .pending_hello
                    .map(|ph| state.admission.poll_wait_hint(ph.ticket))
                    .unwrap_or(ADMISSION_POLL_TICK);
                match ring_rx.recv_timeout(tick) {
                    Ok(_) => {
                        // any frame before the queued hello resolves is
                        // a protocol violation — on_frame's guard would
                        // say the same; close without feeding it
                        eprintln!(
                            "[sbp-serve] frame while the SessionHello is queued for \
                             admission, closing"
                        );
                        ring_depth.fetch_sub(1, Ordering::SeqCst);
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            continue;
        }
        let idle0 = Instant::now();
        let msg = if idle_timeout.is_zero() {
            match ring_rx.recv() {
                Ok(msg) => msg,
                // transport closed: Stage A dropped its ring end
                Err(_) => break,
            }
        } else {
            match ring_rx.recv_timeout(idle_timeout) {
                Ok(msg) => msg,
                Err(RecvTimeoutError::Timeout) => {
                    eprintln!(
                        "[sbp-serve] session {} idle past {:?} with no keep-alive, reaping",
                        machine.session_id, idle_timeout
                    );
                    idle_reaped = true;
                    break;
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        };
        compute_idle += idle0.elapsed();
        ring_depth.fetch_sub(1, Ordering::SeqCst);
        let step = machine.on_frame(state, msg, &mut |m| link.send(m));
        if let Some((accept, keys)) = machine.take_handshake() {
            // keyed handshake completed on this frame: rx seals before
            // the accept leaves, tx after — the accept itself (like
            // every pre-handshake frame) is plaintext. Arming rx here
            // is race-free even against Stage A mid-read: the guest
            // only seals after it has *received* the accept, which
            // cannot leave before the rx direction is armed.
            link.set_secure_rx(keys.guest_to_host);
            link.send(accept);
            link.set_secure_tx(keys.host_to_guest);
        }
        if let Step::Close { clean } = step {
            clean_close = clean;
            break;
        }
    }
    // the slot frees (or the ticket cancels) exactly once, however the
    // session ended
    machine.abandon_admission(state);
    // end the receive direction so a Stage-A thread still blocked in a
    // transport read exits promptly (answers already sent precede the
    // FIN — write_frame flushes per frame)
    link.shutdown();
    let outcome = machine.outcome(
        clean_close,
        idle_reaped,
        t0.elapsed().as_secs_f64(),
        ring_high.load(Ordering::Relaxed),
        decode_stall_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        compute_idle.as_secs_f64(),
    );
    state.ring_high_water.fetch_max(outcome.ring_high_water, Ordering::Relaxed);
    state
        .decode_stall_nanos
        .fetch_add(decode_stall_nanos.load(Ordering::Relaxed), Ordering::Relaxed);
    if idle_reaped {
        state.sessions_idle_reaped.fetch_add(1, Ordering::Relaxed);
    }
    if !outcome.is_control_only() {
        state.sessions_served.fetch_add(1, Ordering::Relaxed);
    }
    outcome
}

/// Spawn an in-process serving session thread over any owned host
/// transport (the in-memory analogue of one accepted TCP session).
pub fn spawn_serve_session<T: HostTransport + Send + Sync + 'static>(
    state: Arc<HostServeState>,
    link: T,
) -> std::thread::JoinHandle<SessionOutcome> {
    std::thread::Builder::new()
        .name("sbp-serve-session".into())
        .spawn(move || serve_session(&state, link))
        .expect("spawn serve session thread")
}

/// One served session as seen by the host process: its outcome, peer
/// address, and exact per-session wire traffic.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// What the session did.
    pub outcome: SessionOutcome,
    /// Peer address of the guest connection.
    pub peer: String,
    /// Exact serialized wire traffic of this session alone.
    pub comm: NetSnapshot,
}

/// How many per-session reports a serve loop retains in memory. An
/// unlimited (`max_sessions = 0`) server runs indefinitely; the
/// aggregate traffic stays exact forever, but individual
/// [`SessionReport`]s beyond this many are dropped oldest-first
/// (counted in [`ServeLoopReport::sessions_dropped`]).
pub const RETAINED_SESSION_REPORTS: usize = 4096;

/// Bounded-memory outcome of a completed serve loop.
#[derive(Debug, Default)]
pub struct ServeLoopReport {
    /// The most recent per-session reports, in completion order (at
    /// most [`RETAINED_SESSION_REPORTS`]); control-only connections are
    /// excluded.
    pub sessions: Vec<SessionReport>,
    /// Exact aggregate wire traffic across **all** served sessions,
    /// including any whose individual reports were dropped.
    pub comm: NetSnapshot,
    /// Per-session reports dropped after the retention cap was hit.
    pub sessions_dropped: u64,
    /// Reactor worker threads the loop ran ([`ServeConfig::workers`],
    /// resolved: 0 became the CPU count).
    pub workers: usize,
    /// Per-worker peak concurrent sessions — the shard occupancy
    /// high-water of each reactor worker, indexed by worker. Their sum
    /// bounds (and under all-concurrent load equals) the loop's peak
    /// concurrent sessions; the spread shows how evenly least-occupied
    /// dispatch balanced the shards.
    pub worker_peak_sessions: Vec<usize>,
    /// Transient accept errors (fd exhaustion, aborted handshakes)
    /// survived with backoff instead of winding the service down.
    pub accept_retries: u64,
    /// Hellos refused with [`ToGuest::Busy`] by the v5 admission
    /// controller (immediate sheds + queue expiries). Zero when
    /// admission is off.
    pub sessions_shed: u64,
    /// Hellos that waited in the admission queue before resolving.
    pub sessions_queued: u64,
    /// Total seconds hellos spent in the admission queue.
    pub admission_queue_wait_seconds: f64,
    /// Admission retunes that changed the advertised `max_inflight`
    /// window.
    pub window_retunes: u64,
}

struct LoopAccum {
    sessions: Vec<SessionReport>,
    comm: NetSnapshot,
    dropped: u64,
}

/// Where the serve loop's connections come from: a [`TcpListener`] in
/// production, injectable fakes in tests (e.g. a listener that fails
/// its first accepts with `EMFILE` to exercise the backoff path).
pub trait AcceptSource: Sync {
    /// Accept the next inbound connection (blocking).
    fn accept(&self) -> std::io::Result<(TcpStream, SocketAddr)>;
    /// The bound local address (aims the wake-up self-connection).
    fn local_addr(&self) -> std::io::Result<SocketAddr>;
}

impl AcceptSource for TcpListener {
    fn accept(&self) -> std::io::Result<(TcpStream, SocketAddr)> {
        TcpListener::accept(self)
    }
    fn local_addr(&self) -> std::io::Result<SocketAddr> {
        TcpListener::local_addr(self)
    }
}

/// Accept guest connections on `listener` and serve them on a **sharded
/// reactor** until `max_sessions` *serving* sessions have **completed**
/// (0 = unlimited) or a handshaked session requests shutdown
/// ([`ToHost::Shutdown`] after a hello →
/// [`HostServeState::request_stop`]). Control-only connections (stray
/// probes, the administrative stop connection) consume no session
/// budget and produce no report.
///
/// This is the body of the looping `sbp serve-predict` subcommand: one
/// host process, many concurrent guest sessions, one shared model share
/// and routing cache. [`ServeConfig::workers`] reactor threads each own
/// a shard of the live sessions as non-blocking state machines
/// ([`SessionMachine`] over [`NbConn`]); the accept loop dispatches
/// each connection to the least-occupied shard. Host thread count is
/// `workers + 1`, independent of session count — the previous
/// architecture's two threads *per session* are gone, which is what
/// lets one process hold thousands of concurrent sessions.
///
/// **Ordering guarantee:** a session lives on exactly one worker for
/// its whole life, and that worker decodes its frames in arrival order
/// and queues each answer before decoding the next frame, so answers
/// leave per link in frame order — serve protocol v3 stays
/// byte-identical on the wire to the threaded [`serve_session`] engine
/// (asserted end-to-end by `tests/serve_soak.rs`).
///
/// Liveness: sessions idle past [`ServeConfig::session_idle_timeout`]
/// are reaped (dead-peer defense); transient accept errors (`EMFILE`,
/// `ECONNABORTED`…) are retried with capped backoff instead of
/// draining the service; a non-transient accept error stops accepting
/// but still drains resident sessions. Per-session reports are capped
/// ([`RETAINED_SESSION_REPORTS`]), so an unlimited server's memory is
/// bounded by its *concurrent* sessions, not its lifetime. Shutdown
/// requests and budget exhaustion wake the accept loop with a loopback
/// self-connection, so it reacts promptly even with no client traffic.
pub fn serve_predict_loop(
    listener: &TcpListener,
    state: &Arc<HostServeState>,
    max_sessions: usize,
) -> std::io::Result<ServeLoopReport> {
    serve_predict_loop_on(listener, state, max_sessions)
}

/// [`serve_predict_loop`] over any [`AcceptSource`] — the actual
/// reactor body, generic so tests can inject erroring listeners.
pub fn serve_predict_loop_on<A: AcceptSource>(
    listener: &A,
    state: &Arc<HostServeState>,
    max_sessions: usize,
) -> std::io::Result<ServeLoopReport> {
    let local = listener.local_addr()?;
    // the wake-up self-connection must target a routable address even
    // when the listener is bound to the unspecified address (0.0.0.0 /
    // ::), so rewrite those to the loopback of the same family
    let wake_ip = match local.ip() {
        std::net::IpAddr::V4(ip) if ip.is_unspecified() => {
            std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
        }
        std::net::IpAddr::V6(ip) if ip.is_unspecified() => {
            std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
        }
        ip => ip,
    };
    let wake = SocketAddr::new(wake_ip, local.port());
    let workers = if state.cfg.workers > 0 {
        state.cfg.workers
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    let accum: Arc<Mutex<LoopAccum>> = Arc::new(Mutex::new(LoopAccum {
        sessions: Vec::new(),
        comm: NetSnapshot::default(),
        dropped: 0,
    }));
    // per-shard occupancy, maintained by the dispatcher (+1 on dispatch)
    // and the workers (−1 on session end) — the dispatch key
    let occupancy: Arc<Vec<AtomicUsize>> =
        Arc::new((0..workers).map(|_| AtomicUsize::new(0)).collect());
    let mut senders = Vec::with_capacity(workers);
    let mut worker_handles = Vec::with_capacity(workers);
    for w in 0..workers {
        let (tx, rx) = std::sync::mpsc::channel::<(TcpStream, SocketAddr)>();
        senders.push(tx);
        let st = Arc::clone(state);
        let occ = Arc::clone(&occupancy);
        let sink = Arc::clone(&accum);
        let handle = std::thread::Builder::new()
            .name(format!("sbp-serve-worker-{w}"))
            .spawn(move || reactor_worker(st, rx, occ, w, sink, wake, max_sessions))
            .expect("spawn serve worker thread");
        worker_handles.push(handle);
    }
    let mut accept_retries = 0u64;
    let mut backoff = Duration::from_millis(1);
    while !state.stop_requested() && !budget_met(state, max_sessions) {
        let (stream, peer) = match listener.accept() {
            Ok(conn) => conn,
            Err(e) if accept_error_is_transient(&e) => {
                // one fd spike or aborted handshake must not wind the
                // whole service down: log, back off (capped), retry —
                // a reset backoff after any success keeps the common
                // case latency-free
                accept_retries += 1;
                eprintln!("[sbp-serve] transient accept error ({e}), retrying in {backoff:?}");
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(100));
                continue;
            }
            Err(e) => {
                // never abandon in-flight sessions over an accept error:
                // stop accepting, drain below
                eprintln!("[sbp-serve] accept failed, draining sessions: {e}");
                break;
            }
        };
        backoff = Duration::from_millis(1);
        if state.stop_requested() || budget_met(state, max_sessions) {
            break; // the wake-up connection (or a late arrival) — drop it
        }
        // dispatch to the least-occupied shard; occupancy is bumped
        // here rather than at adoption so a burst of accepts spreads
        // evenly even before any worker has polled its inbox
        let w = least_occupied(&occupancy);
        occupancy[w].fetch_add(1, Ordering::SeqCst);
        if senders[w].send((stream, peer)).is_err() {
            occupancy[w].fetch_sub(1, Ordering::SeqCst);
        }
    }
    // dropping the inbox senders is the workers' drain signal: finish
    // the sessions already resident, then exit
    drop(senders);
    let mut worker_peak_sessions = Vec::with_capacity(workers);
    for h in worker_handles {
        worker_peak_sessions.push(h.join().map(|s| s.peak_sessions).unwrap_or(0));
    }
    // sessions still parked when the loop drains can never resume —
    // report each exactly once, like any other session
    let leftover: Vec<ParkedSession> = {
        let mut map = state.parked_lock();
        map.drain().map(|(_, p)| p).collect()
    };
    for p in leftover {
        eprintln!(
            "[sbp-serve] session {} still parked at loop drain, giving it up",
            p.machine.session_id
        );
        expire_parked(state, p, &accum, wake, max_sessions);
    }
    let accum = Arc::try_unwrap(accum)
        .map(|m| m.into_inner().unwrap_or_else(|e| e.into_inner()))
        .unwrap_or_else(|_| LoopAccum {
            sessions: Vec::new(),
            comm: NetSnapshot::default(),
            dropped: 0,
        });
    let adm = state.admission_stats();
    Ok(ServeLoopReport {
        sessions: accum.sessions,
        comm: accum.comm,
        sessions_dropped: accum.dropped,
        workers,
        worker_peak_sessions,
        accept_retries,
        sessions_shed: adm.sessions_shed,
        sessions_queued: adm.sessions_queued,
        admission_queue_wait_seconds: adm.queue_wait_seconds,
        window_retunes: adm.window_retunes,
    })
}

/// The shard index with the fewest live-or-dispatched sessions.
fn least_occupied(occupancy: &[AtomicUsize]) -> usize {
    let mut best = 0usize;
    let mut best_n = usize::MAX;
    for (i, o) in occupancy.iter().enumerate() {
        let n = o.load(Ordering::SeqCst);
        if n < best_n {
            best = i;
            best_n = n;
        }
    }
    best
}

/// Accept errors worth retrying: resource pressure (`EMFILE`/`ENFILE`)
/// and per-connection failures (the peer aborted its own handshake) —
/// conditions that clear on their own, unlike a dead listener fd.
/// Checked by raw errno for the fd-exhaustion pair because std has no
/// stable `ErrorKind` for them.
fn accept_error_is_transient(e: &std::io::Error) -> bool {
    if matches!(e.raw_os_error(), Some(23) | Some(24)) {
        return true; // ENFILE / EMFILE
    }
    matches!(
        e.kind(),
        std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::Interrupted
            | std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
    )
}

/// What one reactor worker reports when it drains.
struct WorkerStats {
    /// Peak concurrent sessions resident on this shard.
    peak_sessions: usize,
}

/// One live session on a reactor worker: its non-blocking connection,
/// the shared protocol machine, and per-session accounting state.
struct NbSession {
    conn: NbConn,
    peer: SocketAddr,
    machine: SessionMachine,
    counters: NetCounters,
    t0: Instant,
    /// Last time a complete frame arrived (or queued answers flushed) —
    /// the idle-reap clock.
    last_activity: Instant,
    /// `Some(clean)` once the session has ended and only its write
    /// backlog remains to drain.
    closing: Option<bool>,
    idle_reaped: bool,
    /// The close was transport-level (FIN, reset, torn frame) rather
    /// than a protocol decision — the only kind of death a session may
    /// be parked over: a protocol violation is final.
    parkable: bool,
    /// Answer frames (`RouteAnswers`/`RouteAnswersDelta`) sent on this
    /// session so far — the host side of the resume cursor.
    answers_sent: u64,
    /// Keys inserted into the session's delta basis so far (mirrored
    /// exactly by the guest) — the desync cross-check
    /// [`ToGuest::ResumeAccept`] carries as `basis_epoch`.
    basis_inserts: u64,
    /// Un-acknowledged answer frames, verbatim, for replay on resume
    /// (bounded by [`replay_retain_cap`]; empty unless the session is
    /// v4 and [`ServeConfig::resume_window`] is on).
    replay: std::collections::VecDeque<ReplayEntry>,
    /// Times this session has resumed across connections.
    resumes: u32,
    /// Answers not yet emitted, in frame order — the Stage C
    /// re-sequencing queue. Every answer (computed inline or fanned
    /// out) passes through here, so a batch whose walk is still on the
    /// pool holds back every answer behind it: emission order equals
    /// frame order *by construction*, whatever finishes first. The
    /// sweep drains the front as entries complete; encoding, byte
    /// accounting, and replay bookkeeping all happen at emission time,
    /// exactly as the inline path did.
    pending: VecDeque<PendingAnswer>,
}

/// One entry of a session's answer re-sequencing queue.
enum PendingAnswer {
    /// Ready to emit (inline answers, accepts, acks).
    Ready(ToGuest),
    /// A batch whose walk is out on the compute pool.
    Compute(PendingCompute),
}

/// An in-flight Stage C batch: the frame header, the serial residue of
/// its cache lookup pass, and the shard slots its pool jobs fill in.
struct PendingCompute {
    session: u32,
    chunk: u32,
    n: u32,
    n_known: u32,
    plan: RoutePlan,
    /// The walk list (shared with the shard jobs; the store pass needs
    /// the keys again at emission time).
    keys: Arc<Vec<(u32, u32)>>,
    shards: Arc<ShardResults>,
    /// When the batch's frame entered the serial pass — emission closes
    /// the admission limiter's service-latency clock.
    started: Instant,
}

/// Shared result slots of one sharded walk. Jobs fill their slot and
/// count down `remaining`; the sweep thread polls `remaining` and
/// concatenates the slots — 8-query-aligned shards make that
/// concatenation byte-exact — once it reaches zero.
struct ShardResults {
    slots: Vec<OnceLock<Vec<u8>>>,
    remaining: AtomicUsize,
}

/// Context one reactor worker shares across every session of its shard:
/// the wire suite for ct-free serving frames — the same fixed plain
/// suite [`super::tcp::TcpHostTransport`]'s send path falls back to, so
/// byte accounting matches the threaded host exactly — and one reusable
/// encode scratch buffer, the per-worker replacement for the threaded
/// engine's per-session decode thread + ring.
struct WorkerCtx {
    suite: CipherSuite,
    ct_len: usize,
    scratch: Vec<u8>,
}

/// Soft cap on one session's unflushed write backlog: past this the
/// worker stops *reading* that session's frames until the kernel drains
/// answers, so a guest that never reads cannot grow host memory —
/// the reactor's analogue of the blocking engine's socket-level
/// backpressure.
const WRITE_SOFT_LIMIT: usize = 1 << 20;

/// How long a worker parks when a full sweep over its shard made no
/// progress (no frame, no flushed byte, no new connection). Counted in
/// [`HostServeState::poll_stall_seconds`].
const POLL_PARK: Duration = Duration::from_micros(200);

/// Fallback poll cadence for a hello parked in the admission queue.
/// The threaded engine normally sleeps the controller's
/// [`AdmissionController::poll_wait_hint`] — until the earlier of the
/// ticket's queue deadline and the next AIMD retune boundary — and only
/// falls back to this fixed tick if the ticket vanished underneath it;
/// the reactor polls at sweep cadence and needs neither.
const ADMISSION_POLL_TICK: Duration = Duration::from_millis(1);

/// Consecutive progress-free sweeps before a worker parks: a few hot
/// spins ride out the sub-microsecond gap between back-to-back frames
/// of a pipelined guest without paying the park latency.
const PARK_AFTER_IDLE_SWEEPS: u32 = 16;

/// One reactor worker: owns a shard of sessions, sweeping each
/// non-blocking connection for readable frames, feeding them through
/// the shared [`SessionMachine`] in arrival order, and flushing queued
/// answers — all on this one thread, which is the entire ordering
/// argument. New connections arrive over `inbox`; the inbox closing is
/// the drain signal.
fn reactor_worker(
    state: Arc<HostServeState>,
    inbox: Receiver<(TcpStream, SocketAddr)>,
    occupancy: Arc<Vec<AtomicUsize>>,
    slot: usize,
    accum: Arc<Mutex<LoopAccum>>,
    wake: SocketAddr,
    max_sessions: usize,
) -> WorkerStats {
    let suite = CipherSuite::new_plain(64);
    let ct_len = suite.ct_byte_len();
    let mut ctx = WorkerCtx { suite, ct_len, scratch: Vec::new() };
    let mut sessions: Vec<NbSession> = Vec::new();
    let mut inbox_open = true;
    let mut idle_sweeps = 0u32;
    let mut peak = 0usize;
    let idle_timeout = state.cfg.session_idle_timeout;
    loop {
        // adopt newly dispatched connections without blocking
        while inbox_open {
            match inbox.try_recv() {
                Ok((stream, peer)) => {
                    if let Some(sess) = adopt_conn(&state, stream, peer) {
                        sessions.push(sess);
                    } else {
                        occupancy[slot].fetch_sub(1, Ordering::SeqCst);
                    }
                    idle_sweeps = 0;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => inbox_open = false,
            }
        }
        // parked sessions age on their own clock (the resume window),
        // swept opportunistically by whichever worker gets here first —
        // before the empty-shard branch, so a fully idle service still
        // expires its parked sessions
        sweep_parked(&state, &accum, wake, max_sessions);
        // opportunistic AIMD retune (internally rate-limited): any
        // worker's sweep cadence is more than fine-grained enough
        state.admission_retune();
        peak = peak.max(sessions.len());
        if sessions.is_empty() {
            if !inbox_open {
                break; // drained: no sessions, no more connections
            }
            // idle shard: block on the inbox instead of spinning (the
            // timeout keeps the drain signal prompt)
            match inbox.recv_timeout(Duration::from_millis(20)) {
                Ok((stream, peer)) => {
                    if let Some(sess) = adopt_conn(&state, stream, peer) {
                        sessions.push(sess);
                    } else {
                        occupancy[slot].fetch_sub(1, Ordering::SeqCst);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => inbox_open = false,
            }
            continue;
        }
        // sweep every session once; finished ones leave the shard
        let mut progress = false;
        let now = Instant::now();
        let mut i = 0usize;
        while i < sessions.len() {
            let finished =
                sweep_session(&state, &mut sessions[i], &mut ctx, now, idle_timeout, &mut progress);
            if finished {
                let sess = sessions.swap_remove(i);
                finalize_session(&state, sess, &accum, wake, max_sessions);
                occupancy[slot].fetch_sub(1, Ordering::SeqCst);
                progress = true;
            } else {
                i += 1;
            }
        }
        if progress {
            idle_sweeps = 0;
        } else {
            idle_sweeps += 1;
            if idle_sweeps >= PARK_AFTER_IDLE_SWEEPS {
                // nothing readable anywhere on the shard: park briefly.
                // This is the reactor's poll stall — one sleeping thread
                // per *worker*, where the old host parked one blocked
                // read per *session*.
                std::thread::sleep(POLL_PARK);
                state
                    .poll_stall_nanos
                    .fetch_add(POLL_PARK.as_nanos() as u64, Ordering::Relaxed);
            }
        }
    }
    WorkerStats { peak_sessions: peak }
}

/// Wrap an accepted socket as a shard session (non-blocking mode on).
fn adopt_conn(state: &HostServeState, stream: TcpStream, peer: SocketAddr) -> Option<NbSession> {
    match NbConn::new(stream) {
        Ok(conn) => {
            let now = Instant::now();
            Some(NbSession {
                conn,
                peer,
                machine: SessionMachine::new(state),
                counters: NetCounters::default(),
                t0: now,
                last_activity: now,
                closing: None,
                idle_reaped: false,
                parkable: false,
                answers_sent: 0,
                basis_inserts: 0,
                replay: std::collections::VecDeque::new(),
                resumes: 0,
                pending: VecDeque::new(),
            })
        }
        Err(e) => {
            eprintln!("[sbp-serve] failed to adopt connection from {peer}: {e}");
            None
        }
    }
}

/// One readiness sweep over one session: emit answers whose Stage C
/// walks have completed, flush what the kernel will take, drain every
/// frame the socket already holds through the protocol machine (in
/// arrival order, answers re-sequenced FIFO through the pending queue),
/// then check the idle deadline. Returns `true` when the session is
/// over *and* its final answers have left — the caller then finalizes
/// it. A session never finishes (and so never parks) while an answer is
/// still pending: the resume cursor and replay buffer only see emitted
/// frames, so every completion path below waits for the drain.
fn sweep_session(
    state: &Arc<HostServeState>,
    sess: &mut NbSession,
    ctx: &mut WorkerCtx,
    now: Instant,
    idle_timeout: Duration,
    progress: &mut bool,
) -> bool {
    // 0. a hello parked in the admission queue resolves here: each
    //    sweep polls the ticket, and the deferred accept (or the Busy
    //    shed) joins the pending queue like any other answer
    if sess.machine.pending_hello_active() {
        let NbSession { machine, pending, .. } = sess;
        let step = machine.poll_admission(state, &mut |m: ToGuest| {
            pending.push_back(PendingAnswer::Ready(m));
        });
        if let Some((accept, keys)) = sess.machine.take_handshake() {
            // a queued keyed hello just admitted. The hello was the
            // session's first meaningful frame, so nothing can be
            // pending ahead of the accept: it emits directly, with rx
            // armed before it leaves and tx after (the accept itself
            // is plaintext)
            sess.conn.arm_secure_rx(keys.guest_to_host);
            emit_to_guest(state, sess, ctx, accept);
            sess.conn.arm_secure_tx(keys.host_to_guest);
        }
        if let Step::Close { clean } = step {
            sess.closing = Some(clean);
        }
    }
    // 0b. emit answers whose pool shards landed since the last sweep —
    //    front-of-queue order, so a still-running walk holds back
    //    everything behind it
    if drain_pending(state, sess, ctx) {
        sess.last_activity = now;
        *progress = true;
    }
    // 1. drain the write backlog first: answers already computed take
    //    priority over new work, and a closing session only waits here
    match sess.conn.flush_pending() {
        Ok(0) => {}
        Ok(_) => {
            sess.last_activity = now;
            *progress = true;
        }
        Err(e) => {
            eprintln!("[sbp-serve] transport error, closing: {e}");
            sess.parkable = true;
            sess.closing = Some(sess.closing.unwrap_or(false));
            // a dead transport still waits for in-flight walks: their
            // answers are queued (unsendably) so the replay buffer and
            // resume cursor stay exact for a later resume
            return sess.pending.is_empty();
        }
    }
    if sess.closing.is_some() {
        // done once the final answers have left — or once a peer that
        // stopped reading them has been silent a whole idle window
        // (the write-side dual of the dead-peer reap)
        return sess.pending.is_empty()
            && (sess.conn.write_idle()
                || (!idle_timeout.is_zero()
                    && now.duration_since(sess.last_activity) >= idle_timeout));
    }
    // 2. read and answer every frame the socket already holds — but
    //    stop reading while the write backlog is past the soft limit,
    //    so a guest that never reads its answers is backpressured at
    //    the socket instead of growing host memory. The pending-answer
    //    cap is the Stage C analogue: a guest pipelining batches faster
    //    than the pool walks them is backpressured the same way instead
    //    of growing the dispatch queue (an honest guest never hits it —
    //    it keeps at most `max_inflight` batches unanswered).
    let pending_cap = state.cfg.max_inflight.max(1) as usize * 2 + 4;
    while sess.closing.is_none()
        && sess.conn.pending_write() < WRITE_SOFT_LIMIT
        && sess.pending.len() < pending_cap
    {
        match sess.conn.poll_frame() {
            Ok(RecvPoll::Frame) => {
                *progress = true;
                sess.last_activity = now;
                let payload = sess.conn.frame_payload();
                let wire_len = (payload.len() + codec::FRAME_HEADER_LEN) as u64;
                // serving frames carry no ciphertexts, so no Setup
                // state is needed to decode them
                let msg = match codec::decode_to_host(None, payload) {
                    Ok(m) => m,
                    Err(e) => {
                        eprintln!("[sbp-host] malformed frame, closing: {e}");
                        sess.closing = Some(false);
                        break;
                    }
                };
                sess.conn.consume_frame();
                let resume = match &msg {
                    ToHost::SessionResume { session, last_acked_chunk } => {
                        Some((*session, *last_acked_chunk, None))
                    }
                    ToHost::SessionResumeSecure { session, last_acked_chunk, pubkey } => {
                        Some((*session, *last_acked_chunk, Some(*pubkey)))
                    }
                    _ => None,
                };
                if let Some((session, last_acked_chunk, guest_pk)) = resume {
                    // handled by the reactor, not the protocol machine:
                    // resuming swaps a parked machine into this slot
                    if !resume_session(state, sess, ctx, session, last_acked_chunk, guest_pk, wire_len)
                    {
                        // nothing (valid) to resume — close; the guest
                        // backs off and retries until the dying
                        // connection has actually been parked
                        sess.closing = Some(false);
                    }
                    continue;
                }
                sess.counters.record_to_host(msg.kind(), wire_len);
                match msg {
                    ToHost::PredictRoute { session, chunk, queries } => {
                        // intercepted before the protocol machine: the
                        // serial half runs here in frame order, the
                        // pure walk goes to the Stage C pool (or inline
                        // below the shard threshold) — either way the
                        // answer joins the pending queue, never
                        // skipping ahead
                        let t0 = Instant::now();
                        match sess.machine.route_serial(state, session, chunk, queries) {
                            Ok(walk) => dispatch_route(state, sess, walk, t0),
                            Err(()) => sess.closing = Some(false),
                        }
                    }
                    other => {
                        let NbSession { machine, pending, .. } = sess;
                        let step = machine.on_frame(state, other, &mut |m: ToGuest| {
                            pending.push_back(PendingAnswer::Ready(m));
                        });
                        if let Some((accept, keys)) = sess.machine.take_handshake() {
                            // keyed handshake completed on this frame:
                            // the machine rejects a late keyed hello,
                            // so the pending queue is empty and the
                            // accept emits directly — rx armed before
                            // it leaves, tx after (accept plaintext)
                            sess.conn.arm_secure_rx(keys.guest_to_host);
                            emit_to_guest(state, sess, ctx, accept);
                            sess.conn.arm_secure_tx(keys.host_to_guest);
                        }
                        if let Step::Close { clean } = step {
                            sess.closing = Some(clean);
                        }
                    }
                }
                // emit whatever became ready before reading the next
                // frame — the common (inline) case leaves this sweep
                // with the same frame-in/answer-out cadence as before
                if drain_pending(state, sess, ctx) {
                    sess.last_activity = now;
                    *progress = true;
                }
            }
            Ok(RecvPoll::Pending) => break,
            Ok(RecvPoll::Closed) => {
                // FIN without SessionClose: transport close, not clean
                sess.parkable = true;
                sess.closing = Some(false);
            }
            Err(e) => {
                eprintln!("[sbp-host] transport error, closing: {e}");
                sess.parkable = true;
                sess.closing = Some(false);
            }
        }
    }
    // 3. push what this sweep produced toward the kernel
    match sess.conn.flush_pending() {
        Ok(0) => {}
        Ok(_) => {
            sess.last_activity = now;
            *progress = true;
        }
        Err(e) => {
            eprintln!("[sbp-serve] transport error, closing: {e}");
            sess.parkable = true;
            sess.closing = Some(sess.closing.unwrap_or(false));
            return sess.pending.is_empty();
        }
    }
    if sess.closing.is_some() {
        // done once the final answers have left — or once a peer that
        // stopped reading them has been silent a whole idle window
        // (the write-side dual of the dead-peer reap)
        return sess.pending.is_empty()
            && (sess.conn.write_idle()
                || (!idle_timeout.is_zero()
                    && now.duration_since(sess.last_activity) >= idle_timeout));
    }
    // 4. dead-peer reaping: a whole idle window with no frame at all —
    //    no batch, no KeepAlive — means the peer is presumed gone. The
    //    write drain is skipped deliberately: there is no one reading.
    //    (With an answer still pending the session is not idle — it
    //    owes the peer a frame — so reaping waits for the drain. A
    //    hello queued for admission is likewise not idle: the guest is
    //    waiting on *us*, bounded by the queue deadline instead.)
    if sess.pending.is_empty()
        && !sess.machine.pending_hello_active()
        && !idle_timeout.is_zero()
        && now.duration_since(sess.last_activity) >= idle_timeout
    {
        eprintln!(
            "[sbp-serve] session {} idle past {:?} with no keep-alive, reaping",
            sess.machine.session_id, idle_timeout
        );
        sess.idle_reaped = true;
        sess.closing = Some(false);
        return true;
    }
    false
}

/// Resolve one batch's walk for a reactor session: the cache lookup
/// pass runs serially here (two sessions contend for microseconds of
/// map probes, never compute — see [`HostServeState::route_plan`]),
/// then the pure walk either runs inline — batches below
/// `compute_shard_min` must not pay dispatch latency — or fans out to
/// the Stage C pool as fire-and-forget shard jobs while this sweep
/// thread goes straight back to polling sockets. Either way the answer
/// joins the session's pending queue, which is what preserves frame
/// order: a fanned-out batch parks a [`PendingAnswer::Compute`] at its
/// queue position and nothing behind it emits first.
fn dispatch_route(
    state: &Arc<HostServeState>,
    sess: &mut NbSession,
    walk: RouteWalk,
    started: Instant,
) {
    let RouteWalk { session, chunk, n, n_known, fresh } = walk;
    let (plan, keys) = state.route_plan(fresh);
    match state.shard_geometry(keys.len()) {
        Some((shard_len, n_shards)) => {
            // even n_shards == 1 goes to the pool here: the point is to
            // get the walk off the sweep thread, so one hot session
            // cannot freeze its shard's neighbors
            state.compute_jobs.fetch_add(n_shards as u64, Ordering::Relaxed);
            state.compute_sharded_batches.fetch_add(1, Ordering::Relaxed);
            sess.machine.compute_jobs += n_shards as u64;
            sess.machine.compute_sharded_batches += 1;
            let keys = Arc::new(keys);
            let shards = Arc::new(ShardResults {
                slots: (0..n_shards).map(|_| OnceLock::new()).collect(),
                remaining: AtomicUsize::new(n_shards),
            });
            for s in 0..n_shards {
                let st = Arc::clone(state);
                let keys = Arc::clone(&keys);
                let res = Arc::clone(&shards);
                state.pool().submit(move || {
                    let a = s * shard_len;
                    let b = (a + shard_len).min(keys.len());
                    // a panicking walk must still count its shard down
                    // or the sweep would wait forever; the empty slot
                    // is the poison marker the drain detects
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        st.walk_packed(&keys[a..b])
                    }));
                    if let Ok(bytes) = out {
                        let _ = res.slots[s].set(bytes);
                    }
                    res.remaining.fetch_sub(1, Ordering::Release);
                });
            }
            sess.pending.push_back(PendingAnswer::Compute(PendingCompute {
                session,
                chunk,
                n,
                n_known,
                plan,
                keys,
                shards,
                started,
            }));
        }
        None => {
            let walked = state.walk_packed(&keys);
            let bits = state.finish_route(plan, &keys, walked);
            sess.pending.push_back(PendingAnswer::Ready(SessionMachine::route_answer(
                session, chunk, n, n_known, bits,
            )));
            state.note_service(started.elapsed());
        }
    }
}

/// Emit everything at the front of the session's pending queue that is
/// ready — `Ready` frames immediately, `Compute` entries once their
/// last shard has landed. Stops at the first still-running walk: that
/// is the re-sequencing, nothing behind it can leave early, so per-link
/// answer order equals frame order no matter which shards finish first.
/// Returns whether anything was emitted.
fn drain_pending(state: &Arc<HostServeState>, sess: &mut NbSession, ctx: &mut WorkerCtx) -> bool {
    let mut emitted = false;
    loop {
        match sess.pending.front() {
            None => break,
            Some(PendingAnswer::Ready(_)) => {
                let Some(PendingAnswer::Ready(m)) = sess.pending.pop_front() else {
                    unreachable!("front was Ready")
                };
                emit_to_guest(state, sess, ctx, m);
                emitted = true;
            }
            Some(PendingAnswer::Compute(pc)) => {
                if pc.shards.remaining.load(Ordering::Acquire) != 0 {
                    break; // walk still out on the pool
                }
                let Some(PendingAnswer::Compute(pc)) = sess.pending.pop_front() else {
                    unreachable!("front was Compute")
                };
                // every shard starts at a multiple of 8 queries, so
                // each sub-bitmap is a whole number of bytes of the
                // global packing: concatenation *is* recombination
                let mut walked = Vec::with_capacity(pc.keys.len().div_ceil(8));
                let mut poisoned = false;
                for slot in &pc.shards.slots {
                    match slot.get() {
                        Some(bytes) => walked.extend_from_slice(bytes),
                        None => {
                            poisoned = true;
                            break;
                        }
                    }
                }
                if poisoned {
                    // a shard job panicked — impossible for an in-range
                    // batch (the walk is total), but if it happens the
                    // batch cannot be answered and the session cannot
                    // park (its resume cursor would desync): report it
                    // dead on the spot
                    eprintln!(
                        "[sbp-serve] compute shard panicked on session {}, closing",
                        sess.machine.session_id
                    );
                    sess.pending.clear();
                    sess.parkable = false;
                    sess.closing = Some(false);
                    return emitted;
                }
                let bits = state.finish_route(pc.plan, &pc.keys, walked);
                let m = SessionMachine::route_answer(pc.session, pc.chunk, pc.n, pc.n_known, bits);
                emit_to_guest(state, sess, ctx, m);
                state.note_service(pc.started.elapsed());
                emitted = true;
            }
        }
    }
    emitted
}

/// Encode one frame onto the session's connection with the byte
/// accounting and resume bookkeeping the read loop used to do inline.
/// Emission time is when a frame becomes real — the resume cursor, the
/// basis epoch, and the replay buffer all advance here, in emission
/// order, so a batch that took the Stage C detour is indistinguishable
/// from an inline one by the time it reaches the wire (or the replay
/// buffer of a session parked before the wire took it).
fn emit_to_guest(state: &HostServeState, sess: &mut NbSession, ctx: &mut WorkerCtx, m: ToGuest) {
    codec::encode_to_guest_into(&ctx.suite, ctx.ct_len, &m, &mut ctx.scratch);
    sess.counters
        .record_to_guest(m.kind(), (ctx.scratch.len() + codec::FRAME_HEADER_LEN) as u64);
    sess.conn.queue_frame(&ctx.scratch);
    // replay buffering is v4-only and costs nothing when resumption is
    // off or the peer cannot resume; hello state is stable by the time
    // any answer emits, so evaluating it here matches the inline path
    let buffer_replay = !state.cfg.resume_window.is_zero()
        && sess.machine.hello_seen
        && sess.machine.negotiated >= SERVE_PROTOCOL_V4;
    let basis_on = sess.machine.basis.capacity() > 0;
    // track the resume cursor and the basis epoch from the emitted
    // frames themselves — the exact arithmetic the guest's mirror runs,
    // so the two cross-check on resume
    let (is_answer, inserted) = match &m {
        ToGuest::RouteAnswers { n, .. } => (true, if basis_on { *n as u64 } else { 0 }),
        ToGuest::RouteAnswersDelta { n, n_known, .. } => (true, (*n - *n_known) as u64),
        _ => (false, 0),
    };
    if is_answer {
        sess.answers_sent += 1;
        if buffer_replay {
            sess.replay.push_back(ReplayEntry {
                kind: m.kind(),
                epoch_before: sess.basis_inserts,
                bytes: ctx.scratch.clone(),
            });
            let replay_cap = replay_retain_cap(&state.cfg);
            while sess.replay.len() > replay_cap {
                sess.replay.pop_front();
            }
        }
        sess.basis_inserts += inserted;
    }
}

/// Swap a parked session's state into the connection that presented a
/// valid [`ToHost::SessionResume`] (or, for a keyed session, a
/// [`ToHost::SessionResumeSecure`] carrying a fresh guest public key),
/// emit the resume-accept handshake, and queue the un-acknowledged
/// answer frames. A keyed resume derives **fresh** AEAD keys for the
/// new connection — retained answers were stored as plaintext, so the
/// replay re-seals them with fresh nonces at queue time and never
/// re-uses a nonce from the dead connection — while the session's
/// handle rotor carries over unchanged (the guest's memoized routes
/// rotate under it). Returns `false` (and leaves any parked state
/// untouched, for the expiry sweep to report) when there is nothing
/// valid to resume — a fresh close is the defined answer and the
/// guest's retry loop covers the park race.
fn resume_session(
    state: &HostServeState,
    sess: &mut NbSession,
    ctx: &mut WorkerCtx,
    session: u32,
    last_acked_chunk: u32,
    guest_pk: Option<[u8; PUBKEY_LEN]>,
    wire_len: u64,
) -> bool {
    // the DH runs before any parked state moves: a degenerate client
    // public key must leave the parked session intact for a correct
    // retry to claim
    let fresh_keys = match guest_pk {
        None => None,
        Some(gpk) => {
            let mut rng = ChaCha20Rng::from_os_entropy();
            let (sk, host_pk) = keypair(&mut rng);
            let Some(shared) = shared_secret(&sk, &gpk) else {
                eprintln!(
                    "[sbp-serve] degenerate client public key in SessionResumeSecure, closing"
                );
                return false;
            };
            Some((host_pk, derive_session_keys(&shared)))
        }
    };
    // only the very first frame of a fresh connection may resume (a
    // hello still queued for admission counts as mid-session too)
    if sess.machine.hello_seen
        || sess.machine.pending_hello_active()
        || sess.machine.batches > 0
        || sess.machine.keep_alives > 0
        || sess.resumes > 0
    {
        eprintln!(
            "[sbp-serve] SessionResume mid-session on session {}, closing",
            sess.machine.session_id
        );
        return false;
    }
    let window = state.cfg.resume_window;
    if window.is_zero() {
        eprintln!("[sbp-serve] SessionResume for {session} but resumption is disabled, closing");
        return false;
    }
    let parked = {
        let mut map = state.parked_lock();
        let Some(p) = map.get(&session) else {
            eprintln!("[sbp-serve] SessionResume for unknown/unparked session {session}, closing");
            return false;
        };
        // a session resumes with the channel kind it handshook: a
        // plaintext resume of a keyed session would leak what the
        // session encrypted, a keyed resume of a plaintext session has
        // no rotor for its routes — both close, parked state untouched
        if p.machine.rotor.is_some() != fresh_keys.is_some() {
            eprintln!(
                "[sbp-serve] resume channel kind mismatch for session {session} \
                 (keyed session: {}), closing",
                p.machine.rotor.is_some()
            );
            return false;
        }
        if p.parked_at.elapsed() > window {
            // expired but not yet swept: the sweep owns reporting it
            eprintln!("[sbp-serve] SessionResume for expired session {session}, closing");
            return false;
        }
        let acked = last_acked_chunk as u64;
        if acked > p.answers_sent || p.answers_sent - acked > p.replay.len() as u64 {
            eprintln!(
                "[sbp-serve] SessionResume for {session} acks {acked} of {} answers with {} \
                 retained, cannot replay — closing",
                p.answers_sent,
                p.replay.len()
            );
            return false;
        }
        map.remove(&session).expect("parked entry vanished under the lock")
    };
    sess.machine = parked.machine;
    sess.counters = parked.counters;
    sess.answers_sent = parked.answers_sent;
    sess.basis_inserts = parked.basis_inserts;
    sess.replay = parked.replay;
    sess.resumes = parked.resumes + 1;
    sess.t0 = parked.t0;
    // a valid resume inside the window is **never shed**: the session
    // already paid admission at its hello (its slot was released at
    // park), so it re-acquires by force even past the live limit
    if state.admission.enabled() {
        state.admission.force_admit();
        sess.machine.admitted = true;
    }
    sess.counters.record_to_host(
        if fresh_keys.is_some() {
            ToHostKind::SessionResumeSecure
        } else {
            ToHostKind::SessionResume
        },
        wire_len,
    );
    // drop what the guest confirmed; everything left replays, in order
    while sess.replay.len() as u64 > sess.answers_sent - last_acked_chunk as u64 {
        sess.replay.pop_front();
    }
    let basis_epoch = match sess.replay.front() {
        Some(first) => first.epoch_before as u32,
        None => sess.basis_inserts as u32,
    };
    let next_chunk = (sess.answers_sent + 1) as u32;
    let accept = match &fresh_keys {
        None => ToGuest::ResumeAccept { next_chunk, basis_epoch },
        Some((host_pk, _)) => {
            ToGuest::ResumeAcceptSecure { next_chunk, basis_epoch, pubkey: *host_pk }
        }
    };
    codec::encode_to_guest_into(&ctx.suite, ctx.ct_len, &accept, &mut ctx.scratch);
    sess.counters
        .record_to_guest(accept.kind(), (ctx.scratch.len() + codec::FRAME_HEADER_LEN) as u64);
    // the resume accept is the connection's last plaintext frame: it is
    // queued before the send direction arms, then both directions seal
    // — so every replayed answer below re-enters queue_frame as
    // plaintext and is re-sealed under the *new* keys with fresh nonces
    sess.conn.queue_frame(&ctx.scratch);
    if let Some((_, keys)) = fresh_keys {
        sess.conn.arm_secure_rx(keys.guest_to_host);
        sess.conn.arm_secure_tx(keys.host_to_guest);
    }
    for entry in &sess.replay {
        sess.counters
            .record_to_guest(entry.kind, (entry.bytes.len() + codec::FRAME_HEADER_LEN) as u64);
        sess.conn.queue_frame(&entry.bytes);
    }
    state.sessions_resumed.fetch_add(1, Ordering::Relaxed);
    eprintln!(
        "[sbp-serve] session {session} resumed from {} (replaying {} answer frames)",
        sess.peer,
        sess.replay.len()
    );
    true
}

/// Park an uncleanly dead v4 session instead of reporting it, when
/// eligible; returns the session back when it is not (the caller then
/// finalizes normally). A second unclean death under the same id
/// replaces the unreachable older parked state, which is reported on
/// the spot — once, like every session.
fn try_park(
    state: &HostServeState,
    mut sess: NbSession,
    accum: &Arc<Mutex<LoopAccum>>,
    wake: SocketAddr,
    max_sessions: usize,
) -> Option<NbSession> {
    let eligible = !state.cfg.resume_window.is_zero()
        && sess.parkable
        && !sess.idle_reaped
        && sess.closing == Some(false)
        && sess.machine.hello_seen
        && sess.machine.negotiated >= SERVE_PROTOCOL_V4
        && !state.stop_requested();
    if !eligible {
        return Some(sess);
    }
    let sid = sess.machine.session_id;
    sess.conn.shutdown();
    // a parked session consumes no serving capacity: its admission slot
    // frees for the outage and a resume re-acquires by force
    sess.machine.admission_release(state);
    eprintln!("[sbp-serve] session {sid} disconnected uncleanly, parking for resume");
    let parked = ParkedSession {
        machine: sess.machine,
        counters: sess.counters,
        answers_sent: sess.answers_sent,
        basis_inserts: sess.basis_inserts,
        replay: sess.replay,
        resumes: sess.resumes,
        t0: sess.t0,
        parked_at: Instant::now(),
        peer: sess.peer,
    };
    let displaced = state.parked_lock().insert(sid, parked);
    if let Some(old) = displaced {
        eprintln!("[sbp-serve] session {sid} parked again before resuming, reporting the old state");
        expire_parked(state, old, accum, wake, max_sessions);
    }
    None
}

/// Report a parked session that will never resume (window expired, loop
/// drained, or displaced by a newer park under the same id). This is
/// the session's **only** report — parking deferred it, nothing else
/// emitted one.
fn expire_parked(
    state: &HostServeState,
    parked: ParkedSession,
    accum: &Arc<Mutex<LoopAccum>>,
    wake: SocketAddr,
    max_sessions: usize,
) {
    state.sessions_resume_expired.fetch_add(1, Ordering::Relaxed);
    let outcome =
        parked.machine.outcome(false, false, parked.t0.elapsed().as_secs_f64(), 0, 0.0, 0.0);
    if !outcome.is_control_only() {
        state.sessions_served.fetch_add(1, Ordering::Relaxed);
        if let Ok(mut acc) = accum.lock() {
            let comm = parked.counters.snapshot();
            acc.comm = acc.comm.add(&comm);
            acc.sessions.push(SessionReport { outcome, peer: parked.peer.to_string(), comm });
            if acc.sessions.len() > RETAINED_SESSION_REPORTS {
                acc.sessions.remove(0);
                acc.dropped += 1;
            }
        }
    }
    if state.stop_requested() || budget_met(state, max_sessions) {
        let _ = TcpStream::connect(wake);
    }
}

/// Give up on parked sessions whose resume window has run out. Any
/// worker may run this; `try_lock` keeps it off the hot path's critical
/// section — a missed sweep is just retried next loop.
fn sweep_parked(
    state: &HostServeState,
    accum: &Arc<Mutex<LoopAccum>>,
    wake: SocketAddr,
    max_sessions: usize,
) {
    let window = state.cfg.resume_window;
    if window.is_zero() {
        return;
    }
    let expired: Vec<ParkedSession> = {
        let mut map = match state.parked.try_lock() {
            Ok(map) => map,
            // recover a poisoned map like parked_lock(); a contended
            // one is simply some other worker already sweeping
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => return,
        };
        if map.is_empty() {
            return;
        }
        let dead: Vec<u32> = map
            .iter()
            .filter(|(_, p)| p.parked_at.elapsed() > window)
            .map(|(id, _)| *id)
            .collect();
        dead.into_iter().filter_map(|id| map.remove(&id)).collect()
    };
    for p in expired {
        eprintln!(
            "[sbp-serve] parked session {} saw no resume inside {:?}, giving it up",
            p.machine.session_id, window
        );
        expire_parked(state, p, accum, wake, max_sessions);
    }
}

/// Retire a finished shard session: close the socket, assemble its
/// outcome, account it, and poke the accept loop if the service should
/// now wind down. Uncleanly dead v4 sessions detour through the parked
/// store first — for them this call is deferred to their final close,
/// resume-window expiry, or loop drain, whichever ends the session.
fn finalize_session(
    state: &HostServeState,
    sess: NbSession,
    accum: &Arc<Mutex<LoopAccum>>,
    wake: SocketAddr,
    max_sessions: usize,
) {
    let Some(mut sess) = try_park(state, sess, accum, wake, max_sessions) else {
        return;
    };
    // the slot frees (or a still-queued ticket cancels) exactly once,
    // however the session ended
    sess.machine.abandon_admission(state);
    sess.conn.shutdown();
    // ring/stall metrics are the threaded pipeline's; the reactor has
    // no per-session ring, so they are structurally zero here
    let outcome = sess.machine.outcome(
        sess.closing.unwrap_or(false) && !sess.idle_reaped,
        sess.idle_reaped,
        sess.t0.elapsed().as_secs_f64(),
        0,
        0.0,
        0.0,
    );
    if sess.idle_reaped {
        state.sessions_idle_reaped.fetch_add(1, Ordering::Relaxed);
    }
    // control-only connections are not serving sessions — keep them
    // out of the counters, reports, and the session budget
    if !outcome.is_control_only() {
        state.sessions_served.fetch_add(1, Ordering::Relaxed);
        if let Ok(mut acc) = accum.lock() {
            let comm = sess.counters.snapshot();
            acc.comm = acc.comm.add(&comm);
            acc.sessions.push(SessionReport { outcome, peer: sess.peer.to_string(), comm });
            if acc.sessions.len() > RETAINED_SESSION_REPORTS {
                acc.sessions.remove(0);
                acc.dropped += 1;
            }
        }
    }
    if state.stop_requested() || budget_met(state, max_sessions) {
        // poke the accept loop awake so it sees the state
        let _ = TcpStream::connect(wake);
    }
}

/// The loop's session budget: `max_sessions` completed serving sessions
/// (0 = unlimited). One definition shared by the accept loop and the
/// session threads' wake-up check.
fn budget_met(state: &HostServeState, max_sessions: usize) -> bool {
    max_sessions != 0 && state.sessions_served() >= max_sessions as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::federation::transport::link_pair_bounded;

    fn toy_state(cache_capacity: usize) -> Arc<HostServeState> {
        // two splits over two features; 4 rows
        let model = HostModel { party: 0, splits: vec![(0, 0, 1.0), (1, 2, -1.0)] };
        let slice = PartySlice {
            cols: vec![0, 1],
            x: vec![0.5, 0.0, 2.0, -2.0, 0.5, 5.0, 2.0, -1.5],
            n: 4,
        };
        HostServeState::new(
            model,
            slice,
            ServeConfig { cache_capacity, ..ServeConfig::default() },
        )
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let c = RoutingCache::new(2);
        c.store((0, 0), true);
        c.store((1, 0), false);
        assert_eq!(c.lookup((0, 0)), Some(true)); // refresh (0,0)
        c.store((2, 0), true); // evicts (1,0)
        assert_eq!(c.lookup((1, 0)), None);
        assert_eq!(c.lookup((0, 0)), Some(true));
        assert_eq!(c.lookup((2, 0)), Some(true));
        let s = c.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.hits, 4);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn zero_capacity_cache_is_inert() {
        let c = RoutingCache::new(0);
        c.store((0, 0), true);
        assert_eq!(c.lookup((0, 0)), None);
        assert_eq!(c.stats(), CacheStats::default());
    }

    #[test]
    fn session_state_machine_handshake_and_answers() {
        let state = toy_state(16);
        let (guest, host) = link_pair_bounded(8, 1);
        let handle = spawn_serve_session(state.clone(), host);

        guest.send(ToHost::SessionHello { session_id: 7, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept {
            session_id,
            max_inflight,
            delta_window,
            protocol,
            basis_evict,
        } = guest.recv()
        else {
            panic!("expected SessionAccept")
        };
        assert_eq!(session_id, 7);
        assert_eq!(max_inflight, 8);
        assert_eq!(delta_window, 1 << 16);
        assert_eq!(protocol, SERVE_PROTOCOL_VERSION);
        assert_eq!(basis_evict, BasisEvict::Lru, "v3 default negotiates the LRU basis");

        guest.send(ToHost::KeepAlive);
        assert!(matches!(guest.recv(), ToGuest::Ack));

        // row 1 under handle 0: x[1*2+0] = 2.0 > 1.0 → right;
        // row 1 under handle 1: x[1*2+1] = -2.0 ≤ -1.0 → left
        guest.send(ToHost::PredictRoute {
            session: 7,
            chunk: 1,
            queries: vec![(1, 0), (1, 1)],
        });
        let ToGuest::RouteAnswers { session, chunk, n, bits } = guest.recv() else {
            panic!("expected RouteAnswers")
        };
        assert_eq!((session, chunk, n), (7, 1, 2));
        assert_eq!(bits, vec![0b10]);

        // repeat: both keys are in the session's delta basis now, so the
        // answers are elided from the wire entirely — the guest's
        // mirrored basis reconstructs them bit-identically
        guest.send(ToHost::PredictRoute {
            session: 7,
            chunk: 2,
            queries: vec![(1, 0), (1, 1)],
        });
        let ToGuest::RouteAnswersDelta { session, chunk, n, n_known, bits } = guest.recv()
        else {
            panic!("expected RouteAnswersDelta for a fully repeated batch")
        };
        assert_eq!((session, chunk, n, n_known), (7, 2, 2, 2));
        assert!(bits.is_empty(), "all answers elided");
        guest.send(ToHost::SessionClose { session_id: 7 });
        let outcome = handle.join().expect("session thread");
        assert!(outcome.clean_close);
        assert_eq!(outcome.queries, 4);
        assert_eq!(outcome.batches, 2);
        assert_eq!(outcome.keep_alives, 1);
        assert_eq!(outcome.answers_elided, 2);
        assert_eq!(outcome.protocol, SERVE_PROTOCOL_VERSION);
        assert_eq!(outcome.basis_evict, BasisEvict::Lru);
        assert!(
            outcome.ring_high_water >= 1 && outcome.ring_high_water <= 8,
            "decode ring occupancy bounded by max_inflight, got {}",
            outcome.ring_high_water
        );
        // the elided repeats never touched the cache: 2 misses, 0 hits
        let cs = state.cache_stats();
        assert_eq!(cs.hits, 0);
        assert_eq!(cs.misses, 2);
        assert_eq!(state.answers_elided(), 2);
    }

    #[test]
    fn delta_off_answers_repeats_in_full_through_the_cache() {
        // delta_window = 0: the pre-suppression behavior — repeats are
        // re-answered in full, the second batch hitting the shared cache
        let model = HostModel { party: 0, splits: vec![(0, 0, 1.0), (1, 2, -1.0)] };
        let slice = PartySlice {
            cols: vec![0, 1],
            x: vec![0.5, 0.0, 2.0, -2.0, 0.5, 5.0, 2.0, -1.5],
            n: 4,
        };
        let state = HostServeState::new(
            model,
            slice,
            ServeConfig { cache_capacity: 16, delta_window: 0, ..ServeConfig::default() },
        );
        let (guest, host) = link_pair_bounded(8, 1);
        let handle = spawn_serve_session(state.clone(), host);
        guest.send(ToHost::SessionHello { session_id: 3, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept { delta_window, .. } = guest.recv() else {
            panic!("expected SessionAccept")
        };
        assert_eq!(delta_window, 0);
        for chunk in [1u32, 2] {
            guest.send(ToHost::PredictRoute {
                session: 3,
                chunk,
                queries: vec![(1, 0), (1, 1)],
            });
            let ToGuest::RouteAnswers { bits, .. } = guest.recv() else {
                panic!("expected RouteAnswers (delta off)")
            };
            assert_eq!(bits, vec![0b10]);
        }
        guest.send(ToHost::SessionClose { session_id: 3 });
        let outcome = handle.join().expect("session thread");
        assert_eq!(outcome.answers_elided, 0);
        let cs = state.cache_stats();
        assert_eq!(cs.hits, 2);
        assert_eq!(cs.misses, 2);
    }

    #[test]
    fn zero_query_batch_is_answered_not_rejected() {
        let state = toy_state(0);
        let (guest, host) = link_pair_bounded(8, 1);
        let handle = spawn_serve_session(state, host);
        guest.send(ToHost::SessionHello { session_id: 5, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept { .. } = guest.recv() else { panic!("expected accept") };
        // a streaming tail with nothing to ask this host is still a
        // well-formed batch and gets a well-formed (empty) answer
        guest.send(ToHost::PredictRoute { session: 5, chunk: 9, queries: Vec::new() });
        let ToGuest::RouteAnswers { session, chunk, n, bits } = guest.recv() else {
            panic!("expected RouteAnswers")
        };
        assert_eq!((session, chunk, n), (5, 9, 0));
        assert!(bits.is_empty());
        guest.send(ToHost::SessionClose { session_id: 5 });
        let outcome = handle.join().expect("session thread");
        assert!(outcome.clean_close);
        assert_eq!(outcome.batches, 1);
        assert_eq!(outcome.queries, 0);
    }

    #[test]
    fn wrong_session_id_closes_the_session() {
        let state = toy_state(0);
        let (guest, host) = link_pair_bounded(8, 1);
        let handle = spawn_serve_session(state, host);
        guest.send(ToHost::SessionHello { session_id: 9, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept { .. } = guest.recv() else { panic!("expected accept") };
        guest.send(ToHost::PredictRoute { session: 3, chunk: 0, queries: vec![(0, 0)] });
        let outcome = handle.join().expect("session thread");
        assert!(!outcome.clean_close);
        assert_eq!(outcome.batches, 0);
    }

    #[test]
    fn helloless_tagged_frames_adopt_the_first_session_id() {
        // a PredictSession that never opened a handshake still tags its
        // frames; the first batch fixes the id, mixing ids afterwards
        // closes the session, and handshake-gated features stay off
        let state = toy_state(0);
        let (guest, host) = link_pair_bounded(8, 1);
        let handle = spawn_serve_session(state, host);
        guest.send(ToHost::PredictRoute { session: 42, chunk: 0, queries: vec![(0, 0)] });
        let ToGuest::RouteAnswers { session, n, bits, .. } = guest.recv() else {
            panic!("expected RouteAnswers")
        };
        assert_eq!((session, n, bits), (42, 1, vec![1u8]), "adopted id echoed");
        // same id again: served; a different id: closed
        guest.send(ToHost::PredictRoute { session: 42, chunk: 1, queries: vec![(0, 1)] });
        let ToGuest::RouteAnswers { .. } = guest.recv() else { panic!("expected answer") };
        guest.send(ToHost::PredictRoute { session: 7, chunk: 2, queries: vec![(0, 0)] });
        let outcome = handle.join().expect("session thread");
        assert!(!outcome.clean_close, "mixing ids is still a protocol error");
        assert_eq!(outcome.session_id, 42);
        assert_eq!(outcome.batches, 2);
        assert_eq!(outcome.protocol, 0, "no handshake, no negotiated protocol");
        assert_eq!(outcome.answers_elided, 0, "delta stays off without a handshake");
    }

    #[test]
    fn v2_hello_negotiated_down_to_frozen_basis() {
        let state = toy_state(0);
        let (guest, host) = link_pair_bounded(8, 1);
        let handle = spawn_serve_session(state, host);
        guest.send(ToHost::SessionHello { session_id: 4, protocol: SERVE_PROTOCOL_V2 });
        let ToGuest::SessionAccept { session_id, protocol, basis_evict, .. } = guest.recv()
        else {
            panic!("expected SessionAccept")
        };
        assert_eq!(session_id, 4);
        assert_eq!(protocol, SERVE_PROTOCOL_V2, "host negotiates the session down");
        assert_eq!(basis_evict, BasisEvict::Freeze, "v2 sessions always freeze");
        guest.send(ToHost::SessionClose { session_id: 4 });
        let outcome = handle.join().expect("session thread");
        assert!(outcome.clean_close);
        assert_eq!(outcome.protocol, SERVE_PROTOCOL_V2);
        assert_eq!(outcome.basis_evict, BasisEvict::Freeze);
    }

    #[test]
    fn lru_basis_keeps_eliding_past_the_window_where_freeze_stops() {
        // working set of 3 keys through a 2-entry basis: the frozen
        // basis never admits the third key, so its repeats are re-sent
        // forever; the LRU basis rotates and elides the whole repeat
        // batch. Answer *bits* are identical either way — eviction only
        // moves answers between the wire and the mirrored basis.
        let run = |evict: BasisEvict| {
            let model = HostModel { party: 0, splits: vec![(0, 0, 1.0), (1, 2, -1.0)] };
            let slice = PartySlice {
                cols: vec![0, 1],
                x: vec![0.5, 0.0, 2.0, -2.0, 0.5, 5.0, 2.0, -1.5],
                n: 4,
            };
            let state = HostServeState::new(
                model,
                slice,
                ServeConfig {
                    cache_capacity: 0,
                    delta_window: 2,
                    basis_evict: evict,
                    ..ServeConfig::default()
                },
            );
            let (guest, host) = link_pair_bounded(8, 1);
            let handle = spawn_serve_session(state, host);
            guest.send(ToHost::SessionHello { session_id: 6, protocol: SERVE_PROTOCOL_VERSION });
            let ToGuest::SessionAccept { basis_evict, .. } = guest.recv() else {
                panic!("expected accept")
            };
            assert_eq!(basis_evict, evict);
            let mut frames = Vec::new();
            for (chunk, batch) in
                [vec![(0, 0), (1, 0)], vec![(2, 0), (0, 0)], vec![(2, 0), (0, 0)]]
                    .into_iter()
                    .enumerate()
            {
                guest.send(ToHost::PredictRoute {
                    session: 6,
                    chunk: chunk as u32,
                    queries: batch,
                });
                frames.push(guest.recv());
            }
            guest.send(ToHost::SessionClose { session_id: 6 });
            let outcome = handle.join().expect("session thread");
            (frames, outcome)
        };

        let (lru, lru_outcome) = run(BasisEvict::Lru);
        // batch 1: both fresh. batch 2: (0,0) was the LRU victim of
        // (2,0)'s insert, so both re-travel. batch 3: both keys are now
        // the two resident ones — fully elided.
        assert!(matches!(&lru[0], ToGuest::RouteAnswers { bits, .. } if bits[..] == [0b01]));
        assert!(matches!(&lru[1], ToGuest::RouteAnswers { bits, .. } if bits[..] == [0b11]));
        let ToGuest::RouteAnswersDelta { n, n_known, bits, .. } = &lru[2] else {
            panic!("lru batch 3 must be fully elided, got {:?}", lru[2].kind())
        };
        assert_eq!((*n, *n_known), (2, 2));
        assert!(bits.is_empty());
        assert_eq!(lru_outcome.answers_elided, 2);

        let (frz, frz_outcome) = run(BasisEvict::Freeze);
        // the frozen basis holds {(0,0),(1,0)} forever: (2,0) re-pays
        // its bit in every batch, (0,0) is elided in batches 2 and 3
        assert!(matches!(&frz[0], ToGuest::RouteAnswers { bits, .. } if bits[..] == [0b01]));
        for f in &frz[1..] {
            let ToGuest::RouteAnswersDelta { n, n_known, bits, .. } = f else {
                panic!("freeze repeats must be partial deltas, got {:?}", f.kind())
            };
            assert_eq!((*n, *n_known), (2, 1));
            assert_eq!(bits[..], [0b1], "(2,0)'s bit travels again");
        }
        assert_eq!(frz_outcome.answers_elided, 2);
    }

    #[test]
    fn legacy_sessionless_flow_still_served() {
        let state = toy_state(0);
        let (guest, host) = link_pair_bounded(8, 1);
        let handle = spawn_serve_session(state, host);
        guest.send(ToHost::PredictRoute {
            session: SESSIONLESS_ID,
            chunk: 0,
            queries: vec![(0, 0)],
        });
        let ToGuest::RouteAnswers { session, chunk, n, bits } = guest.recv() else {
            panic!("expected RouteAnswers")
        };
        // row 0 under handle 0: x[0] = 0.5 ≤ 1.0 → left
        assert_eq!((session, chunk, n, bits), (SESSIONLESS_ID, 0, 1, vec![1u8]));
        guest.send(ToHost::Shutdown);
        let outcome = handle.join().expect("session thread");
        assert!(outcome.clean_close);
    }

    #[test]
    fn poisoned_routing_cache_recovers_for_later_sessions() {
        let state = toy_state(16);
        // poison the cache lock the way a real incident would: a session
        // thread panics while holding it
        let state2 = state.clone();
        let prev_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // keep the expected panic quiet
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _batch = state2.cache.batch();
            panic!("session dies holding the cache lock");
        }));
        std::panic::set_hook(prev_hook);
        assert!(state.cache.inner.is_poisoned(), "the lock must actually be poisoned");

        // a later session must keep serving through the same cache
        // instead of joining a panic cascade
        let (guest, host) = link_pair_bounded(8, 1);
        let handle = spawn_serve_session(state.clone(), host);
        guest.send(ToHost::SessionHello { session_id: 11, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept { .. } = guest.recv() else { panic!("expected accept") };
        guest.send(ToHost::PredictRoute {
            session: 11,
            chunk: 0,
            queries: vec![(1, 0), (1, 1)],
        });
        let ToGuest::RouteAnswers { bits, .. } = guest.recv() else {
            panic!("expected RouteAnswers through the poisoned cache")
        };
        assert_eq!(bits, vec![0b10]);
        guest.send(ToHost::SessionClose { session_id: 11 });
        let outcome = handle.join().expect("session thread");
        assert!(outcome.clean_close);
        let cs = state.cache_stats();
        assert_eq!(cs.misses, 2, "stats() recovers the poisoned guard too");
    }

    #[test]
    fn threaded_engine_reaps_idle_sessions() {
        let model = HostModel { party: 0, splits: vec![(0, 0, 1.0), (1, 2, -1.0)] };
        let slice = PartySlice {
            cols: vec![0, 1],
            x: vec![0.5, 0.0, 2.0, -2.0, 0.5, 5.0, 2.0, -1.5],
            n: 4,
        };
        let state = HostServeState::new(
            model,
            slice,
            ServeConfig {
                cache_capacity: 0,
                session_idle_timeout: Duration::from_millis(50),
                ..ServeConfig::default()
            },
        );
        let (guest, host) = link_pair_bounded(8, 1);
        let handle = spawn_serve_session(state.clone(), host);
        guest.send(ToHost::SessionHello { session_id: 8, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept { .. } = guest.recv() else { panic!("expected accept") };
        guest.send(ToHost::PredictRoute { session: 8, chunk: 0, queries: Vec::new() });
        let ToGuest::RouteAnswers { .. } = guest.recv() else { panic!("expected answer") };
        // …then silence. The guest holds its link open but never speaks
        // again — indistinguishable from a crashed peer. The session
        // must end by reaping, not hang forever.
        let outcome = handle.join().expect("session thread");
        assert!(outcome.idle_reaped, "the silent session must be reaped");
        assert!(!outcome.clean_close);
        assert_eq!(outcome.batches, 1);
        assert_eq!(state.sessions_idle_reaped(), 1);
        assert_eq!(state.sessions_served(), 1, "a reaped session still served its batch");
        drop(guest);
    }

    #[test]
    fn transient_accept_errors_are_classified() {
        use std::io::{Error, ErrorKind};
        assert!(accept_error_is_transient(&Error::from_raw_os_error(24)), "EMFILE");
        assert!(accept_error_is_transient(&Error::from_raw_os_error(23)), "ENFILE");
        assert!(accept_error_is_transient(&Error::from(ErrorKind::ConnectionAborted)));
        assert!(!accept_error_is_transient(&Error::from(ErrorKind::PermissionDenied)));
        assert!(!accept_error_is_transient(&Error::from(ErrorKind::InvalidInput)));
    }

    #[test]
    fn v3_hello_keeps_the_negotiated_lru_basis() {
        // the protocol bump to v4 must not demote v3 peers to Freeze:
        // the evict gate is "v3 or newer", not "current version"
        let state = toy_state(0);
        let (guest, host) = link_pair_bounded(8, 1);
        let handle = spawn_serve_session(state, host);
        guest.send(ToHost::SessionHello { session_id: 12, protocol: SERVE_PROTOCOL_V3 });
        let ToGuest::SessionAccept { protocol, basis_evict, .. } = guest.recv() else {
            panic!("expected SessionAccept")
        };
        assert_eq!(protocol, SERVE_PROTOCOL_V3, "negotiated down to the peer's version");
        assert_eq!(basis_evict, BasisEvict::Lru, "v3 still runs the configured LRU");
        guest.send(ToHost::SessionClose { session_id: 12 });
        let outcome = handle.join().expect("session thread");
        assert_eq!(outcome.protocol, SERVE_PROTOCOL_V3);
        assert_eq!(outcome.basis_evict, BasisEvict::Lru);
    }

    // ---- reactor resumption tests: a real listener, real sockets, and
    // a guest transport whose connection is killed mid-stream

    use crate::crypto::cipher::CipherSuite as Suite;
    use crate::federation::tcp::TcpGuestTransport;
    use crate::federation::transport::GuestTransport;

    fn spawn_reactor(
        cfg: ServeConfig,
        max_sessions: usize,
    ) -> (String, Arc<HostServeState>, std::thread::JoinHandle<ServeLoopReport>) {
        let model = HostModel { party: 0, splits: vec![(0, 0, 1.0), (1, 2, -1.0)] };
        let slice = PartySlice {
            cols: vec![0, 1],
            x: vec![0.5, 0.0, 2.0, -2.0, 0.5, 5.0, 2.0, -1.5],
            n: 4,
        };
        let state = HostServeState::new(model, slice, cfg);
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind test listener");
        let addr = format!("127.0.0.1:{}", listener.local_addr().unwrap().port());
        let st = Arc::clone(&state);
        let handle = std::thread::Builder::new()
            .name("sbp-test-reactor".into())
            .spawn(move || serve_predict_loop(&listener, &st, max_sessions).expect("serve loop"))
            .expect("spawn test reactor");
        (addr, state, handle)
    }

    fn stop_reactor(
        state: &Arc<HostServeState>,
        addr: &str,
        handle: std::thread::JoinHandle<ServeLoopReport>,
    ) -> ServeLoopReport {
        state.request_stop();
        let _ = TcpStream::connect(addr);
        handle.join().expect("reactor thread")
    }

    fn wait_until(what: &str, pred: impl Fn() -> bool) {
        for _ in 0..600 {
            if pred() {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("timed out waiting for {what}");
    }

    /// Reconnect and run the resume handshake, riding out the park race
    /// (a resume that lands before the dying connection was swept is
    /// answered by a close; retry).
    fn resume_handshake(t: &TcpGuestTransport, session: u32, last_acked: u32) -> (u32, u32) {
        for _ in 0..200 {
            if t.reconnect().is_err() {
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            if t
                .try_send(ToHost::SessionResume { session, last_acked_chunk: last_acked })
                .is_err()
            {
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            match t.try_recv() {
                Ok(ToGuest::ResumeAccept { next_chunk, basis_epoch }) => {
                    return (next_chunk, basis_epoch)
                }
                Ok(other) => panic!("expected ResumeAccept, got {:?}", other.kind()),
                Err(_) => std::thread::sleep(Duration::from_millis(5)),
            }
        }
        panic!("session {session} never resumed");
    }

    #[test]
    fn resume_replays_unacked_answers_and_keeps_the_basis() {
        let (addr, state, handle) = spawn_reactor(
            ServeConfig {
                cache_capacity: 0,
                workers: 2,
                resume_window: Duration::from_secs(5),
                ..ServeConfig::default()
            },
            0,
        );
        let t = TcpGuestTransport::connect(&addr, Suite::new_plain(64)).expect("connect");
        t.send(ToHost::SessionHello { session_id: 21, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept { protocol, .. } = t.recv() else { panic!("expected accept") };
        assert_eq!(protocol, SERVE_PROTOCOL_VERSION);
        t.send(ToHost::PredictRoute { session: 21, chunk: 1, queries: vec![(1, 0), (1, 1)] });
        let ToGuest::RouteAnswers { bits, .. } = t.recv() else { panic!("expected answer 1") };
        assert_eq!(bits, vec![0b10]);
        // second request: the answer is computed and buffered, but this
        // guest dies before reading it
        t.send(ToHost::PredictRoute { session: 21, chunk: 2, queries: vec![(2, 0), (0, 0)] });
        t.kill();

        let (next_chunk, basis_epoch) = resume_handshake(&t, 21, 1);
        assert_eq!(next_chunk, 3, "host had sent 2 answer frames; the next fresh one is #3");
        assert_eq!(basis_epoch, 2, "two keys inserted by the acked frame");
        // the un-acked answer replays byte-identically: both chunk-2
        // keys were fresh, so it was a plain RouteAnswers
        let ToGuest::RouteAnswers { session, chunk, n, bits } = t.recv() else {
            panic!("expected the replayed answer")
        };
        assert_eq!((session, chunk, n), (21, 2, 2));
        assert_eq!(bits, vec![0b11]);
        // basis continuity: a key answered before the disconnect is
        // still known — the parked basis moved with the session
        t.send(ToHost::PredictRoute { session: 21, chunk: 3, queries: vec![(1, 0)] });
        let ToGuest::RouteAnswersDelta { n, n_known, bits, .. } = t.recv() else {
            panic!("expected a fully elided delta after resume")
        };
        assert_eq!((n, n_known), (1, 1));
        assert!(bits.is_empty());
        t.send(ToHost::SessionClose { session_id: 21 });

        wait_until("the session to finish", || state.sessions_served() == 1);
        let report = stop_reactor(&state, &addr, handle);
        assert_eq!(state.sessions_resumed(), 1);
        assert_eq!(state.sessions_resume_expired(), 0);
        assert_eq!(state.sessions_idle_reaped(), 0, "no phantom idle reap");
        assert_eq!(state.sessions_served(), 1, "a resumed session counts once");
        assert_eq!(report.sessions.len(), 1, "…and is reported once");
        let s = &report.sessions[0];
        assert!(s.outcome.clean_close);
        assert_eq!(s.outcome.batches, 3);
        assert_eq!(s.outcome.queries, 5);
    }

    #[test]
    fn parked_session_expires_by_resume_window_while_neighbors_serve_on() {
        // ordering 1: resume window << idle timeout — expiry must come
        // from the window, the idle reaper must never touch the parked
        // session, and a live neighbor session must not be disturbed
        let (addr, state, handle) = spawn_reactor(
            ServeConfig {
                cache_capacity: 0,
                workers: 2,
                resume_window: Duration::from_millis(50),
                session_idle_timeout: Duration::from_secs(10),
                ..ServeConfig::default()
            },
            0,
        );
        let neighbor = TcpGuestTransport::connect(&addr, Suite::new_plain(64)).expect("connect");
        neighbor.send(ToHost::SessionHello { session_id: 33, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept { .. } = neighbor.recv() else { panic!("expected accept") };

        let t = TcpGuestTransport::connect(&addr, Suite::new_plain(64)).expect("connect");
        t.send(ToHost::SessionHello { session_id: 31, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept { .. } = t.recv() else { panic!("expected accept") };
        t.send(ToHost::PredictRoute { session: 31, chunk: 1, queries: vec![(0, 0)] });
        let ToGuest::RouteAnswers { .. } = t.recv() else { panic!("expected answer") };
        t.kill();

        wait_until("the parked session to expire", || state.sessions_resume_expired() == 1);
        assert_eq!(state.sessions_idle_reaped(), 0, "expiry is the window's, not the reaper's");
        assert_eq!(state.sessions_parked(), 0);
        // a resume after expiry finds nothing and is refused cleanly
        let _ = t.reconnect();
        let _ = t.try_send(ToHost::SessionResume { session: 31, last_acked_chunk: 1 });
        assert!(t.try_recv().is_err(), "expired session must not resume");
        // the neighbor kept its session through all of it
        neighbor.send(ToHost::PredictRoute { session: 33, chunk: 1, queries: vec![(1, 1)] });
        let ToGuest::RouteAnswers { bits, .. } = neighbor.recv() else {
            panic!("neighbor session must still serve")
        };
        assert_eq!(bits, vec![0b1]);
        neighbor.send(ToHost::SessionClose { session_id: 33 });
        wait_until("both sessions to be reported", || state.sessions_served() == 2);
        let report = stop_reactor(&state, &addr, handle);
        assert_eq!(report.sessions.len(), 2, "expired + neighbor, each reported once");

        // ordering 2: idle timeout << resume window — the parked
        // session must survive many idle windows untouched
        let (addr, state, handle) = spawn_reactor(
            ServeConfig {
                cache_capacity: 0,
                workers: 2,
                resume_window: Duration::from_secs(10),
                session_idle_timeout: Duration::from_millis(50),
                ..ServeConfig::default()
            },
            0,
        );
        let t = TcpGuestTransport::connect(&addr, Suite::new_plain(64)).expect("connect");
        t.send(ToHost::SessionHello { session_id: 32, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept { .. } = t.recv() else { panic!("expected accept") };
        t.send(ToHost::PredictRoute { session: 32, chunk: 1, queries: vec![(0, 0)] });
        let ToGuest::RouteAnswers { .. } = t.recv() else { panic!("expected answer") };
        t.kill();
        wait_until("the session to park", || state.sessions_parked() == 1);
        std::thread::sleep(Duration::from_millis(300)); // six idle windows
        assert_eq!(state.sessions_parked(), 1, "parked state outlives the idle timeout");
        assert_eq!(state.sessions_idle_reaped(), 0);
        assert_eq!(state.sessions_resume_expired(), 0);
        let report = stop_reactor(&state, &addr, handle);
        // drained at loop end: reported exactly once, as expired
        assert_eq!(state.sessions_resume_expired(), 1);
        assert_eq!(state.sessions_served(), 1);
        assert_eq!(report.sessions.len(), 1);
    }

    #[test]
    fn failed_resume_attempts_are_control_only_and_the_server_stays_healthy() {
        let (addr, state, handle) = spawn_reactor(
            ServeConfig {
                cache_capacity: 0,
                workers: 2,
                resume_window: Duration::from_secs(5),
                ..ServeConfig::default()
            },
            0,
        );
        // resume for a session that was never parked: refused by close
        let t = TcpGuestTransport::connect(&addr, Suite::new_plain(64)).expect("connect");
        assert!(t.try_send(ToHost::SessionResume { session: 999, last_acked_chunk: 0 }).is_ok());
        assert!(t.try_recv().is_err(), "unknown session must not resume");
        // the server is unharmed: a normal session still serves
        let t2 = TcpGuestTransport::connect(&addr, Suite::new_plain(64)).expect("connect");
        t2.send(ToHost::SessionHello { session_id: 41, protocol: SERVE_PROTOCOL_VERSION });
        let ToGuest::SessionAccept { .. } = t2.recv() else { panic!("expected accept") };
        t2.send(ToHost::PredictRoute { session: 41, chunk: 1, queries: vec![(0, 0)] });
        let ToGuest::RouteAnswers { .. } = t2.recv() else { panic!("expected answer") };
        t2.send(ToHost::SessionClose { session_id: 41 });
        wait_until("the real session to finish", || state.sessions_served() == 1);
        let report = stop_reactor(&state, &addr, handle);
        assert_eq!(state.sessions_resumed(), 0);
        assert_eq!(state.sessions_served(), 1, "the failed attempt is control-only");
        assert_eq!(report.sessions.len(), 1);
    }
}
